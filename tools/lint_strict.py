#!/usr/bin/env python3
"""Strict lint gate (`make lint-strict`).

Two checks clippy does not make:

1. **No stray panics on the data/control plane.** `.unwrap()` /
   `.expect(` are denied in non-test code under `rust/src/net/` and
   `rust/src/rpc/` — a worker or the coordinator must degrade with an
   error, not take the whole cluster down with a panic. Intentional
   panic sites (mutex-poisoning policy, platform guarantees) are
   enumerated in `tools/lint_allow.txt` as `path|substring` lines; every
   entry must still match something, so the allowlist cannot rot.

2. **RPC protocol completeness.** The `Request`/`Response` enums in
   `rust/src/rpc/mod.rs` are wire-framed by hand; this check parses the
   encode/decode matches and `handle_request` and asserts:
   every variant has an encode tag, tags are unique, decode covers every
   tag with the same variant<->tag bijection, and every `Request`
   variant is handled by the server dispatch.

Pure stdlib, no third-party deps. Exit 0 = clean.
"""

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
PANIC_SCOPES = [ROOT / "rust/src/net", ROOT / "rust/src/rpc"]
RPC_MOD = ROOT / "rust/src/rpc/mod.rs"
ALLOWLIST = ROOT / "tools/lint_allow.txt"

PANIC_PAT = re.compile(r"\.unwrap\(\)|\.expect\(")


def sanitize(line, in_block_comment):
    """Blank out string literals and comments so panic matches are real
    code. Returns (sanitized_line, in_block_comment_after)."""
    out = []
    i = 0
    n = len(line)
    in_str = False
    while i < n:
        c = line[i]
        if in_block_comment:
            if line.startswith("*/", i):
                in_block_comment = False
                i += 2
            else:
                i += 1
            continue
        if in_str:
            if c == "\\":
                i += 2
                continue
            if c == '"':
                in_str = False
            i += 1
            continue
        if c == '"':
            in_str = True
            out.append(c)
            i += 1
            continue
        if c == "'":
            # char literal: 'x', '\n', '\'' or '"' — skip it whole so a
            # quote char cannot open a phantom string
            m = re.match(r"'(\\.|[^\\'])'", line[i:])
            if m:
                i += m.end()
                continue
            i += 1
            continue
        if line.startswith("//", i):
            break
        if line.startswith("/*", i):
            in_block_comment = True
            i += 2
            continue
        out.append(c)
        i += 1
    return "".join(out), in_block_comment


def non_test_lines(path):
    """Yield (lineno, sanitized_line) outside `#[cfg(test)] mod` blocks."""
    in_block = False
    pending_test_attr = False
    test_depth = None  # brace depth inside a cfg(test) module
    depth = 0
    for lineno, raw in enumerate(path.read_text().splitlines(), 1):
        line, in_block = sanitize(raw, in_block)
        opens = line.count("{")
        closes = line.count("}")
        stripped = line.strip()
        if test_depth is None:
            if "#[cfg(test)]" in stripped:
                pending_test_attr = True
            elif pending_test_attr and stripped.startswith("mod "):
                # the whole module is test code; skip until its brace closes
                test_depth = depth
                pending_test_attr = False
            elif stripped and not stripped.startswith("#["):
                pending_test_attr = False
        depth += opens - closes
        if test_depth is not None:
            if depth <= test_depth:
                test_depth = None
            continue
        yield lineno, line


def load_allowlist():
    entries = []
    if ALLOWLIST.exists():
        for raw in ALLOWLIST.read_text().splitlines():
            line = raw.strip()
            if not line or line.startswith("#"):
                continue
            if "|" not in line:
                sys.exit(f"lint-strict: malformed allowlist line: {line!r}")
            path, substr = line.split("|", 1)
            entries.append({"path": path.strip(), "substr": substr, "hits": 0})
    return entries


def check_panics():
    errors = []
    allow = load_allowlist()
    for scope in PANIC_SCOPES:
        for path in sorted(scope.rglob("*.rs")):
            rel = path.relative_to(ROOT).as_posix()
            prev = ""
            for lineno, line in non_test_lines(path):
                if not PANIC_PAT.search(line):
                    if line.strip():
                        prev = line
                    continue
                # builder chains split one call per line: match the
                # allowlist against the joined tail too, so an entry can
                # say `.lock().unwrap()` about a `.lock()\n.unwrap()`
                context = prev.strip() + line.strip()
                matched = False
                for entry in allow:
                    if rel.endswith(entry["path"]) and (
                        entry["substr"] in line or entry["substr"] in context
                    ):
                        entry["hits"] += 1
                        matched = True
                if line.strip():
                    prev = line
                if not matched:
                    errors.append(
                        f"{rel}:{lineno}: unwrap/expect in non-test "
                        f"net/rpc code: {line.strip()}"
                    )
    for entry in allow:
        if entry["hits"] == 0:
            errors.append(
                f"tools/lint_allow.txt: stale entry "
                f"{entry['path']}|{entry['substr']} matches nothing — remove it"
            )
    return errors


def enum_variants(text, name):
    m = re.search(rf"pub enum {name} \{{", text)
    if not m:
        sys.exit(f"lint-strict: enum {name} not found in {RPC_MOD}")
    body = balanced(text, m.end() - 1)
    variants = []
    depth = 0
    for line in body.splitlines():
        code, _ = sanitize(line, False)
        if depth == 0:
            vm = re.match(r"\s*([A-Z]\w*)\s*(\{|\(|,|$)", code)
            if vm:
                variants.append(vm.group(1))
        depth += code.count("{") - code.count("}")
        depth += code.count("(") - code.count(")")
    return variants


def balanced(text, open_idx):
    """Return the text between the brace at open_idx and its match."""
    depth = 0
    for i in range(open_idx, len(text)):
        if text[i] == "{":
            depth += 1
        elif text[i] == "}":
            depth -= 1
            if depth == 0:
                return text[open_idx + 1 : i]
    sys.exit("lint-strict: unbalanced braces")


def fn_body(text, start_pat):
    m = re.search(start_pat, text)
    if not m:
        sys.exit(f"lint-strict: pattern {start_pat!r} not found in {RPC_MOD}")
    open_idx = text.index("{", m.end())
    return balanced(text, open_idx)


def encode_tags(body, enum):
    """Map variant -> first `w.u8(N)` written in its encode arm."""
    tags = {}
    current = None
    for line in body.splitlines():
        code, _ = sanitize(line, False)
        vm = re.search(rf"{enum}::(\w+)(?:\s*\{{[^}}]*\}}|\s*\([^)]*\))?\s*=>", code)
        if vm:
            current = vm.group(1)
        tm = re.search(r"w\.u8\((\d+)\)", code)
        if tm and current is not None and current not in tags:
            tags[current] = int(tm.group(1))
    return tags


def decode_tags(body, enum):
    """Map tag -> variant from `N => Enum::Variant` decode arms."""
    tags = {}
    for line in body.splitlines():
        code, _ = sanitize(line, False)
        m = re.search(rf"(\d+)\s*=>\s*\{{?\s*$|(\d+)\s*=>\s*{enum}::(\w+)", code)
        dm = re.search(rf"^\s*(\d+)\s*=>", code)
        if not dm:
            continue
        tag = int(dm.group(1))
        vm = re.search(rf"{enum}::(\w+)", code)
        if vm:
            tags[tag] = vm.group(1)
        else:
            tags[tag] = None  # multi-line arm; variant named later
    return tags


def fill_multiline_decode(body, enum, tags):
    """Resolve `N => { ... Enum::Variant { ... } }` multi-line arms."""
    lines = body.splitlines()
    for i, line in enumerate(lines):
        dm = re.search(r"^\s*(\d+)\s*=>\s*\{?\s*$", sanitize(line, False)[0])
        if not dm:
            continue
        tag = int(dm.group(1))
        if tags.get(tag) is not None:
            continue
        for look in lines[i + 1 : i + 30]:
            vm = re.search(rf"{enum}::(\w+)", sanitize(look, False)[0])
            if vm:
                tags[tag] = vm.group(1)
                break
    return tags


def check_protocol():
    errors = []
    text = RPC_MOD.read_text()
    for enum, impl_pat in [
        ("Request", r"impl Request\b"),
        ("Response", r"impl Response\b"),
    ]:
        variants = enum_variants(text, enum)
        if not variants:
            errors.append(f"rpc: no variants parsed for {enum}")
            continue
        impl_body = fn_body(text, impl_pat)
        enc_body = fn_body(impl_body, r"fn encode\b")
        dec_body = fn_body(impl_body, r"fn decode\b")
        enc = encode_tags(enc_body, enum)
        dec = fill_multiline_decode(dec_body, enum, decode_tags(dec_body, enum))
        for v in variants:
            if v not in enc:
                errors.append(f"rpc: {enum}::{v} has no encode frame tag")
        dup = {}
        for v, t in enc.items():
            if t in dup:
                errors.append(
                    f"rpc: {enum}::{v} and {enum}::{dup[t]} share frame tag {t}"
                )
            dup[t] = v
        for v, t in enc.items():
            if dec.get(t) != v:
                errors.append(
                    f"rpc: {enum}::{v} encodes tag {t} but decode arm {t} "
                    f"is {dec.get(t)}"
                )
        for t, v in dec.items():
            if v not in variants:
                errors.append(f"rpc: decode arm {t} names unknown {enum}::{v}")
    # Every Request variant must be dispatched by the server.
    handled = set(
        re.findall(r"Request::(\w+)", fn_body(text, r"fn handle_request\b"))
    )
    for v in enum_variants(text, "Request"):
        if v not in handled:
            errors.append(f"rpc: Request::{v} is not handled in handle_request")
    return errors


def main():
    errors = check_panics() + check_protocol()
    if errors:
        for e in errors:
            print(e, file=sys.stderr)
        print(f"lint-strict: {len(errors)} error(s)", file=sys.stderr)
        return 1
    print("lint-strict: clean (panic scopes + rpc protocol table)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
