//! End-to-end driver: decentralized training of the transformer LM
//! through all three layers (EXPERIMENTS.md §End-to-End records a run).
//!
//! * Layer 1/2: the `tlm_train_step` artifact is the JAX fwd/bwd+SGD graph
//!   (Pallas-kernel lineage verified by the python test suite), lowered
//!   once by `make artifacts` and executed here via PJRT — no Python.
//! * Layer 3: worker threads + the smart Group Generator; P-Reduce group
//!   averaging runs the `preduce_tlm_g*` artifacts.
//!
//! Data is a synthetic noisy successor-rule token stream, so the loss
//! curve is meaningful: ln(vocab) ~ 5.55 at init, approaching the
//! entropy of the rule as the model learns it.
//!
//!   make artifacts && cargo run --release --example train_transformer -- \
//!       [--iters N] [--workers W] [--slow WORKER,FACTOR] [--prefetch N]

use std::time::Duration;

use ripples::cluster::HeterogeneityProfile;
use ripples::collectives::OverlapConfig;
use ripples::runtime::threaded::{
    run_threaded, EngineClient, ThreadSched, ThreadedConfig, Workload,
};

fn flag(args: &[String], key: &str) -> Option<String> {
    args.iter()
        .position(|a| a == key)
        .and_then(|i| args.get(i + 1).cloned())
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let iters: usize = flag(&args, "--iters").map(|v| v.parse()).transpose()?.unwrap_or(200);
    let workers: usize = flag(&args, "--workers").map(|v| v.parse()).transpose()?.unwrap_or(8);
    let hetero = match flag(&args, "--slow") {
        Some(s) => {
            let (w, f) = s.split_once(',').expect("--slow W,FACTOR");
            HeterogeneityProfile {
                slow_worker: Some((w.parse()?, f.parse()?)),
                ..HeterogeneityProfile::default()
            }
        }
        None => HeterogeneityProfile::default(),
    };
    let prefetch: usize =
        flag(&args, "--prefetch").map(|v| v.parse()).transpose()?.unwrap_or(0);
    let wpn = 4.min(workers);
    assert!(workers % wpn == 0, "workers must be a multiple of {wpn}");

    let artifacts = ripples::runtime::artifacts_dir();
    let (engine, _server) = EngineClient::spawn(artifacts)?;
    let cfg = ThreadedConfig {
        n_nodes: workers / wpn,
        workers_per_node: wpn,
        iters,
        group_size: 3,
        sched: ThreadSched::SmartGg,
        lr: 0.25,
        seed: 7,
        hetero,
        workload: Workload::Tlm { batch: 8, seq: 64, vocab: 256 },
        step_artifact: "tlm_train_step".into(),
        init_artifact: "tlm_init".into(),
        preduce_prefix: "preduce_tlm_g".into(),
        compute_floor: Duration::ZERO,
        overlap: OverlapConfig::serial(),
        prefetch,
        load_floor: Duration::ZERO,
    };
    println!(
        "e2e: transformer LM ({} params/replica), {} workers x {} iters, smart GG",
        435_000, workers, iters
    );
    let report = run_threaded(cfg, engine)?;

    // aggregate loss curve
    let mut per_iter: Vec<(f64, usize)> = vec![(0.0, 0); iters];
    for &(_, it, loss) in &report.losses {
        per_iter[it as usize].0 += loss as f64;
        per_iter[it as usize].1 += 1;
    }
    println!("\niter   mean LM loss");
    let stride = (iters / 20).max(1);
    for (it, (sum, cnt)) in per_iter.iter().enumerate() {
        if it % stride == 0 || it == iters - 1 {
            println!("{it:>5}  {:.4}", sum / *cnt as f64);
        }
    }
    let first = per_iter[0].0 / per_iter[0].1 as f64;
    let last_w = &per_iter[iters.saturating_sub(5)..];
    let last = last_w.iter().map(|(s, c)| s / *c as f64).sum::<f64>() / last_w.len() as f64;
    println!(
        "\nwall {:.1}s  throughput {:.1} iters/s  {} P-Reduces  loss {first:.3} -> {last:.3}",
        report.wall.as_secs_f64(),
        (iters * workers) as f64 / report.wall.as_secs_f64(),
        report.preduce_count,
    );
    // write the loss curve for EXPERIMENTS.md
    let mut csv = String::from("iter,mean_loss\n");
    for (it, (sum, cnt)) in per_iter.iter().enumerate() {
        csv.push_str(&format!("{it},{:.5}\n", sum / *cnt as f64));
    }
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/e2e_transformer_loss.csv", csv)?;
    println!("loss curve -> results/e2e_transformer_loss.csv");
    assert!(last < first, "LM must learn the successor rule");
    println!("train_transformer OK");
    Ok(())
}
