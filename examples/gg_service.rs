//! Distributed GG demo: workers coordinate through the Group Generator
//! over real TCP (the paper's gRPC service, §6.2), exercising the RPC
//! protocol end-to-end from multiple worker threads.
//!
//!   cargo run --release --example gg_service

use std::sync::{Arc, Mutex};
use std::thread;

use ripples::gg::GgConfig;
use ripples::rpc::{GgClient, GgServer};

fn main() -> anyhow::Result<()> {
    let n_workers = 8;
    let server = GgServer::spawn("127.0.0.1:0", GgConfig::smart(n_workers, 4, 3, 8), 42)?;
    println!("GG server on {}", server.addr);

    // Pool of armed groups awaiting completion, fed by sync responses.
    // The lead member (lowest rank) of an armed group reports completion
    // (the data plane is out of scope for this control-plane demo).
    let armed_pool: Arc<Mutex<Vec<(u64, Vec<usize>)>>> = Arc::new(Mutex::new(Vec::new()));
    let mut handles = Vec::new();
    for w in 0..n_workers {
        let addr = server.addr;
        let armed_pool = Arc::clone(&armed_pool);
        handles.push(thread::spawn(move || -> anyhow::Result<u64> {
            let mut client = GgClient::connect(addr)?;
            let mut my_groups = 0u64;
            for _iter in 0..20 {
                // "compute" ...
                thread::sleep(std::time::Duration::from_millis(2 + w as u64));
                // sync request: the GG assigns (or reuses) a group; the
                // measured step duration rides along as the SpeedReport
                let (assigned, armed) = client.sync(w, (2 + w as u64) as f64 * 1e-3)?;
                if let Some((_gid, members)) = &assigned {
                    assert!(members.contains(&w), "assigned group must include self");
                }
                armed_pool.lock().unwrap().extend(armed);
                // complete armed groups this worker leads
                let mine: Vec<u64> = {
                    let mut pool = armed_pool.lock().unwrap();
                    let (mine, rest): (Vec<_>, Vec<_>) =
                        pool.drain(..).partition(|(_, m)| m[0] == w);
                    *pool = rest;
                    mine.into_iter().map(|(gid, _)| gid).collect()
                };
                for gid in mine {
                    let newly = client.complete(gid)?;
                    armed_pool.lock().unwrap().extend(newly);
                    my_groups += 1;
                }
            }
            Ok(my_groups)
        }));
    }
    let mut led = 0;
    for h in handles {
        led += h.join().expect("worker panicked")?;
    }
    let mut probe = GgClient::connect(server.addr)?;
    let stats = probe.stats()?;
    println!(
        "workers led {led} completed groups; GG saw {} requests, \
         {} groups created, {} conflicts, {} buffer hits",
        stats.requests, stats.groups_created, stats.conflicts, stats.buffer_hits
    );
    println!("measured speed table (EWMA ms): {:?}", stats.speeds);
    assert_eq!(stats.requests, n_workers as u64 * 20);
    assert!(stats.speeds.iter().all(|&v| v > 0.0), "speed reports missing");
    server.shutdown();
    println!("gg_service OK");
    Ok(())
}
