//! Heterogeneity demo: the paper's headline story in one run.
//!
//! Sweeps every synchronization algorithm over {homogeneous, 2x, 5x}
//! one-worker slowdowns on the calibrated 16-worker cluster and prints
//! time-to-target, per-iteration time, and degradation — the Fig. 1 /
//! Fig. 19 narrative: All-Reduce wins homo but collapses under stragglers;
//! AD-PSGD tolerates stragglers but is sync-bound; Ripples smart GG gets
//! both.
//!
//!   cargo run --release --example heterogeneity_demo

use ripples::bench::{base_params, fmt_ttt};
use ripples::config::AlgoKind;
use ripples::metrics::Table;
use ripples::sim;

fn main() {
    let mut table = Table::new(&[
        "algorithm",
        "homo t2t(s)",
        "2x t2t(s)",
        "5x t2t(s)",
        "5x degradation",
    ]);
    for &kind in AlgoKind::all() {
        let mut row = vec![kind.name().to_string()];
        let mut homo_time = None;
        let mut five_time = None;
        for slow in [None, Some((7usize, 2.0f64)), Some((7usize, 5.0f64))] {
            let mut p = base_params(kind);
            p.exp.cluster.hetero.slow_worker = slow;
            let res = sim::run(&p);
            let t = res.time_to_target.unwrap_or(res.final_time);
            match slow {
                None => homo_time = Some(t),
                Some((_, f)) if f == 5.0 => five_time = Some(t),
                _ => {}
            }
            row.push(fmt_ttt(&res));
        }
        row.push(format!(
            "{:.2}x",
            five_time.unwrap_or(f64::NAN) / homo_time.unwrap_or(f64::NAN)
        ));
        table.row(row);
        eprint!(".");
    }
    eprintln!();
    println!("{}", table.render());
    println!(
        "expected shape: all-reduce degrades worst under 5x; ripples-smart\n\
         keeps both the best homo time and the mildest degradation."
    );
}
