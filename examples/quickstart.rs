//! Quickstart: the full three-layer system on a small workload.
//!
//! Eight Rust worker threads train the MLP classifier through the PJRT
//! artifacts (JAX Layer-2 graph, Pallas-kernel-verified math), while the
//! smart Group Generator schedules P-Reduce groups; the group averaging
//! itself executes the Layer-1 `preduce_mlp_g*` artifacts.
//!
//!   make artifacts && cargo run --release --example quickstart

use std::time::Duration;

use ripples::cluster::HeterogeneityProfile;
use ripples::collectives::OverlapConfig;
use ripples::runtime::threaded::{
    run_threaded, EngineClient, ThreadSched, ThreadedConfig, Workload,
};

fn main() -> anyhow::Result<()> {
    let artifacts = ripples::runtime::artifacts_dir();
    let (engine, _server) = EngineClient::spawn(artifacts)?;
    println!("artifacts available: {:?}", engine.available()?);

    let cfg = ThreadedConfig {
        n_nodes: 2,
        workers_per_node: 4,
        iters: 30,
        group_size: 3,
        sched: ThreadSched::SmartGg,
        lr: 0.05,
        seed: 42,
        hetero: HeterogeneityProfile::default(),
        workload: Workload::Mlp { batch: 128, in_dim: 32, classes: 10 },
        step_artifact: "mlp_train_step".into(),
        init_artifact: "mlp_init".into(),
        preduce_prefix: "preduce_mlp_g".into(),
        compute_floor: Duration::ZERO,
        overlap: OverlapConfig::serial(),
        prefetch: 0,
        load_floor: Duration::ZERO,
    };
    println!(
        "training MLP on {} workers, smart GG, {} iters...",
        cfg.n_nodes * cfg.workers_per_node,
        cfg.iters
    );
    let report = run_threaded(cfg, engine)?;

    // average loss per iteration across workers
    let mut per_iter: Vec<(f64, usize)> = vec![(0.0, 0); 30];
    for &(_, it, loss) in &report.losses {
        per_iter[it as usize].0 += loss as f64;
        per_iter[it as usize].1 += 1;
    }
    println!("\niter   mean loss");
    for (it, (sum, cnt)) in per_iter.iter().enumerate() {
        if it % 5 == 0 || it == 29 {
            println!("{it:>4}   {:.4}", sum / *cnt as f64);
        }
    }
    let first = per_iter[0].0 / per_iter[0].1 as f64;
    let last = per_iter[29].0 / per_iter[29].1 as f64;
    println!(
        "\nwall {:?}, {} P-Reduces, loss {first:.3} -> {last:.3}",
        report.wall, report.preduce_count
    );
    assert!(last < first, "training must reduce loss");
    println!("quickstart OK");
    Ok(())
}
