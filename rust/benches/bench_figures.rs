//! End-to-end figure benchmarks: regenerates every table/figure of the
//! paper's evaluation and reports the wall time of each harness. This is
//! the `cargo bench` entry point for deliverable (d) — one harness per
//! paper table and figure (see DESIGN.md §Experiment-index).
//!
//! Run: `cargo bench --bench bench_figures`
//! CSV traces land in `results/` (same as `ripples fig all --csv results`).

use std::path::Path;
use std::time::Instant;

use ripples::bench::figures;

fn main() {
    let csv_dir = Path::new("results");
    std::fs::create_dir_all(csv_dir).ok();
    let ids =
        ["1", "2b", "15", "16", "17", "18", "19", "20", "dyn", "overlap", "wire", "failures"];
    let mut total = 0.0;
    for id in ids {
        let t0 = Instant::now();
        let tables = figures::run_figure(id, Some(csv_dir)).expect("figure harness");
        let dt = t0.elapsed().as_secs_f64();
        total += dt;
        for (fig_id, title, table) in tables {
            println!("== {title} ({dt:.2}s) ==");
            println!("{}", table.render());
            let path = csv_dir.join(format!(
                "{}.csv",
                title.to_lowercase().replace(' ', "_")
            ));
            std::fs::write(&path, table.to_csv()).expect("write table CSV");
            let json_path = csv_dir.join(format!("BENCH_{fig_id}.json"));
            std::fs::write(&json_path, figures::to_json_entry(&fig_id, &title, &table))
                .expect("write table JSON");
        }
    }
    println!("all figure harnesses regenerated in {total:.1}s; CSVs in results/");
}
