//! Primitive benchmarks (hand-rolled harness; criterion is not in the
//! vendored registry). Measures the Layer-3 hot paths:
//!   * fused P-Reduce mean (GB/s) across group sizes and model sizes
//!   * threaded chunked ring all-reduce
//!   * Group Generator request/complete throughput (random vs smart)
//!   * Group Generator RPC serving over real TCP (locked vs sharded)
//!   * lock vector ops and static scheduler lookups
//!
//! Run: `cargo bench --bench bench_primitives`
//!
//! The ring bench also *asserts* the buffer-recycling property of
//! `ChannelTransport` (per-edge spare channels): one collective must
//! allocate only a small constant number of chunk buffers, not one per
//! schedule step — measured through a counting global allocator.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use ripples::collectives::{preduce_mean_inplace, ring};
use ripples::gg::{GgConfig, GroupGenerator, LockVector, StaticScheduler};
use ripples::util::rng::Pcg32;

/// Counts bytes handed out by the allocator (thread stacks are mmap'd
/// and invisible here, which is what makes the ring assertion sharp).
struct CountingAlloc;

static ALLOCATED: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATED.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATED.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Robust timing: median of `reps` runs of `f` (returns seconds).
fn time_median<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[reps / 2]
}

fn rand_buf(seed: u64, n: usize) -> Vec<f32> {
    let mut rng = Pcg32::new(seed);
    (0..n).map(|_| rng.gen_f32()).collect()
}

fn bench_preduce_fused() {
    println!("\n== fused P-Reduce mean (preduce_mean_inplace) ==");
    println!("{:<10} {:<12} {:>12} {:>12}", "group", "elements", "median ms", "GB/s");
    for &g in &[2usize, 3, 4, 8, 16] {
        for &n in &[22_026usize, 434_816, 2_420_000] {
            let mut bufs: Vec<Vec<f32>> = (0..g).map(|i| rand_buf(i as u64, n)).collect();
            let mut scratch = Vec::new();
            let t = time_median(9, || {
                let mut refs: Vec<&mut [f32]> =
                    bufs.iter_mut().map(|b| b.as_mut_slice()).collect();
                preduce_mean_inplace(&mut refs, &mut scratch);
            });
            // bytes touched: read g*n + write g*n floats
            let gbps = (2.0 * g as f64 * n as f64 * 4.0) / t / 1e9;
            println!("{g:<10} {n:<12} {:>12.3} {gbps:>12.2}", t * 1e3);
        }
    }
}

fn bench_ring() {
    println!("\n== threaded chunked ring all-reduce ==");
    println!("{:<10} {:<12} {:>12} {:>14}", "ranks", "elements", "median ms", "alloc MB/op");
    for &p in &[2usize, 4, 8] {
        for &n in &[22_026usize, 434_816] {
            // buffers allocated outside the measured/counted region
            let mut bufs: Vec<Vec<f32>> = (0..p).map(|i| rand_buf(i as u64, n)).collect();
            ring::ring_allreduce_mean(&mut bufs); // warmup
            let before = ALLOCATED.load(Ordering::Relaxed);
            let t = time_median(7, || {
                ring::ring_allreduce_mean(&mut bufs);
            });
            let bytes_per_op =
                (ALLOCATED.load(Ordering::Relaxed) - before) as f64 / 7.0;
            println!(
                "{p:<10} {n:<12} {:>12.3} {:>14.2}",
                t * 1e3,
                bytes_per_op / 1e6
            );
        }
    }
    assert_transport_recycles();
}

/// Buffer-recycling regression gate for `ChannelTransport`. Run
/// single-threaded (both ends of a 2-rank loop driven alternately) so
/// the measurement is deterministic: after a short warmup the spare
/// channels supply every send, and the steady state allocates no chunk
/// buffers at all. The pre-reuse transport cloned the payload on every
/// send — 2 chunks per exchange, O(steps) — so the O(1) gate below is
/// unpassable for it regardless of scheduling.
fn assert_transport_recycles() {
    use ripples::collectives::ring::{ChannelTransport, ChunkTransport};
    let n = 100_000usize; // chunk elements per transfer (400 KB)
    let steps = 64u32;
    let payload = vec![1.0f32; n];
    let mut transports = ChannelTransport::ring(2);
    let (mut b, mut a) = (transports.pop().unwrap(), transports.pop().unwrap());
    let mut out_a = Vec::new();
    let mut out_b = Vec::new();
    let mut exchange = |step: u32, a: &mut ChannelTransport, b: &mut ChannelTransport| {
        a.send(step, &payload).unwrap();
        b.recv(step, &mut out_b).unwrap();
        b.send(step, &payload).unwrap();
        a.recv(step, &mut out_a).unwrap();
    };
    for step in 0..4 {
        exchange(step, &mut a, &mut b); // warmup seeds the spare pools
    }
    let before = ALLOCATED.load(Ordering::Relaxed);
    for step in 0..steps {
        exchange(step, &mut a, &mut b);
    }
    let bytes = ALLOCATED.load(Ordering::Relaxed) - before;
    let chunk_bytes = (4 * n) as u64;
    println!(
        "transport     : {:>10.1} KB allocated over {steps} steady-state \
         exchanges ({:.0} KB/chunk)",
        bytes as f64 / 1e3,
        chunk_bytes as f64 / 1e3
    );
    // generous O(1) budget (channel nodes, stray growth); per-send
    // cloning would sit at 2 * steps * chunk_bytes = 128 chunks
    assert!(
        bytes < 8 * chunk_bytes,
        "ChannelTransport allocations regressed: {bytes} bytes over {steps} \
         exchanges (per-send cloning would allocate {})",
        2 * steps as u64 * chunk_bytes
    );
}

fn bench_gg() {
    println!("\n== Group Generator request+complete throughput ==");
    println!("{:<22} {:>14} {:>12}", "policy", "ops/s", "us/op");
    for (name, cfg) in [
        ("random k=3", GgConfig::random(16, 4, 3)),
        ("smart k=3", GgConfig::smart(16, 4, 3, 8)),
        ("random k=3 n=64", GgConfig::random(64, 4, 3)),
        ("smart k=3 n=64", GgConfig::smart(64, 4, 3, 8)),
    ] {
        let ops = 20_000usize;
        let t = time_median(5, || {
            let mut gg = GroupGenerator::new(cfg.clone());
            let mut rng = Pcg32::new(7);
            let n = cfg.n_workers;
            let mut armed: Vec<(u64, Vec<usize>)> = Vec::new();
            for i in 0..ops {
                let (_, newly) = gg.request(i % n, &mut rng);
                for g in newly {
                    armed.push((g.id, g.members));
                }
                // complete oldest armed to keep the system flowing
                while armed.len() > 4 {
                    let (gid, _) = armed.remove(0);
                    for g in gg.complete(gid) {
                        armed.push((g.id, g.members));
                    }
                }
            }
            while let Some((gid, _)) = armed.pop() {
                for g in gg.complete(gid) {
                    armed.push((g.id, g.members));
                }
            }
        });
        println!("{name:<22} {:>14.0} {:>12.3}", ops as f64 / t, t / ops as f64 * 1e6);
    }
}

/// One measured run: `p` localhost TCP clients hammer a fresh GgServer
/// with heartbeats + probes (the lock-free hot path on the sharded
/// backend; fully serialized on the locked oracle), returning RPC round
/// trips per second. Each client keeps one connection for the whole run
/// (the reconnect-per-call pattern this repo used to have would dominate
/// the measurement with handshakes).
fn gg_rpc_throughput(p: usize, mode: ripples::rpc::GgMode, iters: usize) -> f64 {
    use ripples::rpc::{GgClient, GgServer};
    use std::sync::{Arc, Barrier};

    let cfg = GgConfig::random(p.max(4), 4, 3);
    let server = GgServer::spawn_with_backend("127.0.0.1:0", cfg, 11, None, mode)
        .expect("spawn bench GG");
    let addr = server.addr;
    let barrier = Arc::new(Barrier::new(p + 1));
    let handles: Vec<_> = (0..p)
        .map(|w| {
            let b = Arc::clone(&barrier);
            std::thread::spawn(move || {
                let mut c = GgClient::connect(addr).expect("bench client");
                c.set_io_timeout(std::time::Duration::from_secs(60)).expect("timeout");
                b.wait();
                for _ in 0..iters {
                    c.heartbeat(w).expect("heartbeat");
                    c.probe(u64::MAX).expect("probe");
                }
            })
        })
        .collect();
    barrier.wait();
    let t0 = Instant::now();
    for h in handles {
        h.join().expect("bench rank");
    }
    let secs = t0.elapsed().as_secs_f64().max(1e-9);
    server.shutdown();
    (2 * p * iters) as f64 / secs
}

/// Concurrent GG RPC serving: single-lock oracle vs sharded backend over
/// real TCP through the reactor, p in {4, 64, 256} client threads. No
/// asserts — machine-dependent ratios are printed, not gated (the
/// differential suites gate *correctness*; `fig scale` records the
/// measured numbers).
fn bench_gg_rpc() {
    use ripples::rpc::GgMode;
    println!("\n== Group Generator RPC serving (real TCP, reactor) ==");
    println!(
        "{:<10} {:>16} {:>16} {:>10}",
        "clients", "locked rpc/s", "sharded rpc/s", "ratio"
    );
    for &p in &[4usize, 64, 256] {
        // ~constant total work so the big fan-outs stay quick
        let iters = (20_000 / p).max(20);
        let locked = gg_rpc_throughput(p, GgMode::SingleLock, iters);
        let sharded = gg_rpc_throughput(p, GgMode::Sharded, iters);
        println!(
            "{p:<10} {locked:>16.0} {sharded:>16.0} {:>9.2}x",
            sharded / locked
        );
    }
}

fn bench_lockvec_and_sched() {
    println!("\n== lock vector + static scheduler micro ==");
    let mut lv = LockVector::new(1024);
    let groups: Vec<Vec<usize>> = (0..256).map(|i| vec![i * 4, i * 4 + 1, i * 4 + 2]).collect();
    let t = time_median(9, || {
        for g in &groups {
            assert!(lv.try_lock(g));
        }
        for g in &groups {
            lv.release(g);
        }
    });
    println!(
        "lock vector   : {:>10.1} ns per try_lock+release (3-member group)",
        t / groups.len() as f64 * 1e9
    );
    let s = StaticScheduler::new(4, 4);
    let t = time_median(9, || {
        let mut acc = 0usize;
        for iter in 0..1000u64 {
            for w in 0..16 {
                if let Some(g) = s.group_of(w, iter) {
                    acc += g.len();
                }
            }
        }
        std::hint::black_box(acc);
    });
    println!(
        "static sched  : {:>10.1} ns per group_of lookup",
        t / 16_000.0 * 1e9
    );
}

fn main() {
    println!("ripples primitive benchmarks (hand-rolled harness)");
    bench_preduce_fused();
    bench_ring();
    bench_gg();
    bench_gg_rpc();
    bench_lockvec_and_sched();
    println!("\nbench_primitives done");
}
