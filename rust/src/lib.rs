//! # Ripples — Heterogeneity-Aware Asynchronous Decentralized Training
//!
//! A Rust + JAX + Pallas reproduction of *"Heterogeneity-Aware
//! Asynchronous Decentralized Training"* (Luo, He, Zhuo, Qian, 2019):
//! the **Partial All-Reduce (P-Reduce)** synchronization primitive, the
//! centralized **Group Generator** (random and smart: Group Buffer,
//! Global Division, Inter-Intra scheduling, slowdown filtering), the
//! conflict-free **static scheduler**, and the baselines it is evaluated
//! against (Parameter Server, Ring All-Reduce, D-PSGD, AD-PSGD).
//!
//! Three layers (see DESIGN.md):
//! * **Layer 3 (this crate)** — coordinator, schedulers, simulated
//!   cluster, collectives, metrics, benches.
//! * **Layer 2 (python/compile)** — JAX train-step graphs, AOT-lowered to
//!   HLO text artifacts.
//! * **Layer 1 (python/compile/kernels)** — Pallas kernels (group-mean
//!   P-Reduce arithmetic, MXU-tiled matmul, fused SGD), verified against
//!   pure-jnp oracles.
//!
//! The [`runtime`] module executes the AOT artifacts via PJRT, so Python
//! never runs on the training path. The [`net`] module is the deployable
//! composition: worker *processes* coordinating through the TCP Group
//! Generator service ([`rpc`]) and moving model bytes over the TCP data
//! plane (`ripples launch` / `ripples worker`; DESIGN.md §Deployment).
//! Workers piggyback measured step-duration EWMAs on their GG RPCs
//! ([`rpc::SpeedReport`] → [`gg::SpeedTable`]), so the slowdown filter
//! runs on *measured* heterogeneity and reacts to stragglers that
//! appear — or recover — mid-run ([`cluster::SlowdownEvent`],
//! `--slow-schedule`; DESIGN.md §Hardware-Adaptation).

pub mod bench;
pub mod check;
pub mod cluster;
pub mod collectives;
pub mod comm;
pub mod config;
pub mod fault;
pub mod gg;
pub mod metrics;
pub mod model;
pub mod net;
pub mod rpc;
pub mod runtime;
pub mod sim;
pub mod step;
pub mod topo;
pub mod util;

pub use cluster::{BandwidthEvent, CrashEvent, HeterogeneityProfile, SlowdownEvent};
pub use collectives::{AbortedError, OverlapConfig, WireCodec};
pub use config::{AlgoConfig, AlgoKind, ClusterConfig, Experiment, TrainConfig};
pub use fault::{Fault, FaultPlan, FaultyTransport};
pub use gg::{GgConfig, Group, GroupGenerator, ShardedGg, SpeedTable, StaticScheduler};
pub use sim::{SimParams, SimResult};
pub use step::PipelineConfig;
pub use topo::{SyncPlan, Topology};
