//! Metrics output: CSV traces, aligned tables, speedup summaries.

use crate::sim::SimResult;
use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// Write a loss trace as CSV (`time,avg_iter,loss`).
pub fn write_trace_csv(res: &SimResult, path: &Path) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "time,avg_iter,loss")?;
    for tp in &res.trace {
        writeln!(f, "{:.6},{:.2},{:.6}", tp.time, tp.avg_iter, tp.loss)?;
    }
    Ok(())
}

/// A simple aligned text table (the figure harness output format).
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(out, "{:<w$}", c, w = widths[i] + 2);
            }
            out.push('\n');
        };
        line(&mut out, &self.header);
        let total: usize = widths.iter().map(|w| w + 2).sum();
        out.push_str(&"-".repeat(total.max(ncol)));
        out.push('\n');
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }

    /// JSON rendering of the same table:
    /// `{"header": [...], "rows": [[...], ...]}` (all cells as strings).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\"header\": [");
        let cells = |out: &mut String, row: &[String]| {
            for (i, c) in row.iter().enumerate() {
                if i > 0 {
                    out.push_str(", ");
                }
                out.push('"');
                out.push_str(&json_escape(c));
                out.push('"');
            }
        };
        cells(&mut out, &self.header);
        out.push_str("], \"rows\": [");
        for (i, row) in self.rows.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            out.push('[');
            cells(&mut out, row);
            out.push(']');
        }
        out.push_str("]}");
        out
    }

    /// CSV rendering of the same table.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let _ = writeln!(out, "{}", self.header.iter().map(|s| esc(s)).collect::<Vec<_>>().join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.iter().map(|s| esc(s)).collect::<Vec<_>>().join(","));
        }
        out
    }
}

/// Escape a string for embedding in a JSON string literal (used by
/// [`Table::to_json`] and the `BENCH_<id>.json` figure wrapper).
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// One worker's throughput over a `ripples launch` run (the distributed
/// analogue of `SimResult.per_worker_iters`).
#[derive(Debug, Clone)]
pub struct WorkerStat {
    pub rank: usize,
    pub iters: u64,
    pub preduces: u64,
    pub secs: f64,
    pub loss_first: f64,
    pub loss_last: f64,
    /// Data-plane chunk bytes sent/received (the wire codec's
    /// compression is directly visible here; 0 when not measured).
    pub bytes_tx: u64,
    pub bytes_rx: u64,
    /// Per-stage stall seconds from the staged step pipeline
    /// (DESIGN.md §Perf): compute waiting for batches, the loader
    /// waiting on backpressure, and training blocked on reconcile.
    pub load_wait_secs: f64,
    pub compute_wait_secs: f64,
    pub reconcile_wait_secs: f64,
}

/// Per-worker throughput table for a distributed run: iteration rate is
/// the heterogeneity metric (a gated fast worker converges to the slow
/// worker's rate; see EXPERIMENTS.md §Deployment-run), wire MB the
/// bandwidth one (tx+rx chunk bytes — compare `--wire` codecs), and the
/// stall column the pipeline one (per-stage exposed seconds
/// load/compute/reconcile — compare `--prefetch` depths).
pub fn worker_table(stats: &[WorkerStat]) -> Table {
    let mut t = Table::new(&[
        "worker",
        "iters",
        "iters/s",
        "preduces",
        "wire MB",
        "stall l/c/r s",
        "loss first→last",
    ]);
    for s in stats {
        let rate = if s.secs > 0.0 { s.iters as f64 / s.secs } else { 0.0 };
        t.row(vec![
            s.rank.to_string(),
            s.iters.to_string(),
            format!("{rate:.1}"),
            s.preduces.to_string(),
            format!("{:.2}", (s.bytes_tx + s.bytes_rx) as f64 / 1e6),
            format!(
                "{:.2}/{:.2}/{:.2}",
                s.load_wait_secs, s.compute_wait_secs, s.reconcile_wait_secs
            ),
            format!("{:.4} → {:.4}", s.loss_first, s.loss_last),
        ]);
    }
    t
}

/// Measured slowdown factor per worker: EWMA step seconds divided by
/// the fastest measured worker's. 0.0 where nothing was measured.
pub fn relative_speeds(speeds: &[f64]) -> Vec<f64> {
    let reference = speeds
        .iter()
        .copied()
        .filter(|&v| v > 0.0)
        .fold(f64::INFINITY, f64::min);
    speeds
        .iter()
        .map(|&v| if v > 0.0 && reference.is_finite() { v / reference } else { 0.0 })
        .collect()
}

/// Measured per-worker speed table for GG-scheduled runs: the online
/// telemetry (EWMA step time, relative factor) next to the configured
/// ground truth and the filter's observable (drafts by other
/// initiators). Rendered by `ripples launch` and the dynamic-straggler
/// harness (EXPERIMENTS.md §Dynamic-straggler).
pub fn speed_table(speeds: &[f64], true_factors: &[f64], drafts: &[u64]) -> Table {
    let rel = relative_speeds(speeds);
    let mut t = Table::new(&["worker", "ewma ms", "rel speed", "true factor", "drafts"]);
    for w in 0..speeds.len() {
        t.row(vec![
            w.to_string(),
            if speeds[w] > 0.0 { format!("{:.1}", speeds[w] * 1e3) } else { "-".into() },
            if rel[w] > 0.0 { format!("{:.2}", rel[w]) } else { "-".into() },
            true_factors.get(w).map_or("-".into(), |f| format!("{f:.2}")),
            drafts.get(w).map_or("-".into(), |d| d.to_string()),
        ]);
    }
    t
}

/// Summary line per algorithm, matching the paper's reporting style.
/// GG-scheduled runs with measured speed telemetry get a second line
/// with the per-worker relative speeds the slowdown filter acted on.
pub fn summarize(res: &SimResult) -> String {
    // Empty results (zero workers) must print 0.0, not NaN — same guard
    // as the per-worker rate in [`worker_table`].
    let iters_per_worker = if res.per_worker_iters.is_empty() {
        0.0
    } else {
        res.total_iters as f64 / res.per_worker_iters.len() as f64
    };
    let mut out = format!(
        "{:<18} time={:>9.2}s  iters/worker={:>7.1}  per-iter={:>7.4}s  sync%={:>5.1}  conflicts={}",
        res.algo,
        res.final_time,
        iters_per_worker,
        res.per_iter_time(),
        res.sync_fraction() * 100.0,
        res.conflicts,
    );
    if res.bytes_on_wire > 0 {
        let _ = write!(out, "  wireMB={:.1}", res.bytes_on_wire as f64 / 1e6);
    }
    if res.measured_speeds.iter().any(|&v| v > 0.0) {
        let rel = relative_speeds(&res.measured_speeds);
        let rel_s: Vec<String> = rel.iter().map(|v| format!("{v:.2}")).collect();
        let ms_s: Vec<String> =
            res.measured_speeds.iter().map(|v| format!("{:.1}", v * 1e3)).collect();
        out.push_str(&format!(
            "\nmeasured speeds: rel=[{}] ewma_ms=[{}]",
            rel_s.join(" "),
            ms_s.join(" ")
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::TracePoint;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["algo", "speedup"]);
        t.row(vec!["all-reduce".into(), "4.27".into()]);
        t.row(vec!["ps".into(), "1.00".into()]);
        let s = t.render();
        assert!(s.contains("algo"));
        assert!(s.lines().count() == 4);
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[2].find("4.27"), lines[3].find("1.00"));
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x,y".into(), "z".into()]);
        assert!(t.to_csv().contains("\"x,y\""));
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only one".into()]);
    }

    #[test]
    fn worker_table_renders_rates() {
        let t = worker_table(&[
            WorkerStat {
                rank: 0,
                iters: 100,
                preduces: 30,
                secs: 4.0,
                loss_first: 1.5,
                loss_last: 0.5,
                bytes_tx: 2_000_000,
                bytes_rx: 1_500_000,
                load_wait_secs: 0.75,
                compute_wait_secs: 0.125,
                reconcile_wait_secs: 1.5,
            },
            WorkerStat {
                rank: 1,
                iters: 40,
                preduces: 30,
                secs: 4.0,
                loss_first: 1.5,
                loss_last: 0.6,
                bytes_tx: 0,
                bytes_rx: 0,
                load_wait_secs: 0.0,
                compute_wait_secs: 0.0,
                reconcile_wait_secs: 0.0,
            },
        ]);
        let s = t.render();
        assert!(s.contains("25.0"), "{s}"); // 100 iters / 4 s
        assert!(s.contains("10.0"), "{s}");
        assert!(s.contains("3.50"), "{s}"); // (2.0 + 1.5) MB on the wire
        assert!(s.contains("0.75/0.13/1.50"), "{s}"); // per-stage stalls
        assert!(s.contains("0.00/0.00/0.00"), "{s}");
        assert_eq!(s.lines().count(), 4);
    }

    #[test]
    fn relative_speeds_vs_fastest() {
        assert_eq!(relative_speeds(&[]), Vec::<f64>::new());
        assert_eq!(relative_speeds(&[0.0, 0.0]), vec![0.0, 0.0]);
        let rel = relative_speeds(&[0.010, 0.0, 0.030]);
        assert!((rel[0] - 1.0).abs() < 1e-12);
        assert_eq!(rel[1], 0.0);
        assert!((rel[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn speed_table_golden_rendering() {
        let t = speed_table(&[0.010, 0.0, 0.030], &[1.0, 1.0, 3.0], &[12, 7, 0]);
        // golden per-line content (cells are right-padded; compare trimmed)
        let got: Vec<String> =
            t.render().lines().map(|l| l.trim_end().to_string()).collect();
        assert_eq!(got.len(), 5);
        assert_eq!(got[0], "worker  ewma ms  rel speed  true factor  drafts");
        assert!(got[1].chars().all(|c| c == '-') && got[1].len() >= got[0].len());
        assert_eq!(got[2], "0       10.0     1.00       1.00         12");
        assert_eq!(got[3], "1       -        -          1.00         7");
        assert_eq!(got[4], "2       30.0     3.00       3.00         0");
    }

    #[test]
    fn summarize_appends_measured_speed_line() {
        let mut res = SimResult {
            algo: "ripples-smart".into(),
            final_time: 10.0,
            total_iters: 100,
            per_worker_iters: vec![50, 50],
            ..SimResult::default()
        };
        let base = summarize(&res);
        assert_eq!(base.lines().count(), 1, "no telemetry, no speed line: {base}");
        res.measured_speeds = vec![0.010, 0.025];
        let with = summarize(&res);
        let lines: Vec<&str> = with.lines().collect();
        assert_eq!(lines.len(), 2);
        assert_eq!(lines[0], base);
        assert_eq!(lines[1], "measured speeds: rel=[1.00 2.50] ewma_ms=[10.0 25.0]");
    }

    #[test]
    fn summarize_empty_result_has_no_nan() {
        // Regression: an empty result (no workers ran) used to divide by
        // `per_worker_iters.len() == 0` and print `NaN`.
        let res = SimResult { algo: "ripples-smart".into(), ..SimResult::default() };
        let line = summarize(&res);
        assert!(!line.contains("NaN"), "{line}");
        assert!(line.contains("iters/worker=    0.0"), "{line}");
    }

    #[test]
    fn table_to_json_escapes_and_structures() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x\"y".into(), "1.5".into()]);
        t.row(vec!["plain".into(), "2".into()]);
        let j = t.to_json();
        assert_eq!(
            j,
            "{\"header\": [\"a\", \"b\"], \"rows\": [[\"x\\\"y\", \"1.5\"], [\"plain\", \"2\"]]}"
        );
        // must be parseable by the in-repo JSON parser
        let parsed = crate::util::json::parse(&j).unwrap();
        assert_eq!(parsed.get("rows").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(
            parsed.get("rows").unwrap().as_arr().unwrap()[0].as_arr().unwrap()[0].as_str(),
            Some("x\"y")
        );
    }

    #[test]
    fn trace_csv_roundtrip() {
        let mut res = SimResult::default();
        res.trace.push(TracePoint { time: 1.5, avg_iter: 10.0, loss: 0.5 });
        res.per_worker_iters = vec![10];
        let dir = std::env::temp_dir().join("ripples_test_metrics");
        let path = dir.join("trace.csv");
        write_trace_csv(&res, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("time,avg_iter,loss"));
        assert!(text.contains("1.500000,10.00,0.500000"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
