//! Metrics output: CSV traces, aligned tables, speedup summaries.

use crate::sim::SimResult;
use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// Write a loss trace as CSV (`time,avg_iter,loss`).
pub fn write_trace_csv(res: &SimResult, path: &Path) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "time,avg_iter,loss")?;
    for tp in &res.trace {
        writeln!(f, "{:.6},{:.2},{:.6}", tp.time, tp.avg_iter, tp.loss)?;
    }
    Ok(())
}

/// A simple aligned text table (the figure harness output format).
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(out, "{:<w$}", c, w = widths[i] + 2);
            }
            out.push('\n');
        };
        line(&mut out, &self.header);
        let total: usize = widths.iter().map(|w| w + 2).sum();
        out.push_str(&"-".repeat(total.max(ncol)));
        out.push('\n');
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }

    /// CSV rendering of the same table.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let _ = writeln!(out, "{}", self.header.iter().map(|s| esc(s)).collect::<Vec<_>>().join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.iter().map(|s| esc(s)).collect::<Vec<_>>().join(","));
        }
        out
    }
}

/// One worker's throughput over a `ripples launch` run (the distributed
/// analogue of `SimResult.per_worker_iters`).
#[derive(Debug, Clone)]
pub struct WorkerStat {
    pub rank: usize,
    pub iters: u64,
    pub preduces: u64,
    pub secs: f64,
    pub loss_first: f64,
    pub loss_last: f64,
}

/// Per-worker throughput table for a distributed run: iteration rate is
/// the heterogeneity metric (a gated fast worker converges to the slow
/// worker's rate; see EXPERIMENTS.md §Deployment-run).
pub fn worker_table(stats: &[WorkerStat]) -> Table {
    let mut t = Table::new(&["worker", "iters", "iters/s", "preduces", "loss first→last"]);
    for s in stats {
        let rate = if s.secs > 0.0 { s.iters as f64 / s.secs } else { 0.0 };
        t.row(vec![
            s.rank.to_string(),
            s.iters.to_string(),
            format!("{rate:.1}"),
            s.preduces.to_string(),
            format!("{:.4} → {:.4}", s.loss_first, s.loss_last),
        ]);
    }
    t
}

/// Summary line per algorithm, matching the paper's reporting style.
pub fn summarize(res: &SimResult) -> String {
    format!(
        "{:<18} time={:>9.2}s  iters/worker={:>7.1}  per-iter={:>7.4}s  sync%={:>5.1}  conflicts={}",
        res.algo,
        res.final_time,
        res.total_iters as f64 / res.per_worker_iters.len() as f64,
        res.per_iter_time(),
        res.sync_fraction() * 100.0,
        res.conflicts,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::TracePoint;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["algo", "speedup"]);
        t.row(vec!["all-reduce".into(), "4.27".into()]);
        t.row(vec!["ps".into(), "1.00".into()]);
        let s = t.render();
        assert!(s.contains("algo"));
        assert!(s.lines().count() == 4);
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[2].find("4.27"), lines[3].find("1.00"));
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x,y".into(), "z".into()]);
        assert!(t.to_csv().contains("\"x,y\""));
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only one".into()]);
    }

    #[test]
    fn worker_table_renders_rates() {
        let t = worker_table(&[
            WorkerStat {
                rank: 0,
                iters: 100,
                preduces: 30,
                secs: 4.0,
                loss_first: 1.5,
                loss_last: 0.5,
            },
            WorkerStat {
                rank: 1,
                iters: 40,
                preduces: 30,
                secs: 4.0,
                loss_first: 1.5,
                loss_last: 0.6,
            },
        ]);
        let s = t.render();
        assert!(s.contains("25.0"), "{s}"); // 100 iters / 4 s
        assert!(s.contains("10.0"), "{s}");
        assert_eq!(s.lines().count(), 4);
    }

    #[test]
    fn trace_csv_roundtrip() {
        let mut res = SimResult::default();
        res.trace.push(TracePoint { time: 1.5, avg_iter: 10.0, loss: 0.5 });
        res.per_worker_iters = vec![10];
        let dir = std::env::temp_dir().join("ripples_test_metrics");
        let path = dir.join("trace.csv");
        write_trace_csv(&res, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("time,avg_iter,loss"));
        assert!(text.contains("1.500000,10.00,0.500000"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
