//! Configuration: cluster topology, training hyperparameters, algorithm
//! selection — plus a TOML-subset file loader (no serde in the registry).
//!
//! The defaults reproduce the paper's testbed: 4 nodes x 4 GPUs (16
//! workers), FDR InfiniBand between nodes, PCIe/QPI within a node
//! (Maverick2 GTX partition, Fig. 14).

mod parse;

pub use parse::{parse_toml_subset, TomlValue};

use crate::cluster::HeterogeneityProfile;
use crate::collectives::codec::WireCodec;
use crate::collectives::pipeline::OverlapConfig;
use crate::step::PipelineConfig;

/// Which synchronization algorithm runs (paper §2.2, §4, §5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AlgoKind {
    /// Ring all-reduce every iteration (Horovod-like baseline).
    AllReduce,
    /// Centralized parameter server (TensorFlow PS baseline).
    ParameterServer,
    /// Synchronous decentralized SGD on a fixed ring graph.
    DPsgd,
    /// Asynchronous decentralized SGD, bipartite active/passive (Lian et al.).
    AdPsgd,
    /// Ripples with the rule-based conflict-free static schedule (§4.2).
    RipplesStatic,
    /// Ripples with plain randomized GG (§4.1).
    RipplesRandom,
    /// Ripples with smart GG: Group Buffer + Global Division + Inter-Intra
    /// + slowdown filter (§5).
    RipplesSmart,
}

impl AlgoKind {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s.to_ascii_lowercase().as_str() {
            "allreduce" | "all-reduce" | "ar" => AlgoKind::AllReduce,
            "ps" | "parameter-server" | "parameterserver" => AlgoKind::ParameterServer,
            "dpsgd" | "d-psgd" => AlgoKind::DPsgd,
            "adpsgd" | "ad-psgd" => AlgoKind::AdPsgd,
            "ripples-static" | "static" => AlgoKind::RipplesStatic,
            "ripples-random" | "random" => AlgoKind::RipplesRandom,
            "ripples-smart" | "smart" | "ripples" => AlgoKind::RipplesSmart,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            AlgoKind::AllReduce => "all-reduce",
            AlgoKind::ParameterServer => "parameter-server",
            AlgoKind::DPsgd => "d-psgd",
            AlgoKind::AdPsgd => "ad-psgd",
            AlgoKind::RipplesStatic => "ripples-static",
            AlgoKind::RipplesRandom => "ripples-random",
            AlgoKind::RipplesSmart => "ripples-smart",
        }
    }

    pub fn all() -> &'static [AlgoKind] {
        &[
            AlgoKind::AllReduce,
            AlgoKind::ParameterServer,
            AlgoKind::DPsgd,
            AlgoKind::AdPsgd,
            AlgoKind::RipplesStatic,
            AlgoKind::RipplesRandom,
            AlgoKind::RipplesSmart,
        ]
    }
}

/// Interconnect cost model (see `comm::CostModel` for the formulas).
#[derive(Debug, Clone)]
pub struct LinkConfig {
    /// Bandwidth within one node (PCIe/NVLink-ish), bytes/sec.
    pub intra_bw: f64,
    /// Bandwidth between nodes (FDR InfiniBand ~ 56 Gb/s), bytes/sec.
    pub inter_bw: f64,
    /// One-way latency within a node, seconds.
    pub intra_lat: f64,
    /// One-way latency between nodes, seconds.
    pub inter_lat: f64,
    /// GG RPC round-trip latency, seconds (gRPC-on-IB in the paper).
    pub rpc_rtt: f64,
}

impl Default for LinkConfig {
    fn default() -> Self {
        Self {
            intra_bw: 12.0e9,  // ~PCIe 3.0 x16 effective
            inter_bw: 6.0e9,   // ~FDR IB effective per direction
            intra_lat: 5e-6,
            inter_lat: 25e-6,
            rpc_rtt: 150e-6,
        }
    }
}

/// Cluster shape: `n_nodes` nodes with `workers_per_node` workers each.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub n_nodes: usize,
    pub workers_per_node: usize,
    pub link: LinkConfig,
    pub hetero: HeterogeneityProfile,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            n_nodes: 4,
            workers_per_node: 4,
            link: LinkConfig::default(),
            hetero: HeterogeneityProfile::default(),
        }
    }
}

impl ClusterConfig {
    pub fn n_workers(&self) -> usize {
        self.n_nodes * self.workers_per_node
    }

    pub fn node_of(&self, worker: usize) -> usize {
        worker / self.workers_per_node
    }

    pub fn same_node(&self, a: usize, b: usize) -> bool {
        self.node_of(a) == self.node_of(b)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.n_nodes == 0 || self.workers_per_node == 0 {
            return Err("cluster must have at least one node and worker".into());
        }
        if let Some((w, f)) = self.hetero.slow_worker {
            if w >= self.n_workers() {
                return Err(format!("slow worker {w} out of range"));
            }
            if f < 1.0 {
                return Err(format!("slowdown factor {f} must be >= 1"));
            }
        }
        for ev in &self.hetero.schedule {
            if ev.worker >= self.n_workers() {
                return Err(format!("slow-schedule worker {} out of range", ev.worker));
            }
            if ev.factor < 1.0 {
                return Err(format!("slow-schedule factor {} must be >= 1", ev.factor));
            }
        }
        for ev in &self.hetero.bandwidth {
            if ev.worker >= self.n_workers() {
                return Err(format!("bw-schedule worker {} out of range", ev.worker));
            }
            if !(ev.factor >= 1.0 && ev.factor.is_finite()) {
                return Err(format!("bw-schedule factor {} must be >= 1", ev.factor));
            }
        }
        Ok(())
    }
}

/// Algorithm-specific knobs.
#[derive(Debug, Clone)]
pub struct AlgoConfig {
    pub kind: AlgoKind,
    /// Group size for randomized GG (paper uses 3 in §7.1.3).
    pub group_size: usize,
    /// Slowdown filter threshold `C_thres` (§5.3), in iterations.
    pub c_thres: u64,
    /// Iterations between synchronizations ("section length", Fig. 16).
    pub section_len: usize,
    /// AD-PSGD communication graph: ring neighbors only if true, else any
    /// opposite-set worker (bipartite sets are always enforced).
    pub adpsgd_ring_only: bool,
    /// Parameter-server key-range shard count (`comm::CostModel::
    /// ps_round_sharded`; the real PS baseline's `--ps-shards`). The
    /// default 1 keeps the classic two-phase PS round bit-identical.
    pub ps_shards: usize,
}

impl Default for AlgoConfig {
    fn default() -> Self {
        Self {
            kind: AlgoKind::RipplesSmart,
            group_size: 3,
            c_thres: 8,
            section_len: 1,
            adpsgd_ring_only: false,
            ps_shards: 1,
        }
    }
}

impl AlgoConfig {
    pub fn validate(&self, n_workers: usize) -> Result<(), String> {
        if self.group_size < 2 {
            return Err("group_size must be >= 2".into());
        }
        if self.group_size > n_workers {
            return Err(format!(
                "group_size {} exceeds worker count {n_workers}",
                self.group_size
            ));
        }
        if self.section_len == 0 {
            return Err("section_len must be >= 1".into());
        }
        if self.ps_shards == 0 {
            return Err("ps_shards must be >= 1".into());
        }
        Ok(())
    }
}

/// How the simulator charges each P-Reduce collective for worker
/// placement (`[topology]` section; DESIGN.md §Perf, "Hierarchical
/// P-Reduce"). The deployment plane's equivalent is `launch --topo`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SyncShape {
    /// Legacy worst-edge ring cost (`CostModel::ring_allreduce_throttled`)
    /// — the bit-identical default.
    #[default]
    Flat,
    /// Shared-uplink serialization with a placement-blind ring order
    /// (machines interleaved — what a speed-sorted order degenerates to).
    FlatBlind,
    /// Shared-uplink serialization with a node-major (bandwidth-ordered)
    /// ring — the degenerate single-level plan.
    FlatOrdered,
    /// Two-level hierarchical P-Reduce: intra-machine gather, leader
    /// ring, intra-machine broadcast.
    Hier,
}

impl SyncShape {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s.to_ascii_lowercase().as_str() {
            "flat" => SyncShape::Flat,
            "flat-blind" | "blind" => SyncShape::FlatBlind,
            "flat-ordered" | "ordered" => SyncShape::FlatOrdered,
            "hier" | "hierarchical" => SyncShape::Hier,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            SyncShape::Flat => "flat",
            SyncShape::FlatBlind => "flat-blind",
            SyncShape::FlatOrdered => "flat-ordered",
            SyncShape::Hier => "hier",
        }
    }
}

/// Placement model for the sync collective (`[topology]` section).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct TopologyConfig {
    pub shape: SyncShape,
    /// Ranks per machine for the placement model; 0 (default) follows
    /// `cluster.workers_per_node`. Lets a sweep shrink or grow machines
    /// without disturbing the GG's architecture-aware grouping.
    pub nodes: usize,
}

impl TopologyConfig {
    /// Machine size the cost functions should use.
    pub fn per_machine(&self, cluster_wpn: usize) -> usize {
        if self.nodes > 0 {
            self.nodes
        } else {
            cluster_wpn.max(1)
        }
    }
}

/// Training-loop knobs (model-agnostic).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub lr: f32,
    pub max_iters: usize,
    /// Stop when the smoothed global loss falls below this (paper's
    /// "time to loss = 0.32" methodology, §7.1.4).
    pub loss_target: Option<f64>,
    pub seed: u64,
    /// Evaluate global loss every `eval_every` iterations of worker 0.
    pub eval_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            lr: 0.05,
            max_iters: 4000,
            loss_target: None,
            seed: 42,
            eval_every: 20,
        }
    }
}

/// Failure-handling policy (`[faults]` section).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultConfig {
    /// Repair on crash: declare the rank dead, abort its groups, let
    /// partners retry in repaired groups. `false` models the
    /// pre-fault-tolerance control plane (the AD-PSGD deadlock class):
    /// a crash holds its locks forever and the cluster grinds to a halt
    /// — what `fig failures` measures against.
    pub repair: bool,
    /// Virtual seconds between a crash and its detection (the sim's
    /// stand-in for the heartbeat deadline / accusation grace).
    pub detect_secs: f64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        Self { repair: true, detect_secs: 0.5 }
    }
}

impl FaultConfig {
    pub fn validate(&self) -> Result<(), String> {
        if !(self.detect_secs >= 0.0 && self.detect_secs.is_finite()) {
            return Err(format!("faults.detect_secs {} must be >= 0", self.detect_secs));
        }
        Ok(())
    }
}

/// Checkpointing policy (`[ckpt]` section; the deployment plane's
/// `--ckpt-every`/`--ckpt-dir` — see `net::ckpt`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CkptConfig {
    /// Snapshot every N iterations (0 = never).
    pub every: u64,
    /// Shared checkpoint directory.
    pub dir: Option<String>,
}

/// Top-level experiment configuration.
#[derive(Debug, Clone, Default)]
pub struct Experiment {
    pub cluster: ClusterConfig,
    pub algo: AlgoConfig,
    pub train: TrainConfig,
    /// Pipelined P-Reduce overlap knobs (`[overlap]` section; the serial
    /// default reproduces the stop-and-wait sync path bit-for-bit).
    pub overlap: OverlapConfig,
    /// Staged step-pipeline knobs (`[pipeline]` section): loader-stage
    /// prefetch depth and per-batch load time. The inline default
    /// (`prefetch = 0`) keeps the lockstep step model bit-for-bit; with
    /// prefetch the sim's step duration becomes `max(load, compute)`
    /// after the pipeline primes (DESIGN.md §Perf, "Staged step
    /// pipeline").
    pub pipeline: PipelineConfig,
    /// Crash repair/detection policy (`[faults]` section).
    pub faults: FaultConfig,
    /// Checkpoint cadence and location (`[ckpt]` section).
    pub ckpt: CkptConfig,
    /// Data-plane wire codec (`[wire]` section, `--wire`): how model
    /// elements are represented on the wire. The `fp32` default is the
    /// exact, golden-path behaviour; `fp16`/`q8` trade bounded precision
    /// for 2x/4x fewer bytes per sync (DESIGN.md §Perf, "Wire formats").
    pub wire: WireCodec,
    /// Sync-collective placement shape (`[topology]` section). The
    /// `flat` default charges the legacy worst-edge ring, bit-for-bit.
    pub topology: TopologyConfig,
}

impl Experiment {
    pub fn validate(&self) -> Result<(), String> {
        self.cluster.validate()?;
        self.algo.validate(self.cluster.n_workers())?;
        self.overlap.validate()?;
        self.pipeline.validate()?;
        self.faults.validate()?;
        for ev in &self.cluster.hetero.crashes {
            if ev.worker >= self.cluster.n_workers() {
                return Err(format!("crash worker {} out of range", ev.worker));
            }
            if ev.rejoin_after_secs.is_some_and(|r| !(r >= 0.0 && r.is_finite())) {
                return Err(format!(
                    "crash rejoin delay {:?} must be finite and >= 0",
                    ev.rejoin_after_secs
                ));
            }
        }
        Ok(())
    }

    /// Load from the TOML-subset format (see `config::parse`).
    pub fn from_file(path: &str) -> Result<Self, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {path}: {e}"))?;
        Self::from_str_cfg(&text)
    }

    pub fn from_str_cfg(text: &str) -> Result<Self, String> {
        let doc = parse_toml_subset(text)?;
        let mut exp = Experiment::default();
        for (section, key, value) in &doc {
            exp.apply(section, key, value)?;
        }
        exp.validate()?;
        Ok(exp)
    }

    fn apply(&mut self, section: &str, key: &str, v: &TomlValue) -> Result<(), String> {
        let bad = || format!("bad value for {section}.{key}: {v:?}");
        match (section, key) {
            ("cluster", "n_nodes") => self.cluster.n_nodes = v.as_usize().ok_or_else(bad)?,
            ("cluster", "workers_per_node") => {
                self.cluster.workers_per_node = v.as_usize().ok_or_else(bad)?
            }
            ("cluster", "intra_bw") => self.cluster.link.intra_bw = v.as_f64().ok_or_else(bad)?,
            ("cluster", "inter_bw") => self.cluster.link.inter_bw = v.as_f64().ok_or_else(bad)?,
            ("cluster", "intra_lat") => self.cluster.link.intra_lat = v.as_f64().ok_or_else(bad)?,
            ("cluster", "inter_lat") => self.cluster.link.inter_lat = v.as_f64().ok_or_else(bad)?,
            ("cluster", "rpc_rtt") => self.cluster.link.rpc_rtt = v.as_f64().ok_or_else(bad)?,
            ("cluster", "slow_worker") => {
                let pair = v.as_arr().ok_or_else(bad)?;
                if pair.len() != 2 {
                    return Err(bad());
                }
                self.cluster.hetero.slow_worker = Some((
                    pair[0].as_usize().ok_or_else(bad)?,
                    pair[1].as_f64().ok_or_else(bad)?,
                ));
            }
            ("cluster", "jitter") => self.cluster.hetero.jitter = v.as_f64().ok_or_else(bad)?,
            ("cluster", "slow_schedule") => {
                // flat [w, f, iter] triples: [7, 6.0, 40, 7, 1.0, 120]
                let arr = v.as_arr().ok_or_else(bad)?;
                if arr.is_empty() || arr.len() % 3 != 0 {
                    return Err(format!(
                        "cluster.slow_schedule wants flat [worker, factor, iter] \
                         triples, got {} values",
                        arr.len()
                    ));
                }
                self.cluster.hetero.schedule = arr
                    .chunks(3)
                    .map(|c| {
                        Ok(crate::cluster::SlowdownEvent {
                            worker: c[0].as_usize().ok_or_else(bad)?,
                            factor: c[1].as_f64().ok_or_else(bad)?,
                            start_iter: c[2].as_usize().ok_or_else(bad)? as u64,
                        })
                    })
                    .collect::<Result<_, String>>()?;
            }
            ("algo", "kind") => {
                let s = v.as_str().ok_or_else(bad)?;
                self.algo.kind =
                    AlgoKind::parse(s).ok_or_else(|| format!("unknown algorithm '{s}'"))?;
            }
            ("algo", "group_size") => self.algo.group_size = v.as_usize().ok_or_else(bad)?,
            ("algo", "c_thres") => self.algo.c_thres = v.as_usize().ok_or_else(bad)? as u64,
            ("algo", "section_len") => self.algo.section_len = v.as_usize().ok_or_else(bad)?,
            ("algo", "adpsgd_ring_only") => {
                self.algo.adpsgd_ring_only = v.as_bool().ok_or_else(bad)?
            }
            ("algo", "ps_shards") => self.algo.ps_shards = v.as_usize().ok_or_else(bad)?,
            ("train", "lr") => self.train.lr = v.as_f64().ok_or_else(bad)? as f32,
            ("train", "max_iters") => self.train.max_iters = v.as_usize().ok_or_else(bad)?,
            ("train", "loss_target") => {
                self.train.loss_target = Some(v.as_f64().ok_or_else(bad)?)
            }
            ("train", "seed") => self.train.seed = v.as_usize().ok_or_else(bad)? as u64,
            ("train", "eval_every") => self.train.eval_every = v.as_usize().ok_or_else(bad)?,
            ("overlap", "shards") => self.overlap.shards = v.as_usize().ok_or_else(bad)?,
            ("overlap", "max_staleness") => {
                self.overlap.max_staleness = v.as_usize().ok_or_else(bad)? as u64
            }
            ("pipeline", "prefetch") => {
                self.pipeline.prefetch = v.as_usize().ok_or_else(bad)?
            }
            ("pipeline", "load_secs") => {
                self.pipeline.load_secs = v.as_f64().ok_or_else(bad)?
            }
            ("cluster", "crash_schedule") => {
                // flat [worker, iter, rejoin_secs] triples; rejoin < 0 =
                // the rank stays gone: [7, 30, -1, 2, 10, 15.0]
                let arr = v.as_arr().ok_or_else(bad)?;
                if arr.is_empty() || arr.len() % 3 != 0 {
                    return Err(format!(
                        "cluster.crash_schedule wants flat [worker, iter, \
                         rejoin_secs] triples, got {} values",
                        arr.len()
                    ));
                }
                self.cluster.hetero.crashes = arr
                    .chunks(3)
                    .map(|c| {
                        let rejoin = c[2].as_f64().ok_or_else(bad)?;
                        Ok(crate::cluster::CrashEvent {
                            worker: c[0].as_usize().ok_or_else(bad)?,
                            at_iter: c[1].as_usize().ok_or_else(bad)? as u64,
                            rejoin_after_secs: (rejoin >= 0.0).then_some(rejoin),
                        })
                    })
                    .collect::<Result<_, String>>()?;
            }
            ("faults", "repair") => self.faults.repair = v.as_bool().ok_or_else(bad)?,
            ("faults", "detect_secs") => {
                self.faults.detect_secs = v.as_f64().ok_or_else(bad)?
            }
            ("cluster", "bw_schedule") => {
                // flat [worker, divisor, iter] triples, like slow_schedule
                let arr = v.as_arr().ok_or_else(bad)?;
                if arr.is_empty() || arr.len() % 3 != 0 {
                    return Err(format!(
                        "cluster.bw_schedule wants flat [worker, divisor, iter] \
                         triples, got {} values",
                        arr.len()
                    ));
                }
                self.cluster.hetero.bandwidth = arr
                    .chunks(3)
                    .map(|c| {
                        Ok(crate::cluster::BandwidthEvent {
                            worker: c[0].as_usize().ok_or_else(bad)?,
                            factor: c[1].as_f64().ok_or_else(bad)?,
                            start_iter: c[2].as_usize().ok_or_else(bad)? as u64,
                        })
                    })
                    .collect::<Result<_, String>>()?;
            }
            ("wire", "codec") => {
                let s = v.as_str().ok_or_else(bad)?;
                self.wire = WireCodec::parse(s)
                    .ok_or_else(|| format!("unknown wire codec '{s}' (fp32|fp16|q8)"))?;
            }
            ("ckpt", "every") => self.ckpt.every = v.as_usize().ok_or_else(bad)? as u64,
            ("ckpt", "dir") => self.ckpt.dir = Some(v.as_str().ok_or_else(bad)?.to_string()),
            ("topology", "shape") => {
                let s = v.as_str().ok_or_else(bad)?;
                self.topology.shape = SyncShape::parse(s).ok_or_else(|| {
                    format!(
                        "unknown topology shape '{s}' \
                         (flat|flat-blind|flat-ordered|hier)"
                    )
                })?;
            }
            ("topology", "nodes") => self.topology.nodes = v.as_usize().ok_or_else(bad)?,
            _ => return Err(format!("unknown config key {section}.{key}")),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_testbed() {
        let c = ClusterConfig::default();
        assert_eq!(c.n_workers(), 16);
        assert_eq!(c.node_of(0), 0);
        assert_eq!(c.node_of(15), 3);
        assert!(c.same_node(4, 7));
        assert!(!c.same_node(3, 4));
        assert!(c.validate().is_ok());
    }

    #[test]
    fn algo_kind_roundtrip() {
        for &k in AlgoKind::all() {
            assert_eq!(AlgoKind::parse(k.name()), Some(k), "{k:?}");
        }
        assert_eq!(AlgoKind::parse("nonsense"), None);
    }

    #[test]
    fn validation_rejects_bad_configs() {
        let mut e = Experiment::default();
        e.algo.group_size = 1;
        assert!(e.validate().is_err());
        e.algo.group_size = 99;
        assert!(e.validate().is_err());
        e.algo.group_size = 3;
        e.cluster.hetero.slow_worker = Some((99, 5.0));
        assert!(e.validate().is_err());
        e.cluster.hetero.slow_worker = Some((3, 0.5));
        assert!(e.validate().is_err());
        e.cluster.hetero.slow_worker = Some((3, 5.0));
        assert!(e.validate().is_ok());
    }

    #[test]
    fn config_file_roundtrip() {
        let text = r#"
            # paper heterogeneous setup
            [cluster]
            n_nodes = 4
            workers_per_node = 4
            slow_worker = [7, 5.0]

            [algo]
            kind = "ripples-smart"
            group_size = 3
            c_thres = 8

            [train]
            lr = 0.1
            max_iters = 2000
            loss_target = 0.32
        "#;
        let e = Experiment::from_str_cfg(text).unwrap();
        assert_eq!(e.cluster.n_workers(), 16);
        assert_eq!(e.cluster.hetero.slow_worker, Some((7, 5.0)));
        assert_eq!(e.algo.kind, AlgoKind::RipplesSmart);
        assert_eq!(e.train.loss_target, Some(0.32));
        assert!((e.train.lr - 0.1).abs() < 1e-6);
    }

    #[test]
    fn config_file_unknown_key_rejected() {
        assert!(Experiment::from_str_cfg("[algo]\nwat = 1\n").is_err());
    }

    #[test]
    fn ps_shards_config_roundtrip_and_validation() {
        let e = Experiment::from_str_cfg("[algo]\nps_shards = 4\n").unwrap();
        assert_eq!(e.algo.ps_shards, 4);
        // default 1 = the classic unsharded PS round
        assert_eq!(Experiment::default().algo.ps_shards, 1);
        assert!(Experiment::from_str_cfg("[algo]\nps_shards = 0\n").is_err());
    }

    #[test]
    fn overlap_config_roundtrip_and_validation() {
        let e = Experiment::from_str_cfg("[overlap]\nshards = 4\nmax_staleness = 2\n")
            .unwrap();
        assert_eq!(e.overlap.shards, 4);
        assert_eq!(e.overlap.max_staleness, 2);
        assert!(!e.overlap.is_serial());
        // default = serial (golden-test semantics)
        assert!(Experiment::default().overlap.is_serial());
        assert_eq!(Experiment::default().overlap.shards, 1);
        // zero shards fails validation
        assert!(Experiment::from_str_cfg("[overlap]\nshards = 0\n").is_err());
    }

    #[test]
    fn pipeline_config_roundtrip_and_validation() {
        let e = Experiment::from_str_cfg("[pipeline]\nprefetch = 4\nload_secs = 0.02\n")
            .unwrap();
        assert_eq!(e.pipeline.prefetch, 4);
        assert_eq!(e.pipeline.load_secs, 0.02);
        assert!(e.pipeline.is_staged());
        // default = inline (bit-identical lockstep step model)
        assert_eq!(Experiment::default().pipeline, PipelineConfig::inline());
        assert!(!Experiment::default().pipeline.is_staged());
        // negative load time fails validation
        assert!(Experiment::from_str_cfg("[pipeline]\nload_secs = -0.5\n").is_err());
    }

    #[test]
    fn wire_and_bw_schedule_config_roundtrip() {
        let e = Experiment::from_str_cfg(
            "[wire]\ncodec = \"q8\"\n\n\
             [cluster]\nbw_schedule = [7, 16.0, 0, 7, 1.0, 40]\n",
        )
        .unwrap();
        assert_eq!(e.wire, WireCodec::Q8);
        assert_eq!(e.cluster.hetero.bandwidth.len(), 2);
        assert_eq!(e.cluster.hetero.bandwidth[0].worker, 7);
        assert_eq!(e.cluster.hetero.bandwidth_factor_at(7, 10), 16.0);
        assert_eq!(e.cluster.hetero.bandwidth_factor_at(7, 40), 1.0);
        // default: exact wire, no throttles
        assert_eq!(Experiment::default().wire, WireCodec::Fp32);
        assert!(Experiment::default().cluster.hetero.bandwidth.is_empty());
        // malformed / out-of-range rejected
        assert!(Experiment::from_str_cfg("[wire]\ncodec = \"mp3\"\n").is_err());
        assert!(Experiment::from_str_cfg("[cluster]\nbw_schedule = [7, 16.0]\n").is_err());
        assert!(
            Experiment::from_str_cfg("[cluster]\nbw_schedule = [99, 16.0, 0]\n").is_err()
        );
        assert!(
            Experiment::from_str_cfg("[cluster]\nbw_schedule = [7, 0.5, 0]\n").is_err()
        );
    }

    #[test]
    fn topology_config_roundtrip_and_defaults() {
        let e = Experiment::from_str_cfg("[topology]\nshape = \"hier\"\nnodes = 2\n")
            .unwrap();
        assert_eq!(e.topology.shape, SyncShape::Hier);
        assert_eq!(e.topology.nodes, 2);
        assert_eq!(e.topology.per_machine(4), 2); // explicit override wins
        // default: legacy flat shape, machine size follows the cluster
        let d = Experiment::default();
        assert_eq!(d.topology.shape, SyncShape::Flat);
        assert_eq!(d.topology.per_machine(4), 4);
        assert_eq!(d.topology.per_machine(0), 1); // never a zero divisor
        // every shape name round-trips; junk is rejected
        for s in [
            SyncShape::Flat,
            SyncShape::FlatBlind,
            SyncShape::FlatOrdered,
            SyncShape::Hier,
        ] {
            assert_eq!(SyncShape::parse(s.name()), Some(s), "{s:?}");
        }
        assert!(Experiment::from_str_cfg("[topology]\nshape = \"torus\"\n").is_err());
    }

    #[test]
    fn slow_schedule_config_roundtrip() {
        let e = Experiment::from_str_cfg(
            "[cluster]\nslow_schedule = [7, 6.0, 40, 7, 1.0, 120]\n",
        )
        .unwrap();
        assert_eq!(e.cluster.hetero.schedule.len(), 2);
        assert_eq!(e.cluster.hetero.schedule[0].worker, 7);
        assert_eq!(e.cluster.hetero.schedule[0].factor, 6.0);
        assert_eq!(e.cluster.hetero.schedule[1].start_iter, 120);
        assert_eq!(e.cluster.hetero.slowdown_at(7, 50), 6.0);
        assert_eq!(e.cluster.hetero.slowdown_at(7, 120), 1.0);
    }

    #[test]
    fn crash_faults_and_ckpt_config_roundtrip() {
        let e = Experiment::from_str_cfg(
            "[cluster]\ncrash_schedule = [7, 30, -1, 2, 10, 15.0]\n\n\
             [faults]\nrepair = false\ndetect_secs = 0.25\n\n\
             [ckpt]\nevery = 50\ndir = \"ckpts\"\n",
        )
        .unwrap();
        assert_eq!(e.cluster.hetero.crashes.len(), 2);
        assert_eq!(e.cluster.hetero.crashes[0].worker, 7);
        assert_eq!(e.cluster.hetero.crashes[0].at_iter, 30);
        assert_eq!(e.cluster.hetero.crashes[0].rejoin_after_secs, None);
        assert_eq!(e.cluster.hetero.crashes[1].rejoin_after_secs, Some(15.0));
        assert!(!e.faults.repair);
        assert_eq!(e.faults.detect_secs, 0.25);
        assert_eq!(e.ckpt.every, 50);
        assert_eq!(e.ckpt.dir.as_deref(), Some("ckpts"));
        // defaults: repair on, no checkpoints
        let d = Experiment::default();
        assert!(d.faults.repair);
        assert_eq!(d.ckpt, CkptConfig::default());
    }

    #[test]
    fn crash_schedule_config_rejected_when_malformed() {
        // not flat triples
        assert!(Experiment::from_str_cfg("[cluster]\ncrash_schedule = [7, 30]\n").is_err());
        // out-of-range worker (default 16-worker cluster)
        assert!(
            Experiment::from_str_cfg("[cluster]\ncrash_schedule = [99, 30, -1]\n").is_err()
        );
        // negative detect window
        assert!(Experiment::from_str_cfg("[faults]\ndetect_secs = -1.0\n").is_err());
    }

    #[test]
    fn slow_schedule_config_rejected_when_malformed() {
        // not a flat triple list
        assert!(Experiment::from_str_cfg("[cluster]\nslow_schedule = [7, 6.0]\n").is_err());
        // wrong value type inside a triple
        assert!(Experiment::from_str_cfg(
            "[cluster]\nslow_schedule = [7, \"fast\", 40]\n"
        )
        .is_err());
        // out-of-range worker fails validation (default 16-worker cluster)
        assert!(
            Experiment::from_str_cfg("[cluster]\nslow_schedule = [99, 6.0, 40]\n").is_err()
        );
        // factor below 1 fails validation
        assert!(
            Experiment::from_str_cfg("[cluster]\nslow_schedule = [7, 0.5, 40]\n").is_err()
        );
    }
}
