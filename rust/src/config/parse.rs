//! TOML-subset parser: `[section]` headers and `key = value` lines where
//! value ∈ {int, float, bool, "string", [v, v, ...]}. Comments with `#`.
//!
//! Deliberately small — config files in this repo only need flat sections.

/// A parsed value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Num(f64),
    Bool(bool),
    Str(String),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            TomlValue::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// Parse into a flat list of `(section, key, value)` triples, preserving
/// file order (later keys override earlier ones when applied in order).
pub fn parse_toml_subset(text: &str) -> Result<Vec<(String, String, TomlValue)>, String> {
    let mut out = Vec::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            section = name.trim().to_string();
            if section.is_empty() {
                return Err(format!("line {}: empty section name", lineno + 1));
            }
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected 'key = value'", lineno + 1))?;
        let key = key.trim();
        if key.is_empty() {
            return Err(format!("line {}: empty key", lineno + 1));
        }
        let value = parse_value(value.trim())
            .map_err(|e| format!("line {}: {e}", lineno + 1))?;
        out.push((section.clone(), key.to_string(), value));
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| format!("unterminated string: {s}"))?;
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| format!("unterminated array: {s}"))?;
        let mut items = Vec::new();
        let inner = inner.trim();
        if !inner.is_empty() {
            for part in inner.split(',') {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(TomlValue::Arr(items));
    }
    s.parse::<f64>()
        .map(TomlValue::Num)
        .map_err(|_| format!("cannot parse value: {s}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse_toml_subset(
            "[a]\nx = 1\ny = 2.5  # trailing comment\nz = true\ns = \"hi # not a comment\"\n[b]\narr = [1, 2.5, 3]\n",
        )
        .unwrap();
        assert_eq!(doc.len(), 5);
        assert_eq!(doc[0], ("a".into(), "x".into(), TomlValue::Num(1.0)));
        assert_eq!(doc[1].2.as_f64(), Some(2.5));
        assert_eq!(doc[2].2.as_bool(), Some(true));
        assert_eq!(doc[3].2.as_str(), Some("hi # not a comment"));
        assert_eq!(doc[4].0, "b");
        assert_eq!(doc[4].2.as_arr().unwrap().len(), 3);
    }

    #[test]
    fn as_usize_rejects_fractions_and_negatives() {
        assert_eq!(TomlValue::Num(3.0).as_usize(), Some(3));
        assert_eq!(TomlValue::Num(3.5).as_usize(), None);
        assert_eq!(TomlValue::Num(-1.0).as_usize(), None);
    }

    #[test]
    fn errors_have_line_numbers() {
        let err = parse_toml_subset("x = 1\noops\n").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
        let err = parse_toml_subset("[a]\nk = \n").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn empty_array_ok() {
        let doc = parse_toml_subset("a = []\n").unwrap();
        assert_eq!(doc[0].2.as_arr().unwrap().len(), 0);
    }

    #[test]
    fn error_paths_cover_every_malformation() {
        // unterminated string
        let err = parse_toml_subset("s = \"oops\n").unwrap_err();
        assert!(err.contains("unterminated string"), "{err}");
        // unterminated array
        let err = parse_toml_subset("a = [1, 2\n").unwrap_err();
        assert!(err.contains("unterminated array"), "{err}");
        // bad value inside an array propagates with the line number
        let err = parse_toml_subset("x = 1\na = [1, zz]\n").unwrap_err();
        assert!(err.contains("line 2") && err.contains("zz"), "{err}");
        // empty section header
        let err = parse_toml_subset("[ ]\n").unwrap_err();
        assert!(err.contains("empty section"), "{err}");
        // empty key
        let err = parse_toml_subset(" = 5\n").unwrap_err();
        assert!(err.contains("empty key"), "{err}");
        // unparseable scalar
        let err = parse_toml_subset("x = 5abc\n").unwrap_err();
        assert!(err.contains("cannot parse value"), "{err}");
        // a line that is neither section nor key=value
        let err = parse_toml_subset("just words\n").unwrap_err();
        assert!(err.contains("expected 'key = value'"), "{err}");
    }

    #[test]
    fn comment_only_and_blank_lines_are_skipped() {
        let doc = parse_toml_subset("# header\n\n   \n# more\nx = 1\n").unwrap();
        assert_eq!(doc.len(), 1);
        assert_eq!(doc[0].1, "x");
    }
}
