//! Physical placement model and the per-group synchronization plan.
//!
//! The paper's P-Reduce rings are flat and order-blind; PR 5's bandwidth
//! schedules made the cost visible — a constrained uplink is crossed
//! `2(p-1)` times per collective. This module is the shape layer that
//! fixes it (DESIGN.md §Perf, "Hierarchical P-Reduce"):
//!
//! * [`Topology`] — rank → machine placement, parsed from `--topo` /
//!   `[topology] nodes = "..."` with the grammar `m0:0,1;m1:2,3`
//!   (machine name, colon, comma-separated ranks; machines separated by
//!   semicolons). Ranks absent from the spec get an implicit singleton
//!   machine — a worker the operator did not place is assumed alone.
//! * [`SyncPlan`] — the placement-aware execution plan the Group
//!   Generator attaches to every drafted group: a node-major list of
//!   member lists (leader first). The plan is computed by the *pure*
//!   [`SyncPlan::make`] from `(members, topology, measured speeds)`, so
//!   the single-lock and sharded GG backends produce bit-identical plans
//!   and the RPC layer can assemble it at reply time without touching
//!   either state machine.
//!
//! Plan semantics (executed by `collectives::hier` and `net::worker`):
//! multi-member nodes reduce intra-node onto their leader, the leaders
//! run one inter-node ring dividing by the *group total*, then broadcast
//! back. The all-singleton plan degenerates to a flat ring whose order
//! is the plan's node order — bandwidth-ordered by the measured
//! [`SpeedTable`](crate::gg::SpeedTable) telemetry (slowest first), so
//! adjacent slow links collapse instead of gating every edge.

/// Rank → machine placement, the operator-declared ground truth the GG
/// plans against. Construct with [`Topology::parse`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Topology {
    /// Machine index per rank (`0..n_workers`).
    node_of: Vec<usize>,
    /// Machine names, indexed by machine id (implicit singletons are
    /// named after their rank).
    names: Vec<String>,
}

impl Topology {
    /// Parse a `name:r0,r1;name2:r2,...` placement spec for `n_workers`
    /// ranks. Errors (satellite-tested): a rank outside `0..n_workers`,
    /// the same rank placed on two machines, or a machine with no ranks.
    /// Ranks the spec never mentions are placed alone on an implicit
    /// machine named after the rank.
    pub fn parse(spec: &str, n_workers: usize) -> Result<Topology, String> {
        let mut node_of: Vec<Option<usize>> = vec![None; n_workers];
        let mut names: Vec<String> = Vec::new();
        for part in spec.split(';').filter(|p| !p.trim().is_empty()) {
            let part = part.trim();
            let (name, ranks) = part
                .split_once(':')
                .ok_or_else(|| format!("bad topology entry {part:?}: expected NAME:R,R,..."))?;
            let name = name.trim();
            if name.is_empty() {
                return Err(format!("bad topology entry {part:?}: empty machine name"));
            }
            let node = names.len();
            let mut placed = 0usize;
            for r in ranks.split(',').filter(|r| !r.trim().is_empty()) {
                let rank: usize = r
                    .trim()
                    .parse()
                    .map_err(|e| format!("bad rank {r:?} on machine {name:?}: {e}"))?;
                if rank >= n_workers {
                    return Err(format!(
                        "unknown rank {rank} on machine {name:?} (cluster has {n_workers} workers)"
                    ));
                }
                if let Some(prev) = node_of[rank] {
                    return Err(format!(
                        "rank {rank} placed on two machines: {:?} and {name:?}",
                        names[prev]
                    ));
                }
                node_of[rank] = Some(node);
                placed += 1;
            }
            if placed == 0 {
                return Err(format!("machine {name:?} has no ranks (empty node)"));
            }
            names.push(name.to_string());
        }
        // implicit singleton machines for unplaced ranks
        let node_of = node_of
            .into_iter()
            .enumerate()
            .map(|(rank, n)| match n {
                Some(n) => n,
                None => {
                    names.push(rank.to_string());
                    names.len() - 1
                }
            })
            .collect();
        Ok(Topology { node_of, names })
    }

    /// Machine index of `rank` (ranks beyond the parsed cluster size are
    /// treated as alone — a rejoined replacement keeps its placement).
    pub fn node_of(&self, rank: usize) -> usize {
        self.node_of.get(rank).copied().unwrap_or(usize::MAX - rank)
    }

    /// Number of machines (explicit + implicit singletons).
    pub fn n_nodes(&self) -> usize {
        self.names.len()
    }

    /// Number of ranks this topology places.
    pub fn n_workers(&self) -> usize {
        self.node_of.len()
    }

    /// Machine name by index.
    pub fn name(&self, node: usize) -> &str {
        &self.names[node]
    }
}

/// The placement-aware execution plan for one drafted group: node-major
/// member lists, leader first within each node. Attached to Sync/Armed
/// RPC replies so every member executes the same shape.
///
/// Invariants (guaranteed by [`SyncPlan::make`], checked by
/// [`SyncPlan::validate`]): the concatenation of `nodes` is a
/// permutation of the group's members; no node is empty.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct SyncPlan {
    /// One entry per physical node with drafted members; each inner list
    /// is `[leader, member, member, ...]`. All-singleton = flat ring in
    /// this exact order.
    pub nodes: Vec<Vec<usize>>,
}

impl SyncPlan {
    /// Build the plan for `members` from the placement and the measured
    /// per-rank EWMA step seconds (`speeds[r]`, 0.0 = unmeasured — the
    /// [`SpeedTable`](crate::gg::SpeedTable) snapshot convention).
    ///
    /// Pure and deterministic: both GG backends call this at RPC reply
    /// time, so the differential `prop_gg` equivalence is untouched.
    ///
    /// * With a topology: members bucket by machine (node order = first
    ///   appearance in drafted order); each bucket's leader is its
    ///   fastest *measured* member (lowest EWMA; ties and the unmeasured
    ///   case fall back to lowest rank), remaining members ascend by
    ///   rank.
    /// * Without: every member is its own node, stably ordered
    ///   slowest-first by EWMA (unmeasured members keep drafted order at
    ///   the tail) — the bandwidth-ordered flat ring.
    pub fn make(members: &[usize], topo: Option<&Topology>, speeds: &[f64]) -> SyncPlan {
        let ewma = |r: usize| speeds.get(r).copied().unwrap_or(0.0);
        let Some(topo) = topo else {
            // flat degenerate case: bandwidth-ordered singletons
            let mut order: Vec<usize> = members.to_vec();
            // stable sort, slowest (largest EWMA) first; unmeasured (0.0)
            // members sink to the tail in drafted order
            order.sort_by(|&a, &b| {
                ewma(b).partial_cmp(&ewma(a)).unwrap_or(std::cmp::Ordering::Equal)
            });
            return SyncPlan { nodes: order.into_iter().map(|r| vec![r]).collect() };
        };
        let mut nodes: Vec<(usize, Vec<usize>)> = Vec::new();
        for &m in members {
            let node = topo.node_of(m);
            match nodes.iter_mut().find(|(n, _)| *n == node) {
                Some((_, bucket)) => bucket.push(m),
                None => nodes.push((node, vec![m])),
            }
        }
        let nodes = nodes
            .into_iter()
            .map(|(_, mut bucket)| {
                // leader = fastest measured member (ties / all-unmeasured
                // resolve to lowest rank); the rest ascend by rank
                let lead = *bucket
                    .iter()
                    .min_by(|&&a, &&b| {
                        let ka = if ewma(a) > 0.0 { ewma(a) } else { f64::INFINITY };
                        let kb = if ewma(b) > 0.0 { ewma(b) } else { f64::INFINITY };
                        ka.partial_cmp(&kb)
                            .unwrap_or(std::cmp::Ordering::Equal)
                            .then(a.cmp(&b))
                    })
                    .expect("non-empty bucket");
                bucket.retain(|&m| m != lead);
                bucket.sort_unstable();
                let mut out = Vec::with_capacity(bucket.len() + 1);
                out.push(lead);
                out.extend(bucket);
                out
            })
            .collect();
        SyncPlan { nodes }
    }

    /// A trivially flat plan in drafted order (what plan-less peers --
    /// e.g. pre-topology launchers -- implicitly run).
    pub fn flat(members: &[usize]) -> SyncPlan {
        SyncPlan { nodes: members.iter().map(|&m| vec![m]).collect() }
    }

    /// True when every node is a singleton — execute as a flat ring in
    /// plan order.
    pub fn is_flat(&self) -> bool {
        self.nodes.iter().all(|n| n.len() == 1)
    }

    /// Total member count across nodes.
    pub fn total(&self) -> usize {
        self.nodes.iter().map(|n| n.len()).sum()
    }

    /// The flat ring order (node-major flatten) — the execution order of
    /// the degenerate case, and the canonical member enumeration.
    pub fn ring_order(&self) -> Vec<usize> {
        self.nodes.iter().flatten().copied().collect()
    }

    /// One leader per node, in node order — the inter-node ring.
    pub fn leaders(&self) -> Vec<usize> {
        self.nodes.iter().map(|n| n[0]).collect()
    }

    /// Locate `rank` as `(node_index, index_within_node)`.
    pub fn position_of(&self, rank: usize) -> Option<(usize, usize)> {
        self.nodes.iter().enumerate().find_map(|(ni, node)| {
            node.iter().position(|&m| m == rank).map(|ii| (ni, ii))
        })
    }

    /// Check the plan covers exactly `members` (as a set) with no empty
    /// node — what an executing worker asserts before trusting a plan
    /// that crossed the wire.
    pub fn validate(&self, members: &[usize]) -> Result<(), String> {
        if self.nodes.iter().any(|n| n.is_empty()) {
            return Err("plan has an empty node".into());
        }
        let mut planned = self.ring_order();
        let mut expect = members.to_vec();
        planned.sort_unstable();
        expect.sort_unstable();
        if planned != expect {
            return Err(format!(
                "plan members {planned:?} do not match group members {expect:?}"
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_places_ranks_and_implicit_singletons() {
        let t = Topology::parse("m0:0,1;m1:2,3", 6).unwrap();
        assert_eq!(t.node_of(0), t.node_of(1));
        assert_eq!(t.node_of(2), t.node_of(3));
        assert_ne!(t.node_of(0), t.node_of(2));
        // 4 and 5 are implicit singletons on their own machines
        assert_ne!(t.node_of(4), t.node_of(5));
        assert_ne!(t.node_of(4), t.node_of(0));
        assert_eq!(t.n_nodes(), 4);
        assert_eq!(t.name(t.node_of(0)), "m0");
        assert_eq!(t.name(t.node_of(4)), "4");
        assert_eq!(t.n_workers(), 6);
    }

    #[test]
    fn parse_rejects_unknown_rank() {
        let err = Topology::parse("m0:0,9", 4).unwrap_err();
        assert!(err.contains("unknown rank 9"), "{err}");
    }

    #[test]
    fn parse_rejects_rank_on_two_machines() {
        let err = Topology::parse("m0:0,1;m1:1,2", 4).unwrap_err();
        assert!(err.contains("rank 1 placed on two machines"), "{err}");
        // same machine twice is the same defect
        let err = Topology::parse("m0:0,0", 4).unwrap_err();
        assert!(err.contains("two machines"), "{err}");
    }

    #[test]
    fn parse_rejects_empty_node() {
        let err = Topology::parse("m0:0;empty:", 4).unwrap_err();
        assert!(err.contains("empty node"), "{err}");
        let err = Topology::parse("m0:0;:1", 4).unwrap_err();
        assert!(err.contains("empty machine name"), "{err}");
        assert!(Topology::parse("m0", 4).is_err()); // no colon at all
    }

    #[test]
    fn parse_tolerates_whitespace_and_empty_spec() {
        let t = Topology::parse(" m0 : 0 , 1 ; m1 : 2 ", 3).unwrap();
        assert_eq!(t.node_of(0), t.node_of(1));
        assert_ne!(t.node_of(0), t.node_of(2));
        // an empty spec = everyone alone
        let t = Topology::parse("", 3).unwrap();
        assert_eq!(t.n_nodes(), 3);
    }

    #[test]
    fn plan_without_topology_orders_slowest_first() {
        // speeds are EWMA step seconds: larger = slower
        let speeds = vec![0.01, 0.08, 0.02, 0.0];
        let plan = SyncPlan::make(&[0, 1, 2, 3], None, &speeds);
        assert!(plan.is_flat());
        assert_eq!(plan.ring_order(), vec![1, 2, 0, 3]); // unmeasured 3 last
        assert_eq!(plan.total(), 4);
        plan.validate(&[0, 1, 2, 3]).unwrap();
    }

    #[test]
    fn plan_with_topology_buckets_by_node_and_picks_fast_leader() {
        let topo = Topology::parse("a:0,1,2;b:3,4,5", 6).unwrap();
        let speeds = vec![0.03, 0.01, 0.02, 0.0, 0.0, 0.0];
        let plan = SyncPlan::make(&[0, 3, 1, 4, 2, 5], Some(&topo), &speeds);
        assert!(!plan.is_flat());
        assert_eq!(plan.nodes.len(), 2);
        // node a first (rank 0 drafted first); leader 1 (fastest measured)
        assert_eq!(plan.nodes[0], vec![1, 0, 2]);
        // node b: nobody measured -> lowest rank leads
        assert_eq!(plan.nodes[1], vec![3, 4, 5]);
        assert_eq!(plan.leaders(), vec![1, 3]);
        assert_eq!(plan.position_of(2), Some((0, 2)));
        assert_eq!(plan.position_of(3), Some((1, 0)));
        assert_eq!(plan.position_of(9), None);
        plan.validate(&[0, 1, 2, 3, 4, 5]).unwrap();
    }

    #[test]
    fn plan_is_deterministic_for_shuffled_speeds_ties() {
        let topo = Topology::parse("a:0,1;b:2,3", 4).unwrap();
        // exact EWMA ties: lowest rank must lead, stably
        let speeds = vec![0.02, 0.02, 0.02, 0.02];
        let p1 = SyncPlan::make(&[2, 0, 3, 1], Some(&topo), &speeds);
        let p2 = SyncPlan::make(&[2, 0, 3, 1], Some(&topo), &speeds);
        assert_eq!(p1, p2);
        assert_eq!(p1.nodes, vec![vec![2, 3], vec![0, 1]]);
    }

    #[test]
    fn plan_validate_catches_mismatches() {
        let plan = SyncPlan { nodes: vec![vec![0, 1], vec![2]] };
        plan.validate(&[2, 0, 1]).unwrap();
        assert!(plan.validate(&[0, 1]).is_err());
        assert!(plan.validate(&[0, 1, 3]).is_err());
        let empty = SyncPlan { nodes: vec![vec![0], vec![]] };
        assert!(empty.validate(&[0]).is_err());
    }

    #[test]
    fn flat_plan_preserves_drafted_order() {
        let plan = SyncPlan::flat(&[3, 1, 2]);
        assert!(plan.is_flat());
        assert_eq!(plan.ring_order(), vec![3, 1, 2]);
    }
}
