//! Staged step pipeline: the shared load → compute → reconcile
//! decomposition of the per-worker training step (DESIGN.md §Perf,
//! "Staged step pipeline").
//!
//! The monolithic worker loop serializes three activities that have no
//! data dependency on each other across *adjacent* iterations: drawing
//! the next mini-batch, running SGD on the current one, and folding
//! finished P-Reduce shards back into the live model. This module owns
//! the machinery every execution surface shares to overlap them:
//!
//! * [`Bounded`] — a bounded SPSC handoff queue with blocking
//!   backpressure, poison-aware shutdown, and built-in stall meters
//!   (the `load_wait`/`compute_wait`/`reconcile_wait` counters reported
//!   by workers come from these meters). A queue drains its remaining
//!   items even after [`Bounded::poison`], so a consumer always sees
//!   every item the producer completed before the fault — the
//!   keep-fully-averaged-shards rule of the overlap engine extended to
//!   every stage boundary.
//! * [`Stage`] — one pipeline stage as a value: pull an input, produce
//!   an output. [`spawn`] drives a stage on its own thread between two
//!   queues and propagates close/poison in both directions, so a fault
//!   (or a clean shutdown) anywhere in the pipeline unwinds every
//!   stage without deadlocking.
//! * [`PipelineConfig`] — the `--prefetch N` / `--load-ms` knobs shared
//!   by the distributed worker, the threaded runtime, and the
//!   simulator's virtual-time model (`[pipeline]` config section).
//!
//! Buffer recycling falls out of the topology rather than a dedicated
//! pool type: stages hand *spare* buffers back upstream through a
//! second bounded queue (consumer → producer), so the loader refills
//! recycled allocations instead of allocating per batch, and the spare
//! queue's bound doubles as the prefetch-depth limit. `prefetch = 0`
//! (the default) bypasses the queues entirely and runs today's inline
//! lockstep loop bit-for-bit.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Why a [`Bounded::pop`] returned no item: the producer side shut the
/// queue down cleanly, or poisoned it (fault propagation across a stage
/// boundary — the queue analogue of a poison frame on the ring).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueueEnd {
    /// Clean shutdown: the producer finished and no more items exist.
    Closed,
    /// Fault shutdown: the producer hit an error (collective abort,
    /// stage failure). Items popped before this were still valid.
    Poisoned,
}

struct Inner<T> {
    q: VecDeque<T>,
    closed: bool,
    poisoned: bool,
    /// High-water mark: the most items ever queued at once (the
    /// capacity property test pins `max_occupancy <= capacity`).
    max_occupancy: usize,
}

/// A bounded SPSC handoff queue: `push` blocks while full
/// (backpressure), `pop` blocks while empty, and either side can end
/// the stream with [`close`](Bounded::close) (clean) or
/// [`poison`](Bounded::poison) (fault). Remaining items are always
/// drained before the consumer observes the end.
///
/// Both blocking directions are metered ([`recv_wait`](Bounded::recv_wait),
/// [`send_wait`](Bounded::send_wait)) — those meters are the per-stage
/// stall counters the pipeline reports.
pub struct Bounded<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
    recv_wait_ns: AtomicU64,
    send_wait_ns: AtomicU64,
}

impl<T> Bounded<T> {
    /// A queue holding at most `cap` items (`cap` is clamped to >= 1).
    pub fn new(cap: usize) -> Arc<Self> {
        Arc::new(Self {
            inner: Mutex::new(Inner {
                q: VecDeque::with_capacity(cap.max(1)),
                closed: false,
                poisoned: false,
                max_occupancy: 0,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cap: cap.max(1),
            recv_wait_ns: AtomicU64::new(0),
            send_wait_ns: AtomicU64::new(0),
        })
    }

    fn add_wait(meter: &AtomicU64, since: Instant) {
        let ns = since.elapsed().as_nanos().min(u64::MAX as u128) as u64;
        meter.fetch_add(ns, Ordering::Relaxed);
    }

    /// Blocking send. Waits while the queue is full (metered as
    /// producer stall time); returns the item back if the queue was
    /// closed or poisoned before it could be accepted.
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut g = self.inner.lock().unwrap();
        while g.q.len() >= self.cap && !g.closed && !g.poisoned {
            let t0 = Instant::now();
            g = self.not_full.wait(g).unwrap();
            Self::add_wait(&self.send_wait_ns, t0);
        }
        if g.closed || g.poisoned {
            return Err(item);
        }
        g.q.push_back(item);
        g.max_occupancy = g.max_occupancy.max(g.q.len());
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking receive. Drains queued items first — even after close
    /// or poison — then reports how the stream ended (metered as
    /// consumer stall time).
    pub fn pop(&self) -> Result<T, QueueEnd> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.q.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Ok(item);
            }
            if g.poisoned {
                return Err(QueueEnd::Poisoned);
            }
            if g.closed {
                return Err(QueueEnd::Closed);
            }
            let t0 = Instant::now();
            g = self.not_empty.wait(g).unwrap();
            Self::add_wait(&self.recv_wait_ns, t0);
        }
    }

    /// Non-blocking receive: `Ok(Some)` on an item, `Ok(None)` when
    /// empty but still open, `Err` when empty and ended.
    pub fn try_pop(&self) -> Result<Option<T>, QueueEnd> {
        let mut g = self.inner.lock().unwrap();
        if let Some(item) = g.q.pop_front() {
            drop(g);
            self.not_full.notify_one();
            return Ok(Some(item));
        }
        if g.poisoned {
            return Err(QueueEnd::Poisoned);
        }
        if g.closed {
            return Err(QueueEnd::Closed);
        }
        Ok(None)
    }

    /// Clean end-of-stream: queued items remain poppable, further
    /// pushes fail, blocked threads wake. Idempotent.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Fault end-of-stream: like [`close`](Bounded::close) but consumers
    /// observe [`QueueEnd::Poisoned`] after draining. Poison wins over a
    /// concurrent close. Idempotent.
    pub fn poison(&self) {
        self.inner.lock().unwrap().poisoned = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// True once [`poison`](Bounded::poison) has been called.
    pub fn is_poisoned(&self) -> bool {
        self.inner.lock().unwrap().poisoned
    }

    /// Items currently queued.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }

    /// True when no items are queued.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The configured bound.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// High-water mark of queued items over the queue's lifetime.
    pub fn max_occupancy(&self) -> usize {
        self.inner.lock().unwrap().max_occupancy
    }

    /// Total time consumers spent blocked in [`pop`](Bounded::pop).
    pub fn recv_wait(&self) -> Duration {
        Duration::from_nanos(self.recv_wait_ns.load(Ordering::Relaxed))
    }

    /// Total time producers spent blocked in [`push`](Bounded::push)
    /// (backpressure from a full queue).
    pub fn send_wait(&self) -> Duration {
        Duration::from_nanos(self.send_wait_ns.load(Ordering::Relaxed))
    }
}

/// Closes a [`Bounded`] queue when dropped — placed at the top of a
/// stage thread so a panic (or any early return) still releases peers
/// blocked on the queue instead of wedging the pipeline.
pub struct CloseGuard<T>(pub Arc<Bounded<T>>);

impl<T> Drop for CloseGuard<T> {
    fn drop(&mut self) {
        self.0.close();
    }
}

/// One pipeline stage as a value: transform an input pulled from the
/// upstream queue into an output for the downstream queue. Stages are
/// driven by [`spawn`]; state (RNG streams, datasets, scratch) lives in
/// the implementing struct, which is what makes a loader's batch
/// sequence deterministic regardless of queue timing.
pub trait Stage {
    /// Upstream item type (often a recycled buffer to refill).
    type In: Send + 'static;
    /// Downstream item type.
    type Out: Send + 'static;
    /// Process one item. An `Err` poisons the downstream queue and
    /// stops the stage.
    fn process(&mut self, item: Self::In) -> Result<Self::Out, String>;
}

/// Drive a [`Stage`] on its own thread: pop from `rx`, process, push to
/// `tx`, until either queue ends. Close/poison propagates both ways —
/// upstream close drains into a downstream close, upstream poison or a
/// stage error becomes a downstream poison, and a downstream shutdown
/// closes `rx` so the producer above stops too.
pub fn spawn<S>(
    mut stage: S,
    rx: Arc<Bounded<S::In>>,
    tx: Arc<Bounded<S::Out>>,
) -> std::thread::JoinHandle<Result<(), String>>
where
    S: Stage + Send + 'static,
{
    std::thread::spawn(move || {
        let _up = CloseGuard(Arc::clone(&rx));
        let _down = CloseGuard(Arc::clone(&tx));
        loop {
            match rx.pop() {
                Ok(item) => match stage.process(item) {
                    Ok(out) => {
                        if tx.push(out).is_err() {
                            // downstream ended first: stop pulling so the
                            // guard's close unwinds the upstream producer
                            return Ok(());
                        }
                    }
                    Err(e) => {
                        tx.poison();
                        return Err(e);
                    }
                },
                Err(QueueEnd::Closed) => return Ok(()),
                Err(QueueEnd::Poisoned) => {
                    tx.poison();
                    return Err("upstream stage poisoned".into());
                }
            }
        }
    })
}

/// Staged-pipeline knobs, shared by the distributed worker
/// (`--prefetch` / `--load-ms`), the threaded runtime, and the
/// simulator's virtual-time model (`[pipeline]` config section).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PipelineConfig {
    /// Mini-batches the loader stage keeps ready ahead of compute
    /// (queue depth). 0 = no loader thread: the inline lockstep loop,
    /// bit-identical to the pre-pipeline behaviour.
    pub prefetch: usize,
    /// Modeled per-batch load duration: virtual seconds in the sim, an
    /// emulated I/O floor (`--load-ms`) on real surfaces. 0 = loading
    /// costs only what the batch synthesis itself costs.
    pub load_secs: f64,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self::inline()
    }
}

impl PipelineConfig {
    /// The lockstep default: no loader stage, no modeled load cost.
    pub fn inline() -> Self {
        Self { prefetch: 0, load_secs: 0.0 }
    }

    /// True when a loader stage should run on its own thread.
    pub fn is_staged(&self) -> bool {
        self.prefetch > 0
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.prefetch > 1024 {
            return Err(format!(
                "pipeline.prefetch {} is unreasonable (max 1024)",
                self.prefetch
            ));
        }
        if !self.load_secs.is_finite() || self.load_secs < 0.0 {
            return Err(format!(
                "pipeline.load_secs must be finite and >= 0 (got {})",
                self.load_secs
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn push_pop_fifo_and_close() {
        let q = Bounded::new(4);
        for i in 0..3 {
            q.push(i).unwrap();
        }
        q.close();
        assert_eq!(q.pop(), Ok(0));
        assert_eq!(q.pop(), Ok(1));
        assert_eq!(q.pop(), Ok(2));
        assert_eq!(q.pop(), Err(QueueEnd::Closed));
        assert_eq!(q.push(9), Err(9));
        assert_eq!(q.max_occupancy(), 3);
    }

    #[test]
    fn poison_drains_then_reports() {
        let q = Bounded::new(4);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.poison();
        // queued items survive the poison; only the end marker changes
        assert_eq!(q.pop(), Ok(1));
        assert_eq!(q.try_pop(), Ok(Some(2)));
        assert_eq!(q.pop(), Err(QueueEnd::Poisoned));
        assert_eq!(q.try_pop(), Err(QueueEnd::Poisoned));
        assert!(q.is_poisoned());
    }

    #[test]
    fn poison_wins_over_close() {
        let q = Bounded::<u32>::new(2);
        q.close();
        q.poison();
        assert_eq!(q.pop(), Err(QueueEnd::Poisoned));
    }

    #[test]
    fn try_pop_empty_open_is_none() {
        let q = Bounded::<u32>::new(2);
        assert_eq!(q.try_pop(), Ok(None));
    }

    #[test]
    fn backpressure_blocks_and_meters() {
        let q = Bounded::new(1);
        q.push(0u32).unwrap();
        let q2 = Arc::clone(&q);
        let producer = thread::spawn(move || q2.push(1).is_ok());
        thread::sleep(Duration::from_millis(20));
        // producer is blocked on the full queue; free a slot
        assert_eq!(q.pop(), Ok(0));
        assert!(producer.join().unwrap());
        assert_eq!(q.pop(), Ok(1));
        assert!(q.send_wait() >= Duration::from_millis(5), "{:?}", q.send_wait());
        assert_eq!(q.max_occupancy(), 1);
    }

    #[test]
    fn pop_blocks_until_push_and_meters() {
        let q = Bounded::<u32>::new(2);
        let q2 = Arc::clone(&q);
        let consumer = thread::spawn(move || q2.pop());
        thread::sleep(Duration::from_millis(20));
        q.push(7).unwrap();
        assert_eq!(consumer.join().unwrap(), Ok(7));
        assert!(q.recv_wait() >= Duration::from_millis(5), "{:?}", q.recv_wait());
    }

    #[test]
    fn close_guard_releases_blocked_consumer() {
        let q = Bounded::<u32>::new(2);
        let q2 = Arc::clone(&q);
        let consumer = thread::spawn(move || q2.pop());
        let producer = thread::spawn(move || {
            let _guard = CloseGuard(Arc::clone(&q));
            // exits without pushing: the guard must close the queue
        });
        producer.join().unwrap();
        assert_eq!(consumer.join().unwrap(), Err(QueueEnd::Closed));
    }

    /// Doubler stage used by the driver tests.
    struct Doubler;
    impl Stage for Doubler {
        type In = u32;
        type Out = u32;
        fn process(&mut self, item: u32) -> Result<u32, String> {
            if item == 13 {
                return Err("unlucky".into());
            }
            Ok(item * 2)
        }
    }

    #[test]
    fn spawned_stage_maps_and_closes_downstream() {
        let rx = Bounded::new(2);
        let tx = Bounded::new(2);
        let h = spawn(Doubler, Arc::clone(&rx), Arc::clone(&tx));
        for i in 0..5u32 {
            rx.push(i).unwrap();
        }
        rx.close();
        let mut got = Vec::new();
        while let Ok(v) = tx.pop() {
            got.push(v);
        }
        assert_eq!(got, vec![0, 2, 4, 6, 8]);
        assert_eq!(tx.pop(), Err(QueueEnd::Closed));
        assert!(h.join().unwrap().is_ok());
    }

    #[test]
    fn stage_error_poisons_downstream_and_closes_upstream() {
        let rx = Bounded::new(4);
        let tx = Bounded::new(4);
        let h = spawn(Doubler, Arc::clone(&rx), Arc::clone(&tx));
        rx.push(1).unwrap();
        rx.push(13).unwrap(); // stage error
        // good output before the fault still arrives, then poison
        assert_eq!(tx.pop(), Ok(2));
        assert_eq!(tx.pop(), Err(QueueEnd::Poisoned));
        assert!(h.join().unwrap().is_err());
        // the guard closed the upstream queue so producers stop
        assert_eq!(rx.push(5), Err(5));
    }

    #[test]
    fn upstream_poison_propagates_through_stage() {
        let rx = Bounded::new(4);
        let tx = Bounded::new(4);
        let h = spawn(Doubler, Arc::clone(&rx), Arc::clone(&tx));
        rx.push(3).unwrap();
        rx.poison();
        assert_eq!(tx.pop(), Ok(6));
        assert_eq!(tx.pop(), Err(QueueEnd::Poisoned));
        assert!(h.join().unwrap().is_err());
    }

    #[test]
    fn downstream_shutdown_stops_stage_cleanly() {
        let rx = Bounded::new(4);
        let tx = Bounded::<u32>::new(1);
        let h = spawn(Doubler, Arc::clone(&rx), Arc::clone(&tx));
        tx.close();
        // the stage notices on its next push and exits Ok, closing rx
        rx.push(1).unwrap_or(());
        assert!(h.join().unwrap().is_ok());
    }

    #[test]
    fn pipeline_config_validation_and_defaults() {
        let d = PipelineConfig::default();
        assert_eq!(d, PipelineConfig::inline());
        assert!(!d.is_staged());
        assert!(d.validate().is_ok());
        assert!(PipelineConfig { prefetch: 4, load_secs: 0.01 }.is_staged());
        assert!(PipelineConfig { prefetch: 4, load_secs: 0.01 }.validate().is_ok());
        assert!(PipelineConfig { prefetch: 2000, load_secs: 0.0 }.validate().is_err());
        assert!(PipelineConfig { prefetch: 0, load_secs: -1.0 }.validate().is_err());
        assert!(PipelineConfig { prefetch: 0, load_secs: f64::NAN }.validate().is_err());
    }
}
