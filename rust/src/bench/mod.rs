//! Benchmark harnesses that regenerate every table and figure of the
//! paper's evaluation (§7). Each `figN` function builds the workload,
//! sweeps the parameters, runs all algorithms involved, and returns the
//! rows the paper reports (plus the paper's expected *shape* for
//! comparison). Invoked via `ripples fig <id>` and by `cargo bench`.

pub mod ablation;
pub mod figures;

use crate::config::{AlgoKind, Experiment};
use crate::model::MlpSpec;
use crate::sim::{self, SimParams, SimResult};

/// The fast "bench" model: small enough that real-math convergence sweeps
/// run in seconds, big enough to show the algorithms' statistical
/// differences. Communication costs stay calibrated to VGG-16 regardless
/// (see `SimParams.model_bytes`).
pub fn bench_spec() -> MlpSpec {
    MlpSpec { in_dim: 16, hidden: vec![64], classes: 10 }
}

/// Default loss target for time-to-convergence experiments (the analogue
/// of the paper's "loss = 0.32" on VGG-16/CIFAR-10, §7.1.4).
pub const LOSS_TARGET: f64 = 0.02;

/// Standard experiment: 16 workers on 4 nodes, VGG-16-calibrated costs.
pub fn base_params(kind: AlgoKind) -> SimParams {
    let mut exp = Experiment::default();
    exp.algo.kind = kind;
    exp.train.lr = 0.08;
    exp.train.max_iters = 2500;
    exp.train.eval_every = 5;
    exp.train.loss_target = Some(LOSS_TARGET);
    exp.train.seed = 42;
    let mut p = SimParams::vgg16_defaults(exp);
    p.spec = bench_spec();
    p.dataset_size = 2048;
    p.batch = 64;
    p.data_bias = 0.6; // non-IID shards: sync structure drives convergence
    p
}

/// Run `kind` with an optional `(worker, factor)` slowdown.
pub fn run_algo(kind: AlgoKind, slow: Option<(usize, f64)>) -> SimResult {
    let mut p = base_params(kind);
    p.exp.cluster.hetero.slow_worker = slow;
    sim::run(&p)
}

/// Time-to-target, falling back to final time when the target wasn't hit
/// (reported with a `>` marker by the tables).
pub fn ttt(res: &SimResult) -> (f64, bool) {
    match res.time_to_target {
        Some(t) => (t, true),
        None => (res.final_time, false),
    }
}

/// Format a time-to-target with the miss marker.
pub fn fmt_ttt(res: &SimResult) -> String {
    let (t, hit) = ttt(res);
    if hit {
        format!("{t:.1}")
    } else {
        format!(">{t:.1}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_params_valid() {
        for &k in AlgoKind::all() {
            base_params(k).exp.validate().unwrap();
        }
    }

    #[test]
    fn bench_model_converges_to_target() {
        // The whole harness depends on the target being reachable.
        let mut p = base_params(AlgoKind::AllReduce);
        p.exp.train.max_iters = 1200;
        let res = sim::run(&p);
        assert!(
            res.time_to_target.is_some(),
            "target {LOSS_TARGET} unreachable: last loss {:?}",
            res.trace.last().map(|t| t.loss)
        );
    }
}
