//! Ablation harness for the smart-GG design choices DESIGN.md calls out:
//! Group Buffer, Global Division, Inter-Intra scheduling, and the
//! slowdown filter are toggled one at a time to quantify what each
//! contributes (§5's incremental story). Run via `ripples ablation`.

use crate::config::AlgoKind;
use crate::gg::GgConfig;
use crate::metrics::Table;
use crate::sim::{ripples, SimResult};

use super::base_params;

/// One ablation variant: a named GG configuration.
pub struct Variant {
    pub name: &'static str,
    pub cfg_fn: fn(usize, usize, usize) -> GgConfig,
}

fn random(n: usize, wpn: usize, k: usize) -> GgConfig {
    GgConfig::random(n, wpn, k)
}

fn gb_only(n: usize, wpn: usize, k: usize) -> GgConfig {
    let mut c = GgConfig::random(n, wpn, k);
    c.use_group_buffer = true;
    c
}

fn gb_gd(n: usize, wpn: usize, k: usize) -> GgConfig {
    let mut c = GgConfig::random(n, wpn, k);
    c.use_group_buffer = true;
    c.use_global_division = true;
    c
}

fn full_smart(n: usize, wpn: usize, k: usize) -> GgConfig {
    GgConfig::smart(n, wpn, k, 8)
}

fn smart_no_filter(n: usize, wpn: usize, k: usize) -> GgConfig {
    let mut c = GgConfig::smart(n, wpn, k, 8);
    c.c_thres = None;
    c.s_thres = None; // both filter legs off: measured and counter
    c
}

pub const VARIANTS: &[Variant] = &[
    Variant { name: "random (baseline)", cfg_fn: random },
    Variant { name: "+ group buffer", cfg_fn: gb_only },
    Variant { name: "+ global division", cfg_fn: gb_gd },
    Variant { name: "+ inter-intra (full smart)", cfg_fn: full_smart },
    Variant { name: "smart w/o slowdown filter", cfg_fn: smart_no_filter },
];

/// Run a variant in the event engine with a custom GG config.
fn run_variant(v: &Variant, slow: Option<(usize, f64)>) -> SimResult {
    let mut p = base_params(AlgoKind::RipplesSmart);
    p.exp.cluster.hetero.slow_worker = slow;
    let cfg = (v.cfg_fn)(
        p.exp.cluster.n_workers(),
        p.exp.cluster.workers_per_node,
        p.exp.algo.group_size,
    );
    ripples::run_with_gg(&p, cfg)
}

/// The ablation table: each §5 mechanism toggled, homo + 5x straggler.
pub fn ablation_table() -> Table {
    let mut t = Table::new(&[
        "variant",
        "homo t2t(s)",
        "homo conflicts",
        "5x t2t(s)",
        "5x degradation",
    ]);
    for v in VARIANTS {
        let homo = run_variant(v, None);
        let slow = run_variant(v, Some((7, 6.0)));
        let homo_t = homo.time_to_target.unwrap_or(homo.final_time);
        let slow_t = slow.time_to_target.unwrap_or(slow.final_time);
        t.row(vec![
            v.name.into(),
            format!("{homo_t:.1}"),
            format!("{}", homo.conflicts),
            format!("{slow_t:.1}"),
            format!("{:.2}x", slow_t / homo_t),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variants_all_run_short() {
        for v in VARIANTS {
            let mut p = base_params(AlgoKind::RipplesSmart);
            p.exp.train.max_iters = 30;
            p.exp.train.loss_target = None;
            let cfg = (v.cfg_fn)(16, 4, 3);
            let res = ripples::run_with_gg(&p, cfg);
            assert_eq!(res.total_iters, 30 * 16, "{}", v.name);
        }
    }

    #[test]
    fn group_buffer_reduces_conflicts() {
        let mut p = base_params(AlgoKind::RipplesSmart);
        p.exp.train.max_iters = 120;
        p.exp.train.loss_target = None;
        let random = ripples::run_with_gg(&p, (VARIANTS[0].cfg_fn)(16, 4, 3));
        let gb = ripples::run_with_gg(&p, (VARIANTS[1].cfg_fn)(16, 4, 3));
        assert!(
            gb.conflicts < random.conflicts,
            "GB {} vs random {}",
            gb.conflicts,
            random.conflicts
        );
    }
}
