//! One harness per paper figure/table. Every function prints the measured
//! rows next to the paper's expected shape and writes CSV traces under
//! `results/` when `csv_dir` is set.

use std::path::Path;

use crate::cluster::calibration;
use crate::comm::CostModel;
use crate::config::{AlgoKind, ClusterConfig};
use crate::metrics::{self, Table};
use crate::sim::{self, SimResult};

use super::{base_params, fmt_ttt, run_algo, ttt};

/// Write the per-algorithm trace CSV if an output dir is configured.
fn dump_trace(csv_dir: Option<&Path>, tag: &str, res: &SimResult) {
    if let Some(dir) = csv_dir {
        let path = dir.join(format!("{tag}.csv"));
        if let Err(e) = metrics::write_trace_csv(res, &path) {
            eprintln!("warn: could not write {}: {e}", path.display());
        }
    }
}

/// Fig. 1 — All-Reduce vs AD-PSGD, homogeneous and heterogeneous (one
/// worker 5x slower). Paper shape: AR ~3x faster homo; AD-PSGD ~1.75x
/// faster hetero.
pub fn fig1(csv_dir: Option<&Path>) -> Table {
    let mut t = Table::new(&["setting", "algorithm", "time-to-loss(s)", "paper shape"]);
    // §7.4: heterogeneity = *adding* 5x the normal iteration time of
    // sleep, i.e. a 6x total compute multiplier on the slow worker.
    for (setting, slow) in [("homo", None), ("hetero-5x", Some((7usize, 6.0f64)))] {
        let ar = run_algo(AlgoKind::AllReduce, slow);
        let ad = run_algo(AlgoKind::AdPsgd, slow);
        dump_trace(csv_dir, &format!("fig1_{setting}_allreduce"), &ar);
        dump_trace(csv_dir, &format!("fig1_{setting}_adpsgd"), &ad);
        let shape = if setting == "homo" {
            "AR ~3.0x faster"
        } else {
            "AD-PSGD ~1.75x faster"
        };
        t.row(vec![setting.into(), "all-reduce".into(), fmt_ttt(&ar), shape.into()]);
        t.row(vec![setting.into(), "ad-psgd".into(), fmt_ttt(&ad), String::new()]);
    }
    t
}

/// Fig. 2(b) — computation vs synchronization share per algorithm/task.
/// Paper shape: AD-PSGD spends >90% of the (initiating worker's) time in
/// synchronization on both VGG-16 and ResNet-50.
pub fn fig2b(_csv_dir: Option<&Path>) -> Table {
    let mut t = Table::new(&["task", "algorithm", "compute %", "sync %", "paper shape"]);
    for (task, make) in [
        ("vgg16/cifar10", false),
        ("resnet50/imagenet", true),
    ] {
        for kind in [AlgoKind::AdPsgd, AlgoKind::AllReduce, AlgoKind::RipplesSmart] {
            let mut p = base_params(kind);
            if make {
                p.compute_base = calibration::RESNET50_COMPUTE;
                p.model_bytes = calibration::RESNET50_BYTES;
            }
            p.exp.train.loss_target = None;
            p.exp.train.max_iters = 120;
            let res = sim::run(&p);
            let sync = res.sync_fraction() * 100.0;
            let shape = if kind == AlgoKind::AdPsgd { ">90% sync" } else { "" };
            t.row(vec![
                task.into(),
                kind.name().into(),
                format!("{:.1}", 100.0 - sync),
                format!("{sync:.1}"),
                shape.into(),
            ]);
        }
    }
    t
}

/// Fig. 15 — micro-benchmark: compute cost vs batch size; all-reduce cost
/// vs worker count and placement (dense = 4/node, sparse = 1/node).
/// Paper shape: intra-node or sparse placements beat dense multi-node.
pub fn fig15(_csv_dir: Option<&Path>) -> Table {
    let mut t = Table::new(&["op", "setting", "time (ms)", "paper shape"]);
    for bs in [64usize, 128, 256] {
        t.row(vec![
            "compute".into(),
            format!("B.S. {bs}"),
            format!("{:.1}", calibration::vgg16_compute(bs) * 1e3),
            if bs == 256 { "per-sample cost shrinks with batch" } else { "" }.into(),
        ]);
    }
    let bytes = calibration::VGG16_BYTES;
    for w in [2usize, 4, 8, 16] {
        // dense placement: fill nodes with 4 workers each
        let cluster = ClusterConfig {
            n_nodes: w.div_ceil(4),
            workers_per_node: 4.min(w),
            ..ClusterConfig::default()
        };
        let cost = CostModel::from_cluster(&cluster);
        let group: Vec<usize> = (0..w).collect();
        t.row(vec![
            "all-reduce".into(),
            format!("W. {w} (dense)"),
            format!("{:.2}", cost.ring_allreduce(&group, bytes) * 1e3),
            if w == 16 { "multi-node dense is slowest" } else { "" }.into(),
        ]);
    }
    for w in [4usize, 8, 12] {
        // sparse placement: one worker per node
        let cluster = ClusterConfig {
            n_nodes: w,
            workers_per_node: 1,
            ..ClusterConfig::default()
        };
        let cost = CostModel::from_cluster(&cluster);
        let group: Vec<usize> = (0..w).collect();
        t.row(vec![
            "all-reduce".into(),
            format!("S.W. {w} (sparse)"),
            format!("{:.2}", cost.ring_allreduce(&group, bytes) * 1e3),
            if w == 4 { "sparse ~ single-node speeds" } else { "" }.into(),
        ]);
    }
    t
}

/// Fig. 16 — effect of synchronization frequency ("section length"):
/// throughput rises but iterations-to-converge rise too.
pub fn fig16(csv_dir: Option<&Path>) -> Table {
    let mut t = Table::new(&[
        "section len",
        "iters-to-target",
        "time-to-target(s)",
        "per-iter(s)",
        "paper shape",
    ]);
    for (i, section) in [1usize, 2, 4, 8, 16].into_iter().enumerate() {
        let mut p = base_params(AlgoKind::RipplesSmart);
        p.exp.algo.section_len = section;
        p.exp.train.max_iters = 5000;
        p.exp.train.eval_every = 2; // fine-grained: the effect is ~tens of iters
        let res = sim::run(&p);
        dump_trace(csv_dir, &format!("fig16_section{section}"), &res);
        let iters = res
            .avg_iters_to_target
            .map(|v| format!("{v:.0}"))
            .unwrap_or_else(|| format!(">{:.0}", res.total_iters as f64 / 16.0));
        t.row(vec![
            section.to_string(),
            iters,
            fmt_ttt(&res),
            format!("{:.4}", res.per_iter_time()),
            if i == 0 { "iters grow as sync gets rarer" } else { "" }.into(),
        ]);
    }
    t
}

/// Fig. 17 — homogeneous speedups over Parameter Server: per-iteration
/// and overall (time-to-target). Paper: AR 4.27x overall, AD-PSGD 1.42x,
/// Ripples static/smart ~5.0-5.3x, random ~3x, smart ~1.1x faster than AR.
pub fn fig17(csv_dir: Option<&Path>) -> Table {
    let mut t = Table::new(&[
        "algorithm",
        "per-iter speedup",
        "overall speedup",
        "time-to-target(s)",
        "paper overall",
    ]);
    let algos = [
        (AlgoKind::ParameterServer, "1.00"),
        (AlgoKind::AllReduce, "4.27"),
        (AlgoKind::AdPsgd, "1.42"),
        (AlgoKind::RipplesRandom, "3.03"),
        (AlgoKind::RipplesStatic, "5.01"),
        (AlgoKind::RipplesSmart, "5.26"),
    ];
    let ps = run_algo(AlgoKind::ParameterServer, None);
    let ps_iter = ps.per_iter_time();
    let (ps_time, _) = ttt(&ps);
    for (kind, paper) in algos {
        let res = if kind == AlgoKind::ParameterServer {
            ps.clone()
        } else {
            run_algo(kind, None)
        };
        dump_trace(csv_dir, &format!("fig17_{}", kind.name()), &res);
        let (time, _) = ttt(&res);
        t.row(vec![
            kind.name().into(),
            format!("{:.2}", ps_iter / res.per_iter_time()),
            format!("{:.2}", ps_time / time),
            fmt_ttt(&res),
            paper.into(),
        ]);
    }
    t
}

/// Fig. 18 — statistical efficiency: iterations to reach the loss target
/// per algorithm (the convergence curves go to CSV). Paper shape:
/// AD-PSGD needs the fewest iterations (most randomness), static the most
/// among Ripples variants; randomness ordering random < smart < static.
pub fn fig18(csv_dir: Option<&Path>) -> Table {
    let mut t = Table::new(&["algorithm", "iters-to-target", "vs PS", "paper shape"]);
    let ps = run_algo(AlgoKind::ParameterServer, None);
    let ps_iters = ps.avg_iters_to_target.unwrap_or(f64::INFINITY);
    for kind in [
        AlgoKind::ParameterServer,
        AlgoKind::AllReduce,
        AlgoKind::AdPsgd,
        AlgoKind::RipplesRandom,
        AlgoKind::RipplesSmart,
        AlgoKind::RipplesStatic,
    ] {
        let res = if kind == AlgoKind::ParameterServer {
            ps.clone()
        } else {
            run_algo(kind, None)
        };
        dump_trace(csv_dir, &format!("fig18_{}", kind.name()), &res);
        let iters = res.avg_iters_to_target;
        let rel = iters.map(|v| format!("{:.2}x", ps_iters / v)).unwrap_or("-".into());
        let shape = match kind {
            AlgoKind::AdPsgd => "fewest iterations (1.28x of PS)",
            AlgoKind::RipplesStatic => "most iterations among Ripples",
            _ => "",
        };
        t.row(vec![
            kind.name().into(),
            iters.map(|v| format!("{v:.0}")).unwrap_or("-".into()),
            rel,
            shape.into(),
        ]);
    }
    t
}

/// Fig. 19 — heterogeneity tolerance: overall speedup vs the *homogeneous
/// PS baseline* under a 2x and 5x one-worker slowdown. Paper shape: smart
/// GG degrades least; static still beats AR; AR degrades most.
pub fn fig19(csv_dir: Option<&Path>) -> Table {
    let mut t = Table::new(&[
        "slowdown",
        "algorithm",
        "overall speedup vs PS-homo",
        "degradation vs own homo",
        "paper (homo -> 2x -> 5x)",
    ]);
    let ps_homo = run_algo(AlgoKind::ParameterServer, None);
    let (ps_time, _) = ttt(&ps_homo);
    let algos = [
        (AlgoKind::AllReduce, "4.27 -> 1.66"),
        (AlgoKind::AdPsgd, "1.42 -> 1.37"),
        (AlgoKind::RipplesRandom, "3.03 -> 2.13"),
        (AlgoKind::RipplesStatic, "5.01 -> 2.47"),
        (AlgoKind::RipplesSmart, "5.26 -> 4.23"),
    ];
    // "2x / 5x slowdown" = that much *added* sleep (§7.4): total compute
    // multipliers of 3x and 6x on the slow worker.
    for (label, factor) in [("2x", 3.0f64), ("5x", 6.0)] {
        for (kind, paper) in algos {
            let homo = run_algo(kind, None);
            let res = run_algo(kind, Some((7, factor)));
            dump_trace(csv_dir, &format!("fig19_{label}_{}", kind.name()), &res);
            let (time, _) = ttt(&res);
            let (homo_time, _) = ttt(&homo);
            t.row(vec![
                label.into(),
                kind.name().into(),
                format!("{:.2}", ps_time / time),
                format!("{:.2}x slower", time / homo_time),
                if label == "2x" { paper.into() } else { String::new() },
            ]);
        }
    }
    t
}

/// Fig. 20 — fixed time budget on the large model (ResNet-50-calibrated):
/// iterations completed and final loss. Paper shape: AR completes fewer
/// iterations but converges best per iteration at large batch; AD-PSGD
/// far behind on throughput; Prague smart close second to AR.
pub fn fig20(csv_dir: Option<&Path>) -> Table {
    let budget = 1800.0; // virtual seconds, the scaled "10 hours"
    let mut t = Table::new(&[
        "algorithm",
        "iterations (avg/worker)",
        "final loss",
        "paper total iters",
    ]);
    let paper = [
        (AlgoKind::AllReduce, "55800"),
        (AlgoKind::AdPsgd, "32100"),
        (AlgoKind::RipplesStatic, "58200 (Prague static)"),
        (AlgoKind::RipplesSmart, "56800 (Prague smart)"),
    ];
    for (kind, paper_iters) in paper {
        let mut exp = crate::config::Experiment::default();
        exp.cluster.n_nodes = 8; // the paper's 32-worker setup
        exp.algo.kind = kind;
        exp.train.lr = 0.06;
        exp.train.eval_every = 10;
        exp.train.seed = 42;
        let mut p = sim::SimParams::resnet50_defaults(exp);
        p.spec = super::bench_spec();
        p.dataset_size = 4096;
        p.batch = 32;
        let res = sim::run_time_budget(&p, budget);
        dump_trace(csv_dir, &format!("fig20_{}", kind.name()), &res);
        let avg_iters = res.total_iters as f64 / res.per_worker_iters.len() as f64;
        let loss = res.trace.last().map(|tp| tp.loss).unwrap_or(f64::NAN);
        t.row(vec![
            kind.name().into(),
            format!("{avg_iters:.0}"),
            format!("{loss:.4}"),
            paper_iters.into(),
        ]);
    }
    t
}

/// Dynamic straggler — filter reaction time. Not a paper figure: the
/// paper's §5.3 filter assumes the scheduler knows who is slow; this
/// harness measures how the *online* speed table reacts when worker 7
/// turns 6x slow at its iteration 40 and recovers at its iteration 56
/// (EXPERIMENTS.md §Dynamic-straggler; the recovery point is early
/// enough that the slowed worker actually reaches it inside the
/// iteration budget). Expected shape: with the measured (EWMA) filter
/// the straggler stops being drafted shortly after onset AND is
/// re-admitted after recovery; the counter-only filter excludes it but
/// can never re-admit (the progress deficit is frozen); with no filter
/// it keeps being drafted throughout.
pub fn fig_dyn(csv_dir: Option<&Path>) -> Table {
    use crate::cluster::SlowdownEvent;
    use crate::gg::GgConfig;
    use crate::sim::ripples;

    let mut t = Table::new(&[
        "filter",
        "onset req",
        "last drafted req",
        "total reqs",
        "straggler drafts",
        "end rel speed",
        "readmitted",
    ]);
    let variants: [(&str, fn(GgConfig) -> GgConfig); 3] = [
        ("measured (EWMA)", |c| c),
        ("counter-only", |c| {
            let mut c = c;
            c.s_thres = None;
            c
        }),
        ("off", |c| {
            let mut c = c;
            c.s_thres = None;
            c.c_thres = None;
            c
        }),
    ];
    for (name, tweak) in variants {
        let mut p = base_params(AlgoKind::RipplesSmart);
        p.exp.train.loss_target = None;
        p.exp.train.max_iters = 220;
        p.exp.cluster.hetero.schedule = vec![
            SlowdownEvent { worker: 7, factor: 6.0, start_iter: 40 },
            SlowdownEvent { worker: 7, factor: 1.0, start_iter: 56 },
        ];
        let cfg = tweak(GgConfig::smart(
            p.exp.cluster.n_workers(),
            p.exp.cluster.workers_per_node,
            p.exp.algo.group_size,
            p.exp.algo.c_thres,
        ));
        let res = ripples::run_with_gg(&p, cfg);
        dump_trace(csv_dir, &format!("dyn_{}", name.replace([' ', '(', ')'], "")), &res);
        let rel = metrics::relative_speeds(&res.measured_speeds);
        let last = res.last_drafted_request[7];
        // drafted within the final 10% of requests = still/again drafted
        let readmitted = res.gg_requests.saturating_sub(last) < res.gg_requests / 10;
        t.row(vec![
            name.into(),
            res.onset_request.map_or("-".into(), |r| r.to_string()),
            last.to_string(),
            res.gg_requests.to_string(),
            res.drafts[7].to_string(),
            format!("{:.2}", rel[7]),
            if readmitted { "yes" } else { "no" }.into(),
        ]);
    }
    t
}

/// Overlap pipeline — hidden vs exposed sync cost, plus the staged
/// step-pipeline axis. Not a paper figure: the paper's worker loop is
/// stop-and-wait; this harness sweeps the pipelined P-Reduce
/// (`[overlap]`: K shards, bounded staleness S) and the staged loader
/// (`[pipeline]`: prefetch depth, per-batch load cost) and measures how
/// much of the sync and load cost the virtual-time model hides
/// (DESIGN.md §Perf, EXPERIMENTS.md §Overlap-sweep). Expected shape:
/// exposed-sync fraction drops by well over 30% at K=4 vs serial; with
/// a load segment at half the compute cost, staging cuts the exposed
/// load wait to the priming step and lifts throughput back toward the
/// load-free rate — in both cases at an equivalent loss trajectory.
pub fn fig_overlap(csv_dir: Option<&Path>) -> Table {
    use crate::collectives::OverlapConfig;
    use crate::step::PipelineConfig;
    let mut t = Table::new(&[
        "mode",
        "exposed sync %",
        "hidden share %",
        "load wait s",
        "iters/s",
        "final loss",
        "expected shape",
    ]);
    for (label, shards, staleness, prefetch, load_mult) in [
        ("serial", 1usize, 0u64, 0usize, 0.0f64),
        ("K=2 S=4", 2, 4, 0, 0.0),
        ("K=4 S=4", 4, 4, 0, 0.0),
        ("K=8 S=4", 8, 4, 0, 0.0),
        ("load lockstep", 1, 0, 0, 0.5),
        ("load staged P=4", 1, 0, 4, 0.5),
        ("load staged K=4 S=4", 4, 4, 4, 0.5),
    ] {
        let mut p = base_params(AlgoKind::RipplesSmart);
        p.exp.train.loss_target = None;
        p.exp.train.max_iters = 300;
        p.exp.overlap = OverlapConfig { shards, max_staleness: staleness };
        p.exp.pipeline =
            PipelineConfig { prefetch, load_secs: load_mult * p.compute_base };
        let res = sim::run(&p);
        dump_trace(csv_dir, &format!("overlap_{}", label.replace([' ', '='], "")), &res);
        let loss = res.trace.last().map(|tp| tp.loss).unwrap_or(f64::NAN);
        t.row(vec![
            label.into(),
            format!("{:.3}", res.sync_fraction() * 100.0),
            format!("{:.1}", res.hidden_sync_share() * 100.0),
            format!("{:.3}", res.load_wait_time),
            format!("{:.1}", res.total_iters as f64 / res.final_time),
            format!("{loss:.4}"),
            match label {
                "serial" => "K=4 exposes >=30% less sync at equal loss",
                "load lockstep" => "staged hides the load wait at equal loss",
                _ => "",
            }
            .into(),
        ]);
    }
    t
}

/// Wire-format sweep — codec × link bandwidth. Not a paper figure: the
/// paper ships raw `f32` chunks; this harness sweeps the compressed
/// wire codecs (`--wire fp32|fp16|q8`) against a uniform and a
/// bandwidth-constrained cluster (every link throttled 512x via
/// `cluster::BandwidthEvent` — the repo's first *bandwidth*
/// heterogeneity axis; EXPERIMENTS.md §Wire-sweep). Expected shape: on
/// the constrained link q8 moves ~4x fewer bytes and exposes >=2x less
/// sync time than fp32 at an equivalent final loss (the codec noise is
/// bounded per chunk range); on the uniform link the codecs barely
/// matter because sync is overhead-, not bandwidth-, dominated.
pub fn fig_wire(csv_dir: Option<&Path>) -> Table {
    use crate::cluster::BandwidthEvent;
    use crate::collectives::WireCodec;
    let mut t = Table::new(&[
        "link",
        "codec",
        "exposed sync s",
        "wire MB",
        "iters/s",
        "final loss",
        "expected shape",
    ]);
    for (link, throttle) in [("uniform", None), ("constrained-512x", Some(512.0))] {
        for codec in [WireCodec::Fp32, WireCodec::Fp16, WireCodec::Q8] {
            let mut p = base_params(AlgoKind::RipplesSmart);
            p.exp.train.loss_target = None;
            p.exp.train.max_iters = 160;
            p.exp.wire = codec;
            if let Some(factor) = throttle {
                p.exp.cluster.hetero.bandwidth = (0..p.exp.cluster.n_workers())
                    .map(|w| BandwidthEvent { worker: w, factor, start_iter: 0 })
                    .collect();
            }
            let res = sim::run(&p);
            dump_trace(csv_dir, &format!("wire_{link}_{}", codec.name()), &res);
            let loss = res.trace.last().map(|tp| tp.loss).unwrap_or(f64::NAN);
            t.row(vec![
                link.into(),
                codec.name().into(),
                format!("{:.3}", res.sync_time),
                format!("{:.1}", res.bytes_on_wire as f64 / 1e6),
                format!("{:.2}", res.total_iters as f64 / res.final_time),
                format!("{loss:.4}"),
                if link == "constrained-512x" && codec == WireCodec::Fp32 {
                    "q8 >=2x less exposed sync at equal loss"
                } else {
                    ""
                }
                .into(),
            ]);
        }
    }
    t
}

/// Failure sweep — crash tolerance. Not a paper figure: the paper's
/// control plane only handles graceful departure; this harness measures
/// what a *crash* costs under three policies at equal virtual time
/// (EXPERIMENTS.md §Crash-sweep). Expected shape: crash-no-repair
/// freezes the dead rank's lock partners (the AD-PSGD deadlock class)
/// and falls furthest behind; crash-with-repair loses only the dead
/// rank's own throughput; crash-with-rejoin recovers most of that too;
/// crash-free is the ceiling.
pub fn fig_failures(csv_dir: Option<&Path>) -> Table {
    use crate::cluster::CrashEvent;
    let mut t = Table::new(&[
        "scenario",
        "iters (total)",
        "min/max live iters",
        "aborted",
        "deaths",
        "rejoins",
        "frozen workers",
        "expected shape",
    ]);
    let mk = |crash: Option<CrashEvent>, repair: bool| {
        let mut p = base_params(AlgoKind::RipplesSmart);
        p.exp.train.loss_target = None;
        p.exp.train.max_iters = 160;
        p.exp.cluster.hetero.crashes = crash.into_iter().collect();
        p.exp.faults.repair = repair;
        p
    };
    let crash = CrashEvent { worker: 7, at_iter: 40, rejoin_after_secs: None };
    let rejoin = CrashEvent { worker: 7, at_iter: 40, rejoin_after_secs: Some(10.0) };
    let free = sim::run(&mk(None, true));
    let budget = free.final_time; // equal-virtual-time comparison
    let scenarios: [(&str, SimResult, &str); 4] = [
        ("crash-free", free, "the ceiling"),
        (
            "crash+repair",
            sim::run_until(&mk(Some(crash), true), Some(budget)),
            "loses ~1 worker's share",
        ),
        (
            "crash+rejoin",
            sim::run_until(&mk(Some(rejoin), true), Some(budget)),
            "recovers most of it",
        ),
        (
            "crash-no-repair",
            sim::run_until(&mk(Some(crash), false), Some(budget)),
            "lock partners freeze; worst",
        ),
    ];
    for (name, res, shape) in scenarios {
        dump_trace(csv_dir, &format!("failures_{}", name.replace('+', "_")), &res);
        let live: Vec<u64> = res
            .per_worker_iters
            .iter()
            .enumerate()
            .filter(|(w, _)| *w != 7)
            .map(|(_, &i)| i)
            .collect();
        let (min, max) = (
            live.iter().copied().min().unwrap_or(0),
            live.iter().copied().max().unwrap_or(0),
        );
        let frozen = live.iter().filter(|&&i| i < max / 2).count();
        t.row(vec![
            name.into(),
            res.total_iters.to_string(),
            format!("{min}/{max}"),
            res.groups_aborted.to_string(),
            res.deaths.to_string(),
            res.rejoins.to_string(),
            frozen.to_string(),
            shape.into(),
        ]);
    }
    t
}

/// Scale sweep (`fig scale`) — not a paper figure: the paper stops at 32
/// workers, where a single-lock Group Generator is invisible; this
/// harness measures what the coordinator costs at scale-out and what the
/// sharded state buys back (EXPERIMENTS.md §Scale-sweep). Two planes:
/// *sim* — p up to 1024 workers with a busy coordinator
/// (`gg_service` > 0), single-lock (`gg_shards = 1`) vs sharded
/// (`gg_shards = 16`) contention model, virtual seconds for a fixed
/// iteration budget; *real-tcp* — 64 localhost ranks hammer one
/// `GgServer` through the reactor, locked vs sharded backend, measured
/// RPC round trips per second. Expected shape: the shards=1 slowdown
/// grows with p and shards=16 recovers most of it; the sharded backend
/// serves at least as many RPC/s as the locked oracle.
pub fn fig_scale(csv_dir: Option<&Path>) -> Table {
    fig_scale_at(csv_dir, &[64, 256, 1024], 1e-3, 64, 40)
}

/// Parameterized core of [`fig_scale`]: tests call it with smaller p and
/// fewer real ranks so the sweep stays fast. `gg_service` is the modeled
/// coordinator CPU seconds per GG RPC.
pub fn fig_scale_at(
    _csv_dir: Option<&Path>,
    ps: &[usize],
    gg_service: f64,
    real_ranks: usize,
    real_iters: usize,
) -> Table {
    use crate::rpc::GgMode;
    let mut t = Table::new(&[
        "setting",
        "p",
        "coordinator",
        "virtual s",
        "rpc/s",
        "expected shape",
    ]);
    for &p in ps {
        for shards in [1usize, 16] {
            let mut sp = scale_sim_params(p);
            sp.gg_service = gg_service;
            sp.gg_shards = shards;
            let res = sim::run(&sp);
            t.row(vec![
                "sim".into(),
                p.to_string(),
                format!("shards={shards}"),
                format!("{:.3}", res.final_time),
                "-".into(),
                if shards == 16 { "sharding recovers the contention" } else { "" }.into(),
            ]);
        }
    }
    for (name, mode) in [("locked", GgMode::SingleLock), ("sharded", GgMode::Sharded)] {
        let (calls, secs) = real_gg_round_trips(real_ranks, real_iters, mode);
        t.row(vec![
            "real-tcp".into(),
            real_ranks.to_string(),
            name.into(),
            "-".into(),
            format!("{:.0}", calls as f64 / secs),
            if name == "sharded" { "sharded >= locked rpc/s" } else { "" }.into(),
        ]);
    }
    t
}

/// A p-worker cluster (4 workers/node, the testbed density) running a
/// small fixed iteration budget — the scale sweep measures coordinator
/// cost, not convergence.
fn scale_sim_params(p: usize) -> sim::SimParams {
    let mut sp = base_params(AlgoKind::RipplesRandom);
    sp.exp.cluster.n_nodes = p.div_ceil(4);
    sp.exp.cluster.workers_per_node = 4.min(p);
    sp.exp.train.loss_target = None;
    sp.exp.train.max_iters = 24;
    sp.exp.train.eval_every = 8;
    sp.dataset_size = 512;
    sp.batch = 32;
    sp
}

/// One real scale run: `ranks` localhost TCP clients into a fresh
/// [`GgServer`], each looping `iters` sync + transitive-complete rounds
/// (every armed group is returned to the request that armed it, and each
/// client drains its hand before parking in `wait_done`, so the chain
/// always drains — same argument as the reactor's concurrency test).
/// Returns (RPC round trips issued, wall seconds to serve them all).
fn real_gg_round_trips(ranks: usize, iters: usize, mode: crate::rpc::GgMode) -> (u64, f64) {
    use crate::gg::GgConfig;
    use crate::rpc::{GgClient, GgServer};
    use std::sync::{Arc, Barrier};

    let cfg = GgConfig::random(ranks, 4, 4.min(ranks).max(2));
    let server = GgServer::spawn_with_backend("127.0.0.1:0", cfg, 7, None, mode)
        .expect("spawn scale GG");
    let addr = server.addr;
    let barrier = Arc::new(Barrier::new(ranks + 1));
    let handles: Vec<_> = (0..ranks)
        .map(|w| {
            let b = Arc::clone(&barrier);
            std::thread::spawn(move || {
                let mut c = GgClient::connect(addr).expect("scale client");
                c.set_io_timeout(std::time::Duration::from_secs(60)).expect("timeout");
                b.wait();
                let mut calls = 0u64;
                for _ in 0..iters {
                    let (assigned, armed) = c.sync(w, 0.01).expect("sync");
                    calls += 1;
                    let mut todo: Vec<_> = armed.into_iter().map(|(g, _)| g).collect();
                    while let Some(gid) = todo.pop() {
                        for (ng, _) in c.complete(gid).expect("complete") {
                            todo.push(ng);
                        }
                        calls += 1;
                    }
                    if let Some((gid, _, _)) = assigned {
                        c.wait_done(gid).expect("wait_done");
                        calls += 1;
                    }
                }
                calls
            })
        })
        .collect();
    barrier.wait();
    let t0 = std::time::Instant::now();
    let mut calls = 0u64;
    for h in handles {
        calls += h.join().expect("scale rank");
    }
    let secs = t0.elapsed().as_secs_f64().max(1e-9);
    server.shutdown();
    (calls, secs)
}

/// Paper table (`fig paper`) — the headline comparison the satellite
/// tables orbit: the four algorithms raced to the *same* target loss,
/// homogeneous and under both heterogeneity axes (one 5x-slow worker;
/// one worker's links throttled 16x). Speedups are relative to the
/// homogeneous PS run, the paper's reporting convention (Fig. 17/19).
pub fn fig_paper(csv_dir: Option<&Path>) -> Table {
    fig_paper_at(csv_dir, super::LOSS_TARGET, 2500)
}

/// Parameterized core of [`fig_paper`]: tests call it with a laxer
/// target and a smaller iteration budget so the 12-run sweep stays fast.
pub fn fig_paper_at(csv_dir: Option<&Path>, target: f64, max_iters: usize) -> Table {
    use crate::cluster::BandwidthEvent;
    let mut t = Table::new(&[
        "setting",
        "algorithm",
        "time-to-loss(s)",
        "speedup vs ps-homo",
        "paper shape",
    ]);
    let algos = [
        AlgoKind::ParameterServer,
        AlgoKind::AllReduce,
        AlgoKind::AdPsgd,
        AlgoKind::RipplesSmart,
    ];
    let run_one = |kind: AlgoKind,
                   slow: Option<(usize, f64)>,
                   bw: Vec<BandwidthEvent>|
     -> SimResult {
        let mut p = base_params(kind);
        p.exp.train.loss_target = Some(target);
        p.exp.train.max_iters = max_iters;
        p.exp.cluster.hetero.slow_worker = slow;
        p.exp.cluster.hetero.bandwidth = bw;
        sim::run(&p)
    };
    let ps_homo = run_one(AlgoKind::ParameterServer, None, Vec::new());
    let (ps_time, _) = ttt(&ps_homo);
    // §7.4 again: "5x slowdown" = 5x *added* sleep = 6x total compute.
    let throttle = vec![BandwidthEvent { worker: 7, factor: 16.0, start_iter: 0 }];
    for (setting, slow, bw) in [
        ("homo", None, Vec::new()),
        ("hetero-5x", Some((7usize, 6.0f64)), Vec::new()),
        ("hetero-bw16x", None, throttle),
    ] {
        for kind in algos {
            let res = if setting == "homo" && kind == AlgoKind::ParameterServer {
                ps_homo.clone()
            } else {
                run_one(kind, slow, bw.clone())
            };
            dump_trace(csv_dir, &format!("paper_{setting}_{}", kind.name()), &res);
            let (time, _) = ttt(&res);
            let shape = match (setting, kind) {
                ("homo", AlgoKind::ParameterServer) => "baseline (1.00x)",
                ("homo", AlgoKind::RipplesSmart) => "fastest homo (~5.3x)",
                ("hetero-5x", AlgoKind::AllReduce) => "barrier waits for straggler",
                ("hetero-5x", AlgoKind::RipplesSmart) => "degrades least (~4.2x)",
                ("hetero-bw16x", AlgoKind::AdPsgd) => "pays only when 7 is picked",
                _ => "",
            };
            t.row(vec![
                setting.into(),
                kind.name().into(),
                fmt_ttt(&res),
                format!("{:.2}", ps_time / time),
                shape.into(),
            ]);
        }
    }
    t
}

/// Topo sweep (`fig topo`) — hierarchical P-Reduce vs flat rings when
/// rank placement matters: a 2-rack cluster (4 ranks/machine) whose
/// machines share one constrained 1.5 GB/s uplink each, moving VGG-size
/// buffers (EXPERIMENTS.md §Topo-sweep, DESIGN.md §Perf "Hierarchical
/// P-Reduce"). Two planes: *model* — closed-form collective cost of one
/// full-cluster sync at p up to 512 for the three placement-aware
/// shapes (placement-blind flat ring, bandwidth-ordered flat ring,
/// two-level hier); *sim* — the p=8 anchor run end-to-end (all-reduce
/// barrier engine, real SGD math) so the equal-loss claim is visible:
/// every shape records the bit-identical final loss and only the clock
/// moves. Expected shape: hier beats blind >= 2x at every p (the
/// fig-topo acceptance); the ordered flat ring lands in between at the
/// anchor, and latency accumulation (2(p-1) steps vs 2(L-1)) hands hier
/// the win again at large p.
pub fn fig_topo(csv_dir: Option<&Path>) -> Table {
    fig_topo_at(csv_dir, &[8, 32, 128, 512], 40)
}

/// Parameterized core of [`fig_topo`]: tests call it with fewer p points
/// and a smaller sim iteration budget so the sweep stays fast.
pub fn fig_topo_at(csv_dir: Option<&Path>, ps: &[usize], sim_iters: usize) -> Table {
    use crate::config::SyncShape;
    let mut t = Table::new(&[
        "setting",
        "p",
        "shape",
        "sync s",
        "final loss",
        "expected shape",
    ]);
    // model plane: one full-cluster collective on the 2-rack fabric
    // (numbers match `comm::tests::rack2`)
    let cost = CostModel {
        workers_per_node: 4,
        intra_bw: 12e9,
        inter_bw: 1.5e9,
        intra_lat: 5e-6,
        inter_lat: 25e-6,
        rpc_rtt: 1e-4,
    };
    // 4x the calibrated VGG-16 wire size: the uncompressed fp32 gradient
    // buffer, the worst case the placement plan has to move (and the
    // fixture `comm::tests::rack2` prices)
    let bytes = 4 * calibration::VGG16_BYTES;
    for &p in ps {
        let group: Vec<usize> = (0..p).collect();
        for (name, secs, note) in [
            (
                "flat-blind",
                cost.ring_allreduce_uplink(&group, bytes, &[], 4, true),
                "every edge crosses; uplinks serialize",
            ),
            (
                "flat-ordered",
                cost.ring_allreduce_uplink(&group, bytes, &[], 4, false),
                "one crossing per uplink per step",
            ),
            (
                "hier",
                cost.hierarchical(&group, bytes, &[], 4),
                ">= 2x over blind",
            ),
        ] {
            t.row(vec![
                "model".into(),
                p.to_string(),
                name.into(),
                format!("{secs:.6}"),
                "-".into(),
                note.into(),
            ]);
        }
    }
    // sim plane: the p=8 anchor, all four shapes (flat = legacy default)
    let anchor = |shape: SyncShape| -> SimResult {
        let mut sp = base_params(AlgoKind::AllReduce);
        sp.exp.train.loss_target = None;
        sp.exp.train.max_iters = sim_iters;
        sp.exp.train.eval_every = 10;
        sp.exp.cluster.n_nodes = 2;
        sp.exp.cluster.workers_per_node = 4;
        sp.exp.cluster.link.inter_bw = 1.5e9;
        sp.exp.topology.shape = shape;
        sp.model_bytes = bytes;
        sim::run(&sp)
    };
    for (shape, name, note) in [
        (SyncShape::Flat, "flat", "legacy default == ordered"),
        (SyncShape::FlatBlind, "flat-blind", ""),
        (SyncShape::FlatOrdered, "flat-ordered", ""),
        (SyncShape::Hier, "hier", "same loss bits, least sync"),
    ] {
        let res = anchor(shape);
        dump_trace(csv_dir, &format!("topo_{name}"), &res);
        t.row(vec![
            "sim".into(),
            "8".into(),
            name.into(),
            format!("{:.3}", res.sync_time),
            format!("{:.6}", res.trace.last().map(|tp| tp.loss).unwrap_or(f64::NAN)),
            note.into(),
        ]);
    }
    t
}

/// Run one figure by id; `all` runs everything. Returns
/// `(id, title, table)` so callers can derive stable artifact names
/// (`BENCH_<id>.json`, CSV files).
#[allow(clippy::type_complexity)]
pub fn run_figure(
    id: &str,
    csv_dir: Option<&Path>,
) -> Result<Vec<(String, String, Table)>, String> {
    let all: Vec<(&str, &str, fn(Option<&Path>) -> Table)> = vec![
        ("1", "Figure 1", fig1),
        ("2b", "Figure 2b", fig2b),
        ("15", "Figure 15", fig15),
        ("16", "Figure 16", fig16),
        ("17", "Figure 17", fig17),
        ("18", "Figure 18", fig18),
        ("19", "Figure 19", fig19),
        ("20", "Figure 20", fig20),
        ("dyn", "Dynamic straggler (filter reaction)", fig_dyn),
        ("overlap", "Overlap pipeline (hidden vs exposed sync)", fig_overlap),
        ("wire", "Wire formats (codec x bandwidth)", fig_wire),
        ("failures", "Failure sweep (crash tolerance)", fig_failures),
        ("scale", "Scale sweep (coordinator contention x sharding)", fig_scale),
        ("topo", "Topo sweep (hierarchical vs flat placement)", fig_topo),
        ("paper", "Paper table (algorithms x heterogeneity)", fig_paper),
    ];
    let selected: Vec<_> = if id == "all" {
        all
    } else {
        all.into_iter().filter(|(n, ..)| *n == id).collect()
    };
    if selected.is_empty() {
        return Err(format!(
            "unknown figure '{id}' (try 1, 2b, 15, 16, 17, 18, 19, 20, dyn, overlap, \
             wire, failures, scale, topo, paper, all)"
        ));
    }
    Ok(selected
        .into_iter()
        .map(|(n, title, f)| (n.to_string(), title.to_string(), f(csv_dir)))
        .collect())
}

/// Machine-readable form of one figure run, written by
/// `ripples fig --json DIR` as `BENCH_<id>.json` (the perf-trajectory
/// artifact the `bench-json` Makefile target accumulates).
pub fn to_json_entry(id: &str, title: &str, table: &Table) -> String {
    format!(
        "{{\"figure\": \"{}\", \"title\": \"{}\", \"table\": {}}}",
        metrics::json_escape(id),
        metrics::json_escape(title),
        table.to_json()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig15_rows_and_placement_shape() {
        let t = fig15(None);
        let csv = t.to_csv();
        assert!(csv.contains("B.S. 128"));
        assert!(csv.contains("W. 16 (dense)"));
        assert!(csv.contains("S.W. 12 (sparse)"));
        // parse the dense-16 and sparse-12 all-reduce times: paper's
        // observation is dense multi-node is slower than sparse
        let get = |needle: &str| -> f64 {
            csv.lines()
                .find(|l| l.contains(needle))
                .and_then(|l| l.split(',').nth(2))
                .and_then(|v| v.parse().ok())
                .unwrap()
        };
        assert!(get("W. 16 (dense)") > get("S.W. 12 (sparse)"));
        assert!(get("W. 2 (dense)") < get("W. 16 (dense)"));
    }

    #[test]
    fn unknown_figure_rejected() {
        assert!(run_figure("99", None).is_err());
        let ok = run_figure("2b", None).unwrap();
        assert_eq!(ok[0].0, "2b");
        assert_eq!(ok[0].1, "Figure 2b");
    }

    #[test]
    fn dyn_scenario_filter_shapes() {
        let t = fig_dyn(None);
        let csv = t.to_csv();
        let row = |name: &str| {
            csv.lines()
                .find(|l| l.starts_with(name))
                .unwrap_or_else(|| panic!("missing row {name}:\n{csv}"))
                .trim()
                .to_string()
        };
        // the measured filter re-admits the recovered straggler; the
        // counter-only filter cannot (frozen deficit); no filter keeps
        // drafting throughout
        assert!(row("measured (EWMA)").ends_with("yes"), "{csv}");
        assert!(row("counter-only").ends_with("no"), "{csv}");
        assert!(row("off").ends_with("yes"), "{csv}");
    }

    #[test]
    fn overlap_scenario_hides_sync_at_equal_loss() {
        let t = fig_overlap(None);
        let csv = t.to_csv();
        let col = |name: &str, idx: usize| -> f64 {
            csv.lines()
                .find(|l| l.starts_with(name))
                .unwrap_or_else(|| panic!("missing row {name}:\n{csv}"))
                .split(',')
                .nth(idx)
                .unwrap()
                .parse()
                .unwrap()
        };
        let serial_exposed = col("serial", 1);
        let k4_exposed = col("K=4 S=4", 1);
        // the acceptance bar: >= 30% less exposed sync at K=4 vs serial
        assert!(
            k4_exposed <= serial_exposed * 0.7,
            "K=4 exposed {k4_exposed}% vs serial {serial_exposed}%:\n{csv}"
        );
        // pipelining deeper must not expose meaningfully more (small
        // absolute slack: the runs' schedules diverge slightly)
        assert!(col("K=8 S=4", 1) <= col("K=2 S=4", 1) + 0.5, "{csv}");
        // hidden share only exists with overlap on
        assert_eq!(col("serial", 2), 0.0, "{csv}");
        assert!(col("K=4 S=4", 2) > 0.0, "{csv}");
        // throughput must not regress
        assert!(col("K=4 S=4", 4) >= col("serial", 4), "{csv}");
        // equal loss trajectory: both converge to comparable losses
        let ls = col("serial", 4 + 1);
        let l4 = col("K=4 S=4", 4 + 1);
        assert!(
            (ls - l4).abs() < 0.5 * ls.max(l4) + 0.02,
            "loss diverged: serial {ls} vs K=4 {l4}:\n{csv}"
        );
        // ---- staged step-pipeline axis (DESIGN.md §Perf) ----
        // zero-load rows expose no load wait at all
        assert_eq!(col("serial", 3), 0.0, "{csv}");
        // lockstep pays the load segment every step; staging the loader
        // strictly cuts the exposed load wait and restores throughput
        let lock_wait = col("load lockstep", 3);
        let staged_wait = col("load staged P=4", 3);
        assert!(lock_wait > 0.0, "{csv}");
        assert!(
            staged_wait < 0.5 * lock_wait,
            "staged load wait {staged_wait}s vs lockstep {lock_wait}s:\n{csv}"
        );
        assert!(
            col("load staged P=4", 4) > col("load lockstep", 4),
            "staging did not lift throughput:\n{csv}"
        );
        // staging composes with the sharded overlap at equal loss
        let ll = col("load lockstep", 5);
        let lsg = col("load staged K=4 S=4", 5);
        assert!(
            (ll - lsg).abs() < 0.5 * ll.max(lsg) + 0.02,
            "loss diverged across staged axis: {ll} vs {lsg}:\n{csv}"
        );
    }

    #[test]
    fn wire_scenario_q8_halves_constrained_sync_at_equal_loss() {
        let t = fig_wire(None);
        let csv = t.to_csv();
        let col = |link: &str, codec: &str, idx: usize| -> f64 {
            csv.lines()
                .find(|l| l.starts_with(&format!("{link},{codec},")))
                .unwrap_or_else(|| panic!("missing row {link}/{codec}:\n{csv}"))
                .split(',')
                .nth(idx)
                .unwrap()
                .parse()
                .unwrap()
        };
        // the acceptance bar: >=2x exposed-sync reduction for q8 vs fp32
        // on the bandwidth-constrained link
        let fp32_sync = col("constrained-512x", "fp32", 2);
        let q8_sync = col("constrained-512x", "q8", 2);
        assert!(
            q8_sync <= 0.5 * fp32_sync,
            "q8 sync {q8_sync}s vs fp32 {fp32_sync}s:\n{csv}"
        );
        // fp16 sits in between
        let fp16_sync = col("constrained-512x", "fp16", 2);
        assert!(fp16_sync < fp32_sync, "{csv}");
        // bytes shrink by the codec's ratio everywhere
        assert!(col("uniform", "fp16", 3) < 0.6 * col("uniform", "fp32", 3), "{csv}");
        assert!(col("uniform", "q8", 3) < 0.3 * col("uniform", "fp32", 3), "{csv}");
        // equal-loss tolerance: the q8 run trains comparably to fp32
        let lf = col("constrained-512x", "fp32", 5);
        let lq = col("constrained-512x", "q8", 5);
        assert!(
            (lf - lq).abs() < 0.5 * lf.max(lq) + 0.05,
            "loss diverged: fp32 {lf} vs q8 {lq}:\n{csv}"
        );
        // on the uniform link the codec barely matters (overhead-bound;
        // generous slack — different durations re-phase the schedule)
        let uf = col("uniform", "fp32", 2);
        let uq = col("uniform", "q8", 2);
        assert!(uq <= uf * 1.25 + 0.05, "uniform q8 {uq}s vs fp32 {uf}s:\n{csv}");
    }

    #[test]
    fn failures_scenario_shapes() {
        let t = fig_failures(None);
        let csv = t.to_csv();
        let col = |name: &str, idx: usize| -> String {
            csv.lines()
                .find(|l| l.starts_with(name))
                .unwrap_or_else(|| panic!("missing row {name}:\n{csv}"))
                .split(',')
                .nth(idx)
                .unwrap()
                .to_string()
        };
        let iters = |name: &str| -> u64 { col(name, 1).parse().unwrap() };
        // ordering at equal virtual time: free >= rejoin >= repair > none
        assert!(iters("crash-free") >= iters("crash+rejoin"), "{csv}");
        assert!(iters("crash+rejoin") >= iters("crash+repair"), "{csv}");
        assert!(
            iters("crash-no-repair") < iters("crash+repair"),
            "repair must beat the deadlock class:\n{csv}"
        );
        // the crash actually fired and was repaired
        assert_eq!(col("crash+repair", 4), "1", "{csv}");
        assert_eq!(col("crash+rejoin", 5), "1", "{csv}");
        assert_eq!(col("crash-free", 4), "0", "{csv}");
        // only the unrepaired run freezes survivors
        assert_eq!(col("crash+repair", 6), "0", "{csv}");
        assert!(col("crash-no-repair", 6).parse::<u64>().unwrap() >= 1, "{csv}");
    }

    #[test]
    fn paper_table_shape() {
        // Laxer target + smaller budget than the committed BENCH_paper
        // run, same harness: the *shape* claims must already hold.
        let t = fig_paper_at(None, 0.32, 600);
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 13, "header + 3 settings x 4 algos:\n{csv}");
        let cell = |setting: &str, algo: &str, idx: usize| -> String {
            csv.lines()
                .find(|l| l.starts_with(&format!("{setting},{algo},")))
                .unwrap_or_else(|| panic!("missing row {setting}/{algo}:\n{csv}"))
                .split(',')
                .nth(idx)
                .unwrap()
                .to_string()
        };
        // time-to-loss, tolerating the `>` target-miss marker
        let ttl = |setting: &str, algo: &str| -> f64 {
            cell(setting, algo, 2).trim_start_matches('>').parse().unwrap()
        };
        // speedups are normalized to the homogeneous PS run
        assert_eq!(cell("homo", "parameter-server", 3), "1.00", "{csv}");
        // homogeneous: Ripples beats the PS baseline outright (Fig. 17)
        assert!(
            ttl("homo", "ripples-smart") < ttl("homo", "parameter-server"),
            "{csv}"
        );
        // the headline claim (Fig. 19): under a straggler, Ripples
        // reaches the target before both baselines
        assert!(
            ttl("hetero-5x", "ripples-smart") < ttl("hetero-5x", "ad-psgd"),
            "{csv}"
        );
        assert!(
            ttl("hetero-5x", "ripples-smart") < ttl("hetero-5x", "parameter-server"),
            "{csv}"
        );
        // a 16x link throttle can only slow the barrier algorithms down
        assert!(ttl("hetero-bw16x", "all-reduce") >= ttl("homo", "all-reduce"), "{csv}");
        assert!(
            ttl("hetero-bw16x", "parameter-server") >= ttl("homo", "parameter-server"),
            "{csv}"
        );
    }

    #[test]
    fn scale_scenario_shapes() {
        // Smaller p, fewer real ranks, and a cranked-up service cost
        // (10 ms/RPC) than the committed BENCH_scale run so the
        // contention signal dominates schedule noise and the sweep stays
        // fast; the same harness, the same shape claims.
        let t = fig_scale_at(None, &[8, 16], 1e-2, 8, 6);
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 7, "header + 2p x 2 shards + 2 real:\n{csv}");
        let cell = |setting: &str, p: usize, coord: &str, idx: usize| -> f64 {
            csv.lines()
                .find(|l| l.starts_with(&format!("{setting},{p},{coord},")))
                .unwrap_or_else(|| panic!("missing row {setting}/{p}/{coord}:\n{csv}"))
                .split(',')
                .nth(idx)
                .unwrap()
                .parse()
                .unwrap()
        };
        for &p in &[8usize, 16] {
            let locked = cell("sim", p, "shards=1", 3);
            let sharded = cell("sim", p, "shards=16", 3);
            assert!(locked > 0.0 && sharded > 0.0, "{csv}");
            assert!(
                sharded < locked,
                "p={p}: sharding must recover contention ({sharded} vs {locked}):\n{csv}"
            );
        }
        // real plane: both backends served every RPC (throughput ratios
        // are the bench's claim, not this 1-core test's)
        assert!(cell("real-tcp", 8, "locked", 4) > 0.0, "{csv}");
        assert!(cell("real-tcp", 8, "sharded", 4) > 0.0, "{csv}");
    }

    #[test]
    fn topo_scenario_shapes() {
        // Fewer model p points and a 6-iteration sim anchor than the
        // committed BENCH_topo run; the same harness, the same shape
        // claims — the acceptance's ">= 2x over blind" is asserted live
        // on both planes.
        let t = fig_topo_at(None, &[8, 32], 6);
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 11, "header + 2p x 3 model + 4 sim:\n{csv}");
        let cell = |setting: &str, p: usize, shape: &str, idx: usize| -> f64 {
            csv.lines()
                .find(|l| l.starts_with(&format!("{setting},{p},{shape},")))
                .unwrap_or_else(|| panic!("missing row {setting}/{p}/{shape}:\n{csv}"))
                .split(',')
                .nth(idx)
                .unwrap()
                .parse()
                .unwrap()
        };
        for &p in &[8usize, 32] {
            let blind = cell("model", p, "flat-blind", 3);
            let ordered = cell("model", p, "flat-ordered", 3);
            let hier = cell("model", p, "hier", 3);
            assert!(blind > 0.0 && ordered > 0.0 && hier > 0.0, "{csv}");
            assert!(
                blind >= 2.0 * hier,
                "p={p}: two-level must halve blind-flat sync ({blind} vs {hier}):\n{csv}"
            );
            assert!(blind > ordered, "p={p}:\n{csv}");
        }
        // the p=8 anchor: hier also beats the bandwidth-ordered ring
        assert!(cell("model", 8, "hier", 3) < cell("model", 8, "flat-ordered", 3), "{csv}");
        // sim plane: equal loss across all four shapes, >= 2x sync win
        let loss = |shape: &str| -> String {
            csv.lines()
                .find(|l| l.starts_with(&format!("sim,8,{shape},")))
                .unwrap_or_else(|| panic!("missing sim row {shape}:\n{csv}"))
                .split(',')
                .nth(4)
                .unwrap()
                .to_string()
        };
        let flat_loss = loss("flat");
        for shape in ["flat-blind", "flat-ordered", "hier"] {
            assert_eq!(loss(shape), flat_loss, "{shape}: loss moved:\n{csv}");
        }
        let s_blind = cell("sim", 8, "flat-blind", 3);
        let s_ordered = cell("sim", 8, "flat-ordered", 3);
        let s_hier = cell("sim", 8, "hier", 3);
        assert!(s_blind >= 2.0 * s_hier, "sim: {s_blind} vs {s_hier}:\n{csv}");
        assert!(s_blind > s_ordered && s_ordered > s_hier, "{csv}");
    }

    #[test]
    fn committed_topo_artifact_is_well_formed() {
        // The checked-in `results/BENCH_topo.json` (refreshed by
        // `make fig` / `ripples fig topo --json`) must stay parseable and
        // keep the acceptance shape: hier >= 2x over the placement-blind
        // flat ring at every model p and on the sim anchor, with the
        // sim's final loss bit-identical across shapes.
        let path =
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("results/BENCH_topo.json");
        let json = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("committed artifact {} unreadable: {e}", path.display()));
        let parsed = crate::util::json::parse(&json).unwrap();
        assert_eq!(parsed.get("figure").unwrap().as_str(), Some("topo"));
        let table = parsed.get("table").unwrap();
        let header: Vec<_> = table
            .get("header")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|c| c.as_str().unwrap().to_string())
            .collect();
        assert_eq!(header, ["setting", "p", "shape", "sync s", "final loss", "expected shape"]);
        let rows: Vec<Vec<String>> = table
            .get("rows")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|r| {
                r.as_arr()
                    .unwrap()
                    .iter()
                    .map(|c| c.as_str().unwrap().to_string())
                    .collect()
            })
            .collect();
        assert_eq!(rows.len(), 16, "4 model p x 3 shapes + 4 sim rows");
        let cell = |setting: &str, p: &str, shape: &str, idx: usize| -> f64 {
            rows.iter()
                .find(|r| r[0] == setting && r[1] == p && r[2] == shape)
                .unwrap_or_else(|| panic!("missing row {setting}/{p}/{shape}"))[idx]
                .parse()
                .unwrap()
        };
        for p in ["8", "32", "128", "512"] {
            let blind = cell("model", p, "flat-blind", 3);
            let hier = cell("model", p, "hier", 3);
            assert!(blind > 0.0 && hier > 0.0);
            assert!(blind >= 2.0 * hier, "p={p}: {blind} vs {hier}");
            assert!(blind > cell("model", p, "flat-ordered", 3), "p={p}");
        }
        // hier beats even the ordered flat ring at the anchor and again
        // at large p where per-step latency accumulates over 2(p-1) steps
        assert!(cell("model", "8", "hier", 3) < cell("model", "8", "flat-ordered", 3));
        assert!(cell("model", "512", "hier", 3) < cell("model", "512", "flat-ordered", 3));
        // sim anchor: equal loss, >= 2x sync win, ordered in between
        let sim_loss = |shape: &str| -> String {
            rows.iter()
                .find(|r| r[0] == "sim" && r[2] == shape)
                .unwrap_or_else(|| panic!("missing sim row {shape}"))[4]
                .clone()
        };
        let flat_loss = sim_loss("flat");
        for shape in ["flat-blind", "flat-ordered", "hier"] {
            assert_eq!(sim_loss(shape), flat_loss, "{shape}: loss moved");
        }
        let s_blind = cell("sim", "8", "flat-blind", 3);
        let s_ordered = cell("sim", "8", "flat-ordered", 3);
        let s_hier = cell("sim", "8", "hier", 3);
        assert!(s_blind >= 2.0 * s_hier, "{s_blind} vs {s_hier}");
        assert!(s_blind > s_ordered && s_ordered > s_hier);
    }

    #[test]
    fn committed_scale_artifact_is_well_formed() {
        // The checked-in `results/BENCH_scale.json` (refreshed by
        // `make fig` / `ripples fig scale --json`) must stay parseable
        // and keep the shape claims: sharding recovers the simulated
        // contention at every p, and the real sharded backend out-serves
        // the locked oracle.
        let path =
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("results/BENCH_scale.json");
        let json = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("committed artifact {} unreadable: {e}", path.display()));
        let parsed = crate::util::json::parse(&json).unwrap();
        assert_eq!(parsed.get("figure").unwrap().as_str(), Some("scale"));
        let table = parsed.get("table").unwrap();
        let header: Vec<_> = table
            .get("header")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|c| c.as_str().unwrap().to_string())
            .collect();
        assert_eq!(
            header,
            ["setting", "p", "coordinator", "virtual s", "rpc/s", "expected shape"]
        );
        let rows: Vec<Vec<String>> = table
            .get("rows")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|r| {
                r.as_arr()
                    .unwrap()
                    .iter()
                    .map(|c| c.as_str().unwrap().to_string())
                    .collect()
            })
            .collect();
        assert_eq!(rows.len(), 8, "3 sim p x 2 shards + 2 real rows");
        let cell = |setting: &str, p: &str, coord: &str, idx: usize| -> f64 {
            rows.iter()
                .find(|r| r[0] == setting && r[1] == p && r[2] == coord)
                .unwrap_or_else(|| panic!("missing row {setting}/{p}/{coord}"))[idx]
                .parse()
                .unwrap()
        };
        for p in ["64", "256", "1024"] {
            let locked = cell("sim", p, "shards=1", 3);
            let sharded = cell("sim", p, "shards=16", 3);
            assert!(locked > 0.0 && sharded > 0.0);
            assert!(sharded < locked, "p={p}: {sharded} vs {locked}");
        }
        // contention share under shards=1 grows with p...
        assert!(
            cell("sim", "1024", "shards=1", 3) / cell("sim", "1024", "shards=16", 3)
                > cell("sim", "64", "shards=1", 3) / cell("sim", "64", "shards=16", 3)
        );
        // ...and the real sharded backend out-serves the locked oracle
        // at 64 ranks (the bench asserts nothing; the artifact records
        // the measured ratio)
        let locked_rps = cell("real-tcp", "64", "locked", 4);
        let sharded_rps = cell("real-tcp", "64", "sharded", 4);
        assert!(locked_rps > 0.0);
        assert!(sharded_rps > locked_rps, "{sharded_rps} vs {locked_rps}");
    }

    #[test]
    fn overlap_artifact_is_well_formed_when_present() {
        // `results/BENCH_overlap.json` is produced by `make bench-json`
        // (`fig all --json results`); unlike BENCH_paper/BENCH_scale it
        // is not committed yet, so absence is a skip, not a failure —
        // but once generated it must keep the staged-axis shape.
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("results/BENCH_overlap.json");
        let Ok(json) = std::fs::read_to_string(&path) else {
            eprintln!("SKIP: {} not generated (run `make bench-json`)", path.display());
            return;
        };
        let parsed = crate::util::json::parse(&json).unwrap();
        assert_eq!(parsed.get("figure").unwrap().as_str(), Some("overlap"));
        let table = parsed.get("table").unwrap();
        let header: Vec<_> = table
            .get("header")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|c| c.as_str().unwrap().to_string())
            .collect();
        assert_eq!(
            header,
            [
                "mode",
                "exposed sync %",
                "hidden share %",
                "load wait s",
                "iters/s",
                "final loss",
                "expected shape"
            ]
        );
        let rows: Vec<Vec<String>> = table
            .get("rows")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|r| {
                r.as_arr()
                    .unwrap()
                    .iter()
                    .map(|c| c.as_str().unwrap().to_string())
                    .collect()
            })
            .collect();
        assert_eq!(rows.len(), 7, "4 overlap rows + 3 staged-axis rows");
        let cell = |mode: &str, idx: usize| -> f64 {
            rows.iter()
                .find(|r| r[0] == mode)
                .unwrap_or_else(|| panic!("missing row {mode}"))[idx]
                .parse()
                .unwrap()
        };
        // zero-load rows expose no load wait; the staged run hides most
        // of what lockstep exposes and wins back throughput
        assert_eq!(cell("serial", 3), 0.0);
        assert!(cell("load lockstep", 3) > 0.0);
        assert!(cell("load staged P=4", 3) < 0.5 * cell("load lockstep", 3));
        assert!(cell("load staged P=4", 4) > cell("load lockstep", 4));
    }

    #[test]
    fn json_entry_wraps_table() {
        let mut t = Table::new(&["a"]);
        t.row(vec!["1".into()]);
        let j = to_json_entry("17", "Figure 17", &t);
        let parsed = crate::util::json::parse(&j).unwrap();
        assert_eq!(parsed.get("figure").unwrap().as_str(), Some("17"));
        assert_eq!(
            parsed.get("table").unwrap().get("rows").unwrap().as_arr().unwrap().len(),
            1
        );
    }

    #[test]
    fn committed_paper_table_artifact_is_well_formed() {
        // The checked-in `results/BENCH_paper.json` (refreshed by
        // `make paper`) must stay parseable and keep the full
        // 3-settings x 4-algorithms sweep with the PS-homo anchor row.
        let path =
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("results/BENCH_paper.json");
        let json = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("committed artifact {} unreadable: {e}", path.display()));
        let parsed = crate::util::json::parse(&json).unwrap();
        assert_eq!(parsed.get("figure").unwrap().as_str(), Some("paper"));
        let table = parsed.get("table").unwrap();
        let header: Vec<_> = table
            .get("header")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|c| c.as_str().unwrap().to_string())
            .collect();
        assert_eq!(
            header,
            ["setting", "algorithm", "time-to-loss(s)", "speedup vs ps-homo", "paper shape"]
        );
        let rows = table.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 12, "3 settings x 4 algorithms");
        for setting in ["homo", "hetero-5x", "hetero-bw16x"] {
            for kind in [
                AlgoKind::ParameterServer,
                AlgoKind::AllReduce,
                AlgoKind::AdPsgd,
                AlgoKind::RipplesSmart,
            ] {
                let row = rows
                    .iter()
                    .map(|r| r.as_arr().unwrap())
                    .find(|r| r[0].as_str() == Some(setting) && r[1].as_str() == Some(kind.name()))
                    .unwrap_or_else(|| panic!("missing row {setting}/{}", kind.name()));
                let speedup: f64 = row[3].as_str().unwrap().parse().unwrap();
                assert!(speedup > 0.0, "{setting}/{}: bad speedup", kind.name());
            }
        }
        // the speedup column is anchored at the homogeneous PS run
        let anchor = rows
            .iter()
            .map(|r| r.as_arr().unwrap())
            .find(|r| r[0].as_str() == Some("homo") && r[1].as_str() == Some("parameter-server"))
            .unwrap();
        assert_eq!(anchor[3].as_str(), Some("1.00"));
    }
}
