//! The decentralized static scheduler (§4.2, Figs. 9-10): a rule-based,
//! conflict-free, periodic group schedule computed locally by every worker
//! from `(worker, iteration)` — no GG round trip, no lock vector.
//!
//! The 4-phase rule generalizes Fig. 10 from (4 nodes x 4 workers) to any
//! `(n_nodes, workers_per_node)`:
//!
//! * phase 0 — local-rank-0 workers of all nodes form one global "head"
//!   group; local rank 1 skips; remaining local ranks pair up within their
//!   node (odd one out skips).
//! * phase 1 — all workers of each node sync intra-node.
//! * phase 2 — rank 0 pairs with the last local rank (intra-node); rank 1
//!   pairs with rank 1 on the *opposite node* of the ring; rank 2 skips;
//!   remaining ranks pair up within the node.
//! * phase 3 — intra-node sync again.
//!
//! Every phase is a partition of a subset of workers, so groups in the
//! same iteration never overlap: conflict-free by construction (verified
//! by the property tests below and in `tests/prop_gg.rs`).

/// Static schedule generator for a two-level cluster.
#[derive(Debug, Clone)]
pub struct StaticScheduler {
    pub n_nodes: usize,
    pub workers_per_node: usize,
}

impl StaticScheduler {
    pub fn new(n_nodes: usize, workers_per_node: usize) -> Self {
        assert!(n_nodes >= 1 && workers_per_node >= 1);
        Self { n_nodes, workers_per_node }
    }

    pub fn n_workers(&self) -> usize {
        self.n_nodes * self.workers_per_node
    }

    /// Cycle length of the schedule (Fig. 9: 4).
    pub const PHASES: usize = 4;

    fn node_of(&self, w: usize) -> usize {
        w / self.workers_per_node
    }

    fn rank_of(&self, w: usize) -> usize {
        w % self.workers_per_node
    }

    fn worker(&self, node: usize, rank: usize) -> usize {
        node * self.workers_per_node + rank
    }

    /// The group worker `w` joins in iteration `iter`; `None` = skip sync.
    /// Sorted members; guaranteed identical for every member (consistency)
    /// and disjoint across groups of the same iteration (conflict-freedom).
    pub fn group_of(&self, w: usize, iter: u64) -> Option<Vec<usize>> {
        let phase = (iter % Self::PHASES as u64) as usize;
        let node = self.node_of(w);
        let rank = self.rank_of(w);
        let wpn = self.workers_per_node;
        match phase {
            0 => {
                if rank == 0 {
                    // all head workers, across all nodes
                    if self.n_nodes == 1 {
                        return None;
                    }
                    Some((0..self.n_nodes).map(|nd| self.worker(nd, 0)).collect())
                } else if rank == 1 {
                    None
                } else {
                    // pair (2,3), (4,5), ... within the node
                    self.pair_within(node, rank, 2)
                }
            }
            1 | 3 => {
                if wpn == 1 {
                    return None;
                }
                Some((0..wpn).map(|r| self.worker(node, r)).collect())
            }
            2 => {
                if rank == 0 {
                    if wpn == 1 {
                        // degenerate: no last-rank partner; head workers
                        // pair with the opposite node instead
                        return self.opposite_pair(node, 0);
                    }
                    Some(sorted(vec![self.worker(node, 0), self.worker(node, wpn - 1)]))
                } else if rank == wpn - 1 && wpn >= 2 {
                    Some(sorted(vec![self.worker(node, 0), self.worker(node, wpn - 1)]))
                } else if rank == 1 {
                    self.opposite_pair(node, 1)
                } else if rank == 2 {
                    None
                } else {
                    // ranks 3..wpn-2 pair within the node
                    self.pair_within(node, rank, 3)
                }
            }
            _ => unreachable!(),
        }
    }

    /// Pair ranks `(base, base+1), (base+2, base+3), ...` within a node,
    /// excluding the node's last rank in phase-2 (it pairs with rank 0).
    fn pair_within(&self, node: usize, rank: usize, base: usize) -> Option<Vec<usize>> {
        let wpn = self.workers_per_node;
        // In phase 2, the last rank belongs to the (0, last) pair.
        let limit = if base == 3 { wpn.saturating_sub(1) } else { wpn };
        if rank < base || rank >= limit {
            return None;
        }
        let idx = rank - base;
        let mate_rank = if idx % 2 == 0 { rank + 1 } else { rank - 1 };
        if mate_rank < base || mate_rank >= limit {
            return None; // odd one out
        }
        Some(sorted(vec![self.worker(node, rank), self.worker(node, mate_rank)]))
    }

    /// Pair `(node, rank)` with the same rank on the opposite node of the
    /// ring of nodes. Odd node counts leave the middle node unpaired.
    fn opposite_pair(&self, node: usize, rank: usize) -> Option<Vec<usize>> {
        if self.n_nodes < 2 {
            return None;
        }
        let half = self.n_nodes / 2;
        let mate_node = (node + half) % self.n_nodes;
        if mate_node == node {
            return None;
        }
        // Only valid if the mapping is an involution (node <-> mate_node).
        if (mate_node + half) % self.n_nodes != node {
            return None;
        }
        Some(sorted(vec![self.worker(node, rank), self.worker(mate_node, rank)]))
    }

    /// All groups of one iteration (deduplicated) — for analysis/benches.
    pub fn groups_of_iter(&self, iter: u64) -> Vec<Vec<usize>> {
        let mut out: Vec<Vec<usize>> = Vec::new();
        for w in 0..self.n_workers() {
            if let Some(g) = self.group_of(w, iter) {
                if !out.contains(&g) {
                    out.push(g);
                }
            }
        }
        out
    }
}

fn sorted(mut v: Vec<usize>) -> Vec<usize> {
    v.sort_unstable();
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_invariants(s: &StaticScheduler) {
        for iter in 0..8u64 {
            // consistency: every member computes the same group
            for w in 0..s.n_workers() {
                if let Some(g) = s.group_of(w, iter) {
                    assert!(g.contains(&w), "iter {iter} w {w}: group {g:?} lacks self");
                    assert!(g.len() >= 2, "iter {iter} w {w}: singleton group");
                    for &m in &g {
                        assert_eq!(
                            s.group_of(m, iter).as_ref(),
                            Some(&g),
                            "iter {iter}: member {m} disagrees with {w}"
                        );
                    }
                }
            }
            // conflict-freedom: groups partition
            let groups = s.groups_of_iter(iter);
            let mut seen = vec![false; s.n_workers()];
            for g in &groups {
                for &m in g {
                    assert!(!seen[m], "iter {iter}: worker {m} in two groups");
                    seen[m] = true;
                }
            }
        }
    }

    #[test]
    fn paper_shape_4x4() {
        let s = StaticScheduler::new(4, 4);
        check_invariants(&s);
        // phase 0: head-worker group spans all nodes (Fig. 9 "G5"-style)
        let g = s.group_of(0, 0).unwrap();
        assert_eq!(g, vec![0, 4, 8, 12]);
        // rank 1 skips phase 0 (the "-" cells)
        assert_eq!(s.group_of(1, 0), None);
        // ranks 2,3 pair within node
        assert_eq!(s.group_of(2, 0).unwrap(), vec![2, 3]);
        // phase 1: full intra-node groups
        assert_eq!(s.group_of(5, 1).unwrap(), vec![4, 5, 6, 7]);
        // phase 2: rank0<->rank3 same node, rank1 <-> opposite node rank 1
        assert_eq!(s.group_of(0, 2).unwrap(), vec![0, 3]);
        assert_eq!(s.group_of(1, 2).unwrap(), vec![1, 9]);
        assert_eq!(s.group_of(2, 2), None);
        // phase 3 = phase 1
        assert_eq!(s.group_of(14, 3).unwrap(), vec![12, 13, 14, 15]);
    }

    #[test]
    fn periodicity() {
        let s = StaticScheduler::new(4, 4);
        for w in 0..16 {
            for i in 0..4u64 {
                assert_eq!(s.group_of(w, i), s.group_of(w, i + 4));
                assert_eq!(s.group_of(w, i), s.group_of(w, i + 400));
            }
        }
    }

    #[test]
    fn various_shapes_hold_invariants() {
        for (nodes, wpn) in [(2, 2), (2, 4), (4, 4), (8, 4), (4, 8), (3, 4), (4, 3), (1, 4), (6, 5)] {
            check_invariants(&StaticScheduler::new(nodes, wpn));
        }
    }

    #[test]
    fn schedule_mixes_inter_and_intra() {
        // The architecture-aware point: most groups intra-node, a few inter.
        let s = StaticScheduler::new(4, 4);
        let mut inter = 0;
        let mut intra = 0;
        for iter in 0..4u64 {
            for g in s.groups_of_iter(iter) {
                let n0 = g[0] / s.workers_per_node;
                if g.iter().all(|&m| m / s.workers_per_node == n0) {
                    intra += 1;
                } else {
                    inter += 1;
                }
            }
        }
        assert!(intra > inter, "intra {intra} should dominate inter {inter}");
        assert!(inter >= 2, "schedule must still propagate across nodes");
    }

    #[test]
    fn connectivity_updates_reach_all_workers() {
        // Spectral-gap sanity (§3.3): the union of groups over one period
        // must form a connected graph over workers.
        for (nodes, wpn) in [(4, 4), (2, 4), (8, 2), (3, 5)] {
            let s = StaticScheduler::new(nodes, wpn);
            let n = s.n_workers();
            let mut reach = vec![false; n];
            reach[0] = true;
            // propagate for a few periods
            for _ in 0..4 {
                for iter in 0..4u64 {
                    for g in s.groups_of_iter(iter) {
                        if g.iter().any(|&m| reach[m]) {
                            for &m in &g {
                                reach[m] = true;
                            }
                        }
                    }
                }
            }
            assert!(
                reach.iter().all(|&r| r),
                "({nodes},{wpn}): unreachable workers {:?}",
                reach.iter().enumerate().filter(|(_, &r)| !r).map(|(i, _)| i).collect::<Vec<_>>()
            );
        }
    }
}
