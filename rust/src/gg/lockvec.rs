//! The GG's lock vector (§4.1): one bit per worker indicating whether the
//! worker is currently claimed by an armed group. Backed by a `u64` bitset
//! — lock/try-lock over a whole group is a handful of word ops, which is
//! what keeps the centralized GG off the critical path.
//!
//! Two implementations share the semantics: [`LockVector`] (plain, owned
//! by the single-lock [`GroupGenerator`](crate::gg::GroupGenerator)) and
//! [`AtomicLockVector`] (shared-reference, used by
//! [`ShardedGg`](crate::gg::ShardedGg) so probes read lock bits without
//! any lock at all).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Fixed-capacity bitset sized to the worker count.
#[derive(Debug, Clone)]
pub struct LockVector {
    words: Vec<u64>,
    n: usize,
    locked_count: usize,
}

impl LockVector {
    pub fn new(n: usize) -> Self {
        Self { words: vec![0; n.div_ceil(64)], n, locked_count: 0 }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    #[inline]
    pub fn is_locked(&self, w: usize) -> bool {
        debug_assert!(w < self.n);
        self.words[w / 64] >> (w % 64) & 1 == 1
    }

    pub fn locked_count(&self) -> usize {
        self.locked_count
    }

    /// True if every member of `group` is free.
    pub fn all_free(&self, group: &[usize]) -> bool {
        group.iter().all(|&w| !self.is_locked(w))
    }

    /// Atomically lock the whole group if every member is free.
    /// Returns false (and changes nothing) on any conflict.
    pub fn try_lock(&mut self, group: &[usize]) -> bool {
        if !self.all_free(group) {
            return false;
        }
        for &w in group {
            self.words[w / 64] |= 1 << (w % 64);
        }
        self.locked_count += group.len();
        true
    }

    /// Release the whole group. Panics (debug) if any bit wasn't set —
    /// releasing an unlocked worker is a protocol bug.
    pub fn release(&mut self, group: &[usize]) {
        for &w in group {
            debug_assert!(self.is_locked(w), "releasing unlocked worker {w}");
            self.words[w / 64] &= !(1 << (w % 64));
        }
        self.locked_count -= group.len();
    }

    /// Clear `w`'s bit if set, returning whether a bit was cleared.
    ///
    /// Failure-repair sweep: after a rank is declared dead every group
    /// naming it is aborted, which releases its locks through the normal
    /// [`LockVector::release`] path — but a dead rank must *never* keep a
    /// lock bit, so [`crate::gg::GroupGenerator::declare_dead`] finishes
    /// with this unconditional sweep as a guard against protocol drift.
    pub fn force_release(&mut self, w: usize) -> bool {
        if self.is_locked(w) {
            self.words[w / 64] &= !(1 << (w % 64));
            self.locked_count -= 1;
            true
        } else {
            false
        }
    }

    /// Indices of currently-free workers.
    pub fn free_workers(&self) -> Vec<usize> {
        (0..self.n).filter(|&w| !self.is_locked(w)).collect()
    }
}

/// [`LockVector`] semantics over atomic words, for the sharded GG.
///
/// # Concurrency contract
///
/// *Readers* ([`AtomicLockVector::is_locked`],
/// [`AtomicLockVector::locked_count`], [`AtomicLockVector::all_free`])
/// are lock-free and may run from any thread at any time — they feed
/// heuristics (idle filters, probes, stats), where a stale bit is
/// harmless.
///
/// *Mutators* ([`AtomicLockVector::try_lock`],
/// [`AtomicLockVector::release`], [`AtomicLockVector::force_release`])
/// MUST be externally serialized — in [`ShardedGg`](crate::gg::ShardedGg)
/// they only run under the scheduler mutex. That contract is what lets
/// `try_lock` be a plain check-then-set (no CAS loop, no rollback): no
/// other mutator can interleave between the all-free check and the bit
/// stores, exactly like the `&mut self` version above.
#[derive(Debug)]
pub struct AtomicLockVector {
    words: Vec<AtomicU64>,
    n: usize,
    locked_count: AtomicUsize,
}

impl AtomicLockVector {
    pub fn new(n: usize) -> Self {
        Self {
            words: (0..n.div_ceil(64)).map(|_| AtomicU64::new(0)).collect(),
            n,
            locked_count: AtomicUsize::new(0),
        }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    #[inline]
    pub fn is_locked(&self, w: usize) -> bool {
        debug_assert!(w < self.n);
        self.words[w / 64].load(Ordering::Acquire) >> (w % 64) & 1 == 1
    }

    pub fn locked_count(&self) -> usize {
        self.locked_count.load(Ordering::Acquire)
    }

    /// True if every member of `group` is free.
    pub fn all_free(&self, group: &[usize]) -> bool {
        group.iter().all(|&w| !self.is_locked(w))
    }

    /// Lock the whole group if every member is free; false (and nothing
    /// changed) on any conflict. Mutator — see the serialization contract.
    pub fn try_lock(&self, group: &[usize]) -> bool {
        if !self.all_free(group) {
            return false;
        }
        for &w in group {
            self.words[w / 64].fetch_or(1 << (w % 64), Ordering::AcqRel);
        }
        self.locked_count.fetch_add(group.len(), Ordering::AcqRel);
        true
    }

    /// Release the whole group. Panics (debug) if any bit wasn't set —
    /// releasing an unlocked worker is a protocol bug. Mutator.
    pub fn release(&self, group: &[usize]) {
        for &w in group {
            debug_assert!(self.is_locked(w), "releasing unlocked worker {w}");
            self.words[w / 64].fetch_and(!(1 << (w % 64)), Ordering::AcqRel);
        }
        self.locked_count.fetch_sub(group.len(), Ordering::AcqRel);
    }

    /// Clear `w`'s bit if set, returning whether a bit was cleared (the
    /// dead-rank guard sweep; see [`LockVector::force_release`]). Mutator.
    pub fn force_release(&self, w: usize) -> bool {
        if self.is_locked(w) {
            self.words[w / 64].fetch_and(!(1 << (w % 64)), Ordering::AcqRel);
            self.locked_count.fetch_sub(1, Ordering::AcqRel);
            true
        } else {
            false
        }
    }

    /// Indices of currently-free workers.
    pub fn free_workers(&self) -> Vec<usize> {
        (0..self.n).filter(|&w| !self.is_locked(w)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lock_release_roundtrip() {
        let mut lv = LockVector::new(100);
        assert!(lv.try_lock(&[0, 63, 64, 99]));
        assert!(lv.is_locked(0) && lv.is_locked(63) && lv.is_locked(64) && lv.is_locked(99));
        assert!(!lv.is_locked(1));
        assert_eq!(lv.locked_count(), 4);
        lv.release(&[0, 63, 64, 99]);
        assert_eq!(lv.locked_count(), 0);
        assert!(lv.all_free(&[0, 63, 64, 99]));
    }

    #[test]
    fn conflicting_lock_fails_atomically() {
        let mut lv = LockVector::new(16);
        assert!(lv.try_lock(&[0, 4, 5]));
        // overlapping group must fail and leave 7 unlocked
        assert!(!lv.try_lock(&[4, 5, 7]));
        assert!(!lv.is_locked(7), "failed try_lock must not partially lock");
        assert_eq!(lv.locked_count(), 3);
    }

    #[test]
    fn disjoint_groups_coexist() {
        let mut lv = LockVector::new(16);
        assert!(lv.try_lock(&[0, 1]));
        assert!(lv.try_lock(&[2, 3]));
        assert!(lv.try_lock(&[8, 15]));
        assert_eq!(lv.locked_count(), 6);
    }

    #[test]
    fn free_workers_lists_complement() {
        let mut lv = LockVector::new(8);
        lv.try_lock(&[1, 3, 5]);
        assert_eq!(lv.free_workers(), vec![0, 2, 4, 6, 7]);
    }

    #[test]
    fn force_release_clears_only_set_bits() {
        let mut lv = LockVector::new(8);
        lv.try_lock(&[2, 5]);
        assert!(lv.force_release(2), "locked bit must be cleared");
        assert!(!lv.is_locked(2));
        assert_eq!(lv.locked_count(), 1);
        assert!(!lv.force_release(2), "idempotent on a free worker");
        assert_eq!(lv.locked_count(), 1);
        assert!(lv.is_locked(5), "other bits untouched");
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn double_release_panics_in_debug() {
        let mut lv = LockVector::new(4);
        lv.try_lock(&[1]);
        lv.release(&[1]);
        lv.release(&[1]);
    }

    #[test]
    fn atomic_lockvec_mirrors_plain_semantics() {
        let lv = AtomicLockVector::new(100);
        assert!(lv.try_lock(&[0, 63, 64, 99]));
        assert!(lv.is_locked(0) && lv.is_locked(63) && lv.is_locked(64) && lv.is_locked(99));
        assert!(!lv.is_locked(1));
        assert_eq!(lv.locked_count(), 4);
        assert!(!lv.try_lock(&[64, 65]), "overlap must fail");
        assert!(!lv.is_locked(65), "failed try_lock must not partially lock");
        lv.release(&[0, 63, 64, 99]);
        assert_eq!(lv.locked_count(), 0);
        assert!(lv.all_free(&[0, 63, 64, 99]));
        lv.try_lock(&[2, 5]);
        assert!(lv.force_release(2));
        assert!(!lv.force_release(2), "idempotent on a free worker");
        assert_eq!(lv.free_workers(), (0..100).filter(|&w| w != 5).collect::<Vec<_>>());
    }

    #[test]
    fn atomic_lockvec_readers_are_safe_under_concurrent_mutation() {
        // Mutators serialized by a mutex (the ShardedGg contract);
        // lock-free readers hammer from other threads — the counter and
        // bits must stay consistent at quiescence.
        use std::sync::{Arc, Mutex};
        let lv = Arc::new(AtomicLockVector::new(64));
        let gate = Arc::new(Mutex::new(()));
        let stop = Arc::new(AtomicUsize::new(0));
        let reader = {
            let (lv, stop) = (lv.clone(), stop.clone());
            std::thread::spawn(move || {
                while stop.load(Ordering::Acquire) == 0 {
                    let _ = lv.locked_count();
                    let _ = lv.is_locked(7);
                }
            })
        };
        let writers: Vec<_> = (0..4u64)
            .map(|t| {
                let (lv, gate) = (lv.clone(), gate.clone());
                std::thread::spawn(move || {
                    let group = [t as usize * 2, t as usize * 2 + 1];
                    for _ in 0..500 {
                        let _g = gate.lock().unwrap();
                        if lv.try_lock(&group) {
                            lv.release(&group);
                        }
                    }
                })
            })
            .collect();
        for w in writers {
            w.join().unwrap();
        }
        stop.store(1, Ordering::Release);
        reader.join().unwrap();
        assert_eq!(lv.locked_count(), 0);
        assert!(lv.all_free(&(0..64).collect::<Vec<_>>()));
    }
}
