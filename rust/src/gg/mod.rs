//! Group Generator: the paper's centralized scheduler (§4.1, §5).
//!
//! [`GroupGenerator`] is a *pure state machine* — no threads, no clocks —
//! so the exact same code drives the discrete-event simulator, the
//! threaded runtime, and the TCP RPC server, and can be unit/property
//! tested exhaustively.
//!
//! Protocol (matching Fig. 8):
//!  1. Worker finishes an iteration and calls [`GroupGenerator::request`].
//!  2. GG assigns a group: from the worker's Group Buffer if non-empty
//!     (smart GG, §5.1), else freshly generated — a single random group
//!     (§4.1) or a Global Division over all idle workers (§5.1), possibly
//!     architecture-aware (§5.2) and slowdown-filtered (§5.3).
//!  3. New groups try to acquire the lock vector; conflicting groups wait
//!     in the pending queue (serialization = the atomicity guarantee).
//!  4. When a P-Reduce finishes, the engine calls
//!     [`GroupGenerator::complete`]; locks release and pending groups arm.
//!
//! # Online speed telemetry
//!
//! The slowdown filter (§5.3) needs to know which workers are slow.
//! Rather than trusting launch-time configuration, every engine feeds
//! *measured* per-worker step durations into the GG's [`SpeedTable`]
//! (workers piggyback an EWMA on their `Sync` RPCs; the simulator
//! observes its own virtual compute times). Global Division then
//! excludes workers whose relative speed — EWMA step time divided by
//! the fastest worker's — exceeds [`GgConfig::s_thres`], so a straggler
//! that *appears mid-run* stops being drafted within ~1/α steps, and a
//! straggler that *recovers* is re-admitted just as fast (the pure
//! counter filter would exclude it forever: its progress deficit never
//! shrinks). Configured slowdowns remain simulator ground truth only.
//!
//! # Fault tolerance
//!
//! Graceful departure is [`GroupGenerator::retire`]; a *crash* is
//! [`GroupGenerator::declare_dead`]: the rank's locks are released, its
//! speed entry is purged, and every live group naming it is aborted so
//! ring peers unwind and retry in a repaired group instead of waiting
//! forever — the deadlock class AD-PSGD is criticized for. Engines can
//! also abort a single broken group ([`GroupGenerator::abort_group`],
//! fed by data-plane failure reports) and re-admit a checkpoint-restored
//! replacement ([`GroupGenerator::rejoin`]). Probing distinguishes
//! "completed" from "aborted" via [`GroupGenerator::was_aborted`]. See
//! DESIGN.md §Fault-tolerance for the full detection → abort → repair →
//! rejoin data flow.
//!
//! ```
//! use ripples::gg::{GgConfig, GroupGenerator};
//! use ripples::util::rng::Pcg32;
//!
//! let mut gg = GroupGenerator::new(GgConfig::smart(8, 4, 2, 8));
//! let mut rng = Pcg32::new(42);
//! // workers report measured step durations; worker 7 is 6x slower
//! for w in 0..8 {
//!     gg.report_speed(w, if w == 7 { 0.060 } else { 0.010 });
//! }
//! let rel = gg.relative_speed(7).unwrap();
//! assert!((rel - 6.0).abs() < 1e-9);
//! // a fast initiator's Global Division never drafts the straggler
//! let (assigned, armed) = gg.request(0, &mut rng);
//! assert!(assigned.is_some());
//! for g in &armed {
//!     assert!(!g.members.contains(&7));
//! }
//! ```

pub mod lockvec;
pub mod sharded;
pub mod static_sched;

pub use lockvec::{AtomicLockVector, LockVector};
pub use sharded::{CompleteOutcome, GroupPhase, ShardedGg};
pub use static_sched::StaticScheduler;

use crate::util::rng::Pcg32;
use std::collections::{HashMap, HashSet, VecDeque};

pub type GroupId = u64;

/// Default measured-slowdown filter threshold: a worker measured more
/// than 1.5x slower than the fastest peer is excluded from other
/// initiators' divisions — between homogeneous noise (relative ≈
/// 1.0–1.2 under jitter) and the mildest configured straggler (2x
/// total multiplier), so even the paper's gentlest scenario is
/// filtered while jittered-but-healthy workers are not.
pub const DEFAULT_S_THRES: f64 = 1.5;

/// Default EWMA smoothing factor for server-side speed observations
/// (per-step updates: `ewma = α·sample + (1-α)·ewma`). 0.25 reacts to a
/// mid-run slowdown within ~4 steps while riding out single-step noise;
/// see DESIGN.md §Hardware-Adaptation.
pub const SPEED_ALPHA: f64 = 0.25;

/// One scalar EWMA update: seed with the first sample (`prev <= 0`
/// means "no measurement yet"), then fold with `alpha`. The single
/// definition of the smoothing shared by [`SpeedTable::observe`] and
/// the distributed worker loop, so the worker-side EWMA cannot drift
/// from the sim/threaded path.
pub fn ewma_step(prev: f64, sample: f64, alpha: f64) -> f64 {
    if prev > 0.0 {
        alpha * sample + (1.0 - alpha) * prev
    } else {
        sample
    }
}

/// Online per-worker speed telemetry: EWMA seconds per local SGD step.
///
/// Fed either by raw per-step observations ([`SpeedTable::observe`],
/// the simulator path) or by already-smoothed worker-side EWMAs
/// ([`SpeedTable::report`], the RPC piggyback path). Relative speed is
/// measured against the fastest known worker, so `relative(w)` is the
/// measured analogue of the configured slowdown factor.
#[derive(Debug, Clone)]
pub struct SpeedTable {
    ewma: Vec<Option<f64>>,
    alpha: f64,
}

impl SpeedTable {
    pub fn new(n_workers: usize, alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0, "bad EWMA alpha {alpha}");
        Self { ewma: vec![None; n_workers], alpha }
    }

    /// Fold one raw step-duration sample into worker `w`'s EWMA. The
    /// very first observation must seed the EWMA with the raw sample —
    /// seeding from 0.0 as `α·sample` would make a brand-new worker
    /// look `1/α` times too fast and transiently misclassify *other*
    /// workers as stragglers relative to it. The old `unwrap_or(0.0)`
    /// here only behaved because [`ewma_step`] happens to treat
    /// `prev <= 0` as "seed"; the seed decision is made explicitly at
    /// this call site now so the invariant no longer hangs off a
    /// helper's internal guard.
    pub fn observe(&mut self, w: usize, step_secs: f64) {
        if !(step_secs > 0.0 && step_secs.is_finite()) {
            return; // ignore garbage samples
        }
        self.ewma[w] = Some(match self.ewma[w] {
            Some(prev) => ewma_step(prev, step_secs, self.alpha),
            None => step_secs,
        });
    }

    /// Replace worker `w`'s entry with an already-smoothed EWMA (the
    /// worker did the smoothing; re-smoothing would double the lag).
    pub fn report(&mut self, w: usize, ewma_secs: f64) {
        if ewma_secs > 0.0 && ewma_secs.is_finite() {
            self.ewma[w] = Some(ewma_secs);
        }
    }

    /// EWMA step seconds of `w`, if any measurement arrived yet.
    pub fn get(&self, w: usize) -> Option<f64> {
        self.ewma[w]
    }

    /// Fastest known EWMA (the reference for relative speeds).
    pub fn reference(&self) -> Option<f64> {
        self.reference_excluding(&[])
    }

    /// Fastest known EWMA among workers *not* flagged in `skip` (workers
    /// beyond `skip.len()` count as not skipped). The GG passes its
    /// retired mask here: a fast worker that left the session must not
    /// keep suppressing everyone else's relative speed — that would hold
    /// a recovered straggler excluded for the whole drain.
    pub fn reference_excluding(&self, skip: &[bool]) -> Option<f64> {
        self.ewma
            .iter()
            .enumerate()
            .filter(|(w, _)| !skip.get(*w).copied().unwrap_or(false))
            .filter_map(|(_, e)| *e)
            .fold(None, |acc: Option<f64>, v| Some(acc.map_or(v, |a| a.min(v))))
    }

    /// Measured slowdown factor of `w` vs the fastest known worker.
    pub fn relative(&self, w: usize) -> Option<f64> {
        Some(self.ewma[w]? / self.reference()?)
    }

    /// All EWMAs, 0.0 where nothing was measured (wire-friendly).
    pub fn snapshot(&self) -> Vec<f64> {
        self.ewma.iter().map(|e| e.unwrap_or(0.0)).collect()
    }

    /// Forget everything measured about `w` (death purge / rejoin reset:
    /// a dead rank's frozen EWMA must not anchor the reference, and a
    /// rejoined replacement starts with fresh measurements).
    pub fn clear(&mut self, w: usize) {
        self.ewma[w] = None;
    }
}

/// A synchronization group: sorted member list.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Group {
    pub id: GroupId,
    pub members: Vec<usize>,
}

/// GG policy knobs; presets for the paper's three schedulers below.
#[derive(Debug, Clone)]
pub struct GgConfig {
    pub n_workers: usize,
    pub workers_per_node: usize,
    /// Target group size (paper uses 3).
    pub group_size: usize,
    /// §5.1 Group Buffer: reuse scheduled groups instead of creating new.
    pub use_group_buffer: bool,
    /// §5.1 Global Division: partition all idle workers at once.
    pub use_global_division: bool,
    /// §5.2 architecture-aware Inter-Intra generation (implies GD).
    pub inter_intra: bool,
    /// §5.3 slowdown filter threshold; None disables.
    pub c_thres: Option<u64>,
    /// Measured slowdown filter: exclude workers whose [`SpeedTable`]
    /// relative speed exceeds this factor from *other* initiators'
    /// divisions (the initiator itself always participates, like the
    /// counter filter). None disables; workers with no measurement yet
    /// are judged by the `c_thres` counter rule instead. Note the EWMA
    /// times the *compute phase only* (sync wait would conflate a
    /// worker's own speed with its partners'), so when telemetry exists
    /// it fully replaces the counter rule — the price is that a worker
    /// slow purely in its *link* (fast compute, slow transfers) passes;
    /// every heterogeneity source this repo models is compute-time.
    pub s_thres: Option<f64>,
    /// EWMA smoothing for per-step speed observations ([`SPEED_ALPHA`]).
    pub speed_alpha: f64,
    /// The engine driving this GG is a collective *rendezvous* runtime
    /// (threaded or distributed): members physically meet to execute a
    /// group, so freshly generated groups must draft only idle workers —
    /// drafting a worker whose front group is pending creates a circular
    /// wait. The event simulator leaves this off and keeps the paper's
    /// unrestricted §4.1 sampling (pending groups just queue there).
    pub rendezvous: bool,
    /// Physical rank → machine placement (`--topo` / `[topology]`).
    /// When set, every drafted group's RPC reply carries a two-level
    /// [`SyncPlan`](crate::topo::SyncPlan) (intra-node reduce →
    /// inter-node ring → broadcast); when `None`, replies carry the
    /// bandwidth-ordered flat ring built from [`SpeedTable`] telemetry.
    /// Plans are assembled at reply time from this field plus the speed
    /// snapshot — the GG state machines themselves never read it, so
    /// both backends stay bit-identical (DESIGN.md §Perf).
    pub topology: Option<crate::topo::Topology>,
}

impl GgConfig {
    /// Plain randomized GG (§4.1).
    pub fn random(n_workers: usize, workers_per_node: usize, group_size: usize) -> Self {
        Self {
            n_workers,
            workers_per_node,
            group_size,
            use_group_buffer: false,
            use_global_division: false,
            inter_intra: false,
            c_thres: None,
            s_thres: None,
            speed_alpha: SPEED_ALPHA,
            rendezvous: false,
            topology: None,
        }
    }

    /// Smart GG: GB + GD + Inter-Intra + slowdown filter (§5).
    pub fn smart(
        n_workers: usize,
        workers_per_node: usize,
        group_size: usize,
        c_thres: u64,
    ) -> Self {
        Self {
            n_workers,
            workers_per_node,
            group_size,
            use_group_buffer: true,
            use_global_division: true,
            inter_intra: true,
            c_thres: Some(c_thres),
            s_thres: Some(DEFAULT_S_THRES),
            speed_alpha: SPEED_ALPHA,
            rendezvous: false,
            topology: None,
        }
    }
}

/// Counters reported by `ripples fig`/benches.
#[derive(Debug, Clone, Default)]
pub struct GgStats {
    pub requests: u64,
    pub groups_created: u64,
    pub conflicts: u64,
    pub divisions: u64,
    pub buffer_hits: u64,
    pub max_pending: usize,
    /// Ranks declared dead ([`GroupGenerator::declare_dead`]).
    pub deaths: u64,
    /// Groups torn down by failure repair (abort ≠ complete).
    pub groups_aborted: u64,
    /// Dead ranks re-registered ([`GroupGenerator::rejoin`]).
    pub rejoins: u64,
}

/// What a death declaration tore down: the groups that were aborted
/// (locks released, Group Buffers purged) plus any pending groups that
/// armed once the dead rank's locks came free. Engines must stop
/// tracking the former and start tracking the latter.
#[derive(Debug, Clone, Default)]
pub struct DeathPurge {
    pub aborted: Vec<Group>,
    pub newly_armed: Vec<Group>,
}

/// Bound on the remembered aborted-group ids: old ids are pruned once
/// the set exceeds this (ids are monotonic, so the most recent survive).
/// Far above anything a bounded run creates; keeps unbounded services
/// from leaking. The single shared definition for *both* backends — the
/// oracle prunes against the whole set, [`ShardedGg`] prunes each of its
/// id shards against its `1/GROUP_SHARDS` slice with the same recent-id
/// window, so the two agree on every `was_aborted` answer
/// (`modelcheck::aborted_cap_agrees_across_backends` pins this).
pub const ABORTED_SET_CAP: usize = 1 << 16;

/// The GG state machine.
#[derive(Debug)]
pub struct GroupGenerator {
    cfg: GgConfig,
    locks: LockVector,
    pending: VecDeque<GroupId>,
    groups: HashMap<GroupId, Group>,
    /// Per-worker Group Buffer: ordered ids of groups the worker belongs to.
    gb: Vec<VecDeque<GroupId>>,
    /// §5.3 progress counters (requests seen per worker).
    counters: Vec<u64>,
    /// Measured per-worker step durations (the dynamic §5.3 input).
    speed: SpeedTable,
    /// Times each worker was drafted into a fresh group created by a
    /// *different* initiator (the slowdown filter's observable).
    drafts: Vec<u64>,
    /// `stats.requests` value at each worker's most recent such draft
    /// (0 = never): "requests since the filter last drafted w".
    last_drafted: Vec<u64>,
    /// Workers that have left the training session (threaded-runtime
    /// termination protocol): never drafted into new groups.
    retired: Vec<bool>,
    /// Workers declared dead by failure detection (crash, not Retire):
    /// also retired, plus every group naming them has been aborted.
    dead: Vec<bool>,
    /// Ids of groups torn down by failure repair, so Wait/Probe can tell
    /// "aborted — do not run the collective" from "completed" (bounded;
    /// see [`ABORTED_SET_CAP`]).
    aborted: HashSet<GroupId>,
    next_id: GroupId,
    pub stats: GgStats,
}

impl GroupGenerator {
    pub fn new(cfg: GgConfig) -> Self {
        assert!(cfg.group_size >= 2 && cfg.group_size <= cfg.n_workers);
        let n = cfg.n_workers;
        let alpha = cfg.speed_alpha;
        Self {
            cfg,
            locks: LockVector::new(n),
            pending: VecDeque::new(),
            groups: HashMap::new(),
            gb: (0..n).map(|_| VecDeque::new()).collect(),
            counters: vec![0; n],
            speed: SpeedTable::new(n, alpha),
            drafts: vec![0; n],
            last_drafted: vec![0; n],
            retired: vec![false; n],
            dead: vec![false; n],
            aborted: HashSet::new(),
            next_id: 1,
            stats: GgStats::default(),
        }
    }

    pub fn config(&self) -> &GgConfig {
        &self.cfg
    }

    pub fn group(&self, id: GroupId) -> Option<&Group> {
        self.groups.get(&id)
    }

    pub fn counters(&self) -> &[u64] {
        &self.counters
    }

    /// Fold one raw measured step duration into `w`'s EWMA (simulator /
    /// threaded-runtime path).
    pub fn observe_speed(&mut self, w: usize, step_secs: f64) {
        self.speed.observe(w, step_secs);
    }

    /// Accept a worker-smoothed EWMA step duration (the `SpeedReport`
    /// piggybacked on `Sync` RPCs).
    pub fn report_speed(&mut self, w: usize, ewma_secs: f64) {
        self.speed.report(w, ewma_secs);
    }

    /// The measured speed table.
    pub fn speed_table(&self) -> &SpeedTable {
        &self.speed
    }

    /// Measured slowdown factor of `w` vs the fastest known *live*
    /// worker (retired ranks are excluded from the reference — their
    /// frozen EWMAs would otherwise suppress everyone forever).
    pub fn relative_speed(&self, w: usize) -> Option<f64> {
        Some(self.speed.get(w)? / self.speed.reference_excluding(&self.retired)?)
    }

    /// Per-worker counts of drafts into groups created by *other*
    /// initiators (what the slowdown filter suppresses for stragglers).
    pub fn drafts(&self) -> &[u64] {
        &self.drafts
    }

    /// Per-worker `stats.requests` value at the most recent such draft
    /// (0 = never drafted by another initiator).
    pub fn last_drafted(&self) -> &[u64] {
        &self.last_drafted
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Live group count (armed + pending).
    pub fn live_groups(&self) -> usize {
        self.groups.len()
    }

    /// Ids of all live groups (armed + pending), unordered.
    pub fn live_group_ids(&self) -> Vec<GroupId> {
        self.groups.keys().copied().collect()
    }

    /// Front of a worker's Group Buffer (None when empty).
    pub fn gb_front(&self, w: usize) -> Option<GroupId> {
        self.gb[w].front().copied()
    }

    /// Mark a worker as departed: it is never drafted into new groups.
    /// Groups already in its GB must still be drained (see the threaded
    /// runtime's termination protocol).
    pub fn retire(&mut self, w: usize) {
        self.retired[w] = true;
    }

    pub fn is_retired(&self, w: usize) -> bool {
        self.retired[w]
    }

    pub fn is_dead(&self, w: usize) -> bool {
        self.dead[w]
    }

    /// True if `id` was torn down by failure repair (as opposed to
    /// completing normally). Memory is bounded ([`ABORTED_SET_CAP`]).
    pub fn was_aborted(&self, id: GroupId) -> bool {
        self.aborted.contains(&id)
    }

    /// Lock-vector view of one worker (test/diagnostic accessor).
    pub fn is_locked_worker(&self, w: usize) -> bool {
        self.locks.is_locked(w)
    }

    /// Total lock bits currently set (test/diagnostic accessor).
    pub fn locked_count(&self) -> usize {
        self.locks.locked_count()
    }

    /// Snapshot of one worker's Group Buffer (test/diagnostic accessor).
    pub fn gb_snapshot(&self, w: usize) -> Vec<GroupId> {
        self.gb[w].iter().copied().collect()
    }

    fn note_aborted(&mut self, id: GroupId) {
        self.aborted.insert(id);
        if self.aborted.len() > ABORTED_SET_CAP {
            // ids are monotonic: keep the most recent window
            let min_keep = self.next_id.saturating_sub(ABORTED_SET_CAP as u64);
            self.aborted.retain(|&g| g >= min_keep);
        }
    }

    /// Remove one live group without completing it: purge it from every
    /// member's Group Buffer (any position — unlike completion, an
    /// aborted group need not be at the front) and drop it from the
    /// pending queue or release its locks. Returns the group plus
    /// whether locks were released (armed) — arming whatever those
    /// locks were blocking is the caller's choice: immediately
    /// ([`GroupGenerator::abort_group`]) or once after a batch
    /// ([`GroupGenerator::declare_dead`]). `None` for unknown ids.
    fn teardown_group(&mut self, id: GroupId) -> Option<(Group, bool)> {
        let group = self.groups.remove(&id)?;
        self.stats.groups_aborted += 1;
        self.note_aborted(id);
        if self.cfg.use_group_buffer {
            for &m in &group.members {
                self.gb[m].retain(|&g| g != id);
            }
        }
        if let Some(pos) = self.pending.iter().position(|&p| p == id) {
            self.pending.remove(pos);
            return Some((group, false)); // pending groups hold no locks
        }
        self.locks.release(&group.members);
        Some((group, true))
    }

    /// Tear one group down without completing it and arm whatever its
    /// locks were blocking. Idempotent on unknown ids (a duplicate abort
    /// report from a second ring survivor is expected, not an error).
    ///
    /// Returns the groups that armed as a result.
    pub fn abort_group(&mut self, id: GroupId) -> Vec<Group> {
        match self.teardown_group(id) {
            Some((group, true)) => self.arm_unblocked(&group.members),
            _ => Vec::new(),
        }
    }

    /// Failure detection verdict: `w` crashed. The rank is retired (never
    /// drafted again), its speed telemetry is purged (a frozen EWMA must
    /// not anchor the filter's reference), and every live group naming it
    /// — armed or pending — is aborted so its partners unblock instead of
    /// waiting forever on a dead rank's locks. Idempotent.
    pub fn declare_dead(&mut self, w: usize) -> DeathPurge {
        if self.dead[w] {
            return DeathPurge::default();
        }
        self.dead[w] = true;
        self.retired[w] = true;
        self.stats.deaths += 1;
        self.speed.clear(w);
        self.gb[w].clear();
        let mut doomed: Vec<GroupId> = self
            .groups
            .iter()
            .filter(|(_, g)| g.members.contains(&w))
            .map(|(&id, _)| id)
            .collect();
        doomed.sort_unstable(); // HashMap order is randomized; stay deterministic
        // Remove every doomed group first, then arm in one sweep — arming
        // as we go could transiently hand out a pending group that names
        // the dead rank and is itself about to be aborted.
        let mut released: Vec<usize> = Vec::new();
        let mut aborted = Vec::new();
        for id in doomed {
            let (group, was_armed) =
                self.teardown_group(id).expect("doomed id is live");
            if was_armed {
                released.extend(group.members.iter().copied());
            }
            aborted.push(group);
        }
        let newly_armed =
            if released.is_empty() { Vec::new() } else { self.arm_unblocked(&released) };
        // Guard against protocol drift: a dead rank must never keep a bit.
        debug_assert!(!self.locks.is_locked(w), "dead rank {w} still locked");
        self.locks.force_release(w);
        DeathPurge { aborted, newly_armed }
    }

    /// A replacement process re-registers rank `w` (checkpoint-restored):
    /// purge whatever the old incarnation left behind (its death may not
    /// have been declared yet — a fast restart), then clear the dead and
    /// retired flags so the rank is drafted again. The progress counter
    /// catches up to the fastest live worker so the §5.3 counter rule
    /// cannot freeze the rejoiner out of divisions; speed telemetry
    /// restarts from scratch.
    pub fn rejoin(&mut self, w: usize) -> DeathPurge {
        let purge = self.declare_dead(w);
        self.dead[w] = false;
        self.retired[w] = false;
        self.speed.clear(w);
        let caught_up = (0..self.cfg.n_workers)
            .filter(|&x| x != w && !self.retired[x])
            .map(|x| self.counters[x])
            .max()
            .unwrap_or(0);
        self.counters[w] = self.counters[w].max(caught_up);
        self.stats.rejoins += 1;
        purge
    }

    /// Worker `w` requests synchronization.
    ///
    /// Returns `(assigned, newly_armed)`: the id of the group that
    /// satisfies this request, plus any groups that acquired their locks
    /// as a result of this call (the engine should consider starting them
    /// once all members are ready). `assigned` is `None` when no sync is
    /// possible — the worker is retired with an empty buffer, or every
    /// potential partner has retired — and the worker should skip this
    /// sync step.
    pub fn request(&mut self, w: usize, rng: &mut Pcg32) -> (Option<GroupId>, Vec<Group>) {
        assert!(w < self.cfg.n_workers);
        self.stats.requests += 1;
        self.counters[w] += 1;

        if self.cfg.use_group_buffer {
            if let Some(&front) = self.gb[w].front() {
                self.stats.buffer_hits += 1;
                return (Some(front), Vec::new());
            }
        }
        if self.retired[w] {
            return (None, Vec::new()); // drained and departed
        }

        let member_lists = if self.cfg.use_global_division || self.cfg.inter_intra {
            self.global_division(w, rng)
        } else {
            match self.random_group(w, rng) {
                Some(g) => vec![g],
                None => Vec::new(),
            }
        };
        if member_lists.is_empty() {
            return (None, Vec::new()); // nobody left to pair with
        }

        let mut newly_armed = Vec::new();
        let mut assigned = None;
        for members in member_lists {
            let contains_w = members.contains(&w);
            let id = self.create_group(w, members, &mut newly_armed);
            if contains_w && assigned.is_none() {
                assigned = Some(id);
            }
        }
        (assigned, newly_armed)
    }

    /// A group's P-Reduce finished: release locks, pop Group Buffers, and
    /// arm pending groups whose members are now free (in FIFO order).
    ///
    /// Idempotent: completing an unknown (already-completed) id is a
    /// no-op returning no newly armed groups — a duplicate or retried
    /// leader `Complete` RPC must not crash the control plane.
    pub fn complete(&mut self, id: GroupId) -> Vec<Group> {
        let Some(group) = self.groups.remove(&id) else {
            return Vec::new();
        };
        self.locks.release(&group.members);
        if self.cfg.use_group_buffer {
            for &m in &group.members {
                // The completed group should be at the front of each GB:
                // groups arm in creation order and serialize via locks.
                if self.gb[m].front() == Some(&id) {
                    self.gb[m].pop_front();
                } else {
                    self.gb[m].retain(|&g| g != id);
                }
            }
        }
        self.arm_unblocked(&group.members)
    }

    /// Arm pending groups that can now lock after `released` workers came
    /// free, preserving FIFO fairness. Hot-path optimization (§Perf): a
    /// pending group whose members do not intersect the released set was
    /// already blocked before this call, and nothing here can unblock it
    /// (arming other groups only *sets* lock bits) — skip its try_lock.
    /// Shared by completion and the failure-repair abort path.
    fn arm_unblocked(&mut self, released: &[usize]) -> Vec<Group> {
        let mut armed = Vec::new();
        let mut still_pending = VecDeque::new();
        while let Some(pid) = self.pending.pop_front() {
            let g = &self.groups[&pid];
            let touched = g.members.iter().any(|m| released.contains(m));
            if touched && self.locks.try_lock(&g.members) {
                armed.push(g.clone());
            } else {
                still_pending.push_back(pid);
            }
        }
        self.pending = still_pending;
        armed
    }

    /// True if `id` currently holds its locks (armed) — pending otherwise.
    pub fn is_armed(&self, id: GroupId) -> bool {
        self.groups.contains_key(&id) && !self.pending.contains(&id)
    }

    // ------------------------------------------------------------------
    // group creation
    // ------------------------------------------------------------------

    fn create_group(
        &mut self,
        initiator: usize,
        mut members: Vec<usize>,
        newly_armed: &mut Vec<Group>,
    ) -> GroupId {
        members.sort_unstable();
        members.dedup();
        debug_assert!(members.len() >= 2);
        let id = self.next_id;
        self.next_id += 1;
        let group = Group { id, members };
        self.stats.groups_created += 1;
        for &m in &group.members {
            if m != initiator {
                self.drafts[m] += 1;
                self.last_drafted[m] = self.stats.requests;
            }
        }
        if self.cfg.use_group_buffer {
            for &m in &group.members {
                self.gb[m].push_back(id);
            }
        }
        if self.locks.try_lock(&group.members) {
            newly_armed.push(group.clone());
        } else {
            self.stats.conflicts += 1;
            self.pending.push_back(id);
            self.stats.max_pending = self.stats.max_pending.max(self.pending.len());
        }
        self.groups.insert(id, group);
        id
    }

    /// §4.1: a uniformly random group of `group_size` containing `w`
    /// (None when nobody is available to pair with).
    ///
    /// In rendezvous mode candidates are restricted to *idle* workers
    /// (empty GB, unlocked) for the same reason Global Division always
    /// is — see [`GgConfig::rendezvous`]. Otherwise this is the paper's
    /// unrestricted sampling, conflicts and all.
    fn random_group(&self, w: usize, rng: &mut Pcg32) -> Option<Vec<usize>> {
        let mut others: Vec<usize> = (0..self.cfg.n_workers)
            .filter(|&x| {
                x != w
                    && !self.retired[x]
                    && (!self.cfg.rendezvous
                        || (self.gb[x].is_empty() && !self.locks.is_locked(x)))
            })
            .collect();
        if others.is_empty() {
            return None;
        }
        let k = self.cfg.group_size.min(others.len() + 1);
        // partial shuffle: pick k-1 distinct others
        let mut members = vec![w];
        for i in 0..k - 1 {
            let j = i + rng.gen_range(others.len() - i);
            others.swap(i, j);
            members.push(others[i]);
        }
        Some(members)
    }

    /// §5.1/§5.2/§5.3: Global Division over the idle workers.
    ///
    /// Idle = empty GB and not locked. The slowdown filter excludes
    /// workers measured more than `s_thres` times slower than the
    /// fastest peer ([`SpeedTable`]); where no telemetry exists it falls
    /// back to the paper's progress-counter rule (within `c_thres` of
    /// the initiator). The initiator itself always participates. The
    /// measured leg is what reacts to stragglers appearing — and
    /// recovering — mid-run.
    fn global_division(&mut self, w: usize, rng: &mut Pcg32) -> Vec<Vec<usize>> {
        self.stats.divisions += 1;
        let c_i = self.counters[w];
        // hoisted: the fastest EWMA is one O(n) scan, not one per
        // candidate — over *live* workers only: a fast retired worker's
        // frozen EWMA would permanently depress every relative speed and
        // keep a recovered straggler excluded through the drain
        let speed_ref = self.speed.reference_excluding(&self.retired);
        let mut idle: Vec<usize> = (0..self.cfg.n_workers)
            .filter(|&x| {
                if x == w {
                    return true;
                }
                let buffer_free = !self.cfg.use_group_buffer || self.gb[x].is_empty();
                let lock_free = !self.locks.is_locked(x) && !self.retired[x];
                // Slowdown filter: when telemetry for `x` exists, the
                // *measured* relative speed drives the decision — it can
                // re-admit a recovered straggler, which the progress
                // counters never can (a deficit only freezes, it does not
                // shrink). The counter rule (c_i - c_x < C_thres; workers
                // ahead always pass) remains the bootstrap and the path
                // for engines that feed no telemetry.
                let measured_rel =
                    self.speed.get(x).and_then(|own| speed_ref.map(|r| own / r));
                let fast_enough = match (self.cfg.s_thres, measured_rel) {
                    (Some(thres), Some(rel)) => rel <= thres,
                    _ => match self.cfg.c_thres {
                        Some(thres) => c_i.saturating_sub(self.counters[x]) < thres,
                        None => true,
                    },
                };
                buffer_free && lock_free && fast_enough
            })
            .collect();
        if idle.len() < 2 {
            // Nobody idle to pair with: skip this sync step. Drafting a
            // *busy* worker here would deadlock collective rendezvous
            // runtimes: the busy worker waits at its own front group F
            // while the new group holds locks F needs — a circular wait
            // (found by the threaded-runtime stress test).
            return Vec::new();
        }
        if self.cfg.inter_intra {
            self.inter_intra_division(&mut idle, rng)
        } else {
            vec_partition(&mut idle, self.cfg.group_size, rng)
        }
    }

    /// §5.2 Inter-Intra Synchronization.
    ///
    /// *Inter* phase: one idle "head worker" per node; heads form
    /// inter-node groups; remaining idle workers form intra-node groups.
    /// *Intra* phase: every node's idle workers sync together locally.
    /// Each involved worker receives both groups in its GB, in order.
    ///
    /// Head selection *rotates* deterministically across divisions rather
    /// than sampling uniformly: the working set of distinct groups stays
    /// small enough for the communicator cache (§6.1) to absorb, which is
    /// essential for smart GG to beat All-Reduce — the paper's Fig. 18
    /// correspondingly shows smart GG trading away some randomness
    /// (slower per-iteration convergence than random GG).
    fn inter_intra_division(&self, idle: &mut Vec<usize>, rng: &mut Pcg32) -> Vec<Vec<usize>> {
        let wpn = self.cfg.workers_per_node.max(1);
        // bucket idle workers per node
        let mut per_node: HashMap<usize, Vec<usize>> = HashMap::new();
        for &x in idle.iter() {
            per_node.entry(x / wpn).or_default().push(x);
        }
        let mut heads = Vec::new();
        let mut locals: Vec<Vec<usize>> = Vec::new();
        let mut nodes: Vec<usize> = per_node.keys().copied().collect();
        nodes.sort_unstable();
        let rotation = self.stats.divisions as usize;
        for nd in nodes {
            let mut ws = per_node.remove(&nd).unwrap();
            ws.sort_unstable();
            // rotate the head rank across divisions (idle-filtered)
            let h = ws
                .iter()
                .position(|&w| w % wpn == rotation % wpn)
                .unwrap_or(rotation % ws.len());
            heads.push(ws.swap_remove(h));
            if !ws.is_empty() {
                locals.push(ws);
            }
        }
        let mut groups = Vec::new();
        // Inter phase: heads grouped in node order (stable chunks so the
        // communicator cache hits; see doc comment above).
        if heads.len() >= 2 {
            heads.sort_unstable();
            let mut i = 0;
            while i < heads.len() {
                let end = (i + self.cfg.group_size).min(heads.len());
                groups.push(heads[i..end].to_vec());
                i = end;
            }
            if groups.len() >= 2 && groups.last().unwrap().len() == 1 {
                let last = groups.pop().unwrap();
                groups.last_mut().unwrap().extend(last);
            }
            groups.retain(|g| g.len() >= 2);
        }
        // Non-heads: random intra-node groups.
        for mut ws in locals {
            if ws.len() >= 2 {
                groups.extend(vec_partition(&mut ws, self.cfg.group_size, rng));
            }
        }
        // Intra phase: all idle workers of each node together.
        let mut per_node2: HashMap<usize, Vec<usize>> = HashMap::new();
        for &x in idle.iter() {
            per_node2.entry(x / wpn).or_default().push(x);
        }
        let mut nodes2: Vec<usize> = per_node2.keys().copied().collect();
        nodes2.sort_unstable();
        for nd in nodes2 {
            let ws = per_node2.remove(&nd).unwrap();
            if ws.len() >= 2 {
                groups.push(ws);
            }
        }
        if groups.is_empty() {
            // e.g. a single idle worker per node and one node: degenerate
            groups.push(idle.clone());
        }
        groups
    }
}

/// Shuffle and partition `items` into chunks of ~`k` (last chunk absorbs
/// the remainder if it would be a singleton).
pub(crate) fn vec_partition(items: &mut Vec<usize>, k: usize, rng: &mut Pcg32) -> Vec<Vec<usize>> {
    rng.shuffle(items);
    let mut out: Vec<Vec<usize>> = Vec::new();
    let mut i = 0;
    while i < items.len() {
        let end = (i + k).min(items.len());
        out.push(items[i..end].to_vec());
        i = end;
    }
    // merge a trailing singleton into the previous group
    if out.len() >= 2 && out.last().unwrap().len() == 1 {
        let last = out.pop().unwrap();
        out.last_mut().unwrap().extend(last);
    }
    out.retain(|g| g.len() >= 2);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Pcg32 {
        Pcg32::new(1234)
    }

    #[test]
    fn random_gg_basic_flow_matches_fig8() {
        // Reproduce Fig. 8's scenario shape: W0 requests, gets [0,4,5]-ish
        // group; overlapping group pends; completion arms it.
        let mut gg = GroupGenerator::new(GgConfig::random(8, 4, 3));
        let mut r = rng();
        let (g1, armed1) = gg.request(0, &mut r);
        let g1 = g1.unwrap();
        assert_eq!(armed1.len(), 1);
        assert_eq!(armed1[0].id, g1);
        assert!(armed1[0].members.contains(&0));
        assert_eq!(armed1[0].members.len(), 3);

        // force a conflicting request by brute-forcing the rng until the
        // generated group overlaps (n=8, k=3: overlap is very likely)
        let mut conflicted = false;
        for w in 1..8 {
            if armed1[0].members.contains(&w) {
                continue;
            }
            let (g2, armed2) = gg.request(w, &mut r);
            let g2 = g2.unwrap();
            let overlap = gg.group(g2).unwrap().members.iter().any(|m| armed1[0].members.contains(m));
            if overlap {
                assert!(armed2.is_empty(), "conflicting group must pend");
                assert!(!gg.is_armed(g2));
                conflicted = true;
                // completing g1 must arm g2 (if no other overlap)
                let armed3 = gg.complete(g1);
                assert!(armed3.iter().any(|g| g.id == g2) || !gg.is_armed(g2));
                break;
            } else {
                assert_eq!(armed2.len(), 1);
                gg.complete(g2);
            }
        }
        assert!(conflicted || gg.stats.conflicts == 0);
    }

    #[test]
    fn random_group_contains_requester_and_distinct() {
        let mut gg = GroupGenerator::new(GgConfig::random(16, 4, 3));
        let mut r = rng();
        for w in 0..16 {
            let (id, _) = gg.request(w, &mut r);
            let id = id.unwrap();
            let g = gg.group(id).unwrap().clone();
            assert!(g.members.contains(&w));
            let mut m = g.members.clone();
            m.dedup();
            assert_eq!(m.len(), 3);
            gg.complete(id);
        }
    }

    #[test]
    fn group_buffer_reuses_scheduled_group() {
        let mut cfg = GgConfig::random(8, 4, 4);
        cfg.use_group_buffer = true;
        cfg.use_global_division = true;
        let mut gg = GroupGenerator::new(cfg);
        let mut r = rng();
        let (id0, armed) = gg.request(0, &mut r);
        let id0 = id0.unwrap();
        // GD partitioned everyone: other members of id0 should get id0 back
        let members = gg.group(id0).unwrap().members.clone();
        assert!(!armed.is_empty());
        let other = members.iter().copied().find(|&m| m != 0).unwrap();
        let (id_other, newly) = gg.request(other, &mut r);
        assert_eq!(id_other, Some(id0), "GB must return the already-scheduled group");
        assert!(newly.is_empty());
        assert!(gg.stats.buffer_hits >= 1);
    }

    #[test]
    fn buffered_random_drafts_only_idle_workers() {
        // Rendezvous safety: in rendezvous mode, random groups must
        // draft only idle workers — drafting a worker whose front group
        // is pending would create a circular wait in collective runtimes
        // (the member waits at its front group while the new group holds
        // the locks that front group needs).
        let mut cfg = GgConfig::random(6, 6, 2);
        cfg.use_group_buffer = true;
        cfg.rendezvous = true;
        let mut gg = GroupGenerator::new(cfg);
        let mut r = rng();
        for round in 0..2 {
            for w in 0..6 {
                let (gid, _) = gg.request(w, &mut r);
                if let Some(gid) = gid {
                    // anything assigned must already hold its locks
                    assert!(gg.is_armed(gid), "round {round} worker {w}");
                }
            }
            // idle-only drafting can never create a lock conflict
            assert_eq!(gg.stats.conflicts, 0, "round {round}");
            assert_eq!(gg.pending_len(), 0, "round {round}");
        }
    }

    #[test]
    fn global_division_groups_are_disjoint() {
        let mut cfg = GgConfig::smart(16, 4, 3, 1_000_000);
        cfg.inter_intra = false; // plain GD
        let mut gg = GroupGenerator::new(cfg);
        let mut r = rng();
        let (_, armed) = gg.request(0, &mut r);
        let mut seen = vec![false; 16];
        for g in &armed {
            for &m in &g.members {
                assert!(!seen[m], "worker {m} in two GD groups");
                seen[m] = true;
            }
        }
        // all GD groups must arm instantly (they're disjoint by design)
        assert_eq!(gg.stats.conflicts, 0);
        assert_eq!(gg.pending_len(), 0);
    }

    #[test]
    fn inter_intra_structure() {
        let mut gg = GroupGenerator::new(GgConfig::smart(16, 4, 3, 1_000_000));
        let mut r = rng();
        let (_, armed) = gg.request(0, &mut r);
        // Phase-1 groups (armed immediately): at most one inter-node group
        // set (heads) + intra-node groups. Every armed group is either
        // all-same-node or composed of distinct nodes (heads).
        assert!(!armed.is_empty());
        let wpn = 4;
        let mut inter_seen = 0;
        for g in &armed {
            let nodes: Vec<usize> = g.members.iter().map(|&m| m / wpn).collect();
            let same_node = nodes.windows(2).all(|p| p[0] == p[1]);
            if !same_node {
                // heads group: all members on distinct nodes
                let mut uniq = nodes.clone();
                uniq.sort_unstable();
                uniq.dedup();
                assert_eq!(uniq.len(), g.members.len(), "head group {g:?}");
                inter_seen += 1;
            }
        }
        assert!(inter_seen >= 1, "expected at least one inter-node head group");
        // Each worker's GB should now hold 2 entries (inter + intra phases)
        let gb_sizes: Vec<usize> = (0..16).map(|w| gg.gb[w].len()).collect();
        assert!(gb_sizes.iter().filter(|&&s| s == 2).count() >= 8, "{gb_sizes:?}");
    }

    #[test]
    fn slowdown_filter_excludes_laggards() {
        let mut cfg = GgConfig::smart(8, 4, 2, 3);
        cfg.inter_intra = false;
        let mut gg = GroupGenerator::new(cfg);
        let mut r = rng();
        // advance counters: worker 7 lags far behind
        for _ in 0..10 {
            for w in 0..7 {
                let (id, _) = gg.request(w, &mut r);
                // drain: complete whatever is armed
                while gg.live_groups() > 0 {
                    let ids: Vec<GroupId> = gg.groups.keys().copied().collect();
                    for gid in ids {
                        if gg.is_armed(gid) {
                            gg.complete(gid);
                        }
                    }
                }
                let _ = id;
            }
        }
        // now a fast worker's division must exclude worker 7
        let (_, armed) = gg.request(0, &mut r);
        for g in &armed {
            assert!(!g.members.contains(&7), "laggard drafted into {g:?}");
        }
        // but when the laggard itself requests, it still gets a group
        let ids: Vec<GroupId> = gg.groups.keys().copied().collect();
        for gid in ids {
            if gg.is_armed(gid) {
                gg.complete(gid);
            }
        }
        let (id7, _) = gg.request(7, &mut r);
        assert!(gg.group(id7.unwrap()).unwrap().members.contains(&7));
    }

    #[test]
    fn speed_table_ewma_and_relative() {
        let mut t = SpeedTable::new(3, 0.5);
        assert_eq!(t.get(0), None);
        assert_eq!(t.relative(0), None);
        t.observe(0, 0.010);
        assert_eq!(t.get(0), Some(0.010)); // first sample seeds the EWMA
        t.observe(0, 0.030);
        assert!((t.get(0).unwrap() - 0.020).abs() < 1e-12);
        t.observe(1, f64::NAN); // garbage ignored
        t.observe(1, -1.0);
        assert_eq!(t.get(1), None);
        t.report(2, 0.040);
        assert!((t.relative(2).unwrap() - 2.0).abs() < 1e-12);
        assert_eq!(t.snapshot(), vec![0.020, 0.0, 0.040]);
    }

    #[test]
    fn speed_table_first_sample_seeds_at_full_value() {
        // Pins the first-sample seed: an `unwrap_or(0.0)` seed folded
        // through a plain `α·sample + (1−α)·prev` would land the first
        // observation at α·sample (4x too fast at α=0.25), making every
        // *other* worker look like a >=1/α straggler relative to the
        // newcomer. (Historically latent — `ewma_step`'s `prev <= 0`
        // guard masked it; the seed is now explicit in `observe`.)
        let mut t = SpeedTable::new(2, 0.25);
        t.observe(0, 0.040);
        assert_eq!(t.get(0), Some(0.040), "first raw sample must land unscaled");
        // healthy peer at a comparable speed: with a correct seed its
        // relative factor is ~1.25, far under the filter threshold; the
        // alpha-scaled seed (0.010) would have put it at 5.0
        t.report(1, 0.050);
        let rel = t.relative(1).unwrap();
        assert!(
            rel < DEFAULT_S_THRES,
            "healthy peer misclassified at {rel}x after a first-sample seed"
        );
        // subsequent samples fold normally
        t.observe(0, 0.080);
        assert!((t.get(0).unwrap() - (0.25 * 0.080 + 0.75 * 0.040)).abs() < 1e-12);
    }

    #[test]
    fn measured_filter_excludes_and_readmits() {
        // plain GD, counter filter off: only the measured filter acts
        let mut cfg = GgConfig::smart(8, 4, 2, 1_000_000);
        cfg.inter_intra = false;
        let mut gg = GroupGenerator::new(cfg);
        let mut r = rng();
        for w in 0..8 {
            gg.report_speed(w, 0.010);
        }
        // worker 5 turns into a 3x straggler: raw observations converge
        // onto the EWMA within ~1/alpha steps
        for _ in 0..16 {
            gg.observe_speed(5, 0.030);
        }
        assert!(gg.relative_speed(5).unwrap() > DEFAULT_S_THRES);
        let (_, armed) = gg.request(0, &mut r);
        assert!(!armed.is_empty());
        for g in &armed {
            assert!(!g.members.contains(&5), "measured straggler drafted: {g:?}");
        }
        for g in armed {
            gg.complete(g.id);
        }
        assert_eq!(gg.drafts()[5], 0, "straggler must not be drafted by others");
        // the straggler itself still gets a group when *it* requests
        let (id5, armed5) = gg.request(5, &mut r);
        assert!(gg.group(id5.unwrap()).unwrap().members.contains(&5));
        for g in armed5 {
            gg.complete(g.id);
        }
        // recovery: fast steps pull the EWMA back under the threshold,
        // and the worker is drafted again (the counter filter could not
        // do this — a progress deficit never shrinks)
        for _ in 0..16 {
            gg.observe_speed(5, 0.010);
        }
        assert!(gg.relative_speed(5).unwrap() < DEFAULT_S_THRES);
        let (_, armed) = gg.request(0, &mut r);
        let drafted: Vec<usize> = armed.iter().flat_map(|g| g.members.clone()).collect();
        assert!(drafted.contains(&5), "recovered worker not re-admitted: {drafted:?}");
        assert!(gg.drafts()[5] >= 1);
        assert_eq!(gg.last_drafted()[5], gg.stats.requests);
    }

    #[test]
    fn unknown_speeds_pass_the_measured_filter() {
        let mut cfg = GgConfig::smart(8, 4, 2, 1_000_000);
        cfg.inter_intra = false;
        let mut gg = GroupGenerator::new(cfg);
        let mut r = rng();
        // nobody has reported anything: GD must still draft everyone
        let (_, armed) = gg.request(0, &mut r);
        let drafted: usize = armed.iter().map(|g| g.members.len()).sum();
        assert_eq!(drafted, 8, "bootstrap division must cover all workers");
    }

    #[test]
    fn complete_releases_and_arms_fifo() {
        let mut gg = GroupGenerator::new(GgConfig::random(4, 4, 2));
        // Hand-roll groups to control membership.
        let mut armed = Vec::new();
        let a = gg.create_group(0, vec![0, 1], &mut armed);
        let b = gg.create_group(1, vec![1, 2], &mut armed); // conflicts with a
        let c = gg.create_group(2, vec![2, 3], &mut armed); // conflicts with b? no: 2,3 free? 2 is free (b pending) -> arms
        assert!(gg.is_armed(a));
        assert!(!gg.is_armed(b));
        assert!(gg.is_armed(c));
        assert_eq!(gg.stats.conflicts, 1);
        // completing a frees worker 1, but b needs 2 (held by c): stays pending
        let newly = gg.complete(a);
        assert!(newly.is_empty());
        assert!(!gg.is_armed(b));
        // completing c frees 2: b arms
        let newly = gg.complete(c);
        assert_eq!(newly.len(), 1);
        assert_eq!(newly[0].id, b);
        gg.complete(b);
        assert_eq!(gg.live_groups(), 0);
        assert_eq!(gg.locks.locked_count(), 0);
    }

    #[test]
    fn complete_is_idempotent_on_unknown_ids() {
        // Regression: a duplicate/retried leader Complete used to panic
        // ("completing unknown group") and take down the control plane.
        let mut gg = GroupGenerator::new(GgConfig::random(4, 4, 2));
        assert!(gg.complete(999).is_empty(), "unknown id must be a no-op");
        let mut armed = Vec::new();
        let a = gg.create_group(0, vec![0, 1], &mut armed);
        let b = gg.create_group(1, vec![1, 2], &mut armed); // pends behind a
        let first = gg.complete(a);
        assert!(first.iter().any(|g| g.id == b), "completion must arm b");
        // the retried duplicate: no panic, no lock corruption, nothing new
        assert!(gg.complete(a).is_empty());
        assert!(gg.is_armed(b), "duplicate complete must not disturb b");
        gg.complete(b);
        assert_eq!(gg.live_groups(), 0);
        assert_eq!(gg.locks.locked_count(), 0);
        assert!(gg.complete(b).is_empty(), "re-complete after drain is a no-op");
    }

    #[test]
    fn retired_fast_worker_does_not_suppress_reference() {
        // Regression: SpeedTable::reference took the min over ALL workers
        // including retired ones, so a fast retired worker kept everyone
        // else's relative() above s_thres and a recovered straggler
        // excluded during drain.
        let mut cfg = GgConfig::smart(4, 4, 2, 1_000_000);
        cfg.inter_intra = false;
        let mut gg = GroupGenerator::new(cfg);
        let mut r = rng();
        gg.report_speed(0, 0.005); // very fast
        for w in 1..4 {
            gg.report_speed(w, 0.012); // 2.4x the fast worker: over 1.5x
        }
        // with worker 0 live, the others are all filtered relative to it
        assert!(gg.relative_speed(1).unwrap() > DEFAULT_S_THRES);
        gg.retire(0);
        // the reference must now be the fastest LIVE worker: everyone
        // measures 1.0x and Global Division drafts all three survivors
        for w in 1..4 {
            assert!(
                (gg.relative_speed(w).unwrap() - 1.0).abs() < 1e-9,
                "worker {w} still judged against the retired reference"
            );
        }
        let (_, armed) = gg.request(1, &mut r);
        let drafted: usize = armed.iter().map(|g| g.members.len()).sum();
        assert_eq!(drafted, 3, "drain division must cover all live workers: {armed:?}");
    }

    #[test]
    fn declare_dead_aborts_armed_and_pending_groups() {
        let mut gg = GroupGenerator::new(GgConfig::random(6, 6, 2));
        let mut armed = Vec::new();
        let a = gg.create_group(0, vec![0, 1], &mut armed); // arms
        let b = gg.create_group(1, vec![1, 2], &mut armed); // pends behind a
        let c = gg.create_group(2, vec![2, 3], &mut armed); // arms
        assert!(gg.is_armed(a) && !gg.is_armed(b) && gg.is_armed(c));
        let purge = gg.declare_dead(1);
        // both groups naming rank 1 die; c survives untouched
        let mut dead_ids: Vec<GroupId> = purge.aborted.iter().map(|g| g.id).collect();
        dead_ids.sort_unstable();
        assert_eq!(dead_ids, vec![a, b]);
        assert!(gg.was_aborted(a) && gg.was_aborted(b) && !gg.was_aborted(c));
        assert!(gg.group(a).is_none() && gg.group(b).is_none());
        assert!(gg.is_armed(c));
        // rank 1 holds no locks and appears in no live group
        assert!(!gg.is_locked_worker(1));
        assert!(gg.is_dead(1) && gg.is_retired(1));
        for id in gg.live_group_ids() {
            assert!(!gg.group(id).unwrap().members.contains(&1));
        }
        // worker 0 came free: nothing pended on it, but its lock is gone
        assert!(!gg.is_locked_worker(0));
        assert_eq!(gg.stats.deaths, 1);
        assert_eq!(gg.stats.groups_aborted, 2);
        // idempotent
        assert!(gg.declare_dead(1).aborted.is_empty());
        assert_eq!(gg.stats.deaths, 1);
    }

    #[test]
    fn declare_dead_arms_groups_blocked_by_the_dead_rank() {
        let mut gg = GroupGenerator::new(GgConfig::random(4, 4, 2));
        let mut armed = Vec::new();
        let a = gg.create_group(0, vec![0, 1], &mut armed); // arms, holds 0&1
        let b = gg.create_group(2, vec![1, 2], &mut armed); // pends behind a
        assert!(!gg.is_armed(b));
        let purge = gg.declare_dead(0);
        assert_eq!(purge.aborted.len(), 1);
        assert_eq!(purge.aborted[0].id, a);
        // releasing the dead rank's group frees worker 1: b arms
        assert_eq!(purge.newly_armed.len(), 1);
        assert_eq!(purge.newly_armed[0].id, b);
        assert!(gg.is_armed(b));
        // and the newly armed group must not name the dead rank
        assert!(!purge.newly_armed[0].members.contains(&0));
    }

    #[test]
    fn dead_worker_is_never_drafted_and_speed_is_purged() {
        let mut cfg = GgConfig::smart(4, 4, 2, 8);
        cfg.inter_intra = false;
        let mut gg = GroupGenerator::new(cfg);
        let mut r = rng();
        for w in 0..4 {
            gg.report_speed(w, 0.010);
        }
        gg.declare_dead(3);
        assert_eq!(gg.speed_table().get(3), None, "speed entry must be purged");
        assert_eq!(gg.speed_table().snapshot()[3], 0.0);
        let (_, armed) = gg.request(0, &mut r);
        for g in &armed {
            assert!(!g.members.contains(&3), "dead rank drafted: {g:?}");
        }
        // a zombie Sync from the dead rank is a skip, not a crash
        for g in armed {
            gg.complete(g.id);
        }
        let (assigned, newly) = gg.request(3, &mut r);
        assert!(assigned.is_none() && newly.is_empty());
    }

    #[test]
    fn abort_group_purges_buffers_and_arms_blocked() {
        let mut cfg = GgConfig::random(4, 4, 2);
        cfg.use_group_buffer = true;
        let mut gg = GroupGenerator::new(cfg);
        let mut armed = Vec::new();
        let a = gg.create_group(0, vec![0, 1], &mut armed);
        let b = gg.create_group(2, vec![1, 2], &mut armed); // pends
        assert_eq!(gg.gb_snapshot(1), vec![a, b]);
        // aborting the pending group releases nothing but purges GBs
        assert!(gg.abort_group(b).is_empty());
        assert_eq!(gg.gb_snapshot(1), vec![a]);
        assert_eq!(gg.gb_snapshot(2), Vec::<GroupId>::new());
        // aborting the armed group releases 0 and 1
        assert!(gg.abort_group(a).is_empty());
        assert_eq!(gg.locked_count(), 0);
        assert_eq!(gg.live_groups(), 0);
        assert!(gg.was_aborted(a) && gg.was_aborted(b));
        assert_eq!(gg.stats.groups_aborted, 2);
        // idempotent on unknown/already-aborted ids
        assert!(gg.abort_group(a).is_empty());
        assert_eq!(gg.stats.groups_aborted, 2);
        // completed groups are NOT "aborted"
        let mut armed = Vec::new();
        let c = gg.create_group(0, vec![0, 1], &mut armed);
        gg.complete(c);
        assert!(!gg.was_aborted(c));
    }

    #[test]
    fn rejoin_readmits_a_dead_rank() {
        let mut cfg = GgConfig::smart(4, 4, 2, 2);
        cfg.inter_intra = false;
        let mut gg = GroupGenerator::new(cfg);
        let mut r = rng();
        // build a progress gap, then kill worker 3
        for _ in 0..6 {
            for w in 0..3 {
                let (_, armed) = gg.request(w, &mut r);
                for g in armed {
                    gg.complete(g.id);
                }
                while let Some(front) = gg.gb_front(w) {
                    if gg.is_armed(front) {
                        gg.complete(front);
                    } else {
                        break;
                    }
                }
            }
        }
        gg.declare_dead(3);
        let (_, armed) = gg.request(0, &mut r);
        for g in &armed {
            assert!(!g.members.contains(&3));
        }
        for g in armed {
            gg.complete(g.id);
        }
        // rejoin: drafted again despite the frozen counter deficit
        gg.rejoin(3);
        assert!(!gg.is_dead(3) && !gg.is_retired(3));
        assert!(
            gg.counters()[3] >= gg.counters()[0],
            "rejoiner's counter must catch up: {:?}",
            gg.counters()
        );
        assert_eq!(gg.stats.rejoins, 1);
        let (_, armed) = gg.request(0, &mut r);
        let drafted: Vec<usize> = armed.iter().flat_map(|g| g.members.clone()).collect();
        assert!(drafted.contains(&3), "rejoined rank not drafted: {drafted:?}");
    }

    #[test]
    fn rejoin_of_a_live_rank_purges_its_stale_groups_first() {
        // fast restart: the old incarnation's death was never declared
        let mut gg = GroupGenerator::new(GgConfig::random(4, 4, 2));
        let mut armed = Vec::new();
        let a = gg.create_group(0, vec![0, 1], &mut armed);
        let purge = gg.rejoin(0);
        assert_eq!(purge.aborted.len(), 1);
        assert_eq!(purge.aborted[0].id, a);
        assert!(!gg.is_dead(0) && !gg.is_retired(0));
        assert_eq!(gg.locked_count(), 0);
        assert_eq!(gg.stats.deaths, 1, "the old incarnation counts as a death");
        assert_eq!(gg.stats.rejoins, 1);
    }

    #[test]
    fn vec_partition_covers_all_no_singletons() {
        let mut r = rng();
        for n in 2..40usize {
            for k in 2..6usize {
                let mut items: Vec<usize> = (0..n).collect();
                let parts = vec_partition(&mut items, k, &mut r);
                let mut all: Vec<usize> = parts.iter().flatten().copied().collect();
                all.sort_unstable();
                assert_eq!(all, (0..n).collect::<Vec<_>>(), "n={n} k={k}");
                assert!(parts.iter().all(|p| p.len() >= 2), "n={n} k={k}: {parts:?}");
            }
        }
    }
}
