//! Scale-out Group Generator: the same state machine as
//! [`GroupGenerator`](crate::gg::GroupGenerator), with the hot state
//! sharded so concurrent Sync/Wait/Heartbeat RPCs stop serializing on
//! one lock (DESIGN.md §Scale).
//!
//! The single-lock GG wraps *everything* — SpeedTable, Group Buffer,
//! LockVector, group table, stats — in one `Mutex`, so at p = 400+ every
//! heartbeat queues behind every division. [`ShardedGg`] splits that
//! state by how it is actually accessed:
//!
//! * **per-rank atomic cells** — progress counters, draft counters,
//!   speed EWMAs (f64 bits; 0 = no measurement), retired/dead flags.
//!   Speed reports and filter reads never take a lock.
//! * **per-rank Group Buffers** — one small mutex per rank; a buffer-hit
//!   `Sync` (the common case under the smart GG) touches only its own
//!   rank's buffer.
//! * **group table + aborted-id set sharded by group id** — `Probe` and
//!   parked `Wait`s read one shard, never the scheduler.
//! * **an atomic LockVector** ([`lockvec::AtomicLockVector`]) — lock-free
//!   readers; writers are serialized by the scheduler core below, so
//!   acquire/release touches only the words covering the group's ranks.
//! * **one small `sched` mutex** — the only serialized path: fresh
//!   division generation (which must see a stable idle view and owns the
//!   RNG), group creation, completion's release-then-arm sweep, and
//!   death/abort teardown. Holding try_lock + pending-push and
//!   release + arm-sweep under the same lock is what prevents the
//!   lost-wakeup race (a group pends just as its blocker's completion
//!   finishes sweeping) and the rendezvous double-draft race (two
//!   concurrent divisions both drafting one idle rank into conflicting
//!   fresh groups, a circular wait).
//!
//! Sequential equivalence: driven single-threaded with the same seed,
//! `ShardedGg` produces *bit-identical* assignments, armed lists, and
//! stats to `GroupGenerator` — the single-lock path stays behind a flag
//! as the differential-testing oracle (`rust/tests/prop_gg.rs`), and the
//! concurrent stress suite (`rust/tests/stress_gg.rs`) checks the
//! paper's invariants under real thread interleavings.

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::util::rng::Pcg32;

use super::lockvec::AtomicLockVector;
use super::{ewma_step, vec_partition, DeathPurge, GgConfig, GgStats, Group, GroupId};

/// Group-table shard count: gid-keyed state (`groups`, `aborted`) is
/// split `gid % GROUP_SHARDS` ways so Probe/Wait readers of different
/// groups do not contend. Ids are assigned sequentially, so consecutive
/// groups land on distinct shards.
const GROUP_SHARDS: usize = 16;

/// One live group's entry in the sharded table. `armed` mirrors "not in
/// the pending queue" — kept here, under the gid shard, so state probes
/// never need the scheduler lock.
#[derive(Debug)]
struct Entry {
    members: Vec<usize>,
    armed: bool,
}

/// The serialized scheduler core: fresh-division RNG, the FIFO pending
/// queue, and the id allocator. Everything else is sharded around it.
#[derive(Debug)]
struct Sched {
    rng: Pcg32,
    pending: VecDeque<GroupId>,
    next_id: GroupId,
}

/// Per-rank speed telemetry on atomic f64 bits (0 bits = no measurement;
/// stored samples are validated `> 0.0 && finite`, whose bit patterns are
/// never zero). Same observe/report/reference semantics as
/// [`SpeedTable`](crate::gg::SpeedTable); concurrent `observe` folds are
/// last-writer-wins, which is fine for a smoothed heuristic input.
#[derive(Debug)]
struct AtomicSpeed {
    bits: Vec<AtomicU64>,
    alpha: f64,
}

impl AtomicSpeed {
    fn new(n: usize, alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0, "bad EWMA alpha {alpha}");
        Self { bits: (0..n).map(|_| AtomicU64::new(0)).collect(), alpha }
    }

    fn get(&self, w: usize) -> Option<f64> {
        let b = self.bits[w].load(Ordering::Acquire);
        if b == 0 {
            None
        } else {
            Some(f64::from_bits(b))
        }
    }

    fn observe(&self, w: usize, step_secs: f64) {
        if !(step_secs > 0.0 && step_secs.is_finite()) {
            return; // ignore garbage samples
        }
        let next = match self.get(w) {
            Some(prev) => ewma_step(prev, step_secs, self.alpha),
            None => step_secs,
        };
        self.bits[w].store(next.to_bits(), Ordering::Release);
    }

    fn report(&self, w: usize, ewma_secs: f64) {
        if ewma_secs > 0.0 && ewma_secs.is_finite() {
            self.bits[w].store(ewma_secs.to_bits(), Ordering::Release);
        }
    }

    fn clear(&self, w: usize) {
        self.bits[w].store(0, Ordering::Release);
    }

    fn reference_excluding(&self, skip: &[bool]) -> Option<f64> {
        (0..self.bits.len())
            .filter(|&w| !skip.get(w).copied().unwrap_or(false))
            .filter_map(|w| self.get(w))
            .fold(None, |acc: Option<f64>, v| Some(acc.map_or(v, |a| a.min(v))))
    }

    fn snapshot(&self) -> Vec<f64> {
        (0..self.bits.len()).map(|w| self.get(w).unwrap_or(0.0)).collect()
    }
}

/// [`GgStats`] on atomic counters (relaxed: they are telemetry, and the
/// scheduler-ordered ones are updated under the sched lock anyway).
#[derive(Debug, Default)]
struct AtomicStats {
    requests: AtomicU64,
    groups_created: AtomicU64,
    conflicts: AtomicU64,
    divisions: AtomicU64,
    buffer_hits: AtomicU64,
    max_pending: AtomicUsize,
    deaths: AtomicU64,
    groups_aborted: AtomicU64,
    rejoins: AtomicU64,
}

impl AtomicStats {
    fn snapshot(&self) -> GgStats {
        GgStats {
            requests: self.requests.load(Ordering::Relaxed),
            groups_created: self.groups_created.load(Ordering::Relaxed),
            conflicts: self.conflicts.load(Ordering::Relaxed),
            divisions: self.divisions.load(Ordering::Relaxed),
            buffer_hits: self.buffer_hits.load(Ordering::Relaxed),
            max_pending: self.max_pending.load(Ordering::Relaxed),
            deaths: self.deaths.load(Ordering::Relaxed),
            groups_aborted: self.groups_aborted.load(Ordering::Relaxed),
            rejoins: self.rejoins.load(Ordering::Relaxed),
        }
    }
}

/// Where a group id stands right now — the sharded analogue of the RPC
/// layer's Pending/Armed/Done/Aborted probe, computed from one gid shard
/// plus the aborted set (never the scheduler lock).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GroupPhase {
    Pending,
    Armed,
    Done,
    Aborted,
}

/// What [`ShardedGg::try_complete`] found: the armed-check and the
/// completion happen under one scheduler hold, so a concurrent Complete
/// race cannot slip between "is it armed?" and "complete it".
#[derive(Debug)]
pub enum CompleteOutcome {
    /// The group completed; these pending groups armed as a result.
    Done(Vec<Group>),
    /// The id is live but still pending — completing it is a protocol
    /// error (it holds no locks to release).
    NotArmed,
    /// Unknown id: already completed or aborted (idempotent duplicate).
    Unknown,
}

/// The sharded Group Generator. All methods take `&self`; see the module
/// docs for the sharding map and the serialization contract.
#[derive(Debug)]
pub struct ShardedGg {
    cfg: GgConfig,
    locks: AtomicLockVector,
    gb: Vec<Mutex<VecDeque<GroupId>>>,
    groups: Vec<Mutex<HashMap<GroupId, Entry>>>,
    aborted: Vec<Mutex<HashSet<GroupId>>>,
    counters: Vec<AtomicU64>,
    speed: AtomicSpeed,
    drafts: Vec<AtomicU64>,
    last_drafted: Vec<AtomicU64>,
    retired: Vec<AtomicBool>,
    dead: Vec<AtomicBool>,
    sched: Mutex<Sched>,
    stats: AtomicStats,
    /// Bumped after every operation that can change a group's phase;
    /// the RPC reactor re-evaluates parked Wait RPCs when it moves.
    epoch: AtomicU64,
}

impl ShardedGg {
    /// `seed` seeds the internal division RNG — drive a
    /// [`GroupGenerator`](crate::gg::GroupGenerator) with
    /// `Pcg32::new(seed)` for the differential oracle.
    pub fn new(cfg: GgConfig, seed: u64) -> Self {
        assert!(cfg.group_size >= 2 && cfg.group_size <= cfg.n_workers);
        let n = cfg.n_workers;
        let alpha = cfg.speed_alpha;
        Self {
            cfg,
            locks: AtomicLockVector::new(n),
            gb: (0..n).map(|_| Mutex::new(VecDeque::new())).collect(),
            groups: (0..GROUP_SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            aborted: (0..GROUP_SHARDS).map(|_| Mutex::new(HashSet::new())).collect(),
            counters: (0..n).map(|_| AtomicU64::new(0)).collect(),
            speed: AtomicSpeed::new(n, alpha),
            drafts: (0..n).map(|_| AtomicU64::new(0)).collect(),
            last_drafted: (0..n).map(|_| AtomicU64::new(0)).collect(),
            retired: (0..n).map(|_| AtomicBool::new(false)).collect(),
            dead: (0..n).map(|_| AtomicBool::new(false)).collect(),
            sched: Mutex::new(Sched {
                rng: Pcg32::new(seed),
                pending: VecDeque::new(),
                next_id: 1,
            }),
            stats: AtomicStats::default(),
            epoch: AtomicU64::new(0),
        }
    }

    #[inline]
    fn shard(&self, id: GroupId) -> &Mutex<HashMap<GroupId, Entry>> {
        &self.groups[(id % GROUP_SHARDS as u64) as usize]
    }

    #[inline]
    fn aborted_shard(&self, id: GroupId) -> &Mutex<HashSet<GroupId>> {
        &self.aborted[(id % GROUP_SHARDS as u64) as usize]
    }

    fn bump_epoch(&self) {
        self.epoch.fetch_add(1, Ordering::Release);
    }

    /// Monotone change counter for group phases (see field docs).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    pub fn config(&self) -> &GgConfig {
        &self.cfg
    }

    pub fn stats(&self) -> GgStats {
        self.stats.snapshot()
    }

    pub fn group(&self, id: GroupId) -> Option<Group> {
        let shard = self.shard(id).lock().unwrap();
        shard.get(&id).map(|e| Group { id, members: e.members.clone() })
    }

    pub fn counters(&self) -> Vec<u64> {
        self.counters.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    pub fn observe_speed(&self, w: usize, step_secs: f64) {
        self.speed.observe(w, step_secs);
    }

    pub fn report_speed(&self, w: usize, ewma_secs: f64) {
        self.speed.report(w, ewma_secs);
    }

    /// All EWMAs, 0.0 where nothing was measured (wire-friendly; same
    /// shape as `SpeedTable::snapshot`).
    pub fn speed_snapshot(&self) -> Vec<f64> {
        self.speed.snapshot()
    }

    pub fn relative_speed(&self, w: usize) -> Option<f64> {
        let retired = self.retired_mask();
        Some(self.speed.get(w)? / self.speed.reference_excluding(&retired)?)
    }

    pub fn drafts(&self) -> Vec<u64> {
        self.drafts.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    pub fn last_drafted(&self) -> Vec<u64> {
        self.last_drafted.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    pub fn pending_len(&self) -> usize {
        self.sched.lock().unwrap().pending.len()
    }

    pub fn live_groups(&self) -> usize {
        self.groups.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn live_group_ids(&self) -> Vec<GroupId> {
        self.groups
            .iter()
            .flat_map(|s| s.lock().unwrap().keys().copied().collect::<Vec<_>>())
            .collect()
    }

    pub fn gb_front(&self, w: usize) -> Option<GroupId> {
        self.gb[w].lock().unwrap().front().copied()
    }

    pub fn gb_snapshot(&self, w: usize) -> Vec<GroupId> {
        self.gb[w].lock().unwrap().iter().copied().collect()
    }

    pub fn retire(&self, w: usize) {
        self.retired[w].store(true, Ordering::Release);
    }

    pub fn is_retired(&self, w: usize) -> bool {
        self.retired[w].load(Ordering::Acquire)
    }

    pub fn is_dead(&self, w: usize) -> bool {
        self.dead[w].load(Ordering::Acquire)
    }

    pub fn was_aborted(&self, id: GroupId) -> bool {
        self.aborted_shard(id).lock().unwrap().contains(&id)
    }

    pub fn is_locked_worker(&self, w: usize) -> bool {
        self.locks.is_locked(w)
    }

    pub fn locked_count(&self) -> usize {
        self.locks.locked_count()
    }

    pub fn is_armed(&self, id: GroupId) -> bool {
        self.shard(id).lock().unwrap().get(&id).is_some_and(|e| e.armed)
    }

    /// One-shot phase probe: a single gid-shard read (plus the aborted
    /// set for dead ids) — what the RPC reactor evaluates for parked
    /// WaitArmed/WaitDone and Probe calls.
    pub fn phase(&self, id: GroupId) -> GroupPhase {
        let armed = self.shard(id).lock().unwrap().get(&id).map(|e| e.armed);
        match armed {
            Some(true) => GroupPhase::Armed,
            Some(false) => GroupPhase::Pending,
            None if self.was_aborted(id) => GroupPhase::Aborted,
            None => GroupPhase::Done,
        }
    }

    fn retired_mask(&self) -> Vec<bool> {
        self.retired.iter().map(|r| r.load(Ordering::Acquire)).collect()
    }

    // ------------------------------------------------------------------
    // the worker protocol
    // ------------------------------------------------------------------

    /// Worker `w` requests synchronization. Same contract and — under
    /// sequential driving with the same seed — same results and stats as
    /// `GroupGenerator::request`. Buffer hits return without touching the
    /// scheduler lock.
    pub fn request(&self, w: usize) -> (Option<GroupId>, Vec<Group>) {
        assert!(w < self.cfg.n_workers);
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        self.counters[w].fetch_add(1, Ordering::Relaxed);

        if self.cfg.use_group_buffer {
            if let Some(front) = self.gb_front(w) {
                self.stats.buffer_hits.fetch_add(1, Ordering::Relaxed);
                return (Some(front), Vec::new());
            }
        }
        if self.retired[w].load(Ordering::Acquire) {
            return (None, Vec::new()); // drained and departed
        }

        let mut sched = self.sched.lock().unwrap();
        // A concurrent division may have drafted `w` between the lock-free
        // buffer check and here: answer with the buffered group, exactly
        // as a later sequential request would. Generating a *fresh* group
        // instead would leave `w` syncing on it while its buffer-front
        // group waits for `w` — a circular wait in rendezvous runtimes.
        // (Unreachable sequentially, so the oracle equivalence holds.)
        if self.cfg.use_group_buffer {
            if let Some(front) = self.gb_front(w) {
                self.stats.buffer_hits.fetch_add(1, Ordering::Relaxed);
                return (Some(front), Vec::new());
            }
        }

        let member_lists = if self.cfg.use_global_division || self.cfg.inter_intra {
            self.global_division(w, &mut sched)
        } else {
            match self.random_group(w, &mut sched) {
                Some(g) => vec![g],
                None => Vec::new(),
            }
        };
        if member_lists.is_empty() {
            return (None, Vec::new()); // nobody left to pair with
        }

        let mut newly_armed = Vec::new();
        let mut assigned = None;
        for members in member_lists {
            let contains_w = members.contains(&w);
            let id = self.create_group(w, members, &mut newly_armed, &mut sched);
            if contains_w && assigned.is_none() {
                assigned = Some(id);
            }
        }
        drop(sched);
        self.bump_epoch();
        (assigned, newly_armed)
    }

    /// Armed-checked completion under one scheduler hold (see
    /// [`CompleteOutcome`]).
    pub fn try_complete(&self, id: GroupId) -> CompleteOutcome {
        let mut sched = self.sched.lock().unwrap();
        let entry = {
            let mut shard = self.shard(id).lock().unwrap();
            match shard.get(&id) {
                None => return CompleteOutcome::Unknown,
                Some(e) if !e.armed => return CompleteOutcome::NotArmed,
                Some(_) => shard.remove(&id).unwrap(),
            }
        };
        self.locks.release(&entry.members);
        if self.cfg.use_group_buffer {
            for &m in &entry.members {
                let mut gb = self.gb[m].lock().unwrap();
                // Completion should be at the front of each member's GB
                // (groups arm in creation order); fall back to a purge.
                if gb.front() == Some(&id) {
                    gb.pop_front();
                } else {
                    gb.retain(|&g| g != id);
                }
            }
        }
        let armed = self.arm_unblocked(&entry.members, &mut sched);
        drop(sched);
        self.bump_epoch();
        CompleteOutcome::Done(armed)
    }

    /// Oracle-shaped completion: unknown ids are an idempotent no-op,
    /// and completing a *pending* id is a protocol bug (the single-lock
    /// GG would corrupt its lock vector; here it panics loudly instead).
    pub fn complete(&self, id: GroupId) -> Vec<Group> {
        match self.try_complete(id) {
            CompleteOutcome::Done(armed) => armed,
            CompleteOutcome::Unknown => Vec::new(),
            CompleteOutcome::NotArmed => {
                panic!("complete() on pending group {id} (protocol bug)")
            }
        }
    }

    /// Tear one group down without completing it; arm whatever its locks
    /// were blocking. Idempotent on unknown ids.
    pub fn abort_group(&self, id: GroupId) -> Vec<Group> {
        let mut sched = self.sched.lock().unwrap();
        let armed = match self.teardown_group(id, &mut sched) {
            Some((group, true)) => self.arm_unblocked(&group.members, &mut sched),
            _ => Vec::new(),
        };
        drop(sched);
        self.bump_epoch();
        armed
    }

    /// Failure detection verdict: `w` crashed. Same semantics as the
    /// single-lock `declare_dead` (retire + speed purge + abort every
    /// group naming the rank + one arm sweep + lock-bit guard sweep).
    pub fn declare_dead(&self, w: usize) -> DeathPurge {
        let mut sched = self.sched.lock().unwrap();
        let purge = self.declare_dead_locked(w, &mut sched);
        drop(sched);
        self.bump_epoch();
        purge
    }

    /// A checkpoint-restored replacement re-registers rank `w`.
    pub fn rejoin(&self, w: usize) -> DeathPurge {
        let mut sched = self.sched.lock().unwrap();
        let purge = self.declare_dead_locked(w, &mut sched);
        self.dead[w].store(false, Ordering::Release);
        self.retired[w].store(false, Ordering::Release);
        self.speed.clear(w);
        let caught_up = (0..self.cfg.n_workers)
            .filter(|&x| x != w && !self.retired[x].load(Ordering::Acquire))
            .map(|x| self.counters[x].load(Ordering::Relaxed))
            .max()
            .unwrap_or(0);
        self.counters[w].fetch_max(caught_up, Ordering::Relaxed);
        self.stats.rejoins.fetch_add(1, Ordering::Relaxed);
        drop(sched);
        self.bump_epoch();
        purge
    }

    // ------------------------------------------------------------------
    // internals (all hold the sched lock)
    // ------------------------------------------------------------------

    fn declare_dead_locked(&self, w: usize, sched: &mut Sched) -> DeathPurge {
        if self.dead[w].load(Ordering::Acquire) {
            return DeathPurge::default();
        }
        self.dead[w].store(true, Ordering::Release);
        self.retired[w].store(true, Ordering::Release);
        self.stats.deaths.fetch_add(1, Ordering::Relaxed);
        self.speed.clear(w);
        self.gb[w].lock().unwrap().clear();
        let mut doomed: Vec<GroupId> = self
            .groups
            .iter()
            .flat_map(|s| {
                s.lock()
                    .unwrap()
                    .iter()
                    .filter(|(_, e)| e.members.contains(&w))
                    .map(|(&id, _)| id)
                    .collect::<Vec<_>>()
            })
            .collect();
        doomed.sort_unstable(); // shard/HashMap order varies; stay deterministic
        // Remove every doomed group first, then arm in one sweep — arming
        // as we go could transiently hand out a pending group that names
        // the dead rank and is itself about to be aborted.
        let mut released: Vec<usize> = Vec::new();
        let mut aborted = Vec::new();
        for id in doomed {
            let (group, was_armed) =
                self.teardown_group(id, sched).expect("doomed id is live");
            if was_armed {
                released.extend(group.members.iter().copied());
            }
            aborted.push(group);
        }
        let newly_armed = if released.is_empty() {
            Vec::new()
        } else {
            self.arm_unblocked(&released, sched)
        };
        // Guard against protocol drift: a dead rank must never keep a bit.
        debug_assert!(!self.locks.is_locked(w), "dead rank {w} still locked");
        self.locks.force_release(w);
        DeathPurge { aborted, newly_armed }
    }

    fn note_aborted(&self, id: GroupId, next_id: GroupId) {
        let mut shard = self.aborted_shard(id).lock().unwrap();
        shard.insert(id);
        // Same bounded memory as the oracle, split per shard: ids are
        // monotone, keep the most recent window.
        if shard.len() > super::ABORTED_SET_CAP / GROUP_SHARDS {
            let min_keep = next_id.saturating_sub(super::ABORTED_SET_CAP as u64);
            shard.retain(|&g| g >= min_keep);
        }
    }

    fn teardown_group(&self, id: GroupId, sched: &mut Sched) -> Option<(Group, bool)> {
        let entry = self.shard(id).lock().unwrap().remove(&id)?;
        self.stats.groups_aborted.fetch_add(1, Ordering::Relaxed);
        self.note_aborted(id, sched.next_id);
        if self.cfg.use_group_buffer {
            for &m in &entry.members {
                self.gb[m].lock().unwrap().retain(|&g| g != id);
            }
        }
        let group = Group { id, members: entry.members };
        if !entry.armed {
            let pos = sched
                .pending
                .iter()
                .position(|&p| p == id)
                .expect("pending group is queued");
            sched.pending.remove(pos);
            return Some((group, false)); // pending groups hold no locks
        }
        self.locks.release(&group.members);
        Some((group, true))
    }

    /// Arm pending groups that can now lock after `released` workers came
    /// free, preserving FIFO fairness (same touched-set skip as the
    /// oracle's `arm_unblocked`).
    fn arm_unblocked(&self, released: &[usize], sched: &mut Sched) -> Vec<Group> {
        let mut armed = Vec::new();
        let mut still_pending = VecDeque::new();
        while let Some(pid) = sched.pending.pop_front() {
            let members = self
                .shard(pid)
                .lock()
                .unwrap()
                .get(&pid)
                .expect("pending id is live")
                .members
                .clone();
            let touched = members.iter().any(|m| released.contains(m));
            if touched && self.locks.try_lock(&members) {
                self.shard(pid).lock().unwrap().get_mut(&pid).unwrap().armed = true;
                armed.push(Group { id: pid, members });
            } else {
                still_pending.push_back(pid);
            }
        }
        sched.pending = still_pending;
        armed
    }

    fn create_group(
        &self,
        initiator: usize,
        mut members: Vec<usize>,
        newly_armed: &mut Vec<Group>,
        sched: &mut Sched,
    ) -> GroupId {
        members.sort_unstable();
        members.dedup();
        debug_assert!(members.len() >= 2);
        let id = sched.next_id;
        sched.next_id += 1;
        self.stats.groups_created.fetch_add(1, Ordering::Relaxed);
        let req_now = self.stats.requests.load(Ordering::Relaxed);
        for &m in &members {
            if m != initiator {
                self.drafts[m].fetch_add(1, Ordering::Relaxed);
                self.last_drafted[m].store(req_now, Ordering::Relaxed);
            }
        }
        if self.cfg.use_group_buffer {
            for &m in &members {
                self.gb[m].lock().unwrap().push_back(id);
            }
        }
        let armed = self.locks.try_lock(&members);
        if armed {
            newly_armed.push(Group { id, members: members.clone() });
        } else {
            self.stats.conflicts.fetch_add(1, Ordering::Relaxed);
            sched.pending.push_back(id);
            self.stats.max_pending.fetch_max(sched.pending.len(), Ordering::Relaxed);
        }
        self.shard(id).lock().unwrap().insert(id, Entry { members, armed });
        id
    }

    /// §4.1 random group — byte-for-byte the oracle's sampling (same RNG
    /// consumption), reading the sharded state instead.
    fn random_group(&self, w: usize, sched: &mut Sched) -> Option<Vec<usize>> {
        let mut others: Vec<usize> = (0..self.cfg.n_workers)
            .filter(|&x| {
                x != w
                    && !self.retired[x].load(Ordering::Acquire)
                    && (!self.cfg.rendezvous
                        || (self.gb[x].lock().unwrap().is_empty()
                            && !self.locks.is_locked(x)))
            })
            .collect();
        if others.is_empty() {
            return None;
        }
        let k = self.cfg.group_size.min(others.len() + 1);
        // partial shuffle: pick k-1 distinct others
        let mut members = vec![w];
        for i in 0..k - 1 {
            let j = i + sched.rng.gen_range(others.len() - i);
            others.swap(i, j);
            members.push(others[i]);
        }
        Some(members)
    }

    /// §5.1/§5.2/§5.3 Global Division — the oracle's logic over sharded
    /// state, serialized under `sched` (a division must see a stable idle
    /// view, and two concurrent divisions must not both draft one idle
    /// rank).
    fn global_division(&self, w: usize, sched: &mut Sched) -> Vec<Vec<usize>> {
        let division = self.stats.divisions.fetch_add(1, Ordering::Relaxed) + 1;
        let c_i = self.counters[w].load(Ordering::Relaxed);
        let retired = self.retired_mask();
        let speed_ref = self.speed.reference_excluding(&retired);
        let mut idle: Vec<usize> = (0..self.cfg.n_workers)
            .filter(|&x| {
                if x == w {
                    return true;
                }
                let buffer_free =
                    !self.cfg.use_group_buffer || self.gb[x].lock().unwrap().is_empty();
                let lock_free = !self.locks.is_locked(x) && !retired[x];
                let measured_rel =
                    self.speed.get(x).and_then(|own| speed_ref.map(|r| own / r));
                let fast_enough = match (self.cfg.s_thres, measured_rel) {
                    (Some(thres), Some(rel)) => rel <= thres,
                    _ => match self.cfg.c_thres {
                        Some(thres) => {
                            c_i.saturating_sub(self.counters[x].load(Ordering::Relaxed))
                                < thres
                        }
                        None => true,
                    },
                };
                buffer_free && lock_free && fast_enough
            })
            .collect();
        if idle.len() < 2 {
            return Vec::new(); // nobody idle to pair with: skip this sync
        }
        if self.cfg.inter_intra {
            self.inter_intra_division(&mut idle, division as usize, &mut sched.rng)
        } else {
            vec_partition(&mut idle, self.cfg.group_size, &mut sched.rng)
        }
    }

    /// §5.2 Inter-Intra — identical group construction to the oracle
    /// (`rotation` is the post-increment division count, exactly the
    /// value the oracle reads from `stats.divisions`).
    fn inter_intra_division(
        &self,
        idle: &mut Vec<usize>,
        rotation: usize,
        rng: &mut Pcg32,
    ) -> Vec<Vec<usize>> {
        let wpn = self.cfg.workers_per_node.max(1);
        let mut per_node: HashMap<usize, Vec<usize>> = HashMap::new();
        for &x in idle.iter() {
            per_node.entry(x / wpn).or_default().push(x);
        }
        let mut heads = Vec::new();
        let mut locals: Vec<Vec<usize>> = Vec::new();
        let mut nodes: Vec<usize> = per_node.keys().copied().collect();
        nodes.sort_unstable();
        for nd in nodes {
            let mut ws = per_node.remove(&nd).unwrap();
            ws.sort_unstable();
            let h = ws
                .iter()
                .position(|&w| w % wpn == rotation % wpn)
                .unwrap_or(rotation % ws.len());
            heads.push(ws.swap_remove(h));
            if !ws.is_empty() {
                locals.push(ws);
            }
        }
        let mut groups = Vec::new();
        if heads.len() >= 2 {
            heads.sort_unstable();
            let mut i = 0;
            while i < heads.len() {
                let end = (i + self.cfg.group_size).min(heads.len());
                groups.push(heads[i..end].to_vec());
                i = end;
            }
            if groups.len() >= 2 && groups.last().unwrap().len() == 1 {
                let last = groups.pop().unwrap();
                groups.last_mut().unwrap().extend(last);
            }
            groups.retain(|g| g.len() >= 2);
        }
        for mut ws in locals {
            if ws.len() >= 2 {
                groups.extend(vec_partition(&mut ws, self.cfg.group_size, rng));
            }
        }
        let mut per_node2: HashMap<usize, Vec<usize>> = HashMap::new();
        for &x in idle.iter() {
            per_node2.entry(x / wpn).or_default().push(x);
        }
        let mut nodes2: Vec<usize> = per_node2.keys().copied().collect();
        nodes2.sort_unstable();
        for nd in nodes2 {
            let ws = per_node2.remove(&nd).unwrap();
            if ws.len() >= 2 {
                groups.push(ws);
            }
        }
        if groups.is_empty() {
            groups.push(idle.clone());
        }
        groups
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gg::GroupGenerator;

    /// Drive oracle and sharded GG through one identical sequential
    /// schedule and compare everything observable at every step.
    fn assert_equivalent(cfg: GgConfig, seed: u64, steps: usize) {
        let mut oracle = GroupGenerator::new(cfg.clone());
        let mut orng = Pcg32::new(seed);
        let sharded = ShardedGg::new(cfg.clone(), seed);
        let mut ops = Pcg32::new(seed ^ 0x5eed);
        let mut armed_live: Vec<GroupId> = Vec::new();
        for step in 0..steps {
            let w = ops.gen_range(cfg.n_workers);
            if ops.gen_range(4) == 0 && !armed_live.is_empty() {
                let id = armed_live.remove(ops.gen_range(armed_live.len()));
                let a = oracle.complete(id);
                let b = sharded.complete(id);
                assert_eq!(a, b, "seed {seed} step {step}: complete({id}) diverged");
                armed_live.extend(a.iter().map(|g| g.id));
            } else {
                let (aa, ag) = oracle.request(w, &mut orng);
                let (ba, bg) = sharded.request(w);
                assert_eq!(aa, ba, "seed {seed} step {step}: assignment diverged");
                assert_eq!(ag, bg, "seed {seed} step {step}: armed set diverged");
                armed_live.extend(ag.iter().map(|g| g.id));
            }
            armed_live.retain(|&id| oracle.is_armed(id));
            assert_eq!(format!("{:?}", oracle.stats), format!("{:?}", sharded.stats()));
            assert_eq!(oracle.counters(), &sharded.counters()[..]);
            assert_eq!(oracle.pending_len(), sharded.pending_len());
            assert_eq!(oracle.locked_count(), sharded.locked_count());
            for x in 0..cfg.n_workers {
                assert_eq!(oracle.gb_snapshot(x), sharded.gb_snapshot(x));
                assert_eq!(oracle.is_locked_worker(x), sharded.is_locked_worker(x));
            }
        }
    }

    #[test]
    fn sequentially_bit_identical_to_the_oracle_random() {
        assert_equivalent(GgConfig::random(8, 4, 3), 42, 300);
    }

    #[test]
    fn sequentially_bit_identical_to_the_oracle_smart() {
        assert_equivalent(GgConfig::smart(16, 4, 3, 8), 7, 300);
    }

    #[test]
    fn sequentially_bit_identical_under_rendezvous() {
        let mut cfg = GgConfig::random(12, 4, 3);
        cfg.rendezvous = true;
        cfg.use_group_buffer = true;
        assert_equivalent(cfg, 1234, 300);
    }

    #[test]
    fn phase_probe_tracks_the_group_lifecycle() {
        let gg = ShardedGg::new(GgConfig::random(6, 3, 3), 9);
        let (assigned, armed) = gg.request(0);
        let id = assigned.unwrap();
        assert_eq!(gg.phase(id), GroupPhase::Armed);
        assert_eq!(armed.len(), 1);
        assert!(matches!(gg.try_complete(id), CompleteOutcome::Done(_)));
        assert_eq!(gg.phase(id), GroupPhase::Done);
        assert!(matches!(gg.try_complete(id), CompleteOutcome::Unknown));
        // an aborted id probes as Aborted, not Done
        let (assigned, _) = gg.request(1);
        let id2 = assigned.unwrap();
        gg.abort_group(id2);
        assert_eq!(gg.phase(id2), GroupPhase::Aborted);
    }

    #[test]
    fn try_complete_rejects_pending_groups() {
        // Arm [0,1,2]-ish group, then force a conflicting pending group
        // by requesting from a free-but-overlapping drafting pattern.
        let cfg = GgConfig::random(4, 2, 4); // whole-cluster groups
        let gg = ShardedGg::new(cfg, 3);
        let (a, _) = gg.request(0);
        let first = a.unwrap();
        let (b, armed) = gg.request(1); // conflicts: everyone is locked
        let second = b.unwrap();
        assert!(armed.is_empty());
        assert_eq!(gg.phase(second), GroupPhase::Pending);
        assert!(matches!(gg.try_complete(second), CompleteOutcome::NotArmed));
        // completing the armed group arms the pending one
        let CompleteOutcome::Done(now_armed) = gg.try_complete(first) else {
            panic!("armed group must complete");
        };
        assert_eq!(now_armed.len(), 1);
        assert_eq!(now_armed[0].id, second);
    }

    #[test]
    fn plan_assembly_is_bit_identical_across_backends() {
        // Plans never live inside either state machine — they are a pure
        // function of (members, topology, speed snapshot). Drive both
        // backends identically, report some speeds, and check the plans
        // assembled from each backend's own snapshot match exactly.
        let topo = crate::topo::Topology::parse("a:0,1,2;b:3,4;c:5", 6).unwrap();
        let mut cfg = GgConfig::random(6, 3, 3);
        cfg.topology = Some(topo);
        let mut oracle = GroupGenerator::new(cfg.clone());
        let mut orng = Pcg32::new(21);
        let sharded = ShardedGg::new(cfg.clone(), 21);
        let mut ops = Pcg32::new(21 ^ 0x5eed);
        for _ in 0..100 {
            let w = ops.gen_range(cfg.n_workers);
            if ops.gen_range(3) == 0 {
                let ewma = 0.01 + 0.01 * w as f64;
                oracle.report_speed(w, ewma);
                sharded.report_speed(w, ewma);
            }
            let (aa, _) = oracle.request(w, &mut orng);
            let (ba, _) = sharded.request(w);
            assert_eq!(aa, ba);
            let Some(id) = aa else { continue };
            let a_speeds = oracle.speed_table().snapshot();
            let b_speeds = sharded.speed_snapshot();
            assert_eq!(a_speeds, b_speeds, "speed snapshots diverged");
            let members = oracle.group(id).unwrap().members.clone();
            let a_plan = crate::topo::SyncPlan::make(
                &members,
                oracle.config().topology.as_ref(),
                &a_speeds,
            );
            let b_plan = crate::topo::SyncPlan::make(
                &members,
                sharded.config().topology.as_ref(),
                &b_speeds,
            );
            assert_eq!(a_plan.nodes, b_plan.nodes, "plans diverged for {members:?}");
            assert!(a_plan.validate(&members).is_ok());
            if oracle.is_armed(id) {
                oracle.complete(id);
                sharded.complete(id);
            }
        }
    }

    #[test]
    fn epoch_moves_on_phase_changes() {
        let gg = ShardedGg::new(GgConfig::random(4, 2, 2), 5);
        let e0 = gg.epoch();
        let (a, _) = gg.request(0);
        assert!(gg.epoch() > e0);
        let e1 = gg.epoch();
        gg.complete(a.unwrap());
        assert!(gg.epoch() > e1);
    }
}
