//! Data-plane framing: length-prefixed messages between worker processes,
//! reusing the `rpc::wire` codec style (little-endian, no deps).
//!
//! Message kinds flowing on a mesh connection:
//!
//! * `Hello { rank }` — sent once by the connecting side so the acceptor
//!   can index the stream by peer rank.
//! * `Chunk { gid, step, data }` — one ring-schedule transfer of model
//!   elements for P-Reduce group `gid`. The `(gid, step)` tag lets the
//!   receiver assert it is consuming the transfer it expects: armed
//!   groups are disjoint (lock vector) and an edge is quiescent between
//!   groups, so a same-group mismatch is a protocol bug, not a
//!   reordering.
//! * `Chunk16` / `ChunkQ8` — the same transfer under a compressed wire
//!   codec (`collectives::codec::WireCodec`): raw binary16 bits, or
//!   per-chunk min/max-scaled int8 with an `(lo, scale)` header. The
//!   frame tag carries the codec, so a receiver decodes whatever the
//!   sender used.
//! * `Poison { gid }` — failure repair: a worker unwinding from group
//!   `gid`'s broken collective poisons its ring successor, which unwinds
//!   and forwards the poison, so the whole ring unblocks in one
//!   round-trip instead of waiting out socket timeouts. A receiver in a
//!   *later* group skips stale frames of aborted predecessors (group ids
//!   are monotone per edge — conflicting groups serialize on the lock
//!   vector).
//!
//! Outer wire format matches the GG RPC: `u32 length (LE) | payload`.
//! Payload element counts are validated against the *remaining payload
//! bytes* before any allocation: a corrupt or malicious frame cannot
//! demand a reservation larger than the bytes it actually shipped.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use crate::collectives::codec::{
    f16_bits_to_f32, f32_to_f16_bits, q8_dequantize_into, q8_params, q8_quantize_one,
    WireCodec,
};
use crate::rpc::wire::{Reader, Writer};

/// Refuse frames above this size (64 MiB ≈ a 16M-parameter f32 chunk);
/// corrupt length prefixes otherwise trigger huge allocations.
pub const MAX_FRAME: usize = 1 << 26;

/// A decoded data-plane message.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Connection preamble: the sender's worker rank.
    Hello { rank: u32 },
    /// One ring-collective transfer, raw `f32` elements.
    Chunk { gid: u64, step: u32, data: Vec<f32> },
    /// One ring-collective transfer, IEEE binary16 bits per element.
    Chunk16 { gid: u64, step: u32, data: Vec<u16> },
    /// One ring-collective transfer, per-chunk min/max-scaled int8:
    /// element `i` decodes to `lo + data[i] · scale/255`.
    ChunkQ8 { gid: u64, step: u32, lo: f32, scale: f32, data: Vec<u8> },
    /// Failure repair: group `gid`'s collective is broken — unwind.
    Poison { gid: u64 },
}

impl Frame {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        match self {
            Frame::Hello { rank } => {
                w.u8(0);
                w.u32(*rank);
            }
            Frame::Chunk { gid, step, data } => {
                w.u8(1);
                w.u64(*gid);
                w.u32(*step);
                w.u32(data.len() as u32);
                for v in data {
                    w.bytes(&v.to_le_bytes());
                }
            }
            Frame::Poison { gid } => {
                w.u8(2);
                w.u64(*gid);
            }
            Frame::Chunk16 { gid, step, data } => {
                w.u8(3);
                w.u64(*gid);
                w.u32(*step);
                w.u32(data.len() as u32);
                for v in data {
                    w.bytes(&v.to_le_bytes());
                }
            }
            Frame::ChunkQ8 { gid, step, lo, scale, data } => {
                w.u8(4);
                w.u64(*gid);
                w.u32(*step);
                w.u32(data.len() as u32);
                w.u32(lo.to_bits());
                w.u32(scale.to_bits());
                w.bytes(data);
            }
        }
        w.finish()
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = Reader::new(buf);
        let tag = r.u8()?;
        let frame = match tag {
            0 => Frame::Hello { rank: r.u32()? },
            1 => {
                let gid = r.u64()?;
                let step = r.u32()?;
                let count = r.u32()? as usize;
                // Validate the declared count against the payload bytes
                // actually present BEFORE reserving anything: a corrupt
                // frame must not buy a huge allocation with a u32.
                let need = count
                    .checked_mul(4)
                    .filter(|&n| n <= MAX_FRAME)
                    .with_context(|| format!("chunk too large: {count} elements"))?;
                let raw = r.bytes(need)?;
                let mut data = Vec::with_capacity(count);
                data.extend(
                    raw.chunks_exact(4)
                        .map(|b| f32::from_le_bytes(b.try_into().unwrap())),
                );
                Frame::Chunk { gid, step, data }
            }
            2 => Frame::Poison { gid: r.u64()? },
            3 => {
                let gid = r.u64()?;
                let step = r.u32()?;
                let count = r.u32()? as usize;
                let need = count
                    .checked_mul(2)
                    .filter(|&n| n <= MAX_FRAME)
                    .with_context(|| format!("chunk16 too large: {count} elements"))?;
                let raw = r.bytes(need)?;
                let mut data = Vec::with_capacity(count);
                data.extend(
                    raw.chunks_exact(2)
                        .map(|b| u16::from_le_bytes(b.try_into().unwrap())),
                );
                Frame::Chunk16 { gid, step, data }
            }
            4 => {
                let gid = r.u64()?;
                let step = r.u32()?;
                let count = r.u32()? as usize;
                let lo = f32::from_bits(r.u32()?);
                let scale = f32::from_bits(r.u32()?);
                if count > MAX_FRAME {
                    bail!("chunkq8 too large: {count} elements");
                }
                let data = r.bytes(count)?.to_vec();
                Frame::ChunkQ8 { gid, step, lo, scale, data }
            }
            t => bail!("bad frame tag {t}"),
        };
        r.done()?;
        Ok(frame)
    }

    /// `(gid, step)` of any chunk variant; `None` for non-chunk frames.
    pub fn chunk_tag(&self) -> Option<(u64, u32)> {
        match self {
            Frame::Chunk { gid, step, .. }
            | Frame::Chunk16 { gid, step, .. }
            | Frame::ChunkQ8 { gid, step, .. } => Some((*gid, *step)),
            _ => None,
        }
    }

    /// Decode a chunk's elements into `out` (replacing its contents),
    /// whichever codec the sender used. Returns `false` (leaving `out`
    /// untouched) for non-chunk frames.
    pub fn take_chunk_data(self, out: &mut Vec<f32>) -> bool {
        match self {
            Frame::Chunk { data, .. } => {
                *out = data;
                true
            }
            Frame::Chunk16 { data, .. } => {
                out.clear();
                out.reserve(data.len());
                out.extend(data.iter().map(|&h| f16_bits_to_f32(h)));
                true
            }
            Frame::ChunkQ8 { lo, scale, data, .. } => {
                q8_dequantize_into(&data, lo, scale, out);
                true
            }
            _ => false,
        }
    }
}

/// Write one length-prefixed frame.
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> Result<()> {
    let payload = frame.encode();
    let len = payload.len() as u32;
    w.write_all(&len.to_le_bytes()).context("write frame length")?;
    w.write_all(&payload).context("write frame payload")?;
    w.flush().context("flush frame")?;
    Ok(())
}

/// Hot-path chunk writer: encodes straight from the `f32` slice into one
/// reused buffer (length prefix included), skipping the intermediate
/// `Frame` a `write_frame` round trip would need. Byte-identical to
/// `write_frame` of the corresponding chunk variant. Returns the number
/// of bytes written (frame prefix included).
pub fn write_chunk_coded<W: Write>(
    w: &mut W,
    codec: WireCodec,
    gid: u64,
    step: u32,
    data: &[f32],
    buf: &mut Vec<u8>,
) -> Result<usize> {
    buf.clear();
    let header = |buf: &mut Vec<u8>, payload_len: usize, tag: u8| {
        buf.reserve(4 + payload_len);
        buf.extend_from_slice(&(payload_len as u32).to_le_bytes());
        buf.push(tag);
        buf.extend_from_slice(&gid.to_le_bytes());
        buf.extend_from_slice(&step.to_le_bytes());
        buf.extend_from_slice(&(data.len() as u32).to_le_bytes());
    };
    match codec {
        WireCodec::Fp32 => {
            header(buf, 1 + 8 + 4 + 4 + 4 * data.len(), 1);
            for v in data {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
        WireCodec::Fp16 => {
            header(buf, 1 + 8 + 4 + 4 + 2 * data.len(), 3);
            for v in data {
                buf.extend_from_slice(&f32_to_f16_bits(*v).to_le_bytes());
            }
        }
        WireCodec::Q8 => {
            header(buf, 1 + 8 + 4 + 4 + 4 + 4 + data.len(), 4);
            let (lo, scale) = q8_params(data);
            buf.extend_from_slice(&lo.to_bits().to_le_bytes());
            buf.extend_from_slice(&scale.to_bits().to_le_bytes());
            for v in data {
                buf.push(q8_quantize_one(*v, lo, scale));
            }
        }
    }
    w.write_all(buf).context("write chunk frame")?;
    w.flush().context("flush chunk frame")?;
    Ok(buf.len())
}

/// [`write_chunk_coded`] pinned to the raw `f32` codec — the original
/// zero-copy fast path, byte-identical to
/// `write_frame(&Frame::Chunk { .. })`.
pub fn write_chunk<W: Write>(w: &mut W, gid: u64, step: u32, data: &[f32]) -> Result<()> {
    let mut buf = Vec::new();
    write_chunk_coded(w, WireCodec::Fp32, gid, step, data, &mut buf).map(|_| ())
}

/// Read one length-prefixed frame, returning the bytes consumed off the
/// stream alongside it (prefix included) — the data plane's byte meter.
pub fn read_frame_counted<R: Read>(r: &mut R) -> Result<(Frame, usize)> {
    let mut lenbuf = [0u8; 4];
    r.read_exact(&mut lenbuf).context("read frame length")?;
    let len = u32::from_le_bytes(lenbuf) as usize;
    if len > MAX_FRAME {
        bail!("frame too large: {len}");
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf).context("read frame payload")?;
    Ok((Frame::decode(&buf)?, 4 + len))
}

/// Read one length-prefixed frame.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Frame> {
    read_frame_counted(r).map(|(f, _)| f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codec_roundtrip() {
        for frame in [
            Frame::Hello { rank: 3 },
            Frame::Chunk { gid: 9, step: 4, data: vec![1.0, -2.5, f32::MIN] },
            Frame::Chunk { gid: u64::MAX, step: 0, data: vec![] },
            Frame::Chunk16 { gid: 5, step: 2, data: vec![0x3c00, 0x7bff, 0x8001] },
            Frame::Chunk16 { gid: 6, step: 0, data: vec![] },
            Frame::ChunkQ8 { gid: 7, step: 1, lo: -1.5, scale: 3.0, data: vec![0, 128, 255] },
            Frame::ChunkQ8 { gid: 8, step: 0, lo: 0.0, scale: 0.0, data: vec![] },
            Frame::Poison { gid: 77 },
        ] {
            assert_eq!(Frame::decode(&frame.encode()).unwrap(), frame);
        }
    }

    #[test]
    fn stream_roundtrip() {
        let mut buf = Vec::new();
        let a = Frame::Hello { rank: 1 };
        let b = Frame::Chunk { gid: 2, step: 3, data: vec![0.5; 7] };
        write_frame(&mut buf, &a).unwrap();
        write_frame(&mut buf, &b).unwrap();
        let mut cur = std::io::Cursor::new(buf);
        assert_eq!(read_frame(&mut cur).unwrap(), a);
        assert_eq!(read_frame(&mut cur).unwrap(), b);
    }

    #[test]
    fn write_chunk_matches_frame_encoding() {
        let (gid, step, data) = (77u64, 5u32, vec![1.5f32, -0.25, 1e20]);
        let mut fast = Vec::new();
        write_chunk(&mut fast, gid, step, &data).unwrap();
        let mut slow = Vec::new();
        write_frame(&mut slow, &Frame::Chunk { gid, step, data }).unwrap();
        assert_eq!(fast, slow);
    }

    #[test]
    fn write_chunk_coded_matches_frame_encoding_per_codec() {
        let (gid, step) = (42u64, 9u32);
        let data = vec![1.5f32, -0.25, 0.75, 100.0];
        let mut scratch = Vec::new();
        for codec in [WireCodec::Fp32, WireCodec::Fp16, WireCodec::Q8] {
            let mut fast = Vec::new();
            let n =
                write_chunk_coded(&mut fast, codec, gid, step, &data, &mut scratch).unwrap();
            assert_eq!(n, fast.len());
            let frame = match codec {
                WireCodec::Fp32 => Frame::Chunk { gid, step, data: data.clone() },
                WireCodec::Fp16 => Frame::Chunk16 {
                    gid,
                    step,
                    data: data.iter().map(|&v| f32_to_f16_bits(v)).collect(),
                },
                WireCodec::Q8 => {
                    let (lo, scale) = q8_params(&data);
                    Frame::ChunkQ8 {
                        gid,
                        step,
                        lo,
                        scale,
                        data: data.iter().map(|&v| q8_quantize_one(v, lo, scale)).collect(),
                    }
                }
            };
            let mut slow = Vec::new();
            write_frame(&mut slow, &frame).unwrap();
            assert_eq!(fast, slow, "{codec} fast path diverged from Frame::encode");
            // the counted reader reports exactly what the writer shipped
            let mut cur = std::io::Cursor::new(fast.clone());
            let (decoded, consumed) = read_frame_counted(&mut cur).unwrap();
            assert_eq!(consumed, fast.len());
            assert_eq!(decoded, frame);
        }
    }

    #[test]
    fn take_chunk_data_decodes_every_codec() {
        let data = vec![0.5f32, -1.0, 2.0];
        let mut out = vec![9.9f32]; // stale contents must be replaced
        assert!(Frame::Chunk { gid: 1, step: 0, data: data.clone() }
            .take_chunk_data(&mut out));
        assert_eq!(out, data);
        let h: Vec<u16> = data.iter().map(|&v| f32_to_f16_bits(v)).collect();
        assert!(Frame::Chunk16 { gid: 1, step: 0, data: h }.take_chunk_data(&mut out));
        assert_eq!(out, data); // these values are fp16-exact
        let (lo, scale) = q8_params(&data);
        let q: Vec<u8> = data.iter().map(|&v| q8_quantize_one(v, lo, scale)).collect();
        assert!(Frame::ChunkQ8 { gid: 1, step: 0, lo, scale, data: q }
            .take_chunk_data(&mut out));
        for (got, want) in out.iter().zip(data.iter()) {
            assert!((got - want).abs() <= scale / 500.0, "{got} vs {want}");
        }
        assert!(!Frame::Poison { gid: 1 }.take_chunk_data(&mut out));
        assert_eq!(Frame::Poison { gid: 1 }.chunk_tag(), None);
        assert_eq!(
            Frame::Chunk16 { gid: 3, step: 7, data: vec![] }.chunk_tag(),
            Some((3, 7))
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Frame::decode(&[9]).is_err()); // bad tag
        assert!(Frame::decode(&[0, 1]).is_err()); // truncated hello
        // trailing bytes after a well-formed hello
        let mut buf = Frame::Hello { rank: 0 }.encode();
        buf.push(0);
        assert!(Frame::decode(&buf).is_err());
        // length prefix beyond MAX_FRAME
        let mut cur = std::io::Cursor::new(((MAX_FRAME + 1) as u32).to_le_bytes().to_vec());
        assert!(read_frame(&mut cur).is_err());
    }

    /// Regression: `Frame::Chunk` decode used to `Vec::with_capacity`
    /// the wire-declared element count before checking it against the
    /// remaining payload, so a tiny corrupt frame could demand a huge
    /// reservation. The count must be validated against the bytes
    /// actually present first — for every chunk variant.
    #[test]
    fn adversarial_count_rejected_before_allocation() {
        for (tag, elem_size) in [(1u8, 4usize), (3, 2), (4, 1)] {
            let mut w = Writer::new();
            w.u8(tag);
            w.u64(7); // gid
            w.u32(0); // step
            // declare ~16M elements (passes the MAX_FRAME element check)
            w.u32((MAX_FRAME / elem_size - 8) as u32);
            if tag == 4 {
                w.u32(0); // lo
                w.u32(0); // scale
            }
            w.bytes(&[0u8; 8]); // ...but ship 8 payload bytes
            let err = Frame::decode(&w.finish())
                .expect_err("under-shipped chunk decoded (allocation-before-check)");
            let msg = format!("{err:#}");
            assert!(
                msg.contains("truncated"),
                "tag {tag}: expected a payload-bounds error, got: {msg}"
            );
            // and a count whose byte size overflows/over-caps still fails
            let mut w = Writer::new();
            w.u8(tag);
            w.u64(7);
            w.u32(0);
            w.u32(u32::MAX);
            if tag == 4 {
                w.u32(0);
                w.u32(0);
            }
            assert!(Frame::decode(&w.finish()).is_err(), "tag {tag}: u32::MAX count");
        }
    }
}
