//! Data-plane framing: length-prefixed messages between worker processes,
//! reusing the `rpc::wire` codec style (little-endian, no deps).
//!
//! Three message kinds flow on a mesh connection:
//!
//! * `Hello { rank }` — sent once by the connecting side so the acceptor
//!   can index the stream by peer rank.
//! * `Chunk { gid, step, data }` — one ring-schedule transfer of model
//!   elements for P-Reduce group `gid`. The `(gid, step)` tag lets the
//!   receiver assert it is consuming the transfer it expects: armed
//!   groups are disjoint (lock vector) and an edge is quiescent between
//!   groups, so a same-group mismatch is a protocol bug, not a
//!   reordering.
//! * `Poison { gid }` — failure repair: a worker unwinding from group
//!   `gid`'s broken collective poisons its ring successor, which unwinds
//!   and forwards the poison, so the whole ring unblocks in one
//!   round-trip instead of waiting out socket timeouts. A receiver in a
//!   *later* group skips stale frames of aborted predecessors (group ids
//!   are monotone per edge — conflicting groups serialize on the lock
//!   vector).
//!
//! Outer wire format matches the GG RPC: `u32 length (LE) | payload`.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use crate::rpc::wire::{Reader, Writer};

/// Refuse frames above this size (64 MiB ≈ a 16M-parameter f32 chunk);
/// corrupt length prefixes otherwise trigger huge allocations.
pub const MAX_FRAME: usize = 1 << 26;

/// A decoded data-plane message.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Connection preamble: the sender's worker rank.
    Hello { rank: u32 },
    /// One ring-collective transfer.
    Chunk { gid: u64, step: u32, data: Vec<f32> },
    /// Failure repair: group `gid`'s collective is broken — unwind.
    Poison { gid: u64 },
}

impl Frame {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        match self {
            Frame::Hello { rank } => {
                w.u8(0);
                w.u32(*rank);
            }
            Frame::Chunk { gid, step, data } => {
                w.u8(1);
                w.u64(*gid);
                w.u32(*step);
                w.u32(data.len() as u32);
                for v in data {
                    w.bytes(&v.to_le_bytes());
                }
            }
            Frame::Poison { gid } => {
                w.u8(2);
                w.u64(*gid);
            }
        }
        w.finish()
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = Reader::new(buf);
        let tag = r.u8()?;
        let frame = match tag {
            0 => Frame::Hello { rank: r.u32()? },
            1 => {
                let gid = r.u64()?;
                let step = r.u32()?;
                let count = r.u32()? as usize;
                if count * 4 > MAX_FRAME {
                    bail!("chunk too large: {count} elements");
                }
                let mut data = Vec::with_capacity(count);
                for _ in 0..count {
                    data.push(f32::from_le_bytes(r.u32()?.to_le_bytes()));
                }
                Frame::Chunk { gid, step, data }
            }
            2 => Frame::Poison { gid: r.u64()? },
            t => bail!("bad frame tag {t}"),
        };
        r.done()?;
        Ok(frame)
    }
}

/// Write one length-prefixed frame.
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> Result<()> {
    let payload = frame.encode();
    let len = payload.len() as u32;
    w.write_all(&len.to_le_bytes()).context("write frame length")?;
    w.write_all(&payload).context("write frame payload")?;
    w.flush().context("flush frame")?;
    Ok(())
}

/// Hot-path chunk writer: encodes straight from the slice into one
/// buffer (length prefix included), skipping the intermediate
/// `Vec<f32>` a `Frame::Chunk` would need. Byte-identical to
/// `write_frame(&Frame::Chunk { .. })`.
pub fn write_chunk<W: Write>(w: &mut W, gid: u64, step: u32, data: &[f32]) -> Result<()> {
    let payload_len = 1 + 8 + 4 + 4 + 4 * data.len();
    let mut buf = Vec::with_capacity(4 + payload_len);
    buf.extend_from_slice(&(payload_len as u32).to_le_bytes());
    buf.push(1); // Frame::Chunk tag
    buf.extend_from_slice(&gid.to_le_bytes());
    buf.extend_from_slice(&step.to_le_bytes());
    buf.extend_from_slice(&(data.len() as u32).to_le_bytes());
    for v in data {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    w.write_all(&buf).context("write chunk frame")?;
    w.flush().context("flush chunk frame")?;
    Ok(())
}

/// Read one length-prefixed frame.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Frame> {
    let mut lenbuf = [0u8; 4];
    r.read_exact(&mut lenbuf).context("read frame length")?;
    let len = u32::from_le_bytes(lenbuf) as usize;
    if len > MAX_FRAME {
        bail!("frame too large: {len}");
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf).context("read frame payload")?;
    Frame::decode(&buf)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codec_roundtrip() {
        for frame in [
            Frame::Hello { rank: 3 },
            Frame::Chunk { gid: 9, step: 4, data: vec![1.0, -2.5, f32::MIN] },
            Frame::Chunk { gid: u64::MAX, step: 0, data: vec![] },
            Frame::Poison { gid: 77 },
        ] {
            assert_eq!(Frame::decode(&frame.encode()).unwrap(), frame);
        }
    }

    #[test]
    fn stream_roundtrip() {
        let mut buf = Vec::new();
        let a = Frame::Hello { rank: 1 };
        let b = Frame::Chunk { gid: 2, step: 3, data: vec![0.5; 7] };
        write_frame(&mut buf, &a).unwrap();
        write_frame(&mut buf, &b).unwrap();
        let mut cur = std::io::Cursor::new(buf);
        assert_eq!(read_frame(&mut cur).unwrap(), a);
        assert_eq!(read_frame(&mut cur).unwrap(), b);
    }

    #[test]
    fn write_chunk_matches_frame_encoding() {
        let (gid, step, data) = (77u64, 5u32, vec![1.5f32, -0.25, 1e20]);
        let mut fast = Vec::new();
        write_chunk(&mut fast, gid, step, &data).unwrap();
        let mut slow = Vec::new();
        write_frame(&mut slow, &Frame::Chunk { gid, step, data }).unwrap();
        assert_eq!(fast, slow);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Frame::decode(&[9]).is_err()); // bad tag
        assert!(Frame::decode(&[0, 1]).is_err()); // truncated hello
        // trailing bytes after a well-formed hello
        let mut buf = Frame::Hello { rank: 0 }.encode();
        buf.push(0);
        assert!(Frame::decode(&buf).is_err());
        // length prefix beyond MAX_FRAME
        let mut cur = std::io::Cursor::new(((MAX_FRAME + 1) as u32).to_le_bytes().to_vec());
        assert!(read_frame(&mut cur).is_err());
    }
}
