//! Distributed TCP data plane for P-Reduce (DESIGN.md §Deployment).
//!
//! The control plane (`rpc`) already moved the Group Generator behind a
//! TCP service; this module moves the *model bytes* too, turning the
//! reproduction into a deployable multi-process system:
//!
//! * [`frame`] — length-prefixed chunk framing over the `rpc::wire` codec;
//! * [`mesh`] — [`WorkerMesh`]: lazy rank-to-rank connections and the
//!   [`mesh::TcpRingTransport`] that plugs into the generic ring schedule
//!   in `collectives::ring`;
//! * [`worker`] — the per-process training loop (pure-Rust MLP +
//!   GG-scheduled ring collectives) behind `ripples worker`;
//! * [`launch`] — the localhost cluster orchestrator behind
//!   `ripples launch`.
//!
//! The same `collectives::ring` schedule the thread runtime executes over
//! mpsc channels runs here over sockets — one implementation of the
//! paper's bandwidth-optimal P-Reduce, two transports.

pub mod frame;
pub mod launch;
pub mod mesh;
pub mod worker;

pub use frame::Frame;
pub use launch::{launch_local, LaunchConfig, LaunchReport};
pub use mesh::{TcpRingTransport, WorkerMesh};
pub use worker::{run_worker, worker_main, WorkerParams, WorkerReport};
