//! Distributed TCP data plane for P-Reduce (DESIGN.md §Deployment).
//!
//! The control plane (`rpc`) already moved the Group Generator behind a
//! TCP service; this module moves the *model bytes* too, turning the
//! reproduction into a deployable multi-process system:
//!
//! * [`frame`] — length-prefixed chunk framing over the `rpc::wire` codec;
//! * [`mesh`] — [`WorkerMesh`]: lazy rank-to-rank connections and the
//!   [`mesh::TcpRingTransport`] that plugs into the generic ring schedule
//!   in `collectives::ring`;
//! * [`worker`] — the per-process training loop (pure-Rust MLP +
//!   GG-scheduled ring collectives) behind `ripples worker`;
//! * [`launch`] — the localhost cluster orchestrator behind
//!   `ripples launch`;
//! * [`adpsgd`] / [`ps`] — the paper's comparison baselines on the same
//!   stack (`--algo adpsgd|ps`): randomized pairwise atomic averaging
//!   and a sharded BSP parameter server (DESIGN.md §Baselines).
//!
//! The same `collectives::ring` schedule the thread runtime executes over
//! mpsc channels runs here over sockets — one implementation of the
//! paper's bandwidth-optimal P-Reduce, two transports. With
//! `--overlap-shards K --max-staleness S` the collective is pipelined
//! over `K` model shards by a dedicated comm thread while training
//! continues on bounded-stale weights (`collectives::pipeline`;
//! DESIGN.md §Perf).
//!
//! # Crash tolerance
//!
//! Workers heartbeat the GG ([`crate::rpc::LivenessConfig`]); a worker
//! whose ring peer dies mid-collective unwinds via socket error or
//! `Poison` frame, restores its pre-collective snapshot, reports
//! `AbortGroup`, and retries in a repaired group. Periodic checkpoints
//! ([`ckpt`], `--ckpt-every`/`--ckpt-dir`) let a replacement process
//! `--rejoin`: it restores the freshest snapshot in the shared directory
//! and re-registers its (new) data-plane address with the GG, which
//! surviving peers re-resolve via `Lookup`. DESIGN.md §Fault-tolerance
//! has the full data flow.
//!
//! # Speed telemetry and dynamic stragglers
//!
//! Each worker timestamps its compute phase, folds the duration into an
//! EWMA ([`crate::gg::SPEED_ALPHA`]), and piggybacks it on every `Sync`
//! RPC as a [`crate::rpc::SpeedReport`]; the GG's speed table then
//! drives the slowdown filter from *measured* heterogeneity. A worker's
//! speed can change mid-run via a slowdown schedule — the launcher's
//! `--slow-schedule W,F@ITER` becomes a per-rank `F@ITER` list:
//!
//! ```
//! use ripples::net::{parse_worker_schedule, WorkerParams, WorkerReport};
//!
//! // worker-side schedule: 3x slow from iteration 40, recovered at 120
//! let p = WorkerParams {
//!     slow_schedule: parse_worker_schedule("3.0@40,1.0@120").unwrap(),
//!     ..WorkerParams::default()
//! };
//! assert_eq!(p.slowdown_at(0), 1.0);
//! assert_eq!(p.slowdown_at(40), 3.0);
//! assert_eq!(p.slowdown_at(120), 1.0);
//!
//! // the REPORT line carries the final measured EWMA back to `launch`
//! let line = "REPORT rank=1 iters=120 preduces=40 loss_first=1.4 \
//!             loss_last=0.3 secs=4.0 ewma=0.024500";
//! let r = WorkerReport::parse_line(line).unwrap();
//! assert!((r.ewma_secs - 0.0245).abs() < 1e-9);
//! ```

pub mod adpsgd;
pub mod ckpt;
pub mod frame;
pub mod launch;
pub mod mesh;
pub mod ps;
pub mod worker;

pub use adpsgd::{pairwise_average, run_adpsgd};
pub use ckpt::Checkpoint;
pub use frame::Frame;
pub use launch::{launch_local, KillSpec, LaunchConfig, LaunchReport};
pub use mesh::{TcpRingTransport, WorkerMesh};
pub use ps::{run_ps_worker, PsServer};
pub use worker::{
    format_worker_schedule, parse_worker_schedule, run_worker, worker_main, WorkerParams,
    WorkerReport,
};
