//! One distributed worker process: pure-Rust MLP training with
//! GG-scheduled P-Reduce groups executing the chunked ring collective
//! over TCP (see DESIGN.md §Deployment).
//!
//! Protocol per iteration (the paper's Fig. 8 worker loop):
//!  1. one local SGD step (plus the heterogeneity sleep, whose factor
//!     may change mid-run via the `--slow-schedule` entries), timed and
//!     folded into an EWMA step duration;
//!  2. `Sync` with the Group Generator, piggybacking the EWMA as a
//!     [`SpeedReport`](crate::rpc::SpeedReport) so the GG's speed table
//!     tracks *measured* heterogeneity; a `None` assignment means "skip";
//!  3. `WaitArmed`, then run the group mean-all-reduce over the
//!     [`WorkerMesh`] following the GG's placement plan: a flat
//!     (bandwidth-ordered) ring, or the two-level hierarchical
//!     collective when a `--topo` map puts the group on several nodes;
//!  4. the lowest drafted rank reports `Complete`; everyone else
//!     blocks on `WaitDone` so their next `Sync` cannot re-observe the
//!     group at the front of their Group Buffer.
//!
//! # Staged step pipeline
//!
//! The worker step is a three-stage pipeline over the shared
//! [`crate::step`] queues (DESIGN.md §Perf, "Staged step pipeline"):
//!
//! * **load** — with `--prefetch N > 0` a loader thread keeps the next
//!   `N` mini-batches ready in a bounded queue (recycled
//!   [`LoadedBatch`] buffers circulate back through a spare queue);
//!   `--load-ms` emulates per-batch I/O. `--prefetch 0` (default) draws
//!   batches inline, bit-identical to the pre-pipeline loop.
//! * **compute** — one SGD step on whatever batch is ready, timed and
//!   EWMA-folded (the queue wait counts: it is what this worker's step
//!   actually costs).
//! * **reconcile** — consume finished P-Reduce shards and fold them
//!   into the live model (the overlap engine below).
//!
//! The driver loop polls the stage queues instead of running straight
//! line; per-stage stall time is reported as `load_wait=` /
//! `compute_wait=` / `reconcile_wait=` on the REPORT line.
//!
//! # Compute/communication overlap
//!
//! With `--max-staleness S > 0` step 3 stops being stop-and-wait: a
//! dedicated *comm thread* (borrowing the GG connection for the
//! duration) arms the group and runs the ring schedule pipelined over
//! `--overlap-shards K` shards of a model snapshot, while the training
//! thread keeps taking up to `S` SGD steps on the live weights. Finished shards stream back and are
//! reconciled between steps with the bounded-staleness apply
//! (`collectives::pipeline::reconcile_shard`: group average plus the
//! local progress made in flight). `S = 0` (the default) is the serial
//! loop above, bit-for-bit. All members of a cluster must run the same
//! `K`: shard step tags are part of the wire schedule. Shards cross the
//! comm→training boundary through a poison-aware bounded queue
//! ([`crate::step::Bounded`]): an abort poisons the queue, the training
//! side drains the shards that fully averaged (valid group means) and
//! then observes the fault — fault propagation across every stage
//! boundary takes the same shape.
//!
//! Termination mirrors the threaded runtime: `Retire`, then keep syncing
//! until the Group Buffer drains — partners of already-scheduled groups
//! would otherwise block forever on our membership. The drain always
//! executes serially (no stale steps are allowed after the timed window).
//!
//! # Crash tolerance
//!
//! A heartbeat thread proves the rank alive on its own GG connection.
//! When a collective breaks (peer socket error, or a `Poison` frame
//! relayed around the ring), the worker rolls back to its pre-collective
//! snapshot, poisons downstream, reports `AbortGroup` (accusing the peer
//! it saw fail), and retries at its next sync in a repaired group.
//! `--ckpt-every`/`--ckpt-dir` snapshot the model + trainer state; a
//! `--rejoin` replacement restores the freshest snapshot in the shared
//! directory and re-registers its new data-plane address, which peers
//! re-resolve through the GG's `Lookup` registry.

use std::io::BufRead;
use std::io::Write as _;
use std::net::SocketAddr;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::collectives::codec::WireCodec;
use crate::collectives::hier::{hier_leader, hier_member};
use crate::collectives::pipeline::{
    reconcile_shard, ring_allreduce_sharded, shard_bounds, OverlapConfig,
};
use crate::config::AlgoKind;
use crate::model::mlp::{loss_only, sgd_step, MlpScratch, MlpSpec};
use crate::model::{BatchProducer, Dataset, LoadedBatch};
use crate::rpc::{GgClient, GroupState, WaitOutcome};
use crate::step::{self, Bounded, CloseGuard, QueueEnd, Stage};
use crate::topo::SyncPlan;

use super::ckpt;
use super::mesh::{HierRole, TcpRingTransport, WorkerMesh};

/// Everything one worker process needs (built from CLI flags by
/// `ripples worker`, or directly by tests).
#[derive(Debug, Clone)]
pub struct WorkerParams {
    pub rank: usize,
    pub n_workers: usize,
    /// Group Generator RPC address.
    pub gg_addr: String,
    /// Wall-clock training budget; iteration counts over a fixed window
    /// are the heterogeneity metric (`EXPERIMENTS.md §Deployment-run`).
    pub secs: f64,
    /// Hard cap on iterations (safety net for tests).
    pub max_iters: u64,
    /// Compute slowdown factor for *this* worker (1.0 = fast).
    pub slowdown: f64,
    /// Mid-run speed changes: `(factor, start_iter)` — once the local
    /// iteration count reaches `start_iter`, `factor` replaces the
    /// static `slowdown` (the entry with the largest active start wins).
    /// Built from `--slow-schedule` by the launcher.
    pub slow_schedule: Vec<(f64, u64)>,
    /// Emulated per-iteration device time; the tiny MLP alone is too fast
    /// for a slowdown to be observable.
    pub compute_floor: Duration,
    pub seed: u64,
    pub lr: f32,
    pub batch: usize,
    /// Non-IID shard skew (probability of drawing the worker's primary
    /// class); makes synchronization statistically observable.
    pub data_bias: f64,
    /// Use the tiny test MLP instead of the paper-default shape.
    pub tiny: bool,
    pub dataset_size: usize,
    pub eval_size: usize,
    /// Pipelined-collective knobs (`--overlap-shards`/`--max-staleness`);
    /// the serial default reproduces the pre-overlap loop bit-for-bit.
    pub overlap: OverlapConfig,
    /// Loader-stage queue depth (`--prefetch`): mini-batches kept ready
    /// ahead of compute by a dedicated loader thread. 0 (default) draws
    /// batches inline — bit-identical to the pre-pipeline loop.
    pub prefetch: usize,
    /// Emulated per-batch I/O latency (`--load-ms`): the loader sleeps
    /// this long per batch (inline draws sleep it on the training
    /// thread), making a slow data source observable on the tiny
    /// synthetic datasets. Zero by default.
    pub load_floor: Duration,
    /// Wire codec this worker *sends* collective chunks with (`--wire`);
    /// receivers decode whatever codec arrives, but the whole cluster
    /// should agree. The `fp32` default is byte-identical to the
    /// pre-codec wire.
    pub wire: WireCodec,
    /// Heartbeat period for the liveness beacon thread (0 = no thread —
    /// the GG then sees this worker only through its Sync traffic).
    pub heartbeat_ms: u64,
    /// How long to wait for ring edges before polling the GG "was the
    /// group aborted? did a member rejoin elsewhere?" while acquiring a
    /// collective's transport.
    pub probe_ms: u64,
    /// Snapshot the model + trainer state every this many iterations
    /// (0 = never) into `ckpt_dir`.
    pub ckpt_every: u64,
    /// Shared checkpoint directory (see `net::ckpt`).
    pub ckpt_dir: Option<PathBuf>,
    /// This process replaces a crashed rank: restore the freshest
    /// checkpoint in `ckpt_dir` and `Rejoin` instead of `Register`.
    pub rejoin: bool,
    /// Which data-plane algorithm this worker runs (`--algo`):
    /// GG-scheduled Ripples/all-reduce (the default), AD-PSGD pairwise
    /// averaging, or the parameter-server client loop.
    pub algo: AlgoKind,
    /// Parameter-server address (`--ps`); required when
    /// `algo == ParameterServer`, ignored otherwise.
    pub ps_addr: Option<String>,
    /// Key-range shard count for PS push/pull framing (`--ps-shards`);
    /// every worker and the server must agree.
    pub ps_shards: usize,
}

impl Default for WorkerParams {
    fn default() -> Self {
        Self {
            rank: 0,
            n_workers: 2,
            gg_addr: "127.0.0.1:7777".into(),
            secs: 5.0,
            max_iters: u64::MAX,
            slowdown: 1.0,
            slow_schedule: Vec::new(),
            compute_floor: Duration::from_millis(5),
            seed: 42,
            lr: 0.1,
            batch: 32,
            data_bias: 0.5,
            tiny: true,
            dataset_size: 2048,
            eval_size: 256,
            overlap: OverlapConfig::serial(),
            prefetch: 0,
            load_floor: Duration::ZERO,
            wire: WireCodec::Fp32,
            heartbeat_ms: 200,
            probe_ms: 200,
            ckpt_every: 0,
            ckpt_dir: None,
            rejoin: false,
            algo: AlgoKind::RipplesSmart,
            ps_addr: None,
            ps_shards: 4,
        }
    }
}

impl WorkerParams {
    /// Effective slowdown factor at local iteration `iter` (shared
    /// schedule semantics: `cluster::scheduled_factor_at`).
    pub fn slowdown_at(&self, iter: u64) -> f64 {
        crate::cluster::scheduled_factor_at(
            self.slow_schedule.iter().copied(),
            self.slowdown,
            iter,
        )
    }

    /// The generous io budget shared by the GG control plane and the
    /// data plane: a worker can legitimately sit behind a peer with most
    /// of its timed window left, but a *crashed* peer must surface as an
    /// error instead of hanging the cluster.
    pub fn io_timeout(&self) -> Duration {
        Duration::from_secs_f64((self.secs * 4.0).max(60.0))
    }
}

/// Parse a worker-local `F@ITER[,F@ITER...]` slowdown schedule (the
/// per-rank form the launcher derives from `--slow-schedule W,F@ITER`).
pub fn parse_worker_schedule(s: &str) -> Result<Vec<(f64, u64)>> {
    let mut out = Vec::new();
    for part in s.split(',').filter(|p| !p.trim().is_empty()) {
        let part = part.trim();
        let (f, iter) = part
            .split_once('@')
            .with_context(|| format!("bad schedule entry {part:?}: expected F@ITER"))?;
        out.push((
            f.trim().parse().with_context(|| format!("bad factor in {part:?}"))?,
            iter.trim().parse().with_context(|| format!("bad iteration in {part:?}"))?,
        ));
    }
    Ok(out)
}

/// Render a worker-local schedule back into the `F@ITER[,...]` flag form.
pub fn format_worker_schedule(schedule: &[(f64, u64)]) -> String {
    schedule
        .iter()
        .map(|(f, i)| format!("{f}@{i}"))
        .collect::<Vec<_>>()
        .join(",")
}

/// What a worker measured over its run.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerReport {
    pub rank: usize,
    /// Iterations completed inside the timed window (drain excluded;
    /// overlap's stale steps included — they are real SGD steps).
    pub iters: u64,
    /// P-Reduce collectives this worker participated in (drain included).
    pub preduces: u64,
    /// Subset of `preduces` that ran the two-level hierarchical
    /// collective (multi-node `SyncPlan` from a `--topo`-configured GG);
    /// 0 when every group ran a flat ring.
    pub hier_preduces: u64,
    pub loss_first: f64,
    pub loss_last: f64,
    pub secs: f64,
    /// Final EWMA step duration, the same value piggybacked to the GG
    /// (0.0 when the worker completed no timed iteration).
    pub ewma_secs: f64,
    /// SGD steps taken on stale weights while a collective was in flight
    /// (0 in serial mode).
    pub stale_steps: u64,
    /// Wall-clock seconds the training thread spent *blocked* on
    /// synchronization (exposed sync): the whole collective in serial
    /// mode; only the un-overlapped remainder with staleness enabled.
    pub sync_blocked_secs: f64,
    /// Collectives this worker unwound from because the group was
    /// aborted by failure repair (each was retried in a repaired group).
    pub aborts: u64,
    /// Load-stage stall: seconds the compute stage spent waiting for a
    /// mini-batch (queue pop wait when staged; inline batch synthesis
    /// plus the `--load-ms` floor when `--prefetch 0`).
    pub load_wait_secs: f64,
    /// Compute-stage stall seen by the loader: seconds the loader
    /// thread spent blocked on backpressure (full batch queue) or
    /// waiting for a recycled buffer. 0 when `--prefetch 0`.
    pub compute_wait_secs: f64,
    /// Reconcile-stage stall: seconds the training thread spent blocked
    /// on the collective/shard queue — the stage-named view of
    /// `sync_blocked_secs` (the two report the same measurement).
    pub reconcile_wait_secs: f64,
    /// Data-plane frame bytes sent (chunk + poison frames, prefixes
    /// included) — the wire codec's compression shows up directly here.
    pub bytes_tx: u64,
    /// Data-plane frame bytes received.
    pub bytes_rx: u64,
}

impl WorkerReport {
    /// One-line stdout encoding consumed by `launch` (`REPORT k=v ...`).
    pub fn to_line(&self) -> String {
        format!(
            "REPORT rank={} iters={} preduces={} loss_first={:.6} loss_last={:.6} \
             secs={:.3} ewma={:.6} stale={} sync_secs={:.6} aborts={} tx={} rx={} \
             load_wait={:.6} compute_wait={:.6} reconcile_wait={:.6} hier={}",
            self.rank,
            self.iters,
            self.preduces,
            self.loss_first,
            self.loss_last,
            self.secs,
            self.ewma_secs,
            self.stale_steps,
            self.sync_blocked_secs,
            self.aborts,
            self.bytes_tx,
            self.bytes_rx,
            self.load_wait_secs,
            self.compute_wait_secs,
            self.reconcile_wait_secs,
            self.hier_preduces
        )
    }

    pub fn parse_line(line: &str) -> Result<Self> {
        let mut rank = None;
        let mut iters = None;
        let mut preduces = None;
        let mut loss_first = None;
        let mut loss_last = None;
        let mut secs = None;
        let mut ewma_secs = 0.0; // optional: absent in pre-telemetry lines
        let mut stale_steps = 0; // optional: absent in pre-overlap lines
        let mut sync_blocked_secs = 0.0; // optional, ditto
        let mut aborts = 0; // optional: absent in pre-fault-tolerance lines
        let mut bytes_tx = 0; // optional: absent in pre-codec lines
        let mut bytes_rx = 0; // optional, ditto
        let mut load_wait_secs = 0.0; // optional: absent in pre-pipeline lines
        let mut compute_wait_secs = 0.0; // optional, ditto
        let mut reconcile_wait_secs = 0.0; // optional, ditto
        let mut hier_preduces = 0; // optional: absent in pre-topology lines
        // Strict prefix check: a garbled/truncated line used to degrade
        // to an empty report via `unwrap_or("")` and surface as a table
        // full of zeros instead of an error.
        let body = line
            .trim()
            .strip_prefix("REPORT ")
            .ok_or_else(|| anyhow!("not a REPORT line: {line:?}"))?;
        for kv in body.split_whitespace() {
            let (k, v) = kv.split_once('=').with_context(|| format!("bad field {kv:?}"))?;
            match k {
                "rank" => rank = Some(v.parse()?),
                "iters" => iters = Some(v.parse()?),
                "preduces" => preduces = Some(v.parse()?),
                "loss_first" => loss_first = Some(v.parse()?),
                "loss_last" => loss_last = Some(v.parse()?),
                "secs" => secs = Some(v.parse()?),
                "ewma" => ewma_secs = v.parse()?,
                "stale" => stale_steps = v.parse()?,
                "sync_secs" => sync_blocked_secs = v.parse()?,
                "aborts" => aborts = v.parse()?,
                "tx" => bytes_tx = v.parse()?,
                "rx" => bytes_rx = v.parse()?,
                "load_wait" => load_wait_secs = v.parse()?,
                "compute_wait" => compute_wait_secs = v.parse()?,
                "reconcile_wait" => reconcile_wait_secs = v.parse()?,
                "hier" => hier_preduces = v.parse()?,
                _ => {} // forward-compatible: ignore unknown fields
            }
        }
        match (rank, iters, preduces, loss_first, loss_last, secs) {
            (Some(rank), Some(iters), Some(preduces), Some(lf), Some(ll), Some(secs)) => {
                Ok(Self {
                    rank,
                    iters,
                    preduces,
                    hier_preduces,
                    loss_first: lf,
                    loss_last: ll,
                    secs,
                    ewma_secs,
                    stale_steps,
                    sync_blocked_secs,
                    aborts,
                    bytes_tx,
                    bytes_rx,
                    load_wait_secs,
                    compute_wait_secs,
                    reconcile_wait_secs,
                })
            }
            _ => bail!("incomplete report line: {line:?}"),
        }
    }
}

/// The per-step training state shared by the main loop and the overlap
/// engine's stale steps: one call = one timed SGD step (batch draw,
/// update, heterogeneity sleep, EWMA fold) on whatever buffer is passed.
pub(crate) struct SgdDriver<'a> {
    pub(crate) p: &'a WorkerParams,
    pub(crate) spec: &'a MlpSpec,
    pub(crate) ds: &'a Dataset,
    pub(crate) class_index: &'a [Vec<usize>],
    pub(crate) scratch: MlpScratch,
    /// Local iteration count (drives batch tags and the slow schedule).
    pub(crate) iters: u64,
    /// Measured step-duration EWMA, piggybacked on every Sync.
    pub(crate) ewma_secs: f64,
    /// Accumulated load-stage stall: time spent obtaining batches
    /// (inline synthesis + `--load-ms` floor, or staged queue waits).
    pub(crate) load_wait_secs: f64,
}

impl SgdDriver<'_> {
    /// The batch tag for local iteration `iter` of rank `rank`: the
    /// loader stage must reproduce this stream exactly, so the formula
    /// lives in one place.
    pub(crate) fn batch_tag(seed: u64, rank: usize, iter: u64) -> u64 {
        seed.wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(((rank as u64) << 32) | iter)
    }

    /// Inline (lockstep) step: draw the batch on this thread, then
    /// compute. Bit-identical to the pre-pipeline loop when
    /// `load_floor` is zero — the load segment is only *metered*.
    pub(crate) fn step(&mut self, flat: &mut [f32]) {
        let step_start = Instant::now();
        let tag = Self::batch_tag(self.p.seed, self.p.rank, self.iters);
        let (x, y) = self.ds.batch_biased(
            tag,
            self.p.batch,
            self.p.rank % self.spec.classes,
            self.p.data_bias,
            self.class_index,
        );
        if self.p.load_floor > Duration::ZERO {
            std::thread::sleep(self.p.load_floor);
        }
        self.load_wait_secs += step_start.elapsed().as_secs_f64();
        self.compute_on(flat, &x, &y, step_start);
    }

    /// Compute on an already-loaded batch (the staged path): SGD step,
    /// heterogeneity sleep, EWMA fold. `step_start` is when the driver
    /// began waiting for the batch, so the EWMA measures what this
    /// worker's step actually costs — queue wait included.
    pub(crate) fn step_on(
        &mut self,
        flat: &mut [f32],
        batch: &LoadedBatch,
        step_start: Instant,
    ) {
        self.compute_on(flat, &batch.x, &batch.y, step_start);
    }

    fn compute_on(
        &mut self,
        flat: &mut [f32],
        x: &[f32],
        y: &[usize],
        step_start: Instant,
    ) {
        sgd_step(self.spec, flat, x, y, self.p.lr, &mut self.scratch);
        let factor = self.p.slowdown_at(self.iters);
        self.iters += 1;
        if self.p.compute_floor > Duration::ZERO {
            std::thread::sleep(self.p.compute_floor.mul_f64(factor));
        }
        let step_secs = step_start.elapsed().as_secs_f64();
        self.ewma_secs =
            crate::gg::ewma_step(self.ewma_secs, step_secs, crate::gg::SPEED_ALPHA);
    }
}

/// The loader stage: recycle the spent batch buffers, emulate the
/// configured I/O floor, fill the next batch of the deterministic tag
/// stream. Driven by [`step::spawn`] between the spare queue and the
/// batch queue.
struct BatchLoader {
    producer: BatchProducer,
    load_floor: Duration,
}

impl Stage for BatchLoader {
    type In = LoadedBatch;
    type Out = LoadedBatch;

    fn process(&mut self, spare: LoadedBatch) -> Result<LoadedBatch, String> {
        self.producer.recycle(spare);
        if self.load_floor > Duration::ZERO {
            thread::sleep(self.load_floor);
        }
        Ok(self.producer.produce())
    }
}

/// Where the compute stage gets its mini-batches: drawn inline
/// (`--prefetch 0`, today's lockstep loop bit-for-bit) or popped from
/// the loader stage's bounded queue.
pub(crate) enum BatchFeed {
    Inline,
    Staged {
        batches: Arc<Bounded<LoadedBatch>>,
        spares: Arc<Bounded<LoadedBatch>>,
        loader: Option<thread::JoinHandle<Result<(), String>>>,
    },
}

impl BatchFeed {
    /// Build the feed for one worker: spawns the loader thread when
    /// `prefetch > 0`, pre-seeding the spare queue so the loader starts
    /// filling immediately. `start_iter` aligns the loader's tag stream
    /// with a checkpoint-restored iteration counter.
    fn build(
        p: &WorkerParams,
        spec: &MlpSpec,
        ds: &Arc<Dataset>,
        class_index: &Arc<Vec<Vec<usize>>>,
        start_iter: u64,
    ) -> Self {
        if p.prefetch == 0 {
            return BatchFeed::Inline;
        }
        let depth = p.prefetch;
        let batches = Bounded::new(depth);
        // one more spare than the queue holds: the loader always has a
        // buffer to fill while `depth` finished batches sit queued
        let spares = Bounded::new(depth + 1);
        for _ in 0..=depth {
            let _ = spares.push(LoadedBatch::with_capacity(p.batch, spec.in_dim));
        }
        let (seed, rank) = (p.seed, p.rank);
        let mut iter = start_iter;
        let producer = BatchProducer::new(
            Arc::clone(ds),
            Arc::clone(class_index),
            p.batch,
            p.rank % spec.classes,
            p.data_bias,
            Box::new(move || {
                let tag = SgdDriver::batch_tag(seed, rank, iter);
                iter += 1;
                tag
            }),
        );
        let loader = step::spawn(
            BatchLoader { producer, load_floor: p.load_floor },
            Arc::clone(&spares),
            Arc::clone(&batches),
        );
        BatchFeed::Staged { batches, spares, loader: Some(loader) }
    }

    /// Shut the pipeline down: close both queues (waking a loader
    /// blocked on either) and join the loader thread. Returns the
    /// loader-side stall time (`compute_wait`: backpressure on the
    /// batch queue plus waiting for recycled buffers).
    fn shutdown(&mut self) -> f64 {
        match self {
            BatchFeed::Inline => 0.0,
            BatchFeed::Staged { batches, spares, loader } => {
                spares.close();
                batches.close();
                if let Some(h) = loader.take() {
                    let _ = h.join();
                }
                (batches.send_wait() + spares.recv_wait()).as_secs_f64()
            }
        }
    }
}

/// One pipelined step: pop a batch from the feed (metering the
/// load-stage stall) and compute on it. The inline feed delegates to
/// [`SgdDriver::step`] unchanged.
fn pipelined_step(
    drv: &mut SgdDriver<'_>,
    feed: &mut BatchFeed,
    flat: &mut [f32],
) -> Result<()> {
    match feed {
        BatchFeed::Inline => {
            drv.step(flat);
            Ok(())
        }
        BatchFeed::Staged { batches, spares, .. } => {
            let step_start = Instant::now();
            let batch = match batches.pop() {
                Ok(b) => b,
                Err(QueueEnd::Poisoned) => bail!("loader stage poisoned"),
                Err(QueueEnd::Closed) => bail!("loader stage ended early"),
            };
            drv.load_wait_secs += step_start.elapsed().as_secs_f64();
            drv.step_on(flat, &batch, step_start);
            let _ = spares.push(batch); // Err only during shutdown
            Ok(())
        }
    }
}

/// How one GG-assigned collective ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum GroupOutcome {
    /// Averaged and completed (the normal path).
    Done,
    /// The group was aborted by failure repair: the model was restored
    /// (serial) or left with only fully-averaged shards (overlap), and
    /// the worker should retry at its next sync in a repaired group.
    Aborted,
}

/// Liveness beacon: a background thread proving this rank alive to the
/// GG on its own connection, so a worker blocked inside a long
/// collective is not mistaken for a crash. Joined on drop.
pub(crate) struct Heartbeat {
    stop: Arc<AtomicBool>,
    handle: Option<thread::JoinHandle<()>>,
}

impl Heartbeat {
    /// No-op guard when `period_ms == 0` or the GG is unreachable.
    pub(crate) fn spawn(addr: &str, rank: usize, period_ms: u64, io: Duration) -> Self {
        let stop = Arc::new(AtomicBool::new(false));
        if period_ms == 0 {
            return Self { stop, handle: None };
        }
        let stop2 = Arc::clone(&stop);
        let addr = addr.to_string();
        let handle = thread::spawn(move || {
            let Ok(mut gg) = GgClient::connect(&addr) else { return };
            let _ = gg.set_io_timeout(io);
            let period = Duration::from_millis(period_ms);
            while !stop2.load(Ordering::Relaxed) {
                if gg.heartbeat(rank).is_err() {
                    return; // server gone: the worker will notice too
                }
                thread::sleep(period);
            }
        });
        Self { stop, handle: Some(handle) }
    }
}

impl Drop for Heartbeat {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Run the distributed training loop over an already-bound mesh and a
/// connected GG client.
pub fn run_worker(
    p: &WorkerParams,
    mesh: &WorkerMesh,
    gg: &mut GgClient,
) -> Result<WorkerReport> {
    p.overlap.validate().map_err(|e| anyhow!("bad overlap config: {e}"))?;
    step::PipelineConfig { prefetch: p.prefetch, load_secs: p.load_floor.as_secs_f64() }
        .validate()
        .map_err(|e| anyhow!("bad pipeline config: {e}"))?;
    let spec = if p.tiny { MlpSpec::tiny() } else { MlpSpec::default_paper() };
    // Shared dataset and identical init across the cluster: seeds must
    // not depend on rank (P-Reduce averages replicas of one model).
    // Arc'd so the loader stage can share them with the training thread.
    let ds = Arc::new(Dataset::gaussian_mixture(
        spec.in_dim,
        spec.classes,
        p.dataset_size,
        p.seed ^ 0xDA7A,
    ));
    let class_index = Arc::new(ds.class_index());
    let (ex, ey) = ds.eval_set(p.eval_size);
    let mut flat = spec.init(p.seed ^ 1);
    let mut restored_iter = 0u64;
    let mut restored_ewma = 0.0f64;

    // ---- membership: advertise the data-plane address; a rejoiner
    // additionally purges its old incarnation and restores the freshest
    // checkpoint any peer wrote (net::ckpt — "seed from the freshest
    // live peer").
    let own_addr = mesh.local_addr().to_string();
    if p.rejoin {
        gg.rejoin(p.rank, &own_addr)?;
        let dir = p
            .ckpt_dir
            .as_ref()
            .context("--rejoin needs --ckpt-dir to restore from")?;
        match ckpt::latest(dir)? {
            Some(c) => {
                if c.weights.len() != flat.len() {
                    bail!(
                        "checkpoint has {} weights, model has {} — wrong --model?",
                        c.weights.len(),
                        flat.len()
                    );
                }
                flat.copy_from_slice(&c.weights);
                restored_iter = c.iter;
                restored_ewma = c.ewma_secs;
            }
            None => eprintln!(
                "worker {}: no checkpoint in {}, rejoining from fresh init",
                p.rank,
                dir.display()
            ),
        }
    } else {
        gg.register(p.rank, &own_addr)?;
    }
    let _beacon = Heartbeat::spawn(&p.gg_addr, p.rank, p.heartbeat_ms, p.io_timeout());

    let loss_first = loss_only(&spec, &flat, &ex, &ey);
    let mut drv = SgdDriver {
        p,
        spec: &spec,
        ds: &*ds,
        class_index: class_index.as_slice(),
        scratch: MlpScratch::new(),
        iters: restored_iter,
        ewma_secs: restored_ewma,
        load_wait_secs: 0.0,
    };
    // loader stage (no-op Inline feed when --prefetch 0); the tag stream
    // starts at the restored iteration so a rejoiner's batches line up
    let mut feed = BatchFeed::build(p, &spec, &ds, &class_index, restored_iter);

    let overlap_active = !p.overlap.is_serial();
    let mut preduces = 0u64;
    let mut hier_preduces = 0u64;
    let mut stale_steps = 0u64;
    let mut sync_blocked = 0.0f64;
    let mut aborts = 0u64;
    // pre-collective snapshot reused across groups: a broken serial
    // collective leaves partial reduce-scatter sums in `flat`, which must
    // be rolled back before retrying in a repaired group
    let mut abort_snap: Vec<f32> = Vec::new();
    let start = Instant::now();
    let iter_budget = p.max_iters.saturating_add(restored_iter);
    while start.elapsed().as_secs_f64() < p.secs && drv.iters < iter_budget {
        // ---- load + compute phases (timestamped, EWMA-folded)
        pipelined_step(&mut drv, &mut feed, &mut flat)?;
        if p.ckpt_every > 0 && drv.iters % p.ckpt_every == 0 {
            if let Some(dir) = &p.ckpt_dir {
                ckpt::save(
                    dir,
                    &ckpt::Checkpoint {
                        rank: p.rank as u32,
                        iter: drv.iters,
                        ewma_secs: drv.ewma_secs,
                        weights: flat.clone(),
                    },
                )?;
            }
        }
        // ---- sync phase (EWMA rides along as the SpeedReport)
        let (assigned, _newly_armed) = gg.sync(p.rank, drv.ewma_secs)?;
        if let Some((gid, members, plan)) = assigned {
            let outcome = if overlap_active {
                let (stale, blocked, outcome) = execute_group_overlapped(
                    p, mesh, gg, gid, &members, &plan, &mut flat, &mut drv, &mut feed,
                    start, iter_budget,
                )?;
                stale_steps += stale;
                sync_blocked += blocked;
                outcome
            } else {
                let t0 = Instant::now();
                let outcome = execute_group(
                    p, mesh, gg, gid, &members, &plan, &mut flat, &mut abort_snap,
                )?;
                sync_blocked += t0.elapsed().as_secs_f64();
                outcome
            };
            match outcome {
                GroupOutcome::Done => {
                    preduces += 1;
                    if !plan.is_flat() {
                        hier_preduces += 1;
                    }
                }
                // repaired at the GG: the next sync drafts a fresh group
                GroupOutcome::Aborted => aborts += 1,
            }
        }
    }
    let timed = start.elapsed().as_secs_f64();
    let iters = drv.iters - restored_iter;

    // ---- termination protocol: retire, then drain the Group Buffer.
    // The drain is always serial: the timed window is over, so there is
    // no compute left to hide transfers behind.
    gg.retire(p.rank)?;
    loop {
        let (assigned, _) = gg.sync(p.rank, drv.ewma_secs)?;
        match assigned {
            None => break,
            Some((gid, members, plan)) => {
                match execute_group(p, mesh, gg, gid, &members, &plan, &mut flat, &mut abort_snap)?
                {
                    GroupOutcome::Done => {
                        preduces += 1;
                        if !plan.is_flat() {
                            hier_preduces += 1;
                        }
                    }
                    GroupOutcome::Aborted => aborts += 1,
                }
            }
        }
    }

    // loader stage shutdown: collect its stall meters before reporting
    let compute_wait = feed.shutdown();

    let loss_last = loss_only(&spec, &flat, &ex, &ey);
    Ok(WorkerReport {
        rank: p.rank,
        iters,
        preduces,
        hier_preduces,
        loss_first,
        loss_last,
        secs: timed,
        ewma_secs: drv.ewma_secs,
        stale_steps,
        sync_blocked_secs: sync_blocked,
        aborts,
        load_wait_secs: drv.load_wait_secs,
        compute_wait_secs: compute_wait,
        reconcile_wait_secs: sync_blocked,
        bytes_tx: mesh.bytes_sent(),
        bytes_rx: mesh.bytes_recv(),
    })
}

/// Wait for the group's ring edges with bounded patience: between waits,
/// ask the GG whether the group was aborted (a member died before
/// arriving) and re-resolve member addresses (a member may have rejoined
/// at a new one). `Ok(None)` = group aborted/completed — skip it.
fn acquire_transport(
    p: &WorkerParams,
    mesh: &WorkerMesh,
    gg: &mut GgClient,
    gid: u64,
    members: &[usize],
) -> Result<Option<(TcpRingTransport, usize)>> {
    let wait = Duration::from_millis(p.probe_ms.max(1));
    let deadline = Instant::now() + p.io_timeout();
    loop {
        if let Some(pair) = mesh.try_ring_transport(gid, members, wait)? {
            return Ok(Some(pair));
        }
        match gg.probe(gid)? {
            GroupState::Aborted | GroupState::Done => return Ok(None),
            GroupState::Armed | GroupState::Pending => {}
        }
        for &m in members {
            if m != p.rank {
                if let Some(addr) = gg.lookup(m)? {
                    if let Ok(parsed) = addr.parse() {
                        mesh.update_peer(m, parsed);
                    }
                }
            }
        }
        if Instant::now() >= deadline {
            bail!(
                "group {gid}: ring edges not established within {:?} ({members:?})",
                p.io_timeout()
            );
        }
    }
}

/// A collective failed under us: restore nothing here (callers decide),
/// but poison downstream so the ring unwinds, drop the broken edge, and
/// report the abort (accusing the peer whose socket failed, if any).
fn unwind_broken_collective(
    mesh: &WorkerMesh,
    gg: &mut GgClient,
    gid: u64,
    transport: &mut TcpRingTransport,
) -> Result<()> {
    transport.poison();
    let suspect = transport.failed_peer();
    if let Some(r) = suspect {
        mesh.invalidate(r);
    }
    gg.abort_group(gid, suspect)
}

/// [`acquire_transport`], hierarchical edition: wait for every edge of
/// the two-level plan this rank participates in (member↔leader duplex,
/// plus the inter-node leader ring when this rank leads its node), with
/// the same bounded probe/re-resolve loop.
fn acquire_hier_transport(
    p: &WorkerParams,
    mesh: &WorkerMesh,
    gg: &mut GgClient,
    gid: u64,
    members: &[usize],
    plan: &SyncPlan,
) -> Result<Option<HierRole>> {
    let wait = Duration::from_millis(p.probe_ms.max(1));
    let deadline = Instant::now() + p.io_timeout();
    loop {
        if let Some(role) = mesh.try_hier_transport(gid, plan, wait)? {
            return Ok(Some(role));
        }
        match gg.probe(gid)? {
            GroupState::Aborted | GroupState::Done => return Ok(None),
            GroupState::Armed | GroupState::Pending => {}
        }
        for &m in members {
            if m != p.rank {
                if let Some(addr) = gg.lookup(m)? {
                    if let Ok(parsed) = addr.parse() {
                        mesh.update_peer(m, parsed);
                    }
                }
            }
        }
        if Instant::now() >= deadline {
            bail!(
                "group {gid}: hierarchical edges not established within {:?} ({:?})",
                p.io_timeout(),
                plan.nodes
            );
        }
    }
}

/// [`unwind_broken_collective`] for the two-level collective: poison
/// *every* live edge of the tree — intra-node duplexes and the leader
/// ring — so both levels unwind, then accuse the peer whose socket
/// actually failed (if any) and report the abort.
fn unwind_broken_hier(
    mesh: &WorkerMesh,
    gg: &mut GgClient,
    gid: u64,
    role: &mut HierRole,
) -> Result<()> {
    role.poison_all();
    let suspect = role.failed_peer();
    if let Some(r) = suspect {
        mesh.invalidate(r);
    }
    gg.abort_group(gid, suspect)
}

/// One *attempt* at a GG-assigned collective — the arm/acquire/run/
/// unwind skeleton shared by the serial and overlapped paths. Waits for
/// the group to arm, acquires transports for the GG's placement plan,
/// runs the collective over `buf` (streaming each finished shard through
/// `on_shard`), and on a broken collective hands `buf` to `on_broken`
/// (the caller's rollback policy) before poisoning downstream and
/// reporting the abort — so a mid-collective failure recovers
/// identically on both paths.
///
/// Plan dispatch: a single-node plan runs the flat sharded ring in the
/// plan's (bandwidth-ordered) member order — every member received the
/// *same* frozen plan from the GG, so the schedules agree. A multi-node
/// plan runs the two-level collective: intra-node gather to the node
/// leader, inter-node ring over the leaders, intra-node broadcast back
/// ([`crate::collectives::hier`]).
///
/// Completion protocol: the lowest drafted rank reports `Complete`
/// (independent of the plan's ring order, so flat and hierarchical
/// groups retire identically), everyone else blocks on `WaitDone` (an
/// abort *there* means that rank died after the collective — the
/// averaged data is fine either way).
#[allow(clippy::too_many_arguments)]
fn collective_attempt(
    p: &WorkerParams,
    mesh: &WorkerMesh,
    gg: &mut GgClient,
    gid: u64,
    members: &[usize],
    plan: &SyncPlan,
    buf: &mut [f32],
    shards: usize,
    on_shard: impl FnMut(usize, &[f32]),
    on_broken: impl FnOnce(&mut [f32]),
) -> Result<GroupOutcome> {
    if members.len() < 2 {
        bail!("GG assigned degenerate group {members:?}");
    }
    plan.validate(members)
        .map_err(|e| anyhow!("group {gid}: bad plan from GG: {e}"))?;
    if gg.wait_armed(gid)? == WaitOutcome::Aborted {
        return Ok(GroupOutcome::Aborted);
    }
    if plan.is_flat() {
        // Degenerate (single-node) plan: flat ring, but in the plan's
        // order — bandwidth-ordered when the GG has speed measurements,
        // so the slowest link is crossed exactly once per chunk stream.
        let order = plan.ring_order();
        let Some((mut transport, pos)) = acquire_transport(p, mesh, gg, gid, &order)? else {
            return Ok(GroupOutcome::Aborted);
        };
        let run =
            ring_allreduce_sharded(pos, order.len(), buf, shards, &mut transport, on_shard);
        if run.is_err() {
            // partial reduce-scatter sums are garbage: let the caller
            // roll back, then unwind the ring and report so everyone
            // retries
            on_broken(buf);
            unwind_broken_collective(mesh, gg, gid, &mut transport)?;
            return Ok(GroupOutcome::Aborted);
        }
    } else {
        let Some(mut role) = acquire_hier_transport(p, mesh, gg, gid, members, plan)? else {
            return Ok(GroupOutcome::Aborted);
        };
        let p_total = plan.total();
        let run = match &mut role {
            HierRole::Member { link } => hier_member(link, buf, shards, on_shard),
            HierRole::Leader { members: links, ring } => hier_leader(
                links,
                ring.as_mut().map(|(t, pos, leaders)| (t, *pos, *leaders)),
                p_total,
                buf,
                shards,
                on_shard,
            ),
        };
        if run.is_err() {
            on_broken(buf);
            unwind_broken_hier(mesh, gg, gid, &mut role)?;
            return Ok(GroupOutcome::Aborted);
        }
    }
    if members[0] == p.rank {
        gg.complete(gid)?;
    } else {
        let _ = gg.wait_done(gid)?;
    }
    Ok(GroupOutcome::Done)
}

/// One GG-assigned P-Reduce, stop-and-wait: snapshot, then run one
/// [`collective_attempt`] in place over the live weights. With the
/// default single shard this is the exact pre-overlap schedule, frames
/// and arithmetic identical. A collective broken by a crashed peer rolls
/// the model back to `snapshot` and returns [`GroupOutcome::Aborted`]
/// instead of erroring: the next sync retries in a repaired group.
fn execute_group(
    p: &WorkerParams,
    mesh: &WorkerMesh,
    gg: &mut GgClient,
    gid: u64,
    members: &[usize],
    plan: &SyncPlan,
    flat: &mut [f32],
    snapshot: &mut Vec<f32>,
) -> Result<GroupOutcome> {
    snapshot.clear();
    snapshot.extend_from_slice(flat);
    collective_attempt(
        p,
        mesh,
        gg,
        gid,
        members,
        plan,
        flat,
        p.overlap.shards,
        |_, _| (),
        |buf| buf.copy_from_slice(snapshot),
    )
}

/// One GG-assigned P-Reduce with compute/communication overlap: the comm
/// thread runs the pipelined ring over a model *snapshot* and streams
/// finished shards back; the training thread keeps stepping on the live
/// weights (up to `max_staleness` steps) and reconciles each finished
/// shard with the bounded-staleness apply. The GG connection is lent to
/// the comm thread for the duration (wait-armed/complete/wait-done are
/// its only RPCs in flight — the training thread's next `Sync` happens
/// strictly after the join). Returns `(stale_steps_taken,
/// seconds_blocked, outcome)`.
///
/// An abort mid-pipeline keeps the shards that fully averaged (they are
/// valid group means, already reconciled) and leaves the rest local —
/// members may disagree on *which* shards averaged, a bounded divergence
/// the next successful averaging contracts, exactly like stale-step
/// noise.
#[allow(clippy::too_many_arguments)]
fn execute_group_overlapped(
    p: &WorkerParams,
    mesh: &WorkerMesh,
    gg: &mut GgClient,
    gid: u64,
    members: &[usize],
    plan: &SyncPlan,
    flat: &mut [f32],
    drv: &mut SgdDriver<'_>,
    feed: &mut BatchFeed,
    start: Instant,
    iter_budget: u64,
) -> Result<(u64, f64, GroupOutcome)> {
    if members.len() < 2 {
        bail!("GG assigned degenerate group {members:?}");
    }
    let k = p.overlap.shards.max(1);
    let n = flat.len();
    // Two copies: `snap` is the reconcile reference the training thread
    // keeps; `work` is the buffer the comm thread averages in place.
    let snap = flat.to_vec();
    let mut work = flat.to_vec();
    // Finished shards cross the comm→training stage boundary through a
    // poison-aware bounded queue (capacity k: the comm thread never
    // blocks on a slow reconciler mid-ring).
    let shard_q: Arc<Bounded<(usize, Vec<f32>)>> = Bounded::new(k);
    let q_comm = Arc::clone(&shard_q);
    thread::scope(|scope| -> Result<(u64, f64, GroupOutcome)> {
        let comm = scope.spawn(move || -> Result<GroupOutcome> {
            // close on every exit path (including panics) so the
            // training thread's pop never hangs on a dead stage
            let _guard = CloseGuard(Arc::clone(&q_comm));
            let outcome = collective_attempt(
                p,
                mesh,
                gg,
                gid,
                members,
                plan,
                &mut work,
                k,
                |s, avg| {
                    // training thread gone = error already in flight; the
                    // collective itself must still finish for the peers
                    let _ = q_comm.push((s, avg.to_vec()));
                },
                // fully averaged shards were already streamed and stay
                // applied; un-averaged shards simply stay local
                |_| (),
            )?;
            if outcome == GroupOutcome::Aborted {
                // fault propagation across the stage boundary: the
                // training side drains valid shards, then observes this
                q_comm.poison();
            }
            Ok(outcome)
        });

        let mut applied = 0usize;
        let mut stale = 0u64;
        let mut blocked = 0.0f64;
        let mut step_err = None;
        let mut comm_ended = false;
        while applied < k && !comm_ended {
            // drain whatever shards already landed, without blocking
            // (pop/try_pop deliver queued shards even after a poison)
            loop {
                match shard_q.try_pop() {
                    Ok(Some((s, avg))) => {
                        let (lo, hi) = shard_bounds(n, k, s);
                        reconcile_shard(&mut flat[lo..hi], &snap[lo..hi], &avg);
                        applied += 1;
                    }
                    Ok(None) => break,
                    Err(_) => {
                        comm_ended = true; // done/aborted; join() knows which
                        break;
                    }
                }
            }
            if applied >= k || comm_ended {
                break;
            }
            // same budget as the main loop: max_iters offset by the
            // checkpoint-restored iteration count, so a rejoined worker
            // keeps hiding sync behind stale steps
            let budget_left = drv.iters < iter_budget
                && start.elapsed().as_secs_f64() < p.secs;
            if stale < p.overlap.max_staleness && budget_left {
                // hidden compute on (slightly) stale weights
                match pipelined_step(drv, feed, flat) {
                    Ok(()) => stale += 1,
                    Err(e) => {
                        // loader stage died: let the collective finish
                        // for the peers (pushes fail fast once closed),
                        // then surface the error after the join
                        shard_q.close();
                        step_err = Some(e);
                        break;
                    }
                }
            } else {
                // staleness bound reached: this is the *exposed* sync
                let t0 = Instant::now();
                let msg = shard_q.pop();
                blocked += t0.elapsed().as_secs_f64();
                match msg {
                    Ok((s, avg)) => {
                        let (lo, hi) = shard_bounds(n, k, s);
                        reconcile_shard(&mut flat[lo..hi], &snap[lo..hi], &avg);
                        applied += 1;
                    }
                    Err(_) => break, // comm thread done/aborted; join() knows
                }
            }
        }
        // completion protocol (leader Complete / member WaitDone) is also
        // exposed wait — the next Sync cannot run before it
        let t0 = Instant::now();
        let res = comm.join().map_err(|_| anyhow!("comm thread panicked"))?;
        blocked += t0.elapsed().as_secs_f64();
        let outcome = res?;
        if let Some(e) = step_err {
            return Err(e);
        }
        Ok((stale, blocked, outcome))
    })
}

/// Entry point for the `ripples worker` subcommand: performs the
/// stdout/stdin address handshake with the launcher (or uses `--peers`
/// when given explicitly), runs the loop, prints the report line.
pub fn worker_main(
    p: &WorkerParams,
    listen: &str,
    peers_flag: Option<&str>,
) -> Result<WorkerReport> {
    let mut mesh = WorkerMesh::bind(p.rank, listen)?;
    // Generous timeout on both planes: a worker can legitimately sit in
    // a collective (or a WaitArmed) behind a peer that still has most of
    // its timed window to train through — but a *crashed* peer must
    // surface as an error here instead of hanging the whole cluster.
    let io_timeout = p.io_timeout();
    mesh.io_timeout = io_timeout;
    mesh.wire = p.wire;
    println!("DATA_ADDR {}", mesh.local_addr());
    std::io::stdout().flush().ok();
    let peer_list = match peers_flag {
        Some(list) => list.to_string(),
        None => {
            // launcher replies with `PEERS addr0,addr1,...` on stdin
            let mut line = String::new();
            std::io::stdin()
                .lock()
                .read_line(&mut line)
                .context("read PEERS line from launcher")?;
            line.trim()
                .strip_prefix("PEERS ")
                .with_context(|| format!("expected PEERS line, got {line:?}"))?
                .to_string()
        }
    };
    let peers: Vec<SocketAddr> = peer_list
        .split(',')
        .map(|a| a.trim().parse().with_context(|| format!("bad peer address {a:?}")))
        .collect::<Result<_>>()?;
    if peers.len() != p.n_workers {
        bail!("expected {} peer addresses, got {}", p.n_workers, peers.len());
    }
    mesh.set_peers(peers);
    let report = match p.algo {
        // The PS client speaks only to the server process — no GG, no
        // mesh traffic (the mesh stays bound so the launcher handshake
        // is identical across algorithms).
        AlgoKind::ParameterServer => super::ps::run_ps_worker(p)?,
        AlgoKind::AdPsgd => {
            let mut gg = GgClient::connect(&p.gg_addr)
                .with_context(|| format!("connect to GG at {}", p.gg_addr))?;
            gg.set_io_timeout(io_timeout)?;
            super::adpsgd::run_adpsgd(p, &mesh, &mut gg)?
        }
        _ => {
            let mut gg = GgClient::connect(&p.gg_addr)
                .with_context(|| format!("connect to GG at {}", p.gg_addr))?;
            gg.set_io_timeout(io_timeout)?;
            run_worker(p, &mesh, &mut gg)?
        }
    };
    println!("{}", report.to_line());
    std::io::stdout().flush().ok();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_line_roundtrip() {
        let r = WorkerReport {
            rank: 3,
            iters: 120,
            preduces: 40,
            hier_preduces: 5,
            loss_first: 1.386294,
            loss_last: 0.25,
            secs: 4.002,
            ewma_secs: 0.024500,
            stale_steps: 17,
            sync_blocked_secs: 0.812500,
            aborts: 2,
            load_wait_secs: 0.137500,
            compute_wait_secs: 0.062500,
            reconcile_wait_secs: 0.812500,
            bytes_tx: 123456,
            bytes_rx: 654321,
        };
        let parsed = WorkerReport::parse_line(&r.to_line()).unwrap();
        assert_eq!(parsed, r);
    }

    #[test]
    fn report_parse_rejects_incomplete() {
        assert!(WorkerReport::parse_line("REPORT rank=1 iters=2").is_err());
        assert!(WorkerReport::parse_line("nonsense").is_err());
    }

    #[test]
    fn report_parse_rejects_corrupted_prefix() {
        // Regression: these used to parse as an *empty* report (prefix
        // strip fell back to ""), then fail only on missing fields with
        // a misleading error — or, worse, would have succeeded silently
        // had the required fields ever grown defaults. A mangled prefix
        // must be its own loud error naming the line.
        let good = "REPORT rank=0 iters=1 preduces=0 loss_first=1.0 \
                    loss_last=0.5 secs=1.0";
        assert!(WorkerReport::parse_line(good).is_ok());
        let bads: Vec<String> = vec![
            good[1..].to_string(),               // truncated: "EPORT rank=..."
            good.replace("REPORT ", "REPORT"),   // glued: "REPORTrank=..."
            format!("x{good}"),                  // garbage prepended
            String::new(),                       // empty line (dead worker)
        ];
        for bad in &bads {
            let err = WorkerReport::parse_line(bad).unwrap_err().to_string();
            assert!(err.contains("not a REPORT line"), "{bad:?} -> {err}");
        }
    }

    #[test]
    fn report_parse_ignores_unknown_fields() {
        let line = "REPORT rank=0 iters=1 preduces=0 loss_first=1.0 \
                    loss_last=0.5 secs=1.0 extra=9";
        assert_eq!(WorkerReport::parse_line(line).unwrap().iters, 1);
    }

    #[test]
    fn report_parse_tolerates_missing_optional_fields() {
        // pre-telemetry/pre-overlap line shape: optional fields default
        let line = "REPORT rank=0 iters=1 preduces=0 loss_first=1.0 \
                    loss_last=0.5 secs=1.0";
        let r = WorkerReport::parse_line(line).unwrap();
        assert_eq!(r.ewma_secs, 0.0);
        assert_eq!(r.stale_steps, 0);
        assert_eq!(r.sync_blocked_secs, 0.0);
        assert_eq!(r.aborts, 0);
        assert_eq!(r.bytes_tx, 0);
        assert_eq!(r.bytes_rx, 0);
        assert_eq!(r.load_wait_secs, 0.0);
        assert_eq!(r.compute_wait_secs, 0.0);
        assert_eq!(r.reconcile_wait_secs, 0.0);
    }

    #[test]
    fn slowdown_schedule_applies_latest_active_entry() {
        let p = WorkerParams {
            slowdown: 1.0,
            slow_schedule: vec![(3.0, 40), (1.0, 120)],
            ..WorkerParams::default()
        };
        assert_eq!(p.slowdown_at(0), 1.0);
        assert_eq!(p.slowdown_at(39), 1.0);
        assert_eq!(p.slowdown_at(40), 3.0); // straggler appears
        assert_eq!(p.slowdown_at(119), 3.0);
        assert_eq!(p.slowdown_at(120), 1.0); // recovery
    }

    #[test]
    fn worker_schedule_flag_roundtrip() {
        let sched = parse_worker_schedule("3.0@40,1.5@120").unwrap();
        assert_eq!(sched, vec![(3.0, 40), (1.5, 120)]);
        assert_eq!(format_worker_schedule(&sched), "3@40,1.5@120");
        assert_eq!(
            parse_worker_schedule(&format_worker_schedule(&sched)).unwrap(),
            sched
        );
        assert_eq!(parse_worker_schedule("").unwrap(), vec![]);
        assert!(parse_worker_schedule("3.0").is_err());
        assert!(parse_worker_schedule("x@3").is_err());
        assert!(parse_worker_schedule("3.0@x").is_err());
    }

    #[test]
    fn default_params_are_serial() {
        let p = WorkerParams::default();
        assert!(p.overlap.is_serial());
        assert_eq!(p.overlap.shards, 1);
        assert_eq!(p.prefetch, 0, "inline loader is the bit-identical default");
        assert_eq!(p.load_floor, Duration::ZERO);
        assert_eq!(p.wire, WireCodec::Fp32, "exact wire is the golden default");
        assert_eq!(p.ckpt_every, 0, "checkpointing is opt-in");
        assert!(!p.rejoin);
        assert!(p.heartbeat_ms > 0, "liveness beacon on by default");
    }

    #[test]
    fn heartbeat_guard_is_noop_without_period_or_server() {
        // period 0: no thread at all
        let hb = Heartbeat::spawn("127.0.0.1:1", 0, 0, Duration::from_secs(1));
        drop(hb);
        // unreachable server: the thread exits on its own; drop must not hang
        let hb = Heartbeat::spawn("127.0.0.1:1", 0, 50, Duration::from_secs(1));
        std::thread::sleep(Duration::from_millis(30));
        drop(hb);
    }
}
