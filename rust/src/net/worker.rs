//! One distributed worker process: pure-Rust MLP training with
//! GG-scheduled P-Reduce groups executing the chunked ring collective
//! over TCP (see DESIGN.md §Deployment).
//!
//! Protocol per iteration (the paper's Fig. 8 worker loop):
//!  1. one local SGD step (plus the heterogeneity sleep, whose factor
//!     may change mid-run via the `--slow-schedule` entries), timed and
//!     folded into an EWMA step duration;
//!  2. `Sync` with the Group Generator, piggybacking the EWMA as a
//!     [`SpeedReport`](crate::rpc::SpeedReport) so the GG's speed table
//!     tracks *measured* heterogeneity; a `None` assignment means "skip";
//!  3. `WaitArmed`, then run the ring mean-all-reduce with the group over
//!     the [`WorkerMesh`];
//!  4. the ring leader (lowest rank) reports `Complete`; everyone else
//!     blocks on `WaitDone` so their next `Sync` cannot re-observe the
//!     group at the front of their Group Buffer.
//!
//! # Compute/communication overlap
//!
//! With `--max-staleness S > 0` step 3 stops being stop-and-wait: a
//! dedicated *comm thread* (borrowing the GG connection for the
//! duration) arms the group and runs the ring schedule pipelined over
//! `--overlap-shards K` shards of a model snapshot, while the training
//! thread keeps taking up to `S` SGD steps on the live weights. Finished shards stream back and are
//! reconciled between steps with the bounded-staleness apply
//! (`collectives::pipeline::reconcile_shard`: group average plus the
//! local progress made in flight). `S = 0` (the default) is the serial
//! loop above, bit-for-bit. All members of a cluster must run the same
//! `K`: shard step tags are part of the wire schedule.
//!
//! Termination mirrors the threaded runtime: `Retire`, then keep syncing
//! until the Group Buffer drains — partners of already-scheduled groups
//! would otherwise block forever on our membership. The drain always
//! executes serially (no stale steps are allowed after the timed window).

use std::io::BufRead;
use std::io::Write as _;
use std::net::SocketAddr;
use std::sync::mpsc::channel;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::collectives::pipeline::{
    reconcile_shard, ring_allreduce_sharded, shard_bounds, OverlapConfig,
};
use crate::model::mlp::{loss_only, sgd_step, MlpScratch, MlpSpec};
use crate::model::Dataset;
use crate::rpc::GgClient;

use super::mesh::WorkerMesh;

/// Everything one worker process needs (built from CLI flags by
/// `ripples worker`, or directly by tests).
#[derive(Debug, Clone)]
pub struct WorkerParams {
    pub rank: usize,
    pub n_workers: usize,
    /// Group Generator RPC address.
    pub gg_addr: String,
    /// Wall-clock training budget; iteration counts over a fixed window
    /// are the heterogeneity metric (`EXPERIMENTS.md §Deployment-run`).
    pub secs: f64,
    /// Hard cap on iterations (safety net for tests).
    pub max_iters: u64,
    /// Compute slowdown factor for *this* worker (1.0 = fast).
    pub slowdown: f64,
    /// Mid-run speed changes: `(factor, start_iter)` — once the local
    /// iteration count reaches `start_iter`, `factor` replaces the
    /// static `slowdown` (the entry with the largest active start wins).
    /// Built from `--slow-schedule` by the launcher.
    pub slow_schedule: Vec<(f64, u64)>,
    /// Emulated per-iteration device time; the tiny MLP alone is too fast
    /// for a slowdown to be observable.
    pub compute_floor: Duration,
    pub seed: u64,
    pub lr: f32,
    pub batch: usize,
    /// Non-IID shard skew (probability of drawing the worker's primary
    /// class); makes synchronization statistically observable.
    pub data_bias: f64,
    /// Use the tiny test MLP instead of the paper-default shape.
    pub tiny: bool,
    pub dataset_size: usize,
    pub eval_size: usize,
    /// Pipelined-collective knobs (`--overlap-shards`/`--max-staleness`);
    /// the serial default reproduces the pre-overlap loop bit-for-bit.
    pub overlap: OverlapConfig,
}

impl Default for WorkerParams {
    fn default() -> Self {
        Self {
            rank: 0,
            n_workers: 2,
            gg_addr: "127.0.0.1:7777".into(),
            secs: 5.0,
            max_iters: u64::MAX,
            slowdown: 1.0,
            slow_schedule: Vec::new(),
            compute_floor: Duration::from_millis(5),
            seed: 42,
            lr: 0.1,
            batch: 32,
            data_bias: 0.5,
            tiny: true,
            dataset_size: 2048,
            eval_size: 256,
            overlap: OverlapConfig::serial(),
        }
    }
}

impl WorkerParams {
    /// Effective slowdown factor at local iteration `iter` (shared
    /// schedule semantics: `cluster::scheduled_factor_at`).
    pub fn slowdown_at(&self, iter: u64) -> f64 {
        crate::cluster::scheduled_factor_at(
            self.slow_schedule.iter().copied(),
            self.slowdown,
            iter,
        )
    }

    /// The generous io budget shared by the GG control plane and the
    /// data plane: a worker can legitimately sit behind a peer with most
    /// of its timed window left, but a *crashed* peer must surface as an
    /// error instead of hanging the cluster.
    pub fn io_timeout(&self) -> Duration {
        Duration::from_secs_f64((self.secs * 4.0).max(60.0))
    }
}

/// Parse a worker-local `F@ITER[,F@ITER...]` slowdown schedule (the
/// per-rank form the launcher derives from `--slow-schedule W,F@ITER`).
pub fn parse_worker_schedule(s: &str) -> Result<Vec<(f64, u64)>> {
    let mut out = Vec::new();
    for part in s.split(',').filter(|p| !p.trim().is_empty()) {
        let part = part.trim();
        let (f, iter) = part
            .split_once('@')
            .with_context(|| format!("bad schedule entry {part:?}: expected F@ITER"))?;
        out.push((
            f.trim().parse().with_context(|| format!("bad factor in {part:?}"))?,
            iter.trim().parse().with_context(|| format!("bad iteration in {part:?}"))?,
        ));
    }
    Ok(out)
}

/// Render a worker-local schedule back into the `F@ITER[,...]` flag form.
pub fn format_worker_schedule(schedule: &[(f64, u64)]) -> String {
    schedule
        .iter()
        .map(|(f, i)| format!("{f}@{i}"))
        .collect::<Vec<_>>()
        .join(",")
}

/// What a worker measured over its run.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerReport {
    pub rank: usize,
    /// Iterations completed inside the timed window (drain excluded;
    /// overlap's stale steps included — they are real SGD steps).
    pub iters: u64,
    /// P-Reduce collectives this worker participated in (drain included).
    pub preduces: u64,
    pub loss_first: f64,
    pub loss_last: f64,
    pub secs: f64,
    /// Final EWMA step duration, the same value piggybacked to the GG
    /// (0.0 when the worker completed no timed iteration).
    pub ewma_secs: f64,
    /// SGD steps taken on stale weights while a collective was in flight
    /// (0 in serial mode).
    pub stale_steps: u64,
    /// Wall-clock seconds the training thread spent *blocked* on
    /// synchronization (exposed sync): the whole collective in serial
    /// mode; only the un-overlapped remainder with staleness enabled.
    pub sync_blocked_secs: f64,
}

impl WorkerReport {
    /// One-line stdout encoding consumed by `launch` (`REPORT k=v ...`).
    pub fn to_line(&self) -> String {
        format!(
            "REPORT rank={} iters={} preduces={} loss_first={:.6} loss_last={:.6} \
             secs={:.3} ewma={:.6} stale={} sync_secs={:.6}",
            self.rank,
            self.iters,
            self.preduces,
            self.loss_first,
            self.loss_last,
            self.secs,
            self.ewma_secs,
            self.stale_steps,
            self.sync_blocked_secs
        )
    }

    pub fn parse_line(line: &str) -> Result<Self> {
        let mut rank = None;
        let mut iters = None;
        let mut preduces = None;
        let mut loss_first = None;
        let mut loss_last = None;
        let mut secs = None;
        let mut ewma_secs = 0.0; // optional: absent in pre-telemetry lines
        let mut stale_steps = 0; // optional: absent in pre-overlap lines
        let mut sync_blocked_secs = 0.0; // optional, ditto
        for kv in line.trim().strip_prefix("REPORT ").unwrap_or("").split_whitespace() {
            let (k, v) = kv.split_once('=').with_context(|| format!("bad field {kv:?}"))?;
            match k {
                "rank" => rank = Some(v.parse()?),
                "iters" => iters = Some(v.parse()?),
                "preduces" => preduces = Some(v.parse()?),
                "loss_first" => loss_first = Some(v.parse()?),
                "loss_last" => loss_last = Some(v.parse()?),
                "secs" => secs = Some(v.parse()?),
                "ewma" => ewma_secs = v.parse()?,
                "stale" => stale_steps = v.parse()?,
                "sync_secs" => sync_blocked_secs = v.parse()?,
                _ => {} // forward-compatible: ignore unknown fields
            }
        }
        match (rank, iters, preduces, loss_first, loss_last, secs) {
            (Some(rank), Some(iters), Some(preduces), Some(lf), Some(ll), Some(secs)) => {
                Ok(Self {
                    rank,
                    iters,
                    preduces,
                    loss_first: lf,
                    loss_last: ll,
                    secs,
                    ewma_secs,
                    stale_steps,
                    sync_blocked_secs,
                })
            }
            _ => bail!("incomplete report line: {line:?}"),
        }
    }
}

/// The per-step training state shared by the main loop and the overlap
/// engine's stale steps: one call = one timed SGD step (batch draw,
/// update, heterogeneity sleep, EWMA fold) on whatever buffer is passed.
struct SgdDriver<'a> {
    p: &'a WorkerParams,
    spec: &'a MlpSpec,
    ds: &'a Dataset,
    class_index: &'a [Vec<usize>],
    scratch: MlpScratch,
    /// Local iteration count (drives batch tags and the slow schedule).
    iters: u64,
    /// Measured step-duration EWMA, piggybacked on every Sync.
    ewma_secs: f64,
}

impl SgdDriver<'_> {
    fn step(&mut self, flat: &mut [f32]) {
        let step_start = Instant::now();
        let tag = self
            .p
            .seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(((self.p.rank as u64) << 32) | self.iters);
        let (x, y) = self.ds.batch_biased(
            tag,
            self.p.batch,
            self.p.rank % self.spec.classes,
            self.p.data_bias,
            self.class_index,
        );
        sgd_step(self.spec, flat, &x, &y, self.p.lr, &mut self.scratch);
        let factor = self.p.slowdown_at(self.iters);
        self.iters += 1;
        if self.p.compute_floor > Duration::ZERO {
            std::thread::sleep(self.p.compute_floor.mul_f64(factor));
        }
        let step_secs = step_start.elapsed().as_secs_f64();
        self.ewma_secs =
            crate::gg::ewma_step(self.ewma_secs, step_secs, crate::gg::SPEED_ALPHA);
    }
}

/// Run the distributed training loop over an already-bound mesh and a
/// connected GG client.
pub fn run_worker(
    p: &WorkerParams,
    mesh: &WorkerMesh,
    gg: &mut GgClient,
) -> Result<WorkerReport> {
    p.overlap.validate().map_err(|e| anyhow!("bad overlap config: {e}"))?;
    let spec = if p.tiny { MlpSpec::tiny() } else { MlpSpec::default_paper() };
    // Shared dataset and identical init across the cluster: seeds must
    // not depend on rank (P-Reduce averages replicas of one model).
    let ds = Dataset::gaussian_mixture(
        spec.in_dim,
        spec.classes,
        p.dataset_size,
        p.seed ^ 0xDA7A,
    );
    let class_index = ds.class_index();
    let (ex, ey) = ds.eval_set(p.eval_size);
    let mut flat = spec.init(p.seed ^ 1);
    let loss_first = loss_only(&spec, &flat, &ex, &ey);
    let mut drv = SgdDriver {
        p,
        spec: &spec,
        ds: &ds,
        class_index: &class_index,
        scratch: MlpScratch::new(),
        iters: 0,
        ewma_secs: 0.0,
    };

    let overlap_active = !p.overlap.is_serial();
    let mut preduces = 0u64;
    let mut stale_steps = 0u64;
    let mut sync_blocked = 0.0f64;
    let start = Instant::now();
    while start.elapsed().as_secs_f64() < p.secs && drv.iters < p.max_iters {
        // ---- compute phase (timestamped, EWMA-folded)
        drv.step(&mut flat);
        // ---- sync phase (EWMA rides along as the SpeedReport)
        let (assigned, _newly_armed) = gg.sync(p.rank, drv.ewma_secs)?;
        if let Some((gid, members)) = assigned {
            if overlap_active {
                let (stale, blocked) = execute_group_overlapped(
                    p, mesh, gg, gid, &members, &mut flat, &mut drv, start,
                )?;
                stale_steps += stale;
                sync_blocked += blocked;
            } else {
                let t0 = Instant::now();
                execute_group(p, mesh, gg, gid, &members, &mut flat)?;
                sync_blocked += t0.elapsed().as_secs_f64();
            }
            preduces += 1;
        }
    }
    let timed = start.elapsed().as_secs_f64();
    let iters = drv.iters;

    // ---- termination protocol: retire, then drain the Group Buffer.
    // The drain is always serial: the timed window is over, so there is
    // no compute left to hide transfers behind.
    gg.retire(p.rank)?;
    loop {
        let (assigned, _) = gg.sync(p.rank, drv.ewma_secs)?;
        match assigned {
            None => break,
            Some((gid, members)) => {
                execute_group(p, mesh, gg, gid, &members, &mut flat)?;
                preduces += 1;
            }
        }
    }

    let loss_last = loss_only(&spec, &flat, &ex, &ey);
    Ok(WorkerReport {
        rank: p.rank,
        iters,
        preduces,
        loss_first,
        loss_last,
        secs: timed,
        ewma_secs: drv.ewma_secs,
        stale_steps,
        sync_blocked_secs: sync_blocked,
    })
}

/// One GG-assigned P-Reduce, stop-and-wait: wait for the group to arm,
/// run the (possibly sharded) ring collective over TCP, report/observe
/// completion. With the default single shard this is the exact
/// pre-overlap schedule, frames and arithmetic identical.
fn execute_group(
    p: &WorkerParams,
    mesh: &WorkerMesh,
    gg: &mut GgClient,
    gid: u64,
    members: &[usize],
    flat: &mut [f32],
) -> Result<()> {
    if members.len() < 2 {
        bail!("GG assigned degenerate group {members:?}");
    }
    gg.wait_armed(gid)?;
    let (mut transport, pos) = mesh.ring_transport(gid, members)?;
    ring_allreduce_sharded(
        pos,
        members.len(),
        flat,
        p.overlap.shards,
        &mut transport,
        |_, _| (),
    )
    .with_context(|| format!("ring collective for group {gid} ({members:?})"))?;
    if members[0] == p.rank {
        gg.complete(gid)?;
    } else {
        gg.wait_done(gid)?;
    }
    Ok(())
}

/// One GG-assigned P-Reduce with compute/communication overlap: the comm
/// thread runs the pipelined ring over a model *snapshot* and streams
/// finished shards back; the training thread keeps stepping on the live
/// weights (up to `max_staleness` steps) and reconciles each finished
/// shard with the bounded-staleness apply. The GG connection is lent to
/// the comm thread for the duration (wait-armed/complete/wait-done are
/// its only RPCs in flight — the training thread's next `Sync` happens
/// strictly after the join). Returns `(stale_steps_taken,
/// seconds_blocked)`.
#[allow(clippy::too_many_arguments)]
fn execute_group_overlapped(
    p: &WorkerParams,
    mesh: &WorkerMesh,
    gg: &mut GgClient,
    gid: u64,
    members: &[usize],
    flat: &mut [f32],
    drv: &mut SgdDriver<'_>,
    start: Instant,
) -> Result<(u64, f64)> {
    if members.len() < 2 {
        bail!("GG assigned degenerate group {members:?}");
    }
    let k = p.overlap.shards.max(1);
    let n = flat.len();
    // Two copies: `snap` is the reconcile reference the training thread
    // keeps; `work` is the buffer the comm thread averages in place.
    let snap = flat.to_vec();
    let mut work = flat.to_vec();
    let rank = p.rank;
    let (tx, rx) = channel::<(usize, Vec<f32>)>();
    thread::scope(|scope| -> Result<(u64, f64)> {
        let comm = scope.spawn(move || -> Result<()> {
            gg.wait_armed(gid)?;
            let (mut transport, pos) = mesh.ring_transport(gid, members)?;
            ring_allreduce_sharded(pos, members.len(), &mut work, k, &mut transport, |s, avg| {
                // training thread gone = error already in flight; the
                // collective itself must still finish for the peers
                let _ = tx.send((s, avg.to_vec()));
            })
            .with_context(|| format!("pipelined ring for group {gid} ({members:?})"))?;
            if members[0] == rank {
                gg.complete(gid)?;
            } else {
                gg.wait_done(gid)?;
            }
            Ok(())
        });

        let mut applied = 0usize;
        let mut stale = 0u64;
        let mut blocked = 0.0f64;
        while applied < k {
            // drain whatever shards already landed, without blocking
            while let Ok((s, avg)) = rx.try_recv() {
                let (lo, hi) = shard_bounds(n, k, s);
                reconcile_shard(&mut flat[lo..hi], &snap[lo..hi], &avg);
                applied += 1;
            }
            if applied >= k {
                break;
            }
            let budget_left = drv.iters < p.max_iters
                && start.elapsed().as_secs_f64() < p.secs;
            if stale < p.overlap.max_staleness && budget_left {
                drv.step(flat); // hidden compute on (slightly) stale weights
                stale += 1;
            } else {
                // staleness bound reached: this is the *exposed* sync
                let t0 = Instant::now();
                let msg = rx.recv();
                blocked += t0.elapsed().as_secs_f64();
                match msg {
                    Ok((s, avg)) => {
                        let (lo, hi) = shard_bounds(n, k, s);
                        reconcile_shard(&mut flat[lo..hi], &snap[lo..hi], &avg);
                        applied += 1;
                    }
                    Err(_) => break, // comm thread died; join() has the error
                }
            }
        }
        // completion protocol (leader Complete / member WaitDone) is also
        // exposed wait — the next Sync cannot run before it
        let t0 = Instant::now();
        let res = comm.join().map_err(|_| anyhow!("comm thread panicked"))?;
        blocked += t0.elapsed().as_secs_f64();
        res?;
        Ok((stale, blocked))
    })
}

/// Entry point for the `ripples worker` subcommand: performs the
/// stdout/stdin address handshake with the launcher (or uses `--peers`
/// when given explicitly), runs the loop, prints the report line.
pub fn worker_main(
    p: &WorkerParams,
    listen: &str,
    peers_flag: Option<&str>,
) -> Result<WorkerReport> {
    let mut mesh = WorkerMesh::bind(p.rank, listen)?;
    // Generous timeout on both planes: a worker can legitimately sit in
    // a collective (or a WaitArmed) behind a peer that still has most of
    // its timed window to train through — but a *crashed* peer must
    // surface as an error here instead of hanging the whole cluster.
    let io_timeout = p.io_timeout();
    mesh.io_timeout = io_timeout;
    println!("DATA_ADDR {}", mesh.local_addr());
    std::io::stdout().flush().ok();
    let peer_list = match peers_flag {
        Some(list) => list.to_string(),
        None => {
            // launcher replies with `PEERS addr0,addr1,...` on stdin
            let mut line = String::new();
            std::io::stdin()
                .lock()
                .read_line(&mut line)
                .context("read PEERS line from launcher")?;
            line.trim()
                .strip_prefix("PEERS ")
                .with_context(|| format!("expected PEERS line, got {line:?}"))?
                .to_string()
        }
    };
    let peers: Vec<SocketAddr> = peer_list
        .split(',')
        .map(|a| a.trim().parse().with_context(|| format!("bad peer address {a:?}")))
        .collect::<Result<_>>()?;
    if peers.len() != p.n_workers {
        bail!("expected {} peer addresses, got {}", p.n_workers, peers.len());
    }
    mesh.set_peers(peers);
    let mut gg = GgClient::connect(&p.gg_addr)
        .with_context(|| format!("connect to GG at {}", p.gg_addr))?;
    gg.set_io_timeout(io_timeout)?;
    let report = run_worker(p, &mesh, &mut gg)?;
    println!("{}", report.to_line());
    std::io::stdout().flush().ok();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_line_roundtrip() {
        let r = WorkerReport {
            rank: 3,
            iters: 120,
            preduces: 40,
            loss_first: 1.386294,
            loss_last: 0.25,
            secs: 4.002,
            ewma_secs: 0.024500,
            stale_steps: 17,
            sync_blocked_secs: 0.812500,
        };
        let parsed = WorkerReport::parse_line(&r.to_line()).unwrap();
        assert_eq!(parsed, r);
    }

    #[test]
    fn report_parse_rejects_incomplete() {
        assert!(WorkerReport::parse_line("REPORT rank=1 iters=2").is_err());
        assert!(WorkerReport::parse_line("nonsense").is_err());
    }

    #[test]
    fn report_parse_ignores_unknown_fields() {
        let line = "REPORT rank=0 iters=1 preduces=0 loss_first=1.0 \
                    loss_last=0.5 secs=1.0 extra=9";
        assert_eq!(WorkerReport::parse_line(line).unwrap().iters, 1);
    }

    #[test]
    fn report_parse_tolerates_missing_optional_fields() {
        // pre-telemetry/pre-overlap line shape: optional fields default
        let line = "REPORT rank=0 iters=1 preduces=0 loss_first=1.0 \
                    loss_last=0.5 secs=1.0";
        let r = WorkerReport::parse_line(line).unwrap();
        assert_eq!(r.ewma_secs, 0.0);
        assert_eq!(r.stale_steps, 0);
        assert_eq!(r.sync_blocked_secs, 0.0);
    }

    #[test]
    fn slowdown_schedule_applies_latest_active_entry() {
        let p = WorkerParams {
            slowdown: 1.0,
            slow_schedule: vec![(3.0, 40), (1.0, 120)],
            ..WorkerParams::default()
        };
        assert_eq!(p.slowdown_at(0), 1.0);
        assert_eq!(p.slowdown_at(39), 1.0);
        assert_eq!(p.slowdown_at(40), 3.0); // straggler appears
        assert_eq!(p.slowdown_at(119), 3.0);
        assert_eq!(p.slowdown_at(120), 1.0); // recovery
    }

    #[test]
    fn worker_schedule_flag_roundtrip() {
        let sched = parse_worker_schedule("3.0@40,1.5@120").unwrap();
        assert_eq!(sched, vec![(3.0, 40), (1.5, 120)]);
        assert_eq!(format_worker_schedule(&sched), "3@40,1.5@120");
        assert_eq!(
            parse_worker_schedule(&format_worker_schedule(&sched)).unwrap(),
            sched
        );
        assert_eq!(parse_worker_schedule("").unwrap(), vec![]);
        assert!(parse_worker_schedule("3.0").is_err());
        assert!(parse_worker_schedule("x@3").is_err());
        assert!(parse_worker_schedule("3.0@x").is_err());
    }

    #[test]
    fn default_params_are_serial() {
        let p = WorkerParams::default();
        assert!(p.overlap.is_serial());
        assert_eq!(p.overlap.shards, 1);
    }
}
