//! Parameter-server baseline over TCP (DESIGN.md §Baselines).
//!
//! One server process owns the authoritative model, partitioned into
//! `--ps-shards` contiguous key ranges (`collectives::pipeline::
//! shard_bounds` — the exact partition `prop_net.rs` pins: disjoint,
//! covering, balanced within one element). Every round, each worker
//! takes one local SGD step, *pushes* its full model as `k` shard frames
//! (`Chunk { gid: round, step: shard }`, `--wire` codec respected), then
//! *pulls* the `k` averaged shards back. The server reads every worker's
//! pushes in rank order, averages per shard, and broadcasts the mean —
//! a classic BSP parameter server.
//!
//! Model averaging here is mathematically the gradient push/pull PS at
//! one local step per round: with `w_i = w_prev - lr * g_i`,
//! `mean_i(w_i) = w_prev - lr * mean_i(g_i)` — shipping weights instead
//! of gradients is the same server update without a second weight
//! broadcast format.
//!
//! Deadlock freedom is by phase ordering, not locks: workers write all
//! `k` pushes before reading anything; the server reads *all* `n·k`
//! pushes before writing anything. The cyclic wait a pull-before-push
//! scheme could build is structurally impossible.
//!
//! Termination: the first worker whose timed window closes sends
//! `Poison`; the server, on reading it (or any EOF), best-effort poisons
//! every connection and exits, which unblocks workers mid-pull. The
//! server is GG-free — PS workers never touch the control plane.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::collectives::codec::WireCodec;
use crate::collectives::pipeline::shard_bounds;
use crate::model::mlp::{loss_only, MlpScratch, MlpSpec};
use crate::model::Dataset;

use super::frame::{read_frame, read_frame_counted, write_chunk_coded, write_frame, Frame};
use super::worker::{SgdDriver, WorkerParams, WorkerReport};

/// The sharded parameter server: one background thread, `n` worker
/// connections, BSP rounds until the first `Poison`/EOF.
pub struct PsServer {
    addr: SocketAddr,
    handle: Option<thread::JoinHandle<Result<u64>>>,
}

impl PsServer {
    /// Bind `listen` (e.g. `"127.0.0.1:0"`) and serve `n_workers`
    /// connections with `shards` key ranges, replying in `wire` codec.
    /// `io` bounds every socket wait (accept phase included).
    pub fn spawn(
        listen: &str,
        n_workers: usize,
        shards: usize,
        wire: WireCodec,
        io: Duration,
    ) -> Result<Self> {
        if n_workers == 0 {
            bail!("ps server needs at least one worker");
        }
        let listener = TcpListener::bind(listen)
            .with_context(|| format!("bind parameter server on {listen}"))?;
        let addr = listener.local_addr()?;
        let shards = shards.max(1);
        let handle =
            thread::spawn(move || serve(listener, n_workers, shards, wire, io));
        Ok(Self { addr, handle: Some(handle) })
    }

    /// The bound server address to hand workers as `--ps`.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Wait for the server to finish; returns the number of completed
    /// BSP rounds.
    pub fn join(mut self) -> Result<u64> {
        match self.handle.take() {
            Some(h) => h.join().map_err(|_| anyhow::anyhow!("ps server panicked"))?,
            None => Ok(0),
        }
    }
}

impl Drop for PsServer {
    fn drop(&mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn serve(
    listener: TcpListener,
    n: usize,
    k: usize,
    wire: WireCodec,
    io: Duration,
) -> Result<u64> {
    // ---- accept phase: one connection per rank, identified by Hello.
    listener.set_nonblocking(true).ok();
    let deadline = Instant::now() + io.max(Duration::from_secs(60));
    let mut pending: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();
    let mut got = 0usize;
    while got < n {
        match listener.accept() {
            Ok((mut s, _)) => {
                s.set_nonblocking(false).ok();
                s.set_nodelay(true).ok();
                // bounded wait for the hello preamble
                s.set_read_timeout(Some(Duration::from_secs(10))).ok();
                match read_frame(&mut s) {
                    Ok(Frame::Hello { rank })
                        if (rank as usize) < n && pending[rank as usize].is_none() =>
                    {
                        s.set_read_timeout(Some(io)).ok();
                        s.set_write_timeout(Some(io)).ok();
                        pending[rank as usize] = Some(s);
                        got += 1;
                    }
                    _ => drop(s), // not a worker; ignore
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    bail!("ps server: only {got}/{n} workers connected in time");
                }
                thread::sleep(Duration::from_millis(2));
            }
            Err(e) => return Err(e).context("ps server accept"),
        }
    }
    let mut conns: Vec<TcpStream> =
        pending.into_iter().map(|c| c.expect("accept loop filled every slot")).collect();

    // ---- BSP rounds: read n·k pushes (rank order), average, broadcast.
    let mut acc: Vec<Vec<f32>> = vec![Vec::new(); k];
    let mut data: Vec<f32> = Vec::new();
    let mut buf: Vec<u8> = Vec::new();
    let mut rounds = 0u64;
    'rounds: loop {
        let gid = rounds + 1;
        let mut first = true;
        for c in conns.iter_mut() {
            for (s, a) in acc.iter_mut().enumerate() {
                let frame = match read_frame_counted(c) {
                    Ok((frame, _)) => frame,
                    Err(_) => break 'rounds, // EOF/timeout: a worker left
                };
                match frame.chunk_tag() {
                    Some((g, st)) if g == gid && st == s as u32 => {}
                    // Poison (a worker's window closed) or protocol skew:
                    // the round cannot complete — shut the server down.
                    _ => break 'rounds,
                }
                if !frame.take_chunk_data(&mut data) {
                    break 'rounds;
                }
                if first {
                    a.clear();
                    a.extend_from_slice(&data);
                } else {
                    if a.len() != data.len() {
                        break 'rounds; // workers disagree on the model
                    }
                    for (x, y) in a.iter_mut().zip(&data) {
                        *x += *y;
                    }
                }
            }
            first = false;
        }
        let inv = 1.0 / n as f32;
        for a in acc.iter_mut() {
            for x in a.iter_mut() {
                *x *= inv;
            }
        }
        for c in conns.iter_mut() {
            for (s, a) in acc.iter().enumerate() {
                if write_chunk_coded(c, wire, gid, s as u32, a, &mut buf).is_err() {
                    break 'rounds;
                }
            }
        }
        rounds += 1;
    }
    // best-effort: unblock everyone still waiting on pulls
    for c in conns.iter_mut() {
        let _ = write_frame(c, &Frame::Poison { gid: rounds + 1 });
    }
    Ok(rounds)
}

/// The PS worker loop: local SGD step, push `k` shards, pull `k` means.
/// Speaks only to the server — no GG, no mesh traffic.
pub fn run_ps_worker(p: &WorkerParams) -> Result<WorkerReport> {
    let addr = p
        .ps_addr
        .as_deref()
        .context("--algo ps needs a parameter-server address (--ps)")?;
    let spec = if p.tiny { MlpSpec::tiny() } else { MlpSpec::default_paper() };
    // Same seeds as every other worker loop: shared dataset, shared init.
    let ds = Dataset::gaussian_mixture(
        spec.in_dim,
        spec.classes,
        p.dataset_size,
        p.seed ^ 0xDA7A,
    );
    let class_index = ds.class_index();
    let (ex, ey) = ds.eval_set(p.eval_size);
    let mut flat = spec.init(p.seed ^ 1);
    let n = flat.len();
    let k = p.ps_shards.max(1);

    let mut conn = TcpStream::connect(addr)
        .with_context(|| format!("connect to parameter server at {addr}"))?;
    conn.set_nodelay(true).ok();
    let io = p.io_timeout();
    conn.set_read_timeout(Some(io)).ok();
    conn.set_write_timeout(Some(io)).ok();
    write_frame(&mut conn, &Frame::Hello { rank: p.rank as u32 })?;

    let loss_first = loss_only(&spec, &flat, &ex, &ey);
    let mut drv = SgdDriver {
        p,
        spec: &spec,
        ds: &ds,
        class_index: &class_index,
        scratch: MlpScratch::new(),
        iters: 0,
        ewma_secs: 0.0,
        load_wait_secs: 0.0,
    };

    let mut rounds = 0u64;
    let mut tx = 0u64;
    let mut rx = 0u64;
    let mut sync_blocked = 0.0f64;
    let mut buf: Vec<u8> = Vec::new();
    let mut data: Vec<f32> = Vec::new();
    let start = Instant::now();
    'outer: while start.elapsed().as_secs_f64() < p.secs && drv.iters < p.max_iters {
        drv.step(&mut flat);
        let gid = rounds + 1;
        let t0 = Instant::now();
        // push phase: all k shards before reading anything (see module
        // docs — this ordering is the deadlock-freedom argument)
        for s in 0..k {
            let (lo, hi) = shard_bounds(n, k, s);
            match write_chunk_coded(&mut conn, p.wire, gid, s as u32, &flat[lo..hi], &mut buf)
            {
                Ok(nb) => tx += nb as u64,
                Err(_) => break 'outer, // server gone
            }
        }
        // pull phase: the k averaged shards, in shard order
        for s in 0..k {
            let (lo, hi) = shard_bounds(n, k, s);
            let frame = match read_frame_counted(&mut conn) {
                Ok((frame, nb)) => {
                    rx += nb as u64;
                    frame
                }
                Err(_) => break 'outer,
            };
            match frame.chunk_tag() {
                Some((g, st)) if g == gid && st == s as u32 => {}
                // Poison: some worker's window closed and the server shut
                // the round down — our push of this round is simply lost.
                _ => break 'outer,
            }
            if !frame.take_chunk_data(&mut data) || data.len() != hi - lo {
                break 'outer;
            }
            flat[lo..hi].copy_from_slice(&data);
        }
        rounds += 1;
        sync_blocked += t0.elapsed().as_secs_f64();
    }
    let timed = start.elapsed().as_secs_f64();
    // tell the server we are done; it poisons everyone else
    let _ = write_frame(&mut conn, &Frame::Poison { gid: rounds + 1 });

    let loss_last = loss_only(&spec, &flat, &ex, &ey);
    Ok(WorkerReport {
        rank: p.rank,
        iters: drv.iters,
        preduces: rounds,
        hier_preduces: 0,
        loss_first,
        loss_last,
        secs: timed,
        ewma_secs: drv.ewma_secs,
        stale_steps: 0,
        sync_blocked_secs: sync_blocked,
        aborts: 0,
        load_wait_secs: drv.load_wait_secs,
        compute_wait_secs: 0.0,
        reconcile_wait_secs: sync_blocked,
        bytes_tx: tx,
        bytes_rx: rx,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two in-process PS workers against a live server: both run the
    /// same number of rounds and end on the identical averaged model.
    #[test]
    fn two_workers_converge_to_identical_models() {
        let server = PsServer::spawn(
            "127.0.0.1:0",
            2,
            3,
            WireCodec::Fp32,
            Duration::from_secs(20),
        )
        .unwrap();
        let addr = server.addr().to_string();
        let mk = |rank: usize| WorkerParams {
            rank,
            n_workers: 2,
            secs: 30.0, // bounded by max_iters, not wall clock
            max_iters: 4,
            compute_floor: Duration::ZERO,
            ps_addr: Some(addr.clone()),
            ps_shards: 3,
            ..WorkerParams::default()
        };
        let (r0, r1) = thread::scope(|scope| {
            let h0 = scope.spawn(|| run_ps_worker(&mk(0)).unwrap());
            let h1 = scope.spawn(|| run_ps_worker(&mk(1)).unwrap());
            (h0.join().unwrap(), h1.join().unwrap())
        });
        assert_eq!(r0.iters, 4);
        assert_eq!(r1.iters, 4);
        assert_eq!(r0.preduces, 4, "every step must complete its round");
        assert_eq!(r1.preduces, 4);
        // both ended on the same pulled mean, so eval losses agree exactly
        assert_eq!(r0.loss_last, r1.loss_last);
        assert!(r0.bytes_tx > 0 && r0.bytes_rx > 0);
        // the server saw exactly the workers' rounds
        assert_eq!(server.join().unwrap(), 4);
    }

    #[test]
    fn server_round_trips_the_mean_for_one_raw_client() {
        let server = PsServer::spawn(
            "127.0.0.1:0",
            1,
            2,
            WireCodec::Fp32,
            Duration::from_secs(20),
        )
        .unwrap();
        let mut conn = TcpStream::connect(server.addr()).unwrap();
        write_frame(&mut conn, &Frame::Hello { rank: 0 }).unwrap();
        let model = vec![2.0f32; 7]; // n=1: the "mean" is the push itself
        let mut buf = Vec::new();
        for s in 0..2u32 {
            let (lo, hi) = shard_bounds(model.len(), 2, s as usize);
            write_chunk_coded(&mut conn, WireCodec::Fp32, 1, s, &model[lo..hi], &mut buf)
                .unwrap();
        }
        let mut pulled = Vec::new();
        for s in 0..2u32 {
            let (frame, _) = read_frame_counted(&mut conn).unwrap();
            assert_eq!(frame.chunk_tag(), Some((1, s)));
            let mut shard = Vec::new();
            assert!(frame.take_chunk_data(&mut shard));
            pulled.extend_from_slice(&shard);
        }
        assert_eq!(pulled, model);
        write_frame(&mut conn, &Frame::Poison { gid: 2 }).unwrap();
        assert_eq!(server.join().unwrap(), 1);
    }

    #[test]
    fn ps_worker_requires_an_address() {
        let p = WorkerParams { ps_addr: None, ..WorkerParams::default() };
        assert!(run_ps_worker(&p).is_err());
    }
}
