//! AD-PSGD baseline over the TCP mesh (arXiv 1710.06952).
//!
//! Randomized pairwise *atomic* model averaging: each iteration an
//! **active** worker takes one SGD step, picks a uniformly random
//! **passive** partner, ships its whole model, and receives the pairwise
//! mean back; the passive averages the push into its own model under a
//! lock and keeps training between serves. The active/passive split is
//! the paper's deadlock-avoidance ordering: actives only *initiate*
//! exchanges and passives only *serve* them, so the wait-for graph is
//! bipartite and acyclic — two actives can never hold each other's
//! models hostage (AD-PSGD §3.2; DESIGN.md §Baselines).
//!
//! Wire protocol per exchange, on the existing directional mesh edges
//! (`net::frame` framing, `--wire` codec respected):
//!
//! * active → passive: `Chunk { gid: xid, step: 0, data: model }` on the
//!   active's outbound edge (xid = the active's exchange counter, so gid
//!   tags stay monotone per edge);
//! * passive → active: `Chunk { gid: xid, step: 1, data: mean }` on the
//!   passive's outbound edge back to the active.
//!
//! Atomicity: the passive holds its model mutex across the average, and
//! its local SGD steps take the same mutex, so a serve never interleaves
//! with a half-applied gradient. The active applies the returned mean as
//! its new model — under a lossless codec both sides hold the identical
//! mean, so the global weight *sum* is preserved exactly (the property
//! `prop_net.rs` pins on [`pairwise_average`]).
//!
//! Termination: every process runs the same timed window; passives keep
//! serving for a short grace period past their own window so an active's
//! final exchange still gets its reply, then everyone retires from the
//! GG (registration/heartbeat ride the same control plane as Ripples).

use std::collections::HashMap;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::model::mlp::{loss_only, MlpScratch, MlpSpec};
use crate::model::Dataset;
use crate::rpc::GgClient;
use crate::util::rng::Pcg32;

use super::frame::{read_frame_counted, write_chunk_coded};
use super::mesh::WorkerMesh;
use super::worker::{Heartbeat, SgdDriver, WorkerParams, WorkerReport};

/// How long a passive keeps serving exchanges after its own timed window
/// closes: an active whose window ends slightly later must still get the
/// reply to its final push.
const SERVE_GRACE: Duration = Duration::from_secs(2);

/// Polling granularity of the passive serve loop (read timeout between
/// frames; also the stop-flag check period).
const SERVE_POLL: Duration = Duration::from_millis(100);

/// In-place pairwise mean: both buffers end up holding `(a + b) / 2`
/// elementwise — the atomic averaging step both AD-PSGD sides apply.
/// `a[i] + b[i]` computed once and halved means the *sum* `a[i] + b[i]`
/// is exactly preserved in f32 (multiplying by 0.5 is exact).
pub fn pairwise_average(a: &mut [f32], b: &mut [f32]) {
    assert_eq!(a.len(), b.len(), "pairwise_average length mismatch");
    for (x, y) in a.iter_mut().zip(b.iter_mut()) {
        let m = (*x + *y) * 0.5;
        *x = m;
        *y = m;
    }
}

/// The passive ranks (odd) an active may draw as exchange partners.
pub fn passive_ranks(n_workers: usize) -> Vec<usize> {
    (0..n_workers).filter(|w| w % 2 == 1).collect()
}

/// Serve one active's exchange stream until EOF/error or `stop`:
/// read a push, average it into the shared model under the lock, reply
/// with the mean. Returns the number of exchanges served.
fn serve_active(
    mesh: &WorkerMesh,
    model: &Mutex<Vec<f32>>,
    stop: &AtomicBool,
    active: usize,
    io_timeout: Duration,
) -> Result<u64> {
    // Wait (politely, stop-aware) for the active's first push to dial us.
    let mut inbound = None;
    while !stop.load(Ordering::Relaxed) {
        if let Some(s) = mesh.inbound_stream(active, SERVE_POLL)? {
            inbound = Some(s);
            break;
        }
    }
    let Some(mut inbound) = inbound else { return Ok(0) };
    inbound.set_read_timeout(Some(SERVE_POLL)).ok();
    let mut reply: Option<TcpStream> = None;
    let mut serves = 0u64;
    let mut data: Vec<f32> = Vec::new();
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let frame = match read_frame_counted(&mut inbound) {
            Ok((frame, nbytes)) => {
                mesh.add_bytes_recv(nbytes as u64);
                frame
            }
            Err(e) => {
                let timed_out = e.downcast_ref::<std::io::Error>().is_some_and(|io| {
                    matches!(
                        io.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    )
                });
                if timed_out && !stop.load(Ordering::Relaxed) {
                    continue; // idle between pushes; keep serving
                }
                break; // stop requested, or the active went away (EOF)
            }
        };
        let Some((xid, step)) = frame.chunk_tag() else { break };
        if step != 0 || !frame.take_chunk_data(&mut data) {
            break; // protocol violation; drop the edge
        }
        {
            let mut m = model.lock().unwrap();
            if m.len() != data.len() {
                bail!(
                    "adpsgd push from rank {active} has {} weights, model has {}",
                    data.len(),
                    m.len()
                );
            }
            // atomic averaging: `data` holds the mean afterwards too
            pairwise_average(&mut m, &mut data);
        }
        if reply.is_none() {
            reply = mesh.outbound_stream(active, io_timeout)?;
        }
        let Some(out) = reply.as_mut() else { break };
        match write_chunk_coded(out, mesh.wire, xid, 1, &data, &mut buf) {
            Ok(n) => mesh.add_bytes_sent(n as u64),
            Err(_) => break, // active gone mid-reply
        }
        serves += 1;
    }
    Ok(serves)
}

/// Run the AD-PSGD training loop over an already-bound mesh and a
/// connected GG client (registration, liveness heartbeat, and retirement
/// use the same control plane as the Ripples loop; the GG schedules no
/// groups because this worker never `Sync`s).
pub fn run_adpsgd(
    p: &WorkerParams,
    mesh: &WorkerMesh,
    gg: &mut GgClient,
) -> Result<WorkerReport> {
    if p.n_workers < 2 {
        bail!("adpsgd needs at least 2 workers (one active, one passive)");
    }
    let spec = if p.tiny { MlpSpec::tiny() } else { MlpSpec::default_paper() };
    // Same seeds as the Ripples worker: shared dataset, identical init.
    let ds = Dataset::gaussian_mixture(
        spec.in_dim,
        spec.classes,
        p.dataset_size,
        p.seed ^ 0xDA7A,
    );
    let class_index = ds.class_index();
    let (ex, ey) = ds.eval_set(p.eval_size);
    let mut flat = spec.init(p.seed ^ 1);

    gg.register(p.rank, &mesh.local_addr().to_string())?;
    let _beacon = Heartbeat::spawn(&p.gg_addr, p.rank, p.heartbeat_ms, p.io_timeout());

    let loss_first = loss_only(&spec, &flat, &ex, &ey);
    let mut drv = SgdDriver {
        p,
        spec: &spec,
        ds: &ds,
        class_index: &class_index,
        scratch: MlpScratch::new(),
        iters: 0,
        ewma_secs: 0.0,
        load_wait_secs: 0.0,
    };

    let mut preduces = 0u64;
    let mut sync_blocked = 0.0f64;
    let start = Instant::now();
    let timed = if p.rank % 2 == 0 {
        // ---- active: step, pick a random passive, exchange.
        let passives = passive_ranks(p.n_workers);
        let mut rng = Pcg32::new(p.seed ^ 0xADB5 ^ ((p.rank as u64) << 17));
        let mut replies: HashMap<usize, TcpStream> = HashMap::new();
        let mut buf: Vec<u8> = Vec::new();
        let mut mean: Vec<f32> = Vec::new();
        'outer: while start.elapsed().as_secs_f64() < p.secs && drv.iters < p.max_iters {
            drv.step(&mut flat);
            let partner = passives[rng.gen_range(passives.len())];
            let t0 = Instant::now();
            let xid = preduces + 1; // monotone gid per edge (global counter)
            let Some(mut push) = mesh.outbound_stream(partner, p.io_timeout())? else {
                break; // partner never answered: window is over for us
            };
            match write_chunk_coded(&mut push, mesh.wire, xid, 0, &flat, &mut buf) {
                Ok(n) => mesh.add_bytes_sent(n as u64),
                Err(_) => break,
            }
            if !replies.contains_key(&partner) {
                match mesh.inbound_stream(partner, p.io_timeout())? {
                    Some(s) => {
                        // bounded patience per reply: a wedged passive
                        // must surface here, not hang the worker
                        s.set_read_timeout(Some(SERVE_GRACE.max(Duration::from_secs(10))))
                            .ok();
                        replies.insert(partner, s);
                    }
                    None => break,
                }
            }
            let reply = replies.get_mut(&partner).expect("inserted above");
            loop {
                let frame = match read_frame_counted(reply) {
                    Ok((frame, nbytes)) => {
                        mesh.add_bytes_recv(nbytes as u64);
                        frame
                    }
                    Err(_) => break 'outer, // passive retired/crashed
                };
                match frame.chunk_tag() {
                    Some((gid, 1)) if gid == xid => {
                        if !frame.take_chunk_data(&mut mean) {
                            break 'outer;
                        }
                        break;
                    }
                    Some((gid, _)) if gid < xid => continue, // stale reply
                    _ => break 'outer,
                }
            }
            if mean.len() != flat.len() {
                break;
            }
            flat.copy_from_slice(&mean);
            preduces += 1;
            sync_blocked += t0.elapsed().as_secs_f64();
        }
        start.elapsed().as_secs_f64()
    } else {
        // ---- passive: train under the model lock, serve every active
        // from a dedicated thread (streams are per-edge, so serves to
        // different actives only contend on the model mutex).
        let actives: Vec<usize> = (0..p.n_workers).filter(|w| w % 2 == 0).collect();
        let model = Mutex::new(std::mem::take(&mut flat));
        let stop = AtomicBool::new(false);
        let served = AtomicU64::new(0);
        let io = p.io_timeout();
        thread::scope(|scope| -> Result<()> {
            let handles: Vec<_> = actives
                .iter()
                .map(|&a| {
                    let (model, stop, served) = (&model, &stop, &served);
                    scope.spawn(move || -> Result<()> {
                        let n = serve_active(mesh, model, stop, a, io)?;
                        served.fetch_add(n, Ordering::Relaxed);
                        Ok(())
                    })
                })
                .collect();
            while start.elapsed().as_secs_f64() < p.secs && drv.iters < p.max_iters {
                {
                    let mut m = model.lock().unwrap();
                    drv.step(&mut m);
                }
                // `std::sync::Mutex` is unfair: the floor sleep runs
                // *inside* `step`, under the lock, and this loop would
                // re-acquire within nanoseconds — parked serve threads
                // could starve for the whole window. A short unlocked
                // pause hands every waiting serve the mutex between
                // steps, at a few percent of a floored step's cost.
                thread::sleep(Duration::from_micros(200));
            }
            // serve out the grace window, then release the serve threads
            thread::sleep(SERVE_GRACE);
            stop.store(true, Ordering::Relaxed);
            for h in handles {
                h.join().expect("adpsgd serve thread panicked")?;
            }
            Ok(())
        })?;
        preduces = served.load(Ordering::Relaxed);
        flat = model.into_inner().unwrap();
        // the timed window excludes the serve grace
        start.elapsed().as_secs_f64() - SERVE_GRACE.as_secs_f64()
    };

    gg.retire(p.rank)?;
    let loss_last = loss_only(&spec, &flat, &ex, &ey);
    Ok(WorkerReport {
        rank: p.rank,
        iters: drv.iters,
        preduces,
        hier_preduces: 0,
        loss_first,
        loss_last,
        secs: timed,
        ewma_secs: drv.ewma_secs,
        stale_steps: 0,
        sync_blocked_secs: sync_blocked,
        aborts: 0,
        load_wait_secs: drv.load_wait_secs,
        compute_wait_secs: 0.0,
        reconcile_wait_secs: sync_blocked,
        bytes_tx: mesh.bytes_sent(),
        bytes_rx: mesh.bytes_recv(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pairwise_average_sets_both_sides_to_the_mean() {
        let mut a = vec![1.0f32, -2.0, 0.5];
        let mut b = vec![3.0f32, 2.0, 0.5];
        pairwise_average(&mut a, &mut b);
        assert_eq!(a, vec![2.0, 0.0, 0.5]);
        assert_eq!(a, b);
    }

    #[test]
    fn passive_ranks_are_the_odd_ranks() {
        assert_eq!(passive_ranks(1), Vec::<usize>::new());
        assert_eq!(passive_ranks(2), vec![1]);
        assert_eq!(passive_ranks(5), vec![1, 3]);
        assert_eq!(passive_ranks(8), vec![1, 3, 5, 7]);
    }

    /// Two meshes, one in-process exchange: the active pushes, the serve
    /// loop averages + replies, both end at the identical mean.
    #[test]
    fn one_exchange_over_tcp_agrees_on_the_mean() {
        let meshes: Vec<WorkerMesh> =
            [0usize, 1].iter().map(|&r| WorkerMesh::bind(r, "127.0.0.1:0").unwrap()).collect();
        let addrs: Vec<std::net::SocketAddr> =
            meshes.iter().map(|m| m.local_addr()).collect();
        for m in &meshes {
            m.set_peers(addrs.clone());
        }
        let io = Duration::from_secs(10);
        let model = Mutex::new(vec![2.0f32; 32]);
        let stop = AtomicBool::new(false);
        let served = thread::scope(|scope| {
            let m1 = &meshes[1];
            let (model, stop) = (&model, &stop);
            let server = scope.spawn(move || serve_active(m1, model, stop, 0, io));
            // active side: push xid 1, read the reply
            let m0 = &meshes[0];
            let mut push = m0.outbound_stream(1, io).unwrap().unwrap();
            let mut buf = Vec::new();
            let flat = vec![4.0f32; 32];
            write_chunk_coded(
                &mut push,
                crate::collectives::codec::WireCodec::Fp32,
                1,
                0,
                &flat,
                &mut buf,
            )
            .unwrap();
            let mut reply = m0.inbound_stream(1, io).unwrap().unwrap();
            let (frame, _) = read_frame_counted(&mut reply).unwrap();
            assert_eq!(frame.chunk_tag(), Some((1, 1)));
            let mut mean = Vec::new();
            assert!(frame.take_chunk_data(&mut mean));
            assert_eq!(mean, vec![3.0f32; 32]);
            stop.store(true, Ordering::Relaxed);
            server.join().unwrap().unwrap()
        });
        assert_eq!(served, 1);
        assert_eq!(*model.lock().unwrap(), vec![3.0f32; 32]);
    }
}
