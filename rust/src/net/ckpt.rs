//! Worker checkpoints: periodic model + trainer-state snapshots that let
//! a replacement process rejoin the cluster after a crash.
//!
//! A checkpoint is one flat file per rank (`ckpt_r<rank>.bin`), written
//! atomically (tmp + rename) every `--ckpt-every` iterations into a
//! directory the whole cluster shares. The shared directory doubles as
//! the "freshest live peer" seed: a rejoiner restores the *newest*
//! checkpoint in the directory regardless of which rank wrote it
//! ([`latest`]), then converges onto its peers through ordinary P-Reduce
//! averaging. Trainer state here is everything plain SGD carries besides
//! the weights: the iteration count (drives batch tags and slowdown
//! schedules) and the speed-telemetry EWMA.
//!
//! Format (little-endian, `rpc::wire` codec): magic `RIPC`, version,
//! rank u32, iter u64, ewma f64-bits, weight count u32, then the f32
//! weights.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::rpc::wire::{Reader, Writer};

const MAGIC: &[u8; 4] = b"RIPC";
const VERSION: u32 = 1;

/// One model + trainer-state snapshot.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub rank: u32,
    /// Local iteration count at snapshot time (the freshness key).
    pub iter: u64,
    /// The worker's speed-telemetry EWMA (0.0 = none yet).
    pub ewma_secs: f64,
    pub weights: Vec<f32>,
}

impl Checkpoint {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.bytes(MAGIC);
        w.u32(VERSION);
        w.u32(self.rank);
        w.u64(self.iter);
        w.u64(self.ewma_secs.to_bits());
        w.u32(self.weights.len() as u32);
        for v in &self.weights {
            w.bytes(&v.to_le_bytes());
        }
        w.finish()
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = Reader::new(buf);
        if r.bytes(4)? != MAGIC {
            bail!("not a ripples checkpoint (bad magic)");
        }
        let version = r.u32()?;
        if version != VERSION {
            bail!("unsupported checkpoint version {version}");
        }
        let rank = r.u32()?;
        let iter = r.u64()?;
        let ewma_secs = f64::from_bits(r.u64()?);
        let count = r.u32()? as usize;
        // Validate the declared count against the bytes actually present
        // BEFORE reserving: a truncated/garbage file (which `latest`
        // must *skip*) could otherwise demand a multi-GiB reservation
        // from four random count bytes (same defect class as the frame
        // decoder's allocation-before-check).
        let need = count
            .checked_mul(4)
            .with_context(|| format!("checkpoint weight count {count} overflows"))?;
        let raw = r.bytes(need)?;
        let mut weights = Vec::with_capacity(count);
        weights.extend(
            raw.chunks_exact(4)
                .map(|b| f32::from_le_bytes(b.try_into().unwrap())),
        );
        r.done()?;
        Ok(Self { rank, iter, ewma_secs, weights })
    }
}

/// The per-rank checkpoint path inside `dir`.
pub fn path_for(dir: &Path, rank: usize) -> PathBuf {
    dir.join(format!("ckpt_r{rank}.bin"))
}

/// Write `ckpt` atomically into `dir` (tmp + rename: a crash mid-write
/// never corrupts the previous snapshot). Creates the directory.
pub fn save(dir: &Path, ckpt: &Checkpoint) -> Result<PathBuf> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("create checkpoint dir {}", dir.display()))?;
    let path = path_for(dir, ckpt.rank as usize);
    let tmp = dir.join(format!("ckpt_r{}.tmp", ckpt.rank));
    std::fs::write(&tmp, ckpt.encode())
        .with_context(|| format!("write {}", tmp.display()))?;
    std::fs::rename(&tmp, &path)
        .with_context(|| format!("rename {} -> {}", tmp.display(), path.display()))?;
    Ok(path)
}

/// Load one checkpoint file.
pub fn load(path: &Path) -> Result<Checkpoint> {
    let buf =
        std::fs::read(path).with_context(|| format!("read checkpoint {}", path.display()))?;
    Checkpoint::decode(&buf).with_context(|| format!("decode {}", path.display()))
}

/// The freshest checkpoint in `dir` — maximum `iter`, ties broken by
/// lowest rank for determinism; unparseable files are skipped (a peer
/// may be writing concurrently on another machine without atomic-rename
/// semantics). `Ok(None)` when the directory is empty or missing.
pub fn latest(dir: &Path) -> Result<Option<Checkpoint>> {
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => {
            return Err(e).with_context(|| format!("list checkpoints in {}", dir.display()))
        }
    };
    let mut best: Option<Checkpoint> = None;
    for entry in entries {
        let path = entry?.path();
        let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if !name.starts_with("ckpt_r") || !name.ends_with(".bin") {
            continue;
        }
        let Ok(ckpt) = load(&path) else { continue };
        let fresher = match &best {
            None => true,
            Some(b) => {
                ckpt.iter > b.iter || (ckpt.iter == b.iter && ckpt.rank < b.rank)
            }
        };
        if fresher {
            best = Some(ckpt);
        }
    }
    Ok(best)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("ripples_ckpt_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn ckpt(rank: u32, iter: u64) -> Checkpoint {
        Checkpoint {
            rank,
            iter,
            ewma_secs: 0.0125,
            weights: (0..64).map(|i| i as f32 * 0.5 - 3.0).collect(),
        }
    }

    #[test]
    fn codec_roundtrip() {
        let c = ckpt(3, 120);
        assert_eq!(Checkpoint::decode(&c.encode()).unwrap(), c);
        assert!(Checkpoint::decode(b"nope").is_err());
        let mut bad = c.encode();
        bad[4] = 99; // version
        assert!(Checkpoint::decode(&bad).is_err());
        bad.truncate(20);
        bad[4] = 1;
        assert!(Checkpoint::decode(&bad).is_err(), "truncated weights");
    }

    /// Regression: decode used to `Vec::with_capacity` the declared
    /// weight count before checking the payload, so a corrupt file in
    /// the shared dir could abort a rejoiner with a huge reservation
    /// instead of being skipped by `latest`.
    #[test]
    fn adversarial_weight_count_rejected_before_allocation() {
        let mut w = Writer::new();
        w.bytes(MAGIC);
        w.u32(VERSION);
        w.u32(0); // rank
        w.u64(1); // iter
        w.u64(0); // ewma bits
        w.u32(u32::MAX); // declared ~4G weights...
        w.bytes(&[0u8; 16]); // ...backed by 16 payload bytes
        assert!(Checkpoint::decode(&w.finish()).is_err());
        // and `latest` skips such a file instead of dying on it
        let dir = tmpdir("adversarial");
        save(&dir, &ckpt(1, 5)).unwrap();
        let mut evil = Writer::new();
        evil.bytes(MAGIC);
        evil.u32(VERSION);
        evil.u32(2);
        evil.u64(999);
        evil.u64(0);
        evil.u32(u32::MAX);
        std::fs::write(path_for(&dir, 2), evil.finish()).unwrap();
        assert_eq!(latest(&dir).unwrap().unwrap().iter, 5);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn save_load_and_latest_picks_freshest() {
        let dir = tmpdir("latest");
        assert_eq!(latest(&dir).unwrap(), None, "missing dir is empty, not an error");
        save(&dir, &ckpt(0, 10)).unwrap();
        save(&dir, &ckpt(1, 30)).unwrap();
        save(&dir, &ckpt(2, 20)).unwrap();
        let best = latest(&dir).unwrap().expect("three checkpoints present");
        assert_eq!((best.rank, best.iter), (1, 30), "freshest = max iter");
        // overwriting a rank's file replaces its snapshot atomically
        save(&dir, &ckpt(2, 99)).unwrap();
        let best = latest(&dir).unwrap().unwrap();
        assert_eq!((best.rank, best.iter), (2, 99));
        // garbage files are skipped, not fatal
        std::fs::write(dir.join("ckpt_r7.bin"), b"garbage").unwrap();
        assert_eq!(latest(&dir).unwrap().unwrap().iter, 99);
        // tie on iter: lowest rank wins (deterministic restore)
        save(&dir, &ckpt(0, 99)).unwrap();
        assert_eq!(latest(&dir).unwrap().unwrap().rank, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn roundtrip_through_disk_is_exact() {
        let dir = tmpdir("roundtrip");
        let c = ckpt(5, 7);
        let path = save(&dir, &c).unwrap();
        assert_eq!(load(&path).unwrap(), c);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
