//! Worker-to-worker connection mesh for the P-Reduce data plane.
//!
//! Every worker process binds one data-plane listener. Connections are
//! *lazy and directed*: the first time rank `a` must send to rank `b`
//! (because `b` follows `a` in some group's ring order), `a` dials `b`,
//! sends a `Hello { rank }` preamble, and caches the stream; `b`'s accept
//! loop indexes the inbound stream by the hello rank. Each directed edge
//! is used by one group at a time — armed groups are pairwise disjoint
//! (the GG's lock vector), so a worker participates in at most one
//! collective at any moment and an edge is quiescent between groups.
//! Frames are tagged with `(gid, step)` and verified on receipt anyway:
//! a mismatch means a protocol bug and fails fast instead of corrupting
//! model bytes.

use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::collectives::ring::ChunkTransport;

use super::frame::{read_frame, write_frame, Frame};

/// Inbound streams registered by the accept loop, keyed by peer rank.
struct Inbound {
    conns: Mutex<HashMap<u32, TcpStream>>,
    cv: Condvar,
}

/// Cap on concurrently pending `Hello` handshakes: far above any real
/// cluster's rank count, far below a connect flood's thread bill.
const MAX_PENDING_HANDSHAKES: usize = 128;

/// One worker's view of the cluster data plane.
pub struct WorkerMesh {
    rank: u32,
    local_addr: SocketAddr,
    /// Rank-indexed peer data-plane addresses (set after the handshake).
    peers: Vec<SocketAddr>,
    outbound: Mutex<HashMap<u32, TcpStream>>,
    inbound: Arc<Inbound>,
    /// Per-transfer socket timeout: a peer dying mid-collective surfaces
    /// as an error instead of a hang.
    pub io_timeout: Duration,
    stop: Arc<AtomicBool>,
    accept_handle: Option<thread::JoinHandle<()>>,
}

impl WorkerMesh {
    /// Bind the data-plane listener (e.g. `"127.0.0.1:0"` for an
    /// ephemeral port) and start the accept loop. Peer addresses arrive
    /// later via [`WorkerMesh::set_peers`] — binding first lets every
    /// worker advertise its address before any dialing starts.
    pub fn bind(rank: usize, listen: &str) -> Result<Self> {
        let listener = TcpListener::bind(listen)
            .with_context(|| format!("bind data plane on {listen}"))?;
        let local_addr = listener.local_addr()?;
        let inbound = Arc::new(Inbound { conns: Mutex::new(HashMap::new()), cv: Condvar::new() });
        let stop = Arc::new(AtomicBool::new(false));
        let inb = Arc::clone(&inbound);
        let stop2 = Arc::clone(&stop);
        let inflight = Arc::new(AtomicUsize::new(0));
        let accept_handle = thread::spawn(move || {
            listener.set_nonblocking(true).ok();
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((mut stream, _)) => {
                        // Handshake per connection on its own thread: a
                        // slow or stuck dialer must not head-of-line-block
                        // every other peer's registration behind its 10 s
                        // hello timeout (found by the slow-dialer test).
                        // In-flight handshakes are capped so a connect
                        // flood cannot spawn unbounded threads — excess
                        // sockets are dropped (a real peer fails fast
                        // and surfaces the error instead of hanging).
                        if inflight.load(Ordering::Relaxed) >= MAX_PENDING_HANDSHAKES {
                            drop(stream);
                            continue;
                        }
                        inflight.fetch_add(1, Ordering::Relaxed);
                        let inb = Arc::clone(&inb);
                        let inflight = Arc::clone(&inflight);
                        let stop = Arc::clone(&stop2);
                        thread::spawn(move || {
                            stream.set_nonblocking(false).ok();
                            stream.set_nodelay(true).ok();
                            // bounded wait for the hello preamble
                            stream.set_read_timeout(Some(Duration::from_secs(10))).ok();
                            match read_frame(&mut stream) {
                                // a mesh being torn down must not admit
                                // late registrations
                                Ok(Frame::Hello { rank }) if !stop.load(Ordering::Relaxed) => {
                                    let mut conns = inb.conns.lock().unwrap();
                                    conns.insert(rank, stream);
                                    inb.cv.notify_all();
                                }
                                _ => drop(stream), // not a peer; ignore
                            }
                            inflight.fetch_sub(1, Ordering::Relaxed);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        thread::sleep(Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(Self {
            rank: rank as u32,
            local_addr,
            peers: Vec::new(),
            outbound: Mutex::new(HashMap::new()),
            inbound,
            io_timeout: Duration::from_secs(60),
            stop,
            accept_handle: Some(accept_handle),
        })
    }

    /// The bound data-plane address to advertise to peers.
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Install the rank-indexed peer address list (index = worker rank).
    pub fn set_peers(&mut self, peers: Vec<SocketAddr>) {
        self.peers = peers;
    }

    /// Dial (or reuse) the outbound edge to `to`, returning a handle that
    /// shares the cached socket.
    fn outbound_to(&self, to: u32) -> Result<TcpStream> {
        let mut cache = self.outbound.lock().unwrap();
        if let Some(s) = cache.get(&to) {
            return Ok(s.try_clone()?);
        }
        let addr = *self
            .peers
            .get(to as usize)
            .ok_or_else(|| anyhow!("no address for rank {to}"))?;
        // The launcher distributes addresses only after every listener is
        // bound, so a *refused* connection is transient (peer mid-restart
        // at worst) — retry those briefly. Anything else (unroutable
        // host, permission) is a configuration error; surface it now
        // rather than spinning through the whole io_timeout.
        let deadline = Instant::now() + self.io_timeout;
        let mut stream = loop {
            match TcpStream::connect(addr) {
                Ok(s) => break s,
                Err(e)
                    if Instant::now() < deadline
                        && matches!(
                            e.kind(),
                            std::io::ErrorKind::ConnectionRefused
                                | std::io::ErrorKind::ConnectionReset
                        ) =>
                {
                    thread::sleep(Duration::from_millis(20));
                }
                Err(e) => return Err(e).with_context(|| format!("dial rank {to} at {addr}")),
            }
        };
        stream.set_nodelay(true).ok();
        stream.set_write_timeout(Some(self.io_timeout)).ok();
        write_frame(&mut stream, &Frame::Hello { rank: self.rank })?;
        let handle = stream.try_clone()?;
        cache.insert(to, stream);
        Ok(handle)
    }

    /// Wait for the inbound edge from `from` (its first chunk may race
    /// ahead of our accept loop registering the stream).
    fn inbound_from(&self, from: u32) -> Result<TcpStream> {
        let deadline = Instant::now() + self.io_timeout;
        let mut conns = self.inbound.conns.lock().unwrap();
        loop {
            if let Some(s) = conns.get(&from) {
                let clone = s.try_clone()?;
                clone.set_read_timeout(Some(self.io_timeout)).ok();
                return Ok(clone);
            }
            let now = Instant::now();
            if now >= deadline {
                bail!("no inbound connection from rank {from} within {:?}", self.io_timeout);
            }
            let (guard, _) = self
                .inbound
                .cv
                .wait_timeout(conns, deadline - now)
                .map_err(|_| anyhow!("poisoned inbound mesh"))?;
            conns = guard;
        }
    }

    /// Build the ring transport for this worker's position in `members`
    /// (the GG's sorted member list): send edge to the successor, receive
    /// edge from the predecessor. Returns the transport plus this
    /// worker's ring position.
    pub fn ring_transport(
        &self,
        gid: u64,
        members: &[usize],
    ) -> Result<(TcpRingTransport, usize)> {
        let p = members.len();
        let pos = members
            .iter()
            .position(|&m| m == self.rank as usize)
            .ok_or_else(|| anyhow!("rank {} not in group {members:?}", self.rank))?;
        if p < 2 {
            bail!("ring needs at least 2 members, got {members:?}");
        }
        let succ = members[(pos + 1) % p] as u32;
        let pred = members[(pos + p - 1) % p] as u32;
        let send = self.outbound_to(succ)?;
        let recv = self.inbound_from(pred)?;
        Ok((TcpRingTransport { gid, send, recv }, pos))
    }
}

impl Drop for WorkerMesh {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

/// A worker's directed ring edges for one P-Reduce group, framing chunk
/// transfers with `(gid, step)` tags (see `net::frame`).
pub struct TcpRingTransport {
    gid: u64,
    send: TcpStream,
    recv: TcpStream,
}

impl ChunkTransport for TcpRingTransport {
    fn send(&mut self, step: u32, data: &[f32]) -> Result<()> {
        super::frame::write_chunk(&mut self.send, self.gid, step, data)
    }

    fn recv(&mut self, step: u32, out: &mut Vec<f32>) -> Result<()> {
        match read_frame(&mut self.recv)? {
            Frame::Chunk { gid, step: got, data } => {
                if gid != self.gid || got != step {
                    bail!(
                        "chunk tag mismatch: got (gid {gid}, step {got}), \
                         expected (gid {}, step {step})",
                        self.gid
                    );
                }
                *out = data;
                Ok(())
            }
            other => bail!("expected chunk frame, got {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::ring::ring_allreduce_via;
    use crate::util::rng::Pcg32;

    /// In-process "multi-process" harness: one mesh per rank, threads as
    /// processes, real TCP on localhost.
    #[test]
    fn tcp_ring_matches_naive_mean() {
        let members = [0usize, 1, 2];
        let n = 103;
        let mut meshes: Vec<WorkerMesh> = members
            .iter()
            .map(|&r| WorkerMesh::bind(r, "127.0.0.1:0").unwrap())
            .collect();
        let addrs: Vec<SocketAddr> = meshes.iter().map(|m| m.local_addr()).collect();
        for m in &mut meshes {
            m.set_peers(addrs.clone());
            m.io_timeout = Duration::from_secs(10);
        }
        let mut rng = Pcg32::new(7);
        let bufs: Vec<Vec<f32>> = members
            .iter()
            .map(|_| (0..n).map(|_| rng.gen_f32() * 2.0 - 1.0).collect())
            .collect();
        let expect: Vec<f32> = (0..n)
            .map(|i| bufs.iter().map(|b| b[i]).sum::<f32>() / members.len() as f32)
            .collect();
        let results: Vec<Vec<f32>> = thread::scope(|scope| {
            let handles: Vec<_> = meshes
                .iter()
                .zip(bufs)
                .map(|(mesh, mut buf)| {
                    let members = &members;
                    scope.spawn(move || {
                        let (mut t, pos) = mesh.ring_transport(42, members).unwrap();
                        ring_allreduce_via(pos, members.len(), &mut buf, &mut t).unwrap();
                        buf
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (r, buf) in results.iter().enumerate() {
            for i in 0..n {
                assert!(
                    (buf[i] - expect[i]).abs() < 1e-5,
                    "rank {r} idx {i}: {} vs {}",
                    buf[i],
                    expect[i]
                );
            }
        }
    }

    #[test]
    fn slow_dialer_does_not_block_other_registrations() {
        // Regression: the accept loop used to run the Hello handshake
        // inline with a 10 s read timeout, so one connect-then-silent
        // socket stalled every other peer's registration behind it. With
        // per-connection handshake threads, a real peer registers (and a
        // collective completes) well inside a 3 s io_timeout even while
        // a silent dialer sits on each mesh.
        let members = [0usize, 1];
        let mut meshes: Vec<WorkerMesh> = members
            .iter()
            .map(|&r| WorkerMesh::bind(r, "127.0.0.1:0").unwrap())
            .collect();
        let addrs: Vec<SocketAddr> = meshes.iter().map(|m| m.local_addr()).collect();
        for m in &mut meshes {
            m.set_peers(addrs.clone());
            m.io_timeout = Duration::from_secs(3); // < the 10 s hello timeout
        }
        // silent dialers: connect, send nothing, stay open for the test
        let _silent: Vec<TcpStream> = addrs
            .iter()
            .map(|a| TcpStream::connect(a).expect("silent dial"))
            .collect();
        // give the accept loops time to pick the silent sockets up first
        thread::sleep(Duration::from_millis(100));
        let results: Vec<Vec<f32>> = thread::scope(|scope| {
            let handles: Vec<_> = meshes
                .iter()
                .enumerate()
                .map(|(r, mesh)| {
                    let members = &members;
                    scope.spawn(move || {
                        let mut buf = vec![r as f32; 16];
                        let (mut t, pos) = mesh.ring_transport(7, members).unwrap();
                        ring_allreduce_via(pos, 2, &mut buf, &mut t).unwrap();
                        buf
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for buf in &results {
            assert!(buf.iter().all(|&v| (v - 0.5).abs() < 1e-6), "{buf:?}");
        }
    }

    #[test]
    fn consecutive_groups_reuse_edges() {
        // Two back-to-back pair collectives over the same mesh: the second
        // must reuse the cached streams and still verify its own gid tag.
        let members = [0usize, 1];
        let mut meshes: Vec<WorkerMesh> = members
            .iter()
            .map(|&r| WorkerMesh::bind(r, "127.0.0.1:0").unwrap())
            .collect();
        let addrs: Vec<SocketAddr> = meshes.iter().map(|m| m.local_addr()).collect();
        for m in &mut meshes {
            m.set_peers(addrs.clone());
            m.io_timeout = Duration::from_secs(10);
        }
        for gid in [1u64, 2] {
            let results: Vec<Vec<f32>> = thread::scope(|scope| {
                let handles: Vec<_> = meshes
                    .iter()
                    .enumerate()
                    .map(|(r, mesh)| {
                        let members = &members;
                        scope.spawn(move || {
                            let mut buf = vec![r as f32; 8];
                            let (mut t, pos) = mesh.ring_transport(gid, members).unwrap();
                            ring_allreduce_via(pos, 2, &mut buf, &mut t).unwrap();
                            buf
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            for buf in &results {
                assert!(buf.iter().all(|&v| (v - 0.5).abs() < 1e-6), "{buf:?}");
            }
        }
    }
}
