//! Worker-to-worker connection mesh for the P-Reduce data plane.
//!
//! Every worker process binds one data-plane listener. Connections are
//! *lazy and directed*: the first time rank `a` must send to rank `b`
//! (because `b` follows `a` in some group's ring order), `a` dials `b`,
//! sends a `Hello { rank }` preamble, and caches the stream; `b`'s accept
//! loop indexes the inbound stream by the hello rank. Each directed edge
//! is used by one group at a time — armed groups are pairwise disjoint
//! (the GG's lock vector), so a worker participates in at most one
//! collective at any moment and an edge is quiescent between groups.
//! Frames are tagged with `(gid, step)` and verified on receipt anyway:
//! a mismatch means a protocol bug and fails fast instead of corrupting
//! model bytes.

use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::io::Read;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::collectives::codec::WireCodec;
use crate::collectives::ring::{AbortedError, ChunkTransport};
use crate::topo::SyncPlan;

use super::frame::{read_frame_counted, write_chunk_coded, write_frame, Frame};

/// Inbound streams registered by the accept loop, keyed by peer rank.
struct Inbound {
    conns: Mutex<HashMap<u32, TcpStream>>,
    cv: Condvar,
}

/// Data-plane byte meter: every frame a transport ships or reads on its
/// ring edges — chunks and poison alike, frame prefix included — so tx
/// and rx count the same frame set cluster-wide (Hello preambles are
/// excluded on both sides). Shared across every transport the mesh
/// hands out, serial and overlapped paths alike; surfaced in the worker
/// REPORT line (`tx=`/`rx=`).
#[derive(Default)]
struct ByteCounters {
    sent: AtomicU64,
    recv: AtomicU64,
}

/// Cap on concurrently pending `Hello` handshakes: far above any real
/// cluster's rank count, far below a connect flood's memory bill.
const MAX_PENDING_HANDSHAKES: usize = 128;

/// Bounded wait for a dialer's `Hello` preamble.
const HELLO_TIMEOUT: Duration = Duration::from_secs(10);

/// Accept-sweep idle backoff bounds (reset to min on any progress).
const ACCEPT_IDLE_MIN: Duration = Duration::from_micros(50);
const ACCEPT_IDLE_MAX: Duration = Duration::from_millis(1);

/// A `Hello` payload is 5 bytes (tag + rank); a length prefix claiming
/// more than this is not a peer preamble — dropped before buffering.
const MAX_HELLO_LEN: usize = 64;

/// One accepted connection still mid-`Hello` in the accept sweep.
struct PendingHello {
    stream: TcpStream,
    buf: Vec<u8>,
    deadline: Instant,
}

/// What one non-blocking pump of a pending handshake decided.
enum HelloDecision {
    /// Still waiting for bytes; `fed` = some arrived this sweep.
    Keep { fed: bool },
    /// Timed out, hung up, errored, or sent a non-`Hello` — discard.
    Drop,
    /// Complete `Hello { rank }` received: register the stream.
    Register(u32),
}

impl PendingHello {
    /// Advance the handshake without ever reading PAST the hello frame:
    /// the dialer's first chunk may already be in flight behind it and
    /// must stay in the socket buffer for the data path (which reads
    /// from the registered stream, not from this buffer).
    fn pump(&mut self, now: Instant) -> HelloDecision {
        if now >= self.deadline {
            return HelloDecision::Drop;
        }
        let mut fed = false;
        loop {
            let need = if self.buf.len() < 4 {
                4 - self.buf.len()
            } else {
                let len = u32::from_le_bytes([
                    self.buf[0],
                    self.buf[1],
                    self.buf[2],
                    self.buf[3],
                ]) as usize;
                if len > MAX_HELLO_LEN {
                    return HelloDecision::Drop;
                }
                4 + len - self.buf.len()
            };
            if need == 0 {
                break;
            }
            let mut tmp = [0u8; 64];
            match (&self.stream).read(&mut tmp[..need.min(64)]) {
                Ok(0) => return HelloDecision::Drop,
                Ok(n) => {
                    self.buf.extend_from_slice(&tmp[..n]);
                    fed = true;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    return HelloDecision::Keep { fed };
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => return HelloDecision::Drop,
            }
        }
        match Frame::decode(&self.buf[4..]) {
            Ok(Frame::Hello { rank }) => HelloDecision::Register(rank),
            _ => HelloDecision::Drop, // not a peer; ignore
        }
    }
}

/// One worker's view of the cluster data plane.
pub struct WorkerMesh {
    rank: u32,
    local_addr: SocketAddr,
    /// Rank-indexed peer data-plane addresses (set after the handshake;
    /// an entry is *updated* when a rank rejoins at a new address — see
    /// [`WorkerMesh::update_peer`]).
    peers: Mutex<Vec<SocketAddr>>,
    outbound: Mutex<HashMap<u32, TcpStream>>,
    inbound: Arc<Inbound>,
    /// Per-transfer socket timeout: a peer dying mid-collective surfaces
    /// as an error instead of a hang.
    pub io_timeout: Duration,
    /// Wire codec every transport this mesh hands out *sends* with
    /// (`--wire`); receivers decode whatever codec arrives, so the knob
    /// is send-side only. Default: raw `f32`, byte-identical to the
    /// pre-codec wire.
    pub wire: WireCodec,
    bytes: Arc<ByteCounters>,
    stop: Arc<AtomicBool>,
    accept_handle: Option<thread::JoinHandle<()>>,
}

impl WorkerMesh {
    /// Bind the data-plane listener (e.g. `"127.0.0.1:0"` for an
    /// ephemeral port) and start the accept loop. Peer addresses arrive
    /// later via [`WorkerMesh::set_peers`] — binding first lets every
    /// worker advertise its address before any dialing starts.
    pub fn bind(rank: usize, listen: &str) -> Result<Self> {
        let listener = TcpListener::bind(listen)
            .with_context(|| format!("bind data plane on {listen}"))?;
        let local_addr = listener.local_addr()?;
        let inbound = Arc::new(Inbound { conns: Mutex::new(HashMap::new()), cv: Condvar::new() });
        let stop = Arc::new(AtomicBool::new(false));
        let inb = Arc::clone(&inbound);
        let stop2 = Arc::clone(&stop);
        let accept_handle = thread::spawn(move || {
            // Event-driven accept loop: ONE thread sweeps every pending
            // handshake over non-blocking sockets instead of spawning a
            // thread per connection. A slow or stuck dialer just sits in
            // the pending set while everyone else registers on the same
            // sweep (the slow-dialer regression test); the set is capped
            // so a connect flood cannot buy unbounded memory — excess
            // sockets are dropped (a real peer fails fast and surfaces
            // the error instead of hanging). Idle backoff ramps 50 µs →
            // 1 ms, replacing the old fixed 2 ms accept-poll sleep.
            listener.set_nonblocking(true).ok();
            let mut pending: Vec<PendingHello> = Vec::new();
            let mut idle = ACCEPT_IDLE_MIN;
            while !stop2.load(Ordering::Relaxed) {
                let mut progress = false;
                loop {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            if pending.len() >= MAX_PENDING_HANDSHAKES {
                                drop(stream);
                                continue;
                            }
                            stream.set_nonblocking(true).ok();
                            stream.set_nodelay(true).ok();
                            pending.push(PendingHello {
                                stream,
                                buf: Vec::new(),
                                deadline: Instant::now() + HELLO_TIMEOUT,
                            });
                            progress = true;
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                        Err(_) => return,
                    }
                }
                let now = Instant::now();
                let mut i = 0;
                while i < pending.len() {
                    match pending[i].pump(now) {
                        HelloDecision::Keep { fed } => {
                            progress |= fed;
                            i += 1;
                        }
                        HelloDecision::Drop => {
                            pending.swap_remove(i);
                            progress = true;
                        }
                        HelloDecision::Register(rank) => {
                            let p = pending.swap_remove(i);
                            progress = true;
                            // a mesh being torn down must not admit late
                            // registrations
                            if !stop2.load(Ordering::Relaxed) {
                                // back to blocking: the data path reads
                                // this stream (via clones) blockingly
                                p.stream.set_nonblocking(false).ok();
                                let mut conns = inb.conns.lock().unwrap();
                                conns.insert(rank, p.stream);
                                inb.cv.notify_all();
                            }
                        }
                    }
                }
                if progress {
                    idle = ACCEPT_IDLE_MIN;
                } else {
                    thread::sleep(idle);
                    idle = (idle * 2).min(ACCEPT_IDLE_MAX);
                }
            }
        });
        Ok(Self {
            rank: rank as u32,
            local_addr,
            peers: Mutex::new(Vec::new()),
            outbound: Mutex::new(HashMap::new()),
            inbound,
            io_timeout: Duration::from_secs(60),
            wire: WireCodec::Fp32,
            bytes: Arc::new(ByteCounters::default()),
            stop,
            accept_handle: Some(accept_handle),
        })
    }

    /// The bound data-plane address to advertise to peers.
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Data-plane frame bytes sent so far (chunk + poison frames, all
    /// groups, both the serial and the overlap comm-thread path).
    pub fn bytes_sent(&self) -> u64 {
        self.bytes.sent.load(Ordering::Relaxed)
    }

    /// Data-plane frame bytes received so far.
    pub fn bytes_recv(&self) -> u64 {
        self.bytes.recv.load(Ordering::Relaxed)
    }

    /// Fold externally-framed traffic into the mesh byte meter. The
    /// AD-PSGD exchange path writes frames on raw cloned streams (no
    /// [`TcpRingTransport`] in the loop), so it meters itself through
    /// these hooks to keep the worker REPORT's `tx=`/`rx=` comparable
    /// across algorithms.
    pub fn add_bytes_sent(&self, n: u64) {
        self.bytes.sent.fetch_add(n, Ordering::Relaxed);
    }

    /// See [`WorkerMesh::add_bytes_sent`].
    pub fn add_bytes_recv(&self, n: u64) {
        self.bytes.recv.fetch_add(n, Ordering::Relaxed);
    }

    /// Install the rank-indexed peer address list (index = worker rank).
    pub fn set_peers(&self, peers: Vec<SocketAddr>) {
        *self.peers.lock().unwrap() = peers;
    }

    /// A rank came back at a new data-plane address (checkpoint-restored
    /// replacement, learned via the GG's `Lookup` registry): record it
    /// and drop any cached edges to the old incarnation so the next dial
    /// reaches the new process. No-op when the address is unchanged.
    pub fn update_peer(&self, rank: usize, addr: SocketAddr) {
        {
            let mut peers = self.peers.lock().unwrap();
            match peers.get_mut(rank) {
                Some(slot) if *slot != addr => *slot = addr,
                _ => return,
            }
        }
        self.invalidate(rank);
    }

    /// Forget the cached edges to `rank` (both directions): the next
    /// collective re-dials and re-accepts. Called after a socket to the
    /// rank was observed failing — a dead peer's half-open streams must
    /// not be reused, and a rejoined replacement registers fresh ones.
    pub fn invalidate(&self, rank: usize) {
        self.outbound.lock().unwrap().remove(&(rank as u32));
        self.inbound.conns.lock().unwrap().remove(&(rank as u32));
    }

    /// Dial (or reuse) the outbound edge to `to` before `deadline`.
    /// `Ok(None)` = the peer did not answer in time (dead or still
    /// binding — the caller decides by asking the control plane).
    fn outbound_within(&self, to: u32, deadline: Instant) -> Result<Option<TcpStream>> {
        let mut cache = self.outbound.lock().unwrap();
        if let Some(s) = cache.get(&to) {
            return Ok(Some(s.try_clone()?));
        }
        let addr = *self
            .peers
            .lock()
            .unwrap()
            .get(to as usize)
            .ok_or_else(|| anyhow!("no address for rank {to}"))?;
        // The launcher distributes addresses only after every listener is
        // bound, so a *refused* connection is transient (peer crashed or
        // mid-restart) — retry those until the deadline. Anything else
        // (unroutable host, permission) is a configuration error; surface
        // it now rather than spinning through the whole budget.
        let mut stream = loop {
            match TcpStream::connect(addr) {
                Ok(s) => break s,
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::ConnectionRefused
                            | std::io::ErrorKind::ConnectionReset
                    ) =>
                {
                    if Instant::now() >= deadline {
                        return Ok(None);
                    }
                    thread::sleep(Duration::from_millis(20));
                }
                Err(e) => return Err(e).with_context(|| format!("dial rank {to} at {addr}")),
            }
        };
        stream.set_nodelay(true).ok();
        stream.set_write_timeout(Some(self.io_timeout)).ok();
        write_frame(&mut stream, &Frame::Hello { rank: self.rank })?;
        let handle = stream.try_clone()?;
        cache.insert(to, stream);
        Ok(Some(handle))
    }

    /// Wait until `deadline` for the inbound edge from `from` (its first
    /// chunk may race ahead of our accept loop registering the stream).
    /// `Ok(None)` = nothing registered in time.
    fn inbound_within(&self, from: u32, deadline: Instant) -> Result<Option<TcpStream>> {
        let mut conns = self.inbound.conns.lock().unwrap();
        loop {
            if let Some(s) = conns.get(&from) {
                let clone = s.try_clone()?;
                clone.set_read_timeout(Some(self.io_timeout)).ok();
                return Ok(Some(clone));
            }
            let now = Instant::now();
            if now >= deadline {
                return Ok(None);
            }
            let (guard, _) = self
                .inbound
                .cv
                .wait_timeout(conns, deadline - now)
                .map_err(|_| anyhow!("poisoned inbound mesh"))?;
            conns = guard;
        }
    }

    /// Dial (or reuse) the raw outbound stream to `peer`, waiting up to
    /// `wait` for a refused dial to start answering. `Ok(None)` = no
    /// answer in time. Used by the AD-PSGD pairwise exchange, which
    /// frames its own traffic instead of going through a ring transport.
    pub fn outbound_stream(&self, peer: usize, wait: Duration) -> Result<Option<TcpStream>> {
        self.outbound_within(peer as u32, Instant::now() + wait)
    }

    /// Wait up to `wait` for the raw inbound stream registered from
    /// `peer` (clone carries the mesh `io_timeout` as read timeout).
    /// `Ok(None)` = nothing registered in time.
    pub fn inbound_stream(&self, peer: usize, wait: Duration) -> Result<Option<TcpStream>> {
        self.inbound_within(peer as u32, Instant::now() + wait)
    }

    /// Build the ring transport for this worker's position in `members`
    /// (the GG's sorted member list): send edge to the successor, receive
    /// edge from the predecessor. Returns the transport plus this
    /// worker's ring position. Blocks up to the full `io_timeout`.
    pub fn ring_transport(
        &self,
        gid: u64,
        members: &[usize],
    ) -> Result<(TcpRingTransport, usize)> {
        match self.try_ring_transport(gid, members, self.io_timeout)? {
            Some(pair) => Ok(pair),
            None => bail!(
                "group {gid}: ring edges not established within {:?} ({members:?})",
                self.io_timeout
            ),
        }
    }

    /// [`WorkerMesh::ring_transport`] with a bounded wait: `Ok(None)` if
    /// either edge is still missing after `wait`, so the caller can poll
    /// the control plane (has the group been aborted? did a member rejoin
    /// at a new address?) instead of blocking through a crash.
    pub fn try_ring_transport(
        &self,
        gid: u64,
        members: &[usize],
        wait: Duration,
    ) -> Result<Option<(TcpRingTransport, usize)>> {
        let p = members.len();
        let pos = members
            .iter()
            .position(|&m| m == self.rank as usize)
            .ok_or_else(|| anyhow!("rank {} not in group {members:?}", self.rank))?;
        if p < 2 {
            bail!("ring needs at least 2 members, got {members:?}");
        }
        let succ = members[(pos + 1) % p] as u32;
        let pred = members[(pos + p - 1) % p] as u32;
        let deadline = Instant::now() + wait;
        let Some(send) = self.outbound_within(succ, deadline)? else {
            return Ok(None);
        };
        let Some(recv) = self.inbound_within(pred, deadline)? else {
            return Ok(None);
        };
        Ok(Some((
            TcpRingTransport {
                gid,
                send,
                recv,
                succ,
                pred,
                failed: None,
                wire: self.wire,
                bytes: Arc::clone(&self.bytes),
                scratch: Vec::new(),
            },
            pos,
        )))
    }

    /// Duplex edge to one peer: send *and* receive sides both point at
    /// `peer` (a member↔leader link in the two-level collective — the
    /// degenerate "ring" where successor and predecessor coincide).
    fn duplex_edge(
        &self,
        gid: u64,
        peer: u32,
        deadline: Instant,
    ) -> Result<Option<TcpRingTransport>> {
        let Some(send) = self.outbound_within(peer, deadline)? else {
            return Ok(None);
        };
        let Some(recv) = self.inbound_within(peer, deadline)? else {
            return Ok(None);
        };
        Ok(Some(TcpRingTransport {
            gid,
            send,
            recv,
            succ: peer,
            pred: peer,
            failed: None,
            wire: self.wire,
            bytes: Arc::clone(&self.bytes),
            scratch: Vec::new(),
        }))
    }

    /// Build this worker's transports for a two-level hierarchical
    /// P-Reduce over `plan` (see `collectives::hier`): a non-leader gets
    /// one duplex edge to its node leader; a leader gets duplex edges to
    /// its node's members (plan order) plus the inter-node ring over all
    /// leaders (`None` when the plan has a single node). Blocks up to the
    /// full `io_timeout`.
    pub fn hier_transport(&self, gid: u64, plan: &SyncPlan) -> Result<HierRole> {
        match self.try_hier_transport(gid, plan, self.io_timeout)? {
            Some(role) => Ok(role),
            None => bail!(
                "group {gid}: hierarchical edges not established within {:?} \
                 ({:?})",
                self.io_timeout,
                plan.nodes
            ),
        }
    }

    /// [`WorkerMesh::hier_transport`] with a bounded wait: `Ok(None)` if
    /// any edge is still missing after `wait` (same contract as
    /// [`WorkerMesh::try_ring_transport`]).
    pub fn try_hier_transport(
        &self,
        gid: u64,
        plan: &SyncPlan,
        wait: Duration,
    ) -> Result<Option<HierRole>> {
        let deadline = Instant::now() + wait;
        let (ni, idx) = plan
            .position_of(self.rank as usize)
            .ok_or_else(|| anyhow!("rank {} not in plan {:?}", self.rank, plan.nodes))?;
        let node = &plan.nodes[ni];
        if idx > 0 {
            let leader = node[0] as u32;
            return Ok(self
                .duplex_edge(gid, leader, deadline)?
                .map(|link| HierRole::Member { link }));
        }
        // Leader: dial every member edge first so no peer's inbound wait
        // depends on a dial we have not issued yet, then collect inbounds.
        let peers: Vec<u32> = node[1..].iter().map(|&m| m as u32).collect();
        let mut sends = Vec::with_capacity(peers.len());
        for &m in &peers {
            let Some(s) = self.outbound_within(m, deadline)? else {
                return Ok(None);
            };
            sends.push(s);
        }
        let mut members = Vec::with_capacity(peers.len());
        for (&m, send) in peers.iter().zip(sends) {
            let Some(recv) = self.inbound_within(m, deadline)? else {
                return Ok(None);
            };
            members.push(TcpRingTransport {
                gid,
                send,
                recv,
                succ: m,
                pred: m,
                failed: None,
                wire: self.wire,
                bytes: Arc::clone(&self.bytes),
                scratch: Vec::new(),
            });
        }
        let leaders = plan.leaders();
        let ring = if leaders.len() > 1 {
            let now = Instant::now();
            if now >= deadline {
                return Ok(None);
            }
            match self.try_ring_transport(gid, &leaders, deadline - now)? {
                Some((t, pos)) => Some((t, pos, leaders.len())),
                None => return Ok(None),
            }
        } else {
            None
        };
        Ok(Some(HierRole::Leader { members, ring }))
    }
}

impl Drop for WorkerMesh {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

/// A worker's directed ring edges for one P-Reduce group, framing chunk
/// transfers with `(gid, step)` tags (see `net::frame`). On a transport
/// failure the rank whose socket broke is recorded
/// ([`TcpRingTransport::failed_peer`]) so the engine can invalidate that
/// edge and accuse the right suspect; a received `Poison` surfaces as a
/// typed [`AbortedError`] (unwind-and-retry, nobody to accuse).
pub struct TcpRingTransport {
    gid: u64,
    send: TcpStream,
    recv: TcpStream,
    succ: u32,
    pred: u32,
    failed: Option<u32>,
    /// Send-side wire codec (copied from [`WorkerMesh::wire`]); the
    /// receive side decodes whatever codec the predecessor used.
    wire: WireCodec,
    /// Shared mesh-wide byte meter.
    bytes: Arc<ByteCounters>,
    /// Reused encode buffer: one allocation per transport, not per step.
    scratch: Vec<u8>,
}

impl TcpRingTransport {
    /// The rank whose socket was observed failing, if any (set by the
    /// first send/recv error; poison receipt sets nothing).
    pub fn failed_peer(&self) -> Option<usize> {
        self.failed.map(|r| r as usize)
    }

    /// Best-effort: poison the ring successor so it unwinds immediately
    /// instead of waiting out a socket timeout. Errors are swallowed —
    /// the successor may be the dead rank itself. Metered like chunks so
    /// the tx and rx counters measure the same frame set.
    pub fn poison(&mut self) {
        let frame = Frame::Poison { gid: self.gid };
        if write_frame(&mut self.send, &frame).is_ok() {
            let n = 4 + frame.encode().len() as u64; // prefix + payload
            self.bytes.sent.fetch_add(n, Ordering::Relaxed);
        }
    }
}

/// One worker's transports for a two-level hierarchical P-Reduce (built
/// by [`WorkerMesh::hier_transport`]; executed by `collectives::hier`).
pub enum HierRole {
    /// Non-leader: one duplex edge to the node leader.
    Member { link: TcpRingTransport },
    /// Node leader: duplex member edges in plan order, plus the
    /// inter-node ring `(transport, ring position, leader count)` —
    /// `None` when the plan has a single node.
    Leader {
        members: Vec<TcpRingTransport>,
        ring: Option<(TcpRingTransport, usize, usize)>,
    },
}

impl HierRole {
    /// Best-effort poison of *every* edge this role holds, so an abort
    /// unwinds across both levels: a member wakes its leader, a leader
    /// wakes its whole node and both ring neighbours' reads (each of
    /// which repeats this on its own edges — the poison floods the tree).
    pub fn poison_all(&mut self) {
        match self {
            HierRole::Member { link } => link.poison(),
            HierRole::Leader { members, ring } => {
                for m in members {
                    m.poison();
                }
                if let Some((t, _, _)) = ring {
                    t.poison();
                }
            }
        }
    }

    /// The first peer observed failing on any held edge, if any (the
    /// suspect to accuse; poison receipts accuse nobody).
    pub fn failed_peer(&self) -> Option<usize> {
        match self {
            HierRole::Member { link } => link.failed_peer(),
            HierRole::Leader { members, ring } => members
                .iter()
                .filter_map(|m| m.failed_peer())
                .next()
                .or_else(|| ring.as_ref().and_then(|(t, _, _)| t.failed_peer())),
        }
    }
}

impl ChunkTransport for TcpRingTransport {
    fn send(&mut self, step: u32, data: &[f32]) -> Result<()> {
        match write_chunk_coded(
            &mut self.send,
            self.wire,
            self.gid,
            step,
            data,
            &mut self.scratch,
        ) {
            Ok(n) => {
                self.bytes.sent.fetch_add(n as u64, Ordering::Relaxed);
                Ok(())
            }
            Err(e) => {
                self.failed.get_or_insert(self.succ);
                Err(e)
            }
        }
    }

    fn recv(&mut self, step: u32, out: &mut Vec<f32>) -> Result<()> {
        loop {
            let (frame, nbytes) = read_frame_counted(&mut self.recv).map_err(|e| {
                self.failed.get_or_insert(self.pred);
                e
            })?;
            self.bytes.recv.fetch_add(nbytes as u64, Ordering::Relaxed);
            if let Some((gid, got)) = frame.chunk_tag() {
                if gid == self.gid {
                    if got != step {
                        bail!(
                            "chunk tag mismatch: got (gid {gid}, step {got}), \
                             expected (gid {}, step {step})",
                            self.gid
                        );
                    }
                    // decodes whichever codec the sender used
                    frame.take_chunk_data(out);
                    return Ok(());
                }
                // Leftovers of an *earlier* aborted group on this edge
                // (ids are monotone per edge: conflicting groups
                // serialize on the lock vector): the predecessor sent
                // chunks, learned of the abort, and poisoned — while we
                // skipped that group at WaitArmed and never drained them.
                if gid < self.gid {
                    continue;
                }
                bail!(
                    "group {}: unexpected chunk for future group {gid} on ring edge",
                    self.gid
                );
            }
            match frame {
                Frame::Poison { gid } if gid == self.gid => {
                    return Err(AbortedError { gid }.into());
                }
                Frame::Poison { gid } if gid < self.gid => continue, // stale
                other => bail!(
                    "group {}: unexpected frame on ring edge: {other:?}",
                    self.gid
                ),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::ring::ring_allreduce_via;
    use crate::util::rng::Pcg32;

    /// In-process "multi-process" harness: one mesh per rank, threads as
    /// processes, real TCP on localhost.
    #[test]
    fn tcp_ring_matches_naive_mean() {
        let members = [0usize, 1, 2];
        let n = 103;
        let mut meshes: Vec<WorkerMesh> = members
            .iter()
            .map(|&r| WorkerMesh::bind(r, "127.0.0.1:0").unwrap())
            .collect();
        let addrs: Vec<SocketAddr> = meshes.iter().map(|m| m.local_addr()).collect();
        for m in &mut meshes {
            m.set_peers(addrs.clone());
            m.io_timeout = Duration::from_secs(10);
        }
        let mut rng = Pcg32::new(7);
        let bufs: Vec<Vec<f32>> = members
            .iter()
            .map(|_| (0..n).map(|_| rng.gen_f32() * 2.0 - 1.0).collect())
            .collect();
        let expect: Vec<f32> = (0..n)
            .map(|i| bufs.iter().map(|b| b[i]).sum::<f32>() / members.len() as f32)
            .collect();
        let results: Vec<Vec<f32>> = thread::scope(|scope| {
            let handles: Vec<_> = meshes
                .iter()
                .zip(bufs)
                .map(|(mesh, mut buf)| {
                    let members = &members;
                    scope.spawn(move || {
                        let (mut t, pos) = mesh.ring_transport(42, members).unwrap();
                        ring_allreduce_via(pos, members.len(), &mut buf, &mut t).unwrap();
                        buf
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (r, buf) in results.iter().enumerate() {
            for i in 0..n {
                assert!(
                    (buf[i] - expect[i]).abs() < 1e-5,
                    "rank {r} idx {i}: {} vs {}",
                    buf[i],
                    expect[i]
                );
            }
        }
    }

    #[test]
    fn slow_dialer_does_not_block_other_registrations() {
        // Regression: the accept loop used to run the Hello handshake
        // inline with a 10 s read timeout, so one connect-then-silent
        // socket stalled every other peer's registration behind it. With
        // the non-blocking handshake sweep, a silent dialer just sits in
        // the pending set while a real peer registers (and a collective
        // completes) well inside a 3 s io_timeout.
        let members = [0usize, 1];
        let mut meshes: Vec<WorkerMesh> = members
            .iter()
            .map(|&r| WorkerMesh::bind(r, "127.0.0.1:0").unwrap())
            .collect();
        let addrs: Vec<SocketAddr> = meshes.iter().map(|m| m.local_addr()).collect();
        for m in &mut meshes {
            m.set_peers(addrs.clone());
            m.io_timeout = Duration::from_secs(3); // < the 10 s hello timeout
        }
        // silent dialers: connect, send nothing, stay open for the test
        let _silent: Vec<TcpStream> = addrs
            .iter()
            .map(|a| TcpStream::connect(a).expect("silent dial"))
            .collect();
        // give the accept loops time to pick the silent sockets up first
        thread::sleep(Duration::from_millis(100));
        let results: Vec<Vec<f32>> = thread::scope(|scope| {
            let handles: Vec<_> = meshes
                .iter()
                .enumerate()
                .map(|(r, mesh)| {
                    let members = &members;
                    scope.spawn(move || {
                        let mut buf = vec![r as f32; 16];
                        let (mut t, pos) = mesh.ring_transport(7, members).unwrap();
                        ring_allreduce_via(pos, 2, &mut buf, &mut t).unwrap();
                        buf
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for buf in &results {
            assert!(buf.iter().all(|&v| (v - 0.5).abs() < 1e-6), "{buf:?}");
        }
    }

    #[test]
    fn hello_arriving_in_pieces_still_registers() {
        // The sweep must assemble a handshake that trickles in across
        // several reads (frame prefix first, payload later) — the old
        // blocking read_frame got this for free, the non-blocking pump
        // has to buffer.
        use std::io::Write;
        let mesh = WorkerMesh::bind(0, "127.0.0.1:0").unwrap();
        let mut dialer = TcpStream::connect(mesh.local_addr()).unwrap();
        let frame = Frame::Hello { rank: 3 }.encode();
        let mut wire = (frame.len() as u32).to_le_bytes().to_vec();
        wire.extend_from_slice(&frame);
        for b in wire {
            dialer.write_all(&[b]).unwrap();
            dialer.flush().unwrap();
            thread::sleep(Duration::from_millis(2));
        }
        let got = mesh
            .inbound_stream(3, Duration::from_secs(5))
            .unwrap()
            .expect("piecewise hello must register rank 3");
        drop(got);
    }

    #[test]
    fn bytes_behind_the_hello_stay_on_the_data_path() {
        // A dialer's first chunk can share a packet with its Hello. The
        // handshake pump must stop reading at the hello boundary so the
        // chunk is still in the socket buffer for the ring transport.
        use std::io::Write;
        let mesh = WorkerMesh::bind(0, "127.0.0.1:0").unwrap();
        let mut dialer = TcpStream::connect(mesh.local_addr()).unwrap();
        let mut wire = Vec::new();
        for frame in [
            Frame::Hello { rank: 1 },
            Frame::Chunk { gid: 2, step: 0, data: vec![1.0, 2.0, 3.0] },
        ] {
            let payload = frame.encode();
            wire.extend_from_slice(&(payload.len() as u32).to_le_bytes());
            wire.extend_from_slice(&payload);
        }
        dialer.write_all(&wire).unwrap(); // one write: hello + chunk together
        let mut inbound = mesh
            .inbound_stream(1, Duration::from_secs(5))
            .unwrap()
            .expect("hello must register rank 1");
        let (frame, _) = read_frame_counted(&mut inbound).unwrap();
        assert_eq!(
            frame,
            Frame::Chunk { gid: 2, step: 0, data: vec![1.0, 2.0, 3.0] },
            "the chunk behind the hello must survive intact"
        );
    }

    fn pair_meshes(io_secs: u64) -> (Vec<WorkerMesh>, Vec<SocketAddr>) {
        let mut meshes: Vec<WorkerMesh> = [0usize, 1]
            .iter()
            .map(|&r| WorkerMesh::bind(r, "127.0.0.1:0").unwrap())
            .collect();
        let addrs: Vec<SocketAddr> = meshes.iter().map(|m| m.local_addr()).collect();
        for m in &mut meshes {
            m.set_peers(addrs.clone());
            m.io_timeout = Duration::from_secs(io_secs);
        }
        (meshes, addrs)
    }

    #[test]
    fn poison_unwinds_the_ring_as_a_typed_abort() {
        use crate::collectives::ring::AbortedError;
        let (meshes, _) = pair_meshes(10);
        let members = [0usize, 1];
        thread::scope(|scope| {
            let m0 = &meshes[0];
            let m1 = &meshes[1];
            let h0 = scope.spawn(move || {
                let mut buf = vec![1.0f32; 8];
                let (mut t, pos) = m0.ring_transport(5, &members).unwrap();
                let err = ring_allreduce_via(pos, 2, &mut buf, &mut t)
                    .expect_err("poisoned collective must fail");
                assert!(
                    err.downcast_ref::<AbortedError>().is_some(),
                    "expected typed AbortedError, got: {err:#}"
                );
                assert_eq!(
                    err.downcast_ref::<AbortedError>().unwrap().gid,
                    5,
                    "abort must name the poisoned group"
                );
                assert_eq!(t.failed_peer(), None, "poison accuses nobody");
            });
            let h1 = scope.spawn(move || {
                // rank 1 joins the edges but poisons instead of reducing
                let (mut t, _) = m1.ring_transport(5, &members).unwrap();
                t.poison();
            });
            h0.join().unwrap();
            h1.join().unwrap();
        });
    }

    #[test]
    fn stale_frames_of_aborted_groups_are_skipped() {
        let (meshes, _) = pair_meshes(10);
        let members = [0usize, 1];
        thread::scope(|scope| {
            let m0 = &meshes[0];
            let m1 = &meshes[1];
            let h0 = scope.spawn(move || {
                // group 3: rank 0 sent one chunk, learned of the abort,
                // poisoned. group 4 then runs normally on the same edge.
                let (mut t3, _) = m0.ring_transport(3, &members).unwrap();
                t3.send(0, &[9.0; 4]).unwrap();
                t3.poison();
                let mut buf = vec![0.0f32; 8];
                let (mut t4, pos) = m0.ring_transport(4, &members).unwrap();
                ring_allreduce_via(pos, 2, &mut buf, &mut t4).unwrap();
                buf
            });
            let h1 = scope.spawn(move || {
                // rank 1 never consumed group 3's frames (it skipped the
                // group at WaitArmed); its group-4 recv must skip them
                let mut buf = vec![1.0f32; 8];
                let (mut t4, pos) = m1.ring_transport(4, &members).unwrap();
                ring_allreduce_via(pos, 2, &mut buf, &mut t4).unwrap();
                buf
            });
            let b0 = h0.join().unwrap();
            let b1 = h1.join().unwrap();
            assert!(b0.iter().all(|&v| (v - 0.5).abs() < 1e-6), "{b0:?}");
            assert_eq!(b0, b1);
        });
    }

    #[test]
    fn try_ring_transport_times_out_cleanly_on_a_dead_peer() {
        let mesh = WorkerMesh::bind(0, "127.0.0.1:0").unwrap();
        // rank 1's "address" has no listener behind it (peer is dead):
        // grab a port by binding and dropping a listener
        let dead_addr = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        mesh.set_peers(vec![mesh.local_addr(), dead_addr]);
        let t0 = Instant::now();
        let got = mesh
            .try_ring_transport(1, &[0, 1], Duration::from_millis(120))
            .expect("timeout is not an error");
        assert!(got.is_none(), "dead peer must yield None, not a transport");
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "bounded wait must return promptly"
        );
    }

    #[test]
    fn update_peer_drops_stale_edges_only_on_change() {
        let (meshes, addrs) = pair_meshes(10);
        // same address: cached edges must survive (no-op)
        meshes[0].update_peer(1, addrs[1]);
        // new address: the cached entry (if any) is invalidated and the
        // address table rewritten — observable via a fresh dial target
        let replacement = WorkerMesh::bind(1, "127.0.0.1:0").unwrap();
        replacement.set_peers(addrs.clone());
        meshes[0].update_peer(1, replacement.local_addr());
        let members = [0usize, 1];
        thread::scope(|scope| {
            let m0 = &meshes[0];
            let mr = &replacement;
            let h0 = scope.spawn(move || {
                let mut buf = vec![0.0f32; 4];
                let (mut t, pos) = m0.ring_transport(9, &members).unwrap();
                ring_allreduce_via(pos, 2, &mut buf, &mut t).unwrap();
                buf
            });
            let h1 = scope.spawn(move || {
                let mut buf = vec![1.0f32; 4];
                let (mut t, pos) = mr.ring_transport(9, &members).unwrap();
                ring_allreduce_via(pos, 2, &mut buf, &mut t).unwrap();
                buf
            });
            let b0 = h0.join().unwrap();
            assert!(b0.iter().all(|&v| (v - 0.5).abs() < 1e-6), "{b0:?}");
            h1.join().unwrap();
        });
    }

    #[test]
    fn compressed_codecs_cross_the_wire_and_are_metered() {
        // Constant chunks are exact under every codec (q8 collapses to
        // scale 0, 0.5 is fp16-representable), so the collective result
        // must be exact while the byte meter shows the compression.
        let members = [0usize, 1];
        let mut per_codec_sent = Vec::new();
        for wire in [WireCodec::Fp32, WireCodec::Fp16, WireCodec::Q8] {
            let (mut meshes, _) = {
                let mut meshes: Vec<WorkerMesh> = members
                    .iter()
                    .map(|&r| WorkerMesh::bind(r, "127.0.0.1:0").unwrap())
                    .collect();
                let addrs: Vec<SocketAddr> =
                    meshes.iter().map(|m| m.local_addr()).collect();
                for m in &mut meshes {
                    m.set_peers(addrs.clone());
                    m.io_timeout = Duration::from_secs(10);
                }
                (meshes, addrs)
            };
            for m in &mut meshes {
                m.wire = wire;
            }
            let results: Vec<Vec<f32>> = thread::scope(|scope| {
                let handles: Vec<_> = meshes
                    .iter()
                    .enumerate()
                    .map(|(r, mesh)| {
                        let members = &members;
                        scope.spawn(move || {
                            let mut buf = vec![r as f32; 64];
                            let (mut t, pos) = mesh.ring_transport(11, members).unwrap();
                            ring_allreduce_via(pos, 2, &mut buf, &mut t).unwrap();
                            buf
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            for buf in &results {
                assert!(buf.iter().all(|&v| v == 0.5), "{wire}: {buf:?}");
            }
            let sent = meshes[0].bytes_sent();
            assert!(sent > 0, "{wire}: nothing metered");
            assert_eq!(
                meshes[0].bytes_sent(),
                meshes[1].bytes_recv(),
                "{wire}: meter asymmetry on a symmetric pair"
            );
            per_codec_sent.push(sent);
        }
        // compression is visible on the meter: fp32 > fp16 > q8
        assert!(
            per_codec_sent[0] > per_codec_sent[1] && per_codec_sent[1] > per_codec_sent[2],
            "bytes not ordered by codec: {per_codec_sent:?}"
        );
    }

    fn cluster_meshes(n: usize, io_secs: u64) -> Vec<WorkerMesh> {
        let mut meshes: Vec<WorkerMesh> =
            (0..n).map(|r| WorkerMesh::bind(r, "127.0.0.1:0").unwrap()).collect();
        let addrs: Vec<SocketAddr> = meshes.iter().map(|m| m.local_addr()).collect();
        for m in &mut meshes {
            m.set_peers(addrs.clone());
            m.io_timeout = Duration::from_secs(io_secs);
        }
        meshes
    }

    /// Run one hierarchical collective over real sockets: each rank's
    /// thread builds its role from the plan and executes it.
    fn run_hier(
        meshes: &[WorkerMesh],
        plan: &SyncPlan,
        gid: u64,
        bufs: Vec<Vec<f32>>,
        k: usize,
    ) -> Vec<Vec<f32>> {
        use crate::collectives::hier::{hier_leader, hier_member};
        let p_total = plan.total();
        thread::scope(|scope| {
            let handles: Vec<_> = plan
                .ring_order()
                .into_iter()
                .zip(bufs)
                .map(|(r, mut buf)| {
                    let mesh = &meshes[r];
                    scope.spawn(move || {
                        match mesh.hier_transport(gid, plan).unwrap() {
                            HierRole::Member { mut link } => {
                                hier_member(&mut link, &mut buf, k, |_, _| Ok(()))
                                    .unwrap();
                            }
                            HierRole::Leader { mut members, mut ring } => {
                                hier_leader(
                                    &mut members,
                                    ring.as_mut().map(|(t, pos, l)| (t, *pos, *l)),
                                    p_total,
                                    &mut buf,
                                    k,
                                    |_, _| {},
                                )
                                .unwrap();
                            }
                        }
                        (r, buf)
                    })
                })
                .collect();
            let mut out = vec![Vec::new(); meshes.len()];
            for h in handles {
                let (r, buf) = h.join().unwrap();
                out[r] = buf;
            }
            out
        })
    }

    #[test]
    fn hier_transport_two_level_matches_mean() {
        // Two nodes of ragged size over real sockets; the two-level
        // collective must land every rank on the group mean.
        let topo = crate::topo::Topology::parse("a:0,1,2;b:3,4", 5).unwrap();
        let members = [0usize, 1, 2, 3, 4];
        let plan = SyncPlan::make(&members, Some(&topo), &[0.0; 5]);
        assert_eq!(plan.leaders().len(), 2);
        let meshes = cluster_meshes(5, 10);
        let n = 67;
        let mut rng = Pcg32::new(3);
        let bufs: Vec<Vec<f32>> = (0..5)
            .map(|_| (0..n).map(|_| rng.gen_f32() * 2.0 - 1.0).collect())
            .collect();
        // run_hier hands buffers out in ring order — keep them aligned
        let order = plan.ring_order();
        let expect: Vec<f32> = (0..n)
            .map(|i| bufs.iter().map(|b| b[i]).sum::<f32>() / 5.0)
            .collect();
        let ordered: Vec<Vec<f32>> =
            order.iter().map(|&r| bufs[r].clone()).collect();
        let results = run_hier(&meshes, &plan, 21, ordered, 3);
        for (r, buf) in results.iter().enumerate() {
            for i in 0..n {
                assert!(
                    (buf[i] - expect[i]).abs() < 1e-5,
                    "rank {r} idx {i}: {} vs {}",
                    buf[i],
                    expect[i]
                );
            }
        }
    }

    #[test]
    fn hier_poison_unwinds_both_levels() {
        // A member deserts mid-collective: its leader's gather aborts, the
        // leader floods poison over its node and the leader ring, and the
        // far node — leader and member alike — unwinds with the typed
        // abort instead of hanging on a socket timeout.
        use crate::collectives::hier::{hier_leader, hier_member};
        let topo = crate::topo::Topology::parse("a:0,1;b:2,3", 4).unwrap();
        let members = [0usize, 1, 2, 3];
        let plan = SyncPlan::make(&members, Some(&topo), &[0.0; 4]);
        let meshes = cluster_meshes(4, 10);
        let p_total = plan.total();
        thread::scope(|scope| {
            let plan = &plan;
            // rank 1 (member of node a): joins its edge, then poisons
            let m1 = &meshes[1];
            let h1 = scope.spawn(move || {
                let mut role = m1.hier_transport(31, plan).unwrap();
                role.poison_all();
            });
            // rank 0 (leader of node a): gather aborts; flood the poison
            let m0 = &meshes[0];
            let h0 = scope.spawn(move || {
                let mut buf = vec![1.0f32; 8];
                let mut role = m0.hier_transport(31, plan).unwrap();
                let HierRole::Leader { ref mut members, ref mut ring } = role else {
                    panic!("rank 0 must lead node a");
                };
                let err = hier_leader(
                    members,
                    ring.as_mut().map(|(t, pos, l)| (t, *pos, *l)),
                    p_total,
                    &mut buf,
                    1,
                    |_, _| {},
                )
                .expect_err("poisoned gather must fail");
                assert!(err.downcast_ref::<AbortedError>().is_some(), "{err:#}");
                role.poison_all();
            });
            // rank 2 (leader of node b): ring read aborts; flood onward
            let m2 = &meshes[2];
            let h2 = scope.spawn(move || {
                let mut buf = vec![2.0f32; 8];
                let mut role = m2.hier_transport(31, plan).unwrap();
                let HierRole::Leader { ref mut members, ref mut ring } = role else {
                    panic!("rank 2 must lead node b");
                };
                let err = hier_leader(
                    members,
                    ring.as_mut().map(|(t, pos, l)| (t, *pos, *l)),
                    p_total,
                    &mut buf,
                    1,
                    |_, _| {},
                )
                .expect_err("ring neighbour's poison must abort");
                assert!(err.downcast_ref::<AbortedError>().is_some(), "{err:#}");
                role.poison_all();
            });
            // rank 3 (member of node b): ships its shard, then its
            // broadcast wait must end in the typed abort from its leader
            let m3 = &meshes[3];
            let h3 = scope.spawn(move || {
                let mut buf = vec![3.0f32; 8];
                let HierRole::Member { mut link } =
                    m3.hier_transport(31, plan).unwrap()
                else {
                    panic!("rank 3 must be a plain member");
                };
                let err = hier_member(&mut link, &mut buf, 1, |_, _| Ok(()))
                    .expect_err("leader's poison must abort the broadcast wait");
                assert!(err.downcast_ref::<AbortedError>().is_some(), "{err:#}");
            });
            h1.join().unwrap();
            h0.join().unwrap();
            h2.join().unwrap();
            h3.join().unwrap();
        });
    }

    #[test]
    fn consecutive_groups_reuse_edges() {
        // Two back-to-back pair collectives over the same mesh: the second
        // must reuse the cached streams and still verify its own gid tag.
        let members = [0usize, 1];
        let mut meshes: Vec<WorkerMesh> = members
            .iter()
            .map(|&r| WorkerMesh::bind(r, "127.0.0.1:0").unwrap())
            .collect();
        let addrs: Vec<SocketAddr> = meshes.iter().map(|m| m.local_addr()).collect();
        for m in &mut meshes {
            m.set_peers(addrs.clone());
            m.io_timeout = Duration::from_secs(10);
        }
        for gid in [1u64, 2] {
            let results: Vec<Vec<f32>> = thread::scope(|scope| {
                let handles: Vec<_> = meshes
                    .iter()
                    .enumerate()
                    .map(|(r, mesh)| {
                        let members = &members;
                        scope.spawn(move || {
                            let mut buf = vec![r as f32; 8];
                            let (mut t, pos) = mesh.ring_transport(gid, members).unwrap();
                            ring_allreduce_via(pos, 2, &mut buf, &mut t).unwrap();
                            buf
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            for buf in &results {
                assert!(buf.iter().all(|&v| (v - 0.5).abs() < 1e-6), "{buf:?}");
            }
        }
    }
}
