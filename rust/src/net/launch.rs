//! `ripples launch`: spawn an N-process P-Reduce cluster on localhost.
//!
//! The launcher owns the control plane (an in-process [`GgServer`]) and
//! orchestrates worker *processes* (the `ripples worker` subcommand)
//! through a three-phase handshake:
//!
//!  1. every worker binds its data-plane listener on an ephemeral port
//!     and prints `DATA_ADDR <addr>`;
//!  2. the launcher broadcasts the full rank-indexed list over stdin
//!     (`PEERS a0,a1,...`) — no fixed ports, no bind races;
//!  3. workers train, drain, and print a `REPORT` line the launcher
//!     aggregates into a per-worker throughput table (`metrics`).
//!
//! This is the deployment shape of the paper's §6 testbed scaled to one
//! machine; pointing the same `ripples worker` flags (`--gg`, `--listen`,
//! `--peers`) at real hosts is the multi-machine path (DESIGN.md
//! §Deployment).

use std::io::{BufRead, BufReader, Write};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::cluster::SlowdownEvent;
use crate::collectives::codec::WireCodec;
use crate::collectives::pipeline::OverlapConfig;
use crate::config::AlgoKind;
use crate::gg::GgConfig;
use crate::metrics::{speed_table, worker_table, WorkerStat};
use crate::rpc::{GgClient, GgServer, LivenessConfig, StatsReport};

use super::ps::PsServer;
use super::worker::{format_worker_schedule, WorkerReport};

/// Chaos orchestration: kill one worker mid-run, optionally spawn a
/// checkpoint-restored replacement that rejoins the cluster.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KillSpec {
    /// Rank to SIGKILL.
    pub rank: usize,
    /// Seconds after the peer-list broadcast to pull the trigger —
    /// mid-collective with any realistic compute floor.
    pub after_secs: f64,
    /// Spawn a `--rejoin` replacement this many seconds after the kill
    /// (needs `ckpt_dir`); None = the rank stays gone.
    pub rejoin_after_secs: Option<f64>,
}

/// Cluster-launch configuration (CLI: `ripples launch`).
#[derive(Debug, Clone)]
pub struct LaunchConfig {
    /// Path to the `ripples` binary to spawn workers from.
    pub bin: PathBuf,
    pub workers: usize,
    /// Data-plane algorithm (`--algo ripples|allreduce|adpsgd|ps`):
    /// GG-scheduled P-Reduce groups (the default), a full-cluster ring
    /// every iteration, randomized pairwise atomic averaging, or a
    /// sharded parameter server hosted by the launcher.
    pub algo: AlgoKind,
    /// Key-range shards for `--algo ps` (forwarded as `--ps-shards`).
    pub ps_shards: usize,
    /// `(worker, factor)`: that worker's compute takes `factor`x as long.
    pub slow: Option<(usize, f64)>,
    /// Mid-run speed changes (`--slow-schedule W,F@ITER[;...]`): worker
    /// `W`'s factor becomes `F` once its local iteration count reaches
    /// `ITER` — a straggler can appear or recover while the cluster
    /// runs, and only the GG's *measured* speed table can see it.
    pub slow_schedule: Vec<SlowdownEvent>,
    /// Timed training window per worker, seconds.
    pub secs: f64,
    /// Per-worker iteration cap (0 = unlimited).
    pub max_iters: u64,
    pub group_size: usize,
    /// Smart GG (Group Buffer + Global Division + slowdown filter) vs
    /// plain random groups.
    pub smart: bool,
    /// §5.3 slowdown-filter threshold (smart mode).
    pub c_thres: u64,
    /// Workers per "node" for the GG's architecture-aware scheduling;
    /// local processes default to 1 (every process models its own host).
    pub workers_per_node: usize,
    pub seed: u64,
    pub lr: f32,
    pub batch: usize,
    pub data_bias: f64,
    pub compute_floor_ms: u64,
    pub tiny: bool,
    /// Forward worker log lines to the launcher's stdout.
    pub echo: bool,
    /// Pipelined P-Reduce with compute/communication overlap
    /// (`--overlap-shards K`, `--max-staleness S`), forwarded to every
    /// worker — shard step tags are part of the wire schedule, so the
    /// whole cluster must agree on `K`.
    pub overlap: OverlapConfig,
    /// Loader-stage queue depth (`--prefetch`), forwarded to every
    /// worker; 0 keeps the inline bit-identical batch draw.
    pub prefetch: usize,
    /// Emulated per-batch I/O latency in ms (`--load-ms`), forwarded to
    /// every worker.
    pub load_floor_ms: u64,
    /// Data-plane wire codec (`--wire fp32|fp16|q8`), forwarded to every
    /// worker so the whole cluster compresses uniformly.
    pub wire: WireCodec,
    /// GG failure-detection deadline in ms (0 disables the monitor —
    /// a crash then holds its locks forever, the pre-fault-tolerance
    /// behaviour).
    pub liveness_ms: u64,
    /// Worker heartbeat period in ms (0 = no beacon threads).
    pub heartbeat_ms: u64,
    /// Checkpoint cadence forwarded to workers (`--ckpt-every`; 0 = off).
    pub ckpt_every: u64,
    /// Shared checkpoint directory (`--ckpt-dir`).
    pub ckpt_dir: Option<PathBuf>,
    /// Chaos orchestration (`--kill R@SECS`, `--rejoin-after SECS`).
    pub kill: Option<KillSpec>,
    /// Physical placement map (`--topo m0:0,1;m1:2,3`): rank → machine.
    /// With a map, the GG plans two-level hierarchical P-Reduce for
    /// groups spanning machines; None keeps flat rings everywhere.
    pub topo: Option<String>,
}

impl Default for LaunchConfig {
    fn default() -> Self {
        Self {
            bin: std::env::current_exe().unwrap_or_else(|_| PathBuf::from("ripples")),
            workers: 4,
            algo: AlgoKind::RipplesSmart,
            ps_shards: 4,
            slow: None,
            slow_schedule: Vec::new(),
            secs: 5.0,
            max_iters: 0,
            group_size: 2,
            smart: true,
            c_thres: 2,
            workers_per_node: 1,
            seed: 42,
            lr: 0.1,
            batch: 32,
            data_bias: 0.5,
            compute_floor_ms: 5,
            tiny: true,
            echo: false,
            overlap: OverlapConfig::serial(),
            prefetch: 0,
            load_floor_ms: 0,
            wire: WireCodec::Fp32,
            liveness_ms: 4000,
            heartbeat_ms: 200,
            ckpt_every: 0,
            ckpt_dir: None,
            kill: None,
            topo: None,
        }
    }
}

/// Aggregated outcome of a cluster run.
#[derive(Debug)]
pub struct LaunchReport {
    /// Reports from every worker that finished — the killed rank has
    /// none; its replacement (if any) reports under the same rank.
    pub workers: Vec<WorkerReport>,
    /// GG counters plus the measured speed table.
    pub gg_stats: StatsReport,
    /// Configured ground-truth slowdown factor per worker (final
    /// schedule state) — what the measured table should converge to.
    pub true_factors: Vec<f64>,
    /// The rank SIGKILLed by the chaos spec, if any.
    pub killed: Option<usize>,
    /// GG counters snapshotted right after the kill — the "before" for
    /// assertions like "the rejoined rank was drafted *again*".
    pub gg_stats_at_kill: Option<StatsReport>,
}

impl LaunchReport {
    /// Per-worker throughput rows for `metrics::worker_table`.
    pub fn stats(&self) -> Vec<WorkerStat> {
        self.workers
            .iter()
            .map(|w| WorkerStat {
                rank: w.rank,
                iters: w.iters,
                preduces: w.preduces,
                secs: w.secs,
                loss_first: w.loss_first,
                loss_last: w.loss_last,
                bytes_tx: w.bytes_tx,
                bytes_rx: w.bytes_rx,
                load_wait_secs: w.load_wait_secs,
                compute_wait_secs: w.compute_wait_secs,
                reconcile_wait_secs: w.reconcile_wait_secs,
            })
            .collect()
    }

    pub fn render(&self) -> String {
        let s = &self.gg_stats;
        let mut out = format!(
            "{}\nGG: {} requests, {} groups, {} conflicts, {} buffer hits\n",
            worker_table(&self.stats()).render(),
            s.requests,
            s.groups_created,
            s.conflicts,
            s.buffer_hits,
        );
        if s.deaths > 0 || s.groups_aborted > 0 || s.rejoins > 0 {
            out.push_str(&format!(
                "faults: {} deaths, {} groups aborted, {} rejoins{}\n",
                s.deaths,
                s.groups_aborted,
                s.rejoins,
                self.killed.map(|r| format!(" (rank {r} killed)")).unwrap_or_default(),
            ));
        }
        if s.speeds.iter().any(|&v| v > 0.0) {
            out.push_str("measured speed table (GG view):\n");
            out.push_str(&speed_table(&s.speeds, &self.true_factors, &s.drafts).render());
        }
        out
    }
}

/// Spawn the GG and `workers` local worker processes; block until every
/// worker has drained and reported.
pub fn launch_local(cfg: &LaunchConfig) -> Result<LaunchReport> {
    if cfg.workers < 2 {
        bail!("launch needs at least 2 workers");
    }
    if matches!(cfg.algo, AlgoKind::DPsgd) {
        bail!("--algo d-psgd is simulator-only (use `ripples sim`)");
    }
    // Group size only parameterizes the Ripples schedulers; All-Reduce is
    // a full-cluster ring and AD-PSGD / PS ignore the GG's group machinery.
    let ripples = matches!(
        cfg.algo,
        AlgoKind::RipplesSmart | AlgoKind::RipplesStatic | AlgoKind::RipplesRandom
    );
    if ripples && (cfg.group_size < 2 || cfg.group_size > cfg.workers) {
        bail!("group size {} out of range for {} workers", cfg.group_size, cfg.workers);
    }
    if cfg.ps_shards == 0 {
        bail!("ps-shards must be >= 1");
    }
    if let Some((w, f)) = cfg.slow {
        if w >= cfg.workers {
            bail!("slow worker {w} out of range");
        }
        if f < 1.0 {
            bail!("slowdown factor {f} must be >= 1");
        }
    }
    for ev in &cfg.slow_schedule {
        if ev.worker >= cfg.workers {
            bail!("slow-schedule worker {} out of range", ev.worker);
        }
        if ev.factor < 1.0 {
            bail!("slow-schedule factor {} must be >= 1", ev.factor);
        }
    }
    cfg.overlap.validate().map_err(|e| anyhow::anyhow!("bad overlap config: {e}"))?;
    crate::step::PipelineConfig {
        prefetch: cfg.prefetch,
        load_secs: cfg.load_floor_ms as f64 / 1000.0,
    }
    .validate()
    .map_err(|e| anyhow::anyhow!("bad pipeline config: {e}"))?;
    if let Some(kill) = &cfg.kill {
        if kill.rank >= cfg.workers {
            bail!("kill rank {} out of range", kill.rank);
        }
        if kill.after_secs < 0.0 || kill.after_secs >= cfg.secs {
            bail!("kill time {}s outside the {}s training window", kill.after_secs, cfg.secs);
        }
        if kill.rejoin_after_secs.is_some() && cfg.ckpt_dir.is_none() {
            bail!("rejoin needs ckpt_dir (the replacement restores from it)");
        }
        if cfg.liveness_ms == 0 || cfg.heartbeat_ms == 0 {
            bail!("kill orchestration needs liveness_ms and heartbeat_ms > 0");
        }
    }
    // Workers physically rendezvous to execute groups, so the GG must
    // draft only idle workers into fresh groups and every member's own
    // Sync must resolve to the already-scheduled group (Group Buffer) —
    // otherwise two conflicting groups deadlock waiting on each other
    // (same constraint as `runtime::threaded`, which only offers
    // SmartGg/Static). The event simulator runs without `rendezvous`.
    // All-Reduce is "one group = the whole cluster, every iteration";
    // AD-PSGD and PS only use the GG for registration/liveness, so any
    // valid group size will do.
    let (group_size, smart) = match cfg.algo {
        AlgoKind::AllReduce => (cfg.workers, false),
        AlgoKind::AdPsgd | AlgoKind::ParameterServer => (2, false),
        _ => (cfg.group_size, cfg.smart),
    };
    let mut gg_cfg = if smart {
        GgConfig::smart(cfg.workers, cfg.workers_per_node, group_size, cfg.c_thres)
    } else {
        let mut c = GgConfig::random(cfg.workers, cfg.workers_per_node, group_size);
        c.use_group_buffer = true;
        c
    };
    gg_cfg.rendezvous = true;
    if let Some(spec) = &cfg.topo {
        gg_cfg.topology = Some(
            crate::topo::Topology::parse(spec, cfg.workers).context("bad --topo map")?,
        );
    }
    let liveness = (cfg.liveness_ms > 0)
        .then(|| LivenessConfig::with_timeout(Duration::from_millis(cfg.liveness_ms)));
    let server = GgServer::spawn_with_liveness("127.0.0.1:0", gg_cfg, cfg.seed, liveness)
        .context("spawn GG")?;
    let gg_addr = server.addr.to_string();

    // For --algo ps the launcher also hosts the sharded parameter server,
    // speaking the same wire codec as the workers.
    let ps_server = if matches!(cfg.algo, AlgoKind::ParameterServer) {
        let io = Duration::from_secs_f64((cfg.secs * 4.0).max(60.0));
        Some(
            PsServer::spawn("127.0.0.1:0", cfg.workers, cfg.ps_shards, cfg.wire, io)
                .context("spawn parameter server")?,
        )
    } else {
        None
    };
    let ps_addr = ps_server.as_ref().map(|s| s.addr().to_string());

    // One persistent control-plane client for every launcher-side stats
    // snapshot: reconnecting per call paid a fresh TCP round trip each
    // time (and inflated the server's accepted-connection count — see
    // the connection-reuse regression test in `rpc`).
    let mut stats_client = GgClient::connect(server.addr).context("GG stats client")?;

    // Any failure below must not leak worker processes: they would keep
    // training (and holding sockets) for the rest of their timed window.
    let mut children: Vec<WorkerProc> = Vec::new();
    let result =
        run_cluster(cfg, &gg_addr, ps_addr.as_deref(), &mut stats_client, &mut children);
    if result.is_err() {
        for wp in &mut children {
            let _ = wp.child.kill();
            let _ = wp.child.wait();
        }
    }
    let (reports, gg_stats_at_kill) = result?;

    let gg_stats = stats_client.stats()?;
    drop(stats_client);
    server.shutdown();
    if let Some(ps) = ps_server {
        // all workers reported, so the server loop has drained; surface
        // any protocol error it hit
        let _rounds = ps.join().context("parameter server")?;
    }
    // Ground truth per worker: the final scheduled factor, else static
    // (same resolution rule as the worker loop, evaluated at iter = MAX).
    let true_factors = (0..cfg.workers)
        .map(|w| {
            let base = match cfg.slow {
                Some((sw, f)) if sw == w => f,
                _ => 1.0,
            };
            crate::cluster::scheduled_factor_at(
                cfg.slow_schedule
                    .iter()
                    .filter(|ev| ev.worker == w)
                    .map(|ev| (ev.factor, ev.start_iter)),
                base,
                u64::MAX,
            )
        })
        .collect();
    Ok(LaunchReport {
        workers: reports,
        gg_stats,
        true_factors,
        killed: cfg.kill.map(|k| k.rank),
        gg_stats_at_kill,
    })
}

struct WorkerProc {
    rank: usize,
    child: Child,
    out: BufReader<std::process::ChildStdout>,
    /// False for the SIGKILLed rank: EOF without a report is expected.
    expect_report: bool,
}

/// Shared argv for an original worker or a rejoining replacement.
fn worker_command(
    cfg: &LaunchConfig,
    gg_addr: &str,
    ps_addr: Option<&str>,
    rank: usize,
    secs: f64,
) -> Command {
    let slowdown = match cfg.slow {
        Some((w, f)) if w == rank => f,
        _ => 1.0,
    };
    // this rank's share of the cluster-wide slowdown schedule
    let rank_schedule: Vec<(f64, u64)> = cfg
        .slow_schedule
        .iter()
        .filter(|ev| ev.worker == rank)
        .map(|ev| (ev.factor, ev.start_iter))
        .collect();
    let mut cmd = Command::new(&cfg.bin);
    cmd.arg("worker")
        .args(["--rank", &rank.to_string()])
        .args(["--workers", &cfg.workers.to_string()])
        .args(["--gg", gg_addr])
        .args(["--secs", &secs.to_string()])
        .args(["--slowdown", &slowdown.to_string()])
        .args(["--seed", &cfg.seed.to_string()])
        .args(["--lr", &cfg.lr.to_string()])
        .args(["--batch", &cfg.batch.to_string()])
        .args(["--bias", &cfg.data_bias.to_string()])
        .args(["--floor-ms", &cfg.compute_floor_ms.to_string()])
        .args(["--model", if cfg.tiny { "tiny" } else { "paper" }])
        .args(["--overlap-shards", &cfg.overlap.shards.to_string()])
        .args(["--max-staleness", &cfg.overlap.max_staleness.to_string()])
        .args(["--prefetch", &cfg.prefetch.to_string()])
        .args(["--load-ms", &cfg.load_floor_ms.to_string()])
        .args(["--wire", cfg.wire.name()])
        .args(["--heartbeat-ms", &cfg.heartbeat_ms.to_string()])
        .args(["--algo", cfg.algo.name()])
        .stdout(Stdio::piped());
    if let Some(ps) = ps_addr {
        cmd.args(["--ps", ps]).args(["--ps-shards", &cfg.ps_shards.to_string()]);
    }
    if cfg.max_iters > 0 {
        cmd.args(["--iters", &cfg.max_iters.to_string()]);
    }
    if !rank_schedule.is_empty() {
        cmd.args(["--slow-schedule", &format_worker_schedule(&rank_schedule)]);
    }
    if cfg.ckpt_every > 0 {
        cmd.args(["--ckpt-every", &cfg.ckpt_every.to_string()]);
    }
    if let Some(dir) = &cfg.ckpt_dir {
        cmd.args(["--ckpt-dir", &dir.display().to_string()]);
    }
    cmd
}

/// Phases 1–3 of the cluster run (plus the optional chaos phase);
/// every spawned child is pushed into `children` *before* any fallible
/// step so the caller can reap them. Returns the collected reports and,
/// when a kill was orchestrated, the GG stats snapshotted right after it.
fn run_cluster(
    cfg: &LaunchConfig,
    gg_addr: &str,
    ps_addr: Option<&str>,
    stats_client: &mut GgClient,
    children: &mut Vec<WorkerProc>,
) -> Result<(Vec<WorkerReport>, Option<StatsReport>)> {
    // ---- phase 1: spawn everyone, collect advertised data-plane addrs
    let mut addrs: Vec<String> = Vec::new();
    for rank in 0..cfg.workers {
        let mut cmd = worker_command(cfg, gg_addr, ps_addr, rank, cfg.secs);
        cmd.stdin(Stdio::piped());
        let mut child = cmd
            .spawn()
            .with_context(|| format!("spawn worker {rank} from {}", cfg.bin.display()))?;
        let out = BufReader::new(child.stdout.take().expect("piped stdout"));
        // registered before any fallible read so the caller can reap it
        children.push(WorkerProc { rank, child, out, expect_report: true });
        let wp = children.last_mut().unwrap();
        let addr = loop {
            let mut line = String::new();
            if wp.out.read_line(&mut line).context("worker stdout")? == 0 {
                bail!("worker {rank} exited before advertising its data address");
            }
            if let Some(a) = line.trim().strip_prefix("DATA_ADDR ") {
                break a.to_string();
            }
            if cfg.echo {
                print!("[w{rank}] {line}");
            }
        };
        addrs.push(addr);
    }

    // ---- phase 2: broadcast the rank-indexed peer list
    let peer_line = format!("PEERS {}\n", addrs.join(","));
    for wp in children.iter_mut() {
        wp.child
            .stdin
            .take()
            .expect("piped stdin")
            .write_all(peer_line.as_bytes())
            .with_context(|| format!("send peer list to worker {}", wp.rank))?;
        // stdin handle drops here; workers only read the one line
    }
    let training_started = Instant::now();

    // ---- chaos phase: SIGKILL the victim mid-run; optionally spawn a
    // checkpoint-restored replacement that rejoins under the same rank
    let mut stats_at_kill = None;
    if let Some(kill) = &cfg.kill {
        std::thread::sleep(Duration::from_secs_f64(kill.after_secs));
        let victim = &mut children[kill.rank];
        victim.child.kill().context("kill victim worker")?;
        victim.child.wait().context("reap victim worker")?;
        victim.expect_report = false;
        stats_at_kill = Some(stats_client.stats().context("stats after kill")?);
        if let Some(rejoin_after) = kill.rejoin_after_secs {
            std::thread::sleep(Duration::from_secs_f64(rejoin_after));
            let remaining =
                (cfg.secs - training_started.elapsed().as_secs_f64()).max(1.0);
            let mut cmd = worker_command(cfg, gg_addr, ps_addr, kill.rank, remaining);
            // explicit peer list: no launcher handshake the second time
            // (the replacement registers its fresh address with the GG,
            // which survivors re-resolve via Lookup)
            cmd.args(["--peers", &addrs.join(",")])
                .args(["--rejoin", "true"])
                .stdin(Stdio::null());
            let mut child = cmd.spawn().with_context(|| {
                format!("spawn replacement for rank {}", kill.rank)
            })?;
            let out = BufReader::new(child.stdout.take().expect("piped stdout"));
            children.push(WorkerProc {
                rank: kill.rank,
                child,
                out,
                expect_report: true,
            });
        }
    }

    // ---- phase 3: collect reports
    let mut reports: Vec<WorkerReport> = Vec::new();
    for wp in children.iter_mut() {
        let rank = wp.rank;
        let mut report = None;
        let mut line = String::new();
        loop {
            line.clear();
            if wp.out.read_line(&mut line).context("worker stdout")? == 0 {
                break;
            }
            if line.trim().starts_with("REPORT ") {
                // strict parse: a corrupted report line must fail the
                // launch naming the offending rank, not aggregate zeros
                report = Some(
                    WorkerReport::parse_line(&line)
                        .with_context(|| format!("worker {rank}: bad report line"))?,
                );
            } else if cfg.echo {
                print!("[w{rank}] {line}");
            }
        }
        if !wp.expect_report {
            continue; // SIGKILLed: already reaped, no report expected
        }
        let status = wp.child.wait().context("wait for worker")?;
        if !status.success() {
            bail!("worker {rank} failed with {status}");
        }
        let report =
            report.with_context(|| format!("worker {rank} exited without a report"))?;
        if report.rank != rank {
            bail!("worker {rank} reported as rank {}", report.rank);
        }
        reports.push(report);
    }
    Ok((reports, stats_at_kill))
}
