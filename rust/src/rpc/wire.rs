//! Byte-level codec helpers for the RPC frames (little-endian, no deps).

use anyhow::{bail, Result};

/// Append-only byte writer.
#[derive(Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn bytes(&mut self, v: &[u8]) {
        self.buf.extend_from_slice(v);
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Checked byte reader.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("truncated frame: need {n} at {}, have {}", self.pos, self.buf.len());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Take exactly `n` bytes (length-prefixed payloads).
    pub fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        self.take(n)
    }

    /// Remaining bytes (consumes them).
    pub fn rest(&mut self) -> Vec<u8> {
        let s = self.buf[self.pos..].to_vec();
        self.pos = self.buf.len();
        s
    }

    /// Assert the frame is fully consumed.
    pub fn done(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!("trailing bytes in frame: {} unread", self.buf.len() - self.pos);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_types() {
        let mut w = Writer::new();
        w.u8(7);
        w.u32(0xDEADBEEF);
        w.u64(0x0123_4567_89AB_CDEF);
        w.bytes(b"hi");
        let buf = w.finish();
        let mut r = Reader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEADBEEF);
        assert_eq!(r.u64().unwrap(), 0x0123_4567_89AB_CDEF);
        assert_eq!(r.rest(), b"hi");
        assert!(r.done().is_ok());
    }

    #[test]
    fn truncation_detected() {
        let mut r = Reader::new(&[1, 2]);
        assert!(r.u32().is_err());
    }

    #[test]
    fn bytes_takes_exact_and_detects_truncation() {
        let mut r = Reader::new(&[9, 8, 7]);
        assert_eq!(r.bytes(2).unwrap(), &[9, 8]);
        assert!(r.bytes(2).is_err(), "only one byte left");
    }

    #[test]
    fn trailing_bytes_detected() {
        let r = Reader::new(&[1]);
        assert!(r.done().is_err());
    }
}
