//! Event-driven serving for the GG RPC service (DESIGN.md §Scale).
//!
//! The previous server burned one blocking thread per connection plus a
//! 2 ms accept-poll sleep — fine at 4 ranks, not at the hundreds the
//! scale sweep hosts in one process. This module replaces it with:
//!
//! * **one reactor thread** owning the listener and every connection:
//!   non-blocking accepts, non-blocking reads into per-connection
//!   buffers, frame extraction, and outbox flushing, with an adaptive
//!   idle backoff (50 µs → 1 ms) instead of a fixed sleep;
//! * **a small worker pool** draining a condvar work queue: decode the
//!   request, run [`handle_request`] against the shared backend, append
//!   the response frame to the connection's outbox;
//! * **parked waits**: `WaitArmed`/`WaitDone` that cannot resolve yet
//!   hold no thread and no lock — they sit in a waiter list that is
//!   re-evaluated whenever the backend's epoch counter moves (every
//!   phase-changing operation bumps it). The old path polled the state
//!   lock every 1 ms per waiting connection.
//!
//! Concurrency contract with clients: a [`GgClient`](super::GgClient)
//! issues one call at a time per connection (synchronous request →
//! response), so per-connection response ordering is trivially
//! preserved. Frames are still atomic even for a misbehaving pipelined
//! client — each response is appended to the outbox under its mutex in
//! one piece — but interleaving *order* is only guaranteed for the
//! one-outstanding-call contract every client in this repo follows.

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::{handle_request, resolve_wait, Handled, Request, Response, ServerShared};
use crate::gg::GroupId;

/// Same frame cap as the blocking codec path.
const MAX_FRAME: usize = 1 << 24;

/// Idle backoff bounds: reset to `IDLE_MIN` on any progress, double up
/// to `IDLE_MAX` while nothing moves. Replaces the fixed 2 ms sleep.
const IDLE_MIN: Duration = Duration::from_micros(50);
const IDLE_MAX: Duration = Duration::from_millis(1);

/// Best-effort flush budget for responses still queued at shutdown.
const DRAIN_BUDGET: Duration = Duration::from_millis(500);

/// One client connection. The reactor owns reads; responses are staged
/// in `out` (worker threads append whole frames under the mutex, then
/// opportunistically flush; the reactor re-flushes whatever the socket
/// buffer refused).
struct Conn {
    stream: TcpStream,
    out: Mutex<Vec<u8>>,
    closed: AtomicBool,
}

impl Conn {
    fn close(&self) {
        self.closed.store(true, Ordering::Release);
    }

    fn is_closed(&self) -> bool {
        self.closed.load(Ordering::Acquire)
    }
}

/// Reactor-private per-connection read state.
struct ConnState {
    conn: Arc<Conn>,
    rd: Vec<u8>,
}

/// A decoded-frame unit of work for the pool.
struct Job {
    conn: Arc<Conn>,
    frame: Vec<u8>,
}

#[derive(Default)]
struct WorkQueue {
    jobs: Mutex<VecDeque<Job>>,
    cv: Condvar,
}

/// A parked `WaitArmed`/`WaitDone` holding no thread.
struct Waiter {
    conn: Arc<Conn>,
    id: GroupId,
    want_armed: bool,
}

/// Bind `addr` and start the reactor; returns the bound address and the
/// reactor's join handle (workers are joined inside it).
pub(crate) fn spawn(
    addr: &str,
    shared: Arc<ServerShared>,
    stop: Arc<AtomicBool>,
) -> Result<(SocketAddr, thread::JoinHandle<()>)> {
    let listener = TcpListener::bind(addr).context("bind GG server")?;
    let local = listener.local_addr()?;
    listener.set_nonblocking(true).context("nonblocking GG listener")?;
    let handle = thread::spawn(move || run(listener, shared, stop));
    Ok((local, handle))
}

fn run(listener: TcpListener, shared: Arc<ServerShared>, stop: Arc<AtomicBool>) {
    let queue = Arc::new(WorkQueue::default());
    let waiters: Arc<Mutex<Vec<Waiter>>> = Arc::new(Mutex::new(Vec::new()));
    let n_workers =
        thread::available_parallelism().map(|p| p.get()).unwrap_or(1).clamp(2, 8);
    let workers: Vec<_> = (0..n_workers)
        .map(|_| {
            let (shared, queue, waiters, stop) = (
                Arc::clone(&shared),
                Arc::clone(&queue),
                Arc::clone(&waiters),
                Arc::clone(&stop),
            );
            thread::spawn(move || worker_loop(&shared, &queue, &waiters, &stop))
        })
        .collect();

    let mut conns: Vec<ConnState> = Vec::new();
    let mut idle = IDLE_MIN;
    let mut last_epoch = shared.backend.epoch();
    while !stop.load(Ordering::Relaxed) {
        let mut progress = false;
        // accept everything ready, without blocking
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(true).ok();
                    stream.set_nodelay(true).ok();
                    shared.connections_accepted.fetch_add(1, Ordering::AcqRel);
                    conns.push(ConnState {
                        conn: Arc::new(Conn {
                            stream,
                            out: Mutex::new(Vec::new()),
                            closed: AtomicBool::new(false),
                        }),
                        rd: Vec::new(),
                    });
                    progress = true;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(_) => break,
            }
        }
        for cs in &mut conns {
            progress |= pump_reads(cs, &queue);
        }
        for cs in &conns {
            progress |= flush(&cs.conn);
        }
        conns.retain(|cs| !cs.conn.is_closed());
        // Re-evaluate parked waits only when some group's phase may have
        // changed — the epoch is bumped by every mutating backend op.
        let epoch = shared.backend.epoch();
        if epoch != last_epoch {
            last_epoch = epoch;
            progress |= sweep_waiters(&shared, &waiters);
        }
        if progress {
            idle = IDLE_MIN;
        } else {
            thread::sleep(idle);
            idle = (idle * 2).min(IDLE_MAX);
        }
    }

    // Shutdown: wake and join the pool, fail whatever is still parked,
    // then best-effort flush the queued responses (the Shutdown Ok
    // itself is one of them).
    queue.cv.notify_all();
    for w in workers {
        let _ = w.join();
    }
    let parked: Vec<Waiter> = std::mem::take(&mut *waiters.lock().unwrap());
    for w in parked {
        send(&w.conn, &Response::Err { msg: "server stopping".into() });
    }
    let deadline = Instant::now() + DRAIN_BUDGET;
    loop {
        let mut pending = false;
        for cs in &conns {
            flush(&cs.conn);
            pending |=
                !cs.conn.is_closed() && !cs.conn.out.lock().unwrap().is_empty();
        }
        if !pending || Instant::now() >= deadline {
            break;
        }
        thread::sleep(Duration::from_millis(1));
    }
}

/// Drain readable bytes into the connection's buffer and enqueue every
/// complete frame. Returns whether anything moved.
fn pump_reads(cs: &mut ConnState, queue: &WorkQueue) -> bool {
    if cs.conn.is_closed() {
        return false;
    }
    let mut progress = false;
    let mut buf = [0u8; 8192];
    loop {
        match (&cs.conn.stream).read(&mut buf) {
            Ok(0) => {
                cs.conn.close();
                break;
            }
            Ok(n) => {
                cs.rd.extend_from_slice(&buf[..n]);
                progress = true;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => {
                cs.conn.close();
                break;
            }
        }
    }
    while cs.rd.len() >= 4 {
        let len =
            u32::from_le_bytes([cs.rd[0], cs.rd[1], cs.rd[2], cs.rd[3]]) as usize;
        if len > MAX_FRAME {
            cs.conn.close(); // protocol violation
            break;
        }
        if cs.rd.len() < 4 + len {
            break; // frame still arriving
        }
        let frame = cs.rd[4..4 + len].to_vec();
        cs.rd.drain(..4 + len);
        let mut jobs = queue.jobs.lock().unwrap();
        jobs.push_back(Job { conn: Arc::clone(&cs.conn), frame });
        drop(jobs);
        queue.cv.notify_one();
        progress = true;
    }
    progress
}

fn worker_loop(
    shared: &ServerShared,
    queue: &WorkQueue,
    waiters: &Mutex<Vec<Waiter>>,
    stop: &AtomicBool,
) {
    loop {
        let job = {
            let mut jobs = queue.jobs.lock().unwrap();
            loop {
                if let Some(j) = jobs.pop_front() {
                    break Some(j);
                }
                if stop.load(Ordering::Relaxed) {
                    break None;
                }
                // timeout as a stop-flag backstop (the reactor also
                // notify_all()s on shutdown)
                let (guard, _) = queue
                    .cv
                    .wait_timeout(jobs, Duration::from_millis(50))
                    .unwrap();
                jobs = guard;
            }
        };
        let Some(job) = job else { return };
        match Request::decode(&job.frame) {
            Err(_) => job.conn.close(), // garbage client: drop the session
            Ok(req) => match handle_request(shared, &req, stop) {
                Handled::Reply(resp) => {
                    send(&job.conn, &resp);
                    if matches!(req, Request::Shutdown) {
                        queue.cv.notify_all(); // wake peers to observe stop
                    }
                }
                Handled::Park { id, want_armed } => {
                    waiters
                        .lock()
                        .unwrap()
                        .push(Waiter { conn: Arc::clone(&job.conn), id, want_armed });
                    // The phase may have changed between the handler's
                    // evaluation and the park — sweep once so that
                    // transition is never missed (the reactor only
                    // sweeps on *future* epoch moves).
                    sweep_waiters(shared, waiters);
                }
            },
        }
    }
}

/// Resolve every parked wait that can now answer. Waiters are removed
/// under the list lock (so concurrent sweeps never double-reply) and
/// their responses written after it drops.
fn sweep_waiters(shared: &ServerShared, waiters: &Mutex<Vec<Waiter>>) -> bool {
    let resolved: Vec<(Arc<Conn>, Response)> = {
        let mut ws = waiters.lock().unwrap();
        let mut resolved = Vec::new();
        ws.retain(|w| {
            if w.conn.is_closed() {
                return false; // client hung up while parked
            }
            match resolve_wait(shared, w.id, w.want_armed) {
                Some(resp) => {
                    resolved.push((Arc::clone(&w.conn), resp));
                    false
                }
                None => true,
            }
        });
        resolved
    };
    let progress = !resolved.is_empty();
    for (conn, resp) in resolved {
        send(&conn, &resp);
    }
    progress
}

/// Stage one response frame atomically and try to push it out.
fn send(conn: &Conn, resp: &Response) {
    let payload = resp.encode();
    {
        let mut out = conn.out.lock().unwrap();
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&payload);
    }
    flush(conn);
}

/// Write as much of the outbox as the socket accepts right now.
fn flush(conn: &Conn) -> bool {
    if conn.is_closed() {
        return false;
    }
    let mut out = conn.out.lock().unwrap();
    let mut progress = false;
    while !out.is_empty() {
        match (&conn.stream).write(&out) {
            Ok(0) => {
                conn.close();
                break;
            }
            Ok(n) => {
                out.drain(..n);
                progress = true;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => {
                conn.close();
                break;
            }
        }
    }
    progress
}

#[cfg(test)]
mod tests {
    use crate::gg::GgConfig;
    use crate::rpc::{GgClient, GgMode, GgServer};

    /// Many synchronous clients over real sockets against the reactor:
    /// every request answered, shared state consistent, clean shutdown.
    /// Clients complete *transitively* — a Complete's newly-armed groups
    /// are completed too — so every armed group is finished by whichever
    /// client it was handed to and no `wait_done` can park forever.
    #[test]
    fn reactor_serves_many_concurrent_clients() {
        let server =
            GgServer::spawn("127.0.0.1:0", GgConfig::random(8, 4, 2), 3).unwrap();
        let addr = server.addr;
        let handles: Vec<_> = (0..8)
            .map(|w| {
                std::thread::spawn(move || {
                    let mut c = GgClient::connect(addr).unwrap();
                    // a deadlock should fail loudly, not hang the suite
                    c.set_io_timeout(std::time::Duration::from_secs(30)).unwrap();
                    for _ in 0..20 {
                        let (assigned, armed) = c.sync(w, 0.01).unwrap();
                        let mut todo: Vec<_> =
                            armed.into_iter().map(|(gid, _)| gid).collect();
                        while let Some(gid) = todo.pop() {
                            for (ng, _) in c.complete(gid).unwrap() {
                                todo.push(ng);
                            }
                        }
                        if let Some((gid, _, _)) = assigned {
                            // armed-elsewhere groups finish via that
                            // client's transitive completes
                            c.wait_done(gid).unwrap();
                        }
                        c.heartbeat(w).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let mut c = GgClient::connect(addr).unwrap();
        let stats = c.stats().unwrap();
        assert_eq!(stats.requests, 8 * 20, "every Sync must be served exactly once");
        server.shutdown();
    }

    /// The locked (oracle) backend serves the identical protocol through
    /// the same reactor — it must stay a drop-in for differential runs.
    #[test]
    fn reactor_serves_single_lock_backend_too() {
        let server = GgServer::spawn_with_backend(
            "127.0.0.1:0",
            GgConfig::random(4, 4, 2),
            9,
            None,
            GgMode::SingleLock,
        )
        .unwrap();
        let mut c = GgClient::connect(server.addr).unwrap();
        let (assigned, armed) = c.sync(0, 0.0).unwrap();
        let (gid, _, _) = assigned.expect("sync must assign");
        assert!(!armed.is_empty());
        let _ = c.complete(gid).unwrap();
        assert_eq!(c.stats().unwrap().requests, 1);
        server.shutdown();
    }

    /// A parked WaitDone must resolve when a *different* connection
    /// completes the group — the epoch sweep path, not a poll loop.
    #[test]
    fn parked_wait_resolves_via_epoch_sweep() {
        let server =
            GgServer::spawn("127.0.0.1:0", GgConfig::random(4, 4, 2), 7).unwrap();
        let addr = server.addr;
        let mut c = GgClient::connect(addr).unwrap();
        let (assigned, _) = c.sync(0, 0.0).unwrap();
        let (gid, _, _) = assigned.unwrap();
        let waiter = std::thread::spawn(move || {
            let mut c2 = GgClient::connect(addr).unwrap();
            c2.wait_done(gid).unwrap()
        });
        std::thread::sleep(std::time::Duration::from_millis(50));
        c.complete(gid).unwrap();
        waiter.join().unwrap();
        server.shutdown();
    }

    /// Waits still parked at shutdown get an explicit error response
    /// instead of a hang or a silent close.
    #[test]
    fn shutdown_fails_parked_waits() {
        let server =
            GgServer::spawn("127.0.0.1:0", GgConfig::random(4, 4, 2), 8).unwrap();
        let addr = server.addr;
        let mut c = GgClient::connect(addr).unwrap();
        let (assigned, _) = c.sync(0, 0.0).unwrap();
        let (gid, _, _) = assigned.unwrap();
        let waiter = std::thread::spawn(move || {
            let mut c2 = GgClient::connect(addr).unwrap();
            c2.wait_done(gid) // never completed: parked until shutdown
        });
        std::thread::sleep(std::time::Duration::from_millis(50));
        server.shutdown();
        let err = waiter.join().unwrap();
        assert!(err.is_err(), "parked wait must surface the shutdown as an error");
    }
}
