//! TCP RPC for the Group Generator (§6.2's gRPC service, rebuilt on a
//! length-prefixed binary protocol over std TCP — the vendored registry
//! has no gRPC/tokio, and the messages are tiny control packets anyway).
//!
//! Wire format: every frame is `u32 length (LE) | payload`. Payloads are
//! hand-encoded (see [`wire`]); the protocol has three call families:
//!
//! * scheduling — `Sync`, `Complete`, `WaitArmed`/`WaitDone`, `Stats`;
//! * membership — `Retire` (graceful), `Register`/`Lookup` (data-plane
//!   address registry), `Rejoin` (checkpoint-restored replacement);
//! * fault tolerance — `Heartbeat` (liveness), `AbortGroup` (a ring
//!   survivor reports a broken collective and accuses the peer it saw
//!   fail), `Probe` (armed / pending / done / aborted).
//!
//! The server wraps the same pure Group Generator state machine the
//! simulator and the threaded runtime use — by default the sharded
//! implementation ([`ShardedGg`], DESIGN.md §Scale) so concurrent
//! Sync/Wait/Heartbeat RPCs stop serializing on one mutex; the original
//! single-lock [`GroupGenerator`] stays available as [`GgMode::SingleLock`]
//! (the differential-testing oracle and `--gg-backend locked`). With a
//! [`LivenessConfig`] installed, a monitor thread declares ranks dead
//! when their heartbeat goes stale — quickly when a peer accused them,
//! eventually on the hard timeout — which aborts their in-flight groups
//! so ring partners unwind and retry in repaired groups (DESIGN.md
//! §Fault-tolerance).
//!
//! Serving is event-driven ([`reactor`]): one reactor thread multiplexes
//! every connection over non-blocking sockets and a small worker pool
//! executes decoded requests, so one process hosts hundreds of ranks
//! without a thread per socket — and blocking `WaitArmed`/`WaitDone`
//! calls park instead of burning a 1 ms poll loop each.

pub mod reactor;
pub mod wire;

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::gg::{GgConfig, Group, GroupGenerator, GroupId, GroupPhase, ShardedGg};
use crate::topo::{SyncPlan, Topology};
use crate::util::rng::Pcg32;
use wire::{Reader, Writer};

/// Wire marker for "no suspect" in `AbortGroup`.
const NO_SUSPECT: u32 = u32::MAX;

/// Longest accepted address string on the wire.
const MAX_ADDR_LEN: usize = 1 << 12;

/// Piggybacked speed telemetry: the worker's own EWMA of its local SGD
/// step duration (compute phase only, sync wait excluded). Rides on
/// every `Sync`, so the GG's [`crate::gg::SpeedTable`] tracks *measured*
/// heterogeneity with zero extra round trips. `0.0` = no measurement
/// yet (first iteration); the server ignores it.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct SpeedReport {
    /// EWMA seconds per local SGD step.
    pub ewma_step_secs: f64,
}

impl SpeedReport {
    pub fn new(ewma_step_secs: f64) -> Self {
        Self { ewma_step_secs }
    }
}

/// Client -> server messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Worker `w` reached its sync point; `speed` carries its measured
    /// step-duration EWMA (the slowdown filter's dynamic input).
    Sync { worker: u32, speed: SpeedReport },
    /// Group `id` finished its P-Reduce.
    Complete { id: GroupId },
    /// Fetch counters.
    Stats,
    /// Orderly shutdown.
    Shutdown,
    /// Block until group `id` holds its locks (or was already completed).
    /// Distributed workers call this between `Sync` and the data-plane
    /// collective: a pending group must not start moving model bytes.
    WaitArmed { id: GroupId },
    /// Block until group `id` has been completed. Non-leader members call
    /// this after the collective so their next `Sync` cannot observe the
    /// group still at the front of their Group Buffer (the re-execution
    /// race the threaded runtime solves with shared `done` flags).
    WaitDone { id: GroupId },
    /// Worker `w` leaves the session: never drafted into new groups.
    Retire { worker: u32 },
    /// Liveness beacon from `w`'s heartbeat thread. Any rank-bearing RPC
    /// counts as a heartbeat; this one exists so a worker blocked in a
    /// long collective still proves it is alive.
    Heartbeat { worker: u32 },
    /// A ring survivor observed group `id`'s collective break. The GG
    /// aborts the group (locks released, Group Buffers purged) so every
    /// member unwinds and retries in a repaired group; `suspect` (the
    /// peer whose socket failed; `u32::MAX` if unknown) is flagged
    /// for the liveness monitor's fast path.
    AbortGroup { id: GroupId, suspect: u32 },
    /// Non-blocking group-state query ([`GroupState`]).
    Probe { id: GroupId },
    /// A checkpoint-restored replacement re-registers rank `w`: the old
    /// incarnation is purged (death declared if it wasn't yet) and the
    /// rank becomes draftable again; `addr` is the replacement's new
    /// data-plane address for the registry.
    Rejoin { worker: u32, addr: String },
    /// Advertise `w`'s data-plane address (startup; peers re-resolve a
    /// rank's address via `Lookup` when its cached edge breaks).
    Register { worker: u32, addr: String },
    /// Fetch the registered data-plane address of `w`.
    Lookup { worker: u32 },
}

/// Lifecycle of a group as seen by `Probe`/`WaitArmed`/`WaitDone`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GroupState {
    /// Live, waiting in the pending queue for its locks.
    Pending,
    /// Live and holding its locks: the collective may run.
    Armed,
    /// Completed normally (or never existed).
    Done,
    /// Torn down by failure repair: do NOT run the collective.
    Aborted,
}

impl GroupState {
    fn code(self) -> u8 {
        match self {
            GroupState::Pending => 0,
            GroupState::Armed => 1,
            GroupState::Done => 2,
            GroupState::Aborted => 3,
        }
    }

    fn from_code(c: u8) -> Result<Self> {
        Ok(match c {
            0 => GroupState::Pending,
            1 => GroupState::Armed,
            2 => GroupState::Done,
            3 => GroupState::Aborted,
            c => bail!("bad group state code {c}"),
        })
    }
}

/// What a blocking wait resolved to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WaitOutcome {
    /// The awaited condition holds (armed / completed).
    Ready,
    /// The group was aborted by failure repair: skip the collective
    /// (`WaitArmed`) or proceed — the data already landed (`WaitDone`,
    /// where abort can only mean the leader died after the collective).
    Aborted,
}

/// GG counters plus the measured per-worker speed table, returned by
/// `Request::Stats` (what `ripples launch` renders and the e2e suite
/// asserts filter behaviour from).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct StatsReport {
    pub requests: u64,
    pub conflicts: u64,
    pub groups_created: u64,
    pub buffer_hits: u64,
    /// Per-worker measured EWMA step seconds (0.0 = nothing reported).
    pub speeds: Vec<f64>,
    /// Per-worker drafts into groups created by *other* initiators.
    pub drafts: Vec<u64>,
    /// `requests` value at each worker's most recent such draft (0 =
    /// never): how long ago the filter last drafted the worker.
    pub last_drafted: Vec<u64>,
    /// Ranks declared dead by failure detection.
    pub deaths: u64,
    /// Groups torn down by failure repair.
    pub groups_aborted: u64,
    /// Dead ranks re-admitted via `Rejoin`.
    pub rejoins: u64,
}

impl StatsReport {
    /// Measured slowdown factor of `w` vs the fastest measured worker
    /// (None when either side has no measurement). Delegates to
    /// [`crate::metrics::relative_speeds`] — one definition of
    /// "relative speed" for the e2e assertions and the fig harnesses.
    pub fn relative_speed(&self, w: usize) -> Option<f64> {
        let rel = *crate::metrics::relative_speeds(&self.speeds).get(w)?;
        (rel > 0.0).then_some(rel)
    }
}

/// One group on the wire: `(id, members, plan)`. `plan` is the
/// node-major [`SyncPlan`] (`u32` ranks, leader first per node); an
/// empty plan means "flat ring in member order" — exactly what
/// plan-blind peers ran before topology existed, so the degenerate
/// encoding is also the backward-compatible one.
pub type WireGroup = (GroupId, Vec<u32>, Vec<Vec<u32>>);

/// Server -> client messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Assigned {
        id: GroupId,
        members: Vec<u32>,
        /// Placement-aware sync plan for the assigned group (node-major,
        /// leader first; empty = flat in member order).
        plan: Vec<Vec<u32>>,
        armed: Vec<WireGroup>,
    },
    Armed { groups: Vec<WireGroup> },
    Stats(StatsReport),
    Ok,
    Err { msg: String },
    /// `Probe`/`WaitArmed`/`WaitDone` verdict.
    State(GroupState),
    /// `Lookup` result: the registered data-plane address, if any.
    Addr { addr: Option<String> },
}

fn encode_str(w: &mut Writer, s: &str) {
    w.u32(s.len() as u32);
    w.bytes(s.as_bytes());
}

fn decode_str(r: &mut Reader) -> Result<String> {
    let len = r.u32()? as usize;
    if len > MAX_ADDR_LEN {
        bail!("unreasonable string length {len}");
    }
    String::from_utf8(r.bytes(len)?.to_vec()).context("non-utf8 string")
}

impl Request {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        match self {
            Request::Sync { worker, speed } => {
                w.u8(0);
                w.u32(*worker);
                w.u64(speed.ewma_step_secs.to_bits());
            }
            Request::Complete { id } => {
                w.u8(1);
                w.u64(*id);
            }
            Request::Stats => w.u8(2),
            Request::Shutdown => w.u8(3),
            Request::WaitArmed { id } => {
                w.u8(4);
                w.u64(*id);
            }
            Request::WaitDone { id } => {
                w.u8(5);
                w.u64(*id);
            }
            Request::Retire { worker } => {
                w.u8(6);
                w.u32(*worker);
            }
            Request::Heartbeat { worker } => {
                w.u8(7);
                w.u32(*worker);
            }
            Request::AbortGroup { id, suspect } => {
                w.u8(8);
                w.u64(*id);
                w.u32(*suspect);
            }
            Request::Probe { id } => {
                w.u8(9);
                w.u64(*id);
            }
            Request::Rejoin { worker, addr } => {
                w.u8(10);
                w.u32(*worker);
                encode_str(&mut w, addr);
            }
            Request::Register { worker, addr } => {
                w.u8(11);
                w.u32(*worker);
                encode_str(&mut w, addr);
            }
            Request::Lookup { worker } => {
                w.u8(12);
                w.u32(*worker);
            }
        }
        w.finish()
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = Reader::new(buf);
        let tag = r.u8()?;
        let req = match tag {
            0 => Request::Sync {
                worker: r.u32()?,
                speed: SpeedReport::new(f64::from_bits(r.u64()?)),
            },
            1 => Request::Complete { id: r.u64()? },
            2 => Request::Stats,
            3 => Request::Shutdown,
            4 => Request::WaitArmed { id: r.u64()? },
            5 => Request::WaitDone { id: r.u64()? },
            6 => Request::Retire { worker: r.u32()? },
            7 => Request::Heartbeat { worker: r.u32()? },
            8 => Request::AbortGroup { id: r.u64()?, suspect: r.u32()? },
            9 => Request::Probe { id: r.u64()? },
            10 => Request::Rejoin { worker: r.u32()?, addr: decode_str(&mut r)? },
            11 => Request::Register { worker: r.u32()?, addr: decode_str(&mut r)? },
            12 => Request::Lookup { worker: r.u32()? },
            t => bail!("bad request tag {t}"),
        };
        r.done()?;
        Ok(req)
    }
}

fn encode_plan(w: &mut Writer, plan: &[Vec<u32>]) {
    w.u32(plan.len() as u32);
    for node in plan {
        w.u32(node.len() as u32);
        for &m in node {
            w.u32(m);
        }
    }
}

fn decode_plan(r: &mut Reader) -> Result<Vec<Vec<u32>>> {
    let n = r.u32()? as usize;
    if n > 1 << 16 {
        bail!("unreasonable plan node count {n}");
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let k = r.u32()? as usize;
        if k > 1 << 16 {
            bail!("unreasonable plan member count {k}");
        }
        let mut node = Vec::with_capacity(k);
        for _ in 0..k {
            node.push(r.u32()?);
        }
        out.push(node);
    }
    Ok(out)
}

fn encode_groups(w: &mut Writer, groups: &[WireGroup]) {
    w.u32(groups.len() as u32);
    for (id, members, plan) in groups {
        w.u64(*id);
        w.u32(members.len() as u32);
        for &m in members {
            w.u32(m);
        }
        encode_plan(w, plan);
    }
}

fn decode_groups(r: &mut Reader) -> Result<Vec<WireGroup>> {
    let n = r.u32()? as usize;
    if n > 1 << 20 {
        bail!("unreasonable group count {n}");
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let id = r.u64()?;
        let k = r.u32()? as usize;
        if k > 1 << 16 {
            bail!("unreasonable member count {k}");
        }
        let mut members = Vec::with_capacity(k);
        for _ in 0..k {
            members.push(r.u32()?);
        }
        out.push((id, members, decode_plan(r)?));
    }
    Ok(out)
}

impl Response {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        match self {
            Response::Assigned { id, members, plan, armed } => {
                w.u8(0);
                w.u64(*id);
                w.u32(members.len() as u32);
                for &m in members {
                    w.u32(m);
                }
                encode_plan(&mut w, plan);
                encode_groups(&mut w, armed);
            }
            Response::Armed { groups } => {
                w.u8(1);
                encode_groups(&mut w, groups);
            }
            Response::Stats(s) => {
                w.u8(2);
                w.u64(s.requests);
                w.u64(s.conflicts);
                w.u64(s.groups_created);
                w.u64(s.buffer_hits);
                w.u64(s.deaths);
                w.u64(s.groups_aborted);
                w.u64(s.rejoins);
                debug_assert!(
                    s.speeds.len() == s.drafts.len()
                        && s.drafts.len() == s.last_drafted.len()
                );
                w.u32(s.speeds.len() as u32);
                for i in 0..s.speeds.len() {
                    w.u64(s.speeds[i].to_bits());
                    w.u64(s.drafts[i]);
                    w.u64(s.last_drafted[i]);
                }
            }
            Response::Ok => w.u8(3),
            Response::Err { msg } => {
                w.u8(4);
                w.bytes(msg.as_bytes());
            }
            Response::State(s) => {
                w.u8(5);
                w.u8(s.code());
            }
            Response::Addr { addr } => {
                w.u8(6);
                match addr {
                    Some(a) => {
                        w.u8(1);
                        encode_str(&mut w, a);
                    }
                    None => w.u8(0),
                }
            }
        }
        w.finish()
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = Reader::new(buf);
        let tag = r.u8()?;
        let resp = match tag {
            0 => {
                let id = r.u64()?;
                let k = r.u32()? as usize;
                let mut members = Vec::with_capacity(k.min(1 << 16));
                for _ in 0..k {
                    members.push(r.u32()?);
                }
                Response::Assigned {
                    id,
                    members,
                    plan: decode_plan(&mut r)?,
                    armed: decode_groups(&mut r)?,
                }
            }
            1 => Response::Armed { groups: decode_groups(&mut r)? },
            2 => {
                let requests = r.u64()?;
                let conflicts = r.u64()?;
                let groups_created = r.u64()?;
                let buffer_hits = r.u64()?;
                let deaths = r.u64()?;
                let groups_aborted = r.u64()?;
                let rejoins = r.u64()?;
                let n = r.u32()? as usize;
                if n > 1 << 16 {
                    bail!("unreasonable worker count {n}");
                }
                let mut speeds = Vec::with_capacity(n);
                let mut drafts = Vec::with_capacity(n);
                let mut last_drafted = Vec::with_capacity(n);
                for _ in 0..n {
                    speeds.push(f64::from_bits(r.u64()?));
                    drafts.push(r.u64()?);
                    last_drafted.push(r.u64()?);
                }
                Response::Stats(StatsReport {
                    requests,
                    conflicts,
                    groups_created,
                    buffer_hits,
                    speeds,
                    drafts,
                    last_drafted,
                    deaths,
                    groups_aborted,
                    rejoins,
                })
            }
            3 => Response::Ok,
            4 => Response::Err { msg: String::from_utf8_lossy(&r.rest()).into_owned() },
            5 => Response::State(GroupState::from_code(r.u8()?)?),
            6 => Response::Addr {
                addr: if r.u8()? == 1 { Some(decode_str(&mut r)?) } else { None },
            },
            t => bail!("bad response tag {t}"),
        };
        if tag != 4 {
            r.done()?;
        }
        Ok(resp)
    }
}

fn write_frame(stream: &mut TcpStream, payload: &[u8]) -> Result<()> {
    let len = payload.len() as u32;
    stream.write_all(&len.to_le_bytes())?;
    stream.write_all(payload)?;
    Ok(())
}

fn read_frame(stream: &mut TcpStream) -> Result<Vec<u8>> {
    let mut lenbuf = [0u8; 4];
    stream.read_exact(&mut lenbuf)?;
    let len = u32::from_le_bytes(lenbuf) as usize;
    if len > 1 << 24 {
        bail!("frame too large: {len}");
    }
    let mut buf = vec![0u8; len];
    stream.read_exact(&mut buf)?;
    Ok(buf)
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

/// Liveness policy for the server's failure detector. Heartbeats arrive
/// on any rank-bearing RPC plus the dedicated `Heartbeat` beacon; the
/// monitor thread declares a rank dead when its heartbeat goes stale.
#[derive(Debug, Clone)]
pub struct LivenessConfig {
    /// Hard deadline: a non-retired rank whose last heartbeat is older
    /// than this is declared dead.
    pub timeout: Duration,
    /// Fast path: once a ring survivor *accused* the rank (`AbortGroup`
    /// suspect), this much staleness suffices — a healthy-but-slow rank
    /// keeps heartbeating and survives a false accusation.
    pub accused_grace: Duration,
    /// Monitor poll period.
    pub poll: Duration,
}

impl LivenessConfig {
    /// `timeout` with an accusation fast path sized to a few heartbeat
    /// periods and a brisk poll.
    pub fn with_timeout(timeout: Duration) -> Self {
        Self {
            timeout,
            accused_grace: (timeout / 8).max(Duration::from_millis(300)),
            poll: Duration::from_millis(50),
        }
    }
}

impl Default for LivenessConfig {
    fn default() -> Self {
        Self::with_timeout(Duration::from_secs(5))
    }
}

/// Per-rank liveness bookkeeping: `(last_seen, accused)`. `last_seen`
/// is `None` until the rank's first contact — a rank that is slow to
/// *start* (long spawn, long handshake) must not be declared dead by a
/// clock that began at server spawn. A never-seen rank only dies via
/// the accusation path (a peer observed its socket fail).
struct LivenessTracker {
    cfg: LivenessConfig,
    inner: Mutex<(Vec<Option<Instant>>, Vec<bool>)>,
}

/// Which Group Generator implementation backs the server.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum GgMode {
    /// Sharded hot state (default, [`ShardedGg`]): buffer-hit Syncs,
    /// Probes, Waits, Heartbeats, speed reports, and Stats never touch
    /// the scheduler mutex; only division/creation/completion serialize.
    #[default]
    Sharded,
    /// The original whole-state-machine-behind-one-mutex path — kept as
    /// the differential-testing oracle (prop/stress suites drive both
    /// and demand identical behavior) and as `--gg-backend locked`.
    SingleLock,
}

impl GgMode {
    /// Parse a `--gg-backend` CLI value.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "sharded" => Ok(GgMode::Sharded),
            "locked" | "single-lock" => Ok(GgMode::SingleLock),
            other => bail!("unknown GG backend '{other}' (sharded|locked)"),
        }
    }
}

/// The state machine behind either backend, so the reactor, the liveness
/// monitor, and the request handlers are backend-blind. Every method
/// takes `&self`; the single-lock variant serializes internally (that is
/// the point of keeping it — the oracle the sharded path must match).
pub(crate) enum GgBackend {
    SingleLock {
        state: Mutex<(GroupGenerator, Pcg32)>,
        /// Phase-change counter for the reactor's parked waits (the
        /// sharded GG maintains its own).
        epoch: AtomicU64,
    },
    Sharded(ShardedGg),
}

impl GgBackend {
    fn new(mode: GgMode, cfg: GgConfig, seed: u64) -> Self {
        match mode {
            GgMode::SingleLock => GgBackend::SingleLock {
                state: Mutex::new((GroupGenerator::new(cfg), Pcg32::new(seed))),
                epoch: AtomicU64::new(0),
            },
            GgMode::Sharded => GgBackend::Sharded(ShardedGg::new(cfg, seed)),
        }
    }

    fn n_workers(&self) -> usize {
        match self {
            GgBackend::SingleLock { state, .. } => {
                state.lock().unwrap().0.config().n_workers
            }
            GgBackend::Sharded(gg) => gg.config().n_workers,
        }
    }

    /// Monotone counter that moves whenever a group's phase may have
    /// changed; the reactor re-evaluates parked waits when it does.
    pub(crate) fn epoch(&self) -> u64 {
        match self {
            GgBackend::SingleLock { epoch, .. } => epoch.load(Ordering::Acquire),
            GgBackend::Sharded(gg) => gg.epoch(),
        }
    }

    fn bump(&self) {
        if let GgBackend::SingleLock { epoch, .. } = self {
            epoch.fetch_add(1, Ordering::Release);
        }
    }

    fn is_dead(&self, w: usize) -> bool {
        match self {
            GgBackend::SingleLock { state, .. } => state.lock().unwrap().0.is_dead(w),
            GgBackend::Sharded(gg) => gg.is_dead(w),
        }
    }

    fn is_retired(&self, w: usize) -> bool {
        match self {
            GgBackend::SingleLock { state, .. } => state.lock().unwrap().0.is_retired(w),
            GgBackend::Sharded(gg) => gg.is_retired(w),
        }
    }

    /// The `Sync` handler: fold the piggybacked telemetry in *before*
    /// the request so this very division sees it — unless the rank was
    /// declared dead (a zombie's report must not repopulate the purged
    /// speed entry). Wire id 0 with no members encodes "skip this sync"
    /// (GroupIds start at 1). The reply carries the placement-aware
    /// [`SyncPlan`] for the assigned group (and every newly armed one),
    /// assembled outside the state machines from `(members, topology,
    /// speed snapshot)` and frozen per group in the [`PlanCache`] — so
    /// both backends serve identical plans, every member of a group sees
    /// the same schedule, and the differential `prop_gg` equivalence is
    /// untouched.
    fn sync(&self, w: usize, speed: &SpeedReport, plans: &PlanCache) -> Response {
        if w >= self.n_workers() {
            return Response::Err { msg: format!("worker {w} out of range") };
        }
        let resp = match self {
            GgBackend::SingleLock { state, .. } => {
                let mut guard = state.lock().unwrap();
                let (gg, rng) = &mut *guard;
                if !gg.is_dead(w) {
                    gg.report_speed(w, speed.ewma_step_secs);
                }
                let (id, armed) = gg.request(w, rng);
                let id = id.unwrap_or(0);
                let speeds = gg.speed_table().snapshot();
                let topo = gg.config().topology.as_ref();
                let (members, plan) = match gg.group(id) {
                    Some(g) => (
                        g.members.iter().map(|&m| m as u32).collect(),
                        cached_plan(plans, id, &g.members, topo, &speeds),
                    ),
                    None => (Vec::new(), Vec::new()),
                };
                Response::Assigned {
                    id,
                    members,
                    plan,
                    armed: planned_groups(plans, armed, topo, &speeds),
                }
            }
            GgBackend::Sharded(gg) => {
                if !gg.is_dead(w) {
                    gg.report_speed(w, speed.ewma_step_secs);
                }
                let (id, armed) = gg.request(w);
                let id = id.unwrap_or(0);
                let speeds = gg.speed_snapshot();
                let topo = gg.config().topology.as_ref();
                let (members, plan) = match gg.group(id) {
                    Some(g) => (
                        g.members.iter().map(|&m| m as u32).collect(),
                        cached_plan(plans, id, &g.members, topo, &speeds),
                    ),
                    None => (Vec::new(), Vec::new()),
                };
                Response::Assigned {
                    id,
                    members,
                    plan,
                    armed: planned_groups(plans, armed, topo, &speeds),
                }
            }
        };
        self.bump();
        resp
    }

    /// The `Complete` handler. Unknown = already completed or aborted: a
    /// duplicate/retried leader Complete is idempotent, not a crash.
    /// Completing a *pending* group would corrupt the lock vector — a
    /// client protocol violation, rejected. The sharded path does the
    /// armed-check and the completion atomically under one scheduler
    /// hold ([`ShardedGg::try_complete`]); the single-lock path holds
    /// its one mutex across both, same effect.
    fn complete(&self, id: GroupId, plans: &PlanCache) -> Response {
        let resp = match self {
            GgBackend::SingleLock { state, .. } => {
                let mut guard = state.lock().unwrap();
                let (gg, _) = &mut *guard;
                if gg.group(id).is_none() {
                    Response::Armed { groups: Vec::new() }
                } else if !gg.is_armed(id) {
                    Response::Err { msg: format!("group {id} is not armed") }
                } else {
                    let armed = gg.complete(id);
                    let speeds = gg.speed_table().snapshot();
                    let topo = gg.config().topology.as_ref();
                    plans.lock().unwrap().remove(&id);
                    Response::Armed {
                        groups: planned_groups(plans, armed, topo, &speeds),
                    }
                }
            }
            GgBackend::Sharded(gg) => match gg.try_complete(id) {
                crate::gg::CompleteOutcome::Unknown => {
                    Response::Armed { groups: Vec::new() }
                }
                crate::gg::CompleteOutcome::NotArmed => {
                    Response::Err { msg: format!("group {id} is not armed") }
                }
                crate::gg::CompleteOutcome::Done(groups) => {
                    let speeds = gg.speed_snapshot();
                    let topo = gg.config().topology.as_ref();
                    plans.lock().unwrap().remove(&id);
                    Response::Armed {
                        groups: planned_groups(plans, groups, topo, &speeds),
                    }
                }
            },
        };
        self.bump();
        resp
    }

    fn stats_report(&self) -> StatsReport {
        match self {
            GgBackend::SingleLock { state, .. } => {
                let guard = state.lock().unwrap();
                let gg = &guard.0;
                StatsReport {
                    requests: gg.stats.requests,
                    conflicts: gg.stats.conflicts,
                    groups_created: gg.stats.groups_created,
                    buffer_hits: gg.stats.buffer_hits,
                    speeds: gg.speed_table().snapshot(),
                    drafts: gg.drafts().to_vec(),
                    last_drafted: gg.last_drafted().to_vec(),
                    deaths: gg.stats.deaths,
                    groups_aborted: gg.stats.groups_aborted,
                    rejoins: gg.stats.rejoins,
                }
            }
            GgBackend::Sharded(gg) => {
                let stats = gg.stats();
                StatsReport {
                    requests: stats.requests,
                    conflicts: stats.conflicts,
                    groups_created: stats.groups_created,
                    buffer_hits: stats.buffer_hits,
                    speeds: gg.speed_snapshot(),
                    drafts: gg.drafts(),
                    last_drafted: gg.last_drafted(),
                    deaths: stats.deaths,
                    groups_aborted: stats.groups_aborted,
                    rejoins: stats.rejoins,
                }
            }
        }
    }

    fn retire(&self, w: usize) {
        match self {
            GgBackend::SingleLock { state, .. } => state.lock().unwrap().0.retire(w),
            GgBackend::Sharded(gg) => gg.retire(w),
        }
        self.bump();
    }

    fn abort_group(&self, id: GroupId, plans: &PlanCache) {
        match self {
            GgBackend::SingleLock { state, .. } => {
                let _ = state.lock().unwrap().0.abort_group(id);
            }
            GgBackend::Sharded(gg) => {
                let _ = gg.abort_group(id);
            }
        }
        plans.lock().unwrap().remove(&id);
        self.bump();
    }

    fn probe(&self, id: GroupId) -> GroupState {
        match self {
            GgBackend::SingleLock { state, .. } => {
                group_state(&state.lock().unwrap().0, id)
            }
            GgBackend::Sharded(gg) => match gg.phase(id) {
                GroupPhase::Pending => GroupState::Pending,
                GroupPhase::Armed => GroupState::Armed,
                GroupPhase::Done => GroupState::Done,
                GroupPhase::Aborted => GroupState::Aborted,
            },
        }
    }

    fn rejoin(&self, w: usize, plans: &PlanCache) {
        let purge = match self {
            GgBackend::SingleLock { state, .. } => state.lock().unwrap().0.rejoin(w),
            GgBackend::Sharded(gg) => gg.rejoin(w),
        };
        let mut cache = plans.lock().unwrap();
        for g in &purge.aborted {
            cache.remove(&g.id);
        }
        drop(cache);
        self.bump();
    }

    fn declare_dead(&self, w: usize, plans: &PlanCache) {
        let purge = match self {
            GgBackend::SingleLock { state, .. } => state.lock().unwrap().0.declare_dead(w),
            GgBackend::Sharded(gg) => gg.declare_dead(w),
        };
        let mut cache = plans.lock().unwrap();
        for g in &purge.aborted {
            cache.remove(&g.id);
        }
        drop(cache);
        self.bump();
    }
}

/// Everything the reactor, its workers, and the monitor share.
pub(crate) struct ServerShared {
    pub(crate) backend: GgBackend,
    /// Frozen per-group sync plans (see [`PlanCache`]).
    plans: PlanCache,
    /// Rank-indexed data-plane address registry (`Register`/`Lookup`).
    addrs: Mutex<Vec<Option<String>>>,
    liveness: Option<LivenessTracker>,
    /// Total accepted connections (the client-reuse regression tests
    /// assert a persistent client shows up here exactly once).
    pub(crate) connections_accepted: AtomicU64,
}

impl ServerShared {
    /// Record proof of life for `w` (out-of-range ranks ignored — the
    /// request handler rejects them separately).
    fn touch(&self, w: usize) {
        if let Some(l) = &self.liveness {
            let mut g = l.inner.lock().unwrap();
            if let Some(slot) = g.0.get_mut(w) {
                *slot = Some(Instant::now());
            }
        }
    }

    /// Flag `w` for the monitor's accusation fast path.
    fn accuse(&self, w: usize) {
        if let Some(l) = &self.liveness {
            let mut g = l.inner.lock().unwrap();
            if let Some(slot) = g.1.get_mut(w) {
                *slot = true;
            }
        }
    }

    /// A rejoined rank starts with a clean slate.
    fn clear_suspicion(&self, w: usize) {
        if let Some(l) = &self.liveness {
            let mut g = l.inner.lock().unwrap();
            if let Some(slot) = g.0.get_mut(w) {
                *slot = Some(Instant::now());
            }
            if let Some(slot) = g.1.get_mut(w) {
                *slot = false;
            }
        }
    }
}

/// A running GG server: one event-loop reactor thread multiplexing every
/// connection ([`reactor`]), a small worker pool executing requests, and
/// an optional liveness monitor ([`LivenessConfig`]).
pub struct GgServer {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    shared: Arc<ServerShared>,
    handle: Option<thread::JoinHandle<()>>,
    monitor: Option<thread::JoinHandle<()>>,
}

impl GgServer {
    /// Bind and serve on `addr` (use port 0 for an ephemeral port) with
    /// failure detection disabled — crashes hold their locks forever, as
    /// in the pre-fault-tolerance control plane.
    pub fn spawn(addr: &str, cfg: GgConfig, seed: u64) -> Result<Self> {
        Self::spawn_with_liveness(addr, cfg, seed, None)
    }

    /// [`GgServer::spawn`] with an optional liveness monitor: stale
    /// heartbeats (see [`LivenessConfig`]) trigger a death declaration,
    /// aborting the dead rank's groups.
    pub fn spawn_with_liveness(
        addr: &str,
        cfg: GgConfig,
        seed: u64,
        liveness: Option<LivenessConfig>,
    ) -> Result<Self> {
        Self::spawn_with_backend(addr, cfg, seed, liveness, GgMode::default())
    }

    /// Full-control spawn: pick the Group Generator backend explicitly
    /// (the prop/stress suites and `--gg-backend locked` use this; the
    /// default everywhere else is [`GgMode::Sharded`]).
    pub fn spawn_with_backend(
        addr: &str,
        cfg: GgConfig,
        seed: u64,
        liveness: Option<LivenessConfig>,
        mode: GgMode,
    ) -> Result<Self> {
        let n = cfg.n_workers;
        let stop = Arc::new(AtomicBool::new(false));
        let shared = Arc::new(ServerShared {
            backend: GgBackend::new(mode, cfg, seed),
            plans: Mutex::new(HashMap::new()),
            addrs: Mutex::new(vec![None; n]),
            liveness: liveness.map(|cfg| LivenessTracker {
                cfg,
                inner: Mutex::new((vec![None; n], vec![false; n])),
            }),
            connections_accepted: AtomicU64::new(0),
        });
        let monitor = shared.liveness.is_some().then(|| {
            let shared = Arc::clone(&shared);
            let stop = Arc::clone(&stop);
            thread::spawn(move || monitor_liveness(&shared, &stop))
        });
        let (local, handle) =
            reactor::spawn(addr, Arc::clone(&shared), Arc::clone(&stop))?;
        Ok(Self { addr: local, stop, shared, handle: Some(handle), monitor })
    }

    /// Total client connections accepted so far (regression guard: a
    /// persistent [`GgClient`] must appear here exactly once, however
    /// many RPCs it issues).
    pub fn connections_accepted(&self) -> u64 {
        self.shared.connections_accepted.load(Ordering::Acquire)
    }

    fn join_threads(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        if let Some(h) = self.monitor.take() {
            let _ = h.join();
        }
    }

    pub fn shutdown(mut self) {
        self.join_threads();
    }
}

impl Drop for GgServer {
    fn drop(&mut self) {
        self.join_threads();
    }
}

/// Declare ranks dead when their heartbeat goes stale: past the hard
/// `timeout` always, past `accused_grace` once a ring survivor accused
/// them; an accused rank that *never* made contact dies immediately
/// (no proof of life to weigh against the observed socket failure).
/// Retired (gracefully departed), already-dead, and unaccused
/// never-seen ranks are exempt — their silence is expected.
fn monitor_liveness(shared: &ServerShared, stop: &AtomicBool) {
    let tracker = shared.liveness.as_ref().expect("monitor without liveness");
    let n = shared.backend.n_workers();
    while !stop.load(Ordering::Relaxed) {
        thread::sleep(tracker.cfg.poll);
        let now = Instant::now();
        // Verdicts are computed under the liveness lock only; the
        // dead/retired reads and the death declarations go through the
        // backend (lock-free on the sharded path). A rank heartbeating
        // in the window between verdict and declaration was always
        // possible — `touch` never took the state lock — so this holds
        // no new races, and no lock-order edge between liveness and the
        // GG state remains at all.
        let live = tracker.inner.lock().unwrap();
        let mut verdicts = Vec::new();
        for w in 0..n {
            if shared.backend.is_dead(w) || shared.backend.is_retired(w) {
                continue;
            }
            let accused = live.1[w];
            let dead = match live.0[w] {
                Some(seen) => {
                    let stale = now.duration_since(seen);
                    stale > tracker.cfg.timeout
                        || (accused && stale > tracker.cfg.accused_grace)
                }
                None => accused,
            };
            if dead {
                verdicts.push(w);
            }
        }
        drop(live);
        for w in verdicts {
            // clients discover the purge by polling Wait/Probe
            shared.backend.declare_dead(w, &shared.plans);
        }
    }
}

/// Per-group memo of the assembled wire plan. Group members learn their
/// plan from their *own* Sync replies, which happen at different times —
/// against an evolving speed table. Executing a ring requires every
/// member to hold the identical schedule, so the first reply that needs
/// a group's plan freezes it here and every later reply serves the same
/// bytes. Entries are evicted when the group completes or aborts.
pub(crate) type PlanCache = Mutex<HashMap<GroupId, Vec<Vec<u32>>>>;

/// Assemble the wire form of a group's [`SyncPlan`]: node-major, leader
/// first within each node. A flat single-node plan in drafted member
/// order encodes as the empty vec — the degenerate case costs zero bytes
/// and old-style "members only" consumers keep working.
fn wire_plan(members: &[usize], topo: Option<&Topology>, speeds: &[f64]) -> Vec<Vec<u32>> {
    let plan = SyncPlan::make(members, topo, speeds);
    if plan.is_flat() && plan.ring_order() == members {
        return Vec::new();
    }
    plan.nodes
        .into_iter()
        .map(|node| node.into_iter().map(|m| m as u32).collect())
        .collect()
}

/// The memoized form of [`wire_plan`]: compute on first use, then serve
/// the frozen copy for the group's lifetime.
fn cached_plan(
    plans: &PlanCache,
    id: GroupId,
    members: &[usize],
    topo: Option<&Topology>,
    speeds: &[f64],
) -> Vec<Vec<u32>> {
    plans
        .lock()
        .unwrap()
        .entry(id)
        .or_insert_with(|| wire_plan(members, topo, speeds))
        .clone()
}

fn planned_groups(
    plans: &PlanCache,
    groups: Vec<Group>,
    topo: Option<&Topology>,
    speeds: &[f64],
) -> Vec<WireGroup> {
    groups
        .into_iter()
        .map(|g| {
            let plan = cached_plan(plans, g.id, &g.members, topo, speeds);
            (g.id, g.members.into_iter().map(|m| m as u32).collect(), plan)
        })
        .collect()
}

/// Lifecycle of `id` as the Wait/Probe calls report it.
fn group_state(gg: &GroupGenerator, id: GroupId) -> GroupState {
    if gg.group(id).is_none() {
        if gg.was_aborted(id) {
            GroupState::Aborted
        } else {
            GroupState::Done
        }
    } else if gg.is_armed(id) {
        GroupState::Armed
    } else {
        GroupState::Pending
    }
}

/// What a request handler decided: reply now, or park the connection
/// until the awaited group changes phase (the reactor re-evaluates
/// parked waits whenever [`GgBackend::epoch`] moves — no poll loop).
pub(crate) enum Handled {
    Reply(Response),
    Park { id: GroupId, want_armed: bool },
}

/// Evaluate a parked `WaitArmed`/`WaitDone`: `Some(response)` once the
/// wait resolves, `None` while it must stay parked. On the sharded
/// backend this reads one group shard — never the scheduler lock.
pub(crate) fn resolve_wait(
    shared: &ServerShared,
    id: GroupId,
    want_armed: bool,
) -> Option<Response> {
    match shared.backend.probe(id) {
        s @ (GroupState::Done | GroupState::Aborted) => Some(Response::State(s)),
        GroupState::Armed if want_armed => Some(Response::State(GroupState::Armed)),
        GroupState::Armed | GroupState::Pending => None,
    }
}

/// Execute one decoded request against the shared state. Called from the
/// reactor's worker pool; every backend mutation happens inside
/// [`GgBackend`], so this function never holds a lock across calls.
pub(crate) fn handle_request(
    shared: &ServerShared,
    req: &Request,
    stop: &AtomicBool,
) -> Handled {
    // Every rank-bearing request doubles as proof of life.
    match req {
        Request::Sync { worker, .. }
        | Request::Heartbeat { worker }
        | Request::Retire { worker }
        | Request::Register { worker, .. } => shared.touch(*worker as usize),
        _ => {}
    }
    let n = shared.backend.n_workers();
    let resp = match req {
        Request::Heartbeat { .. } => Response::Ok,
        Request::Register { worker, addr } => {
            let w = *worker as usize;
            let mut addrs = shared.addrs.lock().unwrap();
            if w < addrs.len() {
                addrs[w] = Some(addr.clone());
                Response::Ok
            } else {
                Response::Err { msg: format!("worker {w} out of range") }
            }
        }
        Request::Lookup { worker } => Response::Addr {
            addr: shared.addrs.lock().unwrap().get(*worker as usize).cloned().flatten(),
        },
        Request::WaitArmed { id } | Request::WaitDone { id } => {
            let want_armed = matches!(req, Request::WaitArmed { .. });
            return match resolve_wait(shared, *id, want_armed) {
                Some(resp) => Handled::Reply(resp),
                None => Handled::Park { id: *id, want_armed },
            };
        }
        Request::Sync { worker, speed } => {
            shared.backend.sync(*worker as usize, speed, &shared.plans)
        }
        Request::Complete { id } => shared.backend.complete(*id, &shared.plans),
        Request::Stats => Response::Stats(shared.backend.stats_report()),
        Request::Shutdown => {
            stop.store(true, Ordering::Relaxed);
            Response::Ok
        }
        Request::Retire { worker } => {
            let w = *worker as usize;
            if w >= n {
                Response::Err { msg: format!("worker {w} out of range") }
            } else {
                shared.backend.retire(w);
                Response::Ok
            }
        }
        Request::AbortGroup { id, suspect } => {
            // tear the broken group down no matter who (if anyone) gets
            // blamed — the collective cannot finish
            shared.backend.abort_group(*id, &shared.plans);
            let s = *suspect as usize;
            if *suspect != NO_SUSPECT && s < n {
                shared.accuse(s);
            }
            Response::Ok
        }
        Request::Probe { id } => Response::State(shared.backend.probe(*id)),
        Request::Rejoin { worker, addr } => {
            let w = *worker as usize;
            if w >= n {
                Response::Err { msg: format!("worker {w} out of range") }
            } else {
                shared.backend.rejoin(w, &shared.plans);
                shared.addrs.lock().unwrap()[w] = Some(addr.clone());
                shared.clear_suspicion(w);
                Response::Ok
            }
        }
    };
    Handled::Reply(resp)
}

// ---------------------------------------------------------------------------
// Replay seam
// ---------------------------------------------------------------------------

/// In-process, transport-free handle onto the server's pure transition
/// seam: a [`ServerShared`] with no sockets, reactor threads, or liveness
/// monitor. The model checker's conformance replayer
/// ([`crate::check::conform`]) drives decoded [`Request`]s straight
/// through `handle_request` — the same dispatch the reactor's worker pool
/// uses — so a replayed trace exercises the exact request-handling +
/// backend path a live cluster does, minus the wire.
pub struct ReplayServer {
    shared: ServerShared,
    stop: AtomicBool,
}

impl ReplayServer {
    pub fn new(mode: GgMode, cfg: GgConfig, seed: u64) -> Self {
        let n = cfg.n_workers;
        Self {
            shared: ServerShared {
                backend: GgBackend::new(mode, cfg, seed),
                plans: Mutex::new(HashMap::new()),
                addrs: Mutex::new(vec![None; n]),
                liveness: None,
                connections_accepted: AtomicU64::new(0),
            },
            stop: AtomicBool::new(false),
        }
    }

    /// Dispatch one request exactly as the reactor would. `None` means
    /// the request parked (`WaitArmed`/`WaitDone` on a group that has
    /// not resolved) — poll it again via [`ReplayServer::resolve`].
    pub fn apply(&self, req: &Request) -> Option<Response> {
        match handle_request(&self.shared, req, &self.stop) {
            Handled::Reply(resp) => Some(resp),
            Handled::Park { id, want_armed } => resolve_wait(&self.shared, id, want_armed),
        }
    }

    /// Re-evaluate a parked wait (the reactor does this on every epoch
    /// bump).
    pub fn resolve(&self, id: GroupId, want_armed: bool) -> Option<Response> {
        resolve_wait(&self.shared, id, want_armed)
    }

    /// The liveness monitor's accusation seam: declare `w` dead exactly
    /// as `monitor_liveness` does (backend death purge + plan-cache
    /// eviction). There is no `Request` for this — in production only
    /// the monitor's timeout/accusation logic may kill a rank.
    pub fn declare_dead(&self, w: usize) {
        self.shared.backend.declare_dead(w, &self.shared.plans);
    }
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

/// Blocking GG client over one TCP connection.
pub struct GgClient {
    stream: TcpStream,
}

impl GgClient {
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<Self> {
        let stream = TcpStream::connect(addr).context("connect to GG")?;
        stream.set_nodelay(true).ok();
        Ok(Self { stream })
    }

    /// Bound every call — including the blocking `wait_armed`/`wait_done`
    /// — so a dead peer or server surfaces as an error instead of hanging
    /// this worker (and everything reading its pipes) forever. A group
    /// can legitimately stay pending for a few straggler iterations, so
    /// callers should pass the same generous budget as the data plane.
    pub fn set_io_timeout(&mut self, timeout: std::time::Duration) -> Result<()> {
        self.stream.set_read_timeout(Some(timeout)).context("set read timeout")?;
        self.stream.set_write_timeout(Some(timeout)).context("set write timeout")?;
        Ok(())
    }

    fn call(&mut self, req: &Request) -> Result<Response> {
        write_frame(&mut self.stream, &req.encode())?;
        let frame = read_frame(&mut self.stream)?;
        Response::decode(&frame)
    }

    /// Worker sync request; returns `(assigned, newly_armed)`. `assigned`
    /// is None (wire id 0) when the GG says "skip this sync step";
    /// otherwise it carries the server-assembled [`SyncPlan`] for the
    /// group (an empty wire plan decodes to the flat plan in drafted
    /// member order). Armed notifications drop their plans — every
    /// executor learns its own plan from its own `Sync` reply.
    /// `ewma_step_secs` piggybacks the worker's measured step-duration
    /// EWMA (0.0 = no measurement yet).
    #[allow(clippy::type_complexity)]
    pub fn sync(
        &mut self,
        worker: usize,
        ewma_step_secs: f64,
    ) -> Result<(Option<(GroupId, Vec<usize>, SyncPlan)>, Vec<(GroupId, Vec<usize>)>)> {
        match self.call(&Request::Sync {
            worker: worker as u32,
            speed: SpeedReport::new(ewma_step_secs),
        })? {
            Response::Assigned { id, members, plan, armed } => {
                let assigned = (id != 0).then(|| {
                    let members: Vec<usize> =
                        members.into_iter().map(|m| m as usize).collect();
                    let plan = if plan.is_empty() {
                        SyncPlan::flat(&members)
                    } else {
                        SyncPlan {
                            nodes: plan
                                .into_iter()
                                .map(|n| n.into_iter().map(|m| m as usize).collect())
                                .collect(),
                        }
                    };
                    (id, members, plan)
                });
                Ok((
                    assigned,
                    armed
                        .into_iter()
                        .map(|(id, ms, _plan)| {
                            (id, ms.into_iter().map(|m| m as usize).collect())
                        })
                        .collect(),
                ))
            }
            Response::Err { msg } => bail!("GG error: {msg}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn complete(&mut self, id: GroupId) -> Result<Vec<(GroupId, Vec<usize>)>> {
        match self.call(&Request::Complete { id })? {
            Response::Armed { groups } => Ok(groups
                .into_iter()
                .map(|(id, ms, _plan)| (id, ms.into_iter().map(|m| m as usize).collect()))
                .collect()),
            Response::Err { msg } => bail!("GG error: {msg}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn stats(&mut self) -> Result<StatsReport> {
        match self.call(&Request::Stats)? {
            Response::Stats(report) => Ok(report),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Block until `id` holds its locks (no-op if it already completed).
    /// [`WaitOutcome::Aborted`] means failure repair tore the group down:
    /// skip the collective and re-`sync` for a repaired group.
    pub fn wait_armed(&mut self, id: GroupId) -> Result<WaitOutcome> {
        match self.call(&Request::WaitArmed { id })? {
            Response::State(GroupState::Aborted) => Ok(WaitOutcome::Aborted),
            Response::State(_) | Response::Ok => Ok(WaitOutcome::Ready),
            Response::Err { msg } => bail!("GG error: {msg}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Block until `id` has been completed (by its group leader).
    /// [`WaitOutcome::Aborted`] here means the leader died *after* the
    /// collective — the data already landed, so callers may proceed.
    pub fn wait_done(&mut self, id: GroupId) -> Result<WaitOutcome> {
        match self.call(&Request::WaitDone { id })? {
            Response::State(GroupState::Aborted) => Ok(WaitOutcome::Aborted),
            Response::State(_) | Response::Ok => Ok(WaitOutcome::Ready),
            Response::Err { msg } => bail!("GG error: {msg}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Non-blocking group-state query.
    pub fn probe(&mut self, id: GroupId) -> Result<GroupState> {
        match self.call(&Request::Probe { id })? {
            Response::State(s) => Ok(s),
            Response::Err { msg } => bail!("GG error: {msg}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Liveness beacon (the worker's heartbeat thread).
    pub fn heartbeat(&mut self, worker: usize) -> Result<()> {
        match self.call(&Request::Heartbeat { worker: worker as u32 })? {
            Response::Ok => Ok(()),
            Response::Err { msg } => bail!("GG error: {msg}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Report a broken collective: abort group `id` and (optionally)
    /// accuse the peer whose socket was observed failing.
    pub fn abort_group(&mut self, id: GroupId, suspect: Option<usize>) -> Result<()> {
        let suspect = suspect.map_or(NO_SUSPECT, |s| s as u32);
        match self.call(&Request::AbortGroup { id, suspect })? {
            Response::Ok => Ok(()),
            Response::Err { msg } => bail!("GG error: {msg}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Advertise `worker`'s data-plane address.
    pub fn register(&mut self, worker: usize, addr: &str) -> Result<()> {
        match self.call(&Request::Register { worker: worker as u32, addr: addr.into() })? {
            Response::Ok => Ok(()),
            Response::Err { msg } => bail!("GG error: {msg}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Re-register a (possibly dead) rank with a fresh data-plane
    /// address: the checkpoint-restored replacement's first call.
    pub fn rejoin(&mut self, worker: usize, addr: &str) -> Result<()> {
        match self.call(&Request::Rejoin { worker: worker as u32, addr: addr.into() })? {
            Response::Ok => Ok(()),
            Response::Err { msg } => bail!("GG error: {msg}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Current registered data-plane address of `worker`, if any.
    pub fn lookup(&mut self, worker: usize) -> Result<Option<String>> {
        match self.call(&Request::Lookup { worker: worker as u32 })? {
            Response::Addr { addr } => Ok(addr),
            Response::Err { msg } => bail!("GG error: {msg}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Mark `worker` as departed; it is never drafted into new groups.
    pub fn retire(&mut self, worker: usize) -> Result<()> {
        match self.call(&Request::Retire { worker: worker as u32 })? {
            Response::Ok => Ok(()),
            Response::Err { msg } => bail!("GG error: {msg}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn shutdown(&mut self) -> Result<()> {
        match self.call(&Request::Shutdown)? {
            Response::Ok => Ok(()),
            other => bail!("unexpected response {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_codec_roundtrip() {
        for req in [
            Request::Sync { worker: 7, speed: SpeedReport::new(0.0123) },
            Request::Sync { worker: 0, speed: SpeedReport::default() },
            Request::Complete { id: 123456789 },
            Request::Stats,
            Request::Shutdown,
            Request::WaitArmed { id: 1 },
            Request::WaitDone { id: u64::MAX },
            Request::Retire { worker: 3 },
            Request::Heartbeat { worker: 9 },
            Request::AbortGroup { id: 42, suspect: 2 },
            Request::AbortGroup { id: 43, suspect: NO_SUSPECT },
            Request::Probe { id: 7 },
            Request::Rejoin { worker: 1, addr: "127.0.0.1:9999".into() },
            Request::Register { worker: 0, addr: "10.0.0.5:40000".into() },
            Request::Lookup { worker: 15 },
        ] {
            assert_eq!(Request::decode(&req.encode()).unwrap(), req);
        }
    }

    #[test]
    fn response_codec_roundtrip() {
        for resp in [
            Response::Assigned {
                id: 9,
                members: vec![0, 4, 5],
                plan: vec![],
                armed: vec![
                    (9, vec![0, 4, 5], vec![]),
                    (10, vec![1, 2], vec![vec![1], vec![2]]),
                ],
            },
            Response::Assigned {
                id: 3,
                members: vec![0, 1, 2, 3],
                plan: vec![vec![1, 0], vec![3, 2]],
                armed: vec![],
            },
            Response::Armed { groups: vec![] },
            Response::Armed {
                groups: vec![(77, vec![5, 6], vec![vec![6, 5]])],
            },
            Response::Stats(StatsReport {
                requests: 1,
                conflicts: 2,
                groups_created: 3,
                buffer_hits: 4,
                speeds: vec![0.01, 0.0, 0.03],
                drafts: vec![5, 0, 7],
                last_drafted: vec![1, 0, 9],
                deaths: 1,
                groups_aborted: 2,
                rejoins: 1,
            }),
            Response::Stats(StatsReport::default()),
            Response::Ok,
            Response::Err { msg: "boom".into() },
            Response::State(GroupState::Pending),
            Response::State(GroupState::Armed),
            Response::State(GroupState::Done),
            Response::State(GroupState::Aborted),
            Response::Addr { addr: None },
            Response::Addr { addr: Some("127.0.0.1:1234".into()) },
        ] {
            assert_eq!(Response::decode(&resp.encode()).unwrap(), resp);
        }
    }

    #[test]
    fn stats_relative_speed() {
        let s = StatsReport {
            speeds: vec![0.010, 0.0, 0.030],
            ..StatsReport::default()
        };
        assert!((s.relative_speed(0).unwrap() - 1.0).abs() < 1e-12);
        assert_eq!(s.relative_speed(1), None, "unmeasured worker has no factor");
        assert!((s.relative_speed(2).unwrap() - 3.0).abs() < 1e-12);
        assert_eq!(s.relative_speed(99), None);
        assert_eq!(StatsReport::default().relative_speed(0), None);
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Request::decode(&[99]).is_err());
        assert!(Response::decode(&[]).is_err());
        assert!(Request::decode(&[0, 1]).is_err()); // truncated
    }

    #[test]
    fn end_to_end_over_tcp() {
        let server = GgServer::spawn(
            "127.0.0.1:0",
            GgConfig::smart(8, 4, 3, 8),
            42,
        )
        .unwrap();
        let mut client = GgClient::connect(server.addr).unwrap();
        let (assigned, armed) = client.sync(0, 0.0125).unwrap();
        let (id, members, plan) = assigned.expect("sync must assign a group");
        assert!(members.contains(&0));
        assert!(plan.validate(&members).is_ok(), "plan must cover the members");
        assert!(plan.is_flat(), "no topology configured: plan must be flat");
        assert!(!armed.is_empty());
        // complete every armed group
        for (gid, _) in armed {
            let _ = client.complete(gid).unwrap();
        }
        // a duplicate/retried Complete is idempotent: empty armed list,
        // no error, and — regression — no control-plane crash
        let dup = client.complete(id).expect("duplicate Complete must succeed");
        assert!(dup.is_empty(), "duplicate Complete armed {dup:?}");
        let stats = client.stats().unwrap();
        assert_eq!(stats.requests, 1);
        assert!(stats.groups_created >= 1);
        // the piggybacked speed report landed in the GG speed table
        assert_eq!(stats.speeds.len(), 8);
        assert!((stats.speeds[0] - 0.0125).abs() < 1e-12);
        assert!(stats.speeds[1..].iter().all(|&v| v == 0.0));
        client.shutdown().unwrap();
        server.shutdown();
    }

    #[test]
    fn wait_and_retire_over_tcp() {
        let server =
            GgServer::spawn("127.0.0.1:0", GgConfig::random(4, 4, 2), 7).unwrap();
        let mut c = GgClient::connect(server.addr).unwrap();
        let (assigned, _armed) = c.sync(0, 0.0).unwrap();
        let (gid, _, _) = assigned.expect("sync must assign a group");
        // the first group has no conflicts: wait_armed returns immediately
        c.wait_armed(gid).unwrap();
        // a second connection completes the group while we block on it
        let addr = server.addr;
        let h = std::thread::spawn(move || {
            let mut c2 = GgClient::connect(addr).unwrap();
            std::thread::sleep(std::time::Duration::from_millis(30));
            c2.complete(gid).unwrap();
        });
        c.wait_done(gid).unwrap();
        h.join().unwrap();
        // wait on a completed (unknown) id is a no-op, not a hang
        c.wait_armed(gid).unwrap();
        // a retired worker's sync says "skip this step"
        c.retire(0).unwrap();
        let (assigned, newly) = c.sync(0, 0.0).unwrap();
        assert!(assigned.is_none(), "retired worker must not be drafted");
        assert!(newly.is_empty());
        server.shutdown();
    }

    #[test]
    fn abort_probe_and_rejoin_over_tcp() {
        let server =
            GgServer::spawn("127.0.0.1:0", GgConfig::random(4, 4, 2), 11).unwrap();
        let mut c = GgClient::connect(server.addr).unwrap();
        let (assigned, _) = c.sync(0, 0.0).unwrap();
        let (gid, members, _) = assigned.expect("sync must assign");
        assert!(members.contains(&0));
        assert_eq!(c.probe(gid).unwrap(), GroupState::Armed);
        // a ring survivor reports the collective broken, accusing nobody
        c.abort_group(gid, None).unwrap();
        assert_eq!(c.probe(gid).unwrap(), GroupState::Aborted);
        // waits on the aborted group return Aborted instead of hanging
        assert_eq!(c.wait_armed(gid).unwrap(), WaitOutcome::Aborted);
        assert_eq!(c.wait_done(gid).unwrap(), WaitOutcome::Aborted);
        // duplicate abort reports are idempotent
        c.abort_group(gid, Some(1)).unwrap();
        let stats = c.stats().unwrap();
        assert_eq!(stats.groups_aborted, 1);
        assert_eq!(stats.deaths, 0, "abort alone must not declare anyone dead");
        // address registry
        assert_eq!(c.lookup(2).unwrap(), None);
        c.register(2, "127.0.0.1:5555").unwrap();
        assert_eq!(c.lookup(2).unwrap(), Some("127.0.0.1:5555".into()));
        // rejoin re-registers a rank and updates its address
        c.rejoin(2, "127.0.0.1:6666").unwrap();
        assert_eq!(c.lookup(2).unwrap(), Some("127.0.0.1:6666".into()));
        let stats = c.stats().unwrap();
        assert_eq!(stats.rejoins, 1);
        server.shutdown();
    }

    #[test]
    fn liveness_monitor_declares_silent_rank_dead() {
        // rank 0 heartbeats, rank 1 goes silent: the monitor must declare
        // rank 1 dead, aborting the armed group the two of them share, so
        // rank 0's wait unblocks with Aborted instead of hanging forever.
        let liveness = LivenessConfig {
            timeout: Duration::from_millis(250),
            accused_grace: Duration::from_millis(100),
            poll: Duration::from_millis(10),
        };
        let server = GgServer::spawn_with_liveness(
            "127.0.0.1:0",
            GgConfig::random(2, 2, 2),
            5,
            Some(liveness),
        )
        .unwrap();
        let mut c = GgClient::connect(server.addr).unwrap();
        c.heartbeat(1).unwrap(); // rank 1's first and last sign of life
        let (assigned, _) = c.sync(0, 0.0).unwrap();
        let (gid, members, _) = assigned.expect("pair must form");
        assert_eq!(members, vec![0, 1]);
        // keep rank 0 alive past rank 1's deadline
        let deadline = Instant::now() + Duration::from_millis(700);
        let mut dead = false;
        while Instant::now() < deadline {
            c.heartbeat(0).unwrap();
            if c.probe(gid).unwrap() == GroupState::Aborted {
                dead = true;
                break;
            }
            thread::sleep(Duration::from_millis(20));
        }
        assert!(dead, "monitor never aborted the dead rank's group");
        let stats = c.stats().unwrap();
        assert_eq!(stats.deaths, 1, "exactly rank 1 must be declared dead");
        // the survivor's next division excludes the dead rank: with only
        // one live worker there is nobody to pair with — sync says skip
        let (assigned, _) = c.sync(0, 0.0).unwrap();
        assert!(assigned.is_none(), "dead rank must not be drafted");
        server.shutdown();
    }

    #[test]
    fn accusation_fast_path_beats_the_hard_timeout() {
        // hard timeout far beyond the test: only the accusation path can
        // declare the silent suspect dead
        let liveness = LivenessConfig {
            timeout: Duration::from_secs(3600),
            accused_grace: Duration::from_millis(80),
            poll: Duration::from_millis(10),
        };
        let server = GgServer::spawn_with_liveness(
            "127.0.0.1:0",
            GgConfig::random(2, 2, 2),
            6,
            Some(liveness),
        )
        .unwrap();
        let mut c = GgClient::connect(server.addr).unwrap();
        let (assigned, _) = c.sync(0, 0.0).unwrap();
        let (gid, _, _) = assigned.expect("pair must form");
        // survivor reports the broken collective and accuses rank 1
        c.abort_group(gid, Some(1)).unwrap();
        let deadline = Instant::now() + Duration::from_millis(900);
        let mut deaths = 0;
        while Instant::now() < deadline {
            deaths = c.stats().unwrap().deaths;
            if deaths == 1 {
                break;
            }
            thread::sleep(Duration::from_millis(20));
        }
        assert_eq!(deaths, 1, "accused silent rank must die on the fast path");
        server.shutdown();
    }

    #[test]
    fn client_connection_is_reused_across_calls() {
        // Regression: launcher-side stats used to reconnect per call.
        // One persistent GgClient must register exactly one accepted
        // connection no matter how many RPCs it issues.
        let server =
            GgServer::spawn("127.0.0.1:0", GgConfig::random(4, 4, 2), 2).unwrap();
        let mut c = GgClient::connect(server.addr).unwrap();
        for w in 0..4 {
            c.heartbeat(w).unwrap();
            let _ = c.stats().unwrap();
            let _ = c.probe(999).unwrap();
        }
        assert_eq!(
            server.connections_accepted(),
            1,
            "a persistent client must not re-dial per call"
        );
        server.shutdown();
    }

    #[test]
    fn topology_configured_sync_carries_hier_plan() {
        // With a `--topo` placement the Sync reply's plan must bucket the
        // group's members by machine — identically on both backends,
        // since assembly is a pure function of (members, topo, speeds).
        for mode in [GgMode::Sharded, GgMode::SingleLock] {
            let mut cfg = GgConfig::random(4, 4, 4);
            cfg.topology = Some(crate::topo::Topology::parse("m0:0,1;m1:2,3", 4).unwrap());
            let server =
                GgServer::spawn_with_backend("127.0.0.1:0", cfg, 13, None, mode).unwrap();
            let mut c = GgClient::connect(server.addr).unwrap();
            let (assigned, _) = c.sync(0, 0.02).unwrap();
            let (_, members, plan) = assigned.expect("sync must assign");
            assert_eq!(members, vec![0, 1, 2, 3]);
            assert!(!plan.is_flat(), "two machines must yield a two-level plan");
            assert_eq!(plan.nodes, vec![vec![0, 1], vec![2, 3]]);
            assert!(plan.validate(&members).is_ok());
            server.shutdown();
        }
    }

    #[test]
    fn multiple_clients_share_state() {
        let server = GgServer::spawn(
            "127.0.0.1:0",
            GgConfig::random(8, 4, 2),
            1,
        )
        .unwrap();
        let mut c1 = GgClient::connect(server.addr).unwrap();
        let mut c2 = GgClient::connect(server.addr).unwrap();
        let _ = c1.sync(0, 0.0).unwrap();
        let _ = c2.sync(1, 0.0).unwrap();
        let stats = c1.stats().unwrap();
        assert_eq!(stats.requests, 2, "both clients must hit one state machine");
        server.shutdown();
    }
}
