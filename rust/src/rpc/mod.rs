//! TCP RPC for the Group Generator (§6.2's gRPC service, rebuilt on a
//! length-prefixed binary protocol over std TCP — the vendored registry
//! has no gRPC/tokio, and the messages are tiny control packets anyway).
//!
//! Wire format: every frame is `u32 length (LE) | payload`. Payloads are
//! hand-encoded (see [`wire`]); the protocol has three calls:
//!
//! * `Request { worker }  -> Assigned { group_id, members, armed_groups }`
//! * `Complete { group_id } -> Armed { groups }`
//! * `Stats {} -> StatsReply { requests, conflicts, ... }`
//!
//! The server wraps the same pure [`GroupGenerator`] state machine the
//! simulator and the threaded runtime use.

pub mod wire;

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;

use anyhow::{anyhow, bail, Context, Result};

use crate::gg::{GgConfig, Group, GroupGenerator, GroupId};
use crate::util::rng::Pcg32;
use wire::{Reader, Writer};

/// Piggybacked speed telemetry: the worker's own EWMA of its local SGD
/// step duration (compute phase only, sync wait excluded). Rides on
/// every `Sync`, so the GG's [`crate::gg::SpeedTable`] tracks *measured*
/// heterogeneity with zero extra round trips. `0.0` = no measurement
/// yet (first iteration); the server ignores it.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct SpeedReport {
    /// EWMA seconds per local SGD step.
    pub ewma_step_secs: f64,
}

impl SpeedReport {
    pub fn new(ewma_step_secs: f64) -> Self {
        Self { ewma_step_secs }
    }
}

/// Client -> server messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Worker `w` reached its sync point; `speed` carries its measured
    /// step-duration EWMA (the slowdown filter's dynamic input).
    Sync { worker: u32, speed: SpeedReport },
    /// Group `id` finished its P-Reduce.
    Complete { id: GroupId },
    /// Fetch counters.
    Stats,
    /// Orderly shutdown.
    Shutdown,
    /// Block until group `id` holds its locks (or was already completed).
    /// Distributed workers call this between `Sync` and the data-plane
    /// collective: a pending group must not start moving model bytes.
    WaitArmed { id: GroupId },
    /// Block until group `id` has been completed. Non-leader members call
    /// this after the collective so their next `Sync` cannot observe the
    /// group still at the front of their Group Buffer (the re-execution
    /// race the threaded runtime solves with shared `done` flags).
    WaitDone { id: GroupId },
    /// Worker `w` leaves the session: never drafted into new groups.
    Retire { worker: u32 },
}

/// GG counters plus the measured per-worker speed table, returned by
/// `Request::Stats` (what `ripples launch` renders and the e2e suite
/// asserts filter behaviour from).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct StatsReport {
    pub requests: u64,
    pub conflicts: u64,
    pub groups_created: u64,
    pub buffer_hits: u64,
    /// Per-worker measured EWMA step seconds (0.0 = nothing reported).
    pub speeds: Vec<f64>,
    /// Per-worker drafts into groups created by *other* initiators.
    pub drafts: Vec<u64>,
    /// `requests` value at each worker's most recent such draft (0 =
    /// never): how long ago the filter last drafted the worker.
    pub last_drafted: Vec<u64>,
}

impl StatsReport {
    /// Measured slowdown factor of `w` vs the fastest measured worker
    /// (None when either side has no measurement). Delegates to
    /// [`crate::metrics::relative_speeds`] — one definition of
    /// "relative speed" for the e2e assertions and the fig harnesses.
    pub fn relative_speed(&self, w: usize) -> Option<f64> {
        let rel = *crate::metrics::relative_speeds(&self.speeds).get(w)?;
        (rel > 0.0).then_some(rel)
    }
}

/// Server -> client messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Assigned { id: GroupId, members: Vec<u32>, armed: Vec<(GroupId, Vec<u32>)> },
    Armed { groups: Vec<(GroupId, Vec<u32>)> },
    Stats(StatsReport),
    Ok,
    Err { msg: String },
}

impl Request {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        match self {
            Request::Sync { worker, speed } => {
                w.u8(0);
                w.u32(*worker);
                w.u64(speed.ewma_step_secs.to_bits());
            }
            Request::Complete { id } => {
                w.u8(1);
                w.u64(*id);
            }
            Request::Stats => w.u8(2),
            Request::Shutdown => w.u8(3),
            Request::WaitArmed { id } => {
                w.u8(4);
                w.u64(*id);
            }
            Request::WaitDone { id } => {
                w.u8(5);
                w.u64(*id);
            }
            Request::Retire { worker } => {
                w.u8(6);
                w.u32(*worker);
            }
        }
        w.finish()
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = Reader::new(buf);
        let tag = r.u8()?;
        let req = match tag {
            0 => Request::Sync {
                worker: r.u32()?,
                speed: SpeedReport::new(f64::from_bits(r.u64()?)),
            },
            1 => Request::Complete { id: r.u64()? },
            2 => Request::Stats,
            3 => Request::Shutdown,
            4 => Request::WaitArmed { id: r.u64()? },
            5 => Request::WaitDone { id: r.u64()? },
            6 => Request::Retire { worker: r.u32()? },
            t => bail!("bad request tag {t}"),
        };
        r.done()?;
        Ok(req)
    }
}

fn encode_groups(w: &mut Writer, groups: &[(GroupId, Vec<u32>)]) {
    w.u32(groups.len() as u32);
    for (id, members) in groups {
        w.u64(*id);
        w.u32(members.len() as u32);
        for &m in members {
            w.u32(m);
        }
    }
}

fn decode_groups(r: &mut Reader) -> Result<Vec<(GroupId, Vec<u32>)>> {
    let n = r.u32()? as usize;
    if n > 1 << 20 {
        bail!("unreasonable group count {n}");
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let id = r.u64()?;
        let k = r.u32()? as usize;
        if k > 1 << 16 {
            bail!("unreasonable member count {k}");
        }
        let mut members = Vec::with_capacity(k);
        for _ in 0..k {
            members.push(r.u32()?);
        }
        out.push((id, members));
    }
    Ok(out)
}

impl Response {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        match self {
            Response::Assigned { id, members, armed } => {
                w.u8(0);
                w.u64(*id);
                w.u32(members.len() as u32);
                for &m in members {
                    w.u32(m);
                }
                encode_groups(&mut w, armed);
            }
            Response::Armed { groups } => {
                w.u8(1);
                encode_groups(&mut w, groups);
            }
            Response::Stats(s) => {
                w.u8(2);
                w.u64(s.requests);
                w.u64(s.conflicts);
                w.u64(s.groups_created);
                w.u64(s.buffer_hits);
                debug_assert!(
                    s.speeds.len() == s.drafts.len()
                        && s.drafts.len() == s.last_drafted.len()
                );
                w.u32(s.speeds.len() as u32);
                for i in 0..s.speeds.len() {
                    w.u64(s.speeds[i].to_bits());
                    w.u64(s.drafts[i]);
                    w.u64(s.last_drafted[i]);
                }
            }
            Response::Ok => w.u8(3),
            Response::Err { msg } => {
                w.u8(4);
                w.bytes(msg.as_bytes());
            }
        }
        w.finish()
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = Reader::new(buf);
        let tag = r.u8()?;
        let resp = match tag {
            0 => {
                let id = r.u64()?;
                let k = r.u32()? as usize;
                let mut members = Vec::with_capacity(k.min(1 << 16));
                for _ in 0..k {
                    members.push(r.u32()?);
                }
                Response::Assigned { id, members, armed: decode_groups(&mut r)? }
            }
            1 => Response::Armed { groups: decode_groups(&mut r)? },
            2 => {
                let requests = r.u64()?;
                let conflicts = r.u64()?;
                let groups_created = r.u64()?;
                let buffer_hits = r.u64()?;
                let n = r.u32()? as usize;
                if n > 1 << 16 {
                    bail!("unreasonable worker count {n}");
                }
                let mut speeds = Vec::with_capacity(n);
                let mut drafts = Vec::with_capacity(n);
                let mut last_drafted = Vec::with_capacity(n);
                for _ in 0..n {
                    speeds.push(f64::from_bits(r.u64()?));
                    drafts.push(r.u64()?);
                    last_drafted.push(r.u64()?);
                }
                Response::Stats(StatsReport {
                    requests,
                    conflicts,
                    groups_created,
                    buffer_hits,
                    speeds,
                    drafts,
                    last_drafted,
                })
            }
            3 => Response::Ok,
            4 => Response::Err { msg: String::from_utf8_lossy(&r.rest()).into_owned() },
            t => bail!("bad response tag {t}"),
        };
        if tag != 4 {
            r.done()?;
        }
        Ok(resp)
    }
}

fn write_frame(stream: &mut TcpStream, payload: &[u8]) -> Result<()> {
    let len = payload.len() as u32;
    stream.write_all(&len.to_le_bytes())?;
    stream.write_all(payload)?;
    Ok(())
}

fn read_frame(stream: &mut TcpStream) -> Result<Vec<u8>> {
    let mut lenbuf = [0u8; 4];
    stream.read_exact(&mut lenbuf)?;
    let len = u32::from_le_bytes(lenbuf) as usize;
    if len > 1 << 24 {
        bail!("frame too large: {len}");
    }
    let mut buf = vec![0u8; len];
    stream.read_exact(&mut buf)?;
    Ok(buf)
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

/// A running GG server; one thread per connection, shared state machine.
pub struct GgServer {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<thread::JoinHandle<()>>,
}

impl GgServer {
    /// Bind and serve on `addr` (use port 0 for an ephemeral port).
    pub fn spawn(addr: &str, cfg: GgConfig, seed: u64) -> Result<Self> {
        let listener = TcpListener::bind(addr).context("bind GG server")?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let state = Arc::new(Mutex::new((GroupGenerator::new(cfg), Pcg32::new(seed))));
        let stop2 = Arc::clone(&stop);
        let handle = thread::spawn(move || {
            listener.set_nonblocking(true).ok();
            let mut conns: Vec<thread::JoinHandle<()>> = Vec::new();
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        stream.set_nonblocking(false).ok();
                        // Read timeout so connection threads observe the
                        // stop flag instead of blocking forever on idle
                        // clients (shutdown would otherwise deadlock).
                        stream
                            .set_read_timeout(Some(std::time::Duration::from_millis(100)))
                            .ok();
                        let st = Arc::clone(&state);
                        let stop3 = Arc::clone(&stop2);
                        conns.push(thread::spawn(move || {
                            let _ = serve_conn(stream, st, stop3);
                        }));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        thread::sleep(std::time::Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
            }
            for c in conns {
                let _ = c.join();
            }
        });
        Ok(Self { addr: local, stop, handle: Some(handle) })
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for GgServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn group_pairs(groups: Vec<Group>) -> Vec<(GroupId, Vec<u32>)> {
    groups
        .into_iter()
        .map(|g| (g.id, g.members.into_iter().map(|m| m as u32).collect()))
        .collect()
}

fn serve_conn(
    mut stream: TcpStream,
    state: Arc<Mutex<(GroupGenerator, Pcg32)>>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    loop {
        let frame = match read_frame(&mut stream) {
            Ok(f) => f,
            Err(e) => {
                // timeouts poll the stop flag; real errors end the session
                let timed_out = e.downcast_ref::<std::io::Error>().is_some_and(|io| {
                    matches!(
                        io.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    )
                });
                if timed_out && !stop.load(Ordering::Relaxed) {
                    continue;
                }
                return Ok(()); // client hung up or server stopping
            }
        };
        let req = Request::decode(&frame)?;
        // Blocking calls poll the state machine without holding the lock
        // across sleeps (other connections keep making progress).
        if let Request::WaitArmed { id } | Request::WaitDone { id } = req {
            let want_armed = matches!(req, Request::WaitArmed { .. });
            let resp = loop {
                {
                    let guard = state.lock().map_err(|_| anyhow!("poisoned GG"))?;
                    let gg = &guard.0;
                    let done = gg.group(id).is_none();
                    if done || (want_armed && gg.is_armed(id)) {
                        break Response::Ok;
                    }
                }
                if stop.load(Ordering::Relaxed) {
                    break Response::Err { msg: "server stopping".into() };
                }
                thread::sleep(std::time::Duration::from_millis(1));
            };
            write_frame(&mut stream, &resp.encode())?;
            continue;
        }
        let resp = {
            let mut guard = state.lock().map_err(|_| anyhow!("poisoned GG"))?;
            let (gg, rng) = &mut *guard;
            match req {
                Request::Sync { worker, speed } => {
                    let w = worker as usize;
                    if w >= gg.config().n_workers {
                        Response::Err { msg: format!("worker {w} out of range") }
                    } else {
                        // fold the piggybacked telemetry in *before* the
                        // request so this very division sees it
                        gg.report_speed(w, speed.ewma_step_secs);
                        let (id, armed) = gg.request(w, rng);
                        // id 0 with no members encodes "skip this sync"
                        // (GroupIds start at 1)
                        let id = id.unwrap_or(0);
                        let members = gg
                            .group(id)
                            .map(|g| g.members.iter().map(|&m| m as u32).collect())
                            .unwrap_or_default();
                        Response::Assigned { id, members, armed: group_pairs(armed) }
                    }
                }
                Request::Complete { id } => {
                    if gg.group(id).is_none() {
                        // unknown = already completed: a duplicate/retried
                        // leader Complete is idempotent, not a crash
                        Response::Armed { groups: Vec::new() }
                    } else if !gg.is_armed(id) {
                        // completing a pending group would corrupt the lock
                        // vector — a client protocol violation
                        Response::Err { msg: format!("group {id} is not armed") }
                    } else {
                        Response::Armed { groups: group_pairs(gg.complete(id)) }
                    }
                }
                Request::Stats => Response::Stats(StatsReport {
                    requests: gg.stats.requests,
                    conflicts: gg.stats.conflicts,
                    groups_created: gg.stats.groups_created,
                    buffer_hits: gg.stats.buffer_hits,
                    speeds: gg.speed_table().snapshot(),
                    drafts: gg.drafts().to_vec(),
                    last_drafted: gg.last_drafted().to_vec(),
                }),
                Request::Shutdown => {
                    stop.store(true, Ordering::Relaxed);
                    Response::Ok
                }
                Request::Retire { worker } => {
                    let w = worker as usize;
                    if w >= gg.config().n_workers {
                        Response::Err { msg: format!("worker {w} out of range") }
                    } else {
                        gg.retire(w);
                        Response::Ok
                    }
                }
                // handled above without holding the lock
                Request::WaitArmed { .. } | Request::WaitDone { .. } => unreachable!(),
            }
        };
        write_frame(&mut stream, &resp.encode())?;
        if matches!(req, Request::Shutdown) {
            return Ok(());
        }
    }
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

/// Blocking GG client over one TCP connection.
pub struct GgClient {
    stream: TcpStream,
}

impl GgClient {
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<Self> {
        let stream = TcpStream::connect(addr).context("connect to GG")?;
        stream.set_nodelay(true).ok();
        Ok(Self { stream })
    }

    /// Bound every call — including the blocking `wait_armed`/`wait_done`
    /// — so a dead peer or server surfaces as an error instead of hanging
    /// this worker (and everything reading its pipes) forever. A group
    /// can legitimately stay pending for a few straggler iterations, so
    /// callers should pass the same generous budget as the data plane.
    pub fn set_io_timeout(&mut self, timeout: std::time::Duration) -> Result<()> {
        self.stream.set_read_timeout(Some(timeout)).context("set read timeout")?;
        self.stream.set_write_timeout(Some(timeout)).context("set write timeout")?;
        Ok(())
    }

    fn call(&mut self, req: &Request) -> Result<Response> {
        write_frame(&mut self.stream, &req.encode())?;
        let frame = read_frame(&mut self.stream)?;
        Response::decode(&frame)
    }

    /// Worker sync request; returns `(assigned, newly_armed)`. `assigned`
    /// is None (wire id 0) when the GG says "skip this sync step".
    /// `ewma_step_secs` piggybacks the worker's measured step-duration
    /// EWMA (0.0 = no measurement yet).
    #[allow(clippy::type_complexity)]
    pub fn sync(
        &mut self,
        worker: usize,
        ewma_step_secs: f64,
    ) -> Result<(Option<(GroupId, Vec<usize>)>, Vec<(GroupId, Vec<usize>)>)> {
        match self.call(&Request::Sync {
            worker: worker as u32,
            speed: SpeedReport::new(ewma_step_secs),
        })? {
            Response::Assigned { id, members, armed } => {
                let assigned = (id != 0).then(|| {
                    (id, members.into_iter().map(|m| m as usize).collect::<Vec<_>>())
                });
                Ok((
                    assigned,
                    armed
                        .into_iter()
                        .map(|(id, ms)| (id, ms.into_iter().map(|m| m as usize).collect()))
                        .collect(),
                ))
            }
            Response::Err { msg } => bail!("GG error: {msg}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn complete(&mut self, id: GroupId) -> Result<Vec<(GroupId, Vec<usize>)>> {
        match self.call(&Request::Complete { id })? {
            Response::Armed { groups } => Ok(groups
                .into_iter()
                .map(|(id, ms)| (id, ms.into_iter().map(|m| m as usize).collect()))
                .collect()),
            Response::Err { msg } => bail!("GG error: {msg}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn stats(&mut self) -> Result<StatsReport> {
        match self.call(&Request::Stats)? {
            Response::Stats(report) => Ok(report),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Block until `id` holds its locks (no-op if it already completed).
    pub fn wait_armed(&mut self, id: GroupId) -> Result<()> {
        match self.call(&Request::WaitArmed { id })? {
            Response::Ok => Ok(()),
            Response::Err { msg } => bail!("GG error: {msg}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Block until `id` has been completed (by its group leader).
    pub fn wait_done(&mut self, id: GroupId) -> Result<()> {
        match self.call(&Request::WaitDone { id })? {
            Response::Ok => Ok(()),
            Response::Err { msg } => bail!("GG error: {msg}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Mark `worker` as departed; it is never drafted into new groups.
    pub fn retire(&mut self, worker: usize) -> Result<()> {
        match self.call(&Request::Retire { worker: worker as u32 })? {
            Response::Ok => Ok(()),
            Response::Err { msg } => bail!("GG error: {msg}"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn shutdown(&mut self) -> Result<()> {
        match self.call(&Request::Shutdown)? {
            Response::Ok => Ok(()),
            other => bail!("unexpected response {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_codec_roundtrip() {
        for req in [
            Request::Sync { worker: 7, speed: SpeedReport::new(0.0123) },
            Request::Sync { worker: 0, speed: SpeedReport::default() },
            Request::Complete { id: 123456789 },
            Request::Stats,
            Request::Shutdown,
            Request::WaitArmed { id: 1 },
            Request::WaitDone { id: u64::MAX },
            Request::Retire { worker: 3 },
        ] {
            assert_eq!(Request::decode(&req.encode()).unwrap(), req);
        }
    }

    #[test]
    fn response_codec_roundtrip() {
        for resp in [
            Response::Assigned {
                id: 9,
                members: vec![0, 4, 5],
                armed: vec![(9, vec![0, 4, 5]), (10, vec![1, 2])],
            },
            Response::Armed { groups: vec![] },
            Response::Stats(StatsReport {
                requests: 1,
                conflicts: 2,
                groups_created: 3,
                buffer_hits: 4,
                speeds: vec![0.01, 0.0, 0.03],
                drafts: vec![5, 0, 7],
                last_drafted: vec![1, 0, 9],
            }),
            Response::Stats(StatsReport::default()),
            Response::Ok,
            Response::Err { msg: "boom".into() },
        ] {
            assert_eq!(Response::decode(&resp.encode()).unwrap(), resp);
        }
    }

    #[test]
    fn stats_relative_speed() {
        let s = StatsReport {
            speeds: vec![0.010, 0.0, 0.030],
            ..StatsReport::default()
        };
        assert!((s.relative_speed(0).unwrap() - 1.0).abs() < 1e-12);
        assert_eq!(s.relative_speed(1), None, "unmeasured worker has no factor");
        assert!((s.relative_speed(2).unwrap() - 3.0).abs() < 1e-12);
        assert_eq!(s.relative_speed(99), None);
        assert_eq!(StatsReport::default().relative_speed(0), None);
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Request::decode(&[99]).is_err());
        assert!(Response::decode(&[]).is_err());
        assert!(Request::decode(&[0, 1]).is_err()); // truncated
    }

    #[test]
    fn end_to_end_over_tcp() {
        let server = GgServer::spawn(
            "127.0.0.1:0",
            GgConfig::smart(8, 4, 3, 8),
            42,
        )
        .unwrap();
        let mut client = GgClient::connect(server.addr).unwrap();
        let (assigned, armed) = client.sync(0, 0.0125).unwrap();
        let (id, members) = assigned.expect("sync must assign a group");
        assert!(members.contains(&0));
        assert!(!armed.is_empty());
        // complete every armed group
        for (gid, _) in armed {
            let _ = client.complete(gid).unwrap();
        }
        // a duplicate/retried Complete is idempotent: empty armed list,
        // no error, and — regression — no control-plane crash
        let dup = client.complete(id).expect("duplicate Complete must succeed");
        assert!(dup.is_empty(), "duplicate Complete armed {dup:?}");
        let stats = client.stats().unwrap();
        assert_eq!(stats.requests, 1);
        assert!(stats.groups_created >= 1);
        // the piggybacked speed report landed in the GG speed table
        assert_eq!(stats.speeds.len(), 8);
        assert!((stats.speeds[0] - 0.0125).abs() < 1e-12);
        assert!(stats.speeds[1..].iter().all(|&v| v == 0.0));
        client.shutdown().unwrap();
        server.shutdown();
    }

    #[test]
    fn wait_and_retire_over_tcp() {
        let server =
            GgServer::spawn("127.0.0.1:0", GgConfig::random(4, 4, 2), 7).unwrap();
        let mut c = GgClient::connect(server.addr).unwrap();
        let (assigned, _armed) = c.sync(0, 0.0).unwrap();
        let (gid, _) = assigned.expect("sync must assign a group");
        // the first group has no conflicts: wait_armed returns immediately
        c.wait_armed(gid).unwrap();
        // a second connection completes the group while we block on it
        let addr = server.addr;
        let h = std::thread::spawn(move || {
            let mut c2 = GgClient::connect(addr).unwrap();
            std::thread::sleep(std::time::Duration::from_millis(30));
            c2.complete(gid).unwrap();
        });
        c.wait_done(gid).unwrap();
        h.join().unwrap();
        // wait on a completed (unknown) id is a no-op, not a hang
        c.wait_armed(gid).unwrap();
        // a retired worker's sync says "skip this step"
        c.retire(0).unwrap();
        let (assigned, newly) = c.sync(0, 0.0).unwrap();
        assert!(assigned.is_none(), "retired worker must not be drafted");
        assert!(newly.is_empty());
        server.shutdown();
    }

    #[test]
    fn multiple_clients_share_state() {
        let server = GgServer::spawn(
            "127.0.0.1:0",
            GgConfig::random(8, 4, 2),
            1,
        )
        .unwrap();
        let mut c1 = GgClient::connect(server.addr).unwrap();
        let mut c2 = GgClient::connect(server.addr).unwrap();
        let _ = c1.sync(0, 0.0).unwrap();
        let _ = c2.sync(1, 0.0).unwrap();
        let stats = c1.stats().unwrap();
        assert_eq!(stats.requests, 2, "both clients must hit one state machine");
        server.shutdown();
    }
}
