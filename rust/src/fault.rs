//! Deterministic fault injection for tests (the crash-tolerance test
//! harness backbone).
//!
//! A [`FaultPlan`] is a declarative list of failures — kill rank R at its
//! N-th transport operation, drop every frame on edge (a,b), suppress a
//! rank's heartbeats for a window — that harnesses can consult and
//! transports can enforce. Plans are plain data: the same plan replayed
//! over the same seeded workload produces byte-identical failures, so a
//! chaos counterexample is reproducible from its seed alone
//! ([`FaultPlan::random`] derives a plan from a [`Pcg32`] stream).
//!
//! [`FaultyTransport`] wraps any [`ChunkTransport`] (the in-process
//! [`crate::collectives::ring::ChannelTransport`] in unit tests, the
//! framed TCP transport in principle) and injects the plan's failures at
//! the transport boundary, mimicking what a real crash looks like from a
//! survivor's seat: a killed rank's own operations error like a dying
//! process; a cut edge swallows sends and starves receives. The
//! simulator consumes the same plan through
//! [`FaultPlan::crash_events`] → [`crate::cluster::CrashEvent`].

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::cluster::CrashEvent;
use crate::collectives::ring::ChunkTransport;
use crate::util::rng::Pcg32;

/// One injected failure.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Fault {
    /// Rank `rank` dies: every transport operation it attempts from its
    /// `at_op`-th onward fails the way a crashing process's do.
    KillRank { rank: usize, at_op: u64 },
    /// The directed edge `from -> to` drops everything from each
    /// endpoint's `at_op`-th operation on: sends are swallowed, receives
    /// starve (error instead of data).
    CutEdge { from: usize, to: usize, at_op: u64 },
    /// Suppress `rank`'s heartbeats for beats in `[from_beat, to_beat)`
    /// — consumed by liveness-test harnesses driving a heartbeat loop,
    /// not by transports.
    DelayHeartbeat { rank: usize, from_beat: u64, to_beat: u64 },
}

/// A reproducible failure schedule (see module docs).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub faults: Vec<Fault>,
}

impl FaultPlan {
    pub fn new(faults: Vec<Fault>) -> Self {
        Self { faults }
    }

    /// A random single-kill plan over `n_ranks` ranks with the kill point
    /// uniform in `[0, max_op)` — deterministic per seed, so a failing
    /// chaos run names its own counterexample.
    pub fn random(seed: u64, n_ranks: usize, max_op: u64) -> Self {
        assert!(n_ranks > 0 && max_op > 0);
        let mut rng = Pcg32::new(seed ^ 0xFA_17);
        Self::new(vec![Fault::KillRank {
            rank: rng.gen_range(n_ranks),
            at_op: rng.gen_range(max_op as usize) as u64,
        }])
    }

    /// Does `rank`'s `op`-th transport operation die?
    pub fn kills(&self, rank: usize, op: u64) -> bool {
        self.faults.iter().any(|f| {
            matches!(f, Fault::KillRank { rank: r, at_op } if *r == rank && op >= *at_op)
        })
    }

    /// Is the directed edge `from -> to` cut at operation `op`?
    pub fn cuts(&self, from: usize, to: usize, op: u64) -> bool {
        self.faults.iter().any(|f| {
            matches!(f, Fault::CutEdge { from: a, to: b, at_op }
                     if *a == from && *b == to && op >= *at_op)
        })
    }

    /// Is `rank`'s `beat`-th heartbeat suppressed?
    pub fn heartbeat_suppressed(&self, rank: usize, beat: u64) -> bool {
        self.faults.iter().any(|f| {
            matches!(f, Fault::DelayHeartbeat { rank: r, from_beat, to_beat }
                     if *r == rank && beat >= *from_beat && beat < *to_beat)
        })
    }

    /// The plan's kills as simulator crash events (`at_op` becomes the
    /// worker's crash iteration; cuts and heartbeat delays have no sim
    /// analogue and are skipped).
    pub fn crash_events(&self) -> Vec<CrashEvent> {
        self.faults
            .iter()
            .filter_map(|f| match f {
                Fault::KillRank { rank, at_op } => Some(CrashEvent {
                    worker: *rank,
                    at_iter: *at_op,
                    rejoin_after_secs: None,
                }),
                _ => None,
            })
            .collect()
    }
}

/// A [`ChunkTransport`] that injects a shared [`FaultPlan`] at rank
/// `rank`'s seat in a ring (`pred -> rank -> succ`). Operations are
/// counted per endpoint, in call order — deterministic for a
/// deterministic schedule.
pub struct FaultyTransport<T> {
    inner: T,
    plan: Arc<FaultPlan>,
    rank: usize,
    succ: usize,
    pred: usize,
    ops: u64,
}

impl<T: ChunkTransport> FaultyTransport<T> {
    pub fn new(inner: T, plan: Arc<FaultPlan>, rank: usize, pred: usize, succ: usize) -> Self {
        Self { inner, plan, rank, succ, pred, ops: 0 }
    }

    /// Operations performed so far (diagnostics).
    pub fn ops(&self) -> u64 {
        self.ops
    }
}

impl<T: ChunkTransport> ChunkTransport for FaultyTransport<T> {
    fn send(&mut self, step: u32, data: &[f32]) -> Result<()> {
        let op = self.ops;
        self.ops += 1;
        if self.plan.kills(self.rank, op) {
            bail!("injected crash: rank {} died at op {op}", self.rank);
        }
        if self.plan.cuts(self.rank, self.succ, op) {
            return Ok(()); // swallowed: the successor will starve
        }
        self.inner.send(step, data)
    }

    fn recv(&mut self, step: u32, out: &mut Vec<f32>) -> Result<()> {
        let op = self.ops;
        self.ops += 1;
        if self.plan.kills(self.rank, op) {
            bail!("injected crash: rank {} died at op {op}", self.rank);
        }
        if self.plan.cuts(self.pred, self.rank, op) {
            bail!(
                "injected fault: edge {} -> {} dropped (recv starved at op {op})",
                self.pred,
                self.rank
            );
        }
        self.inner.recv(step, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::ring::{ring_allreduce_via, ChannelTransport};
    use std::thread;

    /// Wrap a `p`-rank channel ring in faulty transports sharing `plan`.
    fn faulty_ring(p: usize, plan: &Arc<FaultPlan>) -> Vec<FaultyTransport<ChannelTransport>> {
        ChannelTransport::ring(p)
            .into_iter()
            .enumerate()
            .map(|(r, t)| {
                FaultyTransport::new(t, Arc::clone(plan), r, (r + p - 1) % p, (r + 1) % p)
            })
            .collect()
    }

    #[test]
    fn plan_predicates() {
        let plan = FaultPlan::new(vec![
            Fault::KillRank { rank: 1, at_op: 3 },
            Fault::CutEdge { from: 0, to: 2, at_op: 0 },
            Fault::DelayHeartbeat { rank: 2, from_beat: 5, to_beat: 8 },
        ]);
        assert!(!plan.kills(1, 2));
        assert!(plan.kills(1, 3) && plan.kills(1, 99));
        assert!(!plan.kills(0, 99));
        assert!(plan.cuts(0, 2, 0));
        assert!(!plan.cuts(2, 0, 99), "cuts are directed");
        assert!(!plan.heartbeat_suppressed(2, 4));
        assert!(plan.heartbeat_suppressed(2, 5) && plan.heartbeat_suppressed(2, 7));
        assert!(!plan.heartbeat_suppressed(2, 8));
        let crashes = plan.crash_events();
        assert_eq!(crashes.len(), 1);
        assert_eq!(crashes[0].worker, 1);
        assert_eq!(crashes[0].at_iter, 3);
    }

    #[test]
    fn random_plans_are_reproducible() {
        let a = FaultPlan::random(42, 8, 100);
        let b = FaultPlan::random(42, 8, 100);
        assert_eq!(a, b, "same seed must give the same plan");
        let c = FaultPlan::random(43, 8, 100);
        // different seeds *may* collide, but across a few seeds at least
        // one plan must differ
        let d = FaultPlan::random(44, 8, 100);
        assert!(a != c || a != d, "plans never vary with the seed");
    }

    #[test]
    fn injected_kill_unwinds_every_ring_member_without_hanging() {
        // 3-rank in-process ring; rank 1 dies mid-schedule. Nobody may
        // hang: the victim errors on its own op, its neighbours error
        // when the channel endpoints drop.
        let plan = Arc::new(FaultPlan::new(vec![Fault::KillRank { rank: 1, at_op: 2 }]));
        let transports = faulty_ring(3, &plan);
        let results: Vec<Result<()>> = thread::scope(|scope| {
            let handles: Vec<_> = transports
                .into_iter()
                .enumerate()
                .map(|(r, mut t)| {
                    scope.spawn(move || {
                        let mut buf = vec![r as f32; 32];
                        ring_allreduce_via(r, 3, &mut buf, &mut t)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert!(results.iter().all(|r| r.is_err()), "{results:?}");
        // repaired group: the survivors re-run among themselves and the
        // collective completes exactly (retry-in-a-repaired-group)
        let mut bufs = vec![vec![0.0f32; 32], vec![2.0f32; 32]];
        let mut repaired = ChannelTransport::ring(2);
        thread::scope(|scope| {
            for ((r, buf), mut t) in
                bufs.iter_mut().enumerate().zip(repaired.drain(..))
            {
                scope.spawn(move || {
                    ring_allreduce_via(r, 2, buf, &mut t).expect("repaired ring");
                });
            }
        });
        assert!(bufs[0].iter().all(|&v| (v - 1.0).abs() < 1e-6), "{:?}", bufs[0]);
        assert_eq!(bufs[0], bufs[1]);
    }

    #[test]
    fn cut_edge_starves_exactly_the_downstream_receiver() {
        // pair ring with the 0 -> 1 edge cut from the start: rank 0's
        // sends are swallowed (no error), rank 1's receives starve
        let plan = Arc::new(FaultPlan::new(vec![Fault::CutEdge {
            from: 0,
            to: 1,
            at_op: 0,
        }]));
        let mut ts = faulty_ring(2, &plan);
        let mut t1 = ts.pop().unwrap();
        let mut t0 = ts.pop().unwrap();
        assert!(t0.send(0, &[1.0; 4]).is_ok(), "cut sends are swallowed");
        let mut out = Vec::new();
        assert!(t1.recv(0, &mut out).is_err(), "cut receives must starve");
        // the reverse edge still works
        assert!(t1.send(0, &[2.0; 4]).is_ok());
        assert!(t0.recv(0, &mut out).is_ok());
        assert_eq!(out, vec![2.0; 4]);
    }
}
