//! Cluster-level behaviour: per-worker compute timing and heterogeneity
//! injection (the paper's §7.4 methodology: one worker sleeps 2x or 5x its
//! normal iteration time; plus optional random jitter for "long tail"
//! experiments).

use crate::util::rng::Pcg32;

/// One scheduled speed change: `worker`'s total compute multiplier
/// becomes `factor` once its *local* iteration count reaches
/// `start_iter`. This is the simulator-side ground truth of a straggler
/// that appears (or recovers) mid-run — what the GG's *measured* speed
/// table (see `gg::SpeedTable`) has to discover online.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SlowdownEvent {
    pub worker: usize,
    pub factor: f64,
    pub start_iter: u64,
}

impl SlowdownEvent {
    /// Parse a `W,F@ITER[;W,F@ITER...]` schedule (the `--slow-schedule`
    /// CLI grammar): worker `W`'s factor becomes `F` at its iteration
    /// `ITER`. Later entries for the same worker override earlier ones
    /// once active, so `7,6.0@40;7,1.0@120` is "slow from 40, recovered
    /// from 120".
    pub fn parse_list(s: &str) -> Result<Vec<SlowdownEvent>, String> {
        let mut out = Vec::new();
        for part in s.split(';').filter(|p| !p.trim().is_empty()) {
            let part = part.trim();
            let (wf, iter) = part
                .split_once('@')
                .ok_or_else(|| format!("bad schedule entry {part:?}: expected W,F@ITER"))?;
            let (w, f) = wf
                .split_once(',')
                .ok_or_else(|| format!("bad schedule entry {part:?}: expected W,F@ITER"))?;
            out.push(SlowdownEvent {
                worker: w.trim().parse().map_err(|e| format!("bad worker in {part:?}: {e}"))?,
                factor: f.trim().parse().map_err(|e| format!("bad factor in {part:?}: {e}"))?,
                start_iter: iter
                    .trim()
                    .parse()
                    .map_err(|e| format!("bad iteration in {part:?}: {e}"))?,
            });
        }
        Ok(out)
    }
}

/// One scheduled *link* bandwidth change: `worker`'s network bandwidth
/// is divided by `factor` once its *local* iteration count reaches
/// `start_iter` — the bandwidth analogue of [`SlowdownEvent`] (the repo
/// previously only modelled *compute* heterogeneity). Every ring edge
/// touching the worker is throttled (a ring step costs its slowest
/// edge), which is how a constrained link gates a whole group; the
/// wire codec (`WireCodec`, `--wire`) attacks exactly this cost.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BandwidthEvent {
    pub worker: usize,
    /// Bandwidth *divisor* (>= 1): 4.0 means the link runs at 1/4 speed.
    pub factor: f64,
    pub start_iter: u64,
}

impl BandwidthEvent {
    /// Parse a `W,F@ITER[;W,F@ITER...]` schedule (the `--bw-schedule`
    /// CLI grammar — same shape as [`SlowdownEvent::parse_list`]).
    pub fn parse_list(s: &str) -> Result<Vec<BandwidthEvent>, String> {
        Ok(SlowdownEvent::parse_list(s)?
            .into_iter()
            .map(|ev| BandwidthEvent {
                worker: ev.worker,
                factor: ev.factor,
                start_iter: ev.start_iter,
            })
            .collect())
    }
}

/// One scheduled crash: `worker` dies when its *local* iteration count
/// reaches `at_iter` (mid-iteration — the step never completes), and
/// optionally rejoins `rejoin_after_secs` virtual seconds later as a
/// checkpoint-restored replacement seeded from the freshest live peer.
/// The simulator's ground truth for `fig failures`, mirroring
/// [`SlowdownEvent`]; the deterministic test harness derives these from
/// a [`crate::fault::FaultPlan`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CrashEvent {
    pub worker: usize,
    pub at_iter: u64,
    pub rejoin_after_secs: Option<f64>,
}

impl CrashEvent {
    /// Parse a `W@ITER[+SECS][;W@ITER[+SECS]...]` schedule (the
    /// `--crash` CLI grammar): worker `W` crashes at its iteration
    /// `ITER`; with `+SECS` it rejoins that many virtual seconds later.
    pub fn parse_list(s: &str) -> Result<Vec<CrashEvent>, String> {
        let mut out = Vec::new();
        for part in s.split(';').filter(|p| !p.trim().is_empty()) {
            let part = part.trim();
            let (w, rest) = part
                .split_once('@')
                .ok_or_else(|| format!("bad crash entry {part:?}: expected W@ITER[+SECS]"))?;
            let (iter, rejoin) = match rest.split_once('+') {
                Some((i, r)) => (
                    i,
                    Some(
                        r.trim()
                            .parse::<f64>()
                            .map_err(|e| format!("bad rejoin secs in {part:?}: {e}"))?,
                    ),
                ),
                None => (rest, None),
            };
            if rejoin.is_some_and(|r| r < 0.0) {
                return Err(format!("bad crash entry {part:?}: rejoin secs must be >= 0"));
            }
            out.push(CrashEvent {
                worker: w
                    .trim()
                    .parse()
                    .map_err(|e| format!("bad worker in {part:?}: {e}"))?,
                at_iter: iter
                    .trim()
                    .parse()
                    .map_err(|e| format!("bad iteration in {part:?}: {e}"))?,
                rejoin_after_secs: rejoin,
            });
        }
        Ok(out)
    }
}

/// Resolve a `(factor, start_iter)` schedule at `iter`: the entry with
/// the largest active `start_iter` (<= `iter`) wins; `base` when none
/// is active. Ties on equal `start_iter` resolve deterministically to
/// the *last* such entry in iteration order — i.e. last-in-config wins,
/// so `7,2.0@40;7,6.0@40` means factor 6.0 from iteration 40 regardless
/// of how the entries got merged. (The `start >= b` comparison below is
/// what makes the later equal entry overwrite the earlier one; don't
/// "fix" it to `>` without updating this contract and its test.)
/// The single source of truth for schedule semantics —
/// shared by the simulator profile, the real worker loop, and the
/// launcher's ground-truth table, so they cannot drift apart.
pub fn scheduled_factor_at(
    entries: impl IntoIterator<Item = (f64, u64)>,
    base: f64,
    iter: u64,
) -> f64 {
    let mut factor = base;
    let mut best_start = None;
    for (f, start) in entries {
        if start <= iter && best_start.map_or(true, |b| start >= b) {
            best_start = Some(start);
            factor = f;
        }
    }
    factor
}

/// Heterogeneity specification.
#[derive(Debug, Clone, Default)]
pub struct HeterogeneityProfile {
    /// `(worker, factor)`: that worker's compute takes `factor`x as long.
    /// Matches the paper: factor 3.0 == "2x slowdown added" (1 + 2),
    /// but we follow the paper's looser phrasing and treat the factor as
    /// the total multiplier (2.0 and 5.0 in Fig. 19).
    pub slow_worker: Option<(usize, f64)>,
    /// Lognormal sigma for random per-iteration jitter (0 = none).
    pub jitter: f64,
    /// Time-varying slowdowns applied on top of `slow_worker`: once a
    /// worker's iteration count reaches an entry's `start_iter`, that
    /// entry's factor replaces the static one (the entry with the
    /// largest active `start_iter` wins).
    pub schedule: Vec<SlowdownEvent>,
    /// Scheduled crashes (and optional rejoins) — at most one per worker;
    /// later entries for the same worker are ignored.
    pub crashes: Vec<CrashEvent>,
    /// Per-link bandwidth throttles: once active, the worker's link
    /// bandwidth is divided by the entry's factor (largest active
    /// `start_iter` wins, mirroring the slowdown schedule).
    pub bandwidth: Vec<BandwidthEvent>,
}

impl HeterogeneityProfile {
    /// Static (iteration-0) slowdown of `worker`.
    pub fn slowdown_of(&self, worker: usize) -> f64 {
        match self.slow_worker {
            Some((w, f)) if w == worker => f,
            _ => 1.0,
        }
    }

    /// Slowdown of `worker` at its local iteration `iter`, including any
    /// active scheduled change.
    pub fn slowdown_at(&self, worker: usize, iter: u64) -> f64 {
        scheduled_factor_at(
            self.schedule
                .iter()
                .filter(|ev| ev.worker == worker)
                .map(|ev| (ev.factor, ev.start_iter)),
            self.slowdown_of(worker),
            iter,
        )
    }

    /// True once any schedule entry for `worker` is active at `iter`.
    pub fn schedule_active(&self, worker: usize, iter: u64) -> bool {
        self.schedule
            .iter()
            .any(|ev| ev.worker == worker && ev.start_iter <= iter)
    }

    /// The crash scheduled for `worker`, if any (first entry wins).
    pub fn crash_of(&self, worker: usize) -> Option<&CrashEvent> {
        self.crashes.iter().find(|ev| ev.worker == worker)
    }

    /// Bandwidth divisor of `worker`'s link at its local iteration
    /// `iter` (1.0 = full speed; same largest-active-entry resolution
    /// as the slowdown schedule).
    pub fn bandwidth_factor_at(&self, worker: usize, iter: u64) -> f64 {
        scheduled_factor_at(
            self.bandwidth
                .iter()
                .filter(|ev| ev.worker == worker)
                .map(|ev| (ev.factor, ev.start_iter)),
            1.0,
            iter,
        )
    }
}

/// Per-worker compute-time source: calibrated base cost x slowdown x jitter.
/// Tracks each worker's iteration count internally so scheduled
/// (`SlowdownEvent`) speed changes apply at the right step.
#[derive(Debug)]
pub struct ComputeTimer {
    base: f64,
    profile: HeterogeneityProfile,
    rngs: Vec<Pcg32>,
    iters: Vec<u64>,
}

impl ComputeTimer {
    /// `base` is the homogeneous per-iteration compute time in seconds.
    pub fn new(base: f64, profile: HeterogeneityProfile, n_workers: usize, seed: u64) -> Self {
        let rngs = (0..n_workers)
            .map(|w| Pcg32::new(seed ^ (0xC0FFEE + w as u64 * 7919)))
            .collect();
        Self { base, profile, rngs, iters: vec![0; n_workers] }
    }

    /// Compute duration for `worker`'s next iteration (each call counts
    /// as one iteration for the slowdown schedule).
    pub fn next_compute(&mut self, worker: usize) -> f64 {
        let iter = self.iters[worker];
        self.iters[worker] += 1;
        let mut t = self.base * self.profile.slowdown_at(worker, iter);
        if self.profile.jitter > 0.0 {
            let z = self.rngs[worker].gen_normal();
            t *= (self.profile.jitter * z).exp();
        }
        t
    }

    pub fn base(&self) -> f64 {
        self.base
    }

    pub fn profile(&self) -> &HeterogeneityProfile {
        &self.profile
    }
}

/// Calibrated per-iteration compute costs (seconds), from the paper's
/// micro-benchmark (Fig. 15: VGG-16/CIFAR-10 compute ~0.1-0.3 s depending
/// on batch size on a 1080-Ti) and Fig. 2(b) compute/sync ratios.
pub mod calibration {
    /// VGG-16 on CIFAR-10, batch 128 (Fig. 15 "B.S.128").
    pub const VGG16_COMPUTE: f64 = 0.180;
    /// VGG-16 compute at other batch sizes (Fig. 15 "B.S." bars):
    /// slightly better SIMD utilization at larger batches.
    pub fn vgg16_compute(batch: usize) -> f64 {
        // per-sample cost shrinks mildly with batch (paper: "slightly
        // more efficient when the batch size is larger").
        let per_sample = match batch {
            0..=64 => 1.65e-3,
            65..=128 => 1.41e-3,
            _ => 1.30e-3,
        };
        per_sample * batch as f64
    }

    /// ResNet-50 on ImageNet, batch 32 per worker.
    pub const RESNET50_COMPUTE: f64 = 0.300;
    /// VGG-16 model size in bytes (9.23 MB of f32 weights, §7.1.2).
    pub const VGG16_BYTES: usize = 9_680_000;
    /// ResNet-50 model size in bytes (196 MB, §7.1.2).
    pub const RESNET50_BYTES: usize = 196_000_000;
    /// Per-sync software overhead of the AD-PSGD TF remote-variable
    /// implementation (calibrated so Fig. 2(b)'s >90% sync share on the
    /// initiating worker's critical path holds, while the *average*
    /// per-iteration time stays near PS as Fig. 17 reports — passive
    /// workers free-run and dilute the average).
    pub const ADPSGD_SYNC_OVERHEAD: f64 = 1.05;
    /// PS per-round software overhead: the TensorFlow parameter-server
    /// baseline serializes gradient application and variable serving at
    /// the server (calibrated so Fig. 17's ~5x Ripples-vs-PS per-iteration
    /// gap holds).
    pub const PS_OVERHEAD: f64 = 0.74;
    /// Horovod fused all-reduce software overhead per iteration
    /// (pipeline + fuse-buffer management).
    pub const ALLREDUCE_OVERHEAD: f64 = 0.020;
    /// P-Reduce (single NCCL group call on a cached communicator)
    /// software overhead per operation.
    pub const PREDUCE_OVERHEAD: f64 = 0.003;
    /// NCCL communicator creation cost (amortized by the CommCache;
    /// small groups on one switch initialize in tens of ms).
    pub const COMM_CREATE_COST: f64 = 0.040;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slowdown_applies_to_selected_worker_only() {
        let p = HeterogeneityProfile {
            slow_worker: Some((3, 5.0)),
            ..HeterogeneityProfile::default()
        };
        assert_eq!(p.slowdown_of(3), 5.0);
        assert_eq!(p.slowdown_of(2), 1.0);
        let mut t = ComputeTimer::new(0.1, p, 8, 1);
        assert!((t.next_compute(3) - 0.5).abs() < 1e-12);
        assert!((t.next_compute(0) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn jitter_spreads_times() {
        let p = HeterogeneityProfile { jitter: 0.2, ..HeterogeneityProfile::default() };
        let mut t = ComputeTimer::new(0.1, p, 2, 7);
        let xs: Vec<f64> = (0..200).map(|_| t.next_compute(0)).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert!(min < max, "jitter should vary");
        assert!((mean - 0.1).abs() < 0.02);
        assert!(xs.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn no_jitter_is_deterministic() {
        let p = HeterogeneityProfile::default();
        let mut t = ComputeTimer::new(0.25, p, 4, 3);
        for w in 0..4 {
            assert_eq!(t.next_compute(w), 0.25);
        }
    }

    #[test]
    fn schedule_overrides_static_factor_at_its_iteration() {
        let p = HeterogeneityProfile {
            slow_worker: Some((1, 2.0)),
            jitter: 0.0,
            schedule: vec![
                SlowdownEvent { worker: 1, factor: 6.0, start_iter: 3 },
                SlowdownEvent { worker: 1, factor: 1.0, start_iter: 7 },
            ],
        };
        assert_eq!(p.slowdown_at(1, 0), 2.0); // static phase
        assert_eq!(p.slowdown_at(1, 2), 2.0);
        assert_eq!(p.slowdown_at(1, 3), 6.0); // straggler appears
        assert_eq!(p.slowdown_at(1, 6), 6.0);
        assert_eq!(p.slowdown_at(1, 7), 1.0); // recovery
        assert_eq!(p.slowdown_at(0, 100), 1.0); // other workers untouched
        assert!(!p.schedule_active(1, 2));
        assert!(p.schedule_active(1, 3));
        assert!(!p.schedule_active(0, 100));
    }

    #[test]
    fn compute_timer_applies_schedule_per_call() {
        let p = HeterogeneityProfile {
            slow_worker: None,
            jitter: 0.0,
            schedule: vec![SlowdownEvent { worker: 0, factor: 3.0, start_iter: 2 }],
        };
        let mut t = ComputeTimer::new(0.1, p, 2, 1);
        assert!((t.next_compute(0) - 0.1).abs() < 1e-12); // iter 0
        assert!((t.next_compute(0) - 0.1).abs() < 1e-12); // iter 1
        assert!((t.next_compute(0) - 0.3).abs() < 1e-12); // iter 2: slowed
        assert!((t.next_compute(1) - 0.1).abs() < 1e-12); // other worker clean
    }

    #[test]
    fn scheduled_factor_tie_break_is_last_in_config() {
        // Duplicate `start_iter` entries: the documented contract is
        // last-in-config wins, and it must not depend on whether the
        // duplicates sit before or after other entries.
        let dup = [(2.0f64, 40u64), (6.0, 40)];
        assert_eq!(scheduled_factor_at(dup, 1.0, 39), 1.0);
        assert_eq!(scheduled_factor_at(dup, 1.0, 40), 6.0);
        // swapped order flips the winner — that *is* the contract
        let swapped = [(6.0f64, 40u64), (2.0, 40)];
        assert_eq!(scheduled_factor_at(swapped, 1.0, 40), 2.0);
        // a duplicate of an older start does not displace a newer entry
        let mixed = [(3.0f64, 10u64), (5.0, 40), (4.0, 10)];
        assert_eq!(scheduled_factor_at(mixed, 1.0, 10), 4.0);
        assert_eq!(scheduled_factor_at(mixed, 1.0, 40), 5.0);
        // and the profile surface resolves the same way
        let p = HeterogeneityProfile {
            schedule: vec![
                SlowdownEvent { worker: 1, factor: 2.0, start_iter: 40 },
                SlowdownEvent { worker: 1, factor: 6.0, start_iter: 40 },
            ],
            ..HeterogeneityProfile::default()
        };
        assert_eq!(p.slowdown_at(1, 40), 6.0);
    }

    #[test]
    fn slow_schedule_parsing() {
        let evs = SlowdownEvent::parse_list("0,3.0@40; 7,1.5@120").unwrap();
        assert_eq!(
            evs,
            vec![
                SlowdownEvent { worker: 0, factor: 3.0, start_iter: 40 },
                SlowdownEvent { worker: 7, factor: 1.5, start_iter: 120 },
            ]
        );
        assert_eq!(SlowdownEvent::parse_list("").unwrap(), vec![]);
        assert!(SlowdownEvent::parse_list("0,3.0").is_err()); // no @ITER
        assert!(SlowdownEvent::parse_list("3.0@40").is_err()); // no worker
        assert!(SlowdownEvent::parse_list("x,3.0@40").is_err());
        assert!(SlowdownEvent::parse_list("0,y@40").is_err());
        assert!(SlowdownEvent::parse_list("0,3.0@z").is_err());
    }

    #[test]
    fn crash_schedule_parsing() {
        let evs = CrashEvent::parse_list("7@30; 2@10+15.5").unwrap();
        assert_eq!(
            evs,
            vec![
                CrashEvent { worker: 7, at_iter: 30, rejoin_after_secs: None },
                CrashEvent { worker: 2, at_iter: 10, rejoin_after_secs: Some(15.5) },
            ]
        );
        assert_eq!(CrashEvent::parse_list("").unwrap(), vec![]);
        assert!(CrashEvent::parse_list("7").is_err()); // no @ITER
        assert!(CrashEvent::parse_list("x@30").is_err());
        assert!(CrashEvent::parse_list("7@y").is_err());
        assert!(CrashEvent::parse_list("7@30+z").is_err());
        assert!(CrashEvent::parse_list("7@30+-1").is_err());
    }

    #[test]
    fn bandwidth_schedule_resolves_like_slowdowns() {
        let p = HeterogeneityProfile {
            bandwidth: vec![
                BandwidthEvent { worker: 2, factor: 8.0, start_iter: 10 },
                BandwidthEvent { worker: 2, factor: 1.0, start_iter: 30 },
            ],
            ..HeterogeneityProfile::default()
        };
        assert_eq!(p.bandwidth_factor_at(2, 0), 1.0);
        assert_eq!(p.bandwidth_factor_at(2, 10), 8.0); // link degrades
        assert_eq!(p.bandwidth_factor_at(2, 29), 8.0);
        assert_eq!(p.bandwidth_factor_at(2, 30), 1.0); // link recovers
        assert_eq!(p.bandwidth_factor_at(0, 100), 1.0); // other links clean
        // parse shares the slowdown grammar
        let evs = BandwidthEvent::parse_list("2,8.0@10; 2,1.0@30").unwrap();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0], BandwidthEvent { worker: 2, factor: 8.0, start_iter: 10 });
        assert!(BandwidthEvent::parse_list("2,8.0").is_err());
    }

    #[test]
    fn crash_of_returns_first_entry() {
        let p = HeterogeneityProfile {
            crashes: vec![
                CrashEvent { worker: 1, at_iter: 5, rejoin_after_secs: None },
                CrashEvent { worker: 1, at_iter: 9, rejoin_after_secs: Some(1.0) },
            ],
            ..HeterogeneityProfile::default()
        };
        assert_eq!(p.crash_of(1).unwrap().at_iter, 5);
        assert!(p.crash_of(0).is_none());
    }

    #[test]
    fn vgg_compute_grows_with_batch_sublinearly() {
        let c64 = calibration::vgg16_compute(64);
        let c128 = calibration::vgg16_compute(128);
        let c256 = calibration::vgg16_compute(256);
        assert!(c128 > c64 && c256 > c128);
        // per-sample efficiency improves
        assert!(c128 / 128.0 < c64 / 64.0);
        assert!(c256 / 256.0 <= c128 / 128.0);
    }
}
