//! Cluster-level behaviour: per-worker compute timing and heterogeneity
//! injection (the paper's §7.4 methodology: one worker sleeps 2x or 5x its
//! normal iteration time; plus optional random jitter for "long tail"
//! experiments).

use crate::util::rng::Pcg32;

/// Heterogeneity specification.
#[derive(Debug, Clone, Default)]
pub struct HeterogeneityProfile {
    /// `(worker, factor)`: that worker's compute takes `factor`x as long.
    /// Matches the paper: factor 3.0 == "2x slowdown added" (1 + 2),
    /// but we follow the paper's looser phrasing and treat the factor as
    /// the total multiplier (2.0 and 5.0 in Fig. 19).
    pub slow_worker: Option<(usize, f64)>,
    /// Lognormal sigma for random per-iteration jitter (0 = none).
    pub jitter: f64,
}

impl HeterogeneityProfile {
    pub fn slowdown_of(&self, worker: usize) -> f64 {
        match self.slow_worker {
            Some((w, f)) if w == worker => f,
            _ => 1.0,
        }
    }
}

/// Per-worker compute-time source: calibrated base cost x slowdown x jitter.
#[derive(Debug)]
pub struct ComputeTimer {
    base: f64,
    profile: HeterogeneityProfile,
    rngs: Vec<Pcg32>,
}

impl ComputeTimer {
    /// `base` is the homogeneous per-iteration compute time in seconds.
    pub fn new(base: f64, profile: HeterogeneityProfile, n_workers: usize, seed: u64) -> Self {
        let rngs = (0..n_workers)
            .map(|w| Pcg32::new(seed ^ (0xC0FFEE + w as u64 * 7919)))
            .collect();
        Self { base, profile, rngs }
    }

    /// Compute duration for `worker`'s next iteration.
    pub fn next_compute(&mut self, worker: usize) -> f64 {
        let mut t = self.base * self.profile.slowdown_of(worker);
        if self.profile.jitter > 0.0 {
            let z = self.rngs[worker].gen_normal();
            t *= (self.profile.jitter * z).exp();
        }
        t
    }

    pub fn base(&self) -> f64 {
        self.base
    }
}

/// Calibrated per-iteration compute costs (seconds), from the paper's
/// micro-benchmark (Fig. 15: VGG-16/CIFAR-10 compute ~0.1-0.3 s depending
/// on batch size on a 1080-Ti) and Fig. 2(b) compute/sync ratios.
pub mod calibration {
    /// VGG-16 on CIFAR-10, batch 128 (Fig. 15 "B.S.128").
    pub const VGG16_COMPUTE: f64 = 0.180;
    /// VGG-16 compute at other batch sizes (Fig. 15 "B.S." bars):
    /// slightly better SIMD utilization at larger batches.
    pub fn vgg16_compute(batch: usize) -> f64 {
        // per-sample cost shrinks mildly with batch (paper: "slightly
        // more efficient when the batch size is larger").
        let per_sample = match batch {
            0..=64 => 1.65e-3,
            65..=128 => 1.41e-3,
            _ => 1.30e-3,
        };
        per_sample * batch as f64
    }

    /// ResNet-50 on ImageNet, batch 32 per worker.
    pub const RESNET50_COMPUTE: f64 = 0.300;
    /// VGG-16 model size in bytes (9.23 MB of f32 weights, §7.1.2).
    pub const VGG16_BYTES: usize = 9_680_000;
    /// ResNet-50 model size in bytes (196 MB, §7.1.2).
    pub const RESNET50_BYTES: usize = 196_000_000;
    /// Per-sync software overhead of the AD-PSGD TF remote-variable
    /// implementation (calibrated so Fig. 2(b)'s >90% sync share on the
    /// initiating worker's critical path holds, while the *average*
    /// per-iteration time stays near PS as Fig. 17 reports — passive
    /// workers free-run and dilute the average).
    pub const ADPSGD_SYNC_OVERHEAD: f64 = 1.05;
    /// PS per-round software overhead: the TensorFlow parameter-server
    /// baseline serializes gradient application and variable serving at
    /// the server (calibrated so Fig. 17's ~5x Ripples-vs-PS per-iteration
    /// gap holds).
    pub const PS_OVERHEAD: f64 = 0.74;
    /// Horovod fused all-reduce software overhead per iteration
    /// (pipeline + fuse-buffer management).
    pub const ALLREDUCE_OVERHEAD: f64 = 0.020;
    /// P-Reduce (single NCCL group call on a cached communicator)
    /// software overhead per operation.
    pub const PREDUCE_OVERHEAD: f64 = 0.003;
    /// NCCL communicator creation cost (amortized by the CommCache;
    /// small groups on one switch initialize in tens of ms).
    pub const COMM_CREATE_COST: f64 = 0.040;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slowdown_applies_to_selected_worker_only() {
        let p = HeterogeneityProfile { slow_worker: Some((3, 5.0)), jitter: 0.0 };
        assert_eq!(p.slowdown_of(3), 5.0);
        assert_eq!(p.slowdown_of(2), 1.0);
        let mut t = ComputeTimer::new(0.1, p, 8, 1);
        assert!((t.next_compute(3) - 0.5).abs() < 1e-12);
        assert!((t.next_compute(0) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn jitter_spreads_times() {
        let p = HeterogeneityProfile { slow_worker: None, jitter: 0.2 };
        let mut t = ComputeTimer::new(0.1, p, 2, 7);
        let xs: Vec<f64> = (0..200).map(|_| t.next_compute(0)).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert!(min < max, "jitter should vary");
        assert!((mean - 0.1).abs() < 0.02);
        assert!(xs.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn no_jitter_is_deterministic() {
        let p = HeterogeneityProfile::default();
        let mut t = ComputeTimer::new(0.25, p, 4, 3);
        for w in 0..4 {
            assert_eq!(t.next_compute(w), 0.25);
        }
    }

    #[test]
    fn vgg_compute_grows_with_batch_sublinearly() {
        let c64 = calibration::vgg16_compute(64);
        let c128 = calibration::vgg16_compute(128);
        let c256 = calibration::vgg16_compute(256);
        assert!(c128 > c64 && c256 > c128);
        // per-sample efficiency improves
        assert!(c128 / 128.0 < c64 / 64.0);
        assert!(c256 / 256.0 <= c128 / 128.0);
    }
}
