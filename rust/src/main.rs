//! `ripples` — CLI for the Ripples reproduction.
//!
//! Subcommands:
//! * `train`     — run one simulated training experiment and print metrics
//! * `fig <id>`  — regenerate a paper figure/table (1, 2b, 15..20, all)
//! * `gg-serve`  — run the Group Generator as a TCP RPC service (§6.2)
//! * `launch`    — spawn an N-process P-Reduce cluster on localhost
//! * `worker`    — one distributed worker process (data plane over TCP)
//! * `artifacts` — list and smoke-run the PJRT artifacts (layer check)
//! * `check`     — exhaustively model-check the GG coordination protocol

use std::path::PathBuf;
use std::process::ExitCode;
use std::time::Duration;

use ripples::bench::figures;
use ripples::config::{AlgoKind, Experiment};
use ripples::gg::GgConfig;
use ripples::metrics;
use ripples::net::{launch_local, worker_main, LaunchConfig, WorkerParams};
use ripples::rpc::GgServer;
use ripples::sim::{self, SimParams};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let result = match args.first().map(String::as_str) {
        Some("train") => cmd_train(&args[1..]),
        Some("fig") => cmd_fig(&args[1..]),
        Some("gg-serve") => cmd_gg_serve(&args[1..]),
        Some("launch") => cmd_launch(&args[1..]),
        Some("worker") => cmd_worker(&args[1..]),
        Some("artifacts") => cmd_artifacts(&args[1..]),
        Some("check") => cmd_check(&args[1..]),
        Some("ablation") => cmd_ablation(),
        Some("help") | Some("-h") | Some("--help") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => Err(format!("unknown command '{other}'\n{USAGE}")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

const USAGE: &str = "\
ripples — Heterogeneity-Aware Asynchronous Decentralized Training

USAGE:
  ripples train [--algo NAME] [--config FILE] [--slow W,FACTOR]
                [--slow-schedule W,F@ITER[;W,F@ITER...]]
                [--bw-schedule W,F@ITER[;W,F@ITER...]]
                [--crash W@ITER[+REJOIN_SECS][;...]] [--no-repair true]
                [--overlap-shards K] [--max-staleness S]
                [--prefetch N] [--load-secs S]
                [--wire fp32|fp16|q8]
                [--iters N] [--target LOSS] [--trace FILE.csv]
  ripples fig <1|2b|15|16|17|18|19|20|dyn|overlap|wire|failures|scale|paper|all>
              [--csv DIR] [--json DIR]
  ripples gg-serve [--addr HOST:PORT] [--workers N] [--wpn K]
                   [--mode random|smart] [--group-size G]
                   [--gg-backend sharded|locked] [--liveness-ms MS]
                   [--topo m0:0,1;m1:2,3]
  ripples launch [--workers N] [--slow W:FACTOR] [--secs S] [--iters N]
                 [--algo ripples|allreduce|adpsgd|ps] [--ps-shards K]
                 [--slow-schedule W,F@ITER[;W,F@ITER...]]
                 [--group-size G] [--mode random|smart] [--c-thres C]
                 [--wpn K] [--seed S] [--lr LR] [--batch B] [--bias P]
                 [--floor-ms MS] [--model tiny|paper] [--echo true]
                 [--overlap-shards K] [--max-staleness S]
                 [--prefetch N] [--load-ms MS]
                 [--wire fp32|fp16|q8]
                 [--liveness-ms MS] [--heartbeat-ms MS]
                 [--ckpt-every N] [--ckpt-dir DIR]
                 [--kill R@SECS] [--rejoin-after SECS]
                 [--topo m0:0,1;m1:2,3]
  ripples worker --rank R --workers N --gg HOST:PORT
                 [--algo ripples|allreduce|adpsgd|ps]
                 [--ps HOST:PORT] [--ps-shards K]
                 [--listen HOST:PORT] [--peers a0,a1,...] [--secs S]
                 [--iters N] [--slowdown F] [--slow-schedule F@ITER[,...]]
                 [--seed S] [--lr LR] [--batch B] [--bias P]
                 [--floor-ms MS] [--dataset N] [--model tiny|paper]
                 [--overlap-shards K] [--max-staleness S]
                 [--prefetch N] [--load-ms MS]
                 [--wire fp32|fp16|q8]
                 [--heartbeat-ms MS] [--probe-ms MS]
                 [--ckpt-every N] [--ckpt-dir DIR] [--rejoin true]
  ripples artifacts [--dir DIR]
  ripples check [--ranks N] [--depth D]
                [--scenario drafts|faults|rejoin|rendezvous|all]
                [--mutation skip-arm-sweep|double-grant|complete-keeps-locks|
                            draft-busy|abort-skips-gb-purge|death-keeps-locks|
                            skip-aborted-prune|all]
                [--json FILE]
  ripples ablation

Algorithms: all-reduce, ps, d-psgd, ad-psgd, ripples-static,
            ripples-random, ripples-smart (default)

`launch` spawns N `worker` processes plus a Group Generator service on
localhost; workers train a shared-init MLP and execute GG-assigned
P-Reduce groups as chunked ring all-reduces over TCP (DESIGN.md
§Deployment). Point `worker` at remote hosts manually for multi-machine
runs. `--slow-schedule` makes a straggler appear (or recover) mid-run:
workers report measured EWMA step durations to the GG, whose speed
table drives the slowdown filter (`fig dyn` measures the reaction).
`--overlap-shards K` + `--max-staleness S` pipeline every P-Reduce over
K model shards while workers keep stepping on stale weights (bounded by
S; 0 = serial stop-and-wait) — `fig overlap` sweeps the hidden vs
exposed sync cost, including a staged-vs-lockstep loader axis. The
worker step itself is a staged load → compute → reconcile pipeline:
`--prefetch N` keeps N mini-batches ready ahead of compute on a loader
thread (`--load-ms` emulates per-batch I/O; 0 = inline, bit-identical),
and per-stage stall seconds surface as `load_wait`/`compute_wait`/
`reconcile_wait` in worker REPORTs and the launch table. `--wire fp16|q8` compresses every data-plane chunk
(2x/4x fewer bytes, bounded precision loss); the sim adds per-link
`--bw-schedule` bandwidth throttles and `fig wire` sweeps codec x
bandwidth. Crash tolerance: workers heartbeat the GG, whose
liveness monitor declares silent ranks dead and aborts their groups so
ring peers unwind (poison frames) and retry repaired; `launch --kill
R@SECS` SIGKILLs a worker mid-run, `--rejoin-after SECS` spawns a
replacement that restores the freshest `--ckpt-dir` checkpoint and
rejoins (`fig failures` measures crash-free vs crash-with-repair vs
crash-no-repair; sim crashes via `train --crash`). `launch --algo`
swaps the data plane for a comparison baseline on the same TCP mesh:
`allreduce` rings the whole cluster every iteration, `adpsgd` does
randomized pairwise atomic averaging (actives initiate, passives
serve), `ps` runs workers against a launcher-hosted sharded parameter
server (`--ps-shards`); `fig paper` races all four to a common target
loss (the paper-table speedup comparison). `launch --topo
m0:0,1;m1:2,3` declares which machine hosts each rank: the GG then
ships a placement plan with every group — flat rings become
bandwidth-ordered (slowest measured link crossed once), and groups
spanning machines run the two-level hierarchical P-Reduce (intra-node
gather, leader ring, broadcast back; `fig topo` sweeps the win over
flat rings on a constrained uplink). `fig --json DIR`
writes each figure as machine-readable `DIR/BENCH_<id>.json` (the
`make bench-json` perf trajectory). `check` exhaustively explores every
interleaving of a bounded model of the GG coordination protocol
(sleep-set reduction + state hashing), asserting no deadlock, no double
grant, no leaked locks, GB FIFO sanity, and aborted-set boundedness at
every state; violations print a minimized replayable trace. `--mutation`
runs the self-test mode: the named deliberately broken transition rule
must be *caught* (exit is an error if the checker misses it). `--json
FILE` writes the state-space summary (`make modelcheck` commits it as
results/CHECK_gg.json; DESIGN.md §Correctness).
";

/// Tiny flag parser: `--key value` pairs plus positionals.
fn parse_flags(args: &[String]) -> Result<(Vec<String>, Vec<(String, String)>), String> {
    let mut pos = Vec::new();
    let mut flags = Vec::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = args
                .get(i + 1)
                .ok_or_else(|| format!("flag --{key} needs a value"))?;
            flags.push((key.to_string(), val.clone()));
            i += 2;
        } else {
            pos.push(args[i].clone());
            i += 1;
        }
    }
    Ok((pos, flags))
}

fn get_flag<'a>(flags: &'a [(String, String)], key: &str) -> Option<&'a str> {
    flags.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
}

/// `--wire fp32|fp16|q8`, or `default` when the flag is absent.
fn parse_wire(
    flags: &[(String, String)],
    default: ripples::collectives::WireCodec,
) -> Result<ripples::collectives::WireCodec, String> {
    match get_flag(flags, "wire") {
        None => Ok(default),
        Some(s) => ripples::collectives::WireCodec::parse(s)
            .ok_or_else(|| format!("unknown wire codec '{s}' (fp32|fp16|q8)")),
    }
}

fn cmd_train(args: &[String]) -> Result<(), String> {
    let (_, flags) = parse_flags(args)?;
    let mut exp = match get_flag(&flags, "config") {
        Some(path) => Experiment::from_file(path)?,
        None => Experiment::default(),
    };
    if let Some(algo) = get_flag(&flags, "algo") {
        exp.algo.kind =
            AlgoKind::parse(algo).ok_or_else(|| format!("unknown algorithm '{algo}'"))?;
    }
    if let Some(slow) = get_flag(&flags, "slow") {
        let (w, f) = slow.split_once(',').ok_or("--slow expects WORKER,FACTOR")?;
        exp.cluster.hetero.slow_worker = Some((
            w.parse().map_err(|e| format!("bad worker: {e}"))?,
            f.parse().map_err(|e| format!("bad factor: {e}"))?,
        ));
    }
    if let Some(sched) = get_flag(&flags, "slow-schedule") {
        exp.cluster.hetero.schedule = ripples::cluster::SlowdownEvent::parse_list(sched)?;
    }
    if let Some(sched) = get_flag(&flags, "bw-schedule") {
        exp.cluster.hetero.bandwidth = ripples::cluster::BandwidthEvent::parse_list(sched)?;
    }
    if let Some(crash) = get_flag(&flags, "crash") {
        exp.cluster.hetero.crashes = ripples::cluster::CrashEvent::parse_list(crash)?;
    }
    if parse_or(&flags, "no-repair", false)? {
        exp.faults.repair = false;
    }
    if let Some(iters) = get_flag(&flags, "iters") {
        exp.train.max_iters = iters.parse().map_err(|e| format!("bad iters: {e}"))?;
    }
    if let Some(target) = get_flag(&flags, "target") {
        exp.train.loss_target =
            Some(target.parse().map_err(|e| format!("bad target: {e}"))?);
    }
    exp.overlap.shards = parse_or(&flags, "overlap-shards", exp.overlap.shards)?;
    exp.overlap.max_staleness =
        parse_or(&flags, "max-staleness", exp.overlap.max_staleness)?;
    exp.pipeline.prefetch = parse_or(&flags, "prefetch", exp.pipeline.prefetch)?;
    exp.pipeline.load_secs = parse_or(&flags, "load-secs", exp.pipeline.load_secs)?;
    exp.wire = parse_wire(&flags, exp.wire)?;
    exp.validate()?;
    let mut params = SimParams::vgg16_defaults(exp);
    params.spec = ripples::bench::bench_spec();
    params.dataset_size = 2048;
    params.batch = 64;
    println!(
        "running {} on {} workers ({} nodes)...",
        params.exp.algo.kind.name(),
        params.exp.cluster.n_workers(),
        params.exp.cluster.n_nodes
    );
    let res = sim::run(&params);
    println!("{}", metrics::summarize(&res));
    if let Some(tp) = res.trace.last() {
        println!(
            "final loss {:.4} at iter {:.0} (t={:.1}s)",
            tp.loss, tp.avg_iter, tp.time
        );
    }
    if let Some(t) = res.time_to_target {
        println!(
            "time-to-target: {t:.2}s (avg iters {:.0})",
            res.avg_iters_to_target.unwrap_or(0.0)
        );
    }
    if let Some(path) = get_flag(&flags, "trace") {
        metrics::write_trace_csv(&res, std::path::Path::new(path))
            .map_err(|e| format!("write trace: {e}"))?;
        println!("trace written to {path}");
    }
    Ok(())
}

fn cmd_fig(args: &[String]) -> Result<(), String> {
    let (pos, flags) = parse_flags(args)?;
    let id = pos.first().map(String::as_str).unwrap_or("all");
    let csv_dir = get_flag(&flags, "csv").map(PathBuf::from);
    let json_dir = get_flag(&flags, "json").map(PathBuf::from);
    for dir in [&csv_dir, &json_dir].into_iter().flatten() {
        std::fs::create_dir_all(dir).map_err(|e| format!("create {}: {e}", dir.display()))?;
    }
    for (fig_id, title, table) in figures::run_figure(id, csv_dir.as_deref())? {
        println!("== {title} ==");
        println!("{}", table.render());
        if let Some(dir) = &csv_dir {
            let path = dir.join(format!("{}.csv", title.to_lowercase().replace(' ', "_")));
            std::fs::write(&path, table.to_csv())
                .map_err(|e| format!("write {}: {e}", path.display()))?;
        }
        if let Some(dir) = &json_dir {
            let path = dir.join(format!("BENCH_{fig_id}.json"));
            std::fs::write(&path, figures::to_json_entry(&fig_id, &title, &table))
                .map_err(|e| format!("write {}: {e}", path.display()))?;
            println!("json written to {}", path.display());
        }
    }
    Ok(())
}

fn cmd_gg_serve(args: &[String]) -> Result<(), String> {
    let (_, flags) = parse_flags(args)?;
    let addr = get_flag(&flags, "addr").unwrap_or("127.0.0.1:7777");
    let workers: usize = get_flag(&flags, "workers")
        .unwrap_or("16")
        .parse()
        .map_err(|e| format!("bad workers: {e}"))?;
    let wpn: usize = get_flag(&flags, "wpn")
        .unwrap_or("4")
        .parse()
        .map_err(|e| format!("bad wpn: {e}"))?;
    let group: usize = get_flag(&flags, "group-size")
        .unwrap_or("3")
        .parse()
        .map_err(|e| format!("bad group size: {e}"))?;
    let mut cfg = match get_flag(&flags, "mode").unwrap_or("smart") {
        "random" => GgConfig::random(workers, wpn, group),
        "smart" => GgConfig::smart(workers, wpn, group, 8),
        other => return Err(format!("unknown mode '{other}'")),
    };
    if let Some(topo) = get_flag(&flags, "topo") {
        cfg.topology = Some(
            ripples::Topology::parse(topo, workers).map_err(|e| format!("bad --topo: {e}"))?,
        );
    }
    let liveness_ms: u64 = parse_or(&flags, "liveness-ms", 0)?;
    let liveness = (liveness_ms > 0).then(|| {
        ripples::rpc::LivenessConfig::with_timeout(Duration::from_millis(liveness_ms))
    });
    // `locked` keeps the single-lock oracle backend around for
    // differential debugging; `sharded` (the default) is the scale-out
    // coordinator (DESIGN.md §Scale).
    let backend = get_flag(&flags, "gg-backend").unwrap_or("sharded");
    let mode = ripples::rpc::GgMode::parse(backend).map_err(|e| e.to_string())?;
    let server = GgServer::spawn_with_backend(addr, cfg, 42, liveness, mode)
        .map_err(|e| e.to_string())?;
    println!(
        "GG serving on {} ({workers} workers, {wpn} per node, {backend} backend)",
        server.addr
    );
    println!("press Ctrl-C to stop");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn parse_or<T: std::str::FromStr>(
    flags: &[(String, String)],
    key: &str,
    default: T,
) -> Result<T, String>
where
    T::Err: std::fmt::Display,
{
    match get_flag(flags, key) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|e| format!("bad --{key}: {e}")),
    }
}

/// `W:FACTOR` or `W,FACTOR`.
fn parse_slow(s: &str) -> Result<(usize, f64), String> {
    let (w, f) = s
        .split_once(':')
        .or_else(|| s.split_once(','))
        .ok_or("--slow expects WORKER:FACTOR")?;
    Ok((
        w.parse().map_err(|e| format!("bad worker: {e}"))?,
        f.parse().map_err(|e| format!("bad factor: {e}"))?,
    ))
}

fn cmd_launch(args: &[String]) -> Result<(), String> {
    let (_, flags) = parse_flags(args)?;
    let mut cfg = LaunchConfig {
        bin: std::env::current_exe().map_err(|e| format!("current_exe: {e}"))?,
        ..LaunchConfig::default()
    };
    cfg.workers = parse_or(&flags, "workers", cfg.workers)?;
    if let Some(algo) = get_flag(&flags, "algo") {
        cfg.algo =
            AlgoKind::parse(algo).ok_or_else(|| format!("unknown algorithm '{algo}'"))?;
    }
    cfg.ps_shards = parse_or(&flags, "ps-shards", cfg.ps_shards)?;
    if let Some(slow) = get_flag(&flags, "slow") {
        cfg.slow = Some(parse_slow(slow)?);
    }
    if let Some(sched) = get_flag(&flags, "slow-schedule") {
        cfg.slow_schedule = ripples::cluster::SlowdownEvent::parse_list(sched)?;
    }
    cfg.secs = parse_or(&flags, "secs", cfg.secs)?;
    cfg.max_iters = parse_or(&flags, "iters", cfg.max_iters)?;
    cfg.group_size = parse_or(&flags, "group-size", cfg.group_size)?;
    cfg.c_thres = parse_or(&flags, "c-thres", cfg.c_thres)?;
    cfg.workers_per_node = parse_or(&flags, "wpn", cfg.workers_per_node)?;
    cfg.seed = parse_or(&flags, "seed", cfg.seed)?;
    cfg.lr = parse_or(&flags, "lr", cfg.lr)?;
    cfg.batch = parse_or(&flags, "batch", cfg.batch)?;
    cfg.data_bias = parse_or(&flags, "bias", cfg.data_bias)?;
    cfg.compute_floor_ms = parse_or(&flags, "floor-ms", cfg.compute_floor_ms)?;
    cfg.echo = parse_or(&flags, "echo", cfg.echo)?;
    cfg.overlap.shards = parse_or(&flags, "overlap-shards", cfg.overlap.shards)?;
    cfg.overlap.max_staleness =
        parse_or(&flags, "max-staleness", cfg.overlap.max_staleness)?;
    cfg.prefetch = parse_or(&flags, "prefetch", cfg.prefetch)?;
    cfg.load_floor_ms = parse_or(&flags, "load-ms", cfg.load_floor_ms)?;
    cfg.wire = parse_wire(&flags, cfg.wire)?;
    cfg.liveness_ms = parse_or(&flags, "liveness-ms", cfg.liveness_ms)?;
    cfg.heartbeat_ms = parse_or(&flags, "heartbeat-ms", cfg.heartbeat_ms)?;
    cfg.ckpt_every = parse_or(&flags, "ckpt-every", cfg.ckpt_every)?;
    if let Some(dir) = get_flag(&flags, "ckpt-dir") {
        cfg.ckpt_dir = Some(PathBuf::from(dir));
    }
    if let Some(kill) = get_flag(&flags, "kill") {
        let (r, secs) = kill.split_once('@').ok_or("--kill expects RANK@SECS")?;
        cfg.kill = Some(ripples::net::KillSpec {
            rank: r.parse().map_err(|e| format!("bad kill rank: {e}"))?,
            after_secs: secs.parse().map_err(|e| format!("bad kill time: {e}"))?,
            rejoin_after_secs: match get_flag(&flags, "rejoin-after") {
                Some(v) => Some(v.parse().map_err(|e| format!("bad --rejoin-after: {e}"))?),
                None => None,
            },
        });
    } else if get_flag(&flags, "rejoin-after").is_some() {
        return Err("--rejoin-after needs --kill".into());
    }
    if let Some(topo) = get_flag(&flags, "topo") {
        cfg.topo = Some(topo.to_string());
    }
    match get_flag(&flags, "mode").unwrap_or("smart") {
        "smart" => cfg.smart = true,
        "random" => cfg.smart = false,
        other => return Err(format!("unknown mode '{other}'")),
    }
    match get_flag(&flags, "model").unwrap_or("tiny") {
        "tiny" => cfg.tiny = true,
        "paper" => cfg.tiny = false,
        other => return Err(format!("unknown model '{other}'")),
    }
    println!(
        "launching {} worker processes (group size {}, {} GG{}{})...",
        cfg.workers,
        cfg.group_size,
        if cfg.smart { "smart" } else { "random" },
        cfg.slow
            .map(|(w, f)| format!(", worker {w} slowed {f}x"))
            .unwrap_or_default(),
        if cfg.slow_schedule.is_empty() {
            String::new()
        } else {
            format!(", {} scheduled speed changes", cfg.slow_schedule.len())
        }
    );
    let report = launch_local(&cfg).map_err(|e| format!("{e:#}"))?;
    print!("{}", report.render());
    Ok(())
}

fn cmd_worker(args: &[String]) -> Result<(), String> {
    let (_, flags) = parse_flags(args)?;
    let defaults = WorkerParams::default();
    let p = WorkerParams {
        rank: get_flag(&flags, "rank")
            .ok_or("worker needs --rank")?
            .parse()
            .map_err(|e| format!("bad --rank: {e}"))?,
        n_workers: get_flag(&flags, "workers")
            .ok_or("worker needs --workers")?
            .parse()
            .map_err(|e| format!("bad --workers: {e}"))?,
        gg_addr: get_flag(&flags, "gg").ok_or("worker needs --gg")?.to_string(),
        algo: match get_flag(&flags, "algo") {
            Some(a) => {
                AlgoKind::parse(a).ok_or_else(|| format!("unknown algorithm '{a}'"))?
            }
            None => defaults.algo,
        },
        ps_addr: get_flag(&flags, "ps").map(String::from),
        ps_shards: parse_or(&flags, "ps-shards", defaults.ps_shards)?,
        secs: parse_or(&flags, "secs", defaults.secs)?,
        max_iters: parse_or(&flags, "iters", defaults.max_iters)?,
        slowdown: parse_or(&flags, "slowdown", defaults.slowdown)?,
        slow_schedule: match get_flag(&flags, "slow-schedule") {
            Some(s) => ripples::net::parse_worker_schedule(s)
                .map_err(|e| format!("bad --slow-schedule: {e:#}"))?,
            None => Vec::new(),
        },
        compute_floor: Duration::from_millis(parse_or(
            &flags,
            "floor-ms",
            defaults.compute_floor.as_millis() as u64,
        )?),
        seed: parse_or(&flags, "seed", defaults.seed)?,
        lr: parse_or(&flags, "lr", defaults.lr)?,
        batch: parse_or(&flags, "batch", defaults.batch)?,
        data_bias: parse_or(&flags, "bias", defaults.data_bias)?,
        tiny: match get_flag(&flags, "model").unwrap_or("tiny") {
            "tiny" => true,
            "paper" => false,
            other => return Err(format!("unknown model '{other}'")),
        },
        dataset_size: parse_or(&flags, "dataset", defaults.dataset_size)?,
        eval_size: defaults.eval_size,
        overlap: ripples::collectives::OverlapConfig {
            shards: parse_or(&flags, "overlap-shards", defaults.overlap.shards)?,
            max_staleness: parse_or(&flags, "max-staleness", defaults.overlap.max_staleness)?,
        },
        prefetch: parse_or(&flags, "prefetch", defaults.prefetch)?,
        load_floor: Duration::from_millis(parse_or(
            &flags,
            "load-ms",
            defaults.load_floor.as_millis() as u64,
        )?),
        wire: parse_wire(&flags, defaults.wire)?,
        heartbeat_ms: parse_or(&flags, "heartbeat-ms", defaults.heartbeat_ms)?,
        probe_ms: parse_or(&flags, "probe-ms", defaults.probe_ms)?,
        ckpt_every: parse_or(&flags, "ckpt-every", defaults.ckpt_every)?,
        ckpt_dir: get_flag(&flags, "ckpt-dir").map(PathBuf::from),
        rejoin: parse_or(&flags, "rejoin", defaults.rejoin)?,
    };
    let listen = get_flag(&flags, "listen").unwrap_or("127.0.0.1:0");
    worker_main(&p, listen, get_flag(&flags, "peers")).map_err(|e| format!("{e:#}"))?;
    Ok(())
}

fn cmd_check(args: &[String]) -> Result<(), String> {
    use ripples::check::{self, Mutation, Scenario};
    let (_, flags) = parse_flags(args)?;
    let ranks: usize = parse_or(&flags, "ranks", 3)?;
    let depth: u32 = parse_or(&flags, "depth", 20)?;
    if ranks < 2 {
        return Err("--ranks must be >= 2".into());
    }
    // Self-test mode: the named broken transition rule must be caught.
    if let Some(name) = get_flag(&flags, "mutation") {
        let muts: Vec<Mutation> = if name == "all" {
            Mutation::ALL.to_vec()
        } else {
            vec![Mutation::parse(name)
                .filter(|m| *m != Mutation::None)
                .ok_or_else(|| format!("unknown mutation '{name}'"))?]
        };
        for m in muts {
            let r = check::run_mutation(m, ranks, depth);
            match &r.counterexample {
                Some(cex) => {
                    println!(
                        "mutation {:<22} CAUGHT after {} states:",
                        m.name(),
                        r.stats.states_explored
                    );
                    print!("{}", cex.render());
                }
                None => {
                    return Err(format!(
                        "mutation {} was NOT caught in {} states (depth {}) — \
                         the checker has no teeth",
                        m.name(),
                        r.stats.states_explored,
                        depth
                    ))
                }
            }
        }
        return Ok(());
    }
    let scenarios: Vec<Scenario> = match get_flag(&flags, "scenario").unwrap_or("all") {
        "all" => Scenario::ALL.to_vec(),
        s => vec![Scenario::parse(s).ok_or_else(|| {
            format!("unknown scenario '{s}' (drafts|faults|rejoin|rendezvous|all)")
        })?],
    };
    let mut reports = Vec::new();
    let mut failed = false;
    for s in scenarios {
        let r = check::run_scenario(s, ranks, depth, true);
        println!(
            "scenario {:<10} states={:<8} deduped={:<8} sleep-pruned={:<8} \
             unreduced={:<8} quiescent={:<5} max-depth={}",
            r.scenario,
            r.stats.states_explored,
            r.stats.states_deduped,
            r.stats.sleep_set_pruned,
            r.unreduced_states.unwrap_or(0),
            r.stats.quiescent_states.len(),
            r.stats.max_depth_reached
        );
        if let Some(cex) = &r.counterexample {
            failed = true;
            print!("{}", cex.render());
        }
        reports.push(r);
    }
    if let Some(path) = get_flag(&flags, "json") {
        let path = PathBuf::from(path);
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .map_err(|e| format!("create {}: {e}", parent.display()))?;
            }
        }
        std::fs::write(&path, check::report_json(ranks, depth, &reports))
            .map_err(|e| format!("write {}: {e}", path.display()))?;
        println!("json written to {}", path.display());
    }
    if failed {
        return Err("model check found invariant violations".into());
    }
    println!(
        "model check passed: {} scenario(s) clean at {} ranks, depth {}",
        reports.len(),
        ranks,
        depth
    );
    Ok(())
}

fn cmd_ablation() -> Result<(), String> {
    println!("== Smart-GG ablation (each S5 mechanism toggled) ==");
    println!("{}", ripples::bench::ablation::ablation_table().render());
    Ok(())
}

fn cmd_artifacts(args: &[String]) -> Result<(), String> {
    let (_, flags) = parse_flags(args)?;
    let dir = get_flag(&flags, "dir")
        .map(PathBuf::from)
        .unwrap_or_else(ripples::runtime::artifacts_dir);
    let mut engine = ripples::runtime::PjrtEngine::new(&dir).map_err(|e| e.to_string())?;
    println!("PJRT platform: {}", engine.platform());
    let names = engine.available();
    if names.is_empty() {
        return Err("no artifacts found — run `make artifacts`".into());
    }
    for name in &names {
        let c = engine.load(name).map_err(|e| format!("{name}: {e}"))?;
        println!(
            "  {name:<28} kind={:<16} params={:<8} inputs={}",
            c.meta.kind,
            c.meta.param_count,
            c.meta.inputs.len()
        );
    }
    // smoke-run the preduce path: mean of all-1s and all-3s must be all-2s
    if names.iter().any(|n| n == "preduce_mlp_g2") {
        let n = engine
            .load("preduce_mlp_g2")
            .map_err(|e| e.to_string())?
            .meta
            .param_count;
        let mut stacked = vec![1.0f32; n];
        stacked.extend(std::iter::repeat(3.0f32).take(n));
        let mean = engine
            .preduce("preduce_mlp_g2", &stacked)
            .map_err(|e| e.to_string())?;
        if mean.iter().all(|&v| (v - 2.0).abs() < 1e-6) {
            println!("preduce smoke test: OK (mean(1,3) == 2)");
        } else {
            return Err("preduce smoke test FAILED".into());
        }
    }
    Ok(())
}
