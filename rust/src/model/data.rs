//! Synthetic datasets (the CIFAR-10/ImageNet stand-ins; see DESIGN.md
//! §Hardware-Adaptation — the paper's claims concern synchronization
//! structure and convergence dynamics, not image content).

use crate::util::rng::Pcg32;

/// An in-memory classification dataset: `(n, in_dim)` features + labels.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub x: Vec<f32>,
    pub y: Vec<usize>,
    pub in_dim: usize,
    pub classes: usize,
}

impl Dataset {
    /// Gaussian mixture: class c centered at a random unit-ish vector,
    /// isotropic noise. Linearly-ish separable — converges fast, good for
    /// time-to-loss experiments.
    pub fn gaussian_mixture(in_dim: usize, classes: usize, n: usize, seed: u64) -> Self {
        let mut rng = Pcg32::new(seed);
        let mut centers = vec![0.0f32; classes * in_dim];
        for v in centers.iter_mut() {
            *v = rng.gen_normal() as f32 * 1.5;
        }
        let mut x = vec![0.0f32; n * in_dim];
        let mut y = vec![0usize; n];
        for i in 0..n {
            let c = rng.gen_range(classes);
            y[i] = c;
            for d in 0..in_dim {
                x[i * in_dim + d] =
                    centers[c * in_dim + d] + rng.gen_normal() as f32 * 0.8;
            }
        }
        Self { x, y, in_dim, classes }
    }

    /// Two interleaved spirals lifted into `in_dim` dims — *not* linearly
    /// separable; exercises the nonlinear capacity of the MLP so the
    /// convergence experiments aren't trivially easy.
    pub fn two_spirals(in_dim: usize, n: usize, seed: u64) -> Self {
        assert!(in_dim >= 2);
        let mut rng = Pcg32::new(seed);
        let mut x = vec![0.0f32; n * in_dim];
        let mut y = vec![0usize; n];
        for i in 0..n {
            let c = i % 2;
            let t = rng.gen_f64() * 3.0 * std::f64::consts::PI;
            let r = t / (3.0 * std::f64::consts::PI) * 2.0 + 0.1;
            let sign = if c == 0 { 1.0 } else { -1.0 };
            let px = (sign * r * t.cos()) as f32 + rng.gen_normal() as f32 * 0.05;
            let py = (sign * r * t.sin()) as f32 + rng.gen_normal() as f32 * 0.05;
            x[i * in_dim] = px;
            x[i * in_dim + 1] = py;
            // random but fixed linear lift for the remaining dims
            for d in 2..in_dim {
                let a = ((d * 2654435761) % 1000) as f32 / 1000.0 - 0.5;
                let b = ((d * 40503) % 1000) as f32 / 1000.0 - 0.5;
                x[i * in_dim + d] = a * px + b * py;
            }
            y[i] = c;
        }
        Self { x, y, in_dim, classes: 2 }
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Deterministic batch for `(worker_seed, iteration)`-style indexing:
    /// samples `batch` random rows with a PCG stream derived from `tag`.
    pub fn batch(&self, tag: u64, batch: usize) -> (Vec<f32>, Vec<usize>) {
        let mut rng = Pcg32::new(tag.wrapping_mul(0x9E37_79B9).wrapping_add(1));
        let mut x = Vec::with_capacity(batch * self.in_dim);
        let mut y = Vec::with_capacity(batch);
        for _ in 0..batch {
            let i = rng.gen_range(self.len());
            x.extend_from_slice(&self.x[i * self.in_dim..(i + 1) * self.in_dim]);
            y.push(self.y[i]);
        }
        (x, y)
    }

    /// Row indices per class (for non-IID sharding).
    pub fn class_index(&self) -> Vec<Vec<usize>> {
        let mut idx = vec![Vec::new(); self.classes];
        for (i, &c) in self.y.iter().enumerate() {
            idx[c].push(i);
        }
        idx
    }

    /// Non-IID batch: with probability `bias` each sample is drawn from
    /// `primary_class`, else uniformly. Models the skewed per-worker data
    /// shards that make synchronization *matter* — without skew, each
    /// replica converges alone and sync frequency has no observable
    /// statistical effect (see DESIGN.md §Hardware-Adaptation).
    pub fn batch_biased(
        &self,
        tag: u64,
        batch: usize,
        primary_class: usize,
        bias: f64,
        class_index: &[Vec<usize>],
    ) -> (Vec<f32>, Vec<usize>) {
        let mut rng = Pcg32::new(tag.wrapping_mul(0x9E37_79B9).wrapping_add(1));
        let mut x = Vec::with_capacity(batch * self.in_dim);
        let mut y = Vec::with_capacity(batch);
        let primary = &class_index[primary_class % self.classes];
        for _ in 0..batch {
            let i = if !primary.is_empty() && rng.gen_f64() < bias {
                primary[rng.gen_range(primary.len())]
            } else {
                rng.gen_range(self.len())
            };
            x.extend_from_slice(&self.x[i * self.in_dim..(i + 1) * self.in_dim]);
            y.push(self.y[i]);
        }
        (x, y)
    }

    /// [`Dataset::batch`] into caller-provided buffers (cleared first):
    /// the same tag-derived PCG stream, so the filled batch is
    /// element-identical to the allocating form — the loader stage's
    /// recycling path must not change the data (pinned by tests).
    pub fn batch_into(&self, tag: u64, batch: usize, out: &mut LoadedBatch) {
        let mut rng = Pcg32::new(tag.wrapping_mul(0x9E37_79B9).wrapping_add(1));
        out.x.clear();
        out.y.clear();
        out.x.reserve(batch * self.in_dim);
        out.y.reserve(batch);
        for _ in 0..batch {
            let i = rng.gen_range(self.len());
            out.x.extend_from_slice(&self.x[i * self.in_dim..(i + 1) * self.in_dim]);
            out.y.push(self.y[i]);
        }
    }

    /// [`Dataset::batch_biased`] into caller-provided buffers (cleared
    /// first): same RNG stream, element-identical output.
    pub fn batch_biased_into(
        &self,
        tag: u64,
        batch: usize,
        primary_class: usize,
        bias: f64,
        class_index: &[Vec<usize>],
        out: &mut LoadedBatch,
    ) {
        let mut rng = Pcg32::new(tag.wrapping_mul(0x9E37_79B9).wrapping_add(1));
        out.x.clear();
        out.y.clear();
        out.x.reserve(batch * self.in_dim);
        out.y.reserve(batch);
        let primary = &class_index[primary_class % self.classes];
        for _ in 0..batch {
            let i = if !primary.is_empty() && rng.gen_f64() < bias {
                primary[rng.gen_range(primary.len())]
            } else {
                rng.gen_range(self.len())
            };
            out.x.extend_from_slice(&self.x[i * self.in_dim..(i + 1) * self.in_dim]);
            out.y.push(self.y[i]);
        }
    }

    /// The first `k` rows as a fixed evaluation set.
    pub fn eval_set(&self, k: usize) -> (Vec<f32>, Vec<usize>) {
        let k = k.min(self.len());
        (self.x[..k * self.in_dim].to_vec(), self.y[..k].to_vec())
    }
}

/// One mini-batch in recyclable buffers: features row-major
/// `(batch, in_dim)` plus labels. The staged pipeline circulates these
/// between the loader and compute stages instead of allocating per
/// batch (DESIGN.md §Perf, "Staged step pipeline").
#[derive(Debug, Clone, Default)]
pub struct LoadedBatch {
    pub x: Vec<f32>,
    pub y: Vec<usize>,
}

impl LoadedBatch {
    /// An empty batch pre-sized for `batch` rows of `in_dim` features.
    pub fn with_capacity(batch: usize, in_dim: usize) -> Self {
        Self { x: Vec::with_capacity(batch * in_dim), y: Vec::with_capacity(batch) }
    }
}

/// Deterministic batch-producing iterator with a recycling buffer pool:
/// each [`BatchProducer::produce`] fills the next batch of the stream
/// defined by `next_tag` into a recycled [`LoadedBatch`] (or a fresh
/// one when the pool is dry). The tag closure owns the iteration
/// counter, so the produced sequence is exactly the sequence the inline
/// loop would have drawn — queue timing cannot reorder or skip batches.
pub struct BatchProducer {
    ds: std::sync::Arc<Dataset>,
    class_index: std::sync::Arc<Vec<Vec<usize>>>,
    batch: usize,
    primary_class: usize,
    bias: f64,
    next_tag: Box<dyn FnMut() -> u64 + Send>,
    pool: Vec<LoadedBatch>,
}

impl BatchProducer {
    pub fn new(
        ds: std::sync::Arc<Dataset>,
        class_index: std::sync::Arc<Vec<Vec<usize>>>,
        batch: usize,
        primary_class: usize,
        bias: f64,
        next_tag: Box<dyn FnMut() -> u64 + Send>,
    ) -> Self {
        Self { ds, class_index, batch, primary_class, bias, next_tag, pool: Vec::new() }
    }

    /// Return a consumed batch's buffers to the pool for reuse.
    pub fn recycle(&mut self, spent: LoadedBatch) {
        self.pool.push(spent);
    }

    /// Fill and return the next batch of the stream.
    pub fn produce(&mut self) -> LoadedBatch {
        let mut out = self
            .pool
            .pop()
            .unwrap_or_else(|| LoadedBatch::with_capacity(self.batch, self.ds.in_dim));
        let tag = (self.next_tag)();
        self.ds.batch_biased_into(
            tag,
            self.batch,
            self.primary_class,
            self.bias,
            &self.class_index,
            &mut out,
        );
        out
    }
}

impl Iterator for BatchProducer {
    type Item = LoadedBatch;

    /// Infinite: the training loop, not the data, decides when to stop.
    fn next(&mut self) -> Option<LoadedBatch> {
        Some(self.produce())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gaussian_mixture_shapes_and_labels() {
        let ds = Dataset::gaussian_mixture(16, 10, 100, 1);
        assert_eq!(ds.len(), 100);
        assert_eq!(ds.x.len(), 1600);
        assert!(ds.y.iter().all(|&c| c < 10));
    }

    #[test]
    fn two_spirals_balanced() {
        let ds = Dataset::two_spirals(8, 200, 2);
        let ones = ds.y.iter().filter(|&&c| c == 1).count();
        assert_eq!(ones, 100);
        assert_eq!(ds.classes, 2);
    }

    #[test]
    fn batches_deterministic_per_tag() {
        let ds = Dataset::gaussian_mixture(4, 3, 50, 3);
        let (x1, y1) = ds.batch(7, 16);
        let (x2, y2) = ds.batch(7, 16);
        let (x3, _) = ds.batch(8, 16);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
        assert_ne!(x1, x3);
        assert_eq!(x1.len(), 16 * 4);
    }

    #[test]
    fn dataset_deterministic_per_seed() {
        let a = Dataset::gaussian_mixture(4, 3, 20, 5);
        let b = Dataset::gaussian_mixture(4, 3, 20, 5);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn into_variants_match_allocating_forms() {
        let ds = Dataset::gaussian_mixture(4, 3, 50, 3);
        let idx = ds.class_index();
        let mut buf = LoadedBatch::default();
        for tag in [0u64, 7, u64::MAX] {
            ds.batch_into(tag, 16, &mut buf);
            let (x, y) = ds.batch(tag, 16);
            assert_eq!(buf.x, x);
            assert_eq!(buf.y, y);
            // reuse the same buffers (recycling path) for the biased form
            ds.batch_biased_into(tag, 16, 1, 0.7, &idx, &mut buf);
            let (bx, by) = ds.batch_biased(tag, 16, 1, 0.7, &idx);
            assert_eq!(buf.x, bx);
            assert_eq!(buf.y, by);
        }
    }

    #[test]
    fn producer_replays_the_inline_sequence_and_recycles() {
        let ds = std::sync::Arc::new(Dataset::gaussian_mixture(4, 3, 50, 9));
        let idx = std::sync::Arc::new(ds.class_index());
        let seed = 42u64;
        let mut iter = 0u64;
        let mut producer = BatchProducer::new(
            std::sync::Arc::clone(&ds),
            std::sync::Arc::clone(&idx),
            8,
            1,
            0.5,
            Box::new(move || {
                let tag = seed.wrapping_add(iter);
                iter += 1;
                tag
            }),
        );
        // the produced sequence is exactly the inline loop's sequence
        for i in 0..4u64 {
            let got = producer.next().unwrap();
            let (x, y) = ds.batch_biased(seed.wrapping_add(i), 8, 1, 0.5, &idx);
            assert_eq!(got.x, x, "batch {i} diverged from the inline stream");
            assert_eq!(got.y, y);
            let ptr = got.x.as_ptr();
            producer.recycle(got);
            // the pool really recycles: the next fill reuses the buffers
            let again = producer.pool.last().unwrap();
            assert_eq!(again.x.as_ptr(), ptr);
        }
        // a recycled buffer is refilled, not reallocated
        let spent = producer.produce();
        let ptr = spent.x.as_ptr();
        producer.recycle(spent);
        let next = producer.produce();
        assert_eq!(next.x.as_ptr(), ptr, "pool did not recycle the buffer");
    }

    #[test]
    fn eval_set_prefix() {
        let ds = Dataset::gaussian_mixture(4, 3, 50, 5);
        let (x, y) = ds.eval_set(10);
        assert_eq!(x.len(), 40);
        assert_eq!(y.len(), 10);
        assert_eq!(&x[..], &ds.x[..40]);
    }
}
