//! Pure-Rust differentiable MLP over a flat parameter buffer.
//!
//! This is the *real math* substrate for the convergence experiments
//! (Figs. 16–20): the simulator charges virtual time from the cost model,
//! but loss curves come from actual SGD on actual parameters, so the
//! paper's statistical-efficiency claims (iterations-to-converge per
//! algorithm) are reproduced with real dynamics, not a convergence proxy.
//!
//! Layout matches the paper's §6.1 flatten-and-concatenate scheme (and the
//! JAX Layer-2 models): `[w0, b0, w1, b1, ...]` row-major, so P-Reduce is
//! a plain mean over flat vectors.

use crate::util::rng::Pcg32;

/// MLP shape: `in_dim -> hidden... -> classes`, ReLU between layers,
/// softmax cross-entropy loss.
#[derive(Debug, Clone)]
pub struct MlpSpec {
    pub in_dim: usize,
    pub hidden: Vec<usize>,
    pub classes: usize,
}

impl MlpSpec {
    /// The figure-reproduction default (matches python MlpConfig).
    pub fn default_paper() -> Self {
        Self { in_dim: 32, hidden: vec![128, 128], classes: 10 }
    }

    /// A tiny spec for fast unit tests.
    pub fn tiny() -> Self {
        Self { in_dim: 8, hidden: vec![16], classes: 4 }
    }

    pub fn dims(&self) -> Vec<usize> {
        let mut d = vec![self.in_dim];
        d.extend_from_slice(&self.hidden);
        d.push(self.classes);
        d
    }

    pub fn layers(&self) -> usize {
        self.hidden.len() + 1
    }

    pub fn param_count(&self) -> usize {
        let d = self.dims();
        (0..self.layers()).map(|i| d[i] * d[i + 1] + d[i + 1]).sum()
    }

    /// Offset of layer `i`'s weight matrix and bias inside the flat buffer.
    fn offsets(&self) -> Vec<(usize, usize)> {
        let d = self.dims();
        let mut out = Vec::with_capacity(self.layers());
        let mut off = 0;
        for i in 0..self.layers() {
            let w_off = off;
            off += d[i] * d[i + 1];
            let b_off = off;
            off += d[i + 1];
            out.push((w_off, b_off));
        }
        out
    }

    /// He-initialized flat parameter buffer.
    pub fn init(&self, seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::new(seed);
        let mut flat = vec![0.0f32; self.param_count()];
        let d = self.dims();
        for (i, (w_off, _)) in self.offsets().iter().enumerate() {
            let scale = (2.0 / d[i] as f64).sqrt();
            for k in 0..d[i] * d[i + 1] {
                flat[w_off + k] = (rng.gen_normal() * scale) as f32;
            }
        }
        flat
    }
}

/// Scratch buffers reused across iterations (hot-path: no per-step allocs).
#[derive(Debug, Default)]
pub struct MlpScratch {
    acts: Vec<Vec<f32>>,   // activations per layer, batch-major
    grads: Vec<f32>,       // gradient buffer, same size as params
    delta: Vec<f32>,       // backprop delta, reused per layer
    delta_next: Vec<f32>,
}

impl MlpScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// One forward+backward+SGD step over a batch; returns the mean loss.
///
/// `x` is `(batch, in_dim)` row-major, `y` labels in `0..classes`.
pub fn sgd_step(
    spec: &MlpSpec,
    flat: &mut [f32],
    x: &[f32],
    y: &[usize],
    lr: f32,
    scratch: &mut MlpScratch,
) -> f64 {
    let loss = loss_and_grad(spec, flat, x, y, scratch);
    for (p, g) in flat.iter_mut().zip(scratch.grads.iter()) {
        *p -= lr * *g;
    }
    loss
}

/// Mean cross-entropy loss over the batch (no gradient).
pub fn loss_only(spec: &MlpSpec, flat: &[f32], x: &[f32], y: &[usize]) -> f64 {
    let batch = y.len();
    let d = spec.dims();
    let offsets = spec.offsets();
    let mut h: Vec<f32> = x.to_vec();
    let mut h_next: Vec<f32> = Vec::new();
    for (i, &(w_off, b_off)) in offsets.iter().enumerate() {
        let (din, dout) = (d[i], d[i + 1]);
        h_next.clear();
        h_next.resize(batch * dout, 0.0);
        matmul_bias(&h, &flat[w_off..w_off + din * dout], &flat[b_off..b_off + dout], &mut h_next, batch, din, dout);
        if i + 1 < offsets.len() {
            for v in h_next.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
        }
        std::mem::swap(&mut h, &mut h_next);
    }
    mean_xent(&h, y, spec.classes)
}

/// Forward + backward; gradients land in `scratch.grads`; returns mean loss.
pub fn loss_and_grad(
    spec: &MlpSpec,
    flat: &[f32],
    x: &[f32],
    y: &[usize],
    scratch: &mut MlpScratch,
) -> f64 {
    let batch = y.len();
    let d = spec.dims();
    let offsets = spec.offsets();
    let layers = spec.layers();
    assert_eq!(x.len(), batch * spec.in_dim, "x shape mismatch");

    // ---- forward, caching activations
    scratch.acts.resize(layers + 1, Vec::new());
    scratch.acts[0].clear();
    scratch.acts[0].extend_from_slice(x);
    for i in 0..layers {
        let (din, dout) = (d[i], d[i + 1]);
        let (w_off, b_off) = offsets[i];
        // Split-borrow the two activation slots.
        let (lo, hi) = scratch.acts.split_at_mut(i + 1);
        let inp = &lo[i];
        let out = &mut hi[0];
        out.clear();
        out.resize(batch * dout, 0.0);
        matmul_bias(inp, &flat[w_off..w_off + din * dout], &flat[b_off..b_off + dout], out, batch, din, dout);
        if i + 1 < layers {
            for v in out.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
        }
    }

    let logits = &scratch.acts[layers];
    let loss = mean_xent(logits, y, spec.classes);

    // ---- backward
    scratch.grads.clear();
    scratch.grads.resize(flat.len(), 0.0);
    // delta = dL/dlogits = (softmax - onehot) / batch
    scratch.delta.clear();
    scratch.delta.resize(batch * spec.classes, 0.0);
    softmax_minus_onehot(logits, y, spec.classes, &mut scratch.delta);
    let inv_b = 1.0 / batch as f32;
    for v in scratch.delta.iter_mut() {
        *v *= inv_b;
    }

    for i in (0..layers).rev() {
        let (din, dout) = (d[i], d[i + 1]);
        let (w_off, b_off) = offsets[i];
        let inp = &scratch.acts[i];
        // dW = inp^T @ delta ; db = sum_rows(delta)
        {
            let gw = &mut scratch.grads[w_off..w_off + din * dout];
            for b in 0..batch {
                let drow = &scratch.delta[b * dout..(b + 1) * dout];
                let irow = &inp[b * din..(b + 1) * din];
                for (r, &iv) in irow.iter().enumerate() {
                    if iv != 0.0 {
                        let gw_row = &mut gw[r * dout..(r + 1) * dout];
                        for (gwv, &dv) in gw_row.iter_mut().zip(drow.iter()) {
                            *gwv += iv * dv;
                        }
                    }
                }
            }
        }
        {
            let gb = &mut scratch.grads[b_off..b_off + dout];
            for b in 0..batch {
                let drow = &scratch.delta[b * dout..(b + 1) * dout];
                for (gbv, &dv) in gb.iter_mut().zip(drow.iter()) {
                    *gbv += dv;
                }
            }
        }
        if i > 0 {
            // delta_prev = (delta @ W^T) * relu'(act[i])
            let w = &flat[w_off..w_off + din * dout];
            scratch.delta_next.clear();
            scratch.delta_next.resize(batch * din, 0.0);
            for b in 0..batch {
                let drow = &scratch.delta[b * dout..(b + 1) * dout];
                let orow = &mut scratch.delta_next[b * din..(b + 1) * din];
                for (r, ov) in orow.iter_mut().enumerate() {
                    let wrow = &w[r * dout..(r + 1) * dout];
                    let mut acc = 0.0f32;
                    for (wv, dv) in wrow.iter().zip(drow.iter()) {
                        acc += wv * dv;
                    }
                    *ov = acc;
                }
            }
            let act = &scratch.acts[i];
            for (ov, &av) in scratch.delta_next.iter_mut().zip(act.iter()) {
                if av <= 0.0 {
                    *ov = 0.0;
                }
            }
            std::mem::swap(&mut scratch.delta, &mut scratch.delta_next);
        }
    }
    loss
}

/// out[b,:] = inp[b,:] @ W + bias   (W is (din, dout) row-major)
fn matmul_bias(
    inp: &[f32],
    w: &[f32],
    bias: &[f32],
    out: &mut [f32],
    batch: usize,
    din: usize,
    dout: usize,
) {
    for b in 0..batch {
        let orow = &mut out[b * dout..(b + 1) * dout];
        orow.copy_from_slice(bias);
        let irow = &inp[b * din..(b + 1) * din];
        for (r, &iv) in irow.iter().enumerate() {
            if iv != 0.0 {
                let wrow = &w[r * dout..(r + 1) * dout];
                for (ov, &wv) in orow.iter_mut().zip(wrow.iter()) {
                    *ov += iv * wv;
                }
            }
        }
    }
}

fn mean_xent(logits: &[f32], y: &[usize], classes: usize) -> f64 {
    let batch = y.len();
    let mut total = 0.0f64;
    for b in 0..batch {
        let row = &logits[b * classes..(b + 1) * classes];
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let logsum = row.iter().map(|&v| ((v - max) as f64).exp()).sum::<f64>().ln();
        total += logsum + max as f64 - row[y[b]] as f64;
    }
    total / batch as f64
}

fn softmax_minus_onehot(logits: &[f32], y: &[usize], classes: usize, out: &mut [f32]) {
    let batch = y.len();
    for b in 0..batch {
        let row = &logits[b * classes..(b + 1) * classes];
        let orow = &mut out[b * classes..(b + 1) * classes];
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for (o, &v) in orow.iter_mut().zip(row.iter()) {
            *o = (v - max).exp();
            sum += *o;
        }
        for o in orow.iter_mut() {
            *o /= sum;
        }
        orow[y[b]] -= 1.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::data::Dataset;

    #[test]
    fn param_count_formula() {
        let s = MlpSpec::tiny();
        assert_eq!(s.param_count(), 8 * 16 + 16 + 16 * 4 + 4);
    }

    #[test]
    fn init_deterministic() {
        let s = MlpSpec::tiny();
        assert_eq!(s.init(1), s.init(1));
        assert_ne!(s.init(1), s.init(2));
    }

    #[test]
    fn gradcheck_against_finite_differences() {
        let spec = MlpSpec { in_dim: 3, hidden: vec![5], classes: 3 };
        let mut flat = spec.init(7);
        let x: Vec<f32> = (0..6).map(|i| (i as f32) * 0.3 - 0.8).collect();
        let y = vec![0usize, 2];
        let mut scratch = MlpScratch::new();
        loss_and_grad(&spec, &flat, &x, &y, &mut scratch);
        let analytic = scratch.grads.clone();
        let eps = 1e-3f32;
        for idx in [0usize, 3, 10, 20, spec.param_count() - 1] {
            let orig = flat[idx];
            flat[idx] = orig + eps;
            let lp = loss_only(&spec, &flat, &x, &y);
            flat[idx] = orig - eps;
            let lm = loss_only(&spec, &flat, &x, &y);
            flat[idx] = orig;
            let numeric = ((lp - lm) / (2.0 * eps as f64)) as f32;
            assert!(
                (numeric - analytic[idx]).abs() < 2e-3,
                "idx {idx}: numeric {numeric} vs analytic {}",
                analytic[idx]
            );
        }
    }

    #[test]
    fn loss_decreases_under_sgd() {
        let spec = MlpSpec::tiny();
        let mut flat = spec.init(0);
        let ds = Dataset::gaussian_mixture(spec.in_dim, spec.classes, 256, 3);
        let mut scratch = MlpScratch::new();
        let (x, y) = ds.batch(0, 64);
        let first = sgd_step(&spec, &mut flat, &x, &y, 0.1, &mut scratch);
        let mut last = first;
        for _ in 0..60 {
            last = sgd_step(&spec, &mut flat, &x, &y, 0.1, &mut scratch);
        }
        assert!(last < first * 0.6, "loss {first} -> {last} did not decrease");
    }

    #[test]
    fn loss_only_matches_step_loss() {
        let spec = MlpSpec::tiny();
        let mut flat = spec.init(1);
        let ds = Dataset::gaussian_mixture(spec.in_dim, spec.classes, 128, 5);
        let (x, y) = ds.batch(1, 32);
        let mut scratch = MlpScratch::new();
        let l1 = loss_only(&spec, &flat, &x, &y);
        let l2 = sgd_step(&spec, &mut flat, &x, &y, 0.0, &mut scratch);
        assert!((l1 - l2).abs() < 1e-9);
    }

    #[test]
    fn init_loss_near_uniform() {
        let spec = MlpSpec::default_paper();
        let flat = spec.init(3);
        let ds = Dataset::gaussian_mixture(spec.in_dim, spec.classes, 256, 9);
        let (x, y) = ds.batch(0, 128);
        let loss = loss_only(&spec, &flat, &x, &y);
        // He init keeps logit variance bounded; loss should be within a
        // factor ~2 of the uniform-prediction loss ln(classes).
        let uniform = (spec.classes as f64).ln();
        assert!(loss < 2.5 * uniform && loss > 0.3 * uniform, "loss {loss}");
    }
}
