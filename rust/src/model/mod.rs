//! Pure-Rust model substrate: a differentiable MLP over the paper's flat
//! parameter layout plus synthetic datasets. Used by the discrete-event
//! simulator for real-math convergence experiments; the PJRT runtime
//! (`crate::runtime`) executes the JAX/Pallas artifacts instead.

pub mod data;
pub mod mlp;

pub use data::{BatchProducer, Dataset, LoadedBatch};
pub use mlp::{loss_and_grad, loss_only, sgd_step, MlpScratch, MlpSpec};
