//! Artifact sidecar metadata (`*.meta.json`) parsing and validation.

use crate::util::json::{self, Json};
use std::path::Path;

/// Input tensor signature.
#[derive(Debug, Clone, PartialEq)]
pub struct InputSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl InputSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Parsed `<name>.meta.json` sidecar.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub kind: String,
    pub param_count: usize,
    pub inputs: Vec<InputSpec>,
    pub outputs: Vec<String>,
    pub group_size: Option<usize>,
    pub batch: Option<usize>,
    pub seq: Option<usize>,
    pub use_pallas: bool,
}

impl ArtifactMeta {
    pub fn parse(text: &str) -> Result<Self, String> {
        let j = json::parse(text).map_err(|e| e.to_string())?;
        let req_str = |k: &str| -> Result<String, String> {
            j.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| format!("meta missing string field '{k}'"))
        };
        let inputs = j
            .get("inputs")
            .and_then(Json::as_arr)
            .ok_or("meta missing 'inputs'")?
            .iter()
            .map(|inp| {
                let shape = inp
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or("input missing shape")?
                    .iter()
                    .map(|d| d.as_usize().ok_or("bad dim"))
                    .collect::<Result<Vec<_>, _>>()?;
                let dtype = inp
                    .get("dtype")
                    .and_then(Json::as_str)
                    .ok_or("input missing dtype")?
                    .to_string();
                Ok(InputSpec { shape, dtype })
            })
            .collect::<Result<Vec<_>, &str>>()
            .map_err(str::to_string)?;
        let outputs = j
            .get("outputs")
            .and_then(Json::as_arr)
            .map(|a| {
                a.iter()
                    .filter_map(Json::as_str)
                    .map(str::to_string)
                    .collect::<Vec<_>>()
            })
            .unwrap_or_default();
        Ok(Self {
            name: req_str("name")?,
            kind: req_str("kind")?,
            param_count: j
                .get("param_count")
                .and_then(Json::as_usize)
                .ok_or("meta missing 'param_count'")?,
            inputs,
            outputs,
            group_size: j.get("group_size").and_then(Json::as_usize),
            batch: j.get("batch").and_then(Json::as_usize),
            seq: j.get("seq").and_then(Json::as_usize),
            use_pallas: j.get("use_pallas").and_then(Json::as_bool).unwrap_or(false),
        })
    }

    pub fn load(dir: &Path, name: &str) -> Result<Self, String> {
        let path = dir.join(format!("{name}.meta.json"));
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let meta = Self::parse(&text)?;
        if meta.name != name {
            return Err(format!("sidecar name '{}' != requested '{name}'", meta.name));
        }
        Ok(meta)
    }

    /// Validate that caller-provided input lengths match the signature.
    pub fn check_input_lens(&self, lens: &[usize]) -> Result<(), String> {
        if lens.len() != self.inputs.len() {
            return Err(format!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.inputs.len(),
                lens.len()
            ));
        }
        for (i, (spec, &len)) in self.inputs.iter().zip(lens.iter()).enumerate() {
            if spec.element_count() != len {
                return Err(format!(
                    "{}: input {i} expects {} elements ({:?}), got {len}",
                    self.name,
                    spec.element_count(),
                    spec.shape
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "name": "mlp_train_step", "kind": "mlp_train_step",
        "param_count": 22026, "batch": 128, "use_pallas": false,
        "inputs": [
            {"shape": [22026], "dtype": "float32"},
            {"shape": [128, 32], "dtype": "float32"},
            {"shape": [128], "dtype": "int32"},
            {"shape": [], "dtype": "float32"}
        ],
        "outputs": ["new_flat", "loss"]
    }"#;

    #[test]
    fn parses_sample() {
        let m = ArtifactMeta::parse(SAMPLE).unwrap();
        assert_eq!(m.name, "mlp_train_step");
        assert_eq!(m.param_count, 22026);
        assert_eq!(m.inputs.len(), 4);
        assert_eq!(m.inputs[1].shape, vec![128, 32]);
        assert_eq!(m.inputs[1].element_count(), 4096);
        assert_eq!(m.inputs[3].element_count(), 1); // scalar
        assert_eq!(m.outputs, vec!["new_flat", "loss"]);
        assert_eq!(m.batch, Some(128));
        assert!(!m.use_pallas);
        assert_eq!(m.group_size, None);
    }

    #[test]
    fn check_input_lens_catches_mismatch() {
        let m = ArtifactMeta::parse(SAMPLE).unwrap();
        assert!(m.check_input_lens(&[22026, 4096, 128, 1]).is_ok());
        assert!(m.check_input_lens(&[22026, 4096, 128]).is_err());
        assert!(m.check_input_lens(&[22026, 4095, 128, 1]).is_err());
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(ArtifactMeta::parse(r#"{"name": "x"}"#).is_err());
        assert!(ArtifactMeta::parse("not json").is_err());
    }
}
