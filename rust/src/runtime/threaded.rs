//! Thread-per-worker runtime: the *deployable* composition of all three
//! layers — Rust workers coordinate through the Group Generator while
//! model math executes through the PJRT artifacts (JAX Layer-2 graphs
//! containing the Layer-1 Pallas kernels).
//!
//! PJRT wrapper types are `!Send` (raw C++ pointers), so a dedicated
//! engine-server thread owns the `PjrtEngine`; workers talk to it through
//! an mpsc request channel ([`EngineClient`]). On a CPU testbed this also
//! serializes device compute, which is fine — the system property under
//! test is the synchronization structure, and the engine thread plays the
//! role of the (serially-scheduled) accelerator queue.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::cluster::HeterogeneityProfile;
use crate::collectives::pipeline::OverlapConfig;
use crate::gg::{GgConfig, GroupGenerator, GroupId, StaticScheduler};
use crate::step::{self, Bounded, QueueEnd, Stage};
use crate::util::rng::Pcg32;

use super::engine::PjrtEngine;

// ---------------------------------------------------------------------------
// Engine server
// ---------------------------------------------------------------------------

enum Req {
    MlpStep {
        name: String,
        flat: Vec<f32>,
        x: Vec<f32>,
        y: Vec<i32>,
        lr: f32,
        reply: Sender<Result<(Vec<f32>, f32)>>,
    },
    TlmStep {
        name: String,
        flat: Vec<f32>,
        tokens: Vec<i32>,
        lr: f32,
        reply: Sender<Result<(Vec<f32>, f32)>>,
    },
    Preduce {
        name: String,
        stacked: Vec<f32>,
        reply: Sender<Result<Vec<f32>>>,
    },
    Init {
        name: String,
        seed: i32,
        reply: Sender<Result<Vec<f32>>>,
    },
    Available {
        reply: Sender<Vec<String>>,
    },
}

/// Cloneable, `Send` handle to the engine-server thread.
#[derive(Clone)]
pub struct EngineClient {
    tx: Sender<Req>,
}

// Sender<Req> is Send but not Sync; wrap accessors take &self only after
// clone-per-thread, which is how workers use it.

impl EngineClient {
    /// Spawn the engine server over `artifacts_dir`. Fails fast if the
    /// directory is missing.
    pub fn spawn(artifacts_dir: PathBuf) -> Result<(Self, thread::JoinHandle<()>)> {
        let (tx, rx) = channel::<Req>();
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        let handle = thread::spawn(move || {
            let mut engine = match PjrtEngine::new(&artifacts_dir) {
                Ok(e) => {
                    let _ = ready_tx.send(Ok(()));
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e.to_string()));
                    return;
                }
            };
            while let Ok(req) = rx.recv() {
                match req {
                    Req::MlpStep { name, flat, x, y, lr, reply } => {
                        let _ = reply.send(engine.mlp_train_step(&name, &flat, &x, &y, lr));
                    }
                    Req::TlmStep { name, flat, tokens, lr, reply } => {
                        let _ = reply.send(engine.tlm_train_step(&name, &flat, &tokens, lr));
                    }
                    Req::Preduce { name, stacked, reply } => {
                        let _ = reply.send(engine.preduce(&name, &stacked));
                    }
                    Req::Init { name, seed, reply } => {
                        let _ = reply.send(engine.init_model(&name, seed));
                    }
                    Req::Available { reply } => {
                        let _ = reply.send(engine.available());
                    }
                }
            }
        });
        ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during startup"))?
            .map_err(|e| anyhow!(e))?;
        Ok((Self { tx }, handle))
    }

    fn rt<T>(&self, make: impl FnOnce(Sender<T>) -> Req) -> Result<T>
    where
        T: Send + 'static,
    {
        let (reply, rx) = channel();
        self.tx
            .send(make(reply))
            .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine thread dropped reply"))
    }

    pub fn mlp_step(
        &self,
        name: &str,
        flat: Vec<f32>,
        x: Vec<f32>,
        y: Vec<i32>,
        lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        self.rt(|reply| Req::MlpStep { name: name.into(), flat, x, y, lr, reply })?
    }

    pub fn tlm_step(
        &self,
        name: &str,
        flat: Vec<f32>,
        tokens: Vec<i32>,
        lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        self.rt(|reply| Req::TlmStep { name: name.into(), flat, tokens, lr, reply })?
    }

    pub fn preduce(&self, name: &str, stacked: Vec<f32>) -> Result<Vec<f32>> {
        self.rt(|reply| Req::Preduce { name: name.into(), stacked, reply })?
    }

    pub fn init_model(&self, name: &str, seed: i32) -> Result<Vec<f32>> {
        self.rt(|reply| Req::Init { name: name.into(), seed, reply })?
    }

    pub fn available(&self) -> Result<Vec<String>> {
        self.rt(|reply| Req::Available { reply })
    }
}

// ---------------------------------------------------------------------------
// Threaded Ripples cluster
// ---------------------------------------------------------------------------

/// Which scheduler the threaded cluster uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ThreadSched {
    /// Smart GG (Group Buffer semantics are required in threaded mode so
    /// every member's own request resolves to the shared group).
    SmartGg,
    /// Conflict-free static schedule.
    Static,
}

/// What each worker trains per iteration.
#[derive(Debug, Clone)]
pub enum Workload {
    /// MLP classifier on synthetic gaussian-mixture batches
    /// (`mlp_train_step` artifact signature: batch 128, in_dim 32, 10 classes).
    Mlp { batch: usize, in_dim: usize, classes: usize },
    /// Transformer LM on synthetic Markov token streams
    /// (`tlm_train_step` artifact signature).
    Tlm { batch: usize, seq: usize, vocab: usize },
}

/// Configuration for a threaded run.
#[derive(Debug, Clone)]
pub struct ThreadedConfig {
    pub n_nodes: usize,
    pub workers_per_node: usize,
    pub iters: usize,
    pub group_size: usize,
    pub sched: ThreadSched,
    pub lr: f32,
    pub seed: u64,
    pub hetero: HeterogeneityProfile,
    pub workload: Workload,
    /// Artifact names.
    pub step_artifact: String,
    pub init_artifact: String,
    /// Preduce artifact per group size, e.g. `preduce_mlp_g{G}`.
    pub preduce_prefix: String,
    /// Extra per-iteration sleep to emulate device time (0 for tests).
    pub compute_floor: Duration,
    /// Compute/communication overlap: with `max_staleness > 0`, a worker
    /// waiting at its sync point (group pending, partners mid-compute,
    /// or collective executing elsewhere) takes up to that many extra
    /// SGD steps on its own replica instead of blocking — the in-process
    /// analogue of the distributed comm-thread overlap. Serial default
    /// keeps the pre-overlap rendezvous bit-for-bit. Note `shards` is
    /// accepted for config parity but has no effect here: the in-process
    /// collective is one fused mean with no wire pipeline to shard, so
    /// only `max_staleness` changes behaviour in this engine.
    pub overlap: OverlapConfig,
    /// Staged step pipeline (§Perf): batches the per-worker loader
    /// stage keeps synthesized ahead of compute. 0 = inline lockstep
    /// batch synthesis on the worker thread, the pre-pipeline loop.
    pub prefetch: usize,
    /// Emulated per-batch load cost (sleep in the loader stage, or on
    /// the worker thread itself when `prefetch == 0`).
    pub load_floor: Duration,
}

/// Outcome of a threaded run.
#[derive(Debug)]
pub struct ThreadedReport {
    pub wall: Duration,
    pub per_worker_iters: Vec<u64>,
    /// (worker, iter, loss) samples.
    pub losses: Vec<(usize, u64, f32)>,
    pub preduce_count: u64,
    pub final_models: Vec<Vec<f32>>,
    /// Extra SGD steps each worker took on stale weights while waiting
    /// at a sync point (0 everywhere in serial mode).
    pub stale_steps: Vec<u64>,
    /// Wall-clock each worker spent *blocked* in synchronization
    /// (rendezvous wait + collective, minus time covered by stale
    /// compute) — the exposed-sync measurement the overlap reduces.
    pub sync_wait: Vec<Duration>,
    /// Wall-clock each worker's compute stage spent waiting on its
    /// loader stage for the next batch (with `prefetch == 0` this is
    /// the inline synthesis + `load_floor` cost, fully exposed).
    pub load_wait: Vec<Duration>,
    /// Wall-clock each worker's loader stage spent blocked on
    /// backpressure (bounded queue full: compute is the bottleneck).
    /// Always zero when `prefetch == 0`.
    pub compute_wait: Vec<Duration>,
}

#[derive(Default)]
struct GroupRt {
    members: Vec<usize>,
    arrived: usize,
    armed: bool,
    executing: bool,
    done: bool,
}

struct Coord {
    gg: Option<GroupGenerator>,
    groups: HashMap<GroupId, GroupRt>,
    // static-mode rendezvous: (sidx, lead) -> group state id
    static_groups: HashMap<(u64, usize), GroupRt>,
    rng: Pcg32,
    preduce_count: u64,
}

struct Shared {
    coord: Mutex<Coord>,
    cv: Condvar,
    models: Vec<Mutex<Vec<f32>>>,
    engine: EngineClient,
    cfg: ThreadedConfig,
    sched: StaticScheduler,
}

/// Batch generator: synthetic gaussian-mixture classification batches
/// matching the `mlp_train_step` artifact signature.
pub fn synth_batch(
    rng: &mut Pcg32,
    batch: usize,
    in_dim: usize,
    classes: usize,
) -> (Vec<f32>, Vec<i32>) {
    let mut x = Vec::with_capacity(batch * in_dim);
    let mut y = Vec::with_capacity(batch);
    for _ in 0..batch {
        let c = rng.gen_range(classes);
        y.push(c as i32);
        for d in 0..in_dim {
            // class-dependent mean on a few dims
            let mu = if d % classes == c { 1.2 } else { 0.0 };
            x.push(mu + rng.gen_normal() as f32 * 0.7);
        }
    }
    (x, y)
}

/// Synthetic token stream with learnable structure: a noisy +1 Markov
/// chain over the vocabulary (the LM can reach low loss by learning the
/// successor rule, so the e2e loss curve is meaningful).
pub fn synth_tokens(rng: &mut Pcg32, batch: usize, seq: usize, vocab: usize) -> Vec<i32> {
    let mut out = Vec::with_capacity(batch * seq);
    for _ in 0..batch {
        let mut tok = rng.gen_range(vocab);
        out.push(tok as i32);
        for _ in 1..seq {
            tok = if rng.gen_f32() < 0.85 {
                (tok + 1) % vocab
            } else {
                rng.gen_range(vocab)
            };
            out.push(tok as i32);
        }
    }
    out
}

/// One synthesized training batch — the currency between the loader
/// stage and the compute stage of the staged step pipeline.
enum SynthBatch {
    Mlp { x: Vec<f32>, y: Vec<i32> },
    Tlm { tokens: Vec<i32> },
}

fn synth_for(rng: &mut Pcg32, workload: &Workload) -> SynthBatch {
    match *workload {
        Workload::Mlp { batch, in_dim, classes } => {
            let (x, y) = synth_batch(rng, batch, in_dim, classes);
            SynthBatch::Mlp { x, y }
        }
        Workload::Tlm { batch, seq, vocab } => {
            SynthBatch::Tlm { tokens: synth_tokens(rng, batch, seq, vocab) }
        }
    }
}

/// Loader stage of the staged pipeline (`step::Stage`): consumes demand
/// tokens, synthesizes batches on its own thread with its own RNG
/// stream, pays the emulated `load_floor` there — off the worker's
/// critical path.
struct SynthLoader {
    rng: Pcg32,
    workload: Workload,
    load_floor: Duration,
}

impl Stage for SynthLoader {
    type In = ();
    type Out = SynthBatch;

    fn process(&mut self, _token: ()) -> Result<SynthBatch, String> {
        if self.load_floor > Duration::ZERO {
            thread::sleep(self.load_floor);
        }
        Ok(synth_for(&mut self.rng, &self.workload))
    }
}

/// Where the worker's compute stage gets its next batch: synthesized
/// inline (lockstep, `prefetch == 0` — the pre-pipeline loop, same RNG
/// stream) or popped from the loader stage's bounded queue. The token
/// queue (capacity `prefetch + 1`, pre-seeded) is the demand signal:
/// the worker returns a token per batch consumed, so the loader stays
/// exactly `prefetch` batches ahead and blocks when compute falls
/// behind (`compute_wait`).
enum BatchFeed {
    Inline,
    Staged {
        batches: Arc<Bounded<SynthBatch>>,
        tokens: Arc<Bounded<()>>,
        loader: Option<thread::JoinHandle<Result<(), String>>>,
    },
}

impl BatchFeed {
    fn build(w: usize, cfg: &ThreadedConfig) -> BatchFeed {
        if cfg.prefetch == 0 {
            return BatchFeed::Inline;
        }
        let depth = cfg.prefetch;
        let batches = Bounded::new(depth);
        let tokens = Bounded::new(depth + 1);
        for _ in 0..=depth {
            let _ = tokens.push(());
        }
        let stage = SynthLoader {
            // loader-owned stream, disjoint from the worker RNG that
            // keeps driving stale steps and (inline mode) batches
            rng: Pcg32::new(cfg.seed ^ ((w as u64) << 20) ^ 0x10AD),
            workload: cfg.workload.clone(),
            load_floor: cfg.load_floor,
        };
        let loader = step::spawn(stage, Arc::clone(&tokens), Arc::clone(&batches));
        BatchFeed::Staged { batches, tokens, loader: Some(loader) }
    }

    /// Next batch for the compute stage, metering the exposed load wait.
    fn next(
        &mut self,
        rng: &mut Pcg32,
        cfg: &ThreadedConfig,
        load_wait: &mut Duration,
    ) -> Result<SynthBatch> {
        let t = Instant::now();
        let out = match self {
            BatchFeed::Inline => {
                if cfg.load_floor > Duration::ZERO {
                    thread::sleep(cfg.load_floor);
                }
                synth_for(rng, &cfg.workload)
            }
            BatchFeed::Staged { batches, tokens, .. } => match batches.pop() {
                Ok(b) => {
                    let _ = tokens.push(());
                    b
                }
                Err(QueueEnd::Poisoned) => return Err(anyhow!("loader stage poisoned")),
                Err(QueueEnd::Closed) => return Err(anyhow!("loader stage ended early")),
            },
        };
        *load_wait += t.elapsed();
        Ok(out)
    }

    /// Close the queues, join the loader, and report how long it sat
    /// blocked on backpressure (the compute stage was the bottleneck).
    fn shutdown(&mut self) -> Duration {
        match self {
            BatchFeed::Inline => Duration::ZERO,
            BatchFeed::Staged { batches, tokens, loader } => {
                batches.close();
                tokens.close();
                if let Some(h) = loader.take() {
                    let _ = h.join();
                }
                batches.send_wait() + tokens.recv_wait()
            }
        }
    }
}

/// Run a threaded Ripples training session over the PJRT artifacts.
pub fn run_threaded(cfg: ThreadedConfig, engine: EngineClient) -> Result<ThreadedReport> {
    let n = cfg.n_nodes * cfg.workers_per_node;
    let init = engine.init_model(&cfg.init_artifact, cfg.seed as i32)?;
    let gg = match cfg.sched {
        ThreadSched::SmartGg => Some(GroupGenerator::new(GgConfig::smart(
            n,
            cfg.workers_per_node,
            cfg.group_size,
            8,
        ))),
        ThreadSched::Static => None,
    };
    let shared = Arc::new(Shared {
        coord: Mutex::new(Coord {
            gg,
            groups: HashMap::new(),
            static_groups: HashMap::new(),
            rng: Pcg32::new(cfg.seed ^ 0x7EAD),
            preduce_count: 0,
        }),
        cv: Condvar::new(),
        models: (0..n).map(|_| Mutex::new(init.clone())).collect(),
        engine,
        sched: StaticScheduler::new(cfg.n_nodes, cfg.workers_per_node),
        cfg,
    });

    let start = Instant::now();
    let mut handles = Vec::new();
    for w in 0..n {
        let sh = Arc::clone(&shared);
        handles.push(thread::spawn(move || worker_loop(w, sh)));
    }
    let mut losses = Vec::new();
    let mut per_worker_iters = vec![0u64; n];
    let mut stale_steps = vec![0u64; n];
    let mut sync_wait = vec![Duration::ZERO; n];
    let mut load_wait = vec![Duration::ZERO; n];
    let mut compute_wait = vec![Duration::ZERO; n];
    for (w, h) in handles.into_iter().enumerate() {
        let (iters, mut ls, stale, waited, loaded, fed) = h
            .join()
            .map_err(|_| anyhow!("worker {w} panicked"))??;
        per_worker_iters[w] = iters;
        losses.append(&mut ls);
        stale_steps[w] = stale;
        sync_wait[w] = waited;
        load_wait[w] = loaded;
        compute_wait[w] = fed;
    }
    let wall = start.elapsed();
    let coord = shared.coord.lock().unwrap();
    let preduce_count = coord.preduce_count;
    drop(coord);
    let final_models = shared
        .models
        .iter()
        .map(|m| m.lock().unwrap().clone())
        .collect();
    Ok(ThreadedReport {
        wall,
        per_worker_iters,
        losses,
        preduce_count,
        final_models,
        stale_steps,
        sync_wait,
        load_wait,
        compute_wait,
    })
}

type WorkerOut =
    Result<(u64, Vec<(usize, u64, f32)>, u64, Duration, Duration, Duration)>;

fn worker_loop(w: usize, sh: Arc<Shared>) -> WorkerOut {
    // the feed is shut down on *every* exit path — a worker error must
    // close the queues or the loader thread would block on backpressure
    // forever and the final join would hang
    let mut feed = BatchFeed::build(w, &sh.cfg);
    let res = worker_iters(w, &sh, &mut feed);
    let compute_wait = feed.shutdown();
    let (iters, losses, stale, blocked, load_wait) = res?;
    Ok((iters, losses, stale, blocked, load_wait, compute_wait))
}

fn worker_iters(
    w: usize,
    sh: &Arc<Shared>,
    feed: &mut BatchFeed,
) -> Result<(u64, Vec<(usize, u64, f32)>, u64, Duration, Duration)> {
    let cfg = &sh.cfg;
    let mut rng = Pcg32::new(cfg.seed ^ ((w as u64) << 20) ^ 0xBEEF);
    let mut losses = Vec::new();
    let mut stale_total = 0u64;
    let mut stale_time = Duration::ZERO;
    let mut blocked = Duration::ZERO;
    let mut load_wait = Duration::ZERO;
    for it in 0..cfg.iters as u64 {
        // per-iteration: scheduled (SlowdownEvent) speed changes apply
        let slowdown = cfg.hetero.slowdown_at(w, it);
        // ---- load stage: next batch (inline synthesis or prefetched)
        let t0 = Instant::now();
        let batch = feed.next(&mut rng, cfg, &mut load_wait)?;
        // ---- compute stage (PJRT train step through the AOT artifacts)
        let flat = sh.models[w].lock().unwrap().clone();
        let (new_flat, loss) = match batch {
            SynthBatch::Mlp { x, y } => {
                sh.engine.mlp_step(&cfg.step_artifact, flat, x, y, cfg.lr)?
            }
            SynthBatch::Tlm { tokens } => {
                sh.engine.tlm_step(&cfg.step_artifact, flat, tokens, cfg.lr)?
            }
        };
        *sh.models[w].lock().unwrap() = new_flat;
        losses.push((w, it, loss));
        let compute = t0.elapsed() + cfg.compute_floor;
        if slowdown > 1.0 {
            thread::sleep(compute.mul_f64(slowdown - 1.0));
        } else if cfg.compute_floor > Duration::ZERO {
            thread::sleep(cfg.compute_floor);
        }
        // measured step duration (compute + heterogeneity sleep): the
        // GG's speed table input, same as the distributed SpeedReport
        let step_secs = t0.elapsed().as_secs_f64();
        // ---- sync phase (wall time minus stale compute = exposed wait)
        let t_sync = Instant::now();
        match cfg.sched {
            ThreadSched::SmartGg => {
                let stale_before = stale_time;
                sync_gg(
                    w,
                    &sh,
                    step_secs,
                    Some(StaleBudget {
                        rng: &mut rng,
                        iter: it,
                        taken: &mut stale_total,
                        time: &mut stale_time,
                    }),
                )?;
                blocked += t_sync.elapsed().saturating_sub(stale_time - stale_before);
            }
            ThreadSched::Static => {
                sync_static(w, it, &sh)?;
                blocked += t_sync.elapsed();
            }
        }
    }
    // ---- termination protocol (GG mode): retire so no new group drafts
    // us, then drain every group already scheduled in our Group Buffer —
    // otherwise partners would block forever on our membership.
    if cfg.sched == ThreadSched::SmartGg {
        {
            let mut coord = sh.coord.lock().unwrap();
            coord.gg.as_mut().unwrap().retire(w);
        }
        loop {
            let has_pending = {
                let coord = sh.coord.lock().unwrap();
                coord.gg.as_ref().unwrap().gb_front(w).is_some()
            };
            if !has_pending {
                break;
            }
            // drain: no fresh measurement and no stale steps — the
            // iteration budget is spent, only membership must resolve
            sync_gg(w, &sh, 0.0, None)?;
        }
    }
    Ok((cfg.iters as u64, losses, stale_total, blocked, load_wait))
}

/// Permission for [`sync_gg`] to take bounded stale SGD steps while the
/// worker's group waits (the in-process overlap engine; see
/// [`ThreadedConfig::overlap`]).
struct StaleBudget<'a> {
    rng: &'a mut Pcg32,
    /// Enclosing iteration (drives the heterogeneity schedule).
    iter: u64,
    /// Run-total published stale steps (for the report).
    taken: &'a mut u64,
    /// Run-total wall time spent in stale compute (subtracted from the
    /// sync wait: that time was *hidden*, not exposed).
    time: &'a mut Duration,
}

/// One bounded-staleness SGD step taken while `gid` has not started its
/// collective: compute on a clone of this worker's replica, publish only
/// if the group *still* has not started (publishing after the gather
/// would clobber the average). Linearized by the coord lock: the
/// executor flips `executing` under it before gathering. Returns the
/// wall time spent and whether the step was published.
fn stale_step(
    w: usize,
    gid: GroupId,
    sh: &Shared,
    rng: &mut Pcg32,
    iter: u64,
) -> Result<(Duration, bool)> {
    let cfg = &sh.cfg;
    let slowdown = cfg.hetero.slowdown_at(w, iter);
    let t0 = Instant::now();
    let flat = sh.models[w].lock().unwrap().clone();
    let (new_flat, _loss) = match cfg.workload {
        Workload::Mlp { batch, in_dim, classes } => {
            let (x, y) = synth_batch(rng, batch, in_dim, classes);
            sh.engine.mlp_step(&cfg.step_artifact, flat, x, y, cfg.lr)?
        }
        Workload::Tlm { batch, seq, vocab } => {
            let tokens = synth_tokens(rng, batch, seq, vocab);
            sh.engine.tlm_step(&cfg.step_artifact, flat, tokens, cfg.lr)?
        }
    };
    let compute = t0.elapsed() + cfg.compute_floor;
    if slowdown > 1.0 {
        thread::sleep(compute.mul_f64(slowdown - 1.0));
    } else if cfg.compute_floor > Duration::ZERO {
        thread::sleep(cfg.compute_floor);
    }
    let coord = sh.coord.lock().unwrap();
    let safe = coord
        .groups
        .get(&gid)
        .is_some_and(|e| !e.executing && !e.done);
    if safe {
        *sh.models[w].lock().unwrap() = new_flat;
    }
    drop(coord);
    Ok((t0.elapsed(), safe))
}

/// One GG-scheduled sync step (smart GG semantics; see module docs).
/// `step_secs` is the measured duration of the compute phase just
/// finished (0.0 = no measurement, e.g. the termination drain).
/// With `stale` present and `overlap.max_staleness > 0`, waiting turns
/// into bounded stale compute instead of parking on the condvar.
fn sync_gg(w: usize, sh: &Shared, step_secs: f64, mut stale: Option<StaleBudget>) -> Result<()> {
    let mut coord = sh.coord.lock().unwrap();
    let (gid_opt, newly) = {
        let c = &mut *coord;
        let gg = c.gg.as_mut().expect("GG mode without GG");
        gg.observe_speed(w, step_secs); // ignores non-positive samples
        let out = gg.request(w, &mut c.rng);
        // materialize runtime entries for any groups we haven't seen
        let known: Vec<GroupId> = c.groups.keys().copied().collect();
        let live: Vec<(GroupId, Vec<usize>)> = gg
            .live_group_ids()
            .into_iter()
            .filter(|gid| !known.contains(gid))
            .map(|gid| (gid, gg.group(gid).unwrap().members.clone()))
            .collect();
        for (gid, members) in live {
            c.groups.insert(gid, GroupRt { members, ..Default::default() });
        }
        out
    };
    for g in &newly {
        coord.groups.get_mut(&g.id).expect("armed unknown group").armed = true;
    }
    if !newly.is_empty() {
        sh.cv.notify_all(); // wake waiters whose pending groups just armed
    }
    let Some(gid) = gid_opt else {
        return Ok(()); // GG says skip (retired / nobody left to pair with)
    };
    coord.groups.get_mut(&gid).expect("assigned unknown group").arrived += 1;
    let mut stale_this_group = 0u64;
    loop {
        let entry = coord.groups.get(&gid).expect("group vanished");
        if entry.done {
            // last member cleans up
            let remaining = {
                let e = coord.groups.get_mut(&gid).unwrap();
                e.arrived -= 1;
                e.arrived
            };
            if remaining == 0 {
                coord.groups.remove(&gid);
            }
            return Ok(());
        }
        let runnable =
            entry.armed && entry.arrived == entry.members.len() && !entry.executing;
        if runnable {
            coord.groups.get_mut(&gid).unwrap().executing = true;
            let members = coord.groups[&gid].members.clone();
            drop(coord);
            execute_preduce(&members, sh)?;
            coord = sh.coord.lock().unwrap();
            coord.preduce_count += 1;
            {
                let e = coord.groups.get_mut(&gid).unwrap();
                e.done = true;
            }
            let armed_now = {
                let c = &mut *coord;
                c.gg.as_mut().unwrap().complete(gid)
            };
            for g in armed_now {
                if let Some(e) = coord.groups.get_mut(&g.id) {
                    e.armed = true;
                }
            }
            sh.cv.notify_all();
            // fall through to the done branch next loop iteration
        } else if let Some(b) = stale
            .as_mut()
            .filter(|_| stale_this_group < sh.cfg.overlap.max_staleness)
        {
            // overlap: hide the wait behind an extra (stale) SGD step
            // instead of parking — bounded per collective
            drop(coord);
            let (dur, published) = stale_step(w, gid, sh, b.rng, b.iter)?;
            stale_this_group += 1;
            if published {
                *b.taken += 1;
            }
            // the wait was hidden behind compute either way — a step
            // discarded because the gather raced it still wasn't parking
            *b.time += dur;
            coord = sh.coord.lock().unwrap();
        } else {
            coord = sh.cv.wait(coord).unwrap();
        }
    }
}

/// One statically-scheduled sync step.
fn sync_static(w: usize, it: u64, sh: &Shared) -> Result<()> {
    let members = match sh.sched.group_of(w, it) {
        None => return Ok(()),
        Some(m) => m,
    };
    let key = (it, members[0]);
    let mut coord = sh.coord.lock().unwrap();
    let entry = coord
        .static_groups
        .entry(key)
        .or_insert_with(|| GroupRt { members: members.clone(), armed: true, ..Default::default() });
    entry.arrived += 1;
    loop {
        let entry = coord.static_groups.get(&key).expect("static group vanished");
        if entry.done {
            let remaining = {
                let e = coord.static_groups.get_mut(&key).unwrap();
                e.arrived -= 1;
                e.arrived
            };
            if remaining == 0 {
                coord.static_groups.remove(&key);
            }
            return Ok(());
        }
        if entry.arrived == entry.members.len() && !entry.executing {
            coord.static_groups.get_mut(&key).unwrap().executing = true;
            drop(coord);
            execute_preduce(&members, sh)?;
            coord = sh.coord.lock().unwrap();
            coord.preduce_count += 1;
            coord.static_groups.get_mut(&key).unwrap().done = true;
            sh.cv.notify_all();
        } else {
            coord = sh.cv.wait(coord).unwrap();
        }
    }
}

/// Gather group models, run the Layer-1 P-Reduce artifact, scatter back.
/// Falls back to the in-process fused mean when no artifact matches the
/// group size (sizes other than {2,3,4,8} — e.g. intra-node leftovers).
fn execute_preduce(members: &[usize], sh: &Shared) -> Result<()> {
    let n = sh.models[members[0]].lock().unwrap().len();
    let g = members.len();
    let mut stacked = Vec::with_capacity(g * n);
    for &m in members {
        stacked.extend_from_slice(&sh.models[m].lock().unwrap());
    }
    let artifact = format!("{}{}", sh.cfg.preduce_prefix, g);
    let mean = if matches!(g, 2 | 3 | 4 | 8) {
        sh.engine.preduce(&artifact, stacked)?
    } else {
        // in-process fused fallback (identical math; tested against the
        // Pallas kernel via the python test suite)
        let mut acc = vec![0.0f32; n];
        for c in 0..g {
            for (a, &v) in acc.iter_mut().zip(&stacked[c * n..(c + 1) * n]) {
                *a += v;
            }
        }
        let inv = 1.0 / g as f32;
        for a in acc.iter_mut() {
            *a *= inv;
        }
        acc
    };
    for &m in members {
        sh.models[m].lock().unwrap().copy_from_slice(&mean);
    }
    Ok(())
}
