//! API-compatible stand-in for [`PjrtEngine`] when the crate is built
//! without the `pjrt` feature (the default): the XLA bindings and their
//! native extension library are only present on testbeds that ran
//! `make artifacts`, so every other build — the simulator, the GG service,
//! the TCP data plane, CI — compiles against this stub and gets a clear
//! error if it actually tries to execute an artifact.
//!
//! Keep the public surface in sync with `engine.rs`; the e2e tests and
//! examples compile against whichever module the feature selects.

use std::path::Path;

use anyhow::{bail, Result};

use super::artifact::ArtifactMeta;

const NO_PJRT: &str =
    "ripples was built without the `pjrt` feature; rebuild with \
     `cargo build --features pjrt` (requires the XLA extension library) \
     to execute AOT artifacts";

/// Typed input value for an artifact call (mirror of the real engine's).
#[derive(Debug, Clone)]
pub enum Value<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
    ScalarF32(f32),
    ScalarI32(i32),
}

/// A compiled artifact; never constructed by the stub.
pub struct Compiled {
    pub meta: ArtifactMeta,
}

impl Compiled {
    pub fn call(&self, _inputs: &[Value<'_>]) -> Result<Vec<Vec<f32>>> {
        bail!(NO_PJRT);
    }
}

/// Stub engine: constructing it always fails with an actionable message.
pub struct PjrtEngine {
    _private: (),
}

impl PjrtEngine {
    pub fn new(_artifacts_dir: &Path) -> Result<Self> {
        bail!(NO_PJRT);
    }

    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    pub fn load(&mut self, _name: &str) -> Result<&Compiled> {
        bail!(NO_PJRT);
    }

    pub fn available(&self) -> Vec<String> {
        Vec::new()
    }

    pub fn mlp_train_step(
        &mut self,
        _name: &str,
        _flat: &[f32],
        _x: &[f32],
        _y: &[i32],
        _lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        bail!(NO_PJRT);
    }

    pub fn tlm_train_step(
        &mut self,
        _name: &str,
        _flat: &[f32],
        _tokens: &[i32],
        _lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        bail!(NO_PJRT);
    }

    pub fn init_model(&mut self, _name: &str, _seed: i32) -> Result<Vec<f32>> {
        bail!(NO_PJRT);
    }

    pub fn preduce(&mut self, _name: &str, _stacked: &[f32]) -> Result<Vec<f32>> {
        bail!(NO_PJRT);
    }
}
