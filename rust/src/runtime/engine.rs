//! The PJRT execution engine: compile-once, execute-many for the AOT
//! artifacts. One `PjrtLoadedExecutable` per artifact, cached by name.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use super::artifact::ArtifactMeta;

/// Typed input value for an artifact call.
#[derive(Debug, Clone)]
pub enum Value<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
    ScalarF32(f32),
    ScalarI32(i32),
}

impl Value<'_> {
    fn len(&self) -> usize {
        match self {
            Value::F32(v) => v.len(),
            Value::I32(v) => v.len(),
            Value::ScalarF32(_) | Value::ScalarI32(_) => 1,
        }
    }
}

/// A compiled artifact ready to execute.
pub struct Compiled {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl Compiled {
    /// Execute with shape-checked inputs; returns the flattened f32 output
    /// tensors (the tuple elements in order). Loss scalars come back as
    /// single-element vectors.
    pub fn call(&self, inputs: &[Value<'_>]) -> Result<Vec<Vec<f32>>> {
        let lens: Vec<usize> = inputs.iter().map(Value::len).collect();
        self.meta
            .check_input_lens(&lens)
            .map_err(|e| anyhow!("input check: {e}"))?;
        let mut literals = Vec::with_capacity(inputs.len());
        for (value, spec) in inputs.iter().zip(self.meta.inputs.iter()) {
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            let lit = match value {
                Value::F32(v) => xla::Literal::vec1(v).reshape(&dims)?,
                Value::I32(v) => xla::Literal::vec1(v).reshape(&dims)?,
                Value::ScalarF32(v) => xla::Literal::scalar(*v),
                Value::ScalarI32(v) => xla::Literal::scalar(*v),
            };
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: root is always a tuple.
        let elems = result.to_tuple()?;
        let mut out = Vec::with_capacity(elems.len());
        for e in elems {
            out.push(e.to_vec::<f32>()?);
        }
        Ok(out)
    }
}

/// Artifact loader + executable cache over one PJRT CPU client.
pub struct PjrtEngine {
    client: xla::PjRtClient,
    dir: PathBuf,
    compiled: HashMap<String, Compiled>,
}

impl PjrtEngine {
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        if !artifacts_dir.is_dir() {
            bail!(
                "artifacts directory {} not found — run `make artifacts` first",
                artifacts_dir.display()
            );
        }
        let client = xla::PjRtClient::cpu()?;
        Ok(Self { client, dir: artifacts_dir.to_path_buf(), compiled: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) artifact `name`.
    pub fn load(&mut self, name: &str) -> Result<&Compiled> {
        if !self.compiled.contains_key(name) {
            let meta = ArtifactMeta::load(&self.dir, name)
                .map_err(|e| anyhow!("sidecar: {e}"))?;
            let hlo_path = self.dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                hlo_path
                    .to_str()
                    .context("artifact path not valid UTF-8")?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.compiled.insert(name.to_string(), Compiled { meta, exe });
        }
        Ok(&self.compiled[name])
    }

    /// Names of artifacts present on disk (by sidecar).
    pub fn available(&self) -> Vec<String> {
        let mut names: Vec<String> = std::fs::read_dir(&self.dir)
            .map(|rd| {
                rd.filter_map(|e| e.ok())
                    .filter_map(|e| {
                        e.file_name()
                            .to_str()
                            .and_then(|s| s.strip_suffix(".meta.json"))
                            .map(str::to_string)
                    })
                    .collect()
            })
            .unwrap_or_default();
        names.sort();
        names
    }

    // ------------------------------------------------------------------
    // typed convenience wrappers used by the examples / threaded runtime
    // ------------------------------------------------------------------

    /// MLP train step: `(flat, x, y, lr) -> (new_flat, loss)`.
    pub fn mlp_train_step(
        &mut self,
        name: &str,
        flat: &[f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        let c = self.load(name)?;
        let mut out = c.call(&[
            Value::F32(flat),
            Value::F32(x),
            Value::I32(y),
            Value::ScalarF32(lr),
        ])?;
        if out.len() != 2 {
            bail!("{name}: expected 2 outputs, got {}", out.len());
        }
        let loss = out[1][0];
        Ok((std::mem::take(&mut out[0]), loss))
    }

    /// Transformer-LM train step: `(flat, tokens, lr) -> (new_flat, loss)`.
    pub fn tlm_train_step(
        &mut self,
        name: &str,
        flat: &[f32],
        tokens: &[i32],
        lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        let c = self.load(name)?;
        let mut out = c.call(&[
            Value::F32(flat),
            Value::I32(tokens),
            Value::ScalarF32(lr),
        ])?;
        if out.len() != 2 {
            bail!("{name}: expected 2 outputs, got {}", out.len());
        }
        let loss = out[1][0];
        Ok((std::mem::take(&mut out[0]), loss))
    }

    /// Initialize a model from its `*_init` artifact.
    pub fn init_model(&mut self, name: &str, seed: i32) -> Result<Vec<f32>> {
        let c = self.load(name)?;
        let mut out = c.call(&[Value::ScalarI32(seed)])?;
        Ok(std::mem::take(&mut out[0]))
    }

    /// P-Reduce averaging through the Layer-1 Pallas artifact: `stacked`
    /// holds `group_size` concatenated flat models; returns their mean.
    pub fn preduce(&mut self, name: &str, stacked: &[f32]) -> Result<Vec<f32>> {
        let c = self.load(name)?;
        let g = c
            .meta
            .group_size
            .ok_or_else(|| anyhow!("{name} is not a preduce artifact"))?;
        let n = c.meta.param_count;
        if stacked.len() != g * n {
            bail!("{name}: expected {}x{} elements, got {}", g, n, stacked.len());
        }
        let mut out = c.call(&[Value::F32(stacked)])?;
        Ok(std::mem::take(&mut out[0]))
    }
}
