//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! This is the Layer-3 <-> Layer-2 boundary: Python lowered the JAX/Pallas
//! graphs once at build time (`make artifacts`); from here on the training
//! path is pure Rust. Interchange is HLO *text* (not serialized protos) —
//! see `aot.py` and /opt/xla-example/README.md for why.

pub mod artifact;
#[cfg(feature = "pjrt")]
pub mod engine;
#[cfg(not(feature = "pjrt"))]
#[path = "engine_stub.rs"]
pub mod engine;
pub mod threaded;

pub use artifact::ArtifactMeta;
pub use engine::PjrtEngine;

/// Default artifacts directory, overridable via `RIPPLES_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("RIPPLES_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
