//! Interconnect cost model + communicator cache.
//!
//! The simulator charges virtual time for every transfer using the classic
//! alpha-beta model (`latency + bytes / bandwidth`) with two link domains:
//! intra-node (PCIe/QPI) and inter-node (InfiniBand), mirroring the
//! paper's Maverick2 testbed (Fig. 14) and its observation (Fig. 15) that
//! all-reduce cost depends strongly on worker *placement*.

use crate::config::ClusterConfig;
use std::collections::HashMap;

/// Alpha-beta cost model over the two-level topology.
#[derive(Debug, Clone)]
pub struct CostModel {
    pub workers_per_node: usize,
    pub intra_bw: f64,
    pub inter_bw: f64,
    pub intra_lat: f64,
    pub inter_lat: f64,
    pub rpc_rtt: f64,
}

impl CostModel {
    pub fn from_cluster(c: &ClusterConfig) -> Self {
        Self {
            workers_per_node: c.workers_per_node,
            intra_bw: c.link.intra_bw,
            inter_bw: c.link.inter_bw,
            intra_lat: c.link.intra_lat,
            inter_lat: c.link.inter_lat,
            rpc_rtt: c.link.rpc_rtt,
        }
    }

    pub fn node_of(&self, w: usize) -> usize {
        w / self.workers_per_node
    }

    /// Point-to-point transfer time for `bytes` between workers `a` and `b`.
    pub fn p2p(&self, a: usize, b: usize, bytes: usize) -> f64 {
        if a == b {
            return 0.0;
        }
        if self.node_of(a) == self.node_of(b) {
            self.intra_lat + bytes as f64 / self.intra_bw
        } else {
            self.inter_lat + bytes as f64 / self.inter_bw
        }
    }

    /// Ring all-reduce time for a `group` of workers moving `bytes` each.
    ///
    /// Standard chunked schedule: `2(p-1)` steps, each moving `bytes/p`
    /// over every ring edge in parallel, so each step costs the *slowest*
    /// edge (the paper's "bounded by the edge with the slowest connection",
    /// §2.3). The ring is ordered node-major so workers on the same node
    /// are adjacent — the same placement optimization NCCL applies — which
    /// reproduces Fig. 15's dense-vs-sparse placement effect.
    pub fn ring_allreduce(&self, group: &[usize], bytes: usize) -> f64 {
        self.ring_allreduce_throttled(group, bytes, &[])
    }

    /// [`CostModel::ring_allreduce`] with per-worker link throttles:
    /// `bw_divisor[w]` divides worker `w`'s bandwidth (missing entries
    /// and values below 1 count as 1.0 = full speed), and an edge runs
    /// at the slower of its two endpoints' links — the simulator's
    /// bandwidth-heterogeneity model (`cluster::BandwidthEvent`). With
    /// no throttles this is arithmetically identical to the untuned
    /// cost (multiplying the transfer term by exactly 1.0).
    pub fn ring_allreduce_throttled(
        &self,
        group: &[usize],
        bytes: usize,
        bw_divisor: &[f64],
    ) -> f64 {
        let p = group.len();
        if p <= 1 {
            return 0.0;
        }
        let mut ring = group.to_vec();
        ring.sort_unstable(); // node-major adjacency
        let chunk = (bytes as f64 / p as f64).ceil();
        let div = |w: usize| bw_divisor.get(w).copied().unwrap_or(1.0).max(1.0);
        let mut worst = 0.0f64;
        for i in 0..p {
            let a = ring[i];
            let b = ring[(i + 1) % p];
            let slow = div(a).max(div(b));
            let t = if self.node_of(a) == self.node_of(b) {
                self.intra_lat + chunk * slow / self.intra_bw
            } else {
                self.inter_lat + chunk * slow / self.inter_bw
            };
            if t > worst {
                worst = t;
            }
        }
        2.0 * (p - 1) as f64 * worst
    }

    /// Flat ring under *shared-uplink serialization*: each machine owns
    /// one uplink, and every ring edge leaving that machine in a step
    /// queues on it — so a placement-blind ring order that hops machines
    /// on every edge pays `crossings x chunk` per uplink per step, while
    /// a node-major order pays exactly one. This is the cost shape the
    /// classic worst-edge model ([`Self::ring_allreduce_throttled`])
    /// cannot see: there every crossing is "the same slowest edge", here
    /// they *serialize*. `per_machine` ranks share a machine
    /// (`machine = rank / per_machine`); `interleave` picks the ring
    /// order: `false` = node-major (sorted — machine-adjacent, the
    /// bandwidth-ordered degenerate plan), `true` = round-robin across
    /// machines (the placement-blind worst case a speed-sorted order
    /// degenerates to). `bw_divisor` stretches transfers as in
    /// [`Self::ring_allreduce_throttled`].
    pub fn ring_allreduce_uplink(
        &self,
        group: &[usize],
        bytes: usize,
        bw_divisor: &[f64],
        per_machine: usize,
        interleave: bool,
    ) -> f64 {
        let p = group.len();
        if p <= 1 {
            return 0.0;
        }
        let per = per_machine.max(1);
        let mach = |w: usize| w / per;
        let mut ring = group.to_vec();
        ring.sort_unstable(); // node-major adjacency
        if interleave {
            // round-robin over machines: bucket node-major, then deal one
            // rank per machine per round — maximizes boundary crossings
            let mut ids: Vec<usize> = Vec::new();
            let mut buckets: Vec<Vec<usize>> = Vec::new();
            for &w in &ring {
                match ids.iter().position(|&m| m == mach(w)) {
                    Some(i) => buckets[i].push(w),
                    None => {
                        ids.push(mach(w));
                        buckets.push(vec![w]);
                    }
                }
            }
            ring.clear();
            let mut round = 0;
            while ring.len() < p {
                for b in &buckets {
                    if let Some(&w) = b.get(round) {
                        ring.push(w);
                    }
                }
                round += 1;
            }
        }
        let chunk = (bytes as f64 / p as f64).ceil();
        let div = |w: usize| bw_divisor.get(w).copied().unwrap_or(1.0).max(1.0);
        // per machine: the serialized sum of its outbound crossings
        let mut uplink_ids: Vec<usize> = Vec::new();
        let mut uplink_load: Vec<f64> = Vec::new();
        let mut worst_intra = 0.0f64;
        for i in 0..p {
            let a = ring[i];
            let b = ring[(i + 1) % p];
            let slow = div(a).max(div(b));
            if mach(a) == mach(b) {
                let t = self.intra_lat + chunk * slow / self.intra_bw;
                worst_intra = worst_intra.max(t);
            } else {
                let load = chunk * slow / self.inter_bw;
                match uplink_ids.iter().position(|&m| m == mach(a)) {
                    Some(j) => uplink_load[j] += load,
                    None => {
                        uplink_ids.push(mach(a));
                        uplink_load.push(load);
                    }
                }
            }
        }
        let worst_uplink = uplink_load
            .iter()
            .fold(0.0f64, |w, &t| w.max(self.inter_lat + t));
        2.0 * (p - 1) as f64 * worst_intra.max(worst_uplink)
    }

    /// Two-level hierarchical P-Reduce cost (`collectives::hier` over
    /// real sockets, `SyncPlan` multi-node shape): members ship their
    /// buffer to their machine's leader over point-to-point intra links
    /// (parallel across pairs — the slowest pair bounds the phase), the
    /// leaders run a chunked inter-machine ring (exactly one crossing
    /// per uplink per step, chunk `bytes / L`), and the mean fans back
    /// out intra-machine. Total uplink traffic per machine is
    /// `2(L-1)/L x bytes` — independent of how many ranks share the
    /// machine — versus `2(p-1)/p x bytes` *per crossing* for a flat
    /// ring, which is what makes the two-level shape win on a
    /// constrained uplink. Machines and leaders are derived as in
    /// [`Self::ring_allreduce_uplink`] (leader = lowest rank on the
    /// machine: a stand-in for the GG's fastest-measured pick with
    /// identical transfer counts).
    pub fn hierarchical(
        &self,
        group: &[usize],
        bytes: usize,
        bw_divisor: &[f64],
        per_machine: usize,
    ) -> f64 {
        let p = group.len();
        if p <= 1 {
            return 0.0;
        }
        let per = per_machine.max(1);
        let mach = |w: usize| w / per;
        let div = |w: usize| bw_divisor.get(w).copied().unwrap_or(1.0).max(1.0);
        let mut sorted = group.to_vec();
        sorted.sort_unstable();
        let mut nodes: Vec<Vec<usize>> = Vec::new();
        for &w in &sorted {
            match nodes.last_mut() {
                Some(nd) if mach(nd[0]) == mach(w) => nd.push(w),
                _ => nodes.push(vec![w]),
            }
        }
        // intra fan-in (gather) and fan-out (broadcast): full-size
        // transfers on dedicated member<->leader links, slowest pair wins
        let mut intra = 0.0f64;
        for nd in &nodes {
            for &m in &nd[1..] {
                let slow = div(nd[0]).max(div(m));
                intra = intra.max(self.intra_lat + bytes as f64 * slow / self.intra_bw);
            }
        }
        // inter-machine leader ring: every step moves one chunk over each
        // uplink — no serialization by construction
        let l = nodes.len();
        let ring = if l > 1 {
            let chunk = (bytes as f64 / l as f64).ceil();
            let mut worst = 0.0f64;
            for i in 0..l {
                let a = nodes[i][0];
                let b = nodes[(i + 1) % l][0];
                let slow = div(a).max(div(b));
                worst = worst.max(self.inter_lat + chunk * slow / self.inter_bw);
            }
            2.0 * (l - 1) as f64 * worst
        } else {
            0.0
        };
        2.0 * intra + ring
    }

    /// Pairwise model averaging as AD-PSGD implements it over TF remote
    /// variables: the active worker ships its model to the passive one and
    /// receives the averaged model back — two full-model transfers plus a
    /// per-sync software overhead (lock + graph dispatch), which is what
    /// makes AD-PSGD sync-dominated in Fig. 2(b).
    pub fn pairwise_avg(&self, a: usize, b: usize, bytes: usize, overhead: f64) -> f64 {
        self.pairwise_avg_throttled(a, b, bytes, overhead, 1.0)
    }

    /// [`CostModel::pairwise_avg`] with a link throttle: `bw_divisor`
    /// divides the pair's effective bandwidth (an exchange runs at the
    /// slower endpoint's link, so callers pass the max of both workers'
    /// divisors; values below 1 count as 1.0). At 1.0 this is
    /// arithmetically identical to the unthrottled cost.
    pub fn pairwise_avg_throttled(
        &self,
        a: usize,
        b: usize,
        bytes: usize,
        overhead: f64,
        bw_divisor: f64,
    ) -> f64 {
        let d = bw_divisor.max(1.0);
        let xfer = if a == b {
            0.0
        } else if self.node_of(a) == self.node_of(b) {
            self.intra_lat + bytes as f64 * d / self.intra_bw
        } else {
            self.inter_lat + bytes as f64 * d / self.inter_bw
        };
        2.0 * xfer + overhead
    }

    /// One synchronous PS round for `n` workers: all gradients funnel into
    /// the server link (serialized), then the model fans back out.
    pub fn ps_round(&self, n: usize, bytes: usize) -> f64 {
        self.ps_round_sharded(n, bytes, 1, &[])
    }

    /// [`CostModel::ps_round`] generalized to a key-range-sharded server
    /// and per-worker link throttles (the real PS baseline's cost shape).
    ///
    /// With `k` shards the push and pull phases pipeline: a worker pulls
    /// shard `s` while pushing `s+1`, so the two serialized phases
    /// overlap everywhere except the first push and last pull — total
    /// `(1 + 1/k)` of the one-way serialized load instead of `2`.
    /// Each extra shard adds a per-phase latency term. `bw_divisor[w]`
    /// scales worker `w`'s transfer as in
    /// [`CostModel::ring_allreduce_throttled`] (missing entries and
    /// values below 1 count as full speed). With `k = 1` and no
    /// throttles this is arithmetically identical to the classic
    /// two-phase round: every worker's unit factor is exactly 1.0, the
    /// load term sums to `n · bytes / inter_bw`, and `(1 + 1/1) = 2`.
    pub fn ps_round_sharded(
        &self,
        n: usize,
        bytes: usize,
        k: usize,
        bw_divisor: &[f64],
    ) -> f64 {
        let k = k.max(1) as f64;
        let div = |w: usize| bw_divisor.get(w).copied().unwrap_or(1.0).max(1.0);
        // Server sits on node 0; remote workers share the inter-node pipe,
        // each worker's serialized slice stretched by its link throttle.
        let units: f64 = (0..n).map(div).sum();
        let load = units * bytes as f64 / self.inter_bw;
        (1.0 + 1.0 / k) * load + 2.0 * k * self.inter_lat
    }

    /// GG request/notify round trip (small control messages only).
    pub fn gg_rtt(&self) -> f64 {
        self.rpc_rtt
    }

    /// GG round trip under coordinator contention: `outstanding` RPCs
    /// race for the GG while this one is in flight, each costing
    /// `service` seconds of coordinator CPU, spread over `shards`
    /// independently lockable shards (DESIGN.md §Scale). With
    /// `service == 0` (the default) this is *identically* [`Self::gg_rtt`]
    /// — the pre-scale model, bit-for-bit, which is what keeps the
    /// determinism suite byte-stable. `div_ceil` models the residency:
    /// a shard serves its queue serially, and this request waits behind
    /// its share of the outstanding ones.
    pub fn gg_rtt_contended(&self, outstanding: usize, service: f64, shards: usize) -> f64 {
        if service <= 0.0 {
            return self.gg_rtt();
        }
        self.rpc_rtt + outstanding.div_ceil(shards.max(1)) as f64 * service
    }
}

/// Communicator cache, mirroring §6.1: NCCL communicators are expensive to
/// create (and capped at 64), so Ripples caches them per group membership.
/// We model the same: first use of a group pays `create_cost`, subsequent
/// uses are free; the cache stops admitting (but keeps serving misses at
/// full cost) beyond `capacity`.
#[derive(Debug)]
pub struct CommCache {
    capacity: usize,
    create_cost: f64,
    cached: HashMap<Vec<usize>, u64>,
    pub hits: u64,
    pub misses: u64,
}

impl CommCache {
    pub fn new(capacity: usize, create_cost: f64) -> Self {
        Self { capacity, create_cost, cached: HashMap::new(), hits: 0, misses: 0 }
    }

    /// Cost of obtaining a communicator for `group` (sorted internally).
    pub fn acquire(&mut self, group: &[usize]) -> f64 {
        let mut key = group.to_vec();
        key.sort_unstable();
        if let Some(uses) = self.cached.get_mut(&key) {
            *uses += 1;
            self.hits += 1;
            return 0.0;
        }
        self.misses += 1;
        if self.cached.len() < self.capacity {
            self.cached.insert(key, 1);
        }
        self.create_cost
    }

    pub fn len(&self) -> usize {
        self.cached.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cached.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    fn cm() -> CostModel {
        CostModel::from_cluster(&ClusterConfig::default())
    }

    #[test]
    fn contended_gg_rtt_identity_at_zero_service() {
        // service = 0 must be *exactly* gg_rtt, whatever the load — the
        // determinism suite rides on this identity.
        let m = cm();
        for outstanding in [0, 1, 7, 1024] {
            for shards in [1, 16] {
                assert_eq!(m.gg_rtt_contended(outstanding, 0.0, shards), m.gg_rtt());
            }
        }
    }

    #[test]
    fn contended_gg_rtt_grows_with_load_and_shrinks_with_shards() {
        let m = cm();
        let s = 2e-6;
        // monotone in outstanding load
        assert!(m.gg_rtt_contended(64, s, 1) > m.gg_rtt_contended(8, s, 1));
        // sharding divides the queue this request waits behind
        assert!(m.gg_rtt_contended(64, s, 16) < m.gg_rtt_contended(64, s, 1));
        // exact shape: rtt + ceil(outstanding/shards) * service
        assert_eq!(m.gg_rtt_contended(64, s, 16), m.gg_rtt() + 4.0 * s);
        assert_eq!(m.gg_rtt_contended(65, s, 16), m.gg_rtt() + 5.0 * s);
        // degenerate shard count is clamped, not a divide-by-zero
        assert_eq!(m.gg_rtt_contended(8, s, 0), m.gg_rtt() + 8.0 * s);
    }

    #[test]
    fn p2p_intra_cheaper_than_inter() {
        let m = cm();
        let bytes = 1 << 20;
        assert!(m.p2p(0, 1, bytes) < m.p2p(0, 4, bytes));
        assert_eq!(m.p2p(3, 3, bytes), 0.0);
    }

    #[test]
    fn ring_allreduce_zero_for_singleton() {
        let m = cm();
        assert_eq!(m.ring_allreduce(&[3], 1 << 20), 0.0);
        assert_eq!(m.ring_allreduce(&[], 1 << 20), 0.0);
    }

    #[test]
    fn ring_intra_node_faster_than_cross_node() {
        // Fig. 15: all-reduce among workers in one node beats the same
        // group size spread over nodes *with multiple workers per node*.
        let m = cm();
        let bytes = 9 << 20; // ~VGG-16 9.23 MB
        let intra = m.ring_allreduce(&[0, 1, 2, 3], bytes);
        let spread = m.ring_allreduce(&[0, 1, 4, 5], bytes);
        assert!(intra < spread, "{intra} vs {spread}");
    }

    #[test]
    fn ring_grows_with_group_size() {
        let m = cm();
        let bytes = 9 << 20;
        let g8 = m.ring_allreduce(&(0..8).collect::<Vec<_>>(), bytes);
        let g16 = m.ring_allreduce(&(0..16).collect::<Vec<_>>(), bytes);
        assert!(g16 > g8);
    }

    #[test]
    fn ring_beats_ps_at_scale() {
        // The motivation for all-reduce over PS in the paper's §2.2.
        let m = cm();
        let bytes = 9 << 20;
        let group: Vec<usize> = (0..16).collect();
        assert!(m.ring_allreduce(&group, bytes) < m.ps_round(16, bytes));
    }

    #[test]
    fn throttled_ring_scales_with_the_slowest_link() {
        let m = cm();
        let bytes = 9 << 20;
        let group: Vec<usize> = (0..4).collect();
        let base = m.ring_allreduce(&group, bytes);
        // no throttles / explicit 1.0s: bit-identical to the plain cost
        let ones = vec![1.0; 16];
        assert_eq!(m.ring_allreduce_throttled(&group, bytes, &ones), base);
        // one member's slow link throttles the edges touching it, and
        // (in a 4-ring) every step waits on the slowest edge
        let mut div = vec![1.0; 16];
        div[2] = 8.0;
        let throttled = m.ring_allreduce_throttled(&group, bytes, &div);
        assert!(throttled > base * 4.0, "{throttled} vs {base}");
        // sub-1.0 entries must not *speed up* the link
        let wild = vec![0.25; 16];
        assert_eq!(m.ring_allreduce_throttled(&group, bytes, &wild), base);
    }

    /// The `fig topo` anchor scenario: 8 workers on 2 machines of 4, a
    /// 38.72 MB model, 12 GB/s intra links and a constrained 1.5 GB/s
    /// uplink. Closed forms (chunk = bytes/8 = 4.84 MB):
    ///   blind   = 14 x (25us + 4 x chunk/1.5e9)   ~ 0.18104 s
    ///   ordered = 14 x (25us + chunk/1.5e9)       ~ 0.04552 s
    ///   hier    = 2 x (5us + bytes/12e9)
    ///           + 2 x (25us + (bytes/2)/1.5e9)    ~ 0.03233 s
    fn rack2() -> CostModel {
        CostModel {
            workers_per_node: 4,
            intra_bw: 12e9,
            inter_bw: 1.5e9,
            intra_lat: 5e-6,
            inter_lat: 25e-6,
            rpc_rtt: 1e-4,
        }
    }
    const RACK2_BYTES: usize = 38_720_000;

    #[test]
    fn uplink_serialization_separates_blind_from_ordered() {
        let m = rack2();
        let group: Vec<usize> = (0..8).collect();
        let blind = m.ring_allreduce_uplink(&group, RACK2_BYTES, &[], 4, true);
        let ordered = m.ring_allreduce_uplink(&group, RACK2_BYTES, &[], 4, false);
        assert!((blind - 0.181_043_333).abs() < 1e-6, "blind = {blind}");
        assert!((ordered - 0.045_523_333).abs() < 1e-6, "ordered = {ordered}");
        // node-major crosses each uplink once per step: no serialization,
        // so it coincides with the classic worst-edge model here
        let legacy = m.ring_allreduce_throttled(&group, RACK2_BYTES, &[]);
        assert!((ordered - legacy).abs() < 1e-9, "{ordered} vs {legacy}");
    }

    #[test]
    fn hierarchical_beats_both_flat_shapes_on_a_constrained_uplink() {
        let m = rack2();
        let group: Vec<usize> = (0..8).collect();
        let hier = m.hierarchical(&group, RACK2_BYTES, &[], 4);
        assert!((hier - 0.032_326_667).abs() < 1e-6, "hier = {hier}");
        let blind = m.ring_allreduce_uplink(&group, RACK2_BYTES, &[], 4, true);
        let ordered = m.ring_allreduce_uplink(&group, RACK2_BYTES, &[], 4, false);
        assert!(blind >= 2.0 * hier, "need the >=2x headline: {blind} vs {hier}");
        assert!(ordered > hier, "{ordered} vs {hier}");
    }

    #[test]
    fn hierarchical_degenerates_cleanly() {
        let m = rack2();
        // singleton / empty groups cost nothing
        assert_eq!(m.hierarchical(&[3], RACK2_BYTES, &[], 4), 0.0);
        assert_eq!(m.hierarchical(&[], RACK2_BYTES, &[], 4), 0.0);
        // one machine: no leader ring, just gather + broadcast
        let one = m.hierarchical(&[0, 1, 2, 3], RACK2_BYTES, &[], 4);
        let xfer = 5e-6 + RACK2_BYTES as f64 / 12e9;
        assert!((one - 2.0 * xfer).abs() < 1e-9, "one-machine = {one}");
        // one rank per machine: pure leader ring = ordered flat ring
        let spread: Vec<usize> = vec![0, 4, 8, 12];
        let h = m.hierarchical(&spread, RACK2_BYTES, &[], 4);
        let flat = m.ring_allreduce_uplink(&spread, RACK2_BYTES, &[], 4, false);
        assert!((h - flat).abs() < 1e-9, "{h} vs {flat}");
    }

    #[test]
    fn uplink_ring_respects_throttles_and_degenerates() {
        let m = rack2();
        let group: Vec<usize> = (0..8).collect();
        assert_eq!(m.ring_allreduce_uplink(&[5], RACK2_BYTES, &[], 4, true), 0.0);
        // explicit 1.0 divisors are bit-identical to no divisors
        let ones = vec![1.0; 8];
        for interleave in [false, true] {
            assert_eq!(
                m.ring_allreduce_uplink(&group, RACK2_BYTES, &ones, 4, interleave),
                m.ring_allreduce_uplink(&group, RACK2_BYTES, &[], 4, interleave),
            );
        }
        // a throttled member slows its machine's uplink serialization
        let mut div = vec![1.0; 8];
        div[1] = 4.0;
        let base = m.ring_allreduce_uplink(&group, RACK2_BYTES, &[], 4, true);
        let slow = m.ring_allreduce_uplink(&group, RACK2_BYTES, &div, 4, true);
        assert!(slow > base, "{slow} vs {base}");
        // hierarchical: a slow member stretches its intra pair only
        let hb = m.hierarchical(&group, RACK2_BYTES, &[], 4);
        let hs = m.hierarchical(&group, RACK2_BYTES, &div, 4);
        assert!(hs > hb, "{hs} vs {hb}");
    }

    #[test]
    fn pairwise_includes_overhead() {
        let m = cm();
        let t0 = m.pairwise_avg(0, 4, 1 << 20, 0.0);
        let t1 = m.pairwise_avg(0, 4, 1 << 20, 0.5);
        assert!((t1 - t0 - 0.5).abs() < 1e-12);
    }

    #[test]
    fn pairwise_throttle_is_identity_at_full_speed_and_grows() {
        let m = cm();
        let bytes = 9 << 20;
        let base = m.pairwise_avg(0, 4, bytes, 0.25);
        // 1.0 (and sub-1.0) divisors are bit-identical to the plain cost
        assert_eq!(m.pairwise_avg_throttled(0, 4, bytes, 0.25, 1.0), base);
        assert_eq!(m.pairwise_avg_throttled(0, 4, bytes, 0.25, 0.5), base);
        // a throttled endpoint stretches the transfer term only
        let fast = m.pairwise_avg_throttled(0, 4, bytes, 0.0, 1.0);
        let slow = m.pairwise_avg_throttled(0, 4, bytes, 0.0, 8.0);
        assert!(slow > fast * 2.0, "{slow} vs {fast}");
        assert_eq!(m.pairwise_avg_throttled(3, 3, bytes, 0.25, 8.0), 0.25);
    }

    #[test]
    fn ps_round_sharded_reduces_to_the_classic_round() {
        let m = cm();
        let bytes = 9 << 20;
        for n in [1usize, 4, 16] {
            // k = 1, no throttles: bit-identical to the two-phase cost
            assert_eq!(m.ps_round_sharded(n, bytes, 1, &[]), m.ps_round(n, bytes));
            let ones = vec![1.0; n];
            assert_eq!(m.ps_round_sharded(n, bytes, 1, &ones), m.ps_round(n, bytes));
        }
    }

    #[test]
    fn ps_sharding_pipelines_push_and_pull() {
        // At VGG-scale transfers the (1 + 1/k) pipelining beats the extra
        // per-shard latency, and more shards keep helping monotonically.
        let m = cm();
        let bytes = 9 << 20;
        let k1 = m.ps_round_sharded(16, bytes, 1, &[]);
        let k4 = m.ps_round_sharded(16, bytes, 4, &[]);
        let k8 = m.ps_round_sharded(16, bytes, 8, &[]);
        assert!(k4 < k1, "{k4} vs {k1}");
        assert!(k8 < k4, "{k8} vs {k4}");
    }

    #[test]
    fn ps_round_scales_with_throttled_workers() {
        let m = cm();
        let bytes = 9 << 20;
        let base = m.ps_round_sharded(16, bytes, 4, &[]);
        let mut div = vec![1.0; 16];
        div[7] = 16.0;
        let slow = m.ps_round_sharded(16, bytes, 4, &div);
        // one 16x-throttled worker adds 15 extra serialized units on the
        // shared server pipe: the round must get strictly slower
        assert!(slow > base, "{slow} vs {base}");
    }

    #[test]
    fn comm_cache_hits_and_capacity() {
        let mut cache = CommCache::new(2, 1.0);
        assert_eq!(cache.acquire(&[0, 1, 2]), 1.0); // miss, cached
        assert_eq!(cache.acquire(&[2, 1, 0]), 0.0); // same set -> hit
        assert_eq!(cache.acquire(&[3, 4]), 1.0); // miss, cached (full now)
        assert_eq!(cache.acquire(&[5, 6]), 1.0); // miss, NOT cached
        assert_eq!(cache.acquire(&[5, 6]), 1.0); // still a miss
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.hits, 1);
        assert_eq!(cache.misses, 4);
    }
}
