//! Conformance: the model is only trusted because explored traces
//! replay against the *real* coordinator.
//!
//! Three replay targets share every trace:
//!
//! * the single-lock oracle [`GroupGenerator`] (driven with an external
//!   [`Pcg32`], exactly like the simulator engines);
//! * the sharded backend [`ShardedGg`] (same config, same seed — the
//!   two must stay bit-identical, the standing differential invariant
//!   from `prop_gg`);
//! * the RPC dispatch seam [`crate::rpc::ReplayServer`] — decoded
//!   [`Request`]s through the reactor's own `handle_request`, so the
//!   trace also exercises request validation and the plan cache.
//!
//! [`conformance_replay`] is the *strict* mode: it additionally steps
//! the abstract [`Model`] alongside and demands identical assignments,
//! identical newly-armed sets, and an identical state snapshot after
//! every op. Strict mode only accepts configurations in the
//! **membership-deterministic regime** ([`membership_deterministic`]):
//! the model drafts deterministically where the real GG shuffles, so
//! they can only be compared where the shuffle cannot change membership
//! (group size ≥ n, or Global Division with n ≤ 3 and group size 2).
//!
//! [`replay_against_real`] is the *tolerant* mode used by the committed
//! counterexample fixtures (`rust/tests/fixtures/check/`): mutated-model
//! traces replay against the real backends — which do **not** contain
//! the mutation — asserting after every op that the two backends agree
//! exactly and that the real coordinator never reaches the bad state
//! (via [`assert_real_invariants`]).

use crate::gg::{GgConfig, GroupGenerator, GroupId, ShardedGg};
use crate::rpc::{GgMode, ReplayServer, Request, Response, SpeedReport};
use crate::util::rng::Pcg32;

use super::model::{Model, ModelCfg, Mutation, Op};

/// True when the real backends' RNG cannot influence group membership,
/// making the model's deterministic sampling exact (see module docs).
pub fn membership_deterministic(cfg: &ModelCfg) -> bool {
    if cfg.use_global_division {
        cfg.group_size >= cfg.n || (cfg.n <= 3 && cfg.group_size == 2)
    } else {
        cfg.group_size >= cfg.n
    }
}

/// Lower a model configuration onto the real [`GgConfig`] (all
/// heterogeneity filters off — the model has no notion of speed).
pub fn to_gg_config(cfg: &ModelCfg) -> GgConfig {
    let mut g = GgConfig::random(cfg.n, cfg.n, cfg.group_size);
    g.use_group_buffer = cfg.use_group_buffer;
    g.use_global_division = cfg.use_global_division;
    g.rendezvous = cfg.rendezvous;
    g
}

/// Everything observable about a backend's coordination state, in one
/// comparable value.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BackendSnapshot {
    pub locks: Vec<bool>,
    pub gbs: Vec<Vec<GroupId>>,
    pub retired: Vec<bool>,
    pub dead: Vec<bool>,
    /// Live groups sorted by id: `(id, members, armed)`.
    pub live: Vec<(GroupId, Vec<usize>, bool)>,
    pub pending_len: usize,
}

macro_rules! snapshot_impl {
    ($gg:expr, $n:expr) => {{
        let gg = $gg;
        let n = $n;
        let mut ids = gg.live_group_ids();
        ids.sort_unstable();
        BackendSnapshot {
            locks: (0..n).map(|w| gg.is_locked_worker(w)).collect(),
            gbs: (0..n).map(|w| gg.gb_snapshot(w)).collect(),
            retired: (0..n).map(|w| gg.is_retired(w)).collect(),
            dead: (0..n).map(|w| gg.is_dead(w)).collect(),
            live: ids
                .iter()
                .map(|&id| {
                    let members =
                        gg.group(id).map(|g| g.members.clone()).unwrap_or_default();
                    (id, members, gg.is_armed(id))
                })
                .collect(),
            pending_len: gg.pending_len(),
        }
    }};
}

pub fn snapshot_oracle(gg: &GroupGenerator) -> BackendSnapshot {
    snapshot_impl!(gg, gg.config().n_workers)
}

pub fn snapshot_sharded(gg: &ShardedGg) -> BackendSnapshot {
    snapshot_impl!(gg, gg.config().n_workers)
}

/// The coordination invariants, checked on a *real* backend's snapshot
/// (the fixture replays assert the real code never reaches a mutated
/// model's bad state).
pub fn assert_real_invariants(s: &BackendSnapshot) -> Result<(), String> {
    let n = s.locks.len();
    let mut armed_count = vec![0usize; n];
    for (id, members, armed) in &s.live {
        if *armed {
            for &m in members {
                armed_count[m] += 1;
                if armed_count[m] > 1 {
                    return Err(format!("rank {m} in two armed groups (g{id})"));
                }
            }
        }
    }
    for w in 0..n {
        if s.locks[w] != (armed_count[w] == 1) {
            return Err(format!(
                "rank {w}: lock bit {} vs {} armed memberships",
                s.locks[w], armed_count[w]
            ));
        }
    }
    let unarmed = s.live.iter().filter(|(_, _, a)| !a).count();
    if unarmed != s.pending_len {
        return Err(format!(
            "{} unarmed live groups but pending_len {}",
            unarmed, s.pending_len
        ));
    }
    for (id, members, armed) in &s.live {
        if !armed && !members.iter().any(|&m| s.locks[m]) {
            return Err(format!("pending g{id} {members:?} blocked by nobody (lost wakeup)"));
        }
    }
    for w in 0..n {
        let mut prev = 0;
        for &g in &s.gbs[w] {
            if g <= prev {
                return Err(format!("worker {w} GB not strictly increasing at g{g}"));
            }
            prev = g;
            match s.live.iter().find(|(id, _, _)| *id == g) {
                None => return Err(format!("worker {w} GB holds dead id g{g}")),
                Some((_, members, _)) if !members.contains(&w) => {
                    return Err(format!("worker {w} GB holds g{g} which omits it"))
                }
                Some(_) => {}
            }
        }
    }
    for w in 0..n {
        if !s.dead[w] {
            continue;
        }
        if s.locks[w] {
            return Err(format!("dead rank {w} still locked"));
        }
        if !s.gbs[w].is_empty() {
            return Err(format!("dead rank {w} has a non-empty GB"));
        }
        if let Some((id, _, _)) =
            s.live.iter().find(|(_, members, _)| members.contains(&w))
        {
            return Err(format!("dead rank {w} named by live g{id}"));
        }
    }
    Ok(())
}

/// Result of a tolerant fixture replay: the final backends (for
/// test-specific asserts) plus the per-op snapshots.
pub struct RealReplay {
    pub oracle: GroupGenerator,
    pub rng: Pcg32,
    pub sharded: ShardedGg,
    pub snapshots: Vec<BackendSnapshot>,
}

/// Replay `ops` against the real single-lock and sharded backends
/// (same config, same seed), asserting after every op that the two are
/// state-identical and that [`assert_real_invariants`] holds. `Complete`
/// ops whose group is not armed are skipped (mutated-model traces refer
/// to states the real code refuses to enter) — but both backends must
/// agree on the refusal.
pub fn replay_against_real(
    cfg: &ModelCfg,
    seed: u64,
    ops: &[Op],
) -> Result<RealReplay, String> {
    let gcfg = to_gg_config(cfg);
    let mut oracle = GroupGenerator::new(gcfg.clone());
    let mut rng = Pcg32::new(seed);
    let sharded = ShardedGg::new(gcfg, seed);
    let mut snapshots = Vec::new();
    for (i, &op) in ops.iter().enumerate() {
        match op {
            Op::Sync(w) => {
                let (a, armed) = oracle.request(w, &mut rng);
                let (a2, armed2) = sharded.request(w);
                if a != a2 {
                    return Err(format!("op {i} sync({w}): assigned {a:?} vs {a2:?}"));
                }
                let ids: Vec<GroupId> = armed.iter().map(|g| g.id).collect();
                let ids2: Vec<GroupId> = armed2.iter().map(|g| g.id).collect();
                if ids != ids2 {
                    return Err(format!("op {i} sync({w}): armed {ids:?} vs {ids2:?}"));
                }
            }
            Op::Complete(g) => {
                let armed = oracle.is_armed(g);
                if armed != sharded.is_armed(g) {
                    return Err(format!("op {i} complete(g{g}): armed-ness disagrees"));
                }
                if armed {
                    oracle.complete(g);
                    sharded.complete(g);
                }
            }
            Op::Resume(_) => {}
            Op::Die(w) => {
                oracle.declare_dead(w);
                sharded.declare_dead(w);
            }
            Op::Rejoin(w) => {
                oracle.rejoin(w);
                sharded.rejoin(w);
            }
            Op::Abort(g) => {
                oracle.abort_group(g);
                sharded.abort_group(g);
            }
            Op::Retire(w) => {
                oracle.retire(w);
                sharded.retire(w);
            }
        }
        let so = snapshot_oracle(&oracle);
        let ss = snapshot_sharded(&sharded);
        if so != ss {
            return Err(format!(
                "op {i} ({}): oracle and sharded snapshots diverge\n  oracle:  {so:?}\n  sharded: {ss:?}",
                op.render()
            ));
        }
        assert_real_invariants(&so)
            .map_err(|e| format!("op {i} ({}): real invariant: {e}", op.render()))?;
        snapshots.push(so);
    }
    Ok(RealReplay { oracle, rng, sharded, snapshots })
}

/// Strict conformance: step the unmutated model, the oracle, the
/// sharded backend, and the RPC replay seam in lockstep; every
/// assignment, newly-armed set, RPC response, and state snapshot must
/// agree exactly. Only valid in the membership-deterministic regime.
pub fn conformance_replay(cfg: &ModelCfg, seed: u64, ops: &[Op]) -> Result<(), String> {
    assert!(
        membership_deterministic(cfg),
        "strict conformance requires the membership-deterministic regime"
    );
    let gcfg = to_gg_config(cfg);
    let mut model = Model::new(cfg.clone(), Mutation::None);
    let mut oracle = GroupGenerator::new(gcfg.clone());
    let mut rng = Pcg32::new(seed);
    let sharded = ShardedGg::new(gcfg.clone(), seed);
    let rpc = ReplayServer::new(GgMode::Sharded, gcfg, seed);
    for (i, &op) in ops.iter().enumerate() {
        if !model.enabled().contains(&op) {
            return Err(format!("op {i} ({}) not enabled in the model", op.render()));
        }
        let eff = model.step(op);
        match op {
            Op::Sync(w) => {
                let (a, armed) = oracle.request(w, &mut rng);
                let (a2, armed2) = sharded.request(w);
                let ids: Vec<GroupId> = armed.iter().map(|g| g.id).collect();
                let ids2: Vec<GroupId> = armed2.iter().map(|g| g.id).collect();
                let resp = rpc.apply(&Request::Sync {
                    worker: w as u32,
                    speed: SpeedReport::new(0.0),
                });
                let (a3, ids3) = match resp {
                    Some(Response::Assigned { id, armed, .. }) => (
                        (id != 0).then_some(id),
                        armed.iter().map(|g| g.0).collect::<Vec<GroupId>>(),
                    ),
                    other => return Err(format!("op {i} sync({w}): rpc said {other:?}")),
                };
                if eff.assigned != a || a != a2 || a != a3 {
                    return Err(format!(
                        "op {i} sync({w}): assigned model={:?} oracle={a:?} \
                         sharded={a2:?} rpc={a3:?}",
                        eff.assigned
                    ));
                }
                if eff.newly_armed != ids || ids != ids2 || ids != ids3 {
                    return Err(format!(
                        "op {i} sync({w}): armed model={:?} oracle={ids:?} \
                         sharded={ids2:?} rpc={ids3:?}",
                        eff.newly_armed
                    ));
                }
            }
            Op::Complete(g) => {
                let armed = oracle.complete(g);
                let armed2 = sharded.complete(g);
                let ids: Vec<GroupId> = armed.iter().map(|g| g.id).collect();
                let ids2: Vec<GroupId> = armed2.iter().map(|g| g.id).collect();
                let ids3 = match rpc.apply(&Request::Complete { id: g }) {
                    Some(Response::Armed { groups }) => {
                        groups.iter().map(|g| g.0).collect::<Vec<GroupId>>()
                    }
                    other => {
                        return Err(format!("op {i} complete(g{g}): rpc said {other:?}"))
                    }
                };
                if eff.newly_armed != ids || ids != ids2 || ids != ids3 {
                    return Err(format!(
                        "op {i} complete(g{g}): armed model={:?} oracle={ids:?} \
                         sharded={ids2:?} rpc={ids3:?}",
                        eff.newly_armed
                    ));
                }
            }
            Op::Resume(_) => {}
            Op::Die(w) => {
                oracle.declare_dead(w);
                sharded.declare_dead(w);
                rpc.declare_dead(w);
            }
            Op::Rejoin(w) => {
                oracle.rejoin(w);
                sharded.rejoin(w);
                let addr = format!("replay://{w}");
                match rpc.apply(&Request::Rejoin { worker: w as u32, addr }) {
                    Some(Response::Ok) => {}
                    other => {
                        return Err(format!("op {i} rejoin({w}): rpc said {other:?}"))
                    }
                }
            }
            Op::Abort(g) => {
                oracle.abort_group(g);
                sharded.abort_group(g);
                match rpc.apply(&Request::AbortGroup { id: g, suspect: u32::MAX }) {
                    Some(Response::Ok) => {}
                    other => return Err(format!("op {i} abort(g{g}): rpc said {other:?}")),
                }
            }
            Op::Retire(w) => {
                oracle.retire(w);
                sharded.retire(w);
                match rpc.apply(&Request::Retire { worker: w as u32 }) {
                    Some(Response::Ok) => {}
                    other => {
                        return Err(format!("op {i} retire({w}): rpc said {other:?}"))
                    }
                }
            }
        }
        let so = snapshot_oracle(&oracle);
        let ss = snapshot_sharded(&sharded);
        if so != ss {
            return Err(format!(
                "op {i} ({}): oracle vs sharded diverge\n  {so:?}\n  {ss:?}",
                op.render()
            ));
        }
        diff_model(&model, &so).map_err(|e| {
            format!("op {i} ({}): model vs real diverge: {e}", op.render())
        })?;
    }
    Ok(())
}

/// Compare the abstract model's state against a real snapshot.
fn diff_model(model: &Model, s: &BackendSnapshot) -> Result<(), String> {
    let n = model.cfg.n;
    for w in 0..n {
        if model.is_locked(w) != s.locks[w] {
            return Err(format!("rank {w} lock: model {}", model.is_locked(w)));
        }
        if model.gb_snapshot(w) != s.gbs[w] {
            return Err(format!(
                "rank {w} GB: model {:?} real {:?}",
                model.gb_snapshot(w),
                s.gbs[w]
            ));
        }
        if model.is_retired(w) != s.retired[w] {
            return Err(format!("rank {w} retired: model {}", model.is_retired(w)));
        }
        if model.is_dead(w) != s.dead[w] {
            return Err(format!("rank {w} dead: model {}", model.is_dead(w)));
        }
    }
    let live: Vec<(GroupId, Vec<usize>, bool)> = model
        .live_groups()
        .iter()
        .map(|(&id, (members, armed))| (id, members.clone(), *armed))
        .collect();
    if live != s.live {
        return Err(format!("live groups: model {live:?} real {:?}", s.live));
    }
    let pending = live.iter().filter(|(_, _, a)| !a).count();
    if pending != s.pending_len {
        return Err(format!("pending: model {pending} real {}", s.pending_len));
    }
    Ok(())
}

/// Drive the unmutated model with a seeded random walk over its enabled
/// ops and strict-conformance-replay the whole trace. Used by the
/// `check::tests` random-walk suite and the `modelcheck` integration
/// tests.
pub fn random_walk_conformance(
    cfg: &ModelCfg,
    seed: u64,
    steps: usize,
) -> Result<Vec<Op>, String> {
    let mut model = Model::new(cfg.clone(), Mutation::None);
    let mut rng = Pcg32::new(seed ^ 0x9e37_79b9);
    let mut trace = Vec::new();
    for _ in 0..steps {
        let enabled = model.enabled();
        if enabled.is_empty() {
            break;
        }
        let op = enabled[rng.gen_range(enabled.len())];
        model.step(op);
        trace.push(op);
    }
    conformance_replay(cfg, seed, &trace)?;
    Ok(trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check::scenario_cfg;
    use crate::check::Scenario;

    fn walk_many(cfg: &ModelCfg, seeds: u64, steps: usize) {
        for seed in 0..seeds {
            if let Err(e) = random_walk_conformance(cfg, seed, steps) {
                panic!("conformance walk failed (seed {seed}): {e}");
            }
        }
    }

    #[test]
    fn conformance_drafts_regime() {
        // group_size = n random sampling: membership-deterministic.
        let cfg = scenario_cfg(Scenario::Drafts, 3);
        assert!(membership_deterministic(&cfg));
        walk_many(&cfg, 25, 40);
    }

    #[test]
    fn conformance_gd_pair_regime() {
        // n=3, group size 2, GB+GD: the division is forced.
        let cfg = scenario_cfg(Scenario::Faults, 3);
        assert!(membership_deterministic(&cfg));
        walk_many(&cfg, 25, 40);
    }

    #[test]
    fn conformance_rejoin_regime() {
        let cfg = scenario_cfg(Scenario::Rejoin, 3);
        assert!(membership_deterministic(&cfg));
        walk_many(&cfg, 25, 40);
    }

    #[test]
    fn conformance_rendezvous_regime() {
        let cfg = scenario_cfg(Scenario::Rendezvous, 3);
        assert!(membership_deterministic(&cfg));
        walk_many(&cfg, 25, 40);
    }

    #[test]
    fn nondeterministic_regime_is_rejected() {
        // n=4 with group size 2 random sampling: the shuffle matters.
        let mut cfg = scenario_cfg(Scenario::Drafts, 4);
        cfg.group_size = 2;
        assert!(!membership_deterministic(&cfg));
    }

    #[test]
    fn tolerant_replay_reports_backend_agreement() {
        let cfg = scenario_cfg(Scenario::Faults, 3);
        let ops = [Op::Sync(0), Op::Complete(1), Op::Sync(1), Op::Abort(2)];
        let replay = replay_against_real(&cfg, 7, &ops).expect("replay");
        assert_eq!(replay.snapshots.len(), ops.len());
        assert!(replay.oracle.was_aborted(2));
        assert!(replay.sharded.was_aborted(2));
    }
}
