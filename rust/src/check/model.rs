//! The abstracted protocol model the checker explores.
//!
//! [`Model`] is a small-step operational model of the whole coordination
//! system: the Group Generator's observable state (lock vector, pending
//! FIFO, live group table, per-worker Group Buffers, retired/dead flags,
//! bounded aborted set — mirroring `gg/mod.rs` + `gg/lockvec.rs`) *plus*
//! one automaton per participant (worker sync/complete/retire, death,
//! abort, rejoin, Group Buffer hit, rendezvous draft). Every [`Op`] is an
//! atomic transition, exactly as every `GroupGenerator` method runs under
//! one lock hold in both real backends — so interleavings of `Op`s are
//! precisely the schedules the real coordinator can observe.
//!
//! Two deliberate abstractions (see DESIGN.md §Correctness for the full
//! model ↔ implementation mapping):
//!
//! * **Sampling is deterministic.** Where the real GG shuffles
//!   (`vec_partition`) or samples (`random_group`), the model drafts the
//!   lowest-ranked candidates. The conformance replayer
//!   ([`crate::check::conform`]) therefore only drives configurations in
//!   the *membership-deterministic regime* (group size ≥ n, or Global
//!   Division with n ≤ 3 and group size 2), where the real RNG cannot
//!   influence which members a group gets — there the model and both real
//!   backends must agree exactly.
//! * **Budgets bound the run.** Each worker has a finite sync budget and
//!   each fault class a finite count, so the reachable state space is
//!   finite and the explorer can exhaust it.
//!
//! [`Mutation`] re-breaks one transition rule at a time (the PR 7
//! lost-wakeup, the rendezvous double-draft circular wait, completion
//! without the release-then-arm sweep, ...). The checker must catch every
//! mutation — that is the self-test proving the harness has teeth.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::hash::{Hash, Hasher};

use crate::gg::GroupId;

/// A deliberately re-broken transition rule. `Mutation::None` is the
/// faithful model; every other variant must be *caught* by the explorer
/// (`check --mutation <name>` and the `check::tests` self-tests).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Mutation {
    /// Faithful transition rules.
    #[default]
    None,
    /// Completion releases locks but skips the release-then-arm sweep —
    /// the classic lost wakeup: a pending group stays pending although
    /// nothing holds its locks any more.
    SkipArmSweep,
    /// `try_lock` ignores conflicts: a new group arms even when a member
    /// is already locked by another armed group (double grant).
    DoubleGrant,
    /// Completion removes the group but keeps its lock bits set (leaked
    /// locks).
    CompleteKeepsLocks,
    /// Group generation drops the idleness restriction and drafts busy
    /// workers — the rendezvous double-draft race: a fresh group can arm
    /// while a member is stuck at a *pending* front group, a circular
    /// wait (PR 7's threaded-runtime bug class).
    DraftBusy,
    /// Abort tears the group down but does not purge it from member
    /// Group Buffers (dangling GB entries).
    AbortSkipsGbPurge,
    /// A death declaration marks the rank dead but skips the group
    /// teardown and the force-release guard — the dead rank keeps its
    /// locks and stays named by live groups.
    DeathKeepsLocks,
    /// `note_aborted` never prunes: the aborted-id memory grows past
    /// [`crate::gg::ABORTED_SET_CAP`]'s model analogue.
    SkipAbortedPrune,
}

impl Mutation {
    /// Every broken variant (the self-test sweep).
    pub const ALL: [Mutation; 7] = [
        Mutation::SkipArmSweep,
        Mutation::DoubleGrant,
        Mutation::CompleteKeepsLocks,
        Mutation::DraftBusy,
        Mutation::AbortSkipsGbPurge,
        Mutation::DeathKeepsLocks,
        Mutation::SkipAbortedPrune,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Mutation::None => "none",
            Mutation::SkipArmSweep => "skip-arm-sweep",
            Mutation::DoubleGrant => "double-grant",
            Mutation::CompleteKeepsLocks => "complete-keeps-locks",
            Mutation::DraftBusy => "draft-busy",
            Mutation::AbortSkipsGbPurge => "abort-skips-gb-purge",
            Mutation::DeathKeepsLocks => "death-keeps-locks",
            Mutation::SkipAbortedPrune => "skip-aborted-prune",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        let mut all = vec![Mutation::None];
        all.extend(Mutation::ALL);
        all.into_iter().find(|m| m.name() == s)
    }
}

/// How the engine driving the GG behaves — the worker automata differ.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineSemantics {
    /// Simulator semantics (§4.1): an armed group's collective always
    /// runs to completion — members need not rendezvous, conflicts just
    /// queue at the GG.
    Sim,
    /// Collective-rendezvous semantics (threaded/distributed runtimes):
    /// a group completes only once every member has arrived at it — the
    /// semantics under which drafting busy workers deadlocks.
    Rendezvous,
}

/// Model configuration: the GG policy knobs that matter to coordination,
/// plus the exploration budgets.
#[derive(Debug, Clone)]
pub struct ModelCfg {
    pub n: usize,
    pub group_size: usize,
    pub use_group_buffer: bool,
    pub use_global_division: bool,
    /// GG-side idle restriction for freshly sampled random groups
    /// (`GgConfig::rendezvous`).
    pub rendezvous: bool,
    pub engine: EngineSemantics,
    /// Model analogue of [`crate::gg::ABORTED_SET_CAP`], kept small so
    /// boundedness is observable within the depth bound.
    pub aborted_cap: usize,
    /// Per-worker sync budget.
    pub syncs_per_worker: u32,
    pub max_deaths: u32,
    pub max_rejoins: u32,
    pub max_aborts: u32,
    pub max_retires: u32,
}

/// One atomic transition of the system.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Op {
    /// Worker reaches its sync point and calls the GG (`request`).
    Sync(usize),
    /// The leader of an armed group reports its P-Reduce finished.
    Complete(GroupId),
    /// A worker observes that the group it waited on is gone
    /// (completed or aborted) and goes back to computing.
    Resume(usize),
    /// Failure detection declares the rank dead (`declare_dead`) —
    /// also the liveness-accusation path.
    Die(usize),
    /// A checkpoint-restored replacement re-registers the rank.
    Rejoin(usize),
    /// A ring survivor reports the group's collective broke
    /// (`abort_group`).
    Abort(GroupId),
    /// Graceful departure (`retire`).
    Retire(usize),
}

impl Op {
    /// Render as one fixture-file line (see `rust/tests/fixtures/check/`).
    pub fn render(self) -> String {
        match self {
            Op::Sync(w) => format!("sync {w}"),
            Op::Complete(g) => format!("complete {g}"),
            Op::Resume(w) => format!("resume {w}"),
            Op::Die(w) => format!("die {w}"),
            Op::Rejoin(w) => format!("rejoin {w}"),
            Op::Abort(g) => format!("abort {g}"),
            Op::Retire(w) => format!("retire {w}"),
        }
    }

    /// Parse one fixture-file line (inverse of [`Op::render`]).
    pub fn parse(line: &str) -> Option<Self> {
        let (kind, arg) = line.trim().split_once(' ')?;
        let arg = arg.trim();
        Some(match kind {
            "sync" => Op::Sync(arg.parse().ok()?),
            "complete" => Op::Complete(arg.parse().ok()?),
            "resume" => Op::Resume(arg.parse().ok()?),
            "die" => Op::Die(arg.parse().ok()?),
            "rejoin" => Op::Rejoin(arg.parse().ok()?),
            "abort" => Op::Abort(arg.parse().ok()?),
            "retire" => Op::Retire(arg.parse().ok()?),
            _ => return None,
        })
    }
}

/// Where a worker automaton stands.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WorkerPhase {
    /// Computing locally; may sync while budget remains.
    Idle,
    /// Synced and waiting on its assigned group.
    Waiting(GroupId),
}

/// What one [`Op`] did — the conformance replayer diffs this against the
/// real backends' return values.
#[derive(Debug, Clone, Default)]
pub struct StepEffect {
    /// Group assigned to the syncing worker (Sync only).
    pub assigned: Option<GroupId>,
    /// Groups that acquired their locks as a result of this op.
    pub newly_armed: Vec<GroupId>,
}

/// An invariant violation: which invariant, and a human-readable detail.
#[derive(Debug, Clone)]
pub struct Violation {
    pub invariant: &'static str,
    pub detail: String,
}

/// The full system state (coordinator + worker automata + budgets).
#[derive(Debug, Clone)]
pub struct Model {
    pub cfg: ModelCfg,
    pub mutation: Mutation,
    locks: Vec<bool>,
    pending: VecDeque<GroupId>,
    /// id -> (sorted members, armed)
    groups: BTreeMap<GroupId, (Vec<usize>, bool)>,
    gb: Vec<VecDeque<GroupId>>,
    retired: Vec<bool>,
    dead: Vec<bool>,
    aborted: BTreeSet<GroupId>,
    next_id: GroupId,
    phase: Vec<WorkerPhase>,
    syncs_left: Vec<u32>,
    deaths_left: u32,
    rejoins_left: u32,
    aborts_left: u32,
    retires_left: u32,
}

impl Hash for Model {
    fn hash<H: Hasher>(&self, h: &mut H) {
        // cfg and mutation are constant across a run: not hashed.
        self.locks.hash(h);
        self.pending.hash(h);
        self.groups.hash(h);
        self.gb.hash(h);
        self.retired.hash(h);
        self.dead.hash(h);
        self.aborted.hash(h);
        self.next_id.hash(h);
        self.phase.hash(h);
        self.syncs_left.hash(h);
        self.deaths_left.hash(h);
        self.rejoins_left.hash(h);
        self.aborts_left.hash(h);
        self.retires_left.hash(h);
    }
}

impl Model {
    pub fn new(cfg: ModelCfg, mutation: Mutation) -> Self {
        assert!(cfg.group_size >= 2 && cfg.group_size <= cfg.n);
        let n = cfg.n;
        let syncs = cfg.syncs_per_worker;
        Self {
            mutation,
            locks: vec![false; n],
            pending: VecDeque::new(),
            groups: BTreeMap::new(),
            gb: vec![VecDeque::new(); n],
            retired: vec![false; n],
            dead: vec![false; n],
            aborted: BTreeSet::new(),
            next_id: 1,
            phase: vec![WorkerPhase::Idle; n],
            syncs_left: vec![syncs; n],
            deaths_left: cfg.max_deaths,
            rejoins_left: cfg.max_rejoins,
            aborts_left: cfg.max_aborts,
            retires_left: cfg.max_retires,
            cfg,
        }
    }

    /// Deterministic 64-bit canonical-state hash (std `DefaultHasher`
    /// with its fixed keys — stable across runs, unlike `RandomState`).
    pub fn state_hash(&self) -> u64 {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        self.hash(&mut h);
        h.finish()
    }

    pub fn phase_of(&self, w: usize) -> WorkerPhase {
        self.phase[w]
    }

    pub fn live_groups(&self) -> &BTreeMap<GroupId, (Vec<usize>, bool)> {
        &self.groups
    }

    pub fn gb_snapshot(&self, w: usize) -> Vec<GroupId> {
        self.gb[w].iter().copied().collect()
    }

    pub fn is_locked(&self, w: usize) -> bool {
        self.locks[w]
    }

    pub fn is_retired(&self, w: usize) -> bool {
        self.retired[w]
    }

    pub fn is_dead(&self, w: usize) -> bool {
        self.dead[w]
    }

    pub fn was_aborted(&self, id: GroupId) -> bool {
        self.aborted.contains(&id)
    }

    /// A live (non-dead) worker still waiting on a group.
    pub fn any_live_waiting(&self) -> bool {
        (0..self.cfg.n)
            .any(|w| !self.dead[w] && matches!(self.phase[w], WorkerPhase::Waiting(_)))
    }

    // ------------------------------------------------------------------
    // enabled transitions
    // ------------------------------------------------------------------

    /// All transitions enabled in this state, in a deterministic order.
    pub fn enabled(&self) -> Vec<Op> {
        let mut ops = Vec::new();
        for w in 0..self.cfg.n {
            if self.dead[w] {
                continue;
            }
            match self.phase[w] {
                WorkerPhase::Idle => {
                    if self.syncs_left[w] > 0 {
                        ops.push(Op::Sync(w));
                    }
                }
                WorkerPhase::Waiting(g) => {
                    if !self.groups.contains_key(&g) {
                        ops.push(Op::Resume(w));
                    }
                }
            }
        }
        for (&g, (members, armed)) in &self.groups {
            if *armed {
                let can = match self.cfg.engine {
                    EngineSemantics::Sim => true,
                    EngineSemantics::Rendezvous => members.iter().all(|&m| {
                        self.dead[m] || self.phase[m] == WorkerPhase::Waiting(g)
                    }),
                };
                if can {
                    ops.push(Op::Complete(g));
                }
            }
            if self.aborts_left > 0 {
                ops.push(Op::Abort(g));
            }
        }
        for w in 0..self.cfg.n {
            if self.deaths_left > 0 && !self.dead[w] {
                ops.push(Op::Die(w));
            }
            if self.rejoins_left > 0 && self.dead[w] {
                ops.push(Op::Rejoin(w));
            }
            if self.retires_left > 0 && !self.retired[w] && !self.dead[w] {
                ops.push(Op::Retire(w));
            }
        }
        ops
    }

    /// Successor state under `op` (must be enabled).
    pub fn child(&self, op: Op) -> Model {
        let mut m = self.clone();
        m.step(op);
        m
    }

    // ------------------------------------------------------------------
    // transition effects (each mirrors one GroupGenerator entry point)
    // ------------------------------------------------------------------

    /// Apply one enabled transition in place; returns what it did.
    pub fn step(&mut self, op: Op) -> StepEffect {
        match op {
            Op::Sync(w) => self.step_sync(w),
            Op::Complete(g) => self.step_complete(g),
            Op::Resume(w) => {
                self.phase[w] = WorkerPhase::Idle;
                StepEffect::default()
            }
            Op::Die(w) => {
                self.deaths_left -= 1;
                self.declare_dead_inner(w)
            }
            Op::Rejoin(w) => {
                self.rejoins_left -= 1;
                let eff = self.declare_dead_inner(w);
                self.dead[w] = false;
                self.retired[w] = false;
                eff
            }
            Op::Abort(g) => {
                self.aborts_left -= 1;
                let (members, was_armed) = self.teardown(g);
                let newly_armed =
                    if was_armed { self.arm_unblocked(&members) } else { Vec::new() };
                StepEffect { assigned: None, newly_armed }
            }
            Op::Retire(w) => {
                self.retires_left -= 1;
                self.retired[w] = true;
                StepEffect::default()
            }
        }
    }

    /// Mirrors `GroupGenerator::request` (GB hit first, retired skip,
    /// then division / random sampling, then group creation).
    fn step_sync(&mut self, w: usize) -> StepEffect {
        self.syncs_left[w] -= 1;
        if self.cfg.use_group_buffer {
            if let Some(&front) = self.gb[w].front() {
                self.phase[w] = WorkerPhase::Waiting(front);
                return StepEffect { assigned: Some(front), newly_armed: Vec::new() };
            }
        }
        if self.retired[w] {
            return StepEffect::default(); // drained and departed: skip
        }
        let member_lists = if self.cfg.use_global_division {
            self.division(w)
        } else {
            match self.random_group(w) {
                Some(g) => vec![g],
                None => Vec::new(),
            }
        };
        let mut eff = StepEffect::default();
        for members in member_lists {
            let contains_w = members.contains(&w);
            let (id, armed) = self.create_group(members);
            if armed {
                eff.newly_armed.push(id);
            }
            if contains_w && eff.assigned.is_none() {
                eff.assigned = Some(id);
            }
        }
        if let Some(id) = eff.assigned {
            self.phase[w] = WorkerPhase::Waiting(id);
        }
        eff
    }

    /// Mirrors `global_division` with the sampling abstracted to a
    /// deterministic chunking of the sorted idle list (`vec_partition`
    /// without the shuffle — identical membership in the
    /// membership-deterministic regime the conformance replayer uses).
    fn division(&self, w: usize) -> Vec<Vec<usize>> {
        let idle: Vec<usize> = (0..self.cfg.n)
            .filter(|&x| {
                if x == w {
                    return true;
                }
                if self.retired[x] {
                    return false;
                }
                if self.mutation == Mutation::DraftBusy {
                    return true; // broken rule: idleness ignored
                }
                let buffer_free = !self.cfg.use_group_buffer || self.gb[x].is_empty();
                buffer_free && !self.locks[x]
            })
            .collect();
        if idle.len() < 2 {
            return Vec::new(); // nobody idle to pair with: skip
        }
        let k = self.cfg.group_size;
        let mut out: Vec<Vec<usize>> = idle.chunks(k).map(<[usize]>::to_vec).collect();
        if out.len() >= 2 && out.last().is_some_and(|g| g.len() == 1) {
            let last = out.pop().unwrap_or_default();
            if let Some(prev) = out.last_mut() {
                prev.extend(last);
            }
        }
        out.retain(|g| g.len() >= 2);
        out
    }

    /// Mirrors `random_group` with the partial shuffle abstracted to
    /// "draft the lowest-ranked candidates".
    fn random_group(&self, w: usize) -> Option<Vec<usize>> {
        let others: Vec<usize> = (0..self.cfg.n)
            .filter(|&x| {
                x != w
                    && !self.retired[x]
                    && (!self.cfg.rendezvous
                        || self.mutation == Mutation::DraftBusy
                        || (self.gb[x].is_empty() && !self.locks[x]))
            })
            .collect();
        if others.is_empty() {
            return None;
        }
        let k = self.cfg.group_size.min(others.len() + 1);
        let mut members = vec![w];
        members.extend(others.into_iter().take(k - 1));
        Some(members)
    }

    /// Mirrors `create_group`: sorted members, GB push, try_lock else
    /// pend. Returns `(id, armed)`.
    fn create_group(&mut self, mut members: Vec<usize>) -> (GroupId, bool) {
        members.sort_unstable();
        members.dedup();
        let id = self.next_id;
        self.next_id += 1;
        if self.cfg.use_group_buffer {
            for &m in &members {
                self.gb[m].push_back(id);
            }
        }
        let conflict = members.iter().any(|&m| self.locks[m]);
        let armed = !conflict || self.mutation == Mutation::DoubleGrant;
        if armed {
            for &m in &members {
                self.locks[m] = true;
            }
        } else {
            self.pending.push_back(id);
        }
        self.groups.insert(id, (members, armed));
        (id, armed)
    }

    /// Mirrors `GroupGenerator::complete` (release, GB pop-front-else-
    /// purge, release-then-arm sweep).
    fn step_complete(&mut self, g: GroupId) -> StepEffect {
        let Some((members, _)) = self.groups.remove(&g) else {
            return StepEffect::default(); // idempotent on unknown ids
        };
        if self.mutation != Mutation::CompleteKeepsLocks {
            for &m in &members {
                self.locks[m] = false;
            }
        }
        if self.cfg.use_group_buffer {
            for &m in &members {
                if self.gb[m].front() == Some(&g) {
                    self.gb[m].pop_front();
                } else {
                    self.gb[m].retain(|&x| x != g);
                }
            }
        }
        let newly_armed = if self.mutation == Mutation::SkipArmSweep {
            Vec::new() // broken rule: the lost wakeup
        } else {
            self.arm_unblocked(&members)
        };
        StepEffect { assigned: None, newly_armed }
    }

    /// Mirrors `arm_unblocked`: FIFO sweep with the touched-set skip.
    fn arm_unblocked(&mut self, released: &[usize]) -> Vec<GroupId> {
        let mut armed = Vec::new();
        let mut still = VecDeque::new();
        while let Some(pid) = self.pending.pop_front() {
            let members = match self.groups.get(&pid) {
                Some((m, _)) => m.clone(),
                None => continue,
            };
            let touched = members.iter().any(|m| released.contains(m));
            let free = !members.iter().any(|&m| self.locks[m]);
            if touched && free {
                for &m in &members {
                    self.locks[m] = true;
                }
                if let Some(e) = self.groups.get_mut(&pid) {
                    e.1 = true;
                }
                armed.push(pid);
            } else {
                still.push_back(pid);
            }
        }
        self.pending = still;
        armed
    }

    /// Mirrors `teardown_group`: note aborted, GB purge, pending-drop or
    /// lock release. Returns `(members, was_armed)`.
    fn teardown(&mut self, g: GroupId) -> (Vec<usize>, bool) {
        let Some((members, armed)) = self.groups.remove(&g) else {
            return (Vec::new(), false);
        };
        self.note_aborted(g);
        if self.cfg.use_group_buffer && self.mutation != Mutation::AbortSkipsGbPurge {
            for &m in &members {
                self.gb[m].retain(|&x| x != g);
            }
        }
        if !armed {
            self.pending.retain(|&p| p != g);
            return (members, false); // pending groups hold no locks
        }
        for &m in &members {
            self.locks[m] = false;
        }
        (members, true)
    }

    /// Mirrors `note_aborted`'s bounded memory.
    fn note_aborted(&mut self, g: GroupId) {
        self.aborted.insert(g);
        if self.mutation == Mutation::SkipAbortedPrune {
            return; // broken rule: unbounded growth
        }
        if self.aborted.len() > self.cfg.aborted_cap {
            let min_keep = self.next_id.saturating_sub(self.cfg.aborted_cap as u64);
            self.aborted.retain(|&x| x >= min_keep);
        }
    }

    /// Mirrors `declare_dead`: flags, GB clear, batched teardown of every
    /// group naming the rank, ONE arm sweep, then the force-release
    /// guard.
    fn declare_dead_inner(&mut self, w: usize) -> StepEffect {
        if self.dead[w] {
            return StepEffect::default(); // idempotent
        }
        self.dead[w] = true;
        self.retired[w] = true;
        self.phase[w] = WorkerPhase::Idle; // its process is gone
        self.gb[w].clear();
        if self.mutation == Mutation::DeathKeepsLocks {
            return StepEffect::default(); // broken rule: no purge at all
        }
        let doomed: Vec<GroupId> = self
            .groups
            .iter()
            .filter(|(_, (m, _))| m.contains(&w))
            .map(|(&id, _)| id)
            .collect(); // BTreeMap: already sorted (deterministic teardown order)
        let mut released: Vec<usize> = Vec::new();
        for id in doomed {
            let (members, was_armed) = self.teardown(id);
            if was_armed {
                released.extend(members);
            }
        }
        let newly_armed =
            if released.is_empty() { Vec::new() } else { self.arm_unblocked(&released) };
        self.locks[w] = false; // force_release (a no-op when invariants hold)
        StepEffect { assigned: None, newly_armed }
    }

    // ------------------------------------------------------------------
    // invariants
    // ------------------------------------------------------------------

    /// Check every state invariant; `Err` carries which one broke.
    ///
    /// The invariants (DESIGN.md §Correctness):
    /// 1. no double grant — each rank is a member of at most one armed
    ///    group;
    /// 2. lock-bit consistency — a rank's lock bit is set iff an armed
    ///    group names it (leaked locks show up here);
    /// 3. no lost wakeup — every pending group conflicts with some armed
    ///    group (a pending group whose locks are all free was forgotten
    ///    by an arm sweep and will never arm);
    /// 4. GB sanity — per-worker Group Buffer ids are strictly
    ///    increasing, live, and name the worker;
    /// 5. death hygiene — a dead rank holds no lock, has an empty GB,
    ///    and is named by no live group;
    /// 6. aborted-set boundedness — the remembered aborted ids never
    ///    exceed the cap;
    /// 7. no circular wait (rendezvous engines) — the wait-for graph
    ///    over groups (armed group -> a member's GB-front group; pending
    ///    group -> armed lock holders) is acyclic.
    pub fn check_invariants(&self) -> Result<(), Violation> {
        let n = self.cfg.n;
        // 1 + 2: armed-membership counts vs lock bits
        let mut armed_count = vec![0usize; n];
        for (id, (members, armed)) in &self.groups {
            if *armed {
                for &m in members {
                    armed_count[m] += 1;
                    if armed_count[m] > 1 {
                        return Err(Violation {
                            invariant: "no-double-grant",
                            detail: format!(
                                "rank {m} is a member of two armed groups (second: g{id})"
                            ),
                        });
                    }
                }
            }
        }
        for w in 0..n {
            if self.locks[w] != (armed_count[w] == 1) {
                return Err(Violation {
                    invariant: "lock-consistency",
                    detail: format!(
                        "rank {w}: lock bit {} but {} armed memberships",
                        self.locks[w], armed_count[w]
                    ),
                });
            }
        }
        // 3: pending groups must be blocked by someone
        for &pid in &self.pending {
            let Some((members, armed)) = self.groups.get(&pid) else {
                return Err(Violation {
                    invariant: "pending-live",
                    detail: format!("pending id g{pid} is not a live group"),
                });
            };
            if *armed {
                return Err(Violation {
                    invariant: "pending-live",
                    detail: format!("pending id g{pid} is marked armed"),
                });
            }
            if !members.iter().any(|&m| self.locks[m]) {
                return Err(Violation {
                    invariant: "no-lost-wakeup",
                    detail: format!(
                        "pending g{pid} {members:?} holds no conflict — it was \
                         never armed by a release-then-arm sweep"
                    ),
                });
            }
        }
        // every live !armed group must be queued
        for (id, (_, armed)) in &self.groups {
            if !*armed && !self.pending.contains(id) {
                return Err(Violation {
                    invariant: "pending-live",
                    detail: format!("unarmed live g{id} missing from the pending queue"),
                });
            }
        }
        // 4: GB sanity
        for w in 0..n {
            let mut prev = 0;
            for &g in &self.gb[w] {
                if g <= prev {
                    return Err(Violation {
                        invariant: "gb-fifo",
                        detail: format!("worker {w} GB not strictly increasing at g{g}"),
                    });
                }
                prev = g;
                match self.groups.get(&g) {
                    None => {
                        return Err(Violation {
                            invariant: "gb-live",
                            detail: format!(
                                "worker {w} GB holds g{g} which is not live \
                                 (stale entry after an abort/death purge)"
                            ),
                        })
                    }
                    Some((members, _)) if !members.contains(&w) => {
                        return Err(Violation {
                            invariant: "gb-live",
                            detail: format!("worker {w} GB holds g{g} which omits it"),
                        })
                    }
                    Some(_) => {}
                }
            }
        }
        // 5: death hygiene
        for w in 0..n {
            if !self.dead[w] {
                continue;
            }
            if self.locks[w] {
                return Err(Violation {
                    invariant: "dead-unlocked",
                    detail: format!("dead rank {w} still holds a lock bit"),
                });
            }
            if !self.gb[w].is_empty() {
                return Err(Violation {
                    invariant: "dead-unlocked",
                    detail: format!("dead rank {w} has a non-empty GB"),
                });
            }
            for (id, (members, _)) in &self.groups {
                if members.contains(&w) {
                    return Err(Violation {
                        invariant: "dead-unlocked",
                        detail: format!("dead rank {w} is named by live g{id}"),
                    });
                }
            }
        }
        // 6: aborted-set boundedness
        if self.aborted.len() > self.cfg.aborted_cap {
            return Err(Violation {
                invariant: "aborted-bounded",
                detail: format!(
                    "aborted-id memory holds {} ids, cap {}",
                    self.aborted.len(),
                    self.cfg.aborted_cap
                ),
            });
        }
        // 7: no circular wait (rendezvous engines only — under sim
        // semantics armed groups always complete, so the graph is
        // trivially acyclic: pending -> armed and armed has no edges)
        if self.cfg.engine == EngineSemantics::Rendezvous {
            self.check_wait_graph()?;
        }
        Ok(())
    }

    /// Cycle detection over the wait-for graph: an *armed* group waits
    /// for each member to arrive, and a member stuck at a different
    /// GB-front group delays it (edge armed -> front); a *pending* group
    /// waits for the armed groups holding its locks (edge pending ->
    /// holder). A cycle is a rendezvous deadlock.
    fn check_wait_graph(&self) -> Result<(), Violation> {
        // armed holder of each locked rank
        let mut holder: BTreeMap<usize, GroupId> = BTreeMap::new();
        for (&id, (members, armed)) in &self.groups {
            if *armed {
                for &m in members {
                    holder.insert(m, id);
                }
            }
        }
        let mut edges: BTreeMap<GroupId, Vec<GroupId>> = BTreeMap::new();
        for (&id, (members, armed)) in &self.groups {
            let e = edges.entry(id).or_default();
            if *armed {
                for &m in members {
                    if self.dead[m] {
                        continue;
                    }
                    if let Some(&front) = self.gb[m].front() {
                        if front != id {
                            e.push(front);
                        }
                    }
                }
            } else {
                for &m in members {
                    if let Some(&h) = holder.get(&m) {
                        e.push(h);
                    }
                }
            }
        }
        // iterative DFS 3-coloring
        let mut color: BTreeMap<GroupId, u8> = BTreeMap::new(); // 1=open, 2=done
        for &start in self.groups.keys() {
            if color.contains_key(&start) {
                continue;
            }
            let mut stack = vec![(start, 0usize)];
            color.insert(start, 1);
            while let Some(frame) = stack.last_mut() {
                let (node, next) = (frame.0, frame.1);
                let succ = edges.get(&node).map(Vec::as_slice).unwrap_or(&[]);
                if next < succ.len() {
                    frame.1 += 1;
                    let s = succ[next];
                    match color.get(&s) {
                        Some(1) => {
                            return Err(Violation {
                                invariant: "no-circular-wait",
                                detail: format!(
                                    "wait-for cycle through g{node} -> g{s}: a member \
                                     is stuck at a pending front group whose locks \
                                     this armed group holds"
                                ),
                            })
                        }
                        Some(_) => {}
                        None => {
                            color.insert(s, 1);
                            stack.push((s, 0));
                        }
                    }
                } else {
                    color.insert(node, 2);
                    stack.pop();
                }
            }
        }
        Ok(())
    }

    /// Compact state rendering for counterexample reports.
    pub fn render(&self) -> String {
        let groups: Vec<String> = self
            .groups
            .iter()
            .map(|(id, (m, a))| {
                format!("g{id}{m:?}{}", if *a { "*" } else { "" })
            })
            .collect();
        let phases: Vec<String> = (0..self.cfg.n)
            .map(|w| match self.phase[w] {
                _ if self.dead[w] => format!("{w}:dead"),
                WorkerPhase::Idle if self.retired[w] => format!("{w}:retired"),
                WorkerPhase::Idle => format!("{w}:idle"),
                WorkerPhase::Waiting(g) => format!("{w}:wait(g{g})"),
            })
            .collect();
        format!(
            "groups=[{}] (*=armed) pending={:?} locks={:?} workers=[{}]",
            groups.join(" "),
            self.pending,
            (0..self.cfg.n).filter(|&w| self.locks[w]).collect::<Vec<_>>(),
            phases.join(" ")
        )
    }
}
