//! Exhaustive protocol model checking for the GG coordination state
//! machine (`ripples check`).
//!
//! The paper's central correctness claim over AD-PSGD is that Partial
//! All-Reduce plus GG scheduling is *deadlock-free*; `prop_gg` and
//! `stress_gg` only sample random interleavings of the protocol. This
//! module proves the claim exhaustively on a bounded instance: a
//! loom-style schedule explorer ([`explore`]) enumerates **every**
//! interleaving of an abstracted protocol model ([`model::Model`]) up to
//! a depth bound, with sleep-set partial-order reduction and a
//! canonical-state hash table, checking the coordination invariants (no
//! deadlock, no double grant, no leaked locks, GB FIFO sanity,
//! aborted-set boundedness, no circular wait) at every visited state.
//!
//! Three pillars keep the result meaningful:
//!
//! * **Conformance** ([`conform`]): explored traces replay against the
//!   real [`GroupGenerator`](crate::gg::GroupGenerator), the real
//!   [`ShardedGg`](crate::gg::ShardedGg), and the RPC dispatch seam
//!   ([`crate::rpc::ReplayServer`]), diffing full state after every op —
//!   the model is only trusted because the real code agrees with it.
//! * **Mutation self-tests** ([`model::Mutation`]): deliberately
//!   re-broken transition rules (the PR 7 lost wakeup, the rendezvous
//!   double-draft circular wait, completion without the
//!   release-then-arm sweep, ...) must each be *caught* with a
//!   minimized counterexample — proof the harness has teeth. The
//!   minimized traces are committed as fixtures
//!   (`rust/tests/fixtures/check/`) and replayed against the real
//!   backends, which must refuse to reach the bad states.
//! * **Bounded honesty**: DESIGN.md §Correctness spells out exactly
//!   what the bounds (ranks, depth, budgets, deterministic sampling) do
//!   and do not prove.

pub mod conform;
pub mod explore;
pub mod model;

pub use conform::{
    assert_real_invariants, conformance_replay, membership_deterministic,
    random_walk_conformance, replay_against_real, BackendSnapshot, RealReplay,
};
pub use explore::{explore, explore_with, Counterexample, ExploreStats};
pub use model::{EngineSemantics, Model, ModelCfg, Mutation, Op, Violation};

/// A bounded scenario: which protocol features are live and which fault
/// budgets are nonzero.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    /// Plain §4.1 random drafting, no Group Buffer, simulator
    /// semantics: the conflict/pending/arm-sweep core.
    Drafts,
    /// GB + Global Division with deaths and aborts in the mix (and a
    /// tiny aborted-set cap so boundedness is observable).
    Faults,
    /// GB + GD with a death followed by a checkpoint-restored rejoin.
    Rejoin,
    /// Rendezvous-engine semantics (threaded/distributed): groups only
    /// draft idle workers, members must meet, retires drain — the
    /// regime where drafting a busy worker would deadlock.
    Rendezvous,
}

impl Scenario {
    pub const ALL: [Scenario; 4] =
        [Scenario::Drafts, Scenario::Faults, Scenario::Rejoin, Scenario::Rendezvous];

    pub fn name(self) -> &'static str {
        match self {
            Scenario::Drafts => "drafts",
            Scenario::Faults => "faults",
            Scenario::Rejoin => "rejoin",
            Scenario::Rendezvous => "rendezvous",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Scenario::ALL.into_iter().find(|x| x.name() == s)
    }
}

/// The bounded model configuration for a scenario at `ranks` workers.
/// All four stay inside the membership-deterministic regime at `ranks =
/// 3` so the conformance suite can replay their traces strictly.
pub fn scenario_cfg(s: Scenario, ranks: usize) -> ModelCfg {
    let base = ModelCfg {
        n: ranks,
        group_size: ranks,
        use_group_buffer: false,
        use_global_division: false,
        rendezvous: false,
        engine: EngineSemantics::Sim,
        aborted_cap: 4,
        syncs_per_worker: 3,
        max_deaths: 0,
        max_rejoins: 0,
        max_aborts: 0,
        max_retires: 0,
    };
    match s {
        Scenario::Drafts => base,
        Scenario::Faults => ModelCfg {
            group_size: 2.min(ranks),
            use_group_buffer: true,
            use_global_division: true,
            aborted_cap: 2,
            max_deaths: 1,
            max_aborts: 3,
            ..base
        },
        Scenario::Rejoin => ModelCfg {
            group_size: 2.min(ranks),
            use_group_buffer: true,
            use_global_division: true,
            max_deaths: 1,
            max_rejoins: 1,
            max_aborts: 1,
            ..base
        },
        Scenario::Rendezvous => ModelCfg {
            use_group_buffer: true,
            rendezvous: true,
            engine: EngineSemantics::Rendezvous,
            max_retires: 2,
            max_aborts: 1,
            ..base
        },
    }
}

/// The scenario that makes a given mutation observable (used by the
/// `--mutation` self-test mode and the fixture generator).
pub fn mutation_cfg(m: Mutation, ranks: usize) -> ModelCfg {
    match m {
        Mutation::None
        | Mutation::SkipArmSweep
        | Mutation::DoubleGrant
        | Mutation::CompleteKeepsLocks => scenario_cfg(Scenario::Drafts, ranks),
        Mutation::AbortSkipsGbPurge
        | Mutation::DeathKeepsLocks
        | Mutation::SkipAbortedPrune => scenario_cfg(Scenario::Faults, ranks),
        Mutation::DraftBusy => {
            // The circular wait needs a second disjoint pair, so at
            // least 4 ranks with pair-sized groups and two retires.
            let mut cfg = scenario_cfg(Scenario::Rendezvous, ranks.max(4));
            cfg.group_size = 2;
            cfg
        }
    }
}

/// One scenario's exploration outcome.
#[derive(Debug, Clone)]
pub struct ScenarioReport {
    pub scenario: &'static str,
    pub ranks: usize,
    pub depth: u32,
    pub stats: ExploreStats,
    /// States visited with the sleep-set reduction disabled (only
    /// measured when asked — it re-runs the exploration).
    pub unreduced_states: Option<u64>,
    pub counterexample: Option<Counterexample>,
}

/// Explore one scenario. `measure_reduction` re-runs without sleep sets
/// to report the reduction ratio.
pub fn run_scenario(
    s: Scenario,
    ranks: usize,
    depth: u32,
    measure_reduction: bool,
) -> ScenarioReport {
    let initial = Model::new(scenario_cfg(s, ranks), Mutation::None);
    let (stats, counterexample) = explore(&initial, depth);
    let unreduced_states = measure_reduction
        .then(|| explore_with(&initial, depth, false).0.states_explored);
    ScenarioReport {
        scenario: s.name(),
        ranks,
        depth,
        stats,
        unreduced_states,
        counterexample,
    }
}

/// Explore a mutated model; the mutation is *expected* to be caught.
pub fn run_mutation(m: Mutation, ranks: usize, depth: u32) -> ScenarioReport {
    let cfg = mutation_cfg(m, ranks);
    let n = cfg.n;
    let initial = Model::new(cfg, m);
    let (stats, counterexample) = explore(&initial, depth);
    ScenarioReport {
        scenario: m.name(),
        ranks: n,
        depth,
        stats,
        unreduced_states: None,
        counterexample,
    }
}

/// Serialize scenario reports as the `results/CHECK_gg.json` artifact
/// (shape-asserted by `rust/tests/modelcheck.rs`).
pub fn report_json(ranks: usize, depth: u32, reports: &[ScenarioReport]) -> String {
    let mut out = String::from("{\n");
    out.push_str("  \"id\": \"gg_modelcheck\",\n");
    out.push_str("  \"generated_by\": \"ripples check\",\n");
    out.push_str("  \"placeholder\": false,\n");
    out.push_str(&format!("  \"ranks\": {ranks},\n"));
    out.push_str(&format!("  \"depth\": {depth},\n"));
    out.push_str("  \"scenarios\": [\n");
    for (i, r) in reports.iter().enumerate() {
        let ratio = match r.unreduced_states {
            Some(u) if r.stats.states_explored > 0 => {
                format!("{:.3}", u as f64 / r.stats.states_explored as f64)
            }
            _ => "null".to_string(),
        };
        out.push_str(&format!(
            "    {{\"scenario\": \"{}\", \"states_explored\": {}, \
             \"states_deduped\": {}, \"sleep_set_pruned\": {}, \
             \"max_depth_reached\": {}, \"quiescent_states\": {}, \
             \"unreduced_states\": {}, \"reduction_ratio\": {}, \
             \"violations\": {}}}{}\n",
            r.scenario,
            r.stats.states_explored,
            r.stats.states_deduped,
            r.stats.sleep_set_pruned,
            r.stats.max_depth_reached,
            r.stats.quiescent_states.len(),
            r.unreduced_states.map_or("null".to_string(), |u| u.to_string()),
            ratio,
            u32::from(r.counterexample.is_some()),
            if i + 1 < reports.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::explore::replay_violates;
    use super::*;

    /// Every unmutated scenario explores clean at a modest bound (the
    /// release-mode `make modelcheck` run goes deeper).
    #[test]
    fn scenarios_have_no_violations() {
        for s in Scenario::ALL {
            let depth = match s {
                Scenario::Drafts | Scenario::Rendezvous => 12,
                Scenario::Faults | Scenario::Rejoin => 10,
            };
            let r = run_scenario(s, 3, depth, false);
            assert!(
                r.counterexample.is_none(),
                "scenario {} violated:\n{}",
                s.name(),
                r.counterexample.unwrap().render()
            );
            assert!(r.stats.states_explored > 10, "scenario {} too small", s.name());
        }
    }

    /// Every deliberately broken transition rule is caught, with a
    /// minimized counterexample that still replays to the violation.
    #[test]
    fn every_mutation_is_caught() {
        for m in Mutation::ALL {
            let r = run_mutation(m, 3, 14);
            let cex = r.counterexample.unwrap_or_else(|| {
                panic!("mutation {} was NOT caught — the checker has no teeth", m.name())
            });
            assert!(!cex.minimized.is_empty(), "mutation {}: empty trace", m.name());
            assert!(
                cex.minimized.len() <= cex.trace.len(),
                "mutation {}: minimizer grew the trace",
                m.name()
            );
            let initial = Model::new(mutation_cfg(m, 3), m);
            assert!(
                replay_violates(&initial, &cex.minimized),
                "mutation {}: minimized trace does not replay",
                m.name()
            );
        }
    }

    /// Mutations are caught with and without the sleep-set reduction —
    /// the reduction must not hide bugs.
    #[test]
    fn mutations_caught_without_reduction_too() {
        for m in Mutation::ALL {
            let initial = Model::new(mutation_cfg(m, 3), m);
            let (_, cex) = explore_with(&initial, 14, false);
            assert!(cex.is_some(), "mutation {} missed without reduction", m.name());
        }
    }

    /// Empirical soundness of sleep sets + state caching: on a depth
    /// that exhausts the space (max path length < bound), the reduced
    /// and unreduced explorations must reach exactly the same quiescent
    /// states — sleep sets reduce transitions, never reachable states.
    #[test]
    fn reduction_reaches_same_leaves() {
        let mut cfg = scenario_cfg(Scenario::Drafts, 2);
        cfg.syncs_per_worker = 2;
        let initial = Model::new(cfg, Mutation::None);
        let (reduced, c1) = explore_with(&initial, 16, true);
        let (full, c2) = explore_with(&initial, 16, false);
        assert!(c1.is_none() && c2.is_none());
        // Exhaustive: no path ran into the depth bound.
        assert!(reduced.max_depth_reached < 16);
        assert!(full.max_depth_reached < 16);
        assert_eq!(reduced.quiescent_states, full.quiescent_states);
        assert!(reduced.states_explored <= full.states_explored);
        assert!(reduced.sleep_set_pruned > 0, "reduction never fired");
    }

    /// The minimized lost-wakeup counterexample is exactly the textbook
    /// three-op schedule.
    #[test]
    fn lost_wakeup_minimizes_to_three_ops() {
        let r = run_mutation(Mutation::SkipArmSweep, 3, 14);
        let cex = r.counterexample.expect("caught");
        assert_eq!(cex.minimized.len(), 3, "trace: {:?}", cex.minimized);
        assert!(
            matches!(cex.minimized.last(), Some(Op::Complete(_))),
            "lost wakeup must end in a complete: {:?}",
            cex.minimized
        );
    }

    #[test]
    fn double_grant_minimizes_to_two_syncs() {
        let r = run_mutation(Mutation::DoubleGrant, 3, 14);
        let cex = r.counterexample.expect("caught");
        assert_eq!(cex.minimized.len(), 2, "trace: {:?}", cex.minimized);
    }

    #[test]
    fn report_json_shape() {
        let r = run_scenario(Scenario::Drafts, 3, 8, true);
        let json = report_json(3, 8, &[r]);
        let parsed = crate::util::json::parse(&json).expect("valid JSON");
        assert_eq!(parsed.get("id").and_then(|v| v.as_str()), Some("gg_modelcheck"));
        let scenarios = parsed.get("scenarios").and_then(|v| v.as_arr()).expect("arr");
        assert_eq!(scenarios.len(), 1);
        let s0 = &scenarios[0];
        assert_eq!(s0.get("scenario").and_then(|v| v.as_str()), Some("drafts"));
        assert_eq!(s0.get("violations").and_then(|v| v.as_usize()), Some(0));
        assert!(s0.get("states_explored").and_then(|v| v.as_usize()).unwrap_or(0) > 0);
        assert!(s0.get("reduction_ratio").is_some());
    }

    /// Exhausting a scenario and then replaying a model-generated trace
    /// through the real backends end-to-end (the acceptance path).
    #[test]
    fn explored_scenario_traces_replay_strictly() {
        let cfg = scenario_cfg(Scenario::Faults, 3);
        for seed in 0..10 {
            conform::random_walk_conformance(&cfg, seed, 30)
                .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        }
    }
}
