//! The exhaustive schedule explorer.
//!
//! Depth-first enumeration of every interleaving of enabled [`Op`]s up to
//! a depth bound, with two reductions:
//!
//! * a **canonical-state hash table**: a state already explored with at
//!   least as much remaining depth is not re-expanded (the table maps
//!   `state_hash -> max remaining depth explored`);
//! * **sleep sets** (Godefroid-style partial-order reduction): once a
//!   transition `t` has been fully explored from state `s`, siblings
//!   explored later pass `t` down in their *sleep set* for as long as `t`
//!   stays independent of the path taken — re-exploring `t` there would
//!   only reach already-covered interleavings. Independence is checked
//!   dynamically and conservatively: `a` and `b` are independent at `s`
//!   only if each stays enabled after the other and the two execution
//!   orders land in the same state (equal canonical hashes).
//!
//! Soundness note for the combination: a state is *inserted* into the
//! hash table only when visited with an **empty** sleep set (a full
//! expansion); pruning against the table is then always safe, because the
//! recorded exploration covered a superset of what any later visit —
//! whatever its sleep set — would cover. Visits with a non-empty sleep
//! set recurse without recording. `tests::reduction_reaches_same_leaves`
//! cross-checks the reduced and unreduced explorations empirically.
//!
//! Invariants are checked at *every* visited state. On violation the
//! explorer returns the path as a counterexample and greedily minimizes
//! it (drop one op at a time while the violation still reproduces).

use std::collections::{BTreeSet, HashMap};

use super::model::{EngineSemantics, Model, Op, Violation};

/// Exploration statistics (also serialized into `results/CHECK_gg.json`).
#[derive(Debug, Clone, Default)]
pub struct ExploreStats {
    /// States visited (invariant-checked).
    pub states_explored: u64,
    /// Revisits pruned by the canonical-state hash table.
    pub states_deduped: u64,
    /// Transitions skipped because they were in a sleep set.
    pub sleep_set_pruned: u64,
    /// Deepest path length reached.
    pub max_depth_reached: u32,
    /// Canonical hashes of every quiescent (no-enabled-ops) state seen.
    /// Sleep sets reduce *transitions*, never reachable *states*, so on
    /// a depth that exhausts the space this set must match between the
    /// reduced and unreduced explorations — the empirical soundness
    /// cross-check (`tests::reduction_reaches_same_leaves`).
    pub quiescent_states: BTreeSet<u64>,
}

/// A violation plus the schedule that reaches it.
#[derive(Debug, Clone)]
pub struct Counterexample {
    pub violation: Violation,
    /// The schedule as first found.
    pub trace: Vec<Op>,
    /// Greedily minimized schedule (still reproduces the violation).
    pub minimized: Vec<Op>,
    /// Rendering of the violating state at the end of `minimized`.
    pub state: String,
}

impl Counterexample {
    /// Multi-line human-readable report.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "invariant violated: {}\n  {}\n  trace ({} ops, minimized from {}):\n",
            self.violation.invariant,
            self.violation.detail,
            self.minimized.len(),
            self.trace.len()
        ));
        for (i, op) in self.minimized.iter().enumerate() {
            out.push_str(&format!("    {:>2}. {}\n", i + 1, op.render()));
        }
        out.push_str(&format!("  state: {}\n", self.state));
        out
    }
}

struct Explorer {
    depth: u32,
    use_sleep_sets: bool,
    /// state hash -> max remaining depth already fully expanded with.
    visited: HashMap<u64, u32>,
    stats: ExploreStats,
}

/// Exhaustively explore `initial` to `depth`, checking invariants at
/// every state. Returns the stats and the first counterexample, if any.
pub fn explore(initial: &Model, depth: u32) -> (ExploreStats, Option<Counterexample>) {
    explore_with(initial, depth, true)
}

/// As [`explore`], optionally disabling the sleep-set reduction (used to
/// measure the reduction ratio and to cross-validate the reduction).
pub fn explore_with(
    initial: &Model,
    depth: u32,
    use_sleep_sets: bool,
) -> (ExploreStats, Option<Counterexample>) {
    let mut ex = Explorer {
        depth,
        use_sleep_sets,
        visited: HashMap::new(),
        stats: ExploreStats::default(),
    };
    let mut path = Vec::new();
    let cex = match ex.dfs(initial, depth, &mut path, &[]) {
        Ok(()) => None,
        Err(violation) => {
            let trace = path.clone();
            let minimized = minimize(initial, &trace);
            // Re-derive the violation from the minimized trace (greedy
            // removal may surface the failure through a different — but
            // still real — invariant).
            let mut m = initial.clone();
            let mut violation = violation;
            for &op in &minimized {
                m.step(op);
                if let Err(v) = m.check_invariants() {
                    violation = v;
                    break;
                }
            }
            let state = m.render();
            Some(Counterexample { violation, trace, minimized, state })
        }
    };
    (ex.stats, cex)
}

impl Explorer {
    fn dfs(
        &mut self,
        s: &Model,
        depth_left: u32,
        path: &mut Vec<Op>,
        sleep: &[Op],
    ) -> Result<(), Violation> {
        self.stats.states_explored += 1;
        let here = self.depth - depth_left;
        if here > self.stats.max_depth_reached {
            self.stats.max_depth_reached = here;
        }
        s.check_invariants()?;
        let enabled = s.enabled();
        if enabled.is_empty() {
            self.stats.quiescent_states.insert(s.state_hash());
            // Quiescence. Under sim semantics an armed group always
            // completes and a pending group always conflicts (invariant
            // no-lost-wakeup), so quiescence with a live worker still
            // waiting is a deadlock. Under rendezvous semantics budget
            // exhaustion can strand a waiter benignly; there the
            // no-circular-wait invariant is the deadlock detector.
            if s.cfg.engine == EngineSemantics::Sim && s.any_live_waiting() {
                return Err(Violation {
                    invariant: "no-deadlock",
                    detail: "quiescent state with a live worker still waiting".into(),
                });
            }
            return Ok(());
        }
        if depth_left == 0 {
            return Ok(());
        }
        let h = s.state_hash();
        if let Some(&d) = self.visited.get(&h) {
            if d >= depth_left {
                self.stats.states_deduped += 1;
                return Ok(());
            }
        }
        if sleep.is_empty() {
            self.visited.insert(h, depth_left);
        }
        let mut done: Vec<Op> = Vec::new();
        for &op in &enabled {
            if sleep.contains(&op) {
                self.stats.sleep_set_pruned += 1;
                continue;
            }
            let child = s.child(op);
            let child_sleep: Vec<Op> = if self.use_sleep_sets {
                sleep
                    .iter()
                    .chain(done.iter())
                    .copied()
                    .filter(|&t| independent(s, t, op))
                    .collect()
            } else {
                Vec::new()
            };
            path.push(op);
            self.dfs(&child, depth_left - 1, path, &child_sleep)?;
            path.pop();
            if self.use_sleep_sets {
                done.push(op);
            }
        }
        Ok(())
    }
}

/// Conservative dynamic independence at `s`: both orders must be
/// executable and commute to the same canonical state.
fn independent(s: &Model, a: Op, b: Op) -> bool {
    if a == b {
        return false;
    }
    let sa = s.child(a);
    if !sa.enabled().contains(&b) {
        return false;
    }
    let sb = s.child(b);
    if !sb.enabled().contains(&a) {
        return false;
    }
    sa.child(b).state_hash() == sb.child(a).state_hash()
}

/// Replay `ops` from `initial`; true if some prefix violates an
/// invariant (or ends in a sim-semantics stranded-waiter quiescence).
/// Ops that are not enabled when reached make the candidate invalid.
pub fn replay_violates(initial: &Model, ops: &[Op]) -> bool {
    let mut m = initial.clone();
    for &op in ops {
        if !m.enabled().contains(&op) {
            return false;
        }
        m.step(op);
        if m.check_invariants().is_err() {
            return true;
        }
    }
    m.cfg.engine == EngineSemantics::Sim && m.enabled().is_empty() && m.any_live_waiting()
}

/// Greedy delta-debugging: repeatedly drop the first single op whose
/// removal keeps the violation reproducible, until a fixed point.
pub fn minimize(initial: &Model, trace: &[Op]) -> Vec<Op> {
    let mut best = trace.to_vec();
    loop {
        let mut improved = false;
        for i in 0..best.len() {
            let mut cand = best.clone();
            cand.remove(i);
            if replay_violates(initial, &cand) {
                best = cand;
                improved = true;
                break;
            }
        }
        if !improved {
            return best;
        }
    }
}
