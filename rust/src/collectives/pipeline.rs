//! Pipelined, sharded P-Reduce: compute/communication overlap.
//!
//! The serial worker loop is stop-and-wait — the network idles during
//! compute and the CPU idles during every collective. This module splits
//! the flat model into `K` shards ([`shard_bounds`]) and runs the ring
//! schedule *shard by shard* over the same [`ChunkTransport`]
//! ([`ring_allreduce_sharded`]); each shard gets its own step-tag range,
//! so framed transports verify per-shard ordering exactly as before.
//!
//! Overlap itself is an engine concern (a dedicated comm thread runs the
//! sharded collective on a snapshot while the training thread keeps
//! stepping — see `net::worker` and `runtime::threaded`); this module
//! owns the two pure ingredients every engine shares:
//!
//! * the shard partition (`K` contiguous ranges that exactly tile the
//!   model, ragged sizes included), and
//! * the bounded-staleness apply ([`reconcile_shard`]): the collective
//!   averaged a *snapshot* `s` into `avg` while the live model advanced
//!   from `s` to `x = s + delta`; reconciling to `avg + delta` keeps the
//!   local progress made during the transfer and applies the group
//!   average — the non-blocking-update rule of AD-PSGD (Lian et al.,
//!   1710.06952) and NBSync (He & Dube, 2211.00889), here per shard.
//!
//! Staleness is bounded by [`OverlapConfig::max_staleness`]: the number
//! of extra local SGD steps a worker may take while a collective is in
//! flight. `max_staleness = 0` disables overlap entirely and (with
//! `shards = 1`) takes the exact serial code path — bit-for-bit the
//! pre-overlap behaviour, which the golden tests pin.
//!
//! Wire codecs compose orthogonally: the sharded schedule runs over the
//! same [`ChunkTransport`] as the plain collective, so a compressed
//! transport (`--wire fp16|q8`, `collectives::codec`) compresses every
//! overlapped shard's chunks too — nothing in this module needs to know
//! (the coded-sharded-ring property test in `prop_net.rs` pins it).

use anyhow::Result;

use super::ring::{chunk_bounds, ring_allreduce_via_offset, ChunkTransport};

/// Compute/communication-overlap knobs, shared by the distributed worker
/// (`--overlap-shards` / `--max-staleness`), the threaded runtime, and
/// the simulator's virtual-time model (`[overlap]` config section).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OverlapConfig {
    /// Number of model shards the collective is pipelined over (K >= 1;
    /// 1 = the whole model as a single shard, i.e. today's schedule).
    /// All members of a group must use the same K: shard step tags are
    /// part of the wire schedule.
    pub shards: usize,
    /// Maximum extra local SGD steps a worker may run on stale weights
    /// while a collective for its model is still in flight. 0 = serial
    /// (block through the whole collective, the paper's Fig. 8 loop).
    pub max_staleness: u64,
}

impl Default for OverlapConfig {
    fn default() -> Self {
        Self::serial()
    }
}

impl OverlapConfig {
    /// The stop-and-wait default: one shard, no stale steps.
    pub fn serial() -> Self {
        Self { shards: 1, max_staleness: 0 }
    }

    /// True when no comm thread should be spawned at all: the training
    /// thread blocks through the (possibly sharded) collective inline.
    pub fn is_serial(&self) -> bool {
        self.max_staleness == 0
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.shards == 0 {
            return Err("overlap.shards must be >= 1".into());
        }
        if self.shards > 1024 {
            return Err(format!("overlap.shards {} is unreasonable (max 1024)", self.shards));
        }
        Ok(())
    }
}

/// Shard boundaries: shard `s` of `k` covers `bounds.0 .. bounds.1` of an
/// `n`-element model. Same remainder-spreading rule as the ring schedule's
/// chunk partition, so the `k` shards exactly tile `0..n` for every
/// (ragged) size.
pub fn shard_bounds(n: usize, k: usize, s: usize) -> (usize, usize) {
    chunk_bounds(n, k, s)
}

/// Step tags `base..base + 2(p-1)` for shard `s` of a `p`-rank ring: each
/// shard's schedule owns a disjoint tag range on the shared edge.
pub fn shard_step_base(p: usize, s: usize) -> u32 {
    (2 * p.saturating_sub(1) * s) as u32
}

/// Run rank `r`'s side of the mean-all-reduce pipelined over `k` shards:
/// `k` back-to-back ring schedules, each over one contiguous shard of
/// `buf`, with per-shard step-tag ranges. `on_shard(s)` fires after shard
/// `s` completes — the hook an overlap engine uses to publish finished
/// shards while later ones are still on the wire. With `k = 1` this is
/// exactly [`ring_allreduce_via_offset`]`(.., 0)`, frames and arithmetic
/// identical to the unsharded collective.
pub fn ring_allreduce_sharded<T, F>(
    r: usize,
    p: usize,
    buf: &mut [f32],
    k: usize,
    transport: &mut T,
    mut on_shard: F,
) -> Result<()>
where
    T: ChunkTransport,
    F: FnMut(usize, &[f32]),
{
    let k = k.max(1);
    let n = buf.len();
    for s in 0..k {
        let (lo, hi) = shard_bounds(n, k, s);
        ring_allreduce_via_offset(r, p, &mut buf[lo..hi], transport, shard_step_base(p, s))?;
        on_shard(s, &buf[lo..hi]);
    }
    Ok(())
}

/// Bounded-staleness apply for one finished shard: the collective
/// averaged snapshot values `snap` into `avg`; meanwhile `live` advanced
/// by local SGD. Set `live = avg + (live - snap)` element-wise — the
/// group average plus the local progress made while the shard was in
/// flight. When `live == snap` (no stale steps ran) the result is
/// exactly `avg`, so a zero-staleness overlap run degenerates to the
/// serial semantics.
///
/// All three slices are the *same shard range* of their buffers and must
/// have equal lengths.
pub fn reconcile_shard(live: &mut [f32], snap: &[f32], avg: &[f32]) {
    debug_assert_eq!(live.len(), snap.len());
    debug_assert_eq!(live.len(), avg.len());
    for ((l, &s), &a) in live.iter_mut().zip(snap.iter()).zip(avg.iter()) {
        *l = a + (*l - s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::ring::{ring_allreduce_via, ChannelTransport};
    use crate::util::rng::Pcg32;
    use std::thread;

    fn rand_bufs(p: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Pcg32::new(seed);
        (0..p)
            .map(|_| (0..n).map(|_| rng.gen_f32() * 2.0 - 1.0).collect())
            .collect()
    }

    fn naive_mean(bufs: &[Vec<f32>]) -> Vec<f32> {
        let p = bufs.len();
        let n = bufs[0].len();
        (0..n)
            .map(|i| bufs.iter().map(|b| b[i]).sum::<f32>() / p as f32)
            .collect()
    }

    /// Run the sharded collective over in-memory channels, one thread per
    /// rank, recording each rank's shard-completion order.
    fn sharded_mean(bufs: &mut [Vec<f32>], k: usize) -> Vec<Vec<usize>> {
        let p = bufs.len();
        let transports = ChannelTransport::ring(p);
        thread::scope(|scope| {
            let handles: Vec<_> = bufs
                .iter_mut()
                .enumerate()
                .zip(transports)
                .map(|((r, buf), mut t)| {
                    scope.spawn(move || {
                        let mut order = Vec::new();
                        ring_allreduce_sharded(r, p, buf, k, &mut t, |s, _| order.push(s))
                            .expect("sharded ring");
                        order
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }

    #[test]
    fn shard_bounds_tile_exactly() {
        for n in [0usize, 1, 5, 16, 101, 1000] {
            for k in 1..=9 {
                let mut covered = 0;
                for s in 0..k {
                    let (lo, hi) = shard_bounds(n, k, s);
                    assert_eq!(lo, covered, "n={n} k={k} s={s}");
                    assert!(hi >= lo);
                    covered = hi;
                }
                assert_eq!(covered, n, "n={n} k={k}");
            }
        }
    }

    #[test]
    fn shard_step_bases_are_disjoint() {
        for p in 2..=8usize {
            let steps = 2 * (p - 1) as u32;
            for s in 0..6usize {
                assert_eq!(shard_step_base(p, s), steps * s as u32);
            }
        }
    }

    #[test]
    fn sharded_matches_naive_and_completes_in_order() {
        for (p, n, k) in [(2usize, 64usize, 2usize), (3, 101, 4), (4, 1000, 8), (5, 7, 3)] {
            let mut bufs = rand_bufs(p, n, (p * 31 + n + k) as u64);
            let expect = naive_mean(&bufs);
            let orders = sharded_mean(&mut bufs, k);
            for (r, buf) in bufs.iter().enumerate() {
                for i in 0..n {
                    assert!(
                        (buf[i] - expect[i]).abs() < 1e-5,
                        "p={p} n={n} k={k} rank={r} idx={i}"
                    );
                }
            }
            for order in orders {
                assert_eq!(order, (0..k).collect::<Vec<_>>(), "shards out of order");
            }
        }
    }

    #[test]
    fn single_shard_bitwise_equals_unsharded() {
        // K=1 must take the exact serial schedule: same frames, same
        // arithmetic, bit-identical results (the golden-test guarantee).
        let p = 4;
        let n = 501;
        let mut plain = rand_bufs(p, n, 99);
        let mut sharded = plain.clone();
        let transports = ChannelTransport::ring(p);
        thread::scope(|scope| {
            for ((r, buf), mut t) in plain.iter_mut().enumerate().zip(transports) {
                scope.spawn(move || {
                    ring_allreduce_via(r, p, buf, &mut t).unwrap();
                });
            }
        });
        sharded_mean(&mut sharded, 1);
        for r in 0..p {
            for i in 0..n {
                assert_eq!(
                    plain[r][i].to_bits(),
                    sharded[r][i].to_bits(),
                    "rank {r} idx {i} diverged bitwise"
                );
            }
        }
    }

    #[test]
    fn reconcile_preserves_local_progress() {
        let snap = vec![1.0f32, 2.0, 3.0];
        let avg = vec![0.5f32, 1.5, 2.5]; // group average of the snapshot
        let mut live = vec![1.1f32, 2.0, 2.9]; // snapshot + local delta
        reconcile_shard(&mut live, &snap, &avg);
        // avg + (live - snap): 0.5+0.1, 1.5+0.0, 2.5-0.1
        assert!((live[0] - 0.6).abs() < 1e-6);
        assert!((live[1] - 1.5).abs() < 1e-6);
        assert!((live[2] - 2.4).abs() < 1e-6);
    }

    #[test]
    fn reconcile_zero_staleness_is_exact_copy() {
        // live == snap (no stale steps): the result must be avg exactly,
        // bit for bit — the serial-semantics degeneration.
        let mut rng = Pcg32::new(5);
        let snap: Vec<f32> = (0..64).map(|_| rng.gen_f32() * 2.0 - 1.0).collect();
        let avg: Vec<f32> = (0..64).map(|_| rng.gen_f32() * 2.0 - 1.0).collect();
        let mut live = snap.clone();
        reconcile_shard(&mut live, &snap, &avg);
        for i in 0..64 {
            assert_eq!(live[i].to_bits(), avg[i].to_bits(), "idx {i}");
        }
    }

    #[test]
    fn overlap_config_validation() {
        assert!(OverlapConfig::serial().validate().is_ok());
        assert!(OverlapConfig::serial().is_serial());
        assert!(OverlapConfig { shards: 4, max_staleness: 2 }.validate().is_ok());
        assert!(!OverlapConfig { shards: 4, max_staleness: 2 }.is_serial());
        // K > 1 with zero staleness is still "serial": inline, blocking
        assert!(OverlapConfig { shards: 4, max_staleness: 0 }.is_serial());
        assert!(OverlapConfig { shards: 0, max_staleness: 0 }.validate().is_err());
        assert!(OverlapConfig { shards: 4096, max_staleness: 0 }.validate().is_err());
    }
}
