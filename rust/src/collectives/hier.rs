//! Two-level (hierarchical) P-Reduce: intra-node reduce → inter-node
//! ring → broadcast back.
//!
//! A flat ring crosses every inter-node link `2(p-1)` times; when the
//! group spans racks behind constrained uplinks that is the whole sync
//! cost (DESIGN.md §Perf, "Hierarchical P-Reduce"). The two-level shape
//! moves each model byte across the uplink once per ring step instead:
//!
//! 1. **intra gather** — every non-leader member ships its shard to its
//!    node leader, which accumulates a node-local *sum*;
//! 2. **inter ring** — the node leaders run the ordinary chunked ring
//!    over their sums, with the single division point scaled by the
//!    *group total* ([`ring_allreduce_via_div`]) so the result is the
//!    group mean, not the leader mean;
//! 3. **broadcast** — each leader ships the finished mean back to its
//!    members.
//!
//! The schedule is generic over the same [`ChunkTransport`] as the flat
//! ring, so wire codecs (`--wire fp16|q8`) and the pipelined shard path
//! compress every phase for free. Which ranks lead and how nodes are
//! ordered comes from the GG-attached [`SyncPlan`](crate::topo::SyncPlan);
//! this module only executes it.
//!
//! ## Step tags
//!
//! Per shard `s`, member↔leader edges carry exactly two frames — gather
//! (`2s`) and broadcast (`2s + 1`) — while the leader ring runs the
//! usual `2(L-1)` tags from [`shard_step_base`]`(L, s)`. The two tag
//! spaces live on disjoint edges (a member↔leader pair is never also a
//! ring edge), so framed transports verify ordering exactly as before.
//!
//! ## Abort semantics
//!
//! Any transport error propagates to the caller, which unwinds *both*
//! levels: a leader poisons its member links and its ring edges, a
//! member poisons its leader link (see `net::worker`). The group then
//! aborts through the same GG repair path as a flat collective.

use anyhow::{anyhow, Result};

use super::pipeline::{shard_bounds, shard_step_base};
use super::ring::{ring_allreduce_via_div, ChunkTransport};

/// Gather step tag for shard `s` on a member↔leader edge.
pub fn intra_gather_step(s: usize) -> u32 {
    (2 * s) as u32
}

/// Broadcast step tag for shard `s` on a member↔leader edge.
pub fn intra_bcast_step(s: usize) -> u32 {
    (2 * s) as u32 + 1
}

/// Run a non-leader member's side: per shard, ship our contribution to
/// the node leader and receive the finished group mean back. `on_shard`
/// fires per finished shard, mirroring
/// [`ring_allreduce_sharded`](super::pipeline::ring_allreduce_sharded).
pub fn hier_member<T, F>(
    link: &mut T,
    buf: &mut [f32],
    k: usize,
    mut on_shard: F,
) -> Result<()>
where
    T: ChunkTransport,
    F: FnMut(usize, &[f32]),
{
    let k = k.max(1);
    let n = buf.len();
    let mut incoming: Vec<f32> = Vec::new();
    for s in 0..k {
        let (lo, hi) = shard_bounds(n, k, s);
        link.send(intra_gather_step(s), &buf[lo..hi])?;
        link.recv(intra_bcast_step(s), &mut incoming)?;
        if incoming.len() != hi - lo {
            return Err(anyhow!(
                "hier broadcast shard {s}: expected {} elements, got {}",
                hi - lo,
                incoming.len()
            ));
        }
        buf[lo..hi].copy_from_slice(&incoming);
        on_shard(s, &buf[lo..hi]);
    }
    Ok(())
}

/// Run a node leader's side: per shard, accumulate every member's
/// contribution (in `members` order — the plan's intra order, so every
/// member of the cluster sums in the same sequence), run the inter-node
/// ring over the node sums dividing by `p_total`, and broadcast the
/// finished mean back to the members.
///
/// `ring` is `Some((transport, pos, n_leaders))` when the group spans
/// more than one node; a single-node group (`None`) just scales its sum
/// to the mean locally.
pub fn hier_leader<T, F>(
    members: &mut [T],
    ring: Option<(&mut T, usize, usize)>,
    p_total: usize,
    buf: &mut [f32],
    k: usize,
    mut on_shard: F,
) -> Result<()>
where
    T: ChunkTransport,
    F: FnMut(usize, &[f32]),
{
    let k = k.max(1);
    let n = buf.len();
    let mut incoming: Vec<f32> = Vec::new();
    let mut ring = ring;
    for s in 0..k {
        let (lo, hi) = shard_bounds(n, k, s);
        // phase 1: node-local sum, fixed member order
        for link in members.iter_mut() {
            link.recv(intra_gather_step(s), &mut incoming)?;
            if incoming.len() != hi - lo {
                return Err(anyhow!(
                    "hier gather shard {s}: expected {} elements, got {}",
                    hi - lo,
                    incoming.len()
                ));
            }
            for (b, v) in buf[lo..hi].iter_mut().zip(incoming.iter()) {
                *b += v;
            }
        }
        // phase 2: inter-node ring over node sums; the one division
        // point divides by the group total
        match ring.as_mut() {
            Some((t, pos, leaders)) => ring_allreduce_via_div(
                *pos,
                *leaders,
                &mut buf[lo..hi],
                *t,
                shard_step_base(*leaders, s),
                p_total,
            )?,
            None => {
                let inv = 1.0 / p_total as f32;
                for b in buf[lo..hi].iter_mut() {
                    *b *= inv;
                }
            }
        }
        // phase 3: broadcast the finished mean back
        for link in members.iter_mut() {
            link.send(intra_bcast_step(s), &buf[lo..hi])?;
        }
        on_shard(s, &buf[lo..hi]);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::ring::ChannelTransport;
    use crate::collectives::WireCodec;
    use crate::topo::{SyncPlan, Topology};
    use crate::util::rng::Pcg32;
    use std::thread;

    /// Build a duplex in-memory edge: returns (end_a, end_b) where each
    /// end's `send` feeds the other's `recv`.
    fn duplex(wire: WireCodec) -> (ChannelTransport, ChannelTransport) {
        let mut ring = ChannelTransport::ring_with(2, wire);
        let b = ring.pop().unwrap();
        let a = ring.pop().unwrap();
        (a, b)
    }

    /// Execute a [`SyncPlan`] over in-memory channels, one thread per
    /// member: the test-side mirror of what `net::worker` runs over TCP.
    /// `bufs` is indexed by ring position (`plan.ring_order()` order) and
    /// is updated in place with each member's post-collective buffer.
    fn run_plan(plan: &SyncPlan, bufs: &mut [Vec<f32>], k: usize, wire: WireCodec) {
        let p_total = plan.total();
        let n_leaders = plan.nodes.len();
        // duplex member<->leader edges, per node
        let mut leader_ends: Vec<Vec<ChannelTransport>> = Vec::new();
        let mut member_ends: Vec<Vec<Option<ChannelTransport>>> = Vec::new();
        for node in &plan.nodes {
            let mut le = Vec::new();
            let mut me = Vec::new();
            for _ in &node[1..] {
                let (a, b) = duplex(wire);
                le.push(a);
                me.push(Some(b));
            }
            leader_ends.push(le);
            member_ends.push(me);
        }
        // leader ring transports (only when the group spans >1 node)
        let mut ring_ts: Vec<Option<ChannelTransport>> = if n_leaders > 1 {
            ChannelTransport::ring_with(n_leaders, wire)
                .into_iter()
                .map(Some)
                .collect()
        } else {
            (0..n_leaders).map(|_| None).collect()
        };
        let done: Vec<(usize, Vec<f32>)> = thread::scope(|scope| {
            let mut handles = Vec::new();
            let mut pos = 0usize;
            for (ni, node) in plan.nodes.iter().enumerate() {
                for ii in 0..node.len() {
                    let mut buf = std::mem::take(&mut bufs[pos]);
                    let my_pos = pos;
                    pos += 1;
                    if ii == 0 {
                        let mut links = std::mem::take(&mut leader_ends[ni]);
                        let mut ring_t = ring_ts[ni].take();
                        handles.push(scope.spawn(move || {
                            let ring = ring_t.as_mut().map(|t| (t, ni, n_leaders));
                            hier_leader(&mut links, ring, p_total, &mut buf, k, |_, _| ())
                                .expect("leader");
                            (my_pos, buf)
                        }));
                    } else {
                        let mut link = member_ends[ni][ii - 1].take().unwrap();
                        handles.push(scope.spawn(move || {
                            hier_member(&mut link, &mut buf, k, |_, _| ()).expect("member");
                            (my_pos, buf)
                        }));
                    }
                }
            }
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (pos, buf) in done {
            bufs[pos] = buf;
        }
    }

    fn naive_mean(bufs: &[Vec<f32>]) -> Vec<f32> {
        let p = bufs.len();
        let n = bufs[0].len();
        (0..n)
            .map(|i| bufs.iter().map(|b| b[i]).sum::<f32>() / p as f32)
            .collect()
    }

    fn rand_bufs(p: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Pcg32::new(seed);
        (0..p)
            .map(|_| (0..n).map(|_| rng.gen_f32() * 2.0 - 1.0).collect())
            .collect()
    }

    #[test]
    fn two_node_group_forms_the_group_mean() {
        let topo = Topology::parse("a:0,1,2;b:3,4,5", 6).unwrap();
        for (k, n) in [(1usize, 300usize), (3, 301), (4, 7)] {
            let plan = SyncPlan::make(&[0, 1, 2, 3, 4, 5], Some(&topo), &[]);
            let mut bufs = rand_bufs(6, n, (k * 100 + n) as u64);
            let expect = naive_mean(&bufs);
            run_plan(&plan, &mut bufs, k, WireCodec::Fp32);
            for (r, buf) in bufs.iter().enumerate() {
                for i in 0..n {
                    assert!(
                        (buf[i] - expect[i]).abs() < 1e-5,
                        "k={k} n={n} pos={r} idx={i}: {} vs {}",
                        buf[i],
                        expect[i]
                    );
                }
            }
            // all members identical
            for b in &bufs[1..] {
                assert_eq!(&bufs[0], b);
            }
        }
    }

    #[test]
    fn single_node_group_divides_by_total() {
        let topo = Topology::parse("a:0,1,2", 3).unwrap();
        let plan = SyncPlan::make(&[0, 1, 2], Some(&topo), &[]);
        assert_eq!(plan.nodes.len(), 1);
        let mut bufs =
            vec![vec![3.0f32; 16], vec![6.0f32; 16], vec![9.0f32; 16]];
        run_plan(&plan, &mut bufs, 2, WireCodec::Fp32);
        for b in &bufs {
            assert!(b.iter().all(|&v| (v - 6.0).abs() < 1e-6), "{:?}", &b[..4]);
        }
    }

    #[test]
    fn ragged_nodes_and_singleton_nodes_work() {
        // 3 nodes: sizes 3, 1, 2 — a singleton node's leader has no
        // member links at all
        let topo = Topology::parse("a:0,1,2;b:3;c:4,5", 6).unwrap();
        let plan = SyncPlan::make(&[5, 3, 0, 1, 2, 4], Some(&topo), &[]);
        let mut bufs = rand_bufs(6, 129, 17);
        let expect = naive_mean(&bufs);
        run_plan(&plan, &mut bufs, 2, WireCodec::Fp32);
        for buf in &bufs {
            for i in 0..129 {
                assert!((buf[i] - expect[i]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn codec_composes_with_hierarchy() {
        // fp16 wire: every phase compresses; the result stays within the
        // codec's tolerance of the exact mean
        let topo = Topology::parse("a:0,1;b:2,3", 4).unwrap();
        let plan = SyncPlan::make(&[0, 1, 2, 3], Some(&topo), &[]);
        let mut bufs = rand_bufs(4, 256, 23);
        let expect = naive_mean(&bufs);
        run_plan(&plan, &mut bufs, 2, WireCodec::Fp16);
        for buf in &bufs {
            for i in 0..256 {
                assert!(
                    (buf[i] - expect[i]).abs() < 3e-2,
                    "idx {i}: {} vs {}",
                    buf[i],
                    expect[i]
                );
            }
        }
    }

    #[test]
    fn member_rejects_short_broadcast() {
        // a lying leader edge: member must error on a truncated shard
        let (mut leader_end, mut member_end) = duplex(WireCodec::Fp32);
        let h = thread::spawn(move || {
            let mut incoming = Vec::new();
            leader_end.recv(intra_gather_step(0), &mut incoming).unwrap();
            leader_end.send(intra_bcast_step(0), &incoming[..3]).unwrap();
        });
        let mut buf = vec![1.0f32; 8];
        let err = hier_member(&mut member_end, &mut buf, 1, |_, _| ());
        assert!(err.is_err(), "short broadcast must be rejected");
        h.join().unwrap();
    }

    #[test]
    fn leader_rejects_short_gather() {
        let (mut leader_end, mut member_end) = duplex(WireCodec::Fp32);
        let h = thread::spawn(move || {
            member_end.send(intra_gather_step(0), &[1.0f32; 3]).unwrap();
        });
        let mut buf = vec![1.0f32; 8];
        let err = hier_leader(
            std::slice::from_mut(&mut leader_end),
            None,
            2,
            &mut buf,
            1,
            |_, _| (),
        );
        assert!(err.is_err(), "short gather must be rejected");
        h.join().unwrap();
    }

    #[test]
    fn intra_step_tags_are_disjoint_per_shard() {
        for s in 0..8 {
            assert_ne!(intra_gather_step(s), intra_bcast_step(s));
            if s > 0 {
                assert!(intra_gather_step(s) > intra_bcast_step(s - 1));
            }
        }
    }

    /// Satellite 4: property test — the two-level collective is
    /// *bit-identical* to a flat ring oracle at fp32 when the data is
    /// integer-valued (every partial sum exactly representable, so
    /// associativity differences cannot surface). Random group shapes
    /// and node assignments.
    #[test]
    fn prop_hier_bit_identical_to_flat_oracle_on_integer_data() {
        const SEEDS: u64 = 40;
        for seed in 0..SEEDS {
            let mut rng = Pcg32::new(0x70_90 + seed);
            let p = 2 + rng.gen_range(7); // 2..=8 members
            let n = 1 + rng.gen_range(97);
            let k = 1 + rng.gen_range(3);
            // random node assignment: up to p machines
            let n_machines = 1 + rng.gen_range(p);
            let mut spec_nodes: Vec<Vec<usize>> = vec![Vec::new(); n_machines];
            for r in 0..p {
                let m = rng.gen_range(n_machines);
                spec_nodes[m].push(r);
            }
            let spec = spec_nodes
                .iter()
                .enumerate()
                .filter(|(_, rs)| !rs.is_empty())
                .map(|(m, rs)| {
                    let list: Vec<String> = rs.iter().map(|r| r.to_string()).collect();
                    format!("m{m}:{}", list.join(","))
                })
                .collect::<Vec<_>>()
                .join(";");
            let topo = Topology::parse(&spec, p).unwrap_or_else(|e| {
                panic!("seed {seed}: bad spec {spec:?}: {e}")
            });
            let members: Vec<usize> = (0..p).collect();
            let plan = SyncPlan::make(&members, Some(&topo), &[]);
            plan.validate(&members)
                .unwrap_or_else(|e| panic!("seed {seed}: {e}"));

            // integer-valued data in [-8, 8): sums up to 8*8=64 exact
            let bufs: Vec<Vec<f32>> = (0..p)
                .map(|_| {
                    (0..n)
                        .map(|_| (rng.gen_range(16) as f32) - 8.0)
                        .collect()
                })
                .collect();

            // oracle: flat chunked ring (integer sums are exact, so the
            // reduction order cannot change the bits)
            let mut flat = bufs.clone();
            thread::scope(|scope| {
                let mut ts = ChannelTransport::ring(p);
                for (pos, buf) in flat.iter_mut().enumerate() {
                    let mut t = ts.remove(0);
                    scope.spawn(move || {
                        crate::collectives::ring::ring_allreduce_via_offset(
                            pos, p, buf, &mut t, 0,
                        )
                        .expect("flat oracle");
                    });
                }
            });

            // two-level run over the same pos-indexed data
            let mut hier = bufs.clone();
            run_plan(&plan, &mut hier, k, WireCodec::Fp32);

            for pos in 0..p {
                for i in 0..n {
                    assert_eq!(
                        flat[pos][i].to_bits(),
                        hier[pos][i].to_bits(),
                        "seed {seed} spec {spec:?} pos {pos} idx {i}: \
                         flat {} != hier {}",
                        flat[pos][i],
                        hier[pos][i]
                    );
                }
            }
        }
    }
}
