//! Real chunked ring all-reduce, generic over the rank-to-rank transport.
//!
//! Implements the schedule the paper's P-Reduce leans on (§3.2): the
//! buffer is split into `p` chunks; `p-1` reduce-scatter steps accumulate
//! each chunk onto one rank, then `p-1` all-gather steps broadcast the
//! finished chunks — `2(p-1)` total steps with `n/p` elements on every
//! edge per step, which is bandwidth-optimal.
//!
//! The schedule itself is pure ([`ring_allreduce_via`]) and runs over any
//! [`ChunkTransport`]:
//!
//! * [`ChannelTransport`] — mpsc channels between OS threads in one
//!   process; used by the thread runtime (`runtime::threaded`) and as the
//!   differential oracle for the fused `preduce_mean_inplace` path.
//!   Chunk buffers are *recycled* over a reverse channel per edge, so the
//!   steady state allocates nothing — matching the zero-copy TCP write
//!   path (`net::frame::write_chunk_coded`).
//! * `net::TcpRingTransport` — framed TCP streams between worker
//!   *processes*; the distributed data plane behind `ripples launch`
//!   (see DESIGN.md §Deployment).
//!
//! [`ring_allreduce_via_offset`] runs the same schedule with a step-tag
//! base, which is how `collectives::pipeline` runs K independent
//! per-shard schedules over one edge without tag collisions.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread;

use anyhow::{anyhow, Result};

use super::codec::WireCodec;

/// Typed transport failure: the group's collective was torn down by
/// failure repair (a peer died, or a ring neighbour poisoned the edge —
/// `net::frame::Frame::Poison`). Engines downcast for it
/// (`err.downcast_ref::<AbortedError>()`) to tell "restore the snapshot
/// and retry in a repaired group" from a fatal transport bug.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AbortedError {
    pub gid: u64,
}

impl std::fmt::Display for AbortedError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "group {} aborted: collective poisoned by failure repair", self.gid)
    }
}

impl std::error::Error for AbortedError {}

/// Chunk boundaries: chunk `c` covers `bounds(c).0 .. bounds(c).1`.
pub(crate) fn chunk_bounds(n: usize, p: usize, c: usize) -> (usize, usize) {
    let base = n / p;
    let rem = n % p;
    let start = c * base + c.min(rem);
    let len = base + usize::from(c < rem);
    (start, start + len)
}

/// A rank's pair of directed ring edges: send to successor, receive from
/// predecessor. `step` indexes the schedule (`0..2(p-1)`, plus a shard
/// offset under `collectives::pipeline`), letting framed transports tag
/// and verify ordering; in-memory transports may ignore it.
pub trait ChunkTransport {
    /// Ship `data` to the ring successor.
    fn send(&mut self, step: u32, data: &[f32]) -> Result<()>;
    /// Receive this step's chunk from the ring predecessor into `out`
    /// (replacing its contents). Taking a caller-owned buffer lets the
    /// schedule reuse one allocation across all `2(p-1)` steps.
    fn recv(&mut self, step: u32, out: &mut Vec<f32>) -> Result<()>;
}

/// In-process transport: one mpsc edge in, one out, plus reverse *spare*
/// edges that hand consumed chunk buffers back to their producer for
/// reuse (`send` pops a spare instead of allocating). A non-default
/// [`WireCodec`] applies its encode→decode precision loss to every sent
/// chunk — the numeric effect of the compressed TCP wire, byte shuffling
/// elided — so in-process rings are a differential oracle for the coded
/// data plane too.
pub struct ChannelTransport {
    /// Chunks to the ring successor.
    tx: Sender<Vec<f32>>,
    /// Chunks from the ring predecessor.
    rx: Receiver<Vec<f32>>,
    /// Consumed buffers handed back to the predecessor.
    spare_tx: Sender<Vec<f32>>,
    /// Our own buffers coming back from the successor.
    spare_rx: Receiver<Vec<f32>>,
    /// Wire codec whose precision loss `send` applies (`Fp32` = exact).
    wire: WireCodec,
}

impl ChannelTransport {
    /// Build the four ring edges for `p` ranks: rank `r` sends to
    /// `(r+1)%p` and receives from `(r-1+p)%p`, with a reverse spare
    /// channel along each data edge. Returns one transport per rank.
    pub fn ring(p: usize) -> Vec<ChannelTransport> {
        Self::ring_with(p, WireCodec::Fp32)
    }

    /// [`ChannelTransport::ring`] under a wire codec: every chunk is
    /// roundtripped through the codec before delivery.
    pub fn ring_with(p: usize, wire: WireCodec) -> Vec<ChannelTransport> {
        let mut data_tx: Vec<Option<Sender<Vec<f32>>>> = (0..p).map(|_| None).collect();
        let mut data_rx: Vec<Option<Receiver<Vec<f32>>>> = (0..p).map(|_| None).collect();
        let mut spare_tx: Vec<Option<Sender<Vec<f32>>>> = (0..p).map(|_| None).collect();
        let mut spare_rx: Vec<Option<Receiver<Vec<f32>>>> = (0..p).map(|_| None).collect();
        for r in 0..p {
            let succ = (r + 1) % p;
            let (dtx, drx) = channel();
            data_tx[r] = Some(dtx); // rank r's outbound edge
            data_rx[succ] = Some(drx); // delivered to the successor
            let (stx, srx) = channel();
            spare_tx[succ] = Some(stx); // successor returns spent buffers
            spare_rx[r] = Some(srx); // ...back to rank r
        }
        (0..p)
            .map(|r| ChannelTransport {
                tx: data_tx[r].take().unwrap(),
                rx: data_rx[r].take().unwrap(),
                spare_tx: spare_tx[r].take().unwrap(),
                spare_rx: spare_rx[r].take().unwrap(),
                wire,
            })
            .collect()
    }
}

impl ChunkTransport for ChannelTransport {
    fn send(&mut self, _step: u32, data: &[f32]) -> Result<()> {
        // Reuse a buffer the successor already consumed, if one came back.
        let mut buf = self.spare_rx.try_recv().unwrap_or_default();
        buf.clear();
        buf.extend_from_slice(data);
        if self.wire != WireCodec::Fp32 {
            self.wire.roundtrip_inplace(&mut buf);
        }
        self.tx.send(buf).map_err(|_| anyhow!("ring send: receiver hung up"))
    }

    fn recv(&mut self, _step: u32, out: &mut Vec<f32>) -> Result<()> {
        let incoming = self.rx.recv().map_err(|_| anyhow!("ring recv: sender hung up"))?;
        // Swap the delivered buffer in and recycle the consumed one back
        // to the predecessor (ignore a hung-up spare edge: recycling is
        // best-effort, correctness never depends on it).
        let spent = std::mem::replace(out, incoming);
        let _ = self.spare_tx.send(spent);
        Ok(())
    }
}

/// Run rank `r`'s side of the mean-all-reduce schedule over `transport`.
///
/// All `p` ranks must call this with the same buffer length; on success
/// every rank's `buf` holds the element-wise mean. Transport errors
/// propagate (a peer process dying mid-collective surfaces here rather
/// than deadlocking).
pub fn ring_allreduce_via<T: ChunkTransport>(
    r: usize,
    p: usize,
    buf: &mut [f32],
    transport: &mut T,
) -> Result<()> {
    ring_allreduce_via_offset(r, p, buf, transport, 0)
}

/// [`ring_allreduce_via`] with a step-tag base: step tags run
/// `base_step..base_step + 2(p-1)`. `collectives::pipeline` gives each
/// shard its own tag range so K per-shard schedules share one framed
/// edge without collisions; `base_step = 0` is the plain collective.
pub fn ring_allreduce_via_offset<T: ChunkTransport>(
    r: usize,
    p: usize,
    buf: &mut [f32],
    transport: &mut T,
    base_step: u32,
) -> Result<()> {
    ring_allreduce_via_div(r, p, buf, transport, base_step, p)
}

/// The same schedule with the mean divisor decoupled from the ring size:
/// the fully-reduced chunks are scaled by `1/divisor` instead of `1/p`.
/// `collectives::hier` runs the inter-node ring over `k` node leaders
/// whose buffers already hold *sums* of their node's members, so the
/// single division point must divide by the group total, not `k`.
/// `divisor == p` is exactly [`ring_allreduce_via_offset`].
pub fn ring_allreduce_via_div<T: ChunkTransport>(
    r: usize,
    p: usize,
    buf: &mut [f32],
    transport: &mut T,
    base_step: u32,
    divisor: usize,
) -> Result<()> {
    if p <= 1 {
        // Degenerate ring: nothing to exchange, but the divisor contract
        // still applies (a 1-leader inter ring must still form the mean).
        if divisor > 1 {
            let inv = 1.0 / divisor as f32;
            for b in buf.iter_mut() {
                *b *= inv;
            }
        }
        return Ok(());
    }
    let n = buf.len();
    let mut step = base_step;
    let mut incoming: Vec<f32> = Vec::new(); // reused across all steps
    // --- reduce-scatter: after step s, rank r has accumulated chunk
    //     (r - s) into a partial sum of s+2 contributions.
    for s in 0..p - 1 {
        let send_c = (r + p - s) % p;
        let (lo, hi) = chunk_bounds(n, p, send_c);
        transport.send(step, &buf[lo..hi])?;
        transport.recv(step, &mut incoming)?;
        let recv_c = (r + p - s - 1) % p;
        let (lo, hi) = chunk_bounds(n, p, recv_c);
        if incoming.len() != hi - lo {
            return Err(anyhow!(
                "ring step {step}: expected {} elements, got {}",
                hi - lo,
                incoming.len()
            ));
        }
        for (b, v) in buf[lo..hi].iter_mut().zip(incoming.iter()) {
            *b += v;
        }
        step += 1;
    }
    // Rank r now owns the fully-reduced chunk (r+1)%p; divide it to a mean.
    let owned = (r + 1) % p;
    let (lo, hi) = chunk_bounds(n, p, owned);
    let inv = 1.0 / divisor as f32;
    for b in buf[lo..hi].iter_mut() {
        *b *= inv;
    }
    // --- all-gather: circulate finished chunks.
    for s in 0..p - 1 {
        let send_c = (r + 1 + p - s) % p;
        let (lo, hi) = chunk_bounds(n, p, send_c);
        transport.send(step, &buf[lo..hi])?;
        transport.recv(step, &mut incoming)?;
        let recv_c = (r + p - s) % p;
        let (lo, hi) = chunk_bounds(n, p, recv_c);
        if incoming.len() != hi - lo {
            return Err(anyhow!(
                "ring step {step}: expected {} elements, got {}",
                hi - lo,
                incoming.len()
            ));
        }
        buf[lo..hi].copy_from_slice(&incoming);
        step += 1;
    }
    Ok(())
}

/// Run a mean-all-reduce over `bufs` using the ring schedule, one thread
/// per rank over in-memory channels. Buffers are updated in place; all end
/// up identical.
pub fn ring_allreduce_mean(bufs: &mut [Vec<f32>]) {
    let p = bufs.len();
    if p <= 1 {
        return;
    }
    let n = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == n), "ragged buffers");

    let transports = ChannelTransport::ring(p);
    thread::scope(|scope| {
        for ((r, buf), mut t) in bufs.iter_mut().enumerate().zip(transports) {
            scope.spawn(move || {
                ring_allreduce_via(r, p, buf, &mut t).expect("in-process ring");
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn rand_bufs(p: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Pcg32::new(seed);
        (0..p)
            .map(|_| (0..n).map(|_| rng.gen_f32() * 2.0 - 1.0).collect())
            .collect()
    }

    fn naive_mean(bufs: &[Vec<f32>]) -> Vec<f32> {
        let p = bufs.len();
        let n = bufs[0].len();
        (0..n)
            .map(|i| bufs.iter().map(|b| b[i]).sum::<f32>() / p as f32)
            .collect()
    }

    #[test]
    fn chunk_bounds_partition() {
        for n in [0usize, 1, 7, 16, 100] {
            for p in 1..=8 {
                let mut covered = 0;
                for c in 0..p {
                    let (lo, hi) = chunk_bounds(n, p, c);
                    assert_eq!(lo, covered, "n={n} p={p} c={c}");
                    covered = hi;
                }
                assert_eq!(covered, n);
            }
        }
    }

    #[test]
    fn ring_matches_naive_various_sizes() {
        for (p, n) in [(2usize, 10usize), (3, 7), (4, 64), (5, 1000), (8, 129)] {
            let mut bufs = rand_bufs(p, n, (p * 1000 + n) as u64);
            let expect = naive_mean(&bufs);
            ring_allreduce_mean(&mut bufs);
            for (r, buf) in bufs.iter().enumerate() {
                for i in 0..n {
                    assert!(
                        (buf[i] - expect[i]).abs() < 1e-5,
                        "p={p} n={n} rank={r} idx={i}: {} vs {}",
                        buf[i],
                        expect[i]
                    );
                }
            }
        }
    }

    #[test]
    fn ring_all_ranks_identical() {
        let mut bufs = rand_bufs(6, 333, 77);
        ring_allreduce_mean(&mut bufs);
        for r in 1..6 {
            assert_eq!(bufs[0], bufs[r], "rank {r} diverged");
        }
    }

    #[test]
    fn ring_singleton_and_pair() {
        let mut one = rand_bufs(1, 16, 5);
        let orig = one[0].clone();
        ring_allreduce_mean(&mut one);
        assert_eq!(one[0], orig);

        let mut two = vec![vec![1.0f32; 8], vec![3.0f32; 8]];
        ring_allreduce_mean(&mut two);
        assert!(two[0].iter().all(|&v| (v - 2.0).abs() < 1e-6));
        assert_eq!(two[0], two[1]);
    }

    #[test]
    fn ring_n_smaller_than_p() {
        // Degenerate chunking: some chunks are empty.
        let mut bufs = rand_bufs(8, 3, 9);
        let expect = naive_mean(&bufs);
        ring_allreduce_mean(&mut bufs);
        for buf in &bufs {
            for i in 0..3 {
                assert!((buf[i] - expect[i]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn ring_agrees_with_fused_preduce() {
        // Differential test: the collective schedule and the fused mean
        // must produce the same F^G result.
        let mut ring_bufs = rand_bufs(4, 501, 21);
        let mut a = ring_bufs[0].clone();
        let mut b = ring_bufs[1].clone();
        let mut c = ring_bufs[2].clone();
        let mut d = ring_bufs[3].clone();
        ring_allreduce_mean(&mut ring_bufs);
        let mut scratch = Vec::new();
        super::super::preduce_mean_inplace(
            &mut [&mut a, &mut b, &mut c, &mut d],
            &mut scratch,
        );
        for i in 0..501 {
            assert!((ring_bufs[0][i] - a[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn channel_transport_recycles_buffers() {
        // A pair ring is a closed loop: after the first exchange, every
        // send must reuse a buffer the peer handed back rather than
        // allocating. Observable via pointer stability: across many
        // steps, each side only ever sees the two original allocations.
        let mut transports = ChannelTransport::ring(2);
        let (mut b, mut a) = (transports.pop().unwrap(), transports.pop().unwrap());
        let payload = [1.0f32; 64];
        let mut seen: Vec<*const f32> = Vec::new();
        let mut out_a = Vec::new();
        let mut out_b = Vec::new();
        for step in 0..32u32 {
            a.send(step, &payload).unwrap();
            b.recv(step, &mut out_b).unwrap();
            b.send(step, &payload).unwrap();
            a.recv(step, &mut out_a).unwrap();
            assert_eq!(out_a.len(), 64);
            assert_eq!(out_b.len(), 64);
            let ptr = out_a.as_ptr();
            if !seen.contains(&ptr) {
                seen.push(ptr);
            }
        }
        // a's received buffers cycle among the few initial allocations
        // (the first rounds seed the pool; afterwards nothing is new)
        assert!(
            seen.len() <= 3,
            "buffers not recycled: {} distinct allocations over 32 steps",
            seen.len()
        );
    }

    #[test]
    fn div_schedule_with_divisor_p_is_bit_identical_to_offset() {
        // `ring_allreduce_via_offset` delegates with `divisor = p`; pin
        // that the delegation really is the old schedule bit-for-bit.
        let p = 4;
        let n = 257;
        let run = |via_div: bool| -> Vec<Vec<f32>> {
            let mut bufs = rand_bufs(p, n, 99);
            let transports = ChannelTransport::ring(p);
            thread::scope(|scope| {
                for ((r, buf), mut t) in bufs.iter_mut().enumerate().zip(transports) {
                    scope.spawn(move || {
                        if via_div {
                            ring_allreduce_via_div(r, p, buf, &mut t, 0, p).unwrap();
                        } else {
                            ring_allreduce_via_offset(r, p, buf, &mut t, 0).unwrap();
                        }
                    });
                }
            });
            bufs
        };
        let a = run(true);
        let b = run(false);
        for (x, y) in a.iter().zip(b.iter()) {
            for (u, v) in x.iter().zip(y.iter()) {
                assert_eq!(u.to_bits(), v.to_bits());
            }
        }
    }

    #[test]
    fn div_schedule_scales_by_divisor() {
        // Two ranks holding per-node *sums* of a 6-member group: the
        // inter ring must divide by 6, not 2, to form the group mean.
        let p = 2;
        let n = 64;
        let mut bufs = vec![vec![6.0f32; n], vec![12.0f32; n]];
        let transports = ChannelTransport::ring(p);
        thread::scope(|scope| {
            for ((r, buf), mut t) in bufs.iter_mut().enumerate().zip(transports) {
                scope.spawn(move || {
                    ring_allreduce_via_div(r, p, buf, &mut t, 0, 6).unwrap();
                });
            }
        });
        for buf in &bufs {
            assert!(buf.iter().all(|&v| (v - 3.0).abs() < 1e-6), "{:?}", &buf[..4]);
        }
        // degenerate 1-rank ring still applies the divisor
        let mut solo = vec![8.0f32; 8];
        let (tx, rx) = channel();
        let (spare_tx, spare_rx) = channel();
        let mut t =
            ChannelTransport { tx, rx, spare_tx, spare_rx, wire: WireCodec::Fp32 };
        ring_allreduce_via_div(0, 1, &mut solo, &mut t, 0, 4).unwrap();
        assert!(solo.iter().all(|&v| (v - 2.0).abs() < 1e-6));
    }

    /// A transport that injects a short payload mid-schedule.
    struct Lying {
        inner: ChannelTransport,
    }

    impl ChunkTransport for Lying {
        fn send(&mut self, step: u32, data: &[f32]) -> Result<()> {
            self.inner.send(step, data)
        }
        fn recv(&mut self, step: u32, out: &mut Vec<f32>) -> Result<()> {
            self.inner.recv(step, out)?;
            out.pop();
            Ok(())
        }
    }

    #[test]
    fn ring_rejects_wrong_chunk_size() {
        // Self-loop edge with a corrupting receiver: rank 0 of a fake
        // 2-rank ring immediately sees the truncated chunk and errors.
        let (tx, rx) = channel();
        let (spare_tx, spare_rx) = channel();
        let mut t = Lying {
            inner: ChannelTransport { tx, rx, spare_tx, spare_rx, wire: WireCodec::Fp32 },
        };
        let mut buf = vec![1.0f32; 10];
        let err = ring_allreduce_via(0, 2, &mut buf, &mut t);
        assert!(err.is_err(), "short chunk must be rejected");
    }
}
