//! Wire codecs for the P-Reduce data plane: how model elements are
//! represented on the wire (DESIGN.md §Perf, "Wire formats").
//!
//! The ring schedule is bandwidth-optimal in *transfers* (`2(p-1)` steps
//! of `n/p` elements), but every element still ships as a raw `f32` —
//! 4 bytes/parameter/step. On a constrained link the ring, not the
//! straggler, becomes the bottleneck (AD-PSGD and Hop both observe
//! decentralized training is communication-bound on slow networks), so
//! the data plane supports lossy compressed chunk formats:
//!
//! | codec  | bytes/elem | error bound per element                        |
//! |--------|------------|------------------------------------------------|
//! | `fp32` | 4          | exact (bit-identical, the golden default)      |
//! | `fp16` | 2          | `max(|x|·2⁻¹¹, 2⁻²⁴)`; saturates at ±65504     |
//! | `q8`   | 1 (+8/chunk header) | `(hi−lo)/510` per chunk `[lo, hi]`    |
//!
//! * **fp16** — IEEE-754 binary16 conversion with round-to-nearest-even,
//!   subnormals included. Overflow (and ±inf/NaN input) *saturates* to
//!   the largest finite half, ±65504 — the wire never carries a
//!   non-finite half, so a single huge gradient cannot poison a ring sum
//!   with `inf` ([`f32_to_f16_bits`] / [`f16_bits_to_f32`]).
//! * **q8** — per-chunk min/max-scaled 8-bit quantization: each wire
//!   chunk carries `(lo, scale)` and one byte per element,
//!   `q = round((x−lo)/scale·255)`, decoded as `lo + q·scale/255`.
//!   Deterministic (pure f32 arithmetic, no RNG) and total: NaN inputs
//!   quantize as 0.0 and ±inf clamp to ±[`Q8_CLAMP`] so `hi − lo` stays
//!   finite. The error bound is *relative to the chunk's dynamic range*,
//!   which is why the data plane quantizes per ring chunk (`n/p`
//!   elements) rather than per model: local ranges are tighter.
//!
//! When is `q8` safe? Whenever per-sync perturbations of order
//! `range/510` are small against the SGD step size — weight averaging is
//! a contraction, so the quantization noise does not accumulate across
//! syncs (EXPERIMENTS.md §Wire-sweep measures the loss gap). Partial
//! reduce-scatter sums are re-quantized at every hop, so worst-case
//! error grows with group size `p`; keep `q8` to small groups (the
//! paper's P-Reduce regime) or drop to `fp16`, whose error is relative
//! to each element rather than the chunk range.

use std::fmt;

/// Largest finite IEEE binary16 value (`0x7bff`).
pub const F16_MAX: f32 = 65504.0;
/// Relative error bound of fp16 round-to-nearest (half ulp, `2^-11`).
pub const F16_REL_ERR: f32 = 4.882_812_5e-4;
/// Absolute error bound of fp16 in the subnormal range (`2^-24`).
pub const F16_ABS_ERR: f32 = 5.960_464_5e-8;
/// q8 clamps inputs into `[-Q8_CLAMP, Q8_CLAMP]` so `hi - lo` is finite.
pub const Q8_CLAMP: f32 = f32::MAX / 2.0;

const F16_MAX_BITS: u16 = 0x7bff;

/// On-wire element representation for ring-collective chunks
/// (`--wire fp32|fp16|q8`, config `[wire] codec`). All members of a
/// cluster should agree; receivers decode whatever codec the sender
/// used (the frame tag carries it), so the knob only governs what each
/// worker *sends*.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WireCodec {
    /// Raw little-endian `f32` — the exact, golden-path default.
    #[default]
    Fp32,
    /// IEEE binary16 truncation (round-to-nearest-even, saturating).
    Fp16,
    /// Per-chunk min/max-scaled 8-bit quantization.
    Q8,
}

impl WireCodec {
    /// Parse the CLI/config spelling.
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s.to_ascii_lowercase().as_str() {
            "fp32" | "f32" | "raw" => WireCodec::Fp32,
            "fp16" | "f16" | "half" => WireCodec::Fp16,
            "q8" | "int8" | "i8" => WireCodec::Q8,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            WireCodec::Fp32 => "fp32",
            WireCodec::Fp16 => "fp16",
            WireCodec::Q8 => "q8",
        }
    }

    /// Bytes a chunk of `f32_bytes` worth of raw elements occupies on
    /// the wire under this codec (headers included for `q8`). The
    /// simulator's bytes-on-wire model.
    pub fn wire_bytes(&self, f32_bytes: usize) -> usize {
        let elems = f32_bytes / 4;
        match self {
            WireCodec::Fp32 => f32_bytes,
            WireCodec::Fp16 => elems * 2,
            WireCodec::Q8 => elems + 8, // + per-chunk (lo, scale)
        }
    }

    /// Apply the codec's encode→decode precision loss in place — the
    /// numeric effect of one wire hop without the byte shuffling. Used
    /// by the in-process [`ChannelTransport`](super::ring::ChannelTransport)
    /// and the simulator's coded averaging, so both share the exact
    /// arithmetic of the TCP path.
    pub fn roundtrip_inplace(&self, data: &mut [f32]) {
        match self {
            WireCodec::Fp32 => {}
            WireCodec::Fp16 => {
                for v in data.iter_mut() {
                    *v = f16_bits_to_f32(f32_to_f16_bits(*v));
                }
            }
            WireCodec::Q8 => {
                let (lo, scale) = q8_params(data);
                let step = scale / 255.0;
                for v in data.iter_mut() {
                    *v = lo + q8_quantize_one(*v, lo, scale) as f32 * step;
                }
            }
        }
    }
}

impl fmt::Display for WireCodec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Round-to-nearest-even increment: `base + 1` when the dropped bits
/// `rem` exceed `halfway`, or tie on an odd `base`.
fn rne(base: u32, rem: u32, halfway: u32) -> u32 {
    if rem > halfway || (rem == halfway && base & 1 == 1) {
        base + 1
    } else {
        base
    }
}

/// `f32` → IEEE binary16 bits, round-to-nearest-even. Overflow, ±inf
/// and NaN all saturate to the largest finite half (±[`F16_MAX`]) so
/// the wire never carries a non-finite value.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;
    if exp == 0xff {
        return sign | F16_MAX_BITS; // inf/NaN guard: stay finite
    }
    let unbiased = exp - 127;
    if unbiased >= 16 {
        return sign | F16_MAX_BITS; // overflow saturates
    }
    if unbiased >= -14 {
        // normal half: 10-bit mantissa, RNE over the 13 dropped bits
        let base = (((unbiased + 15) as u32) << 10) | (man >> 13);
        let rounded = rne(base, man & 0x1fff, 0x1000);
        if rounded >= 0x7c00 {
            return sign | F16_MAX_BITS; // rounded into inf: saturate
        }
        return sign | rounded as u16;
    }
    if unbiased < -25 {
        return sign; // below half the smallest subnormal: ±0
    }
    // subnormal half: value = significand · 2^(unbiased-23), renormalized
    // onto the 2^-24 grid (f32 subnormals land here too: exp 0 has no
    // implicit bit, but those values are < 2^-126, far under the cutoff)
    let sig = man | 0x0080_0000;
    let shift = (-(unbiased + 1)) as u32; // 14..=24
    let base = sig >> shift;
    let rem = sig & ((1u32 << shift) - 1);
    let rounded = rne(base, rem, 1u32 << (shift - 1));
    sign | rounded as u16
}

/// IEEE binary16 bits → `f32`, exact (every half is representable).
/// The decoder is total: inf/NaN bit patterns map to their IEEE values
/// even though [`f32_to_f16_bits`] never produces them.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h as u32) & 0x8000) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x3ff) as u32;
    let bits = match exp {
        0 => {
            if man == 0 {
                sign // ±0
            } else {
                // subnormal: man · 2^-24, exact in f32
                let v = man as f32 * (1.0 / 16_777_216.0);
                sign | v.to_bits()
            }
        }
        0x1f => sign | 0x7f80_0000 | (man << 13),
        _ => sign | ((exp + 112) << 23) | (man << 13),
    };
    f32::from_bits(bits)
}

/// Make a value safe for q8 range arithmetic: NaN → 0, ±inf (and
/// anything larger than [`Q8_CLAMP`]) clamps, so `hi - lo` is finite.
fn q8_sanitize(v: f32) -> f32 {
    if v.is_nan() {
        0.0
    } else {
        v.clamp(-Q8_CLAMP, Q8_CLAMP)
    }
}

/// Per-chunk quantization parameters `(lo, scale)` with
/// `scale = hi - lo ≥ 0`, over sanitized values. An empty chunk yields
/// `(0, 0)`.
pub fn q8_params(data: &[f32]) -> (f32, f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &v in data {
        let v = q8_sanitize(v);
        lo = lo.min(v);
        hi = hi.max(v);
    }
    if data.is_empty() {
        return (0.0, 0.0);
    }
    (lo, hi - lo)
}

/// Quantize one value against the chunk's `(lo, scale)`.
pub fn q8_quantize_one(v: f32, lo: f32, scale: f32) -> u8 {
    if scale <= 0.0 {
        return 0;
    }
    let t = (q8_sanitize(v) - lo) / scale * 255.0;
    t.round().clamp(0.0, 255.0) as u8
}

/// Dequantize `bytes` into `out` (replacing its contents).
pub fn q8_dequantize_into(bytes: &[u8], lo: f32, scale: f32, out: &mut Vec<f32>) {
    let step = scale / 255.0;
    out.clear();
    out.reserve(bytes.len());
    out.extend(bytes.iter().map(|&q| lo + q as f32 * step));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn fp16_exact_on_representable_values() {
        for v in [0.0f32, -0.0, 1.0, -2.5, 0.5, 65504.0, -65504.0, 2.0f32.powi(-14)] {
            let back = f16_bits_to_f32(f32_to_f16_bits(v));
            assert_eq!(back.to_bits(), v.to_bits(), "{v} not preserved");
        }
    }

    #[test]
    fn fp16_saturates_instead_of_overflowing() {
        for v in [65520.0f32, 1e9, f32::MAX, f32::INFINITY] {
            assert_eq!(f16_bits_to_f32(f32_to_f16_bits(v)), F16_MAX, "{v}");
            assert_eq!(f16_bits_to_f32(f32_to_f16_bits(-v)), -F16_MAX, "-{v}");
        }
        // NaN input also stays finite (the guard is about the wire)
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_finite());
    }

    #[test]
    fn fp16_subnormals_round_on_the_2neg24_grid() {
        // smallest subnormal half
        assert_eq!(f16_bits_to_f32(1), 2.0f32.powi(-24));
        // below half of it: rounds to zero
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(2.0f32.powi(-26))), 0.0);
        // exactly half of it: RNE tie to even (zero)
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(2.0f32.powi(-25))), 0.0);
        // between grid points: lands on the nearest one
        let v = 3.0 * 2.0f32.powi(-24);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(v)), v);
        // f32 subnormals underflow to zero (they are < 2^-126)
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(f32::from_bits(1))), 0.0);
    }

    #[test]
    fn fp16_error_within_documented_bound() {
        let mut rng = Pcg32::new(0xF16);
        for i in 0..4000 {
            let v = match i % 4 {
                0 => (rng.gen_f32() * 2.0 - 1.0) * 65000.0,
                1 => (rng.gen_f32() * 2.0 - 1.0) * 1.0,
                2 => (rng.gen_f32() * 2.0 - 1.0) * 1e-4,
                _ => (rng.gen_f32() * 2.0 - 1.0) * 2.0f32.powi(-16),
            };
            let back = f16_bits_to_f32(f32_to_f16_bits(v));
            let err = (back as f64 - v as f64).abs();
            let bound = (v.abs() as f64 * F16_REL_ERR as f64).max(F16_ABS_ERR as f64);
            assert!(err <= bound, "v={v} back={back} err={err} bound={bound}");
        }
    }

    #[test]
    fn q8_roundtrip_within_chunk_range_bound() {
        let mut rng = Pcg32::new(0x9_8);
        for _ in 0..200 {
            let n = rng.gen_range(257) + 1;
            let span = 10.0f32.powi(rng.gen_range(7) as i32 - 3);
            let data: Vec<f32> =
                (0..n).map(|_| (rng.gen_f32() * 2.0 - 1.0) * span).collect();
            let (lo, scale) = q8_params(&data);
            let step = scale / 255.0;
            let maxabs = data.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
            for &v in &data {
                let q = q8_quantize_one(v, lo, scale);
                let back = lo + q as f32 * step;
                let err = (back as f64 - v as f64).abs();
                let bound = scale as f64 / 500.0 + maxabs as f64 * 1e-5;
                assert!(err <= bound, "v={v} back={back} err={err} bound={bound}");
            }
        }
    }

    #[test]
    fn q8_degenerate_chunks() {
        // constant chunk: scale 0, every element decodes to lo exactly
        let data = [3.25f32; 9];
        let (lo, scale) = q8_params(&data);
        assert_eq!((lo, scale), (3.25, 0.0));
        let mut out = Vec::new();
        q8_dequantize_into(&[0, 0, 0], lo, scale, &mut out);
        assert_eq!(out, vec![3.25; 3]);
        // empty chunk
        assert_eq!(q8_params(&[]), (0.0, 0.0));
        // non-finite inputs stay total and finite
        let wild = [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 1.0];
        let (lo, scale) = q8_params(&wild);
        assert!(lo.is_finite() && scale.is_finite());
        for &v in &wild {
            let q = q8_quantize_one(v, lo, scale);
            let back = lo + q as f32 * (scale / 255.0);
            assert!(back.is_finite(), "{v} decoded non-finite");
        }
    }

    #[test]
    fn roundtrip_inplace_matches_scalar_paths() {
        let mut rng = Pcg32::new(7);
        let data: Vec<f32> = (0..64).map(|_| rng.gen_f32() * 4.0 - 2.0).collect();
        // fp32: untouched
        let mut a = data.clone();
        WireCodec::Fp32.roundtrip_inplace(&mut a);
        assert_eq!(a, data);
        // fp16: per-element conversion
        let mut b = data.clone();
        WireCodec::Fp16.roundtrip_inplace(&mut b);
        for (got, &v) in b.iter().zip(data.iter()) {
            assert_eq!(got.to_bits(), f16_bits_to_f32(f32_to_f16_bits(v)).to_bits());
        }
        // q8: chunk-wide params then per-element quantize
        let mut c = data.clone();
        WireCodec::Q8.roundtrip_inplace(&mut c);
        let (lo, scale) = q8_params(&data);
        for (got, &v) in c.iter().zip(data.iter()) {
            let want = lo + q8_quantize_one(v, lo, scale) as f32 * (scale / 255.0);
            assert_eq!(got.to_bits(), want.to_bits());
        }
    }

    #[test]
    fn parse_name_roundtrip_and_wire_bytes() {
        for codec in [WireCodec::Fp32, WireCodec::Fp16, WireCodec::Q8] {
            assert_eq!(WireCodec::parse(codec.name()), Some(codec));
        }
        assert_eq!(WireCodec::parse("int8"), Some(WireCodec::Q8));
        assert_eq!(WireCodec::parse("half"), Some(WireCodec::Fp16));
        assert_eq!(WireCodec::parse("nonsense"), None);
        assert_eq!(WireCodec::default(), WireCodec::Fp32);
        assert_eq!(WireCodec::Fp32.wire_bytes(4000), 4000);
        assert_eq!(WireCodec::Fp16.wire_bytes(4000), 2000);
        assert_eq!(WireCodec::Q8.wire_bytes(4000), 1008);
    }
}
