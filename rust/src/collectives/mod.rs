//! Data-plane collective operations over flat `f32` model buffers.
//!
//! Two implementations of the P-Reduce arithmetic:
//!
//! * [`preduce_mean_inplace`] — the fused single-pass mean the simulator's
//!   hot path uses (the paper's F^G applied directly).
//! * [`ring`] — a real chunked ring all-reduce: reduce-scatter then
//!   all-gather, the exact schedule the cost model charges for. The
//!   schedule is generic over a [`ring::ChunkTransport`]: in-memory
//!   channels (thread runtime, differential oracle for the fused path) or
//!   framed TCP streams between worker processes (`net`, the distributed
//!   data plane).
//! * [`pipeline`] — the same ring schedule pipelined over `K` model
//!   shards with per-shard step tags, plus the bounded-staleness
//!   reconcile that lets training overlap the transfer
//!   ([`pipeline::OverlapConfig`]; DESIGN.md §Perf).
//! * [`codec`] — wire codecs for chunk payloads ([`WireCodec`]:
//!   `fp32`/`fp16`/`q8`); both transports compress every chunk —
//!   pipelined shards included — under `--wire` (DESIGN.md §Perf,
//!   "Wire formats").
//! * [`hier`] — the two-level (intra-node reduce → inter-node ring →
//!   broadcast) execution of a topology-aware
//!   [`SyncPlan`](crate::topo::SyncPlan), built on the same
//!   [`ring::ChunkTransport`] and shard machinery (DESIGN.md §Perf,
//!   "Hierarchical P-Reduce").

pub mod codec;
pub mod hier;
pub mod pipeline;
pub mod ring;

pub use codec::WireCodec;
pub use pipeline::OverlapConfig;
pub use ring::AbortedError;

/// Block size for the fused mean: 8K floats (32 KiB) keeps the scratch
/// stripe resident in L1 while each member buffer streams through once.
/// Chosen by the §Perf sweep in EXPERIMENTS.md.
const MEAN_BLOCK: usize = 8192;

/// Apply F^G: replace every buffer in `bufs` with their element-wise mean.
///
/// Blocked two-pass: per `MEAN_BLOCK`-sized stripe, accumulate all members
/// into an L1-resident scratch stripe, scale, and broadcast back — each
/// member byte crosses DRAM exactly twice (read + write), and the scratch
/// traffic stays in cache. Scratch is caller-provided so the training hot
/// loop performs zero allocations.
pub fn preduce_mean_inplace(bufs: &mut [&mut [f32]], scratch: &mut Vec<f32>) {
    let g = bufs.len();
    if g <= 1 {
        return;
    }
    let n = bufs[0].len();
    debug_assert!(bufs.iter().all(|b| b.len() == n), "ragged buffers");
    let inv = 1.0 / g as f32;
    scratch.clear();
    scratch.resize(n.min(MEAN_BLOCK), 0.0);
    let mut lo = 0;
    while lo < n {
        let hi = (lo + MEAN_BLOCK).min(n);
        let stripe = &mut scratch[..hi - lo];
        stripe.copy_from_slice(&bufs[0][lo..hi]);
        for buf in bufs[1..].iter() {
            for (s, &v) in stripe.iter_mut().zip(buf[lo..hi].iter()) {
                *s += v;
            }
        }
        for s in stripe.iter_mut() {
            *s *= inv;
        }
        for buf in bufs.iter_mut() {
            buf[lo..hi].copy_from_slice(stripe);
        }
        lo = hi;
    }
}

/// Weighted F^G row: every buffer becomes `sum_g w[g] * buf[g]`.
pub fn preduce_weighted_inplace(
    bufs: &mut [&mut [f32]],
    weights: &[f32],
    scratch: &mut Vec<f32>,
) {
    let g = bufs.len();
    assert_eq!(g, weights.len());
    if g == 0 {
        return;
    }
    let n = bufs[0].len();
    scratch.clear();
    scratch.resize(n, 0.0);
    for (buf, &w) in bufs.iter().zip(weights.iter()) {
        for (s, &v) in scratch.iter_mut().zip(buf.iter()) {
            *s += w * v;
        }
    }
    for buf in bufs.iter_mut() {
        buf.copy_from_slice(scratch);
    }
}

/// Mean of `k` stacked buffers into `out` (the PS/All-Reduce gradient path).
pub fn mean_into(bufs: &[&[f32]], out: &mut [f32]) {
    let g = bufs.len();
    assert!(g > 0);
    out.copy_from_slice(bufs[0]);
    for buf in &bufs[1..] {
        for (o, &v) in out.iter_mut().zip(buf.iter()) {
            *o += v;
        }
    }
    let inv = 1.0 / g as f32;
    for o in out.iter_mut() {
        *o *= inv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn rand_buf(seed: u64, n: usize) -> Vec<f32> {
        let mut rng = Pcg32::new(seed);
        (0..n).map(|_| rng.gen_f32() * 2.0 - 1.0).collect()
    }

    #[test]
    fn mean_inplace_matches_naive() {
        let n = 1000;
        let mut a = rand_buf(1, n);
        let mut b = rand_buf(2, n);
        let mut c = rand_buf(3, n);
        let expect: Vec<f32> = (0..n).map(|i| (a[i] + b[i] + c[i]) / 3.0).collect();
        let mut scratch = Vec::new();
        preduce_mean_inplace(&mut [&mut a, &mut b, &mut c], &mut scratch);
        for i in 0..n {
            assert!((a[i] - expect[i]).abs() < 1e-6);
            assert_eq!(a[i], b[i]);
            assert_eq!(b[i], c[i]);
        }
    }

    #[test]
    fn mean_inplace_singleton_noop() {
        let mut a = rand_buf(1, 10);
        let orig = a.clone();
        let mut scratch = Vec::new();
        preduce_mean_inplace(&mut [&mut a], &mut scratch);
        assert_eq!(a, orig);
    }

    #[test]
    fn mean_preserves_ensemble_sum() {
        // Doubly-stochastic property: sum over replicas is invariant.
        let n = 257;
        let mut a = rand_buf(4, n);
        let mut b = rand_buf(5, n);
        let before: f64 = a.iter().chain(b.iter()).map(|&v| v as f64).sum();
        let mut scratch = Vec::new();
        preduce_mean_inplace(&mut [&mut a, &mut b], &mut scratch);
        let after: f64 = a.iter().chain(b.iter()).map(|&v| v as f64).sum();
        assert!((before - after).abs() < 1e-3);
    }

    #[test]
    fn weighted_uniform_equals_mean() {
        let n = 128;
        let mut a1 = rand_buf(7, n);
        let mut b1 = rand_buf(8, n);
        let mut a2 = a1.clone();
        let mut b2 = b1.clone();
        let mut s1 = Vec::new();
        let mut s2 = Vec::new();
        preduce_mean_inplace(&mut [&mut a1, &mut b1], &mut s1);
        preduce_weighted_inplace(&mut [&mut a2, &mut b2], &[0.5, 0.5], &mut s2);
        for i in 0..n {
            assert!((a1[i] - a2[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn mean_into_basic() {
        let a = vec![1.0f32, 2.0];
        let b = vec![3.0f32, 6.0];
        let mut out = vec![0.0f32; 2];
        mean_into(&[&a, &b], &mut out);
        assert_eq!(out, vec![2.0, 4.0]);
    }

    #[test]
    fn idempotent_after_first_apply() {
        let n = 64;
        let mut a = rand_buf(9, n);
        let mut b = rand_buf(10, n);
        let mut scratch = Vec::new();
        preduce_mean_inplace(&mut [&mut a, &mut b], &mut scratch);
        let snap = a.clone();
        preduce_mean_inplace(&mut [&mut a, &mut b], &mut scratch);
        assert_eq!(a, snap, "F^G F^G = F^G");
    }
}
