//! Minimal JSON parser for the artifact sidecars (`*.meta.json`).
//!
//! No serde in the vendored registry, and the sidecars are tiny and flat;
//! a ~150-line recursive-descent parser keeps the runtime self-contained.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

pub fn parse(s: &str) -> Result<Json, JsonError> {
    let mut p = Parser { b: s.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a UTF-8 run verbatim
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i]).unwrap());
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sidecar_shape() {
        let j = parse(
            r#"{"name": "mlp_train_step", "param_count": 22026,
                "inputs": [{"shape": [22026], "dtype": "float32"},
                           {"shape": [128, 32], "dtype": "float32"}],
                "use_pallas": false, "outputs": ["new_flat", "loss"]}"#,
        )
        .unwrap();
        assert_eq!(j.get("param_count").unwrap().as_usize(), Some(22026));
        let inputs = j.get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(inputs.len(), 2);
        assert_eq!(
            inputs[1].get("shape").unwrap().as_arr().unwrap()[1].as_usize(),
            Some(32)
        );
        assert_eq!(inputs[0].get("dtype").unwrap().as_str(), Some("float32"));
        assert_eq!(j.get("use_pallas").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("-1.5e2").unwrap().as_f64(), Some(-150.0));
        assert_eq!(parse(r#""a\nb""#).unwrap().as_str(), Some("a\nb"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn nested_and_unicode() {
        let j = parse(r#"{"a": [[1, 2], [3, [4]]], "s": "A"}"#).unwrap();
        assert_eq!(j.get("s").unwrap().as_str(), Some("A"));
        let a = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[1].as_arr().unwrap()[1].as_arr().unwrap()[0].as_f64(), Some(4.0));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }
}
